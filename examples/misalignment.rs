//! Fig. 1 + Fig. 2 reproduction: token misalignment, made visible.
//!
//! 1. **Fig. 1** — greedy (k=0) constraining vs minimally-invasive (k=∞):
//!    same model, same prompt; k=0 forbids bridge tokens, forcing
//!    sub-optimal tokenization, interventions and higher perplexity.
//! 2. **Fig. 2** — template-based generation: externally-forced template
//!    tokens vs the model-preferred ("naturalized", Alg. 3) tokenization
//!    of the same text.
//!
//! Run: `cargo run --release --example misalignment`

use domino::baselines::template::{person_program, TemplateProgram, TemplateRuntime};
use domino::domino::decoder::{Engine, Lookahead};
use domino::domino::generate::Prompt;
use domino::domino::{generate, DominoDecoder, GenConfig, MaskMode, Unconstrained};
use domino::eval::retokenize::retokenize;
use domino::eval::Setup;
use domino::grammar::builtin;
use domino::runtime::sampler::Sampling;
use domino::util::Rng;

const PROMPT: &str = "A person encoded as JSON object:\n";

fn show_tokens(vocab: &domino::tokenizer::Vocab, ids: &[domino::TokenId]) -> String {
    ids.iter().map(|&t| format!("[{}]", vocab.token_str(t).replace('\n', "\\n"))).collect()
}

fn main() -> domino::Result<()> {
    let setup = Setup::load();
    println!("backend: {}\n", setup.backend_name);
    let cfg = GenConfig { max_tokens: 64, sampling: Sampling::Greedy, mode: MaskMode::FullMask };
    let prompt = Prompt::healed(&setup.vocab, PROMPT);

    // ---------- Fig. 1 ----------
    println!("== Fig. 1: greedy constraining distorts tokenization ==\n");
    let mut lm = setup.session()?;
    let mut unc = Unconstrained::new(setup.vocab.len());
    let base = generate(lm.as_mut(), &mut unc, &setup.vocab, &prompt, &cfg, &mut Rng::new(1))?;
    println!("unconstrained      | ppl {:6.3} | {}", base.perplexity(), base.text().escape_debug());

    for (label, k) in [("domino k=inf", Lookahead::Infinite), ("greedy (k=0)", Lookahead::K(0))] {
        let engine = Engine::compile(builtin::json(), setup.vocab.clone())?;
        let mut lm = setup.session()?;
        let mut dec = DominoDecoder::new(engine, k);
        let r = generate(lm.as_mut(), &mut dec, &setup.vocab, &prompt, &cfg, &mut Rng::new(1))?;
        println!(
            "{label:<18} | ppl {:6.3} | interventions {:3} | {}",
            r.perplexity(),
            r.interventions,
            r.text().escape_debug()
        );
        if matches!(k, Lookahead::K(0)) {
            println!("  tokens: {}", show_tokens(&setup.vocab, &r.tokens));
        }
    }

    // ---------- Fig. 2 ----------
    // Token healing OFF and no prompt-joint encoding: this is the naive
    // template execution whose externally-forced tokenization Fig. 2
    // contrasts with the model-preferred one. Like the paper's (1a)/(1b),
    // the template's *phrasing* (here: spaced formatting) differs from
    // what the model would produce, so the forced tokens sit far off the
    // model's preferred distribution.
    println!("\n== Fig. 2: template-induced misalignment ==\n");
    let spaced = TemplateProgram::new()
        .lit("{ \"name\" : \"")
        .gen_stop("name", '"')
        .lit("\" , \"age\" : ")
        .gen("age", "[1-9][0-9]*")
        .lit(" }");
    let rt = TemplateRuntime::compile(spaced, setup.vocab.clone(), false)?;
    let mut lm = setup.session()?;
    let prompt_ids = setup.vocab.encode(PROMPT.as_bytes());
    let templ = rt.run(lm.as_mut(), &prompt_ids, Sampling::Greedy, &mut Rng::new(1))?;
    let _ = person_program;
    println!("template output: {}", templ.text.escape_debug());
    println!("  forced tokens {} + generated {} (model calls {})", templ.forced_tokens, templ.gen_tokens, templ.model_calls);
    println!("  template tokenization:    {}", show_tokens(&setup.vocab, &templ.tokens));

    let mut lm = setup.session()?;
    let nat = retokenize(lm.as_mut(), &setup.vocab, &prompt_ids, templ.text.as_bytes())?;
    println!("  naturalized tokenization: {}", show_tokens(&setup.vocab, &nat.tokens));
    println!(
        "\n  total logP — template: {:.2} ({} tokens) vs naturalized: {:.2} ({} tokens)",
        templ.logprob_sum,
        templ.tokens.len(),
        nat.logprob_sum,
        nat.tokens.len()
    );
    let diverge = templ.tokens != nat.tokens;
    println!("  tokenizations diverge: {diverge} (the Fig. 2 phenomenon)");
    Ok(())
}
