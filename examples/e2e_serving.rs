//! End-to-end serving driver — the repository's headline validation run.
//!
//! Loads the AOT-compiled model (trained at build time by
//! `make artifacts`), starts the serving engine, and pushes a mixed
//! workload of constrained requests through it, reporting per-method
//! latency, throughput, well-formedness and task accuracy — the
//! full-stack composition proof (L3 rust coordinator → PJRT runtime →
//! L2 JAX transformer → L1 Pallas kernels).
//!
//! Run: `cargo run --release --example e2e_serving` (after `make
//! artifacts`; falls back to the mock LM otherwise).

use domino::constraint::{Constraint, ConstraintSpec};
use domino::eval::{score, workload};
use domino::runtime::mock::{json_mock, MockFactory};
use domino::runtime::pjrt::{artifacts_dir, load_vocab, PjrtFactory, PjrtModel};
use domino::server::engine::{EngineCtx, GenRequest};
use domino::server::scheduler::{Scheduler, SchedulerConfig};
use domino::server::tcp;
use domino::server::trace::TraceConfig;
use domino::util::bench::Table;
use domino::util::Rng;
use std::io::{Read, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The tenants the workload is billed to (alternating), so the metrics
/// endpoint below has per-tenant series to prove out.
const TENANTS: [&str; 2] = ["interactive", "batch"];

/// Scrape `GET /metrics` from the example's own exporter and assert the
/// Prometheus exposition is well-formed and carries per-tenant labeled
/// counters — the end-to-end observability proof (CI runs this example,
/// so drift here fails the pipeline).
fn check_metrics_endpoint(addr: std::net::SocketAddr) -> domino::Result<()> {
    let mut conn = std::net::TcpStream::connect(addr)?;
    conn.set_read_timeout(Some(Duration::from_secs(10)))?;
    write!(conn, "GET /metrics HTTP/1.1\r\nHost: {addr}\r\n\r\n")?;
    let mut raw = String::new();
    conn.read_to_string(&mut raw)?;
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("no header/body split in scrape reply"))?;
    anyhow::ensure!(head.starts_with("HTTP/1.1 200"), "scrape status: {head}");
    anyhow::ensure!(
        head.contains("text/plain; version=0.0.4"),
        "scrape content type: {head}"
    );
    for needle in [
        "# HELP domino_requests_total ",
        "# TYPE domino_requests_total counter",
        "# TYPE domino_queue_wait_seconds histogram",
        "domino_queue_wait_seconds_bucket{le=\"+Inf\"}",
        "# TYPE domino_tick_seconds histogram",
        "# TYPE domino_batch_width histogram",
        "domino_tenant_requests_total{tenant=\"interactive\",outcome=\"completed\"}",
        "domino_tenant_requests_total{tenant=\"batch\",outcome=\"completed\"}",
        "domino_tenant_queue_wait_seconds_count{tenant=\"interactive\"}",
        "domino_grammar_requests_total{grammar=\"",
    ] {
        anyhow::ensure!(body.contains(needle), "missing `{needle}` in /metrics scrape");
    }
    println!(
        "metrics endpoint OK: {} bytes, {} series lines, per-tenant labels present",
        body.len(),
        body.lines().filter(|l| !l.starts_with('#') && !l.is_empty()).count()
    );
    Ok(())
}

/// Recover sole ownership of the scheduler for shutdown: a scrape
/// handler thread may still hold a short-lived strong clone right after
/// its response is read, so retry briefly.
fn into_inner(mut server: Arc<Scheduler>) -> Option<Scheduler> {
    for _ in 0..100 {
        match Arc::try_unwrap(server) {
            Ok(s) => return Some(s),
            Err(again) => {
                server = again;
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    None
}

fn main() -> domino::Result<()> {
    let have_artifacts = artifacts_dir().join("model_config.json").exists();
    // Shard count: DOMINO_ENGINES overrides; default 2 on the mock LM
    // (cheap per-shard state), 1 with real artifacts (each shard loads
    // its own thread-pinned PJRT model).
    let engines: usize = std::env::var("DOMINO_ENGINES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if have_artifacts { 1 } else { 2 });
    // With $DOMINO_ARTIFACT_DIR set, grammar engines persist across runs:
    // the first run compiles and writes back, every later run warm-starts
    // (watch `artifact_hits` / `warm start` in the closing metrics line).
    let precompute_dir = std::env::var_os("DOMINO_ARTIFACT_DIR").map(std::path::PathBuf::from);
    if let Some(dir) = &precompute_dir {
        eprintln!("persistent precompute artifacts: {}", dir.display());
    }
    // Tracing at full sample rate: every request records its span tree
    // and lands a Chrome trace-event JSON file for Perfetto under the
    // trace dir ($DOMINO_TRACE_DIR overrides the per-run temp default) —
    // the end-to-end observability artifact CI's integration tests
    // validate the format of.
    let trace_dir = std::env::var_os("DOMINO_TRACE_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::env::temp_dir().join(format!("domino-e2e-traces-{}", std::process::id()))
        });
    eprintln!("perfetto trace dir: {}", trace_dir.display());
    let cfg = SchedulerConfig {
        engines,
        slots_per_engine: 4, // serving slots per shard (continuous batching)
        queue_depth: 256,
        artifact_dir: precompute_dir,
        trace: TraceConfig {
            sample_rate: 1.0,
            trace_dir: Some(trace_dir.clone()),
            ..TraceConfig::default()
        },
        ..SchedulerConfig::default()
    };
    // One vocab Arc shared by every shard (registry keys hash the vocab
    // content, so equal copies would dedupe too — sharing avoids the
    // redundant fingerprint work).
    let server = if have_artifacts {
        let dir = artifacts_dir();
        let vocab = load_vocab(&dir)?;
        eprintln!("loading AOT bundle on {engines} shard(s): vocab {}", vocab.len());
        Scheduler::start(
            move |_shard, registry| {
                let model = PjrtModel::load(&dir)?;
                let factory = Box::new(PjrtFactory { model });
                Ok(EngineCtx::with_registry(factory, vocab.clone(), registry))
            },
            cfg,
        )
    } else {
        eprintln!(
            "no artifacts — using mock LM on {engines} shard(s) (run `make artifacts` for the real model)"
        );
        let (vocab, model) = json_mock(512);
        Scheduler::start(
            move |_shard, registry| {
                Ok(EngineCtx::with_registry(
                    Box::new(MockFactory { model: model.clone() }),
                    vocab.clone(),
                    registry,
                ))
            },
            cfg,
        )
    };

    // Shared ownership: the Prometheus exporter scrapes the same
    // scheduler the workload runs on.
    let server = Arc::new(server);
    let metrics_addr = tcp::spawn_metrics_http(server.clone(), "127.0.0.1:0")?;
    eprintln!("metrics endpoint: http://{metrics_addr}/metrics");

    // Warm the PJRT executables (first executions trigger TFRT lazy
    // initialization and would otherwise penalize the first method).
    // The warmup also asks for its trace on the wire path's terms
    // (`trace: true`), so the inline-summary plumbing is exercised
    // end to end.
    let warm = server.generate(GenRequest {
        prompt: "Q: warmup\nA: ".into(),
        constraint: Constraint::none(),
        max_tokens: 24,
        trace: true,
        ..Default::default()
    })?;
    let summary = warm.trace.ok_or_else(|| anyhow::anyhow!("warmup trace summary missing"))?;
    eprintln!("warmup trace summary: {summary}");

    let n = 20usize;
    let mut rng = Rng::new(42);
    let mut table = Table::new(&[
        "method", "requests", "ok", "accuracy", "well-formed", "tok/s", "p50 latency (s)",
        "interventions",
    ]);

    let methods: Vec<(&str, Constraint)> = vec![
        ("unconstrained", Constraint::none()),
        ("domino k=inf", Constraint::domino(ConstraintSpec::builtin("gsm8k"))),
        (
            "domino +spec s=8",
            Constraint::domino(ConstraintSpec::builtin("gsm8k")).with_speculation(8),
        ),
        // NOTE: on the serving path the online baseline shares the
        // engine's mask cache (states warmed by the DOMINO rows above
        // serve it too), so this row shows *served* online latency, not
        // the paper's raw online masking cost — Tables 2–4 in the benches
        // measure that uncached (see DESIGN.md).
        ("online (llama.cpp, cached)", Constraint::online(ConstraintSpec::builtin("gsm8k"))),
    ];

    for (label, constraint) in methods {
        // Fresh task sample per method, same seed → same tasks.
        let mut task_rng = Rng::new(7);
        let mut latencies = Vec::new();
        let mut correct = 0usize;
        let mut wf = 0usize;
        let mut ok = 0usize;
        let mut tokens = 0usize;
        let mut interventions = 0usize;
        let t0 = Instant::now();

        // Submit in waves of 4 (the slot count) — continuous batching
        // interleaves them.
        let mut pending = Vec::new();
        let mut tasks = Vec::new();
        for i in 0..n {
            let task = workload::math_task(&mut task_rng);
            let req = GenRequest {
                prompt: task.prompt(),
                constraint: constraint.clone(),
                max_tokens: 96,
                temperature: None,
                seed: rng.next_u64(),
                tenant: Some(TENANTS[i % TENANTS.len()].to_string()),
                ..Default::default()
            };
            tasks.push(task);
            pending.push(server.submit(req));
            if pending.len() == 4 || i + 1 == n {
                for (rx, task) in pending.drain(..).zip(tasks.drain(..)) {
                    let resp = rx.recv()?;
                    if resp.error.is_none() {
                        ok += 1;
                        tokens += resp.stats.tokens_out;
                        interventions += resp.stats.interventions;
                        latencies.push(resp.elapsed_s);
                        if score::math_correct(&task, &resp.text) {
                            correct += 1;
                        }
                        if score::well_formed_json(&resp.text, false) {
                            wf += 1;
                        }
                    }
                }
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = latencies.get(latencies.len() / 2).copied().unwrap_or(f64::NAN);
        table.row(&[
            label.to_string(),
            n.to_string(),
            ok.to_string(),
            format!("{:.2}", correct as f64 / n as f64),
            format!("{:.2}", wf as f64 / n as f64),
            format!("{:.1}", tokens as f64 / elapsed),
            format!("{p50:.2}"),
            interventions.to_string(),
        ]);
    }

    println!(
        "\n== e2e serving: GSM8K-style workload, {n} requests/method, {engines} shard(s) × 4 slots ==\n"
    );
    table.print();
    let m = server.metrics()?;
    println!("\nengine metrics (all shards): {}", m.report());
    check_metrics_endpoint(metrics_addr)?;

    // Every sampled request landed a Perfetto file; prove one loads as
    // Chrome trace-event JSON with the per-tick phase spans present
    // (`domino trace FILE` renders any of them as a text timeline).
    let mut traces: Vec<std::path::PathBuf> = std::fs::read_dir(&trace_dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("trace-") && n.ends_with(".json"))
        })
        .collect();
    traces.sort();
    anyhow::ensure!(!traces.is_empty(), "no trace-*.json under {}", trace_dir.display());
    let parsed = domino::util::Json::parse(&std::fs::read_to_string(&traces[0])?)?;
    let events = parsed
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| anyhow::anyhow!("trace file is not Chrome trace-event JSON"))?;
    for span in ["request", "decode", "tick", "decide", "gather", "forward", "finish"] {
        anyhow::ensure!(
            events.iter().any(|e| e.get("name").and_then(|n| n.as_str()) == Some(span)),
            "trace file missing `{span}` span"
        );
    }
    println!(
        "perfetto traces OK: {} files in {} ({} events in the first)",
        traces.len(),
        trace_dir.display(),
        events.len()
    );
    match into_inner(server) {
        Some(server) => server.shutdown(),
        None => eprintln!("warn: scrape handler still live; skipping explicit shutdown"),
    }
    Ok(())
}
