//! Quickstart: constrained generation in ~20 lines.
//!
//! ```sh
//! make artifacts           # once: train + AOT-compile the model
//! cargo run --release --example quickstart
//! ```
//!
//! Falls back to the built-in mock LM when artifacts are missing, so this
//! runs on a fresh checkout too.

use domino::domino::decoder::Lookahead;
use domino::domino::generate::Prompt;
use domino::domino::{generate, DominoDecoder, GenConfig};
use domino::eval::Setup;
use domino::util::Rng;

fn main() -> domino::Result<()> {
    // 1. Model + tokenizer (AOT bundle, or the mock fallback).
    let setup = Setup::load();
    println!("backend: {}", setup.backend_name);

    // 2. Grammar engine via the shared registry (offline precompute:
    //    scanner NFA + subterminal trees, §3.2-3.3 — compiled on first
    //    request, cached by content hash after that).
    let engine = setup.engine("json")?;

    // 3. Generate, constrained and minimally invasive (k = ∞).
    let mut lm = setup.session()?;
    let mut decoder = DominoDecoder::new(engine, Lookahead::Infinite);
    let prompt = Prompt::healed(&setup.vocab, "A person encoded as JSON object:\n");
    let result = generate(
        lm.as_mut(),
        &mut decoder,
        &setup.vocab,
        &prompt,
        &GenConfig::default(),
        &mut Rng::new(7),
    )?;

    println!("--- constrained output -------------------------------------");
    println!("{}", result.text());
    println!("--- stats ---------------------------------------------------");
    println!(
        "tokens: {} | interventions: {} | model calls: {} | perplexity: {:.3}",
        result.tokens.len(),
        result.interventions,
        result.model_calls,
        result.perplexity()
    );
    // The output is guaranteed valid JSON:
    let parsed = domino::util::Json::parse(result.text().trim())?;
    println!("parsed name: {:?}", parsed.get("name").and_then(|v| v.as_str()));
    Ok(())
}
