//! Constrained C code generation (the paper's hardest grammar, §4.3).
//!
//! Demonstrates DOMINO on the App. C C-subset grammar: the grammar engine
//! precompute is the slowest of the builtin set (the paper reports ~20 s
//! on a 32k vocab; here it is proportional to our vocab), and speculation
//! does not help — opportunistic masking is the right accelerator.
//!
//! Run: `cargo run --release --example c_codegen`

use domino::domino::decoder::{Engine, Lookahead};
use domino::domino::generate::Prompt;
use domino::domino::{generate, DominoDecoder, GenConfig, MaskMode};
use domino::eval::Setup;
use domino::grammar::builtin;
use domino::runtime::sampler::Sampling;
use domino::util::Rng;
use std::time::Instant;

fn main() -> domino::Result<()> {
    let setup = Setup::load();
    println!("backend: {}", setup.backend_name);

    let t0 = Instant::now();
    let engine = Engine::compile(builtin::c_lang(), setup.vocab.clone())?;
    println!(
        "C grammar precompute: {:.2}s ({} scanner positions, {} tree nodes, vocab {})",
        t0.elapsed().as_secs_f64(),
        engine.scanner.num_pos(),
        engine.trees.total_nodes(),
        setup.vocab.len()
    );

    let cfg =
        GenConfig { max_tokens: 96, sampling: Sampling::Temperature(0.9), mode: MaskMode::Opportunistic };
    for seed in 0..3 {
        let mut lm = setup.session()?;
        let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let prompt = Prompt::healed(&setup.vocab, "A simple C function:\n");
        let t0 = Instant::now();
        let r = generate(lm.as_mut(), &mut dec, &setup.vocab, &prompt, &cfg, &mut Rng::new(seed))?;
        println!(
            "\n--- sample {seed} ({} tokens, {:.1} tok/s, {} interventions, {} masks) ---",
            r.tokens.len(),
            r.tokens.len() as f64 / t0.elapsed().as_secs_f64(),
            r.interventions,
            r.masks_computed,
        );
        println!("{}", r.text());
    }
    Ok(())
}
