#!/usr/bin/env python3
"""Fail CI when a bench metric regresses more than THRESHOLD vs the
checked-in baseline.

Usage: bench_check.py BASELINE.json FRESH.json

Orientation is inferred from the metric name: ``*_ms`` metrics are
lower-is-better; everything else (``tok_s_*``, ``speedup``) is
higher-is-better. Metrics present on only one side are reported but not
gated, so a newly added bench seeds the baseline on the next refresh
instead of breaking the build. A baseline metric missing from the fresh
report fails: a bench silently stopped emitting. The top-level ``meta``
section is documentation, not data.

Only the Python standard library is used. The comparison logic lives in
:func:`compare` so ``test_bench_check.py`` can unit-test the gate that
guards the merge queue.
"""

import json
import sys

THRESHOLD = 0.25


def is_number(x):
    """A gateable metric value (bool is a JSON number to Python; exclude it)."""
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def sections(report):
    """The data sections of a report: top-level dicts, minus ``meta``."""
    return {
        name: metrics
        for name, metrics in report.items()
        if name != "meta" and isinstance(metrics, dict)
    }


def compare(base, fresh, threshold=THRESHOLD):
    """Diff ``fresh`` against ``base``; return (report_lines, failures).

    ``failures`` is non-empty when a shared metric regressed past
    ``threshold`` or a baseline metric vanished from the fresh report.
    """
    lines = []
    failures = []
    base_sections = sections(base)
    for section, metrics in sorted(sections(fresh).items()):
        base_section = base_sections.get(section, {})
        for name, value in sorted(metrics.items()):
            baseline = base_section.get(name)
            if not is_number(baseline) or not is_number(value):
                lines.append(f"  {section}.{name} = {value} (no baseline - not gated)")
                continue
            if baseline <= 0:
                lines.append(f"  {section}.{name}: baseline {baseline} unusable - not gated")
                continue
            lower_is_better = name.endswith("_ms")
            if lower_is_better:
                regressed = value > baseline * (1 + threshold)
                delta = (value - baseline) / baseline
            else:
                regressed = value < baseline * (1 - threshold)
                delta = (baseline - value) / baseline
            status = "REGRESSED" if regressed else "ok"
            arrow = "higher=worse" if lower_is_better else "lower=worse"
            lines.append(
                f"  {section}.{name}: baseline {baseline:.2f} -> {value:.2f} "
                f"[{arrow}] ({status})"
            )
            if regressed:
                failures.append(
                    f"{section}.{name} regressed {delta:.0%} "
                    f"(baseline {baseline:.2f}, now {value:.2f})"
                )

    # A baseline metric missing from the fresh report means a bench
    # stopped emitting (or its emit_json write failed) — exactly the
    # silent rot this gate exists to catch, so it fails too.
    fresh_sections = sections(fresh)
    for section, metrics in sorted(base_sections.items()):
        fresh_section = fresh_sections.get(section, {})
        for name, baseline in sorted(metrics.items()):
            if is_number(baseline) and name not in fresh_section:
                failures.append(
                    f"{section}.{name} is in the baseline but missing from the "
                    f"fresh report - did a bench stop emitting?"
                )

    return lines, failures


def main() -> None:
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} BASELINE.json FRESH.json")
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)

    lines, failures = compare(base, fresh)
    for line in lines:
        print(line)
    if failures:
        print(f"\nbench regression gate FAILED (threshold {THRESHOLD:.0%}):")
        for failure in failures:
            print(f"  {failure}")
        sys.exit(1)
    print(f"\nbench regression gate passed (threshold {THRESHOLD:.0%})")


if __name__ == "__main__":
    main()
