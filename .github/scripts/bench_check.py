#!/usr/bin/env python3
"""Fail CI when a bench metric regresses more than THRESHOLD vs the
checked-in baseline.

Usage: bench_check.py BASELINE.json FRESH.json

Orientation is inferred from the metric name: ``*_ms`` metrics are
lower-is-better; everything else (``tok_s_*``, ``speedup``) is
higher-is-better. Metrics present on only one side are reported but not
gated, so a newly added bench seeds the baseline on the next refresh
instead of breaking the build. The top-level ``meta`` section is
documentation, not data.

Only the Python standard library is used.
"""

import json
import sys

THRESHOLD = 0.25


def main() -> None:
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} BASELINE.json FRESH.json")
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)

    failures = []
    for section, metrics in sorted(fresh.items()):
        if section == "meta" or not isinstance(metrics, dict):
            continue
        base_section = base.get(section, {})
        if not isinstance(base_section, dict):
            base_section = {}
        for name, value in sorted(metrics.items()):
            baseline = base_section.get(name)
            if not isinstance(baseline, (int, float)) or not isinstance(value, (int, float)):
                print(f"  {section}.{name} = {value} (no baseline - not gated)")
                continue
            lower_is_better = name.endswith("_ms")
            if baseline <= 0:
                print(f"  {section}.{name}: baseline {baseline} unusable - not gated")
                continue
            if lower_is_better:
                regressed = value > baseline * (1 + THRESHOLD)
                delta = (value - baseline) / baseline
            else:
                regressed = value < baseline * (1 - THRESHOLD)
                delta = (baseline - value) / baseline
            status = "REGRESSED" if regressed else "ok"
            arrow = "higher=worse" if lower_is_better else "lower=worse"
            print(
                f"  {section}.{name}: baseline {baseline:.2f} -> {value:.2f} "
                f"[{arrow}] ({status})"
            )
            if regressed:
                failures.append(
                    f"{section}.{name} regressed {delta:.0%} "
                    f"(baseline {baseline:.2f}, now {value:.2f})"
                )

    # A baseline metric missing from the fresh report means a bench
    # stopped emitting (or its emit_json write failed) — exactly the
    # silent rot this gate exists to catch, so it fails too.
    for section, metrics in sorted(base.items()):
        if section == "meta" or not isinstance(metrics, dict):
            continue
        fresh_section = fresh.get(section)
        if not isinstance(fresh_section, dict):
            fresh_section = {}
        for name, baseline in sorted(metrics.items()):
            if isinstance(baseline, (int, float)) and name not in fresh_section:
                failures.append(
                    f"{section}.{name} is in the baseline but missing from the "
                    f"fresh report - did a bench stop emitting?"
                )

    if failures:
        print(f"\nbench regression gate FAILED (threshold {THRESHOLD:.0%}):")
        for failure in failures:
            print(f"  {failure}")
        sys.exit(1)
    print(f"\nbench regression gate passed (threshold {THRESHOLD:.0%})")


if __name__ == "__main__":
    main()
