#!/usr/bin/env python3
"""Documentation checks: markdown link integrity + PROTOCOL drift.

Stdlib-only (like bench_check.py) so it runs before the Rust toolchain
is even installed:

1. Every relative markdown link in the repo's .md files must resolve to
   an existing file (anchors are stripped; http(s)/mailto links are not
   fetched).
2. Every wire field documented in rust/PROTOCOL.md's tables must appear
   as a quoted string in rust/src/server/tcp.rs. This duplicates the
   tier-1 test in rust/tests/docs_drift.rs on purpose: the Python copy
   catches drift in docs-only PRs that skip the Rust jobs.
3. Every `src/*.rs` path named in rust/ARCHITECTURE.md (layer map and
   module table) must exist under rust/ — the architecture document may
   never describe a module that was moved or deleted. Same duplication
   rationale as the PROTOCOL check.

Usage: check_docs.py [repo_root]
Exit 0 when clean, 1 with a per-problem report otherwise.
"""

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FIELD_ROW_RE = re.compile(r"^\| `([a-z0-9_]+)`")
SRC_PATH_RE = re.compile(r"src/[A-Za-z0-9_./]*?\.rs")
SKIP_DIRS = {".git", "target", "node_modules"}


def md_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not SKIP_DIRS.intersection(p.name for p in path.parents):
            yield path


def strip_code_blocks(text: str) -> str:
    """Drop fenced code blocks — their bracketed text is not links."""
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return "\n".join(out)


def check_links(root: Path) -> list:
    problems = []
    for path in md_files(root):
        for target in LINK_RE.findall(strip_code_blocks(path.read_text())):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            resolved = (path.parent / target.split("#", 1)[0]).resolve()
            if not resolved.exists():
                rel = path.relative_to(root)
                problems.append(f"{rel}: broken link -> {target}")
    return problems


def check_protocol_fields(root: Path) -> list:
    protocol = root / "rust" / "PROTOCOL.md"
    tcp = root / "rust" / "src" / "server" / "tcp.rs"
    if not protocol.exists() or not tcp.exists():
        return [f"missing {protocol} or {tcp}"]
    tcp_src = tcp.read_text()
    fields = [
        m.group(1)
        for line in protocol.read_text().splitlines()
        if (m := FIELD_ROW_RE.match(line))
    ]
    problems = []
    if len(fields) < 25:
        problems.append(
            f"PROTOCOL.md: extracted only {len(fields)} fields — table format drift?"
        )
    for field in fields:
        if f'"{field}"' not in tcp_src:
            problems.append(f"PROTOCOL.md documents `{field}` but tcp.rs never names it")
    return problems


def check_architecture_paths(root: Path) -> list:
    architecture = root / "rust" / "ARCHITECTURE.md"
    if not architecture.exists():
        return [f"missing {architecture}"]
    paths = sorted(set(SRC_PATH_RE.findall(architecture.read_text())))
    problems = []
    if len(paths) < 20:
        problems.append(
            f"ARCHITECTURE.md: extracted only {len(paths)} source paths — format drift?"
        )
    for path in paths:
        if not (root / "rust" / path).exists():
            problems.append(f"ARCHITECTURE.md names `{path}` but it does not exist")
    return problems


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    problems = (
        check_links(root) + check_protocol_fields(root) + check_architecture_paths(root)
    )
    for problem in problems:
        print(f"FAIL {problem}")
    if problems:
        print(f"\n{len(problems)} documentation problem(s)")
        return 1
    count = sum(1 for _ in md_files(root))
    print(f"docs OK: {count} markdown files, links resolve, PROTOCOL matches tcp.rs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
