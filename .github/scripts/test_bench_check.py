#!/usr/bin/env python3
"""Unit tests for bench_check.py — the script gates the merge queue, so
it does not get to ship untested itself.

Run directly (``python3 .github/scripts/test_bench_check.py``) or via
unittest discovery; stdlib only.
"""

import unittest

from bench_check import THRESHOLD, compare


def failures(base, fresh):
    return compare(base, fresh)[1]


class CompareTest(unittest.TestCase):
    def test_within_threshold_passes(self):
        base = {"s": {"tok_s_1": 100.0, "cold_boot_ms": 10.0}}
        fresh = {"s": {"tok_s_1": 90.0, "cold_boot_ms": 12.0}}
        self.assertEqual(failures(base, fresh), [])

    def test_higher_is_better_regression_fails(self):
        base = {"batch_step": {"speedup": 2.0}}
        fresh = {"batch_step": {"speedup": 1.4}}  # -30% < -25%
        fails = failures(base, fresh)
        self.assertEqual(len(fails), 1)
        self.assertIn("batch_step.speedup regressed", fails[0])

    def test_lower_is_better_regression_fails(self):
        base = {"warm_start": {"warm_boot_ms": 10.0}}
        fresh = {"warm_start": {"warm_boot_ms": 14.0}}  # +40% > +25%
        fails = failures(base, fresh)
        self.assertEqual(len(fails), 1)
        self.assertIn("warm_start.warm_boot_ms regressed", fails[0])

    def test_improvement_passes_both_orientations(self):
        base = {"s": {"speedup": 2.0, "warm_boot_ms": 10.0}}
        fresh = {"s": {"speedup": 4.0, "warm_boot_ms": 2.0}}
        self.assertEqual(failures(base, fresh), [])

    def test_exactly_at_threshold_passes(self):
        base = {"s": {"tok_s_1": 100.0}}
        fresh = {"s": {"tok_s_1": 100.0 * (1 - THRESHOLD)}}
        self.assertEqual(failures(base, fresh), [])

    def test_new_metric_without_baseline_is_reported_not_gated(self):
        base = {"shard_scaling": {"tok_s_1": 100.0}}
        fresh = {
            "shard_scaling": {"tok_s_1": 100.0},
            "batch_step": {"speedup": 0.1},  # terrible, but unseeded
        }
        lines, fails = compare(base, fresh)
        self.assertEqual(fails, [])
        self.assertTrue(any("batch_step.speedup" in l and "not gated" in l for l in lines))

    def test_baseline_metric_missing_from_fresh_fails(self):
        base = {"batch_step": {"speedup": 2.0}}
        fresh = {"batch_step": {}}
        fails = failures(base, fresh)
        self.assertEqual(len(fails), 1)
        self.assertIn("stop emitting", fails[0])

    def test_missing_section_fails_per_metric(self):
        base = {"batch_step": {"speedup": 2.0, "tok_s_batched_8": 50.0}}
        fresh = {"other": {"x": 1.0}}
        self.assertEqual(len(failures(base, fresh)), 2)

    def test_meta_section_is_ignored(self):
        base = {"meta": {"host": 1.0}}
        fresh = {"meta": {}}
        self.assertEqual(failures(base, fresh), [])

    def test_non_numeric_and_bool_values_not_gated(self):
        base = {"s": {"name": "x", "flag": True, "tok_s_1": 100.0}}
        fresh = {"s": {"name": "y", "flag": False, "tok_s_1": 100.0}}
        self.assertEqual(failures(base, fresh), [])

    def test_zero_or_negative_baseline_not_gated(self):
        base = {"s": {"tok_s_1": 0.0, "speedup": -1.0}}
        fresh = {"s": {"tok_s_1": 1.0, "speedup": 1.0}}
        lines, fails = compare(base, fresh)
        self.assertEqual(fails, [])
        self.assertTrue(any("unusable" in l for l in lines))

    def test_schema_compile_section_orientation(self):
        # The schema_compile section mixes both orientations: *_ms
        # metrics gate upward moves, speedup gates downward moves.
        base = {
            "schema_compile": {
                "schema_to_cfg_ms": 50.0,
                "cold_compile_ms": 20000.0,
                "warm_hit_ms": 1.0,
                "speedup": 50.0,
            }
        }
        good = {
            "schema_compile": {
                "schema_to_cfg_ms": 40.0,
                "cold_compile_ms": 18000.0,
                "warm_hit_ms": 0.5,
                "speedup": 60.0,
            }
        }
        self.assertEqual(failures(base, good), [])
        bad = {
            "schema_compile": {
                "schema_to_cfg_ms": 100.0,  # +100% (lower is better)
                "cold_compile_ms": 20000.0,
                "warm_hit_ms": 1.0,
                "speedup": 10.0,  # -80% (higher is better)
            }
        }
        fails = failures(base, bad)
        self.assertEqual(len(fails), 2)
        self.assertTrue(any("schema_compile.schema_to_cfg_ms" in f for f in fails))
        self.assertTrue(any("schema_compile.speedup" in f for f in fails))

    def test_fig5_speculation_section_gates_draft_metrics(self):
        # All three draft-lane metrics are higher-is-better ratios/counts:
        # a drop past the threshold in any of them fails the gate.
        base = {
            "fig5_speculation": {
                "acceptance_rate": 0.5,
                "tok_per_tick_draft": 1.3,
                "draft_speedup": 1.75,
            }
        }
        good = {
            "fig5_speculation": {
                "acceptance_rate": 0.7,
                "tok_per_tick_draft": 2.0,
                "draft_speedup": 2.1,
            }
        }
        self.assertEqual(failures(base, good), [])
        bad = {
            "fig5_speculation": {
                "acceptance_rate": 0.2,  # -60%
                "tok_per_tick_draft": 1.3,
                "draft_speedup": 1.0,  # -43%
            }
        }
        fails = failures(base, bad)
        self.assertEqual(len(fails), 2)
        self.assertTrue(any("fig5_speculation.acceptance_rate" in f for f in fails))
        self.assertTrue(any("fig5_speculation.draft_speedup" in f for f in fails))

    def test_gateway_scale_section_orientation(self):
        # gateway_scale mixes orientations: the admission p99s gate
        # upward moves, idle_conns_held / scale_flatness gate downward
        # moves; conn_thread_delta and stream_sample_ms are deliberately
        # unseeded (gated inside the bench, reported here).
        base = {
            "gateway_scale": {
                "idle_conns_held": 256.0,
                "admission_p99_small_ms": 10.0,
                "admission_p99_large_ms": 10.0,
                "scale_flatness": 0.667,
            }
        }
        good = {
            "gateway_scale": {
                "idle_conns_held": 256.0,
                "admission_p99_small_ms": 1.0,
                "admission_p99_large_ms": 1.2,
                "scale_flatness": 0.83,
                "conn_thread_delta": 0.0,
                "stream_sample_ms": 40.0,
            }
        }
        lines, fails = compare(base, good)
        self.assertEqual(fails, [])
        self.assertTrue(
            any("conn_thread_delta" in l and "not gated" in l for l in lines)
        )
        bad = {
            "gateway_scale": {
                "idle_conns_held": 128.0,  # -50%: gateway held half the conns
                "admission_p99_small_ms": 10.0,
                "admission_p99_large_ms": 40.0,  # +300%: admission no longer flat
                "scale_flatness": 0.25,  # -62%
            }
        }
        fails = failures(base, bad)
        self.assertEqual(len(fails), 3)
        self.assertTrue(any("gateway_scale.idle_conns_held" in f for f in fails))
        self.assertTrue(any("gateway_scale.admission_p99_large_ms" in f for f in fails))
        self.assertTrue(any("gateway_scale.scale_flatness" in f for f in fails))

    def test_trace_overhead_section_orientation(self):
        # trace_overhead mixes orientations in one section: the overhead
        # ratios (disabled/sampled vs untraced throughput) gate downward
        # moves, capture_ms gates upward moves, and tok_s_untraced is
        # deliberately unseeded (absolute mock throughput is
        # runner-dependent; reported for the trajectory only).
        base = {
            "trace_overhead": {
                "disabled_ratio": 0.95,
                "sampled_ratio": 0.90,
                "capture_ms": 5.0,
            }
        }
        good = {
            "trace_overhead": {
                "disabled_ratio": 0.99,
                "sampled_ratio": 0.97,
                "capture_ms": 1.0,
                "tok_s_untraced": 5000.0,
            }
        }
        lines, fails = compare(base, good)
        self.assertEqual(fails, [])
        self.assertTrue(
            any("tok_s_untraced" in l and "not gated" in l for l in lines)
        )
        bad = {
            "trace_overhead": {
                "disabled_ratio": 0.60,  # -37%: disabled tracing got expensive
                "sampled_ratio": 0.90,
                "capture_ms": 20.0,  # +300%: perfetto render blew up
            }
        }
        fails = failures(base, bad)
        self.assertEqual(len(fails), 2)
        self.assertTrue(any("trace_overhead.disabled_ratio" in f for f in fails))
        self.assertTrue(any("trace_overhead.capture_ms" in f for f in fails))

    def test_custom_threshold(self):
        base = {"s": {"tok_s_1": 100.0}}
        fresh = {"s": {"tok_s_1": 89.0}}
        self.assertEqual(failures(base, fresh), [])  # default 25%
        self.assertEqual(len(compare(base, fresh, threshold=0.10)[1]), 1)


if __name__ == "__main__":
    unittest.main()
