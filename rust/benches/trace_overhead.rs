//! The tracing subsystem must be paid for: this bench proves (a) the
//! serving loop with tracing compiled in but *disabled* (the shipped
//! default) stays within 3% of the raw untraced tick path, and (b) 1%
//! head sampling stays within 10% — both gated (`exit 1`) so a
//! regression that makes the hot path pay for observability fails CI.
//!
//! Three configurations decode the same 8-slot JSON-grammar workload
//! with identical seeds:
//!
//! * **untraced** — the bare `step_batched` tick loop over pre-built
//!   slots (no engine bookkeeping, no tracer): the pure tick-throughput
//!   baseline, as measured by `benches/batch_step.rs`.
//! * **disabled** — `EngineCore` with `Tracer::disabled()`:
//!   `Tracer::begin` returns `None` for every request, so the only
//!   tracing cost is one branch per request plus the always-on
//!   per-phase tick timing that feeds `{"op":"stats"}`.
//! * **sampled** — `EngineCore` at `sample_rate = 0.01`. With a fresh
//!   tracer per run the deterministic 1-in-100 head sampler captures
//!   request id 1, i.e. 1 of the 8 requests records spans + per-token
//!   decisions — a conservative 12.5% effective rate, well above the
//!   nominal 1%.
//!
//! Both ratios gate against the untraced baseline: disabled ≥ 0.97×,
//! sampled ≥ 0.90× (`DOMINO_BENCH_TRACE_RATIO` overrides both bars —
//! the bench-smoke CI job relaxes them because loaded runners
//! time-slice the passes differently). The sampled run must also be
//! byte-identical to the disabled run: tracing may never change tokens.
//!
//! `cargo bench --bench trace_overhead` (env `DOMINO_BENCH_ITERS`
//! overrides the repetition count; `DOMINO_BENCH_JSON` appends
//! machine-readable results for the CI trend file).

use domino::constraint::{Constraint, ConstraintSpec};
use domino::domino::generate::Prompt;
use domino::runtime::mock::{json_mock, MockFactory};
use domino::runtime::sampler::Sampling;
use domino::server::engine::{EngineCore, EngineCtx, GenRequest, GenResponse, Work};
use domino::server::slot::{step_batched, Slot};
use domino::server::trace::{render_timeline, TraceConfig, Tracer};
use domino::util::bench::{emit_json, Table};
use domino::util::Json;
use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Arc};
use std::time::Instant;

const SLOTS: usize = 8;
const MAX_TOKENS: usize = 48;

fn constraint() -> Constraint {
    Constraint::domino(ConstraintSpec::builtin("json"))
}

/// Untraced baseline: decode `SLOTS` pre-built slots to completion with
/// the raw batched tick loop. Returns (seconds, tokens).
fn run_untraced(ctx: &mut EngineCtx) -> (f64, usize) {
    let c = constraint();
    let mut slots: Vec<Slot> = (0..SLOTS)
        .map(|i| {
            let mode = ctx.decode_mode(&c).expect("decode mode");
            let session = ctx.backend.new_session().expect("session");
            let prompt = Prompt::healed(&ctx.vocab, "");
            Slot::new(
                i as u64,
                session,
                mode,
                ctx.vocab.clone(),
                &prompt,
                Sampling::Greedy,
                MAX_TOKENS,
                i as u64,
            )
            .expect("slot")
        })
        .collect();
    let t0 = Instant::now();
    while slots.iter().any(|s| !s.done) {
        let mut view: Vec<&mut Slot> = slots.iter_mut().collect();
        let tick = step_batched(ctx.backend.as_ref(), &mut view);
        assert!(tick.all_ok(), "untraced step failed");
    }
    let secs = t0.elapsed().as_secs_f64();
    (secs, slots.iter().map(|s| s.stats.tokens_out).sum())
}

/// Traced run: the same workload through `EngineCore` wired to `tracer`.
/// Admission (compile, prefill, trace begin) happens before the clock
/// starts so both paths time exactly the decode loop. Returns
/// (seconds, tokens, texts).
fn run_core(ctx: EngineCtx, tracer: Arc<Tracer>) -> (f64, usize, Vec<String>) {
    let mut core = EngineCore::with_tracer(ctx, SLOTS, tracer.clone());
    let mut rxs: Vec<mpsc::Receiver<GenResponse>> = Vec::with_capacity(SLOTS);
    for i in 0..SLOTS {
        let req = GenRequest {
            constraint: constraint(),
            max_tokens: MAX_TOKENS,
            seed: i as u64,
            ..GenRequest::default()
        };
        let (tx, rx) = mpsc::channel();
        let trace = tracer.begin(req.trace, "default");
        core.admit(Work {
            req,
            resp: tx,
            sink: None,
            cancel: Arc::new(AtomicBool::new(false)),
            enqueued: Instant::now(),
            deadline: None,
            trace,
        });
        rxs.push(rx);
    }
    assert_eq!(core.active_len(), SLOTS, "all requests admitted");
    let t0 = Instant::now();
    while core.active_len() > 0 {
        core.step_all();
        core.reap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let mut tokens = 0;
    let mut texts = Vec::with_capacity(SLOTS);
    for rx in rxs {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "traced decode failed: {:?}", resp.error);
        tokens += resp.stats.tokens_out;
        texts.push(resp.text);
    }
    (secs, tokens, texts)
}

fn main() {
    let iters: u32 =
        std::env::var("DOMINO_BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(5).max(1);
    let bar_override: Option<f64> =
        std::env::var("DOMINO_BENCH_TRACE_RATIO").ok().and_then(|s| s.parse().ok());
    let disabled_bar = bar_override.unwrap_or(0.97);
    let sampled_bar = bar_override.unwrap_or(0.90);
    let (vocab, model) = json_mock(2048);
    println!(
        "== trace overhead: {SLOTS} slots × {MAX_TOKENS} tokens, vocab {}, best of {iters} runs ==\n",
        vocab.len()
    );

    let ctx = || EngineCtx::new(Box::new(MockFactory { model: model.clone() }), vocab.clone());
    let mut untraced_best = f64::MAX;
    let mut disabled_best = f64::MAX;
    let mut sampled_best = f64::MAX;
    let mut tokens = 0usize;
    let mut sampled_tracer = Tracer::disabled();
    for _ in 0..iters {
        let (secs, toks) = run_untraced(&mut ctx());
        untraced_best = untraced_best.min(secs);
        tokens = toks;

        let (secs, toks_d, texts_d) = run_core(ctx(), Tracer::disabled());
        disabled_best = disabled_best.min(secs);
        assert_eq!(toks, toks_d, "engine path must commit the same tokens as the raw loop");

        // Fresh tracer each run so the deterministic sampler always
        // captures request id 1 (1 of SLOTS traced per run).
        let tracer = Tracer::new(TraceConfig { sample_rate: 0.01, ..TraceConfig::default() });
        let (secs, toks_s, texts_s) = run_core(ctx(), tracer.clone());
        sampled_best = sampled_best.min(secs);
        assert_eq!(toks_d, toks_s, "sampling must not change the token count");
        assert_eq!(texts_d, texts_s, "tracing on vs off must be byte-identical");
        sampled_tracer = tracer;
    }

    let recent = sampled_tracer.recent();
    assert_eq!(recent.len(), 1, "1-in-100 sampling captures exactly request id 1 of 8");
    let trace = &recent[0];
    assert_eq!(trace.decisions.len(), tokens / SLOTS, "one decision per emitted token");

    // Capture cost: render the captured trace to Perfetto JSON and back
    // through the timeline renderer — the work `--trace-dir` pays per
    // captured request.
    let t0 = Instant::now();
    const RENDERS: u32 = 20;
    for _ in 0..RENDERS {
        let perfetto = trace.perfetto();
        let parsed = Json::parse(&perfetto).expect("perfetto output parses");
        let _ = render_timeline(&parsed).expect("timeline renders");
    }
    let capture_ms = t0.elapsed().as_secs_f64() * 1e3 / RENDERS as f64;

    let tok_s_untraced = tokens as f64 / untraced_best.max(1e-9);
    let tok_s_disabled = tokens as f64 / disabled_best.max(1e-9);
    let tok_s_sampled = tokens as f64 / sampled_best.max(1e-9);
    let disabled_ratio = tok_s_disabled / tok_s_untraced.max(1e-9);
    let sampled_ratio = tok_s_sampled / tok_s_untraced.max(1e-9);

    let mut table = Table::new(&["configuration", "tokens", "best (ms)", "tok/s", "vs untraced"]);
    for (name, best, tok_s, ratio) in [
        ("untraced (raw tick loop)", untraced_best, tok_s_untraced, 1.0),
        ("tracer disabled (default)", disabled_best, tok_s_disabled, disabled_ratio),
        ("1% head sampling", sampled_best, tok_s_sampled, sampled_ratio),
    ] {
        table.row(&[
            name.into(),
            tokens.to_string(),
            format!("{:.2}", best * 1e3),
            format!("{tok_s:.0}"),
            format!("{ratio:.3}x"),
        ]);
    }
    table.print();
    println!("\ncapture cost (perfetto render + timeline): {capture_ms:.3} ms/trace");

    emit_json(
        "trace_overhead",
        &[
            ("disabled_ratio", disabled_ratio),
            ("sampled_ratio", sampled_ratio),
            ("tok_s_untraced", tok_s_untraced),
            ("capture_ms", capture_ms),
        ],
    );

    let mut pass = true;
    for (name, ratio, bar) in
        [("disabled", disabled_ratio, disabled_bar), ("sampled", sampled_ratio, sampled_bar)]
    {
        let ok = ratio >= bar;
        println!(
            "{name} tracing throughput: {ratio:.3}x untraced (acceptance bar: >= {bar}x) — {}",
            if ok { "PASS" } else { "FAIL" }
        );
        pass &= ok;
    }
    if !pass {
        std::process::exit(1);
    }
}
