//! Table 2 reproduction: task accuracy of constrained decoding methods on
//! the GSM8K-style and CoNLL-style workloads.
//!
//! Paper row set: Unconstrained / GUIDANCE / GUIDANCE WS / llama.cpp /
//! DOMINO (k=∞). Reported: accuracy, well-formed rate, perplexity, and
//! throughput relative to unconstrained on the same backend.
//!
//! `cargo bench --bench table2_accuracy` (uses the AOT model when
//! artifacts are present; `DOMINO_BENCH_N` overrides the sample count).

use domino::domino::decoder::Lookahead;
use domino::eval::harness::{eval_task, eval_throughput, Method, Setup};
use domino::util::bench::Table;

fn main() {
    let setup = Setup::load();
    let n: usize =
        std::env::var("DOMINO_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(40);
    println!("== Table 2: task accuracy (backend: {}, n={n} per row) ==\n", setup.backend_name);

    let methods = [
        Method::Unconstrained,
        Method::Guidance { ws: false },
        Method::Guidance { ws: true },
        Method::Online { opportunistic: true },
        Method::Domino { k: Lookahead::Infinite, spec: None, opportunistic: true },
        Method::Domino { k: Lookahead::Infinite, spec: Some(8), opportunistic: true },
    ];

    for task in ["gsm8k", "conll"] {
        let mut table = Table::new(&[
            "Method", "Accuracy", "Well-Formed", "Perplexity", "tok/s", "Perf impact",
        ]);
        let mut base_tps = None;
        for method in &methods {
            let row = match eval_task(&setup, method, task, n, 96, 1234) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("  {}: {e:#}", method.label());
                    continue;
                }
            };
            if matches!(method, Method::Unconstrained) {
                base_tps = Some(row.toks_per_s);
            }
            let impact = base_tps
                .map(|b| format!("{:.2}x", row.toks_per_s / b))
                .unwrap_or_else(|| "-".into());
            table.row(&[
                method.label(),
                format!("{:.3}", row.accuracy),
                format!("{:.3}", row.well_formed),
                format!("{:.3}", row.perplexity),
                format!("{:.1}", row.toks_per_s),
                impact,
            ]);
        }
        println!("-- {task} --");
        table.print();
        println!();
    }
    // Dense-terminal lanes: the builtin `c` grammar and the
    // schema-derived `function_call` CFG have many terminals with big
    // scanner DFAs, so they exercise the wordwise mask kernels and the
    // lazy-DFA path hardest. Free-format throughput (no task oracle to
    // score these against), DOMINO vs the online baseline.
    for grammar in ["c", "function_call"] {
        let mut table = Table::new(&["Method", "Well-Formed", "tok/s", "Perf impact"]);
        let mut base_tps = None;
        for method in [
            Method::Unconstrained,
            Method::Online { opportunistic: true },
            Method::Domino { k: Lookahead::Infinite, spec: None, opportunistic: true },
        ] {
            let row = match eval_throughput(&setup, &method, grammar, n, 96, 1234) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("  {}: {e:#}", method.label());
                    continue;
                }
            };
            if matches!(method, Method::Unconstrained) {
                base_tps = Some(row.toks_per_s);
            }
            let impact = base_tps
                .map(|b| format!("{:.2}x", row.toks_per_s / b))
                .unwrap_or_else(|| "-".into());
            table.row(&[
                method.label(),
                format!("{:.3}", row.well_formed),
                format!("{:.1}", row.toks_per_s),
                impact,
            ]);
        }
        println!("-- {grammar} (dense-terminal, free-format) --");
        table.print();
        println!();
    }
    println!(
        "expected shape (paper Table 2): DOMINO k=inf accuracy >= unconstrained;\n\
         GUIDANCE templates lose accuracy; WS recovers some at lower throughput;\n\
         speculation raises DOMINO throughput above 1x."
    );
}
