//! Schema-constraint compile bench: cold compile vs warm registry hit
//! for a realistic function-calling JSON Schema.
//!
//! "Cold" is the full front-end + engine build a fresh schema pays once:
//! schema parse → normalize → CFG emit → scanner DFAs → subterminal
//! trees. "Warm" is what every later request with the same schema (any
//! spelling — the fingerprint is canonical) pays: one registry hash
//! probe. The bench also isolates the new front-end's own cost
//! (schema → CFG) so regressions in the compiler are attributable.
//!
//! `cargo bench --bench schema_compile`; env `DOMINO_BENCH_ITERS`
//! overrides the repetition count, `DOMINO_BENCH_JSON` appends the
//! `schema_compile` section for the CI trend file, and
//! `DOMINO_BENCH_SCHEMA_RATIO` overrides the warm-vs-cold speedup bar
//! (default 25× — a hash probe vs a grammar compile; generous enough
//! for loaded CI runners).

use domino::constraint::{ConstraintSpec, EngineRegistry};
use domino::eval::workload::FUNCTION_CALL_SCHEMA;
use domino::tokenizer;
use domino::util::bench::{emit_json, time_it, Table};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let iters: u32 =
        std::env::var("DOMINO_BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(3).max(1);
    let vocab = Arc::new(tokenizer::bpe::synthetic_json_vocab(512));
    let spec = ConstraintSpec::json_schema(FUNCTION_CALL_SCHEMA);
    println!(
        "== schema-compile: function-calling schema, vocab {}, best of {iters} ==\n",
        vocab.len()
    );

    // Front-end alone: schema source → CFG (parse + normalize + emit).
    let front = time_it(1, iters.max(10), || {
        std::hint::black_box(spec.to_cfg().expect("schema compiles"));
    });
    let schema_to_cfg_ms = front.min.as_secs_f64() * 1e3;

    // Cold: fresh registry per iteration — the full engine build.
    let mut cold_ms = f64::MAX;
    for _ in 0..iters {
        let reg = EngineRegistry::new(4);
        let t0 = Instant::now();
        reg.get_or_compile(&spec, &vocab, None).unwrap();
        cold_ms = cold_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }

    // Warm: one registry, many hits — also proves (via counters) that a
    // reordered spelling of the same schema is the same cache entry.
    let reg = EngineRegistry::new(4);
    reg.get_or_compile(&spec, &vocab, None).unwrap();
    let reordered = ConstraintSpec::json_schema(
        domino::util::Json::parse(FUNCTION_CALL_SCHEMA).unwrap().to_string(),
    );
    let hits = 1000u32;
    let warm = time_it(10, hits, || {
        std::hint::black_box(reg.get_or_compile(&reordered, &vocab, None).unwrap());
    });
    let warm_hit_ms = warm.mean.as_secs_f64() * 1e3;
    let s = reg.stats();
    assert_eq!(s.misses, 1, "every warm lookup must hit the one compiled entry: {s:?}");
    assert!(s.hits >= hits as u64, "{s:?}");

    let speedup = cold_ms / warm_hit_ms.max(1e-9);
    let mut table = Table::new(&["stage", "time (ms)", "vs cold"]);
    table.row(&["schema → CFG (front-end)".into(), format!("{schema_to_cfg_ms:.3}"), "".into()]);
    table.row(&["cold compile (full engine)".into(), format!("{cold_ms:.2}"), "1.00x".into()]);
    table.row(&["warm registry hit".into(), format!("{warm_hit_ms:.4}"), format!("{speedup:.0}x")]);
    table.print();

    emit_json(
        "schema_compile",
        &[
            ("schema_to_cfg_ms", schema_to_cfg_ms),
            ("cold_compile_ms", cold_ms),
            ("warm_hit_ms", warm_hit_ms),
            ("speedup", speedup),
        ],
    );

    let bar: f64 = std::env::var("DOMINO_BENCH_SCHEMA_RATIO")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(25.0);
    let pass = speedup >= bar;
    println!(
        "\nwarm-hit speedup: {speedup:.0}x (acceptance bar: >= {bar}x) — {}",
        if pass { "PASS" } else { "FAIL" }
    );
    if !pass {
        std::process::exit(1);
    }
}
