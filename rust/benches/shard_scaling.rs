//! Shard-scaling bench: aggregate serving throughput as the scheduler
//! grows from 1 to 4 engine shards on the mock runtime, under a
//! mixed-grammar workload (requests spread across four builtin grammars
//! so grammar-affinity routing has something to route).
//!
//! Also reports the shared registry's miss count per run: it must equal
//! the number of distinct grammars regardless of shard count — one
//! compile per grammar process-wide, never one per shard.
//!
//! `cargo bench --bench shard_scaling` (env `DOMINO_BENCH_N` overrides
//! the request count).

use domino::constraint::{Constraint, ConstraintSpec};
use domino::runtime::mock::{json_mock, MockFactory, MockModel};
use domino::server::engine::{EngineCtx, GenRequest};
use domino::server::scheduler::{Scheduler, SchedulerConfig};
use domino::tokenizer::Vocab;
use domino::util::bench::{emit_json, Table};
use std::sync::Arc;
use std::time::Instant;

const GRAMMARS: [&str; 4] = ["json", "gsm8k", "c", "xml"];

fn start(engines: usize, vocab: Arc<Vocab>, model: Arc<MockModel>) -> Scheduler {
    Scheduler::start(
        move |_shard, registry| {
            Ok(EngineCtx::with_registry(
                Box::new(MockFactory { model: model.clone() }),
                vocab.clone(),
                registry,
            ))
        },
        SchedulerConfig {
            engines,
            slots_per_engine: 4,
            queue_depth: 4096,
            ..SchedulerConfig::default()
        },
    )
}

fn request(grammar: &str, max_tokens: usize, seed: u64) -> GenRequest {
    GenRequest {
        prompt: String::new(),
        constraint: Constraint::domino(ConstraintSpec::builtin(grammar)),
        max_tokens,
        temperature: Some(1.0),
        seed,
        ..Default::default()
    }
}

fn main() {
    let n: usize =
        std::env::var("DOMINO_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(48);
    let max_tokens = 64;
    println!(
        "== shard scaling: {n} mixed-grammar requests × {max_tokens} tokens, \
         grammars {GRAMMARS:?}, mock runtime ==\n"
    );

    let mut table = Table::new(&[
        "engines", "requests", "ok", "wall (s)", "agg tok/s", "speedup", "registry misses",
    ]);
    let mut base_tps: Option<f64> = None;
    let mut json_fields: Vec<(String, f64)> = Vec::new();
    for engines in [1usize, 2, 4] {
        let (vocab, model) = json_mock(512);
        let sched = start(engines, vocab, model);
        // Warm the shared registry (grammar compiles are the offline
        // cost; this bench measures serving throughput).
        for g in GRAMMARS {
            let _ = sched.generate(request(g, 4, 0));
        }
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n)
            .map(|i| sched.submit(request(GRAMMARS[i % GRAMMARS.len()], max_tokens, i as u64)))
            .collect();
        let mut ok = 0usize;
        let mut tokens = 0usize;
        for h in &handles {
            if let Ok(r) = h.recv() {
                if r.error.is_none() {
                    ok += 1;
                    tokens += r.stats.tokens_out;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let tps = tokens as f64 / wall.max(1e-9);
        let misses = sched.metrics().map(|m| m.registry_misses).unwrap_or(0);
        let speedup = match base_tps {
            None => {
                base_tps = Some(tps);
                1.0
            }
            Some(b) => tps / b,
        };
        table.row(&[
            engines.to_string(),
            n.to_string(),
            ok.to_string(),
            format!("{wall:.2}"),
            format!("{tps:.0}"),
            format!("{speedup:.2}x"),
            misses.to_string(),
        ]);
        json_fields.push((format!("tok_s_{engines}"), tps));
        sched.shutdown();
    }
    table.print();
    let fields: Vec<(&str, f64)> = json_fields.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    emit_json("shard_scaling", &fields);
    println!(
        "\nexpected: aggregate tok/s grows with shards on multi-core hosts \
         (each shard is one engine thread); registry misses stay at {} per \
         run — one shared compile per distinct grammar across all shards.",
        GRAMMARS.len()
    );
}
