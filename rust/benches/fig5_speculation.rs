//! Fig. 5 reproduction: throughput (tokens/s) vs number of speculative
//! tokens `s`, for schema-driven JSON (GSM8K schema) and free-form JSON.
//!
//! Paper shape: s ∈ {6, 8, 10} gives ~1.7× on schema-driven generation;
//! speculation is flat/ineffective on free-form JSON.
//!
//! `cargo bench --bench fig5_speculation`

use domino::domino::decoder::Lookahead;
use domino::eval::harness::{eval_throughput, Method, Setup};
use domino::util::bench::Table;

fn main() {
    let setup = Setup::load();
    let n: usize =
        std::env::var("DOMINO_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
    let max_tokens = 96;
    println!(
        "== Fig. 5: throughput vs speculative tokens s (backend: {}, n={n}) ==\n",
        setup.backend_name
    );

    let s_values = [0usize, 2, 4, 6, 8, 10, 12];
    let mut table = Table::new(&[
        "s", "gsm8k tok/s", "(rel)", "gsm8k calls/token", "json tok/s", "(rel)", "json calls/token",
    ]);
    let mut base = [0.0f64; 2];
    for (gi, grammar) in ["gsm8k", "json"].iter().enumerate() {
        let b = eval_throughput(&setup, &Method::Unconstrained, grammar, n, max_tokens, 3)
            .expect("baseline");
        base[gi] = b.toks_per_s;
    }
    println!("unconstrained: gsm8k {:.1} tok/s, json {:.1} tok/s\n", base[0], base[1]);

    for &s in &s_values {
        let method = if s == 0 {
            Method::Domino { k: Lookahead::Infinite, spec: None, opportunistic: true }
        } else {
            Method::Domino { k: Lookahead::Infinite, spec: Some(s), opportunistic: true }
        };
        let mut cells = vec![if s == 0 { "0 (opportunistic)".into() } else { s.to_string() }];
        for (gi, grammar) in ["gsm8k", "json"].iter().enumerate() {
            match eval_throughput(&setup, &method, grammar, n, max_tokens, 3) {
                Ok(r) => {
                    cells.push(format!("{:.1}", r.toks_per_s));
                    cells.push(format!("{:.2}x", r.toks_per_s / base[gi]));
                    cells.push(format!("{:.2}", r.model_calls as f64 / r.tokens.max(1) as f64));
                }
                Err(e) => {
                    eprintln!("{grammar} s={s}: {e:#}");
                    cells.push("-".into());
                    cells.push("-".into());
                    cells.push("-".into());
                }
            }
        }
        table.row(&cells);
    }
    table.print();
    println!(
        "\nexpected shape (paper Fig. 5): schema-driven throughput grows with s\n\
         and plateaus around s=6-10 above 1x; free-form JSON stays flat."
    );
}
