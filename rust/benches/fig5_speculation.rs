//! Fig. 5 reproduction: throughput (tokens/s) vs number of speculative
//! tokens `s`, for schema-driven JSON (GSM8K schema) and free-form JSON.
//!
//! Paper shape: s ∈ {6, 8, 10} gives ~1.7× on schema-driven generation;
//! speculation is flat/ineffective on free-form JSON.
//!
//! A second section measures the grammar-pruned **draft lane**: multi-token
//! proposals from the learned prior, pruned by the grammar as they are
//! built, verified in one scored forward pass. Compared against the
//! no-draft baseline and the prune-after-verify ablation on the
//! deterministic tokens-per-model-call axis (CI-stable, unlike wall
//! clock). `$DOMINO_BENCH_DRAFT_RATIO` (default 1.3) gates the
//! drafted-vs-no-draft ratio — the bench exits 1 on a miss.
//!
//! `cargo bench --bench fig5_speculation`

use domino::domino::decoder::Lookahead;
use domino::eval::harness::{eval_throughput, Method, RowMetrics, Setup};
use domino::util::bench::{emit_json, Table};

fn main() {
    let setup = Setup::load();
    let n: usize =
        std::env::var("DOMINO_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
    let max_tokens = 96;
    println!(
        "== Fig. 5: throughput vs speculative tokens s (backend: {}, n={n}) ==\n",
        setup.backend_name
    );

    let s_values = [0usize, 2, 4, 6, 8, 10, 12];
    let mut table = Table::new(&[
        "s", "gsm8k tok/s", "(rel)", "gsm8k calls/token", "json tok/s", "(rel)", "json calls/token",
    ]);
    let mut base = [0.0f64; 2];
    for (gi, grammar) in ["gsm8k", "json"].iter().enumerate() {
        let b = eval_throughput(&setup, &Method::Unconstrained, grammar, n, max_tokens, 3)
            .expect("baseline");
        base[gi] = b.toks_per_s;
    }
    println!("unconstrained: gsm8k {:.1} tok/s, json {:.1} tok/s\n", base[0], base[1]);

    for &s in &s_values {
        let method = if s == 0 {
            Method::Domino { k: Lookahead::Infinite, spec: None, opportunistic: true }
        } else {
            Method::Domino { k: Lookahead::Infinite, spec: Some(s), opportunistic: true }
        };
        let mut cells = vec![if s == 0 { "0 (opportunistic)".into() } else { s.to_string() }];
        for (gi, grammar) in ["gsm8k", "json"].iter().enumerate() {
            match eval_throughput(&setup, &method, grammar, n, max_tokens, 3) {
                Ok(r) => {
                    cells.push(format!("{:.1}", r.toks_per_s));
                    cells.push(format!("{:.2}x", r.toks_per_s / base[gi]));
                    cells.push(format!("{:.2}", r.model_calls as f64 / r.tokens.max(1) as f64));
                }
                Err(e) => {
                    eprintln!("{grammar} s={s}: {e:#}");
                    cells.push("-".into());
                    cells.push("-".into());
                    cells.push("-".into());
                }
            }
        }
        table.row(&cells);
    }
    table.print();
    println!(
        "\nexpected shape (paper Fig. 5): schema-driven throughput grows with s\n\
         and plateaus around s=6-10 above 1x; free-form JSON stays flat."
    );

    draft_lane_section(&setup, n, max_tokens);
}

/// Tokens per model call — the deterministic tick-efficiency axis the
/// draft lane optimizes (one batched verify call adopts a whole accepted
/// prefix).
fn tok_per_tick(r: &RowMetrics) -> f64 {
    r.tokens as f64 / r.model_calls.max(1) as f64
}

/// Draft-lane comparison on the schema-driven workload: no-draft
/// baseline vs grammar-pruned drafting vs the prune-after-verify
/// ablation. Emits `fig5_speculation` metrics for CI and enforces the
/// `$DOMINO_BENCH_DRAFT_RATIO` bar.
fn draft_lane_section(setup: &Setup, n: usize, max_tokens: usize) {
    let grammar = "gsm8k";
    let draft = 6usize;
    println!("\n== Draft lane: grammar-pruned K={draft} drafting ({grammar}) ==\n");
    let lanes: [(&str, Method); 3] = [
        (
            "no draft (opportunistic)",
            Method::Domino { k: Lookahead::Infinite, spec: None, opportunistic: true },
        ),
        (
            "drafted, prune-before-verify",
            Method::Drafted { k: Lookahead::Infinite, draft, prune: true },
        ),
        (
            "drafted, prune-after-verify",
            Method::Drafted { k: Lookahead::Infinite, draft, prune: false },
        ),
    ];
    let mut table = Table::new(&["lane", "tok/tick", "acceptance", "tok/s"]);
    let mut rows: Vec<Option<RowMetrics>> = Vec::new();
    for (label, method) in &lanes {
        match eval_throughput(setup, method, grammar, n, max_tokens, 3) {
            Ok(r) => {
                let acc = if r.spec_proposed > 0 {
                    format!("{:.0}%", 100.0 * r.spec_accepted as f64 / r.spec_proposed as f64)
                } else {
                    "-".into()
                };
                table.row(&[
                    label.to_string(),
                    format!("{:.2}", tok_per_tick(&r)),
                    acc,
                    format!("{:.1}", r.toks_per_s),
                ]);
                rows.push(Some(r));
            }
            Err(e) => {
                eprintln!("{label}: {e:#}");
                table.row(&[label.to_string(), "-".into(), "-".into(), "-".into()]);
                rows.push(None);
            }
        }
    }
    table.print();
    let (Some(plain), Some(pruned)) = (&rows[0], &rows[1]) else {
        eprintln!("draft lanes failed; no gate applied");
        std::process::exit(1);
    };
    let acceptance_rate = if pruned.spec_proposed > 0 {
        pruned.spec_accepted as f64 / pruned.spec_proposed as f64
    } else {
        0.0
    };
    let draft_speedup = tok_per_tick(pruned) / tok_per_tick(plain).max(1e-9);
    println!(
        "\ndraft speedup (tok/tick vs no draft): {draft_speedup:.2}x, \
         acceptance {:.0}%",
        acceptance_rate * 100.0
    );
    emit_json(
        "fig5_speculation",
        &[
            ("acceptance_rate", acceptance_rate),
            ("tok_per_tick_draft", tok_per_tick(pruned)),
            ("draft_speedup", draft_speedup),
        ],
    );
    let bar: f64 = std::env::var("DOMINO_BENCH_DRAFT_RATIO")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.3);
    if draft_speedup < bar {
        eprintln!(
            "FAIL: grammar-pruned drafting {draft_speedup:.2}x < required {bar:.2}x \
             (set DOMINO_BENCH_DRAFT_RATIO to adjust)"
        );
        std::process::exit(1);
    }
}
