//! §4.3 precomputation-cost reproduction: offline subterminal-tree build
//! time per grammar (paper: 1–5 s per grammar, C ~20 s, on a 32k vocab).
//!
//! Also reports the tree statistics that explain DOMINO's online speed:
//! tree nodes touched per mask vs vocabulary size.
//!
//! `cargo bench --bench precompute`

use domino::constraint::{ConstraintSpec, EngineRegistry};
use domino::domino::decoder::Engine;
use domino::domino::tree::TreeSet;
use domino::eval::Setup;
use domino::grammar::builtin;
use domino::scanner::Scanner;
use domino::util::bench::{time_it, Table};
use std::time::Instant;

fn main() {
    let setup = Setup::load();
    println!(
        "== Grammar precompute cost (vocab {} — paper used 32k; scale ~linearly) ==\n",
        setup.vocab.len()
    );
    let mut table = Table::new(&[
        "Grammar", "terminals", "scanner pos", "tree nodes", "possets", "serial (s)", "parallel (s)",
    ]);
    for name in builtin::GRAMMAR_NAMES {
        let cfg = builtin::by_name(name).unwrap();
        let scanner = Scanner::new(&cfg).unwrap();
        let vocab = setup.vocab.clone();
        let serial = time_it(0, 1, || {
            std::hint::black_box(TreeSet::build_serial(&scanner, &vocab));
        });
        let parallel = time_it(0, 1, || {
            std::hint::black_box(TreeSet::build(&scanner, &vocab));
        });
        let ts = TreeSet::build(&scanner, &vocab);
        table.row(&[
            name.to_string(),
            cfg.num_terminals().to_string(),
            scanner.num_pos().to_string(),
            ts.total_nodes().to_string(),
            ts.num_possets().to_string(),
            format!("{:.3}", serial.mean.as_secs_f64()),
            format!("{:.3}", parallel.mean.as_secs_f64()),
        ]);
    }
    table.print();

    // Full engine compile (incl. Earley tables) for the two extremes.
    println!();
    for name in ["json", "c"] {
        let t = time_it(0, 1, || {
            std::hint::black_box(
                Engine::compile(builtin::by_name(name).unwrap(), setup.vocab.clone()).unwrap(),
            );
        });
        println!("full engine compile `{name}`: {:.3}s", t.mean.as_secs_f64());
    }

    // The serving-path amortization: a cold registry lookup pays the full
    // compile; every warm lookup is a hash probe. This is the per-request
    // cost difference between rebuilding engines and the shared registry.
    println!("\n== EngineRegistry: cold vs warm lookups ==\n");
    let registry = EngineRegistry::new(8);
    let mut table = Table::new(&["grammar", "cold (s)", "warm (us)", "speedup"]);
    for name in ["json", "gsm8k", "c"] {
        let spec = ConstraintSpec::builtin(name);
        let t0 = Instant::now();
        registry.get_or_compile(&spec, &setup.vocab, None).unwrap();
        let cold = t0.elapsed().as_secs_f64();
        let warm_iters = 1000u32;
        let t0 = Instant::now();
        for _ in 0..warm_iters {
            std::hint::black_box(registry.get_or_compile(&spec, &setup.vocab, None).unwrap());
        }
        let warm = t0.elapsed().as_secs_f64() / warm_iters as f64;
        table.row(&[
            name.to_string(),
            format!("{cold:.3}"),
            format!("{:.2}", warm * 1e6),
            format!("{:.0}x", cold / warm.max(1e-12)),
        ]);
    }
    table.print();
    let s = registry.stats();
    println!(
        "\nregistry counters: {} hits / {} misses / {} evictions / {} coalesced / {} ms compiling",
        s.hits, s.misses, s.evictions, s.coalesced, s.compile_ms
    );
}
