//! Table 4 reproduction: GSM8K task accuracy as a function of the
//! lookahead parameter k (§4.2).
//!
//! The paper: k=0 and k=1 impair accuracy badly (bridge tokens like `},`
//! are unavailable, distorting whitespace/structure); k=∞ recovers and
//! slightly exceeds unconstrained.
//!
//! `cargo bench --bench table4_lookahead`

use domino::domino::decoder::Lookahead;
use domino::eval::harness::{eval_task, Method, Setup};
use domino::util::bench::Table;

fn main() {
    let setup = Setup::load();
    let n: usize =
        std::env::var("DOMINO_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(40);
    println!("== Table 4: GSM8K accuracy vs lookahead k (backend: {}, n={n}) ==\n", setup.backend_name);

    let rows = [
        ("Unconstrained", Method::Unconstrained),
        ("Domino (k=0)", Method::Domino { k: Lookahead::K(0), spec: None, opportunistic: false }),
        ("Domino (k=1)", Method::Domino { k: Lookahead::K(1), spec: None, opportunistic: false }),
        ("Domino (k=3)", Method::Domino { k: Lookahead::K(3), spec: None, opportunistic: false }),
        ("Domino (k=inf)", Method::Domino { k: Lookahead::Infinite, spec: None, opportunistic: false }),
    ];

    let mut table =
        Table::new(&["Configuration", "Accuracy", "Well-Formed", "Perplexity", "Interventions"]);
    for (label, method) in rows {
        match eval_task(&setup, &method, "gsm8k", n, 96, 99) {
            Ok(r) => table.row(&[
                label.to_string(),
                format!("{:.3}", r.accuracy),
                format!("{:.3}", r.well_formed),
                format!("{:.3}", r.perplexity),
                r.interventions.to_string(),
            ]),
            Err(e) => eprintln!("{label}: {e:#}"),
        }
    }
    table.print();
    println!(
        "\nexpected shape (paper Table 4): accuracy collapses at k=0/k=1\n\
         (missing bridge tokens), recovers at k=inf to >= unconstrained."
    );
}
