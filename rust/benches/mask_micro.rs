//! Mask-computation micro-benchmark — the §Perf L3 hot path.
//!
//! Compares, per decode step and per grammar state:
//! * DOMINO tree-traversal mask (`compute_mask`, k=∞),
//! * DOMINO single-token check (`check_token` — the opportunistic path),
//! * online full-vocab scan (the llama.cpp-style baseline cost),
//! * decoder `advance` (state update).
//!
//! The paper's claim is that tree size ≪ vocab size makes the first two
//! cheap; this bench quantifies it on this vocab.
//!
//! `cargo bench --bench mask_micro`

use domino::baselines::OnlineChecker;
use domino::constraint::{CachedChecker, MaskCache};
use domino::domino::decoder::{Engine, Lookahead};
use domino::domino::{Checker, DominoDecoder};
use domino::eval::Setup;
use domino::grammar::builtin;
use domino::util::bench::{time_it, Table};
use domino::util::Rng;
use std::sync::Arc;

fn main() {
    let setup = Setup::load();
    println!("== Mask micro-benchmarks (vocab {}) ==\n", setup.vocab.len());
    let mut table = Table::new(&[
        "grammar", "state", "domino mask (us)", "check_token (us)", "online mask (us)", "advance (us)",
    ]);

    for name in ["json", "gsm8k", "c"] {
        let engine = Engine::compile(builtin::by_name(name).unwrap(), setup.vocab.clone()).unwrap();
        // Advance a decoder to a few representative states via random walk.
        let mut rng = Rng::new(5);
        let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let mut states = vec![dec.clone()];
        for _ in 0..24 {
            let mask = dec.compute_mask();
            let allowed: Vec<_> = mask.iter().filter(|&t| t != 0).collect();
            if allowed.is_empty() {
                break;
            }
            let t = *rng.choose(&allowed);
            dec.advance(t).unwrap();
            states.push(dec.clone());
        }
        for (label, idx) in [("start", 0usize), ("mid", states.len() / 2), ("deep", states.len() - 1)] {
            let base = &states[idx];
            let mask_t = time_it(3, 20, || {
                let mut d = base.clone();
                std::hint::black_box(d.compute_mask());
            });
            let check_t = time_it(3, 20, || {
                let mut d = base.clone();
                for tok in [5u32, 100, 300] {
                    std::hint::black_box(d.check_token(tok));
                }
            });
            let online_t = time_it(1, 5, || {
                let mut o = OnlineChecker::new(engine.clone());
                // Bring online checker to the same state.
                std::hint::black_box(o.compute_mask());
            });
            let mask = {
                let mut d = base.clone();
                d.compute_mask()
            };
            let some_tok = mask.iter().find(|&t| t != 0);
            let adv_t = time_it(3, 20, || {
                if let Some(t) = some_tok {
                    let mut d = base.clone();
                    let _ = d.advance(t);
                }
            });
            table.row(&[
                name.to_string(),
                label.to_string(),
                format!("{:.1}", mask_t.mean_us()),
                format!("{:.1}", check_t.mean_us() / 3.0),
                format!("{:.1}", online_t.mean_us()),
                format!("{:.1}", adv_t.mean_us()),
            ]);
        }
    }
    table.print();
    println!("\nnote: online mask is measured at the START state only (cloning deep online state is expensive by construction).");

    // The serving-path mask cache: replay the same random walk twice
    // through a CachedChecker sharing one MaskCache — the second pass
    // (a second slot/request in the same grammar states) should be ~all
    // hits, replacing tree traversals with hash probes.
    println!("\n== State-keyed mask cache (json, k=inf, walk replayed) ==\n");
    let engine = Engine::compile(builtin::json(), setup.vocab.clone()).unwrap();
    let cache = Arc::new(MaskCache::new(1024));
    for pass in 0..2 {
        let mut checker = CachedChecker::new(
            Box::new(DominoDecoder::new(engine.clone(), Lookahead::Infinite)),
            cache.clone(),
            MaskCache::variant(Lookahead::Infinite),
        );
        let mut rng = Rng::new(9);
        let before = cache.stats();
        let t0 = std::time::Instant::now();
        for _ in 0..32 {
            let mask = checker.compute_mask();
            let allowed: Vec<_> = mask.iter().filter(|&t| t != 0).collect();
            if allowed.is_empty() {
                break;
            }
            let t = *rng.choose(&allowed);
            checker.advance(t).unwrap();
        }
        let elapsed = t0.elapsed();
        let s = cache.stats();
        println!(
            "pass {pass}: {} hits / {} misses this pass ({:.0}% lifetime hit rate) in {:.1} us",
            s.hits - before.hits,
            s.misses - before.misses,
            100.0 * s.hit_rate(),
            elapsed.as_secs_f64() * 1e6,
        );
    }
}
