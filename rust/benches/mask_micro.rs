//! Mask-computation micro-benchmark — the §Perf L3 hot path.
//!
//! Three parts:
//!
//! 1. **Kernels** — the word-parallel `TokenMask` sweeps (`apply`,
//!    `iter`, `intersect`) against inline scalar references on a
//!    32k-entry vocabulary, with a grammar-realistic sparse mask (tree
//!    size ≪ vocab size is the paper's whole pitch, so most words are
//!    zero and `apply` runs the chunked fill fast path).
//! 2. **Per-grammar mask cost** — DOMINO tree-traversal mask
//!    (`compute_mask`, k=∞), single-token check, online full-vocab scan,
//!    and `advance`, per decode state. The dense-terminal lanes (`c`,
//!    and the schema-derived `function_call` CFG) are first-class here:
//!    they are where scanner tables are big and the raw kernels matter.
//! 3. **Mask cache** — the serving-path state-keyed cache: replayed-walk
//!    hit behavior, plus an 8-thread contention run of the sharded
//!    layout against a single-shard (one global lock) configuration.
//!
//! Emits a `mask_micro` section into `$DOMINO_BENCH_JSON`
//! (apply/iter/cache speedups) and enforces the in-bench acceptance
//! bars: `apply_speedup >= $DOMINO_BENCH_MASK_RATIO` (default 4) and
//! `cache_speedup >= ratio/2`. `DOMINO_BENCH_ITERS` scales iteration
//! counts.
//!
//! `cargo bench --bench mask_micro`

use domino::baselines::OnlineChecker;
use domino::constraint::{CachedChecker, MaskCache};
use domino::domino::decoder::{DominoDecoder, Lookahead};
use domino::domino::{Checker, TokenMask};
use domino::eval::harness::workload_spec;
use domino::eval::Setup;
use domino::util::bench::{emit_json, time_it, Table};
use domino::util::Rng;
use std::sync::Arc;
use std::time::Instant;

/// Kernel-section vocabulary: the paper's 32k-token regime.
const KERNEL_VOCAB: usize = 32_768;

/// A random mask with roughly `density` of the vocabulary allowed.
fn random_mask(size: usize, density: f64, seed: u64) -> TokenMask {
    let mut rng = Rng::new(seed);
    let mut m = TokenMask::none(size);
    for t in 0..size as u32 {
        if rng.chance(density) {
            m.allow(t);
        }
    }
    m
}

/// Scalar reference `apply`: one `allowed` probe per logit (what the
/// pre-wordwise implementation did).
fn scalar_apply(mask: &TokenMask, logits: &mut [f32]) {
    for (t, l) in logits.iter_mut().enumerate() {
        if !mask.allowed(t as u32) {
            *l = f32::NEG_INFINITY;
        }
    }
}

/// Kernel comparisons on a 32k vocab; returns (apply, iter) speedups.
fn bench_kernels(iters: u32) -> (f64, f64) {
    println!("== Word-parallel TokenMask kernels (vocab {KERNEL_VOCAB}) ==\n");
    let mut table = Table::new(&["kernel", "density", "scalar (us)", "wordwise (us)", "speedup"]);

    // Grammar-realistic sparse mask (the headline numbers) plus a dense
    // mask to show the worst case stays ahead.
    let mut apply_speedup = f64::MAX;
    let mut iter_speedup = f64::MAX;
    for (label, density) in [("sparse 2%", 0.02), ("dense 50%", 0.5)] {
        let mask = random_mask(KERNEL_VOCAB, density, 7);
        let base: Vec<f32> = (0..KERNEL_VOCAB).map(|i| (i % 997) as f32 * 0.01).collect();

        let mut buf = base.clone();
        let scalar_t = time_it(5, iters, || {
            buf.copy_from_slice(&base);
            scalar_apply(&mask, &mut buf);
            std::hint::black_box(&buf);
        });
        let word_t = time_it(5, iters, || {
            buf.copy_from_slice(&base);
            mask.apply(&mut buf);
            std::hint::black_box(&buf);
        });
        let speedup = scalar_t.mean_us() / word_t.mean_us().max(1e-9);
        table.row(&[
            "apply".into(),
            label.into(),
            format!("{:.1}", scalar_t.mean_us()),
            format!("{:.1}", word_t.mean_us()),
            format!("{speedup:.1}x"),
        ]);
        if label.starts_with("sparse") {
            // The acceptance bar is the grammar-realistic lane.
            apply_speedup = speedup;
        }

        // iter: the allocation-free word cursor vs the old
        // Vec-per-word expansion.
        let old_t = time_it(5, iters, || {
            let mut acc = 0u64;
            for (wi, &w) in mask.words().iter().enumerate() {
                let ids: Vec<u32> =
                    (0..64usize).filter(|b| (w >> b) & 1 == 1).map(|b| (wi * 64 + b) as u32).collect();
                for t in ids {
                    acc += t as u64;
                }
            }
            std::hint::black_box(acc);
        });
        let new_t = time_it(5, iters, || {
            let mut acc = 0u64;
            for t in mask.iter() {
                acc += t as u64;
            }
            std::hint::black_box(acc);
        });
        let speedup = old_t.mean_us() / new_t.mean_us().max(1e-9);
        table.row(&[
            "iter".into(),
            label.into(),
            format!("{:.1}", old_t.mean_us()),
            format!("{:.1}", new_t.mean_us()),
            format!("{speedup:.1}x"),
        ]);
        if label.starts_with("sparse") {
            iter_speedup = speedup;
        }

        // intersect: wordwise AND vs per-bit probe+forbid.
        let other = random_mask(KERNEL_VOCAB, density, 11);
        let scalar_t = time_it(5, iters, || {
            let mut m = mask.clone();
            for t in 0..KERNEL_VOCAB as u32 {
                if !other.allowed(t) {
                    m.forbid(t);
                }
            }
            std::hint::black_box(&m);
        });
        let word_t = time_it(5, iters, || {
            let mut m = mask.clone();
            m.intersect(&other);
            std::hint::black_box(&m);
        });
        table.row(&[
            "intersect".into(),
            label.into(),
            format!("{:.1}", scalar_t.mean_us()),
            format!("{:.1}", word_t.mean_us()),
            format!("{:.1}x", scalar_t.mean_us() / word_t.mean_us().max(1e-9)),
        ]);
    }
    table.print();
    (apply_speedup, iter_speedup)
}

/// Mixed get/put throughput (ops/s) over `threads` concurrent workers
/// against a cache with `shards` shards — the 8-slot serving contention
/// shape. ~75% gets / 25% puts over a keyspace small enough to stay
/// within capacity (steady-state hits, no eviction storms).
fn cache_ops_per_s(shards: usize, threads: usize, ops_per_thread: usize) -> f64 {
    let cache = MaskCache::with_shards(1024, shards);
    let masks: Vec<Arc<TokenMask>> =
        (0..8).map(|i| Arc::new(random_mask(2048, 0.1, i as u64))).collect();
    const KEYS: usize = 256;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for th in 0..threads {
            let cache = &cache;
            let masks = &masks;
            s.spawn(move || {
                let mut rng = Rng::new(th as u64 + 1);
                for i in 0..ops_per_thread {
                    let key = rng.below(KEYS) as u64;
                    if i % 4 == 0 {
                        cache.put(1, key, masks[key as usize % masks.len()].clone());
                    } else if let Some(m) = cache.get(1, key) {
                        std::hint::black_box(m.size());
                    }
                }
            });
        }
    });
    (threads * ops_per_thread) as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

fn main() {
    let iters: u32 =
        std::env::var("DOMINO_BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(200).max(1);
    let bar: f64 = std::env::var("DOMINO_BENCH_MASK_RATIO")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4.0);

    let (apply_speedup, iter_speedup) = bench_kernels(iters);

    let setup = Setup::load();
    println!("\n== Mask micro-benchmarks (vocab {}) ==\n", setup.vocab.len());
    let mut table = Table::new(&[
        "grammar", "state", "domino mask (us)", "check_token (us)", "online mask (us)", "advance (us)",
    ]);

    // `c` and the schema-derived `function_call` CFG are the
    // dense-terminal lanes: many terminals with big scanner DFAs.
    for name in ["json", "gsm8k", "c", "function_call"] {
        let engine = setup.engine(name).unwrap();
        // Advance a decoder to a few representative states via random walk.
        let mut rng = Rng::new(5);
        let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let mut states = vec![dec.clone()];
        for _ in 0..24 {
            let mask = dec.compute_mask();
            let allowed: Vec<_> = mask.iter().filter(|&t| t != 0).collect();
            if allowed.is_empty() {
                break;
            }
            let t = *rng.choose(&allowed);
            dec.advance(t).unwrap();
            states.push(dec.clone());
        }
        for (label, idx) in [("start", 0usize), ("mid", states.len() / 2), ("deep", states.len() - 1)] {
            let base = &states[idx];
            let mask_t = time_it(3, 20, || {
                let mut d = base.clone();
                std::hint::black_box(d.compute_mask());
            });
            let check_t = time_it(3, 20, || {
                let mut d = base.clone();
                for tok in [5u32, 100, 300] {
                    std::hint::black_box(d.check_token(tok));
                }
            });
            let online_t = time_it(1, 5, || {
                let mut o = OnlineChecker::new(engine.clone());
                // Bring online checker to the same state.
                std::hint::black_box(o.compute_mask());
            });
            let mask = {
                let mut d = base.clone();
                d.compute_mask()
            };
            let some_tok = mask.iter().find(|&t| t != 0);
            let adv_t = time_it(3, 20, || {
                if let Some(t) = some_tok {
                    let mut d = base.clone();
                    let _ = d.advance(t);
                }
            });
            table.row(&[
                name.to_string(),
                label.to_string(),
                format!("{:.1}", mask_t.mean_us()),
                format!("{:.1}", check_t.mean_us() / 3.0),
                format!("{:.1}", online_t.mean_us()),
                format!("{:.1}", adv_t.mean_us()),
            ]);
        }
    }
    table.print();
    println!("\nnote: online mask is measured at the START state only (cloning deep online state is expensive by construction).");

    // Lazy compile: time-to-first-mask for the schema-derived grammar
    // (the lazy-DFA pitch: compile cost proportional to states visited).
    {
        use domino::domino::decoder::Engine;
        let cfg = workload_spec("function_call").to_cfg().unwrap();
        let t0 = Instant::now();
        let lazy = Engine::compile_lazy(cfg, setup.vocab.clone()).unwrap();
        let mut d = DominoDecoder::new(lazy, Lookahead::Infinite);
        std::hint::black_box(d.compute_mask());
        let lazy_first = t0.elapsed().as_secs_f64();
        let cfg = workload_spec("function_call").to_cfg().unwrap();
        let t0 = Instant::now();
        let eager = Engine::compile(cfg, setup.vocab.clone()).unwrap();
        let mut d = DominoDecoder::new(eager, Lookahead::Infinite);
        std::hint::black_box(d.compute_mask());
        let eager_first = t0.elapsed().as_secs_f64();
        println!(
            "\ntime-to-first-mask `function_call`: eager {:.3}s, lazy {:.3}s ({:.1}x)",
            eager_first,
            lazy_first,
            eager_first / lazy_first.max(1e-9),
        );
    }

    // The serving-path mask cache: replay the same random walk twice
    // through a CachedChecker sharing one MaskCache — the second pass
    // (a second slot/request in the same grammar states) should be ~all
    // hits, replacing tree traversals with hash probes.
    println!("\n== State-keyed mask cache (json, k=inf, walk replayed) ==\n");
    let engine = setup.engine("json").unwrap();
    let cache = Arc::new(MaskCache::new(1024));
    for pass in 0..2 {
        let mut checker = CachedChecker::new(
            Box::new(DominoDecoder::new(engine.clone(), Lookahead::Infinite)),
            cache.clone(),
            MaskCache::variant(Lookahead::Infinite),
        );
        let mut rng = Rng::new(9);
        let before = cache.stats();
        let t0 = std::time::Instant::now();
        for _ in 0..32 {
            let mask = checker.compute_mask();
            let allowed: Vec<_> = mask.iter().filter(|&t| t != 0).collect();
            if allowed.is_empty() {
                break;
            }
            let t = *rng.choose(&allowed);
            checker.advance(t).unwrap();
        }
        let elapsed = t0.elapsed();
        let s = cache.stats();
        println!(
            "pass {pass}: {} hits / {} misses this pass ({:.0}% lifetime hit rate) in {:.1} us",
            s.hits - before.hits,
            s.misses - before.misses,
            100.0 * s.hit_rate(),
            elapsed.as_secs_f64() * 1e6,
        );
    }

    // Sharded-cache contention: 8 concurrent slots hammering one cache,
    // sharded layout vs a single global lock (shards=1).
    println!("\n== MaskCache contention (8 threads, 75% get / 25% put) ==\n");
    let threads = 8;
    let ops = (25_000u32.max(iters * 50)) as usize;
    // Warm the allocator/scheduler once so the first measured run isn't
    // paying one-time costs.
    cache_ops_per_s(1, threads, ops / 10);
    let single = cache_ops_per_s(1, threads, ops);
    let sharded = cache_ops_per_s(8, threads, ops);
    let cache_speedup = sharded / single.max(1e-9);
    let mut table = Table::new(&["layout", "ops/s", "vs single lock"]);
    table.row(&["single lock (1 shard)".into(), format!("{single:.0}"), "1.00x".into()]);
    table.row(&["sharded (8 shards)".into(), format!("{sharded:.0}"), format!("{cache_speedup:.2}x")]);
    table.print();

    emit_json(
        "mask_micro",
        &[
            ("apply_speedup", apply_speedup),
            ("iter_speedup", iter_speedup),
            ("cache_speedup", cache_speedup),
        ],
    );

    let apply_ok = apply_speedup >= bar;
    let cache_ok = cache_speedup >= bar / 2.0;
    println!(
        "\nwordwise apply speedup: {apply_speedup:.2}x (bar >= {bar}x) — {}",
        if apply_ok { "PASS" } else { "FAIL" }
    );
    println!(
        "sharded cache speedup at {threads} threads: {cache_speedup:.2}x (bar >= {:.2}x) — {}",
        bar / 2.0,
        if cache_ok { "PASS" } else { "FAIL" }
    );
    if !apply_ok || !cache_ok {
        std::process::exit(1);
    }
}
