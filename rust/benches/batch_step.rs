//! Batched-vs-per-slot tick throughput: the same 8 slots decoded with
//! one `LmBackend::forward_batch` per tick versus one `append` per slot
//! per tick.
//!
//! This is the serving hot path ISSUE 4 batches: before, a shard with 8
//! live requests paid 8 sequential model calls per tick, so throughput
//! scaled with slot count instead of batch width. The bench proves the
//! batched pipeline (a) is ≥2× faster at 8 slots (acceptance bar;
//! `DOMINO_BENCH_BATCH_RATIO` overrides it — the bench-smoke CI job
//! relaxes it because loaded runners time-slice the two passes
//! differently), and (b) is **token-identical** to the per-slot path
//! across plain, speculative and healing-phase slots — same seeds, same
//! bytes out, byte for byte.
//!
//! `cargo bench --bench batch_step` (env `DOMINO_BENCH_ITERS` overrides
//! the repetition count; `DOMINO_BENCH_JSON` appends machine-readable
//! results for the CI trend file).

use domino::constraint::{Constraint, ConstraintSpec};
use domino::domino::generate::Prompt;
use domino::runtime::mock::{json_mock, MockFactory};
use domino::runtime::sampler::Sampling;
use domino::runtime::LmBackend;
use domino::server::engine::EngineCtx;
use domino::server::slot::{step_batched, Slot};
use domino::util::bench::{emit_json, Table};
use std::time::Instant;

const SLOTS: usize = 8;
const MAX_TOKENS: usize = 64;

/// One request shape: constraint + prompt (a non-empty prompt exercises
/// the healing phase at admission, so healed slots coexist in the batch).
struct Shape {
    constraint: Constraint,
    prompt: &'static str,
}

fn shapes(speculative: bool) -> Vec<Shape> {
    let json = ConstraintSpec::builtin("json");
    if speculative {
        // Mixed batch: plain slots and speculative slots mid-proposal in
        // the same tick, one slot starting from a healed prompt.
        vec![
            Shape { constraint: Constraint::domino(json.clone()), prompt: "" },
            Shape { constraint: Constraint::domino(json.clone()).with_speculation(8), prompt: "" },
            Shape {
                constraint: Constraint::domino(json.clone()).with_speculation(8),
                prompt: "{\"na",
            },
            Shape { constraint: Constraint::none(), prompt: "" },
        ]
    } else {
        vec![Shape { constraint: Constraint::domino(json), prompt: "" }]
    }
}

fn make_slots(ctx: &mut EngineCtx, shapes: &[Shape], n: usize, sampling: Sampling) -> Vec<Slot> {
    (0..n)
        .map(|i| {
            let shape = &shapes[i % shapes.len()];
            let mode = ctx.decode_mode(&shape.constraint).expect("decode mode");
            let session = ctx.backend.new_session().expect("session");
            let prompt = Prompt::healed(&ctx.vocab, shape.prompt);
            Slot::new(
                i as u64,
                session,
                mode,
                ctx.vocab.clone(),
                &prompt,
                sampling,
                MAX_TOKENS,
                i as u64,
            )
            .expect("slot")
        })
        .collect()
}

fn texts(slots: &[Slot]) -> Vec<String> {
    slots.iter().map(Slot::text).collect()
}

/// Decode every slot to completion, per-slot path. Returns (seconds,
/// tokens).
fn run_per_slot(slots: &mut [Slot]) -> (f64, usize) {
    let t0 = Instant::now();
    while slots.iter().any(|s| !s.done) {
        for s in slots.iter_mut() {
            s.step().expect("per-slot step");
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    (secs, slots.iter().map(|s| s.stats.tokens_out).sum())
}

/// Decode every slot to completion, one batched forward per tick.
fn run_batched(backend: &dyn LmBackend, slots: &mut [Slot]) -> (f64, usize) {
    let t0 = Instant::now();
    while slots.iter().any(|s| !s.done) {
        let mut view: Vec<&mut Slot> = slots.iter_mut().collect();
        let tick = step_batched(backend, &mut view);
        assert!(tick.all_ok(), "batched step failed");
    }
    let secs = t0.elapsed().as_secs_f64();
    (secs, slots.iter().map(|s| s.stats.tokens_out).sum())
}

fn main() {
    let iters: u32 =
        std::env::var("DOMINO_BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(5).max(1);
    let bar: f64 =
        std::env::var("DOMINO_BENCH_BATCH_RATIO").ok().and_then(|s| s.parse().ok()).unwrap_or(2.0);
    let (vocab, model) = json_mock(2048);
    println!(
        "== batch step: {SLOTS} slots × {MAX_TOKENS} tokens, vocab {}, best of {iters} runs ==\n",
        vocab.len()
    );

    // Parity first (it is the correctness bar for everything below):
    // same seeds, per-slot vs batched, plain and mixed-speculative.
    let mut ctx = EngineCtx::new(Box::new(MockFactory { model: model.clone() }), vocab.clone());
    for (name, speculative) in [("plain", false), ("mixed speculative", true)] {
        // Temperature sampling: parity must hold through the RNG, which
        // only happens when the two paths' logit rows agree bitwise.
        let shapes = shapes(speculative);
        let mut a = make_slots(&mut ctx, &shapes, SLOTS, Sampling::Temperature(1.0));
        let mut b = make_slots(&mut ctx, &shapes, SLOTS, Sampling::Temperature(1.0));
        run_per_slot(&mut a);
        let backend = MockFactory { model: model.clone() };
        run_batched(&backend, &mut b);
        assert_eq!(
            texts(&a),
            texts(&b),
            "batched output must be byte-identical to per-slot ({name})"
        );
        println!("parity [{name}]: batched output byte-identical to per-slot — PASS");
    }

    // Throughput: plain grammar-constrained slots, both paths. Greedy
    // sampling, so the tick cost is dominated by the model-call boundary
    // this PR batches rather than by O(V) sampling work both paths share.
    let shapes = shapes(false);
    let backend = MockFactory { model: model.clone() };
    let mut per_slot_best = f64::MAX;
    let mut batched_best = f64::MAX;
    let mut tokens = 0usize;
    for _ in 0..iters {
        let mut slots = make_slots(&mut ctx, &shapes, SLOTS, Sampling::Greedy);
        let (secs, toks) = run_per_slot(&mut slots);
        per_slot_best = per_slot_best.min(secs);
        tokens = toks;
        let mut slots = make_slots(&mut ctx, &shapes, SLOTS, Sampling::Greedy);
        let (secs, toks_b) = run_batched(&backend, &mut slots);
        batched_best = batched_best.min(secs);
        assert_eq!(toks, toks_b, "both paths must commit the same tokens");
    }
    let tok_s_per_slot = tokens as f64 / per_slot_best.max(1e-9);
    let tok_s_batched = tokens as f64 / batched_best.max(1e-9);
    let speedup = tok_s_batched / tok_s_per_slot.max(1e-9);

    let mut table = Table::new(&["stepping", "tokens", "best (ms)", "tok/s", "vs per-slot"]);
    table.row(&[
        "per-slot (8 appends/tick)".into(),
        tokens.to_string(),
        format!("{:.2}", per_slot_best * 1e3),
        format!("{tok_s_per_slot:.0}"),
        "1.00x".into(),
    ]);
    table.row(&[
        "batched (1 forward/tick)".into(),
        tokens.to_string(),
        format!("{:.2}", batched_best * 1e3),
        format!("{tok_s_batched:.0}"),
        format!("{speedup:.2}x"),
    ]);
    table.print();

    emit_json(
        "batch_step",
        &[
            ("tok_s_perslot_8", tok_s_per_slot),
            ("tok_s_batched_8", tok_s_batched),
            ("speedup", speedup),
        ],
    );

    let pass = speedup >= bar;
    println!(
        "\nbatched tick speedup at {SLOTS} slots: {speedup:.2}x (acceptance bar: >= {bar}x) — {}",
        if pass { "PASS" } else { "FAIL" }
    );
    if !pass {
        std::process::exit(1);
    }
}
