//! Cold-vs-warm boot bench: full engine compile vs persistent-artifact
//! warm start for the builtin JSON grammar.
//!
//! "Cold" is what every server restart paid before the artifact store:
//! `spec → CFG → scanner DFAs → subterminal trees` (§3.5's offline cost)
//! on the first constrained request. "Warm" is the new boot path: scan
//! `--artifact-dir`, deserialize, validate fingerprints, serve. The
//! acceptance bar (ISSUE 3) is warm ≥ 5× faster than cold; the bench
//! exits non-zero below that so CI catches regressions.
//!
//! `cargo bench --bench warm_start` (env `DOMINO_BENCH_ITERS` overrides
//! the repetition count; `DOMINO_BENCH_JSON` appends machine-readable
//! results for the CI trend file; `DOMINO_BENCH_WARM_RATIO` overrides
//! the pass/fail speedup bar — the default 5× holds on idle hardware,
//! but loaded CI runners time-slice the cold compile and the warm load
//! differently, so the bench-smoke job relaxes it rather than flaking).

use domino::constraint::{ArtifactStore, ConstraintSpec, EngineRegistry};
use domino::tokenizer;
use domino::util::bench::{emit_json, Table};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let iters: u32 =
        std::env::var("DOMINO_BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(3).max(1);
    let vocab = Arc::new(tokenizer::bpe::synthetic_json_vocab(512));
    let spec = ConstraintSpec::builtin("json");
    let dir = std::env::temp_dir().join(format!("domino_warm_start_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "== warm-start: builtin `json`, vocab {}, best of {iters} boots ==\n",
        vocab.len()
    );

    // Cold boot: fresh in-memory registry — the first request pays the
    // full grammar compile.
    let mut cold_ms = f64::MAX;
    for _ in 0..iters {
        let reg = EngineRegistry::new(4);
        let t0 = Instant::now();
        reg.get_or_compile(&spec, &vocab, None).unwrap();
        cold_ms = cold_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }

    // Offline precompile (what `domino precompile` does once per deploy).
    {
        let reg = EngineRegistry::with_store(4, ArtifactStore::new(&dir).unwrap());
        reg.get_or_compile(&spec, &vocab, None).unwrap();
    }

    // Warm boot: fresh registry + warm-start scan, then the first
    // request — which must be a pure in-memory hit (no compile).
    let mut warm_ms = f64::MAX;
    for _ in 0..iters {
        let reg = EngineRegistry::with_store(4, ArtifactStore::new(&dir).unwrap());
        let t0 = Instant::now();
        let loaded = reg.warm_start(&vocab);
        reg.get_or_compile(&spec, &vocab, None).unwrap();
        warm_ms = warm_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        assert_eq!(loaded, 1, "the artifact must load on a warm boot");
        let s = reg.stats();
        assert_eq!(s.misses, 0, "warm boot must not compile: {s:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);

    let speedup = cold_ms / warm_ms.max(1e-9);
    let mut table = Table::new(&["boot", "first request ready (ms)", "vs cold"]);
    table.row(&["cold (compile)".into(), format!("{cold_ms:.2}"), "1.00x".into()]);
    table.row(&["warm (artifact)".into(), format!("{warm_ms:.2}"), format!("{speedup:.1}x")]);
    table.print();

    emit_json(
        "warm_start",
        &[("cold_boot_ms", cold_ms), ("warm_boot_ms", warm_ms), ("speedup", speedup)],
    );

    let bar: f64 =
        std::env::var("DOMINO_BENCH_WARM_RATIO").ok().and_then(|s| s.parse().ok()).unwrap_or(5.0);
    let pass = speedup >= bar;
    println!(
        "\nwarm-start speedup: {speedup:.1}x (acceptance bar: >= {bar}x) — {}",
        if pass { "PASS" } else { "FAIL" }
    );
    if !pass {
        std::process::exit(1);
    }
}
