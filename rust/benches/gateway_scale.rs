//! Gateway + registry scale bench: the two promises the async connection
//! gateway makes, gated.
//!
//! **Phase A — idle-connection hold.** Opens `DOMINO_BENCH_GATEWAY_CONNS`
//! (default 4096) keepalive JSONL connections against a reactor-backed
//! server on the mock runtime and asserts the process thread count does
//! not grow with the connection count (the thread-per-connection design
//! this replaced would add one thread per socket): the delta while
//! holding every connection must be zero, and the total must stay under
//! `max(cores × 2, 16)`. While all connections are held idle, a sample
//! of them runs streaming requests end-to-end to prove the gateway is
//! live, not merely parked.
//!
//! **Phase B — registry admission at scale.** Seeds a synthetic artifact
//! corpus at two sizes (`DOMINO_BENCH_N`, default 1000, and 100× that —
//! 100k grammars at the default), boots a tiered registry over each
//! (O(index) header scan, overflow parked cold), and measures admission
//! latency — hot-tier lookups interleaved with cold artifact loads. The
//! gate: p99 admission over the 100× corpus stays within
//! `DOMINO_BENCH_GATEWAY_RATIO` (default 1.5) of the small-corpus p99 —
//! flat, because neither the hot map nor a keyed O(1) disk load depends
//! on corpus size.
//!
//! `cargo bench --bench gateway_scale`. Exits 1 if either gate fails.

use domino::constraint::{ArtifactStore, ConstraintSpec, EngineRegistry};
use domino::domino::decoder::Engine;
use domino::grammar::builtin;
use domino::runtime::mock::{json_mock, MockFactory};
use domino::server::engine::EngineCtx;
use domino::server::reactor::{Reactor, ReactorConfig};
use domino::server::scheduler::{Scheduler, SchedulerConfig};
use domino::util::bench::{emit_json, Table};
use domino::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Floor (ms) under the admission-ratio gate: individual hot lookups run
/// in microseconds, where the ratio would amplify pure timer noise.
const FLOOR_MS: f64 = 0.25;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Live thread count of this process (`/proc/self/status`); 0 when the
/// platform has no procfs (the thread gates are skipped there).
fn thread_count() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|n| n.parse().ok())
        })
        .unwrap_or(0)
}

fn percentile(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[((samples.len() - 1) as f64 * p).round() as usize]
}

fn mock_sched() -> Arc<Scheduler> {
    let (vocab, model) = json_mock(512);
    Arc::new(Scheduler::start(
        move |_shard, registry| {
            Ok(EngineCtx::with_registry(
                Box::new(MockFactory { model: model.clone() }),
                vocab.clone(),
                registry,
            ))
        },
        SchedulerConfig { engines: 1, slots_per_engine: 4, ..SchedulerConfig::default() },
    ))
}

/// Phase A: hold `target` idle connections, prove bounded threads and a
/// live streaming path. Returns (held, thread_delta, stream_ms).
fn idle_connection_hold(target: usize) -> (usize, i64, f64) {
    let sched = mock_sched();
    let cfg = ReactorConfig { max_connections: target + 64, ..ReactorConfig::default() };
    let reactor = Reactor::start(&sched, Some("127.0.0.1:0"), None, cfg).expect("start gateway");
    let addr = reactor.jsonl_addr().expect("jsonl addr");
    let stats = reactor.stats();

    // Warm the grammar compile so the streaming sample below measures
    // serving, not compilation.
    {
        let conn = TcpStream::connect(addr).expect("warmup connect");
        let mut r = BufReader::new(&conn);
        writeln!(&conn, r#"{{"prompt": "", "grammar": "json", "max_tokens": 2}}"#).unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
    }

    let threads_before = thread_count();
    let mut clients = Vec::with_capacity(target);
    for i in 0..target {
        match TcpStream::connect(addr) {
            Ok(c) => clients.push(c),
            Err(e) => panic!("connect #{i} failed: {e}"),
        }
        if i % 512 == 511 {
            // Let the accept loop drain the backlog.
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    let deadline = Instant::now() + Duration::from_secs(60);
    while (stats.open() as usize) < target {
        assert!(
            Instant::now() < deadline,
            "gateway accepted only {}/{target} connections in 60s",
            stats.open()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let threads_held = thread_count();
    let delta = threads_held as i64 - threads_before as i64;

    // Liveness under load: stream on a sample of the held connections.
    let t0 = Instant::now();
    for conn in clients.iter().take(4) {
        writeln!(
            &*conn,
            r#"{{"prompt": "", "grammar": "json", "stream": true, "max_tokens": 8, "temperature": 1.0}}"#
        )
        .expect("write streaming request");
        let mut reader = BufReader::new(conn);
        let mut streamed = String::new();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).expect("read stream line");
            assert!(!line.is_empty(), "gateway closed a live streaming connection");
            let v = Json::parse(&line).expect("stream line is JSON");
            if let Some(tok) = v.get("token") {
                streamed.push_str(tok.as_str().unwrap());
            } else {
                assert_eq!(v.get("error"), Some(&Json::Null), "{line}");
                assert_eq!(
                    v.get("text").unwrap().as_str().unwrap(),
                    streamed,
                    "stream concatenation must equal the final text"
                );
                break;
            }
        }
    }
    let stream_ms = t0.elapsed().as_secs_f64() * 1e3;

    drop(clients);
    reactor.stop();
    (threads_held, delta, stream_ms)
}

struct CorpusRun {
    size: usize,
    seed_ms: f64,
    warm_ms: f64,
    cold_entries: u64,
    p99_ms: f64,
}

/// Phase B: seed `size` synthetic artifacts, boot a tiered registry over
/// them, and sample admission latency (hot lookups + cold keyed loads).
fn corpus_admission(engine: &Engine, vocab: &Arc<domino::tokenizer::Vocab>, size: usize) -> CorpusRun {
    let dir = std::env::temp_dir().join(format!("domino_gateway_scale_{}_{size}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = ArtifactStore::new(&dir).expect("artifact store");

    let t0 = Instant::now();
    let keys = store.seed_synthetic_corpus(engine, size).expect("seed corpus");
    let seed_ms = t0.elapsed().as_secs_f64() * 1e3;

    let registry =
        EngineRegistry::with_tiers(64, 256, Some(ArtifactStore::new(&dir).expect("reopen store")));
    let t0 = Instant::now();
    let loaded = registry.warm_start(vocab);
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(loaded > 0, "warm start must load up to the hot capacity");

    // Prewarm the hot-path spec once (first call compiles).
    let spec = ConstraintSpec::builtin("fig3");
    registry.get_or_compile(&spec, vocab, None).expect("compile fig3");

    let samples = 512usize;
    let mut lat = Vec::with_capacity(samples);
    for i in 0..samples {
        let t = Instant::now();
        if i % 2 == 0 {
            // Hot-tier admission: the common case at steady state.
            registry.get_or_compile(&spec, vocab, None).expect("hot lookup");
        } else {
            // Cold admission: keyed O(1) artifact load, independent of
            // corpus size.
            let key = keys[(i * 7919) % keys.len()];
            match store.load_keyed(key, vocab) {
                domino::constraint::ArtifactLoad::Hit { .. } => {}
                domino::constraint::ArtifactLoad::Miss => {
                    panic!("synthetic artifact {key:#x} missing from its own corpus")
                }
                domino::constraint::ArtifactLoad::Invalid { reason } => {
                    panic!("synthetic artifact {key:#x} invalid: {reason}")
                }
            }
        }
        lat.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let p99 = percentile(&mut lat, 0.99);
    let cold_entries = registry.stats().cold_entries as u64;

    let _ = std::fs::remove_dir_all(&dir);
    CorpusRun { size, seed_ms, warm_ms, cold_entries, p99_ms: p99 }
}

fn main() {
    let conns = env_usize("DOMINO_BENCH_GATEWAY_CONNS", 4096);
    let small = env_usize("DOMINO_BENCH_N", 1000).max(8);
    let big = small * 100;
    let max_ratio = env_f64("DOMINO_BENCH_GATEWAY_RATIO", 1.5);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let thread_cap = (cores * 2).max(16);

    println!(
        "== gateway scale: {conns} idle JSONL connections on a fixed worker pool, \
         then registry admission over {small} vs {big} on-disk grammars ==\n"
    );

    // --- Phase A ---
    let (threads_held, delta, stream_ms) = idle_connection_hold(conns);
    println!(
        "held {conns} idle connections: {threads_held} threads (delta {delta:+} while \
         holding, cap {thread_cap}); streamed sample in {stream_ms:.1} ms"
    );
    let threads_known = threads_held > 0; // procfs present
    if threads_known && (delta > 2 || threads_held > thread_cap) {
        eprintln!(
            "FAIL: thread count scaled with connections ({threads_held} threads, \
             delta {delta:+} over {conns} connections, cap {thread_cap})"
        );
        std::process::exit(1);
    }

    // --- Phase B ---
    let vocab = Arc::new(domino::tokenizer::bpe::synthetic_json_vocab(256));
    let cfg = builtin::by_name("fig3").expect("builtin fig3");
    let engine = Engine::compile(cfg, vocab.clone()).expect("compile fig3");

    let runs = [
        corpus_admission(&engine, &vocab, small),
        corpus_admission(&engine, &vocab, big),
    ];
    let mut table = Table::new(&[
        "corpus", "seed (ms)", "boot scan (ms)", "cold entries", "admission p99 (ms)",
    ]);
    for r in &runs {
        table.row(&[
            r.size.to_string(),
            format!("{:.1}", r.seed_ms),
            format!("{:.1}", r.warm_ms),
            r.cold_entries.to_string(),
            format!("{:.4}", r.p99_ms),
        ]);
    }
    table.print();

    let p99_small = runs[0].p99_ms.max(FLOOR_MS);
    let p99_big = runs[1].p99_ms.max(FLOOR_MS);
    let ratio = p99_big / p99_small;
    // `scale_flatness` is small/large so that *higher is better* for the
    // CI regression gate (1.0 = the 100× corpus costs admission nothing);
    // `_ms` fields are lower-is-better by suffix.
    let scale_flatness = p99_small / p99_big;
    println!(
        "\nadmission p99: {:.4} ms @ {small} -> {:.4} ms @ {big} \
         ({ratio:.2}x, limit {max_ratio:.2}x)",
        runs[0].p99_ms, runs[1].p99_ms
    );

    emit_json(
        "gateway_scale",
        &[
            ("idle_conns_held", conns as f64),
            ("conn_thread_delta", delta as f64),
            ("stream_sample_ms", stream_ms),
            ("admission_p99_small_ms", runs[0].p99_ms),
            ("admission_p99_large_ms", runs[1].p99_ms),
            ("scale_flatness", scale_flatness),
        ],
    );

    if ratio > max_ratio {
        eprintln!(
            "FAIL: registry admission p99 degraded {ratio:.2}x from {small} to {big} \
             grammars (limit {max_ratio:.2}x via DOMINO_BENCH_GATEWAY_RATIO)"
        );
        std::process::exit(1);
    }
    println!("gateway scale gates OK (threads bounded, admission {ratio:.2}x <= {max_ratio:.2}x)");
}
