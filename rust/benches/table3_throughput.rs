//! Table 3 reproduction: throughput impact of constrained decoding across
//! grammars, relative to unconstrained generation on the same backend.
//!
//! Grammars: JSON (no schema), JSON GSM8K schema, C, XML schema, fixed
//! template. Methods: llama.cpp-style online CFG, GUIDANCE template (where
//! applicable), DOMINO CFG, DOMINO accelerated (opportunistic or
//! speculative — whichever wins, as the paper reports for CFG^accel).
//!
//! `cargo bench --bench table3_throughput`

use domino::domino::decoder::Lookahead;
use domino::eval::harness::{eval_throughput, Method, Setup};
use domino::util::bench::Table;

fn main() {
    let setup = Setup::load();
    let n: usize =
        std::env::var("DOMINO_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(12);
    let max_tokens = 96;
    println!(
        "== Table 3: relative throughput (backend: {}, n={n} × {max_tokens} tokens, temperature 1.0) ==\n",
        setup.backend_name
    );

    let grammars = ["json", "gsm8k", "c", "xml", "template"];
    let mut table = Table::new(&[
        "Grammar", "GCD online (full)", "llama.cpp (opp.)", "Guidance Templ", "Domino CFG (full)",
        "Domino CFG accel", "accel mode",
    ]);

    for grammar in grammars {
        let base = eval_throughput(&setup, &Method::Unconstrained, grammar, n, max_tokens, 7)
            .expect("unconstrained");
        let rel = |m: &Method| -> String {
            match eval_throughput(&setup, m, grammar, n, max_tokens, 7) {
                Ok(r) => format!("{:.2}x", r.toks_per_s / base.toks_per_s),
                Err(e) => {
                    eprintln!("  {grammar}/{}: {e:#}", m.label());
                    "-".into()
                }
            }
        };
        // Template programs only exist for JSON-shaped workloads.
        let guidance = if matches!(grammar, "json" | "gsm8k" | "template") {
            rel(&Method::Guidance { ws: false })
        } else {
            "-".into()
        };
        let gcd = rel(&Method::Online { opportunistic: false });
        let llamacpp = rel(&Method::Online { opportunistic: true });
        let domino_full =
            rel(&Method::Domino { k: Lookahead::Infinite, spec: None, opportunistic: false });
        // Accelerated: speculation (s=8, matching the AOT chunk size) vs
        // plain opportunistic — report the better one, like the paper's
        // CFG^accel column.
        let spec = eval_throughput(
            &setup,
            &Method::Domino { k: Lookahead::Infinite, spec: Some(8), opportunistic: true },
            grammar,
            n,
            max_tokens,
            7,
        );
        let opp = eval_throughput(
            &setup,
            &Method::Domino { k: Lookahead::Infinite, spec: None, opportunistic: true },
            grammar,
            n,
            max_tokens,
            7,
        );
        let (accel, mode) = match (spec, opp) {
            (Ok(s), Ok(o)) if s.toks_per_s >= o.toks_per_s => (s.toks_per_s, "spec s=8"),
            (_, Ok(o)) => (o.toks_per_s, "opportunistic"),
            (Ok(s), _) => (s.toks_per_s, "spec s=8"),
            _ => (f64::NAN, "-"),
        };
        table.row(&[
            grammar.to_string(),
            gcd,
            llamacpp,
            guidance,
            domino_full,
            format!("{:.2}x", accel / base.toks_per_s),
            mode.to_string(),
        ]);
    }
    table.print();
    println!(
        "\nexpected shape (paper Table 3): online CFG ~0.7-0.9x; DOMINO >= online;\n\
         DOMINO accel > 1x on schema-driven grammars (gsm8k/xml/template), \n\
         opportunistic wins on free-form json/c."
    );
}
