//! Multi-tenant fairness bench: a hot tenant floods the scheduler at a
//! 10:1 request ratio and the gate checks that the cold tenant's p99
//! queue wait stays within a small multiple of its solo run — the
//! weighted-fair (deficit-round-robin) drain must confine the damage of
//! a flooding tenant to that tenant.
//!
//! Phase A measures the cold tenant alone (its solo baseline); Phase B
//! replays the same cold workload behind a 10× hot backlog with the
//! cold tenant at DRR weight 4. Both phases run on one engine shard so
//! every request contends for the same slots.
//!
//! `cargo bench --bench fairness`. Env knobs: `DOMINO_BENCH_N` (cold
//! request count, default 24), `DOMINO_BENCH_FAIR_RATIO` (max allowed
//! contended/solo p99 ratio, default 1.5; CI smoke relaxes to 2.0 — the
//! acceptance bar from the issue).
//!
//! Exits 1 if the cold tenant's contended p99 exceeds the ratio — this
//! is a correctness gate on fairness, not just a report.

use domino::eval::harness::{run_contention, ContentionConfig};
use domino::util::bench::{emit_json, Table};

/// Floor (ms) applied to both percentiles before the ratio: on an idle
/// machine the mock runtime drains a small queue in microseconds and the
/// ratio would amplify pure timer noise.
const FLOOR_MS: f64 = 0.25;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let n: usize =
        std::env::var("DOMINO_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(24);
    let max_ratio = env_f64("DOMINO_BENCH_FAIR_RATIO", 1.5);
    let max_tokens = 32;
    println!(
        "== tenant fairness: cold {n} requests solo vs behind a {}-request \
         hot backlog (10:1), cold DRR weight 4, mock runtime ==\n",
        10 * n
    );

    let base = ContentionConfig {
        cold_n: n,
        cold_weight: 4,
        max_tokens,
        ..ContentionConfig::default()
    };

    // Phase A: cold tenant alone.
    let solo = ContentionConfig { hot_n: 0, ..base.clone() };
    let (_, cold_solo) = run_contention(&solo).expect("solo run");

    // Phase B: fresh scheduler, hot backlog first, cold behind it.
    let mix = ContentionConfig { hot_n: 10 * n, ..base };
    let (hot, cold) = run_contention(&mix).expect("contended run");

    let mut table = Table::new(&[
        "phase", "tenant", "requests", "ok", "shed", "queue p50 (ms)", "queue p99 (ms)",
    ]);
    for (phase, tenant, o) in
        [("solo", "cold", &cold_solo), ("contended", "hot", &hot), ("contended", "cold", &cold)]
    {
        table.row(&[
            phase.to_string(),
            tenant.to_string(),
            o.submitted.to_string(),
            o.completed.to_string(),
            o.shed.to_string(),
            format!("{:.3}", o.queue_wait_p50_ms),
            format!("{:.3}", o.queue_wait_p99_ms),
        ]);
    }
    table.print();

    let solo_p99 = cold_solo.queue_wait_p99_ms.max(FLOOR_MS);
    let contended_p99 = cold.queue_wait_p99_ms.max(FLOOR_MS);
    let ratio = contended_p99 / solo_p99;
    // `isolation` is solo/contended so that *higher is better* for the
    // CI regression gate (1.0 = the hot flood cost the cold tenant
    // nothing); the `_ms` fields are lower-is-better by suffix.
    let isolation = solo_p99 / contended_p99;
    println!(
        "\ncold p99: {:.3} ms solo -> {:.3} ms contended ({ratio:.2}x, limit {max_ratio:.2}x)",
        cold_solo.queue_wait_p99_ms, cold.queue_wait_p99_ms
    );

    emit_json(
        "fairness",
        &[
            ("cold_solo_p99_ms", cold_solo.queue_wait_p99_ms),
            ("cold_contended_p99_ms", cold.queue_wait_p99_ms),
            ("isolation", isolation),
        ],
    );

    assert_eq!(cold.completed, n, "cold tenant must fully drain under the flood: {cold:?}");
    if ratio > max_ratio {
        eprintln!(
            "FAIL: cold tenant p99 queue wait degraded {ratio:.2}x under a 10:1 hot flood \
             (limit {max_ratio:.2}x via DOMINO_BENCH_FAIR_RATIO)"
        );
        std::process::exit(1);
    }
    println!("fairness gate OK ({ratio:.2}x <= {max_ratio:.2}x)");
}
