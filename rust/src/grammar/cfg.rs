//! CFG representation: interned terminals, productions, grammar analysis.

use crate::regex;
use anyhow::bail;
use std::collections::HashMap;
use std::fmt;

/// Terminal id (index into [`Cfg::terminals`]).
pub type TermId = u32;
/// Nonterminal id (index into [`Cfg::nonterminals`]).
pub type NtId = u32;

/// How a terminal is defined (§3.1: "terminals ... defined either by a
/// regex or a literal string").
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum TerminalKind {
    /// Fixed byte string, e.g. `"{"` or `"return"`.
    Literal(Vec<u8>),
    /// Regex over bytes, e.g. `/[1-9][0-9]*/`.
    Regex(String),
}

/// A grammar terminal.
#[derive(Clone, Debug)]
pub struct Terminal {
    /// Display name (auto-derived for anonymous literals).
    pub name: String,
    pub kind: TerminalKind,
}

/// Right-hand-side symbol.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Symbol {
    T(TermId),
    Nt(NtId),
}

/// One production `lhs ::= rhs`.
#[derive(Clone, Debug)]
pub struct Production {
    pub lhs: NtId,
    pub rhs: Vec<Symbol>,
}

/// A context-free grammar over regex/literal terminals.
#[derive(Clone, Debug)]
pub struct Cfg {
    pub terminals: Vec<Terminal>,
    pub nonterminals: Vec<String>,
    pub productions: Vec<Production>,
    /// Productions grouped by lhs (indices into `productions`).
    pub prods_by_lhs: Vec<Vec<usize>>,
    pub start: NtId,
    /// Nullable nonterminals (derive ε).
    pub nullable: Vec<bool>,
}

impl Cfg {
    /// Assemble + validate a grammar.
    pub fn new(
        terminals: Vec<Terminal>,
        nonterminals: Vec<String>,
        productions: Vec<Production>,
        start: NtId,
    ) -> crate::Result<Cfg> {
        let nt_count = nonterminals.len();
        let mut prods_by_lhs = vec![Vec::new(); nt_count];
        for (i, p) in productions.iter().enumerate() {
            if p.lhs as usize >= nt_count {
                bail!("production {} has out-of-range lhs", i);
            }
            for s in &p.rhs {
                match s {
                    Symbol::T(t) if *t as usize >= terminals.len() => {
                        bail!("production {} references unknown terminal", i)
                    }
                    Symbol::Nt(n) if *n as usize >= nt_count => {
                        bail!("production {} references unknown nonterminal", i)
                    }
                    _ => {}
                }
            }
            prods_by_lhs[p.lhs as usize].push(i);
        }
        for (nt, prods) in prods_by_lhs.iter().enumerate() {
            if prods.is_empty() {
                bail!("nonterminal `{}` has no productions", nonterminals[nt]);
            }
        }
        // Reject nullable regex terminals: optionality belongs to the CFG
        // (a nullable terminal would let the scanner's `r+` loop accept ε
        // forever — see grammar/mod.rs).
        for t in &terminals {
            match &t.kind {
                TerminalKind::Literal(b) if b.is_empty() => {
                    bail!("terminal `{}` is the empty literal; use an ε-production instead", t.name)
                }
                TerminalKind::Regex(pat) => {
                    let ast = regex::parse(pat)?;
                    if ast.nullable() {
                        bail!(
                            "regex terminal `{}` (/{}/) is nullable; make it non-nullable and lift optionality into the grammar",
                            t.name, pat
                        );
                    }
                }
                _ => {}
            }
        }
        let nullable = compute_nullable(nt_count, &productions);
        Ok(Cfg { terminals, nonterminals, productions, prods_by_lhs, start, nullable })
    }

    pub fn num_terminals(&self) -> usize {
        self.terminals.len()
    }

    pub fn terminal_name(&self, t: TermId) -> &str {
        &self.terminals[t as usize].name
    }

    /// Compile every terminal to its (minimized) DFA.
    pub fn terminal_dfas(&self) -> crate::Result<Vec<regex::Dfa>> {
        self.terminals
            .iter()
            .map(|t| {
                let ast = match &t.kind {
                    TerminalKind::Literal(bytes) => crate::regex::ast::Regex::Literal(bytes.clone()),
                    TerminalKind::Regex(pat) => regex::parse(pat)?,
                };
                Ok(regex::Dfa::from_nfa(&regex::Nfa::from_regex(&ast)))
            })
            .collect()
    }

    /// Compile every terminal to its Thompson NFA **without**
    /// determinizing — the cheap half of scanner construction, used by the
    /// lazy scanner ([`crate::scanner::Scanner::new_lazy`]) which
    /// determinizes per visited state instead.
    pub fn terminal_nfas(&self) -> crate::Result<Vec<regex::Nfa>> {
        self.terminals
            .iter()
            .map(|t| {
                let ast = match &t.kind {
                    TerminalKind::Literal(bytes) => crate::regex::ast::Regex::Literal(bytes.clone()),
                    TerminalKind::Regex(pat) => regex::parse(pat)?,
                };
                Ok(regex::Nfa::from_regex(&ast))
            })
            .collect()
    }
}

fn compute_nullable(nt_count: usize, productions: &[Production]) -> Vec<bool> {
    let mut nullable = vec![false; nt_count];
    loop {
        let mut changed = false;
        for p in productions {
            if nullable[p.lhs as usize] {
                continue;
            }
            let all_nullable = p.rhs.iter().all(|s| match s {
                Symbol::T(_) => false,
                Symbol::Nt(n) => nullable[*n as usize],
            });
            if all_nullable {
                nullable[p.lhs as usize] = true;
                changed = true;
            }
        }
        if !changed {
            return nullable;
        }
    }
}

impl fmt::Display for Cfg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for p in &self.productions {
            write!(f, "{} ::=", self.nonterminals[p.lhs as usize])?;
            if p.rhs.is_empty() {
                write!(f, " ε")?;
            }
            for s in &p.rhs {
                match s {
                    Symbol::T(t) => write!(f, " {}", self.terminals[*t as usize].name)?,
                    Symbol::Nt(n) => write!(f, " {}", self.nonterminals[*n as usize])?,
                }
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

/// Incremental builder used by the EBNF front-end and by tests.
#[derive(Default)]
pub struct CfgBuilder {
    terminals: Vec<Terminal>,
    term_ids: HashMap<TerminalKind, TermId>,
    nonterminals: Vec<String>,
    nt_ids: HashMap<String, NtId>,
    productions: Vec<Production>,
}

impl CfgBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a terminal (deduped by kind).
    pub fn terminal(&mut self, name: &str, kind: TerminalKind) -> TermId {
        if let Some(&id) = self.term_ids.get(&kind) {
            return id;
        }
        let id = self.terminals.len() as TermId;
        self.terminals.push(Terminal { name: name.to_string(), kind: kind.clone() });
        self.term_ids.insert(kind, id);
        id
    }

    pub fn literal(&mut self, text: &str) -> TermId {
        self.terminal(&format!("'{}'", text.escape_debug()), TerminalKind::Literal(text.as_bytes().to_vec()))
    }

    pub fn regex_term(&mut self, name: &str, pattern: &str) -> TermId {
        self.terminal(name, TerminalKind::Regex(pattern.to_string()))
    }

    /// Intern a nonterminal by name.
    pub fn nonterminal(&mut self, name: &str) -> NtId {
        if let Some(&id) = self.nt_ids.get(name) {
            return id;
        }
        let id = self.nonterminals.len() as NtId;
        self.nonterminals.push(name.to_string());
        self.nt_ids.insert(name.to_string(), id);
        id
    }

    pub fn has_nonterminal(&self, name: &str) -> bool {
        self.nt_ids.contains_key(name)
    }

    pub fn production(&mut self, lhs: NtId, rhs: Vec<Symbol>) {
        self.productions.push(Production { lhs, rhs });
    }

    pub fn build(self, start: NtId) -> crate::Result<Cfg> {
        Cfg::new(self.terminals, self.nonterminals, self.productions, start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The running-example grammar from Fig. 3 (a):
    /// `E ::= int | ( E ) | E + E` with `int = /(0+)|([1-9][0-9]*)/`.
    pub fn fig3_grammar() -> Cfg {
        let mut b = CfgBuilder::new();
        let e = b.nonterminal("E");
        let int = b.regex_term("int", "(0+)|([1-9][0-9]*)");
        let lp = b.literal("(");
        let rp = b.literal(")");
        let plus = b.literal("+");
        b.production(e, vec![Symbol::T(int)]);
        b.production(e, vec![Symbol::T(lp), Symbol::Nt(e), Symbol::T(rp)]);
        b.production(e, vec![Symbol::Nt(e), Symbol::T(plus), Symbol::Nt(e)]);
        b.build(e).unwrap()
    }

    #[test]
    fn builds_fig3() {
        let g = fig3_grammar();
        assert_eq!(g.num_terminals(), 4);
        assert_eq!(g.nonterminals, vec!["E"]);
        assert_eq!(g.productions.len(), 3);
        assert!(!g.nullable[0]);
    }

    #[test]
    fn terminal_dedup() {
        let mut b = CfgBuilder::new();
        let a = b.literal("+");
        let c = b.literal("+");
        assert_eq!(a, c);
    }

    #[test]
    fn nullable_computation() {
        let mut b = CfgBuilder::new();
        let s = b.nonterminal("S");
        let a = b.nonterminal("A");
        let x = b.literal("x");
        b.production(s, vec![Symbol::Nt(a), Symbol::Nt(a)]);
        b.production(a, vec![]);
        b.production(a, vec![Symbol::T(x)]);
        let g = b.build(s).unwrap();
        assert!(g.nullable[0] && g.nullable[1]);
    }

    #[test]
    fn rejects_nullable_regex_terminal() {
        let mut b = CfgBuilder::new();
        let s = b.nonterminal("S");
        let ws = b.regex_term("ws", "[ \t]*");
        b.production(s, vec![Symbol::T(ws)]);
        assert!(b.build(s).is_err());
    }

    #[test]
    fn rejects_undefined_nonterminal() {
        let mut b = CfgBuilder::new();
        let s = b.nonterminal("S");
        let orphan = b.nonterminal("orphan");
        b.production(s, vec![Symbol::Nt(orphan)]);
        assert!(b.build(s).is_err());
    }

    #[test]
    fn dfas_compile() {
        let g = fig3_grammar();
        let dfas = g.terminal_dfas().unwrap();
        assert!(dfas[0].accepts(b"12"));
        assert!(!dfas[0].accepts(b"012"));
        assert!(dfas[1].accepts(b"("));
    }
}
