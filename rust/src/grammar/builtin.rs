//! The paper's evaluation grammars (App. C, Listings 3–7) plus the CoNLL
//! NER schema (App. D, Listing 9) and the Fig. 3 running example.
//!
//! Translation notes (llama.cpp-style notation → our scanner/parser split):
//!
//! * character-level rules (`identifier ::= [a-zA-Z_] [a-zA-Z_0-9]*`)
//!   become regex terminals (`identifier ::= /[a-zA-Z_][a-zA-Z_0-9]*/`);
//! * the paper's recursive `ws ::= ([ \t\n] ws)?` is ε-or-nonempty
//!   whitespace: we use a non-nullable `WS ::= /[ \t\n]+/` terminal and an
//!   optional `ws ::= WS?` nonterminal (nullable terminals are rejected by
//!   [`super::cfg::Cfg::new`] — optionality belongs to the CFG);
//! * keyword/identifier overlap in the C grammar (`"int"` matches both the
//!   keyword literal and the identifier regex) is kept: the scanner tracks
//!   both sub-automata and the parser disambiguates — the edge case §3.3
//!   calls out.

use super::cfg::{Cfg, CfgBuilder, Symbol};
use super::ebnf::parse_ebnf;

/// Fig. 3 (a): `E ::= int | ( E ) | E + E`.
pub fn fig3_expr() -> Cfg {
    let mut b = CfgBuilder::new();
    let e = b.nonterminal("E");
    let int = b.regex_term("int", "(0+)|([1-9][0-9]*)");
    let lp = b.literal("(");
    let rp = b.literal(")");
    let plus = b.literal("+");
    b.production(e, vec![Symbol::T(int)]);
    b.production(e, vec![Symbol::T(lp), Symbol::Nt(e), Symbol::T(rp)]);
    b.production(e, vec![Symbol::Nt(e), Symbol::T(plus), Symbol::Nt(e)]);
    b.build(e).expect("fig3 grammar is valid")
}

/// JSON string terminal with escapes — shared by several grammars
/// (Listing 3 `string`).
const JSON_STRING: &str =
    r#"STRING ::= /"([^"\\]|\\(["\\\/bfnrt]|u[0-9a-fA-F]{4}))*"/"#;

/// JSON number terminal (Listing 3 `number`).
const JSON_NUMBER: &str =
    r#"NUMBER ::= /-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][-+]?[0-9]+)?/"#;

const WS: &str = r#"
WS ::= /[ \t\n]+/
ws ::= WS?
"#;

/// Listing 3: basic (schema-free) JSON.
pub fn json() -> Cfg {
    let src = format!(
        r#"
root ::= ws value
value ::= object | array | STRING ws | NUMBER ws | "true" ws | "false" ws | "null" ws
object ::= "{{" ws (pair ("," ws pair)*)? "}}" ws
pair ::= STRING ws ":" ws value
array ::= "[" ws (value ("," ws value)*)? "]" ws
{JSON_STRING}
{JSON_NUMBER}
{WS}
"#
    );
    parse_ebnf(&src).expect("json grammar is valid")
}

/// Listing 4: guided math reasoning schema for GSM8K —
/// `{{"thoughts": [{{step, calculation, result}}...], "answer": n}}`.
pub fn gsm8k_schema() -> Cfg {
    let src = format!(
        r#"
root ::= ws object
object ::= "{{" ws "\"thoughts\"" ws ":" ws "[" ws thought ("," ws thought)* "]" ws "," ws "\"answer\"" ws ":" ws INT ws "}}" ws
thought ::= "{{" ws "\"step\"" ws ":" ws STRING ws "," ws "\"calculation\"" ws ":" ws STRING ws "," ws "\"result\"" ws ":" ws INT ws "}}" ws
INT ::= /-?[0-9]+/
{JSON_STRING}
{WS}
"#
    );
    parse_ebnf(&src).expect("gsm8k grammar is valid")
}

/// App. D (Listing 9): CoNLL-2003 NER output schema —
/// `{{"entities": [{{"entity": s, "type": PER|LOC|ORG|MISC}}...]}}`.
pub fn conll_schema() -> Cfg {
    let src = format!(
        r#"
root ::= ws object
object ::= "{{" ws "\"entities\"" ws ":" ws "[" ws (entity ("," ws entity)*)? "]" ws "}}" ws
entity ::= "{{" ws "\"entity\"" ws ":" ws STRING ws "," ws "\"type\"" ws ":" ws type "}}" ws
type ::= "\"PER\"" ws | "\"LOC\"" ws | "\"ORG\"" ws | "\"MISC\"" ws
{JSON_STRING}
{WS}
"#
    );
    parse_ebnf(&src).expect("conll grammar is valid")
}

/// Listing 5: simple C program grammar.
pub fn c_lang() -> Cfg {
    let src = format!(
        r#"
root ::= ws declaration declaration*
declaration ::= dataType identifier ws "(" ws (parameter ("," ws parameter)*)? ws ")" ws "{{" ws statement* "}}" ws
dataType ::= "int" WS | "float" WS | "char" WS
parameter ::= dataType identifier ws
statement ::=
      dataType identifier ws "=" ws expression ";" ws
    | dataType identifier ws "[" ws expression ws "]" ws ("=" ws expression)? ";" ws
    | identifier ws "=" ws expression ";" ws
    | identifier ws "(" ws argList? ")" ws ";" ws
    | "return" WS expression ";" ws
    | "while" ws "(" ws condition ")" ws "{{" ws statement* "}}" ws
    | "for" ws "(" ws forInit ";" ws condition ";" ws forUpdate ")" ws "{{" ws statement* "}}" ws
    | "if" ws "(" ws condition ")" ws "{{" ws statement* "}}" ws ("else" ws "{{" ws statement* "}}" ws)?
    | COMMENT ws
    | MLCOMMENT ws
forInit ::= dataType identifier ws "=" ws expression | identifier ws "=" ws expression
forUpdate ::= identifier ws "=" ws expression
condition ::= expression relationOperator ws expression
relationOperator ::= "<=" | "<" | "==" | "!=" | ">=" | ">"
expression ::= term (("+" | "-") ws term)*
term ::= factor (("*" | "\/") ws factor)*
factor ::= identifier ws | NUMBER ws | unaryTerm | funcCall | parenExpression | subscript | STRING ws
unaryTerm ::= "-" factor
funcCall ::= identifier "(" ws argList? ")" ws
parenExpression ::= "(" ws expression ")" ws
subscript ::= identifier "[" ws expression "]" ws
argList ::= expression ("," ws expression)*
identifier ::= /[a-zA-Z_][a-zA-Z_0-9]*/
NUMBER ::= /-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][-+]?[0-9]+)?/
{JSON_STRING}
COMMENT ::= /\/\/[^\n]*\n/
MLCOMMENT ::= /\/\*([^*]|(\*[^\/]))*\*\//
{WS}
"#
    );
    parse_ebnf(&src).expect("c grammar is valid")
}

/// Listing 6: XML with a person schema (recursive via `friends`).
pub fn xml_schema() -> Cfg {
    let src = format!(
        r#"
root ::= ws person
person ::= "<person>" ws personattributes "</person>" ws
personattributes ::= nameattribute ageattribute jobattribute friends?
nameattribute ::= "<name>" TEXT "</name>" ws
ageattribute ::= "<age>" TEXT "</age>" ws
jobattribute ::= "<job>" ws jobinfo "</job>" ws
jobinfo ::= jobtitle jobsalary
jobtitle ::= "<title>" TEXT "</title>" ws
jobsalary ::= "<salary>" TEXT "</salary>" ws
friends ::= "<friends>" ws person person* "</friends>" ws
TEXT ::= /[^<]+/
{WS}
"#
    );
    parse_ebnf(&src).expect("xml grammar is valid")
}

/// Listing 7: fixed-template RPG character profile (GUIDANCE-style —
/// everything fixed except the generated fields).
pub fn fixed_template() -> Cfg {
    let src = r#"
root ::= ws dict
dict ::= "{" ws content ws "}" ws
content ::= id_pair "," ws description_pair "," ws name_pair "," ws age_pair "," ws armor_pair "," ws weapon_pair "," ws class_pair "," ws mantra_pair "," ws strength_pair "," ws items_pair
id_pair ::= "\"id\"" ws ":" ws NUMBER
description_pair ::= "\"description\"" ws ":" ws "\"A nimble fighter\""
name_pair ::= "\"name\"" ws ":" ws STRING
age_pair ::= "\"age\"" ws ":" ws NUMBER
armor_pair ::= "\"armor\"" ws ":" ws ("\"leather\"" | "\"chainmail\"" | "\"plate\"")
weapon_pair ::= "\"weapon\"" ws ":" ws ("\"sword\"" | "\"axe\"" | "\"bow\"")
class_pair ::= "\"class\"" ws ":" ws STRING
mantra_pair ::= "\"mantra\"" ws ":" ws STRING
strength_pair ::= "\"strength\"" ws ":" ws NUMBER
items_pair ::= "\"items\"" ws ":" ws "[" ws item "," ws item "," ws item ws "]"
item ::= STRING
STRING ::= /"[^\n\r"]+"/
NUMBER ::= /[0-9]+/
WS ::= /[ \t\n]+/
ws ::= WS?
"#;
    parse_ebnf(src).expect("template grammar is valid")
}

/// All named evaluation grammars, as used by benches and the CLI.
pub fn by_name(name: &str) -> Option<Cfg> {
    Some(match name {
        "fig3" => fig3_expr(),
        "json" => json(),
        "gsm8k" => gsm8k_schema(),
        "conll" => conll_schema(),
        "c" => c_lang(),
        "xml" => xml_schema(),
        "template" => fixed_template(),
        _ => return None,
    })
}

/// Names accepted by [`by_name`].
pub const GRAMMAR_NAMES: &[&str] = &["fig3", "json", "gsm8k", "conll", "c", "xml", "template"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_builtins_compile() {
        for name in GRAMMAR_NAMES {
            let g = by_name(name).unwrap();
            assert!(g.num_terminals() > 0, "{name}");
            // All terminal DFAs must compile too.
            let dfas = g.terminal_dfas().unwrap();
            assert_eq!(dfas.len(), g.num_terminals(), "{name}");
        }
    }

    #[test]
    fn json_terminals() {
        let g = json();
        let names: Vec<&str> = g.terminals.iter().map(|t| t.name.as_str()).collect();
        assert!(names.contains(&"STRING"));
        assert!(names.contains(&"NUMBER"));
        assert!(names.contains(&"WS"));
        let dfas = g.terminal_dfas().unwrap();
        let string_id = g.terminals.iter().position(|t| t.name == "STRING").unwrap();
        assert!(dfas[string_id].accepts(br#""hi there""#));
        assert!(dfas[string_id].accepts("\"é\"".as_bytes()));
        assert!(!dfas[string_id].accepts(br#""""#.strip_suffix(b"\"").unwrap()));
    }

    #[test]
    fn c_keyword_identifier_overlap() {
        let g = c_lang();
        let dfas = g.terminal_dfas().unwrap();
        let ident = g.terminals.iter().position(|t| t.name == "identifier").unwrap();
        let int_kw = g
            .terminals
            .iter()
            .position(|t| matches!(&t.kind, super::super::cfg::TerminalKind::Literal(b) if b == b"int"))
            .unwrap();
        // "int" is accepted by BOTH terminals — the ambiguity §3.3 mentions.
        assert!(dfas[ident].accepts(b"int"));
        assert!(dfas[int_kw].accepts(b"int"));
    }

    #[test]
    fn c_comment_terminals() {
        let g = c_lang();
        let dfas = g.terminal_dfas().unwrap();
        let ml = g.terminals.iter().position(|t| t.name == "MLCOMMENT").unwrap();
        assert!(dfas[ml].accepts(b"/* hi */"));
        assert!(dfas[ml].accepts(b"/* a * b */"));
        assert!(!dfas[ml].accepts(b"/* unterminated"));
        let sl = g.terminals.iter().position(|t| t.name == "COMMENT").unwrap();
        assert!(dfas[sl].accepts(b"// c\n"));
    }

    #[test]
    fn xml_text_terminal_merges() {
        // NAME and NUMBER in the paper's listing share the regex [^<]+ —
        // interning dedups them into one TEXT terminal.
        let g = xml_schema();
        let text_terms =
            g.terminals.iter().filter(|t| matches!(&t.kind, super::super::cfg::TerminalKind::Regex(p) if p == "[^<]+")).count();
        assert_eq!(text_terms, 1);
    }
}
