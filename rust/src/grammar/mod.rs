//! Context-free grammar substrate.
//!
//! * [`cfg`] — the CFG representation DOMINO operates on: interned
//!   **terminals** (each a literal string or a regex over bytes) and
//!   **productions** over terminals + nonterminals.
//! * [`ebnf`] — parser for the grammar notation used throughout the paper's
//!   App. C (`::=` rules, `|`, `( )`, `?`, `*`, `+`, string literals and
//!   slash-delimited regex terminals), with EBNF-operator desugaring to
//!   plain productions.
//! * [`builtin`] — the five evaluation grammars from the paper (JSON,
//!   GSM8K-schema JSON, C subset, XML-with-schema, fixed template) plus the
//!   CoNLL NER schema, translated into this notation.
//! * [`jsonschema`] — the JSON Schema front-end: a useful schema subset
//!   (types, properties/required, enum/const, bounded arrays,
//!   anyOf/oneOf, pattern/format, integer bounds, intra-document `$ref`)
//!   compiled to the same CFG representation, with path-annotated errors
//!   for everything outside the subset.
//!
//! Design note: the paper's llama.cpp-style notation mixes character-level
//! constructs into grammar rules (`identifier ::= [a-zA-Z_] [a-zA-Z_0-9]*`).
//! DOMINO's architecture however is a *scanner/parser split* (§3.2): the
//! scanner owns regular structure, the parser owns context-free structure.
//! Our notation therefore makes the split explicit — character-level rules
//! become regex terminals (`/[a-zA-Z_][a-zA-Z_0-9]*/`). `builtin.rs`
//! documents each translation.

pub mod builtin;
pub mod cfg;
pub mod ebnf;
pub mod jsonschema;

pub use cfg::{Cfg, CfgBuilder, Production, Symbol, TermId, Terminal, TerminalKind};
pub use ebnf::parse_ebnf;
