//! EBNF front-end for the paper's grammar notation.
//!
//! ```text
//! # comment
//! root   ::= value
//! value  ::= object | array | STRING | "true" ws
//! object ::= "{" ws (pair ("," ws pair)*)? "}" ws
//! STRING ::= /"[^"]*"/          # a rule whose body is a single regex
//!                               # defines a *terminal*, not a nonterminal
//! ```
//!
//! * `"..."` — literal terminal (escapes: `\n \t \r \\ \" \u{...}`),
//! * `/.../` — regex terminal (see [`crate::regex::parse`] for the dialect;
//!   `\/` escapes the delimiter),
//! * `|` alternation, `( )` grouping, `?` `*` `+` postfix operators,
//! * EBNF operators are desugared to plain productions via synthetic
//!   nonterminals (`name%opt`, `name%star`, ...),
//! * the first rule is the start symbol.

use super::cfg::{Cfg, CfgBuilder, Symbol};
use anyhow::{bail, Context};
use std::collections::HashMap;

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Name(String),
    Define, // ::=
    Pipe,
    LParen,
    RParen,
    Quest,
    Star,
    Plus,
    Literal(String),
    RegexPat(String),
}

fn tokenize(src: &str) -> crate::Result<Vec<(Tok, usize)>> {
    let mut toks = Vec::new();
    let chars: Vec<char> = src.chars().collect();
    let mut i = 0;
    let mut line = 1;
    while i < chars.len() {
        let c = chars[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            ' ' | '\t' | '\r' => i += 1,
            '#' => {
                while i < chars.len() && chars[i] != '\n' {
                    i += 1;
                }
            }
            ':' => {
                if chars.get(i + 1) == Some(&':') && chars.get(i + 2) == Some(&'=') {
                    toks.push((Tok::Define, line));
                    i += 3;
                } else {
                    bail!("ebnf line {line}: stray `:`");
                }
            }
            '|' => {
                toks.push((Tok::Pipe, line));
                i += 1;
            }
            '(' => {
                toks.push((Tok::LParen, line));
                i += 1;
            }
            ')' => {
                toks.push((Tok::RParen, line));
                i += 1;
            }
            '?' => {
                toks.push((Tok::Quest, line));
                i += 1;
            }
            '*' => {
                toks.push((Tok::Star, line));
                i += 1;
            }
            '+' => {
                toks.push((Tok::Plus, line));
                i += 1;
            }
            '"' => {
                i += 1;
                let mut s = String::new();
                loop {
                    match chars.get(i) {
                        None => bail!("ebnf line {line}: unterminated string literal"),
                        Some('"') => {
                            i += 1;
                            break;
                        }
                        Some('\\') => {
                            i += 1;
                            let e = chars.get(i).context("dangling escape")?;
                            s.push(match e {
                                'n' => '\n',
                                't' => '\t',
                                'r' => '\r',
                                '\\' => '\\',
                                '"' => '"',
                                '/' => '/',
                                other => bail!("ebnf line {line}: unknown string escape \\{other}"),
                            });
                            i += 1;
                        }
                        Some(&c) => {
                            if c == '\n' {
                                line += 1;
                            }
                            s.push(c);
                            i += 1;
                        }
                    }
                }
                toks.push((Tok::Literal(s), line));
            }
            '/' => {
                i += 1;
                let mut s = String::new();
                loop {
                    match chars.get(i) {
                        None => bail!("ebnf line {line}: unterminated regex"),
                        Some('/') => {
                            i += 1;
                            break;
                        }
                        Some('\\') if chars.get(i + 1) == Some(&'/') => {
                            s.push('/');
                            i += 2;
                        }
                        Some('\\') => {
                            s.push('\\');
                            if let Some(&n) = chars.get(i + 1) {
                                s.push(n);
                                i += 2;
                            } else {
                                i += 1;
                            }
                        }
                        Some(&c) => {
                            if c == '\n' {
                                line += 1;
                            }
                            s.push(c);
                            i += 1;
                        }
                    }
                }
                toks.push((Tok::RegexPat(s), line));
            }
            c if c.is_alphanumeric() || c == '_' || c == '%' => {
                let start = i;
                while i < chars.len()
                    && (chars[i].is_alphanumeric() || chars[i] == '_' || chars[i] == '%')
                {
                    i += 1;
                }
                toks.push((Tok::Name(chars[start..i].iter().collect()), line));
            }
            other => bail!("ebnf line {line}: unexpected character `{other}`"),
        }
    }
    Ok(toks)
}

/// Expression tree before desugaring.
#[derive(Debug, Clone)]
enum Expr {
    Ref(String),
    Literal(String),
    RegexPat(String),
    Seq(Vec<Expr>),
    Alt(Vec<Expr>),
    Opt(Box<Expr>),
    Star(Box<Expr>),
    Plus(Box<Expr>),
}

struct RuleParser {
    toks: Vec<(Tok, usize)>,
    pos: usize,
}

impl RuleParser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|(t, _)| t)
    }

    fn line(&self) -> usize {
        self.toks.get(self.pos).or_else(|| self.toks.last()).map_or(0, |(_, l)| *l)
    }

    /// Parse `name ::= alt` until the next `name ::=` or EOF.
    fn rule(&mut self) -> crate::Result<Option<(String, Expr)>> {
        let name = match self.peek() {
            None => return Ok(None),
            Some(Tok::Name(n)) => n.clone(),
            Some(other) => bail!("ebnf line {}: expected rule name, got {:?}", self.line(), other),
        };
        self.pos += 1;
        if self.peek() != Some(&Tok::Define) {
            bail!("ebnf line {}: expected `::=` after `{name}`", self.line());
        }
        self.pos += 1;
        let body = self.alt()?;
        Ok(Some((name, body)))
    }

    fn alt(&mut self) -> crate::Result<Expr> {
        let mut branches = vec![self.seq()?];
        while self.peek() == Some(&Tok::Pipe) {
            self.pos += 1;
            branches.push(self.seq()?);
        }
        Ok(if branches.len() == 1 { branches.pop().unwrap() } else { Expr::Alt(branches) })
    }

    fn seq(&mut self) -> crate::Result<Expr> {
        let mut parts = Vec::new();
        loop {
            match self.peek() {
                Some(Tok::Name(_)) => {
                    // A name followed by `::=` starts the next rule.
                    if self.toks.get(self.pos + 1).map(|(t, _)| t) == Some(&Tok::Define) {
                        break;
                    }
                    parts.push(self.postfix()?);
                }
                Some(Tok::Literal(_)) | Some(Tok::RegexPat(_)) | Some(Tok::LParen) => {
                    parts.push(self.postfix()?)
                }
                _ => break,
            }
        }
        Ok(match parts.len() {
            0 => Expr::Seq(vec![]),
            1 => parts.pop().unwrap(),
            _ => Expr::Seq(parts),
        })
    }

    fn postfix(&mut self) -> crate::Result<Expr> {
        let mut e = self.atom()?;
        loop {
            match self.peek() {
                Some(Tok::Quest) => {
                    self.pos += 1;
                    e = Expr::Opt(Box::new(e));
                }
                Some(Tok::Star) => {
                    self.pos += 1;
                    e = Expr::Star(Box::new(e));
                }
                Some(Tok::Plus) => {
                    self.pos += 1;
                    e = Expr::Plus(Box::new(e));
                }
                _ => break,
            }
        }
        Ok(e)
    }

    fn atom(&mut self) -> crate::Result<Expr> {
        let line = self.line();
        match self.peek().cloned() {
            Some(Tok::Name(n)) => {
                self.pos += 1;
                Ok(Expr::Ref(n))
            }
            Some(Tok::Literal(s)) => {
                self.pos += 1;
                Ok(Expr::Literal(s))
            }
            Some(Tok::RegexPat(s)) => {
                self.pos += 1;
                Ok(Expr::RegexPat(s))
            }
            Some(Tok::LParen) => {
                self.pos += 1;
                let inner = self.alt()?;
                if self.peek() != Some(&Tok::RParen) {
                    bail!("ebnf line {line}: unclosed group");
                }
                self.pos += 1;
                Ok(inner)
            }
            other => bail!("ebnf line {line}: expected atom, got {other:?}"),
        }
    }
}

/// Parse an EBNF grammar source into a [`Cfg`]. The first rule is the start
/// symbol.
pub fn parse_ebnf(src: &str) -> crate::Result<Cfg> {
    let toks = tokenize(src)?;
    let mut parser = RuleParser { toks, pos: 0 };
    let mut rules: Vec<(String, Expr)> = Vec::new();
    while let Some(rule) = parser.rule()? {
        rules.push(rule);
    }
    if rules.is_empty() {
        bail!("ebnf: no rules");
    }

    // Pass 1: rules whose body is a single regex atom define terminals.
    let mut term_defs: HashMap<String, String> = HashMap::new();
    for (name, body) in &rules {
        if let Expr::RegexPat(pat) = body {
            term_defs.insert(name.clone(), pat.clone());
        }
    }

    let mut b = CfgBuilder::new();
    // Pre-intern nonterminals in declaration order so the start symbol is
    // rule 0 and synthetic names can't collide (user names can't contain %).
    for (name, _) in &rules {
        if !term_defs.contains_key(name) {
            b.nonterminal(name);
        }
    }

    let mut lowerer = Lowerer { b, term_defs, anon: 0 };
    let mut defined: HashMap<String, bool> = HashMap::new();
    for (name, body) in &rules {
        if lowerer.term_defs.contains_key(name) {
            continue;
        }
        if defined.insert(name.clone(), true).is_some() {
            bail!("ebnf: duplicate rule `{name}` (use `|` for alternatives)");
        }
        let lhs = lowerer.b.nonterminal(name);
        lowerer.lower_rule(lhs, body)?;
    }
    // Start symbol: the first rule. If it defines a terminal, wrap it in a
    // synthetic start nonterminal.
    let start = if let Some(pat) = lowerer.term_defs.get(&rules[0].0).cloned() {
        let nt = lowerer.b.nonterminal("%root");
        let t = lowerer.b.regex_term(&rules[0].0, &pat);
        lowerer.b.production(nt, vec![Symbol::T(t)]);
        nt
    } else {
        lowerer.b.nonterminal(&rules[0].0)
    };
    lowerer.b.build(start)
}

struct Lowerer {
    b: CfgBuilder,
    term_defs: HashMap<String, String>,
    anon: usize,
}

impl Lowerer {
    /// Lower `lhs ::= body`, flattening top-level alternation into separate
    /// productions.
    fn lower_rule(&mut self, lhs: u32, body: &Expr) -> crate::Result<()> {
        match body {
            Expr::Alt(branches) => {
                for br in branches {
                    let rhs = self.lower_seq(br)?;
                    self.b.production(lhs, rhs);
                }
            }
            other => {
                let rhs = self.lower_seq(other)?;
                self.b.production(lhs, rhs);
            }
        }
        Ok(())
    }

    /// Lower an expression to a symbol sequence (introducing synthetic
    /// nonterminals for nested operators).
    fn lower_seq(&mut self, e: &Expr) -> crate::Result<Vec<Symbol>> {
        match e {
            Expr::Seq(parts) => {
                let mut out = Vec::new();
                for p in parts {
                    out.extend(self.lower_seq(p)?);
                }
                Ok(out)
            }
            other => Ok(vec![self.lower_symbol(other)?]),
        }
    }

    fn lower_symbol(&mut self, e: &Expr) -> crate::Result<Symbol> {
        match e {
            Expr::Ref(name) => {
                if let Some(pat) = self.term_defs.get(name).cloned() {
                    Ok(Symbol::T(self.b.regex_term(name, &pat)))
                } else if self.b.has_nonterminal(name) {
                    Ok(Symbol::Nt(self.b.nonterminal(name)))
                } else {
                    bail!("ebnf: reference to undefined rule `{name}`")
                }
            }
            Expr::Literal(s) => {
                if s.is_empty() {
                    bail!("ebnf: empty literal; use `( ... )?` for optionality");
                }
                Ok(Symbol::T(self.b.literal(s)))
            }
            Expr::RegexPat(pat) => {
                let name = format!("/{pat}/");
                Ok(Symbol::T(self.b.regex_term(&name, pat)))
            }
            Expr::Opt(inner) => {
                let nt = self.fresh("opt");
                let rhs = self.lower_seq(inner)?;
                self.b.production(nt, rhs);
                self.b.production(nt, vec![]);
                Ok(Symbol::Nt(nt))
            }
            Expr::Star(inner) => {
                // star ::= item star | ε  (right-recursive keeps Earley
                // charts shallow for long lists)
                let nt = self.fresh("star");
                let mut rhs = self.lower_seq(inner)?;
                rhs.push(Symbol::Nt(nt));
                self.b.production(nt, rhs);
                self.b.production(nt, vec![]);
                Ok(Symbol::Nt(nt))
            }
            Expr::Plus(inner) => {
                // plus ::= item plus | item
                let nt = self.fresh("plus");
                let item = self.lower_seq(inner)?;
                let mut rec = item.clone();
                rec.push(Symbol::Nt(nt));
                self.b.production(nt, rec);
                self.b.production(nt, item);
                Ok(Symbol::Nt(nt))
            }
            Expr::Seq(_) => {
                let nt = self.fresh("seq");
                let rhs = self.lower_seq(e)?;
                self.b.production(nt, rhs);
                Ok(Symbol::Nt(nt))
            }
            Expr::Alt(branches) => {
                let nt = self.fresh("alt");
                for br in branches {
                    let rhs = self.lower_seq(br)?;
                    self.b.production(nt, rhs);
                }
                Ok(Symbol::Nt(nt))
            }
        }
    }

    fn fresh(&mut self, kind: &str) -> u32 {
        self.anon += 1;
        self.b.nonterminal(&format!("%{kind}{}", self.anon))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grammar::TerminalKind;

    #[test]
    fn parses_fig3_running_example() {
        let g = parse_ebnf(
            r#"
            # Fig. 3 (a)
            E ::= int | "(" E ")" | E "+" E
            int ::= /(0+)|([1-9][0-9]*)/
            "#,
        )
        .unwrap();
        assert_eq!(g.nonterminals[g.start as usize], "E");
        assert_eq!(g.prods_by_lhs[g.start as usize].len(), 3);
        assert_eq!(g.num_terminals(), 4);
        let int = g.terminals.iter().find(|t| t.name == "int").unwrap();
        assert!(matches!(&int.kind, TerminalKind::Regex(p) if p.contains("[1-9]")));
    }

    #[test]
    fn desugars_operators() {
        let g = parse_ebnf(
            r#"
            list ::= "[" (item ("," item)*)? "]"
            item ::= /[a-z]+/
            "#,
        )
        .unwrap();
        // list, %opt, %star, item-as-terminal
        assert!(g.nonterminals.iter().any(|n| n.starts_with("%opt")));
        assert!(g.nonterminals.iter().any(|n| n.starts_with("%star")));
        assert!(g.nullable.iter().any(|&n| n)); // %opt and %star are nullable
    }

    #[test]
    fn literal_escapes() {
        let g = parse_ebnf(r#"s ::= "a\nb\"c""#).unwrap();
        match &g.terminals[0].kind {
            TerminalKind::Literal(b) => assert_eq!(b, b"a\nb\"c"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn regex_with_escaped_slash() {
        let g = parse_ebnf(r#"s ::= /a\/b/"#).unwrap();
        match &g.terminals[0].kind {
            TerminalKind::Regex(p) => assert_eq!(p, "a/b"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn errors() {
        assert!(parse_ebnf("").is_err());
        assert!(parse_ebnf("a ::= undefined_rule").is_err());
        assert!(parse_ebnf("a ::= \"x\" a ::= \"y\"").is_err()); // duplicate
        assert!(parse_ebnf("a ::= (\"x\"").is_err()); // unclosed group
        assert!(parse_ebnf("a := \"x\"").is_err()); // bad define
    }

    #[test]
    fn first_rule_is_start() {
        let g = parse_ebnf("root ::= x \n x ::= \"x\"").unwrap();
        assert_eq!(g.nonterminals[g.start as usize], "root");
    }
}
