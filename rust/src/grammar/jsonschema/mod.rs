//! JSON Schema → CFG compiler front-end.
//!
//! The highest-traffic real-world use of constrained decoding is
//! schema-driven JSON: API callers ship a JSON Schema, not a hand-written
//! EBNF. This subsystem compiles a useful schema subset down to the
//! crate's [`Cfg`], so a schema constraint flows through the exact same
//! engine pipeline (scanner NFA → subterminal trees → Earley tables →
//! registry/artifact caching) as every other grammar.
//!
//! Three stages, one module each:
//!
//! * [`model`] — typed schema model + parser over
//!   [`util::Json`](crate::util::Json), with **path-annotated errors**
//!   (`jsonschema at #/properties/age: unsupported keyword ...`) for
//!   everything outside the subset. A schema compiles to exactly the
//!   constraint it states or it does not compile; nothing is silently
//!   dropped.
//! * [`normalize`] — canonical source form (key order / whitespace /
//!   number spelling erased, so fingerprint-keyed dedup fires for
//!   semantically identical schemas) and intra-document `$ref`
//!   (JSON Pointer) resolution.
//! * [`emit`] — the CFG emitter, mirroring the builtin JSON grammars'
//!   scanner/parser split, with cycle-safe `$ref` recursion into named
//!   nonterminals and a productivity check for unsatisfiable recursion.
//!
//! Supported subset and shape decisions are documented on [`model`] and
//! in `rust/DESIGN.md` ("Schema → CFG pipeline").

pub mod emit;
pub mod model;
pub mod normalize;

pub use model::{SchemaNode, SchemaPath, FORMATS, MAX_UNROLL};

use crate::grammar::Cfg;
use crate::util::Json;
use anyhow::Context;

/// Compile a JSON Schema document (source text) to a [`Cfg`].
pub fn compile(source: &str) -> crate::Result<Cfg> {
    let doc = Json::parse(source.trim())
        .context("jsonschema: the schema document is not valid JSON")?;
    emit::emit(&doc)
}

/// The canonical text form of a schema source — what
/// [`ConstraintSpec::normalized`](crate::constraint::ConstraintSpec::normalized)
/// fingerprints. Errors if the source is not valid JSON.
pub fn canonical_source(source: &str) -> crate::Result<String> {
    normalize::canonical_source(source)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compile_rejects_non_json_sources() {
        let err = compile("not json at all").unwrap_err();
        assert!(format!("{err:#}").contains("not valid JSON"), "{err:#}");
    }

    #[test]
    fn compile_produces_a_grammar_with_dfas() {
        let src = r#"{"type": "object", "required": ["ok"], "properties": {"ok": {"type": "boolean"}}}"#;
        let cfg = compile(src).unwrap();
        assert!(cfg.num_terminals() > 0);
        assert_eq!(cfg.terminal_dfas().unwrap().len(), cfg.num_terminals());
        assert_eq!(cfg.nonterminals[cfg.start as usize], "root");
    }

    #[test]
    fn canonical_source_is_stable() {
        let a = canonical_source(r#"{"type":"object","properties":{"a":{"type":"null"}}}"#).unwrap();
        let b = canonical_source("{ \"properties\": {\"a\": {\"type\": \"null\"}},\n  \"type\": \"object\" }")
            .unwrap();
        assert_eq!(a, b);
    }
}
