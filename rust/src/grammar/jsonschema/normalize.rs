//! Schema-source canonicalization and intra-document `$ref` resolution.
//!
//! * [`canonical_source`] — the canonical text form of a schema document:
//!   parse + re-serialize through [`Json`] (objects are `BTreeMap`s, so
//!   keys sort; whitespace and number spellings normalize away). Two
//!   semantically identical schema sources — differing key order,
//!   insignificant whitespace, `1` vs `1.0` — canonicalize to the same
//!   bytes, which is what makes
//!   [`ConstraintSpec::fingerprint`](crate::constraint::ConstraintSpec)
//!   stable enough for registry and artifact dedup to fire.
//! * [`resolve_pointer`] — RFC 6901 JSON Pointers restricted to the
//!   current document (`#`, `#/$defs/node`, `~0`/`~1` escapes). External
//!   (`http://...`) and anchor (`#name`) refs are rejected, not fetched:
//!   a constraint must never depend on state the fingerprint cannot see.

use crate::util::Json;
use anyhow::bail;

/// Canonical text form of a schema source (sorted keys, no insignificant
/// whitespace). Errors if the source is not valid JSON.
pub fn canonical_source(source: &str) -> crate::Result<String> {
    Ok(Json::parse(source.trim())?.to_string())
}

/// Resolve an intra-document JSON Pointer against the schema document.
pub fn resolve_pointer<'a>(root: &'a Json, pointer: &str) -> crate::Result<&'a Json> {
    let Some(rest) = pointer.strip_prefix('#') else {
        bail!("jsonschema: only intra-document `$ref` (`#/...`) is supported, got `{pointer}`");
    };
    if rest.is_empty() {
        return Ok(root);
    }
    let Some(rest) = rest.strip_prefix('/') else {
        bail!("jsonschema: unsupported `$ref` form `{pointer}` (anchors are not supported; use `#/...` pointers)");
    };
    let mut cur = root;
    for raw in rest.split('/') {
        let seg = raw.replace("~1", "/").replace("~0", "~");
        cur = match cur {
            Json::Obj(map) => map.get(&seg).ok_or_else(|| {
                anyhow::anyhow!("jsonschema: `$ref` target `{pointer}` not found (no key `{seg}`)")
            })?,
            Json::Arr(items) => {
                let idx: usize = seg.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "jsonschema: `$ref` `{pointer}` indexes an array with non-number `{seg}`"
                    )
                })?;
                items.get(idx).ok_or_else(|| {
                    anyhow::anyhow!(
                        "jsonschema: `$ref` target `{pointer}` not found (index {idx} out of range)"
                    )
                })?
            }
            _ => bail!("jsonschema: `$ref` `{pointer}` traverses a non-container at `{seg}`"),
        };
    }
    Ok(cur)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonicalization_erases_key_order_and_whitespace() {
        let a = canonical_source(r#"{"b": 1, "a": {"y": [1, 2], "x": null}}"#).unwrap();
        let b = canonical_source("  {\"a\":{\"x\":null,\n\t\"y\":[1,2]},\"b\":1.0}  ").unwrap();
        assert_eq!(a, b);
        assert!(canonical_source("{nope").is_err());
    }

    #[test]
    fn pointers_resolve_with_escapes() {
        let doc = Json::parse(
            r#"{"$defs": {"a/b": {"type": "null"}, "t~de": 7}, "arr": [10, 20]}"#,
        )
        .unwrap();
        assert_eq!(resolve_pointer(&doc, "#").unwrap(), &doc);
        assert_eq!(
            resolve_pointer(&doc, "#/$defs/a~1b").unwrap(),
            &Json::parse(r#"{"type": "null"}"#).unwrap()
        );
        assert_eq!(resolve_pointer(&doc, "#/$defs/t~0de").unwrap(), &Json::Num(7.0));
        assert_eq!(resolve_pointer(&doc, "#/arr/1").unwrap(), &Json::Num(20.0));
    }

    #[test]
    fn bad_pointers_are_loud() {
        let doc = Json::parse(r#"{"a": [1]}"#).unwrap();
        assert!(resolve_pointer(&doc, "#/missing").is_err());
        assert!(resolve_pointer(&doc, "#/a/5").is_err());
        assert!(resolve_pointer(&doc, "#/a/x").is_err());
        assert!(resolve_pointer(&doc, "#/a/0/deep").is_err());
        assert!(resolve_pointer(&doc, "http://example.com/schema#/a").is_err());
        assert!(resolve_pointer(&doc, "#anchor").is_err());
    }
}
