//! Typed model of the supported JSON Schema subset, parsed from
//! [`Json`](crate::util::Json) values.
//!
//! The parser is deliberately *total over its subset and loud outside
//! it*: every keyword in the document is either consumed by the model or
//! reported as a path-annotated error ([`SchemaPath`]). A schema
//! compiles to exactly the constraint it states or it does not compile —
//! silently dropping a keyword would hand the model an unconstrained
//! hole (see DESIGN.md, "Schema → CFG pipeline").
//!
//! Supported keywords: `type` (including type arrays), `properties` /
//! `required` / boolean `additionalProperties`, `enum` / `const`,
//! `items` / `minItems` / `maxItems` (bounded unrolling, capped at
//! [`MAX_UNROLL`]), `anyOf` / `oneOf`, string `pattern` (the crate's
//! regex dialect) and `format` (the builtins in [`FORMATS`]), integer
//! `minimum` / `maximum` / `exclusiveMinimum` / `exclusiveMaximum`
//! (digit-count approximation — see [`super::emit::int_pattern`]), and
//! intra-document `$ref`. Annotation keywords (`title`, `description`,
//! `$defs`, ...) are accepted and ignored, as the spec directs.

use crate::util::Json;
use anyhow::bail;
use std::collections::BTreeSet;
use std::fmt;

/// Bounded-unrolling ceiling for `minItems` / `maxItems`: each item slot
/// becomes a production chain link, so the cap bounds grammar size.
pub const MAX_UNROLL: usize = 64;

/// The `format` builtins: each compiles to a full-match regex over the
/// string *content* (the emitter wraps it in quotes).
pub const FORMATS: &[(&str, &str)] = &[
    ("date", "[0-9]{4}-[0-9]{2}-[0-9]{2}"),
    (
        "date-time",
        r"[0-9]{4}-[0-9]{2}-[0-9]{2}T[0-9]{2}:[0-9]{2}:[0-9]{2}(\.[0-9]+)?(Z|[+-][0-9]{2}:[0-9]{2})",
    ),
    ("email", r"[A-Za-z0-9._%+-]+@[A-Za-z0-9.-]+\.[A-Za-z]{2,}"),
    (
        "ipv4",
        r"(25[0-5]|2[0-4][0-9]|1[0-9][0-9]|[1-9]?[0-9])(\.(25[0-5]|2[0-4][0-9]|1[0-9][0-9]|[1-9]?[0-9])){3}",
    ),
    ("time", r"[0-9]{2}:[0-9]{2}:[0-9]{2}(\.[0-9]+)?(Z|[+-][0-9]{2}:[0-9]{2})"),
    ("uuid", "[0-9a-fA-F]{8}-[0-9a-fA-F]{4}-[0-9a-fA-F]{4}-[0-9a-fA-F]{4}-[0-9a-fA-F]{12}"),
];

/// The full-match content regex for a builtin `format` name.
pub fn format_pattern(name: &str) -> Option<&'static str> {
    FORMATS.iter().find(|(n, _)| *n == name).map(|(_, p)| *p)
}

/// Keywords that annotate but never constrain (JSON Schema calls them
/// annotations); accepted anywhere and ignored. `$defs` / `definitions`
/// are containers whose contents are reached through `$ref`.
const ANNOTATIONS: &[&str] = &[
    "$comment",
    "$defs",
    "$id",
    "$schema",
    "default",
    "definitions",
    "deprecated",
    "description",
    "examples",
    "readOnly",
    "title",
    "writeOnly",
];

/// Location inside the schema document, rendered as a JSON-pointer-ish
/// `#/properties/name/type` string — carried by every error.
#[derive(Clone, Debug, Default)]
pub struct SchemaPath {
    segs: Vec<String>,
}

impl SchemaPath {
    pub fn root() -> SchemaPath {
        SchemaPath::default()
    }

    /// The path of a `$ref` pointer target (`#/$defs/node` → that path).
    pub fn from_pointer(pointer: &str) -> SchemaPath {
        let segs = pointer
            .trim_start_matches('#')
            .split('/')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect();
        SchemaPath { segs }
    }

    pub fn child(&self, seg: impl Into<String>) -> SchemaPath {
        let mut segs = self.segs.clone();
        segs.push(seg.into());
        SchemaPath { segs }
    }
}

impl fmt::Display for SchemaPath {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.segs.is_empty() {
            write!(f, "#")
        } else {
            write!(f, "#/{}", self.segs.join("/"))
        }
    }
}

/// One schema node of the supported subset.
#[derive(Clone, Debug, PartialEq)]
pub enum SchemaNode {
    /// `{}` or `true` — any JSON value.
    Any,
    /// Intra-document `$ref` (resolved lazily by the emitter so cycles
    /// become named-nonterminal recursion).
    Ref { pointer: String },
    /// `const` — exactly this value.
    Const { value: Json },
    /// `enum` — one of these values.
    Enum { values: Vec<Json> },
    /// `anyOf` / `oneOf` — alternation. (`oneOf` exclusivity is not
    /// CFG-expressible; for the overlapping-branch case the grammar
    /// enforces the `anyOf` relaxation — documented in DESIGN.md.)
    /// `keyword` records which spelling the document used, so emit-stage
    /// errors report the real path (`#/oneOf/1/...`).
    AnyOf { keyword: &'static str, branches: Vec<SchemaNode> },
    /// `type` — one entry per listed type.
    Types { types: Vec<TypeSchema> },
}

/// A single `type` entry with its applicable constraint keywords.
#[derive(Clone, Debug, PartialEq)]
pub enum TypeSchema {
    Null,
    Boolean,
    String { pattern: Option<String>, format: Option<&'static str> },
    Integer { minimum: Option<i64>, maximum: Option<i64> },
    Number,
    Object(ObjectSchema),
    Array(ArraySchema),
}

#[derive(Clone, Debug, PartialEq)]
pub struct ObjectSchema {
    /// Declared properties in canonical (sorted) order — the order the
    /// emitted grammar fixes for generated output.
    pub properties: Vec<(String, SchemaNode)>,
    pub required: BTreeSet<String>,
    /// `additionalProperties: false` was stated. With declared properties
    /// the emitter produces a closed object either way (a strengthening,
    /// never a weakening); without any, `closed` distinguishes `{}`-only
    /// from "any object".
    pub closed: bool,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ArraySchema {
    /// `items` schema; `None` = any JSON value per item.
    pub items: Option<Box<SchemaNode>>,
    pub min_items: usize,
    pub max_items: Option<usize>,
}

fn kind_name(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "boolean",
        Json::Num(_) => "number",
        Json::Str(_) => "string",
        Json::Arr(_) => "array",
        Json::Obj(_) => "object",
    }
}

/// Parse one schema value (object or boolean form) into the model.
pub fn parse_schema(v: &Json, path: &SchemaPath) -> crate::Result<SchemaNode> {
    match v {
        Json::Bool(true) => Ok(SchemaNode::Any),
        Json::Bool(false) => {
            bail!("jsonschema at {path}: the `false` schema matches nothing")
        }
        Json::Obj(m) => parse_object_form(m, path),
        other => bail!(
            "jsonschema at {path}: a schema must be an object or boolean, got {}",
            kind_name(other)
        ),
    }
}

type Map = std::collections::BTreeMap<String, Json>;

/// Every key must end up in `used` (or be an annotation); anything else
/// is an unsupported keyword — a hole the constraint would silently leak
/// through.
fn reject_unused(m: &Map, used: &BTreeSet<&str>, path: &SchemaPath, why: &str) -> crate::Result<()> {
    for k in m.keys() {
        if !used.contains(k.as_str()) && !ANNOTATIONS.contains(&k.as_str()) {
            bail!("jsonschema at {}: unsupported keyword `{k}`{why}", path.child(k.clone()));
        }
    }
    Ok(())
}

const SUPPORTED_HINT: &str = " (supported: type, properties, required, additionalProperties, \
     enum, const, anyOf, oneOf, items, minItems, maxItems, pattern, format, minimum, maximum, \
     exclusiveMinimum, exclusiveMaximum, $ref)";

fn parse_object_form(m: &Map, path: &SchemaPath) -> crate::Result<SchemaNode> {
    let mut used: BTreeSet<&str> = BTreeSet::new();

    if let Some(r) = m.get("$ref") {
        used.insert("$ref");
        let Json::Str(pointer) = r else {
            bail!("jsonschema at {}: `$ref` must be a string", path.child("$ref"));
        };
        reject_unused(m, &used, path, " (keywords cannot be combined with `$ref` here)")?;
        return Ok(SchemaNode::Ref { pointer: pointer.clone() });
    }
    if let Some(c) = m.get("const") {
        used.insert("const");
        reject_unused(m, &used, path, " (keywords cannot be combined with `const` here)")?;
        return Ok(SchemaNode::Const { value: c.clone() });
    }
    if let Some(e) = m.get("enum") {
        used.insert("enum");
        let Json::Arr(values) = e else {
            bail!("jsonschema at {}: `enum` must be an array of values", path.child("enum"));
        };
        if values.is_empty() {
            bail!("jsonschema at {}: `enum` must not be empty", path.child("enum"));
        }
        reject_unused(m, &used, path, " (keywords cannot be combined with `enum` here)")?;
        return Ok(SchemaNode::Enum { values: values.clone() });
    }
    for comb in ["anyOf", "oneOf"] {
        let Some(a) = m.get(comb) else { continue };
        used.insert(comb);
        let Json::Arr(branches) = a else {
            bail!("jsonschema at {}: `{comb}` must be an array of schemas", path.child(comb));
        };
        if branches.is_empty() {
            bail!("jsonschema at {}: `{comb}` must not be empty", path.child(comb));
        }
        let nodes: Vec<SchemaNode> = branches
            .iter()
            .enumerate()
            .map(|(i, b)| parse_schema(b, &path.child(comb).child(i.to_string())))
            .collect::<crate::Result<_>>()?;
        reject_unused(m, &used, path, &format!(" (keywords cannot be combined with `{comb}` here)"))?;
        return Ok(SchemaNode::AnyOf { keyword: comb, branches: nodes });
    }

    // `type` — explicit, or inferred from the structural keywords present
    // (schemas commonly omit `"type": "object"` when `properties` is
    // given).
    let type_names: Vec<String> = match m.get("type") {
        Some(Json::Str(s)) => {
            used.insert("type");
            vec![s.clone()]
        }
        Some(Json::Arr(a)) => {
            used.insert("type");
            if a.is_empty() {
                bail!("jsonschema at {}: `type` array must not be empty", path.child("type"));
            }
            let mut names = Vec::new();
            for t in a {
                let Some(s) = t.as_str() else {
                    bail!(
                        "jsonschema at {}: `type` entries must be strings",
                        path.child("type")
                    );
                };
                if !names.iter().any(|n| n == s) {
                    names.push(s.to_string());
                }
            }
            names
        }
        Some(_) => bail!(
            "jsonschema at {}: `type` must be a string or an array of strings",
            path.child("type")
        ),
        None => {
            let objish =
                ["properties", "required", "additionalProperties"].iter().any(|k| m.contains_key(*k));
            let arrish = ["items", "minItems", "maxItems"].iter().any(|k| m.contains_key(*k));
            match (objish, arrish) {
                (true, false) => vec!["object".to_string()],
                (false, true) => vec!["array".to_string()],
                (true, true) => bail!(
                    "jsonschema at {path}: both object and array keywords without a `type` to disambiguate"
                ),
                (false, false) => {
                    // No constraint keywords at all: the `{}` any-value schema.
                    reject_unused(m, &used, path, SUPPORTED_HINT)?;
                    return Ok(SchemaNode::Any);
                }
            }
        }
    };

    let mut types = Vec::new();
    for name in &type_names {
        types.push(match name.as_str() {
            "null" => TypeSchema::Null,
            "boolean" => TypeSchema::Boolean,
            "number" => TypeSchema::Number,
            "integer" => parse_integer(m, &mut used, path)?,
            "string" => parse_string(m, &mut used, path)?,
            "object" => TypeSchema::Object(parse_object(m, &mut used, path)?),
            "array" => TypeSchema::Array(parse_array(m, &mut used, path)?),
            other => bail!(
                "jsonschema at {}: unknown type `{other}` (known: null, boolean, integer, number, string, array, object)",
                path.child("type")
            ),
        });
    }
    reject_unused(m, &used, path, SUPPORTED_HINT)?;
    Ok(SchemaNode::Types { types })
}

fn parse_string(
    m: &Map,
    used: &mut BTreeSet<&'static str>,
    path: &SchemaPath,
) -> crate::Result<TypeSchema> {
    let pattern = match m.get("pattern") {
        None => None,
        Some(Json::Str(p)) => {
            used.insert("pattern");
            // Validate the dialect up front so the failure names the
            // schema location, not a deep compile stage.
            let ast = match crate::regex::parse(p) {
                Ok(ast) => ast,
                Err(e) => {
                    bail!("jsonschema at {}: invalid `pattern`: {e}", path.child("pattern"))
                }
            };
            // The emitter matches the pattern over the *raw bytes* of the
            // generated string; a pattern that can consume `"`, `\` or a
            // control byte would let the model emit bytes that break the
            // JSON string around it. Loud error, not invalid output.
            if !pattern_is_json_safe(&ast) {
                bail!(
                    "jsonschema at {}: `pattern` may match `\"`, `\\` or a control byte, which cannot appear raw inside a generated JSON string; restrict the pattern (e.g. a class excluding them)",
                    path.child("pattern")
                );
            }
            Some(p.clone())
        }
        Some(_) => bail!("jsonschema at {}: `pattern` must be a string", path.child("pattern")),
    };
    let format = match m.get("format") {
        None => None,
        Some(Json::Str(f)) => {
            used.insert("format");
            match format_pattern(f) {
                Some(p) => Some(p),
                None => {
                    let known: Vec<&str> = FORMATS.iter().map(|(n, _)| *n).collect();
                    bail!(
                        "jsonschema at {}: unsupported `format` `{f}` (supported: {})",
                        path.child("format"),
                        known.join(", ")
                    );
                }
            }
        }
        Some(_) => bail!("jsonschema at {}: `format` must be a string", path.child("format")),
    };
    if pattern.is_some() && format.is_some() {
        bail!("jsonschema at {path}: `pattern` and `format` cannot be combined");
    }
    Ok(TypeSchema::String { pattern, format })
}

/// Can every byte this pattern consumes appear raw inside a JSON string?
/// (Conservative atom-level walk: a class or literal touching `"`, `\` or
/// a control byte fails, even on branches a match might never take.)
fn pattern_is_json_safe(re: &crate::regex::Regex) -> bool {
    use crate::regex::Regex as R;
    let safe_byte = |b: u8| b >= 0x20 && b != b'"' && b != b'\\';
    match re {
        R::Empty => true,
        R::Literal(bytes) => bytes.iter().all(|&b| safe_byte(b)),
        R::Class(set) => set.iter().all(safe_byte),
        R::Concat(parts) | R::Alt(parts) => parts.iter().all(pattern_is_json_safe),
        R::Star(inner) | R::Plus(inner) | R::Opt(inner) => pattern_is_json_safe(inner),
        R::Repeat(inner, _, _) => pattern_is_json_safe(inner),
    }
}

fn int_keyword(
    m: &Map,
    key: &'static str,
    used: &mut BTreeSet<&'static str>,
    path: &SchemaPath,
) -> crate::Result<Option<i64>> {
    match m.get(key) {
        None => Ok(None),
        Some(Json::Num(n)) => {
            used.insert(key);
            if n.fract() != 0.0 || n.abs() > 9.0e15 {
                bail!(
                    "jsonschema at {}: `{key}` must be an integer in ±9e15",
                    path.child(key)
                );
            }
            Ok(Some(*n as i64))
        }
        Some(_) => bail!("jsonschema at {}: `{key}` must be a number", path.child(key)),
    }
}

fn parse_integer(
    m: &Map,
    used: &mut BTreeSet<&'static str>,
    path: &SchemaPath,
) -> crate::Result<TypeSchema> {
    let mut minimum = int_keyword(m, "minimum", used, path)?;
    if let Some(x) = int_keyword(m, "exclusiveMinimum", used, path)? {
        let lo = x.checked_add(1).ok_or_else(|| {
            let at = path.child("exclusiveMinimum");
            anyhow::anyhow!("jsonschema at {at}: `exclusiveMinimum` overflow")
        })?;
        minimum = Some(minimum.map_or(lo, |m0| m0.max(lo)));
    }
    let mut maximum = int_keyword(m, "maximum", used, path)?;
    if let Some(x) = int_keyword(m, "exclusiveMaximum", used, path)? {
        let hi = x.checked_sub(1).ok_or_else(|| {
            let at = path.child("exclusiveMaximum");
            anyhow::anyhow!("jsonschema at {at}: `exclusiveMaximum` overflow")
        })?;
        maximum = Some(maximum.map_or(hi, |m0| m0.min(hi)));
    }
    if let (Some(lo), Some(hi)) = (minimum, maximum) {
        if lo > hi {
            bail!("jsonschema at {path}: integer bounds admit no value (minimum {lo} > maximum {hi})");
        }
    }
    Ok(TypeSchema::Integer { minimum, maximum })
}

fn parse_object(
    m: &Map,
    used: &mut BTreeSet<&'static str>,
    path: &SchemaPath,
) -> crate::Result<ObjectSchema> {
    let mut properties: Vec<(String, SchemaNode)> = Vec::new();
    if let Some(p) = m.get("properties") {
        used.insert("properties");
        let Json::Obj(props) = p else {
            bail!(
                "jsonschema at {}: `properties` must be an object of schemas",
                path.child("properties")
            );
        };
        for (name, sub) in props {
            let node = parse_schema(sub, &path.child("properties").child(name.clone()))?;
            properties.push((name.clone(), node));
        }
    }
    let mut required = BTreeSet::new();
    if let Some(r) = m.get("required") {
        used.insert("required");
        let Json::Arr(names) = r else {
            bail!(
                "jsonschema at {}: `required` must be an array of property names",
                path.child("required")
            );
        };
        for n in names {
            let Some(s) = n.as_str() else {
                bail!(
                    "jsonschema at {}: `required` entries must be strings",
                    path.child("required")
                );
            };
            if !properties.iter().any(|(p, _)| p == s) {
                bail!(
                    "jsonschema at {}: required property `{s}` is not declared in `properties`",
                    path.child("required")
                );
            }
            required.insert(s.to_string());
        }
    }
    let closed = match m.get("additionalProperties") {
        None => false,
        Some(Json::Bool(b)) => {
            used.insert("additionalProperties");
            !*b
        }
        Some(_) => bail!(
            "jsonschema at {}: schema-valued `additionalProperties` is unsupported (use `false`, `true`, or omit)",
            path.child("additionalProperties")
        ),
    };
    Ok(ObjectSchema { properties, required, closed })
}

fn usize_keyword(
    m: &Map,
    key: &'static str,
    used: &mut BTreeSet<&'static str>,
    path: &SchemaPath,
) -> crate::Result<Option<usize>> {
    match m.get(key) {
        None => Ok(None),
        Some(Json::Num(n)) => {
            used.insert(key);
            if n.fract() != 0.0 || *n < 0.0 || *n > 1.0e9 {
                bail!(
                    "jsonschema at {}: `{key}` must be a non-negative integer",
                    path.child(key)
                );
            }
            Ok(Some(*n as usize))
        }
        Some(_) => bail!("jsonschema at {}: `{key}` must be a number", path.child(key)),
    }
}

fn parse_array(
    m: &Map,
    used: &mut BTreeSet<&'static str>,
    path: &SchemaPath,
) -> crate::Result<ArraySchema> {
    let items = match m.get("items") {
        None | Some(Json::Bool(true)) => {
            if m.contains_key("items") {
                used.insert("items");
            }
            None
        }
        Some(s) => {
            used.insert("items");
            Some(Box::new(parse_schema(s, &path.child("items"))?))
        }
    };
    let min_items = usize_keyword(m, "minItems", used, path)?.unwrap_or(0);
    let max_items = usize_keyword(m, "maxItems", used, path)?;
    if let Some(mx) = max_items {
        if min_items > mx {
            bail!("jsonschema at {path}: `minItems` {min_items} exceeds `maxItems` {mx}");
        }
    }
    let widest = max_items.unwrap_or(min_items);
    if widest > MAX_UNROLL || min_items > MAX_UNROLL {
        bail!(
            "jsonschema at {path}: `minItems`/`maxItems` of {widest} exceeds the bounded-unrolling limit {MAX_UNROLL}"
        );
    }
    Ok(ArraySchema { items, min_items, max_items })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> crate::Result<SchemaNode> {
        parse_schema(&Json::parse(src).unwrap(), &SchemaPath::root())
    }

    #[test]
    fn paths_render_as_pointers() {
        assert_eq!(SchemaPath::root().to_string(), "#");
        assert_eq!(SchemaPath::root().child("properties").child("x").to_string(), "#/properties/x");
        assert_eq!(SchemaPath::from_pointer("#/$defs/node").to_string(), "#/$defs/node");
        assert_eq!(SchemaPath::from_pointer("#").to_string(), "#");
    }

    #[test]
    fn parses_any_and_booleans() {
        assert_eq!(parse("{}").unwrap(), SchemaNode::Any);
        assert_eq!(parse("true").unwrap(), SchemaNode::Any);
        let err = parse("false").unwrap_err().to_string();
        assert!(err.contains("matches nothing"), "{err}");
    }

    #[test]
    fn unsupported_keyword_is_path_annotated() {
        let err = parse(
            r#"{"type": "object", "properties": {"x": {"type": "string", "minLength": 3}}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("#/properties/x/minLength"), "{err}");
        assert!(err.contains("unsupported keyword `minLength`"), "{err}");
    }

    #[test]
    fn annotations_are_ignored() {
        let node = parse(
            r#"{"title": "t", "description": "d", "$schema": "s", "type": "string", "default": "x"}"#,
        )
        .unwrap();
        assert_eq!(
            node,
            SchemaNode::Types { types: vec![TypeSchema::String { pattern: None, format: None }] }
        );
    }

    #[test]
    fn type_arrays_dedupe_and_parse() {
        let node = parse(r#"{"type": ["string", "null", "string"]}"#).unwrap();
        let SchemaNode::Types { types } = node else { panic!() };
        assert_eq!(types.len(), 2);
        assert!(parse(r#"{"type": "frob"}"#).unwrap_err().to_string().contains("unknown type"));
        assert!(parse(r#"{"type": []}"#).is_err());
    }

    #[test]
    fn object_shape_is_inferred_and_validated() {
        let node = parse(
            r#"{"properties": {"b": {"type": "integer"}, "a": {"type": "null"}}, "required": ["a"]}"#,
        )
        .unwrap();
        let SchemaNode::Types { types } = node else { panic!() };
        let TypeSchema::Object(o) = &types[0] else { panic!("{types:?}") };
        // Canonical (sorted) property order.
        assert_eq!(o.properties[0].0, "a");
        assert_eq!(o.properties[1].0, "b");
        assert!(o.required.contains("a") && !o.required.contains("b"));
        assert!(!o.closed);

        let err = parse(r#"{"type": "object", "properties": {}, "required": ["ghost"]}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("#/required") && err.contains("ghost"), "{err}");

        let err = parse(r#"{"type": "object", "additionalProperties": {"type": "string"}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("additionalProperties"), "{err}");
    }

    #[test]
    fn integer_bounds_combine_and_validate() {
        let node = parse(r#"{"type": "integer", "exclusiveMinimum": 0, "maximum": 99}"#).unwrap();
        let SchemaNode::Types { types } = node else { panic!() };
        assert_eq!(types[0], TypeSchema::Integer { minimum: Some(1), maximum: Some(99) });
        assert!(parse(r#"{"type": "integer", "minimum": 5, "maximum": 2}"#).is_err());
        assert!(parse(r#"{"type": "integer", "minimum": 1.5}"#).is_err());
        // Bounds on a non-numeric type are a leak, not a no-op.
        let err =
            parse(r#"{"type": "string", "minimum": 3}"#).unwrap_err().to_string();
        assert!(err.contains("unsupported keyword `minimum`"), "{err}");
    }

    #[test]
    fn array_unrolling_is_capped() {
        let node =
            parse(r#"{"type": "array", "items": {"type": "integer"}, "minItems": 1, "maxItems": 3}"#)
                .unwrap();
        let SchemaNode::Types { types } = node else { panic!() };
        let TypeSchema::Array(a) = &types[0] else { panic!() };
        assert_eq!((a.min_items, a.max_items), (1, Some(3)));
        assert!(a.items.is_some());

        let err = parse(r#"{"type": "array", "maxItems": 100000}"#).unwrap_err().to_string();
        assert!(err.contains("bounded-unrolling limit"), "{err}");
        assert!(parse(r#"{"type": "array", "minItems": 3, "maxItems": 1}"#).is_err());
    }

    #[test]
    fn enum_const_ref_combinators() {
        assert_eq!(
            parse(r#"{"const": 42}"#).unwrap(),
            SchemaNode::Const { value: Json::Num(42.0) }
        );
        assert!(parse(r#"{"enum": []}"#).is_err());
        assert_eq!(
            parse(r#"{"$ref": "#/$defs/x"}"#).unwrap(),
            SchemaNode::Ref { pointer: "#/$defs/x".into() }
        );
        let SchemaNode::AnyOf { keyword, branches } =
            parse(r#"{"anyOf": [{"type": "null"}, {"type": "boolean"}]}"#).unwrap()
        else {
            panic!()
        };
        assert_eq!((keyword, branches.len()), ("anyOf", 2));
        // Combining $ref with constraint keywords is rejected, not dropped.
        assert!(parse(r#"{"$ref": "#/x", "type": "string"}"#).is_err());
        // anyOf + oneOf together is a conflict.
        assert!(parse(r#"{"anyOf": [true], "oneOf": [true]}"#).is_err());
    }

    #[test]
    fn string_pattern_and_format() {
        let node = parse(r#"{"type": "string", "pattern": "[a-z]+"}"#).unwrap();
        let SchemaNode::Types { types } = node else { panic!() };
        assert_eq!(types[0], TypeSchema::String { pattern: Some("[a-z]+".into()), format: None });
        // Invalid dialect fails at the schema location.
        let err = parse(r#"{"type": "string", "pattern": "[z-a]"}"#).unwrap_err().to_string();
        assert!(err.contains("#/pattern"), "{err}");
        // Patterns that could emit bytes breaking the surrounding JSON
        // string are rejected up front, not served as invalid output.
        for unsafe_pat in [r#"a"b"#, r"a\\b", "[^a]", r"a\nb"] {
            let src = format!(
                r#"{{"type": "string", "pattern": {}}}"#,
                Json::str(unsafe_pat).to_string()
            );
            let err = parse(&src).unwrap_err().to_string();
            assert!(err.contains("control byte") || err.contains("#/pattern"), "{unsafe_pat}: {err}");
        }
        // Unknown formats list the supported set.
        let err = parse(r#"{"type": "string", "format": "hostname"}"#).unwrap_err().to_string();
        assert!(err.contains("uuid") && err.contains("date-time"), "{err}");
        assert!(parse(r#"{"type": "string", "pattern": "a", "format": "date"}"#).is_err());
        // Every builtin format pattern is valid in the crate dialect.
        for (name, pat) in FORMATS {
            crate::regex::parse(pat).unwrap_or_else(|e| panic!("format {name}: {e:#}"));
        }
    }
}
