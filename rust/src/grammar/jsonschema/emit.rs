//! Schema model → [`Cfg`] emitter.
//!
//! Emission mirrors the crate's builtin JSON grammars
//! ([`crate::grammar::builtin`]): shared `STRING` / `NUMBER` / `WS`
//! regex terminals, an optional-`ws` nonterminal after every token, and
//! object/array punctuation as literal terminals — so a schema-compiled
//! engine scans and parses exactly like the hand-written Listings 3–4
//! grammars do.
//!
//! Shape notes (all documented in DESIGN.md):
//!
//! * **Objects fix a canonical property order** (sorted). Optional
//!   properties are a linear production chain (`rest_i` covers
//!   properties `i..`), not a factorial enumeration of orders.
//! * **`additionalProperties`** absent/`true` with declared properties
//!   still emits the closed object — a *strengthening* (output always
//!   validates); a property-less open object emits the generic
//!   member grammar instead.
//! * **Bounded arrays unroll** into an optional production chain
//!   (capped by [`model::MAX_UNROLL`]).
//! * **Integer bounds approximate by digit count** ([`int_pattern`]) —
//!   the one documented over-approximation in the pipeline.
//! * **Unsatisfiable recursion** (`$ref` cycles with no finite
//!   derivation) is rejected by a productivity check instead of being
//!   handed to the Earley precompute.

use super::model::{self, ArraySchema, ObjectSchema, SchemaNode, SchemaPath, TypeSchema};
use super::normalize;
use crate::grammar::cfg::{Cfg, CfgBuilder, NtId, Symbol, TermId};
use crate::util::Json;
use anyhow::{bail, Context};
use std::collections::HashMap;

/// Compile a parsed schema document to a grammar.
pub fn emit(doc: &Json) -> crate::Result<Cfg> {
    let node = model::parse_schema(doc, &SchemaPath::root())?;
    let mut e = Emitter {
        b: CfgBuilder::new(),
        doc,
        ref_nts: HashMap::new(),
        any_nt: None,
        ws_nt: None,
        anon: 0,
    };
    let root = e.b.nonterminal("root");
    let ws = e.ws();
    let mut rhs = vec![Symbol::Nt(ws)];
    rhs.extend(e.node_syms(&node, &SchemaPath::root())?);
    e.b.production(root, rhs);
    let cfg = e.b.build(root)?;
    check_productive(&cfg)?;
    Ok(cfg)
}

struct Emitter<'a> {
    b: CfgBuilder,
    /// The whole schema document, for `$ref` resolution.
    doc: &'a Json,
    /// `$ref` pointer → its nonterminal. An entry exists from the moment
    /// emission *starts*, so a cyclic reference lands on the in-progress
    /// nonterminal instead of recursing forever.
    ref_nts: HashMap<String, NtId>,
    any_nt: Option<NtId>,
    ws_nt: Option<NtId>,
    anon: usize,
}

impl<'a> Emitter<'a> {
    /// `ws ::= WS?` with `WS ::= /[ \t\n]+/` (built once).
    fn ws(&mut self) -> NtId {
        if let Some(nt) = self.ws_nt {
            return nt;
        }
        let nt = self.b.nonterminal("ws");
        let t = self.b.regex_term("WS", r"[ \t\n]+");
        self.b.production(nt, vec![Symbol::T(t)]);
        self.b.production(nt, vec![]);
        self.ws_nt = Some(nt);
        nt
    }

    /// The JSON string terminal (Listing 3 `string`, escapes included).
    fn string_term(&mut self) -> TermId {
        self.b.regex_term("STRING", r#""([^"\\]|\\(["\\/bfnrt]|u[0-9a-fA-F]{4}))*""#)
    }

    /// The JSON number terminal (Listing 3 `number`).
    fn number_term(&mut self) -> TermId {
        self.b.regex_term("NUMBER", r"-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][-+]?[0-9]+)?")
    }

    fn fresh(&mut self, kind: &str) -> NtId {
        self.anon += 1;
        self.b.nonterminal(&format!("%{kind}{}", self.anon))
    }

    /// The symbol sequence for one schema node's value (each sequence
    /// ends having consumed its trailing `ws`, like the builtin
    /// grammars).
    fn node_syms(&mut self, node: &SchemaNode, path: &SchemaPath) -> crate::Result<Vec<Symbol>> {
        Ok(match node {
            SchemaNode::Any => {
                let nt = self.any_value();
                vec![Symbol::Nt(nt)]
            }
            SchemaNode::Ref { pointer } => {
                let nt = self.ref_nt(pointer, path)?;
                vec![Symbol::Nt(nt)]
            }
            SchemaNode::Const { value } => self.literal_value(value),
            SchemaNode::Enum { values } => {
                let nt = self.fresh("enum");
                for v in values {
                    let syms = self.literal_value(v);
                    self.b.production(nt, syms);
                }
                vec![Symbol::Nt(nt)]
            }
            SchemaNode::AnyOf { keyword, branches } => {
                let nt = self.fresh("alt");
                for (i, branch) in branches.iter().enumerate() {
                    let branch_path = path.child(*keyword).child(i.to_string());
                    let syms = self.node_syms(branch, &branch_path)?;
                    self.b.production(nt, syms);
                }
                vec![Symbol::Nt(nt)]
            }
            SchemaNode::Types { types } => {
                if let [only] = types.as_slice() {
                    self.type_syms(only, path)?
                } else {
                    let nt = self.fresh("types");
                    for t in types {
                        let syms = self.type_syms(t, path)?;
                        self.b.production(nt, syms);
                    }
                    vec![Symbol::Nt(nt)]
                }
            }
        })
    }

    /// A `const`/`enum` value as one literal terminal: its canonical
    /// serialization, matched byte-exactly.
    fn literal_value(&mut self, v: &Json) -> Vec<Symbol> {
        let text = v.to_string();
        let t = self.b.literal(&text);
        let ws = self.ws();
        vec![Symbol::T(t), Symbol::Nt(ws)]
    }

    fn type_syms(&mut self, t: &TypeSchema, path: &SchemaPath) -> crate::Result<Vec<Symbol>> {
        Ok(match t {
            TypeSchema::Null => {
                let t = self.b.literal("null");
                let ws = self.ws();
                vec![Symbol::T(t), Symbol::Nt(ws)]
            }
            TypeSchema::Boolean => {
                let nt = self.fresh("bool");
                for word in ["true", "false"] {
                    let t = self.b.literal(word);
                    let ws = self.ws();
                    self.b.production(nt, vec![Symbol::T(t), Symbol::Nt(ws)]);
                }
                vec![Symbol::Nt(nt)]
            }
            TypeSchema::Number => {
                let t = self.number_term();
                let ws = self.ws();
                vec![Symbol::T(t), Symbol::Nt(ws)]
            }
            TypeSchema::Integer { minimum, maximum } => {
                let pat = int_pattern(*minimum, *maximum);
                let t = self.b.regex_term(&format!("/{pat}/"), &pat);
                let ws = self.ws();
                vec![Symbol::T(t), Symbol::Nt(ws)]
            }
            TypeSchema::String { pattern: None, format: None } => {
                let t = self.string_term();
                let ws = self.ws();
                vec![Symbol::T(t), Symbol::Nt(ws)]
            }
            TypeSchema::String { pattern: Some(p), .. } => self.quoted_term(p),
            TypeSchema::String { pattern: None, format: Some(f) } => self.quoted_term(f),
            TypeSchema::Object(o) => self.object_syms(o, path)?,
            TypeSchema::Array(a) => self.array_syms(a, path)?,
        })
    }

    /// A constrained string: the content regex wrapped in quotes (the
    /// quotes also keep the terminal non-nullable regardless of the
    /// content pattern). Patterns are anchored — full-content matches —
    /// so constrained output always *contains* a match of the schema's
    /// pattern.
    fn quoted_term(&mut self, content: &str) -> Vec<Symbol> {
        let pat = format!("\"({content})\"");
        let t = self.b.regex_term(&format!("/{pat}/"), &pat);
        let ws = self.ws();
        vec![Symbol::T(t), Symbol::Nt(ws)]
    }

    fn object_syms(&mut self, o: &ObjectSchema, path: &SchemaPath) -> crate::Result<Vec<Symbol>> {
        let lb = self.b.literal("{");
        let rb = self.b.literal("}");
        let ws = self.ws();
        if o.properties.is_empty() {
            if o.closed {
                // `additionalProperties: false` with nothing declared:
                // exactly the empty object.
                return Ok(vec![Symbol::T(lb), Symbol::Nt(ws), Symbol::T(rb), Symbol::Nt(ws)]);
            }
            // No declared properties, not closed: any JSON object.
            let nt = self.any_object();
            return Ok(vec![Symbol::Nt(nt)]);
        }

        // One member sequence per declared property, in canonical order.
        let mut members: Vec<Vec<Symbol>> = Vec::new();
        for (name, sub) in &o.properties {
            let key = self.b.literal(&Json::str(name.clone()).to_string());
            let colon = self.b.literal(":");
            let mut syms =
                vec![Symbol::T(key), Symbol::Nt(ws), Symbol::T(colon), Symbol::Nt(ws)];
            syms.extend(self.node_syms(sub, &path.child("properties").child(name.clone()))?);
            members.push(syms);
        }
        let required: Vec<bool> =
            o.properties.iter().map(|(name, _)| o.required.contains(name)).collect();
        let comma = self.b.literal(",");
        let n = members.len();

        // rest[i] (1 ≤ i < n): continuation over properties i.. once at
        // least one earlier property has been emitted. Required links
        // cannot be skipped; optional links carry a skip production.
        let mut rests: Vec<Option<NtId>> = vec![None; n + 1];
        for i in (1..n).rev() {
            let nt = self.fresh("props");
            let mut rhs = vec![Symbol::T(comma), Symbol::Nt(ws)];
            rhs.extend(members[i].clone());
            if let Some(t) = rests[i + 1] {
                rhs.push(Symbol::Nt(t));
            }
            self.b.production(nt, rhs);
            if !required[i] {
                let skip = match rests[i + 1] {
                    Some(t) => vec![Symbol::Nt(t)],
                    None => vec![],
                };
                self.b.production(nt, skip);
            }
            rests[i] = Some(nt);
        }

        // Body: alternation over "property i is the first one present"
        // (only valid while every earlier property is optional), plus ε
        // when the whole object may be empty.
        let body = self.fresh("obj");
        let mut all_optional = true;
        for i in 0..n {
            let mut rhs = members[i].clone();
            if let Some(t) = rests[i + 1] {
                rhs.push(Symbol::Nt(t));
            }
            self.b.production(body, rhs);
            if required[i] {
                all_optional = false;
                break;
            }
        }
        if all_optional {
            self.b.production(body, vec![]);
        }
        Ok(vec![
            Symbol::T(lb),
            Symbol::Nt(ws),
            Symbol::Nt(body),
            Symbol::T(rb),
            Symbol::Nt(ws),
        ])
    }

    fn array_syms(&mut self, a: &ArraySchema, path: &SchemaPath) -> crate::Result<Vec<Symbol>> {
        let lb = self.b.literal("[");
        let rb = self.b.literal("]");
        let ws = self.ws();
        let comma = self.b.literal(",");
        let item: Vec<Symbol> = match &a.items {
            Some(sub) => self.node_syms(sub, &path.child("items"))?,
            None => {
                let nt = self.any_value();
                vec![Symbol::Nt(nt)]
            }
        };
        let sep = [Symbol::T(comma), Symbol::Nt(ws)];

        let mut mid: Vec<Symbol> = Vec::new();
        match a.max_items {
            None => {
                // `tail ::= "," ws item tail | ε` after the required prefix.
                let tail = self.fresh("items");
                let mut rec = sep.to_vec();
                rec.extend(item.clone());
                rec.push(Symbol::Nt(tail));
                self.b.production(tail, rec);
                self.b.production(tail, vec![]);
                if a.min_items == 0 {
                    let opt = self.fresh("elems");
                    let mut first = item.clone();
                    first.push(Symbol::Nt(tail));
                    self.b.production(opt, first);
                    self.b.production(opt, vec![]);
                    mid.push(Symbol::Nt(opt));
                } else {
                    mid.extend(item.clone());
                    for _ in 1..a.min_items {
                        mid.extend(sep.iter().copied());
                        mid.extend(item.clone());
                    }
                    mid.push(Symbol::Nt(tail));
                }
            }
            Some(0) => {} // exactly the empty array
            Some(mx) => {
                // Bounded unroll: optional chain over positions
                // min_items..mx (position 0 belongs to the head).
                let m = a.min_items;
                let mut tail: Option<NtId> = None;
                for _ in m.max(1)..mx {
                    let nt = self.fresh("more");
                    let mut rec = sep.to_vec();
                    rec.extend(item.clone());
                    if let Some(t) = tail {
                        rec.push(Symbol::Nt(t));
                    }
                    self.b.production(nt, rec);
                    self.b.production(nt, vec![]);
                    tail = Some(nt);
                }
                let mut seq: Vec<Symbol> = item.clone();
                for _ in 1..m {
                    seq.extend(sep.iter().copied());
                    seq.extend(item.clone());
                }
                if let Some(t) = tail {
                    seq.push(Symbol::Nt(t));
                }
                if m == 0 {
                    let opt = self.fresh("elems");
                    self.b.production(opt, seq);
                    self.b.production(opt, vec![]);
                    mid.push(Symbol::Nt(opt));
                } else {
                    mid.extend(seq);
                }
            }
        }

        let mut out = vec![Symbol::T(lb), Symbol::Nt(ws)];
        out.extend(mid);
        out.push(Symbol::T(rb));
        out.push(Symbol::Nt(ws));
        Ok(out)
    }

    /// `%any` — the unconstrained JSON value grammar (Listing 3), built
    /// once and shared by every subtree the schema leaves open.
    fn any_value(&mut self) -> NtId {
        if let Some(nt) = self.any_nt {
            return nt;
        }
        let ws = self.ws();
        let string = self.string_term();
        let number = self.number_term();
        let val = self.b.nonterminal("%any");
        self.any_nt = Some(val);
        let obj = self.b.nonterminal("%anyobj");
        let arr = self.b.nonterminal("%anyarr");
        let pair = self.b.nonterminal("%anypair");
        let pairs = self.b.nonterminal("%anypairs");
        let pairs_tail = self.b.nonterminal("%anypairstail");
        let elems = self.b.nonterminal("%anyelems");
        let elems_tail = self.b.nonterminal("%anyelemstail");
        let lb = self.b.literal("{");
        let rb = self.b.literal("}");
        let lsq = self.b.literal("[");
        let rsq = self.b.literal("]");
        let comma = self.b.literal(",");
        let colon = self.b.literal(":");

        self.b.production(val, vec![Symbol::Nt(obj)]);
        self.b.production(val, vec![Symbol::Nt(arr)]);
        self.b.production(val, vec![Symbol::T(string), Symbol::Nt(ws)]);
        self.b.production(val, vec![Symbol::T(number), Symbol::Nt(ws)]);
        for word in ["true", "false", "null"] {
            let t = self.b.literal(word);
            self.b.production(val, vec![Symbol::T(t), Symbol::Nt(ws)]);
        }
        // obj ::= "{" ws pairs "}" ws ; pairs ::= pair pairs_tail | ε
        // pairs_tail ::= "," ws pair pairs_tail | ε
        // pair ::= STRING ws ":" ws val
        self.b.production(
            obj,
            vec![Symbol::T(lb), Symbol::Nt(ws), Symbol::Nt(pairs), Symbol::T(rb), Symbol::Nt(ws)],
        );
        self.b.production(pairs, vec![Symbol::Nt(pair), Symbol::Nt(pairs_tail)]);
        self.b.production(pairs, vec![]);
        self.b.production(
            pairs_tail,
            vec![Symbol::T(comma), Symbol::Nt(ws), Symbol::Nt(pair), Symbol::Nt(pairs_tail)],
        );
        self.b.production(pairs_tail, vec![]);
        self.b.production(
            pair,
            vec![
                Symbol::T(string),
                Symbol::Nt(ws),
                Symbol::T(colon),
                Symbol::Nt(ws),
                Symbol::Nt(val),
            ],
        );
        // arr ::= "[" ws elems "]" ws ; elems ::= val elems_tail | ε
        // elems_tail ::= "," ws val elems_tail | ε
        self.b.production(
            arr,
            vec![Symbol::T(lsq), Symbol::Nt(ws), Symbol::Nt(elems), Symbol::T(rsq), Symbol::Nt(ws)],
        );
        self.b.production(elems, vec![Symbol::Nt(val), Symbol::Nt(elems_tail)]);
        self.b.production(elems, vec![]);
        self.b.production(
            elems_tail,
            vec![Symbol::T(comma), Symbol::Nt(ws), Symbol::Nt(val), Symbol::Nt(elems_tail)],
        );
        self.b.production(elems_tail, vec![]);
        val
    }

    /// The generic-object nonterminal (for property-less open objects).
    fn any_object(&mut self) -> NtId {
        self.any_value();
        self.b.nonterminal("%anyobj")
    }

    /// The nonterminal for a `$ref` target. Memoized per pointer *before*
    /// emission, so cyclic schemas become plain grammar recursion.
    fn ref_nt(&mut self, pointer: &str, path: &SchemaPath) -> crate::Result<NtId> {
        if let Some(&nt) = self.ref_nts.get(pointer) {
            return Ok(nt);
        }
        let nt = self.b.nonterminal(&format!("%ref:{pointer}"));
        self.ref_nts.insert(pointer.to_string(), nt);
        let target = normalize::resolve_pointer(self.doc, pointer)
            .with_context(|| format!("jsonschema at {path}: resolving `$ref`"))?;
        let target_path = SchemaPath::from_pointer(pointer);
        let node = model::parse_schema(target, &target_path)?;
        let syms = self.node_syms(&node, &target_path)?;
        if syms == [Symbol::Nt(nt)] {
            bail!("jsonschema at {path}: `$ref` `{pointer}` refers only to itself");
        }
        self.b.production(nt, syms);
        Ok(nt)
    }
}

/// Digit-count approximation of an integer range, as a regex pattern.
///
/// The admitted set is every integer whose digit count falls inside the
/// bounds' digit counts — exact when the bounds sit on digit-count
/// edges (`1..9`, `0..999`, `-99..-10`), otherwise the documented
/// over-approximation of the pipeline (e.g. `5..17` admits `1..99`).
pub fn int_pattern(minimum: Option<i64>, maximum: Option<i64>) -> String {
    fn digits(mut n: u64) -> usize {
        let mut d = 1;
        while n >= 10 {
            n /= 10;
            d += 1;
        }
        d
    }
    /// Non-negative integers from `lo` up, digit-bounded by `hi`.
    fn nonneg(lo: u64, hi: Option<u64>) -> String {
        match (lo, hi) {
            (0, None) => "0|[1-9][0-9]*".to_string(),
            (0, Some(h)) => match digits(h) - 1 {
                0 => "0|[1-9]".to_string(),
                d => format!("0|[1-9][0-9]{{0,{d}}}"),
            },
            (l, None) => match digits(l) - 1 {
                0 => "[1-9][0-9]*".to_string(),
                d => format!("[1-9][0-9]{{{d},}}"),
            },
            (l, Some(h)) => match (digits(l) - 1, digits(h) - 1) {
                (0, 0) => "[1-9]".to_string(),
                (a, b) => format!("[1-9][0-9]{{{a},{b}}}"),
            },
        }
    }
    match (minimum, maximum) {
        (None, None) => "-?(0|[1-9][0-9]*)".to_string(),
        (Some(lo), None) if lo >= 0 => nonneg(lo as u64, None),
        (Some(lo), None) => format!("(-({}))|0|[1-9][0-9]*", nonneg(1, Some(lo.unsigned_abs()))),
        (None, Some(hi)) if hi < 0 => format!("-({})", nonneg(hi.unsigned_abs(), None)),
        (None, Some(hi)) => format!("(-[1-9][0-9]*)|{}", nonneg(0, Some(hi as u64))),
        (Some(lo), Some(hi)) if lo >= 0 => nonneg(lo as u64, Some(hi as u64)),
        (Some(lo), Some(hi)) if hi < 0 => {
            format!("-({})", nonneg(hi.unsigned_abs(), Some(lo.unsigned_abs())))
        }
        (Some(lo), Some(hi)) => {
            format!("(-({}))|{}", nonneg(1, Some(lo.unsigned_abs())), nonneg(0, Some(hi as u64)))
        }
    }
}

/// Reject grammars with unproductive nonterminals (a `$ref` cycle with
/// no finite derivation): the Earley/tree precompute assumes every
/// nonterminal derives *some* terminal string.
fn check_productive(cfg: &Cfg) -> crate::Result<()> {
    let n = cfg.nonterminals.len();
    let mut productive = vec![false; n];
    loop {
        let mut changed = false;
        for p in &cfg.productions {
            if productive[p.lhs as usize] {
                continue;
            }
            let all = p.rhs.iter().all(|s| match s {
                Symbol::T(_) => true,
                Symbol::Nt(nt) => productive[*nt as usize],
            });
            if all {
                productive[p.lhs as usize] = true;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    if let Some(i) = productive.iter().position(|ok| !ok) {
        bail!(
            "jsonschema: unsatisfiable recursion — `{}` never derives a finite value (give every recursive `$ref` a non-recursive alternative, e.g. through `anyOf`)",
            cfg.nonterminals[i]
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser::earley::{recognize, Earley};
    use crate::scanner::{Pos, Scanner};
    use std::sync::Arc;

    fn compile(src: &str) -> crate::Result<Cfg> {
        emit(&Json::parse(src).unwrap())
    }

    /// Byte-level membership: scanner segmentation × Earley recognition.
    fn accepts(cfg: &Cfg, text: &str) -> bool {
        let scanner = Scanner::new(cfg).unwrap();
        let earley = Earley::new(Arc::new(cfg.clone()));
        if text.is_empty() {
            return recognize(&earley, &[]);
        }
        for (seq, posset) in scanner.traverse(&[Pos::Boundary], text.as_bytes()) {
            for pos in posset {
                if let Pos::In(t, _) = pos {
                    if scanner.accepting(pos) {
                        let mut full = seq.clone();
                        full.push(t);
                        if recognize(&earley, &full) {
                            return true;
                        }
                    }
                }
            }
        }
        false
    }

    #[test]
    fn int_pattern_matches_expected_sets() {
        let cases: &[(Option<i64>, Option<i64>, &[&str], &[&str])] = &[
            (None, None, &["0", "7", "-13", "100"], &["007", "-0", "+1", ""]),
            (Some(0), Some(9), &["0", "9"], &["10", "-1"]),
            (Some(1), Some(9), &["1", "9"], &["0", "10", "-2"]),
            (Some(1), Some(99), &["1", "42", "99"], &["0", "100", "-5"]),
            (Some(0), None, &["0", "12345"], &["-1"]),
            (Some(10), None, &["10", "999"], &["9", "0", "-10"]),
            (None, Some(-10), &["-10", "-99"], &["-9", "0", "7"]),
            (Some(-99), Some(-10), &["-42", "-10"], &["-9", "0", "5", "-100"]),
            (Some(-9), Some(99), &["-9", "0", "42"], &["-10", "100"]),
            (Some(-9), None, &["-9", "0", "12345"], &["-10", "-100"]),
        ];
        for (lo, hi, yes, no) in cases {
            let pat = int_pattern(*lo, *hi);
            for y in *yes {
                assert!(
                    crate::regex::matches(&pat, y).unwrap(),
                    "/{pat}/ should accept {y} for [{lo:?},{hi:?}]"
                );
            }
            for x in *no {
                assert!(
                    !crate::regex::matches(&pat, x).unwrap(),
                    "/{pat}/ should reject {x} for [{lo:?},{hi:?}]"
                );
            }
        }
    }

    #[test]
    fn object_with_required_and_optional_properties() {
        let cfg = compile(
            r#"{"type": "object", "additionalProperties": false,
                "required": ["b"],
                "properties": {"a": {"type": "boolean"}, "b": {"type": "null"}, "c": {"type": "integer"}}}"#,
        )
        .unwrap();
        for ok in [
            r#"{"b": null}"#,
            r#"{"a": true, "b": null}"#,
            r#"{"b":null,"c":7}"#,
            r#"{ "a" : false , "b" : null , "c" : -2 }"#,
        ] {
            assert!(accepts(&cfg, ok), "{ok}");
        }
        for bad in [
            "{}",                         // required `b` missing
            r#"{"a": true}"#,             // required `b` missing
            r#"{"b": null, "a": true}"#,  // canonical order fixed
            r#"{"b": null, "x": 1}"#,     // undeclared property
            r#"{"b": "null"}"#,           // wrong type
        ] {
            assert!(!accepts(&cfg, bad), "{bad}");
        }
    }

    #[test]
    fn all_optional_object_admits_every_subset_in_order() {
        let cfg = compile(
            r#"{"type": "object", "properties": {"x": {"type": "null"}, "y": {"type": "null"}}}"#,
        )
        .unwrap();
        for ok in ["{}", r#"{"x": null}"#, r#"{"y": null}"#, r#"{"x": null, "y": null}"#] {
            assert!(accepts(&cfg, ok), "{ok}");
        }
        assert!(!accepts(&cfg, r#"{"y": null, "x": null}"#), "order is canonical");
    }

    #[test]
    fn arrays_respect_bounds() {
        let cfg = compile(
            r#"{"type": "array", "items": {"type": "boolean"}, "minItems": 1, "maxItems": 3}"#,
        )
        .unwrap();
        assert!(!accepts(&cfg, "[]"));
        assert!(accepts(&cfg, "[true]"));
        assert!(accepts(&cfg, "[true, false, true]"));
        assert!(!accepts(&cfg, "[true, false, true, true]"));
        assert!(!accepts(&cfg, "[1]"));

        let unbounded = compile(r#"{"type": "array", "items": {"type": "null"}}"#).unwrap();
        assert!(accepts(&unbounded, "[]"));
        assert!(accepts(&unbounded, "[null, null, null, null, null]"));

        let empty_only = compile(r#"{"type": "array", "maxItems": 0}"#).unwrap();
        assert!(accepts(&empty_only, "[ ]"));
        assert!(!accepts(&empty_only, "[null]"));
    }

    #[test]
    fn enums_consts_and_unions() {
        let cfg = compile(r#"{"enum": ["red", "green", 7, true, null]}"#).unwrap();
        for ok in [r#""red""#, r#""green""#, "7", "true", "null"] {
            assert!(accepts(&cfg, ok), "{ok}");
        }
        assert!(!accepts(&cfg, r#""blue""#));
        assert!(!accepts(&cfg, "8"));

        let cfg = compile(r#"{"const": {"b": [1, 2], "a": "x"}}"#).unwrap();
        // Canonical serialization of the const value, byte-exact.
        assert!(accepts(&cfg, r#"{"a":"x","b":[1,2]}"#));
        assert!(!accepts(&cfg, r#"{"a":"x","b":[1,3]}"#));

        let cfg =
            compile(r#"{"anyOf": [{"type": "integer", "minimum": 0, "maximum": 9}, {"type": "null"}]}"#)
                .unwrap();
        assert!(accepts(&cfg, "4") && accepts(&cfg, "null"));
        assert!(!accepts(&cfg, "-4"));

        let cfg = compile(r#"{"type": ["string", "null"]}"#).unwrap();
        assert!(accepts(&cfg, r#""hi""#) && accepts(&cfg, "null"));
        assert!(!accepts(&cfg, "3"));
    }

    #[test]
    fn string_pattern_and_format_are_quoted_and_anchored() {
        let cfg = compile(r#"{"type": "string", "pattern": "[a-z]{2,4}"}"#).unwrap();
        assert!(accepts(&cfg, r#""ab""#));
        assert!(!accepts(&cfg, r#""a""#));
        assert!(!accepts(&cfg, r#""abcde""#));
        assert!(!accepts(&cfg, "ab"), "value must still be a JSON string");

        let cfg = compile(r#"{"type": "string", "format": "date"}"#).unwrap();
        assert!(accepts(&cfg, r#""2026-07-28""#));
        assert!(!accepts(&cfg, r#""2026-7-28""#));
    }

    #[test]
    fn empty_schema_is_full_json_and_open_objects_are_generic() {
        let cfg = compile("{}").unwrap();
        for ok in [r#"{"a": [1, {"b": null}], "c": "x"}"#, "3.5", "[]", r#""s""#, "false"] {
            assert!(accepts(&cfg, ok), "{ok}");
        }
        assert!(!accepts(&cfg, "{,}"));

        let cfg = compile(r#"{"type": "object"}"#).unwrap();
        assert!(accepts(&cfg, r#"{"anything": [true]}"#));
        assert!(!accepts(&cfg, "[1]"), "type object excludes arrays");

        let closed = compile(r#"{"type": "object", "additionalProperties": false}"#).unwrap();
        assert!(accepts(&closed, "{ }"));
        assert!(!accepts(&closed, r#"{"a": 1}"#));
    }

    #[test]
    fn recursive_refs_build_named_nonterminals() {
        let cfg = compile(
            r#"{"$ref": "#/$defs/tree",
                "$defs": {"tree": {"type": "object", "additionalProperties": false,
                                   "required": ["v"],
                                   "properties": {"v": {"type": "integer"},
                                                  "kids": {"type": "array", "items": {"$ref": "#/$defs/tree"}}}}}}"#,
        )
        .unwrap();
        assert!(cfg.nonterminals.iter().any(|n| n.contains("%ref:#/$defs/tree")));
        assert!(accepts(&cfg, r#"{"v": 1}"#));
        assert!(accepts(&cfg, r#"{"kids": [{"v": 2}, {"kids": [{"v": 3}], "v": 4}], "v": 1}"#));
        assert!(!accepts(&cfg, r#"{"kids": [7], "v": 1}"#));
    }

    #[test]
    fn emit_stage_errors_keep_the_combinator_path() {
        let err = compile(r#"{"oneOf": [{"type": "null"}, {"$ref": "#/missing"}]}"#)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("#/oneOf/1"), "{msg}");
    }

    #[test]
    fn unsatisfiable_recursion_is_rejected() {
        let err = compile(r#"{"$ref": "#"}"#).unwrap_err().to_string();
        assert!(err.contains("itself"), "{err}");
        // A → B → A with no escape hatch: caught by the productivity check.
        let err = compile(
            r#"{"$ref": "#/$defs/a",
                "$defs": {"a": {"type": "object", "required": ["x"], "properties": {"x": {"$ref": "#/$defs/b"}}},
                          "b": {"type": "object", "required": ["y"], "properties": {"y": {"$ref": "#/$defs/a"}}}}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unsatisfiable recursion"), "{err}");
    }

    #[test]
    fn terminal_dfas_compile_for_a_composite_schema() {
        let cfg = compile(
            r#"{"type": "object", "additionalProperties": false, "required": ["id", "tags"],
                "properties": {"id": {"type": "string", "format": "uuid"},
                               "tags": {"type": "array", "items": {"enum": ["a", "b"]}, "maxItems": 4},
                               "score": {"type": "number"}}}"#,
        )
        .unwrap();
        let dfas = cfg.terminal_dfas().unwrap();
        assert_eq!(dfas.len(), cfg.num_terminals());
        assert!(accepts(&cfg, r#"{"id": "01234567-89ab-cdef-0123-456789abcdef", "tags": ["a", "b"]}"#));
    }
}
