//! `domino` — the serving CLI.
//!
//! ```text
//! domino serve [--addr 127.0.0.1:7761] [--engines 1] [--slots 4]
//!              [--queue-depth 64] [--deadline-ms N] [--mock]
//! domino generate --prompt "..." [--grammar json | --ebnf SRC |
//!                 --ebnf-file PATH | --regex PATTERN | --stop "a,b"]
//!                 [--method domino|domino-full|online|unconstrained]
//!                 [--k N] [--speculative S] [--max-tokens N]
//!                 [--temperature T] [--seed N]
//! domino grammar <name>         # inspect: terminals, tree sizes, precompute time
//! domino grammars               # list builtin grammars
//! ```
//!
//! `--engines N` shards the server across N engine threads sharing one
//! compiled-grammar registry (grammar-affinity routing, bounded queues
//! with overload shedding — see `server::scheduler`). Model artifacts
//! are found via `$DOMINO_ARTIFACTS` (default `./artifacts`);
//! `--mock` uses the test trigram LM instead.

use domino::constraint::{Constraint, ConstraintSpec};
use domino::domino::decoder::Engine as GrammarEngine;
use domino::grammar::builtin;
use domino::runtime::mock::{json_mock, MockFactory};
use domino::runtime::pjrt::{artifacts_dir, load_vocab, PjrtFactory, PjrtModel};
use domino::scanner::Scanner;
use domino::server::engine::{EngineCtx, GenRequest};
use domino::server::scheduler::{Scheduler, SchedulerConfig};
use domino::server::tcp;
use std::collections::HashMap;
use std::time::{Duration, Instant};

fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (flags, positional)
}

fn start_scheduler(flags: &HashMap<String, String>) -> domino::Result<Scheduler> {
    let mock = flags.contains_key("mock");
    let cfg = SchedulerConfig {
        engines: flags.get("engines").and_then(|s| s.parse().ok()).unwrap_or(1),
        slots_per_engine: flags.get("slots").and_then(|s| s.parse().ok()).unwrap_or(4),
        queue_depth: flags.get("queue-depth").and_then(|s| s.parse().ok()).unwrap_or(64),
        default_deadline: flags
            .get("deadline-ms")
            .and_then(|s| s.parse().ok())
            .map(Duration::from_millis),
        ..SchedulerConfig::default()
    };
    // The vocab must be ONE shared Arc across shards: registry keys are
    // fingerprint × vocab identity, so per-shard vocab copies would
    // defeat cross-shard engine dedup. Models stay per-shard (PJRT
    // handles are thread-pinned; each shard init loads its own on its
    // thread).
    if mock {
        let (vocab, model) = json_mock(512);
        Ok(Scheduler::start(
            move |_shard, registry| {
                Ok(EngineCtx::with_registry(
                    Box::new(MockFactory { model: model.clone() }),
                    vocab.clone(),
                    registry,
                ))
            },
            cfg,
        ))
    } else {
        let dir = artifacts_dir();
        let vocab = load_vocab(&dir)?;
        Ok(Scheduler::start(
            move |_shard, registry| {
                let model = PjrtModel::load(&dir)?;
                let factory = Box::new(PjrtFactory { model });
                Ok(EngineCtx::with_registry(factory, vocab.clone(), registry))
            },
            cfg,
        ))
    }
}

/// Build the request constraint from CLI flags. The spec comes from one
/// of `--ebnf-file` / `--ebnf` / `--regex` / `--grammar` / `--stop`
/// (first present wins); the enforcement from `--method` / `--k` /
/// `--speculative`.
fn parse_constraint(flags: &HashMap<String, String>) -> domino::Result<Constraint> {
    let method = flags.get("method").map(|s| s.as_str()).unwrap_or("domino");
    let spec = if let Some(path) = flags.get("ebnf-file") {
        Some(ConstraintSpec::ebnf(std::fs::read_to_string(path)?))
    } else if let Some(src) = flags.get("ebnf") {
        Some(ConstraintSpec::ebnf(src.clone()))
    } else if let Some(p) = flags.get("regex") {
        Some(ConstraintSpec::regex(p.clone()))
    } else if let Some(g) = flags.get("grammar") {
        Some(ConstraintSpec::builtin(g.clone()))
    } else {
        flags
            .get("stop")
            .map(|s| ConstraintSpec::stop(s.split(',').map(|x| x.to_string()).collect()))
    };
    Ok(Constraint::from_parts(
        method,
        spec,
        flags.get("k").and_then(|k| k.parse().ok()),
        flags.get("speculative").and_then(|s| s.parse().ok()),
    ))
}

fn cmd_generate(flags: HashMap<String, String>) -> domino::Result<()> {
    let server = start_scheduler(&flags)?;
    let constraint = parse_constraint(&flags)?;
    let req = GenRequest {
        prompt: flags.get("prompt").cloned().unwrap_or_default(),
        constraint,
        max_tokens: flags.get("max-tokens").and_then(|m| m.parse().ok()).unwrap_or(128),
        temperature: flags.get("temperature").and_then(|t| t.parse().ok()),
        seed: flags.get("seed").and_then(|s| s.parse().ok()).unwrap_or(0),
        ..Default::default()
    };
    let resp = server.generate(req)?;
    if let Some(e) = resp.error {
        anyhow::bail!("{e}");
    }
    println!("{}", resp.text);
    eprintln!(
        "# {} tokens in {:.2}s ({:.1} tok/s) | interventions {} | model calls {} | spec accepted {}",
        resp.stats.tokens_out,
        resp.elapsed_s,
        resp.stats.tokens_out as f64 / resp.elapsed_s.max(1e-9),
        resp.stats.interventions,
        resp.stats.model_calls,
        resp.stats.spec_accepted,
    );
    if let Ok(m) = server.metrics() {
        eprintln!(
            "# registry: {} hit / {} miss ({} ms compiling) | mask cache {:.0}% hit",
            m.registry_hits,
            m.registry_misses,
            m.engine_compile_ms,
            m.mask_cache_hit_rate() * 100.0,
        );
    }
    server.shutdown();
    Ok(())
}

fn cmd_grammar(name: &str) -> domino::Result<()> {
    let cfg = builtin::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown grammar `{name}` (try `domino grammars`)"))?;
    println!("grammar `{name}`:");
    println!("  nonterminals: {}", cfg.nonterminals.len());
    println!("  productions:  {}", cfg.productions.len());
    println!("  terminals:    {}", cfg.num_terminals());
    let t0 = Instant::now();
    let scanner = Scanner::new(&cfg)?;
    println!("  scanner:      {} positions ({:.1} ms)", scanner.num_pos(), t0.elapsed().as_secs_f64() * 1e3);
    // Tree precompute against the bundled (or synthetic) vocabulary.
    let vocab = match load_vocab(&artifacts_dir()) {
        Ok(v) => v,
        Err(_) => std::sync::Arc::new(domino::tokenizer::bpe::synthetic_json_vocab(512)),
    };
    let t0 = Instant::now();
    let engine = GrammarEngine::compile(cfg, vocab.clone())?;
    println!(
        "  trees:        {} nodes over {} positions, vocab {} ({:.2} s precompute)",
        engine.trees.total_nodes(),
        engine.scanner.num_pos(),
        vocab.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let (flags, positional) = parse_flags(&args[args.len().min(1)..]);
    let result = match cmd {
        "serve" => match start_scheduler(&flags) {
            Ok(sched) => {
                let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7761".into());
                tcp::serve(sched, &addr)
            }
            Err(e) => Err(e),
        },
        "generate" => cmd_generate(flags),
        "grammar" => match positional.first() {
            Some(name) => cmd_grammar(name),
            None => Err(anyhow::anyhow!("usage: domino grammar <name>")),
        },
        "grammars" => {
            for g in builtin::GRAMMAR_NAMES {
                println!("{g}");
            }
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: domino <serve|generate|grammar|grammars> [flags]\n\
                 \n\
                 serve     --addr HOST:PORT [--engines N] [--slots N] [--queue-depth N]\n\
                 \u{20}          [--deadline-ms N] [--mock]\n\
                 generate  --prompt STR [--grammar NAME | --ebnf SRC | --ebnf-file PATH |\n\
                 \u{20}           --regex PATTERN | --stop \"SEQ1,SEQ2\"]\n\
                 \u{20}          [--method domino|domino-full|online|unconstrained]\n\
                 \u{20}          [--k N] [--speculative S] [--max-tokens N] [--temperature T] [--seed N] [--mock]\n\
                 grammar   NAME    inspect a builtin grammar\n\
                 grammars          list builtin grammars"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
