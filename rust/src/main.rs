//! `domino` — the serving CLI.
//!
//! ```text
//! domino serve [--addr 127.0.0.1:7761] [--engines 1] [--slots 4]
//!              [--queue-depth 64] [--deadline-ms N] [--artifact-dir DIR]
//!              [--lazy-compile] [--draft K] [--mock]
//!              [--metrics-port P] [--tenant-rate R] [--tenant-burst B]
//!              [--tenant-weights "a=4,b=1"]
//!              [--max-connections N] [--idle-timeout-ms MS]
//!              [--read-timeout-ms MS] [--write-stall-timeout-ms MS]
//!              [--reactor-workers N]
//!              [--registry-hot N] [--registry-warm N]
//!              [--trace-sample-rate R] [--trace-slow-ms MS] [--trace-dir DIR]
//! domino generate --prompt "..." [--grammar json | --ebnf SRC |
//!                 --ebnf-file PATH | --json-schema SRC |
//!                 --json-schema-file PATH | --regex PATTERN | --stop "a,b"]
//!                 [--method domino|domino-full|online|unconstrained]
//!                 [--k N] [--speculative S] [--draft K] [--max-tokens N]
//!                 [--temperature T] [--seed N] [--artifact-dir DIR]
//! domino precompile --artifact-dir DIR [--manifest FILE]
//!                 [--grammar NAME | --ebnf SRC | --ebnf-file PATH |
//!                  --json-schema SRC | --json-schema-file PATH | --regex P]
//!                 [--k N] [--mock]   # batch-compile constraints offline
//! domino grammar <name>         # inspect: terminals, tree sizes, precompute time
//! domino grammars               # list builtin grammars
//! domino metrics-doc            # print docs/METRICS.md from the metric registry
//! domino trace <file.json>      # render a captured trace as a per-tick timeline
//! ```
//!
//! `--metrics-port P` (or `$DOMINO_METRICS_PORT`) serves the Prometheus
//! scrape endpoint (`GET /metrics`, plus `GET /healthz`) on
//! `0.0.0.0:P`. `--tenant-rate R` caps each tenant at R admissions/s
//! (token bucket, burst `--tenant-burst B`, default `max(R, 1)`);
//! `--tenant-weights "a=4,b=1"` sets deficit-round-robin drain weights
//! (unlisted tenants weigh 1). See `rust/OPERATIONS.md`.
//!
//! The gateway (see `rust/ARCHITECTURE.md`) multiplexes every client
//! connection — both JSONL and the metrics endpoint — over a fixed
//! reactor worker pool. `--max-connections N` caps concurrently open
//! connections (over-cap accepts are refused with the structured
//! `overloaded`/`connection_limit` reply); `--idle-timeout-ms` /
//! `--read-timeout-ms` bound silent keepalives and stalled partial
//! requests, `--write-stall-timeout-ms` cuts peers that stop reading
//! their own replies (`0` disables any); `--reactor-workers N` sizes
//! the pool.
//! `--registry-hot N` / `--registry-warm N` size the engine-registry
//! tiers: hot entries keep engine + mask cache, warm entries keep the
//! engine only, overflow parks on disk when `--artifact-dir` is set.
//!
//! `--trace-sample-rate R` head-samples one request in 1/R for
//! request-scoped tracing (span tree + per-token decode decisions);
//! aborted and over-`--trace-slow-ms` requests are always captured
//! (tail sampling). Captured traces land in the `{"op":"trace"}` ring
//! and, with `--trace-dir DIR` (or `$DOMINO_TRACE_DIR`), as
//! Perfetto-loadable Chrome trace-event JSON files. `domino trace
//! FILE` renders one such file (or an `{"op":"trace"}` dump entry) as
//! a per-tick timeline. See `rust/OPERATIONS.md`.
//!
//! `--engines N` shards the server across N engine threads sharing one
//! compiled-grammar registry (grammar-affinity routing, bounded queues
//! with overload shedding — see `server::scheduler`). Model artifacts
//! are found via `$DOMINO_ARTIFACTS` (default `./artifacts`);
//! `--mock` uses the test trigram LM instead.
//!
//! `--draft K` enables the grammar-pruned draft lane (≥ 1 proposed
//! tokens per tick, verified in one batched forward pass). On
//! `generate` it applies to the request; on `serve` it is the default
//! for domino requests that set neither `draft` nor `speculative`.
//!
//! `--artifact-dir DIR` (or `$DOMINO_ARTIFACT_DIR`) enables the
//! persistent *precompute* artifact store: compiled grammar engines are
//! loaded from DIR at boot (warm start), written back after fresh
//! compiles, and their hot mask-cache entries re-saved at shutdown — a
//! restarted server answers its first constrained request with zero
//! compile latency. `domino precompile` fills the store offline from a
//! manifest — a JSON array (or `{"constraints": [...]}`) of entries like
//! `{"grammar": "json"}`, `{"ebnf": "root ::= ...", "k": 2}`,
//! `{"ebnf_file": "g.ebnf"}` or `{"regex": "[0-9]+"}`.

use domino::constraint::{ArtifactStore, Constraint, ConstraintSpec, EngineRegistry};
use domino::domino::decoder::Engine as GrammarEngine;
use domino::grammar::builtin;
use domino::runtime::mock::{json_mock, MockFactory};
use domino::runtime::pjrt::{artifacts_dir, load_vocab, PjrtFactory, PjrtModel};
use domino::scanner::Scanner;
use domino::server::engine::{EngineCtx, GenRequest};
use domino::server::reactor::{Reactor, ReactorConfig};
use domino::server::scheduler::{Scheduler, SchedulerConfig, TenantPolicy};
use domino::server::tcp;
use domino::server::trace::{render_timeline, TraceConfig};
use domino::util::Json;
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (flags, positional)
}

/// The persistent precompute-artifact directory: `--artifact-dir` beats
/// `$DOMINO_ARTIFACT_DIR`; absent = no persistence (pure in-memory
/// registry). Distinct from `$DOMINO_ARTIFACTS`, the *model* bundle dir.
fn constraint_artifact_dir(flags: &HashMap<String, String>) -> Option<PathBuf> {
    flags
        .get("artifact-dir")
        .map(PathBuf::from)
        .or_else(|| std::env::var_os("DOMINO_ARTIFACT_DIR").map(PathBuf::from))
}

/// `--tenant-weights "a=4,b=1"`: deficit-round-robin drain weights per
/// tenant (unlisted tenants weigh 1; weights are clamped ≥ 1 at drain).
fn parse_tenant_weights(s: &str) -> domino::Result<HashMap<String, u32>> {
    let mut weights = HashMap::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (tenant, w) = part.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("--tenant-weights entries must look like `tenant=N`, got `{part}`")
        })?;
        let w: u32 = w.trim().parse().map_err(|_| {
            anyhow::anyhow!("--tenant-weights: weight for `{tenant}` must be an integer, got `{w}`")
        })?;
        weights.insert(tenant.trim().to_string(), w);
    }
    Ok(weights)
}

/// The per-tenant admission policy from `--tenant-rate` / `--tenant-burst`
/// / `--tenant-weights` (all optional; absent = no quota, FIFO-equivalent
/// fairness with every tenant at weight 1).
fn parse_tenant_policy(flags: &HashMap<String, String>) -> domino::Result<TenantPolicy> {
    let num = |name: &str| -> domino::Result<Option<f64>> {
        match flags.get(name) {
            None => Ok(None),
            Some(s) => match s.parse::<f64>() {
                Ok(f) if f.is_finite() && f >= 0.0 => Ok(Some(f)),
                _ => anyhow::bail!("--{name} must be a non-negative number, got `{s}`"),
            },
        }
    };
    let weights = match flags.get("tenant-weights") {
        Some(s) => parse_tenant_weights(s)?,
        None => HashMap::new(),
    };
    Ok(TenantPolicy { rate: num("tenant-rate")?, burst: num("tenant-burst")?, weights })
}

/// Gateway shape from `--max-connections` / `--idle-timeout-ms` /
/// `--read-timeout-ms` / `--write-stall-timeout-ms` / `--reactor-workers`
/// (timeouts in milliseconds; `0` disables one). Invalid values are
/// structured errors, not silent defaults.
fn parse_gateway(flags: &HashMap<String, String>) -> domino::Result<ReactorConfig> {
    let mut cfg = ReactorConfig::default();
    if let Some(s) = flags.get("max-connections") {
        cfg.max_connections = match s.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => anyhow::bail!("--max-connections must be an integer ≥ 1, got `{s}`"),
        };
    }
    if let Some(s) = flags.get("idle-timeout-ms") {
        let ms: u64 = s.parse().map_err(|_| {
            anyhow::anyhow!("--idle-timeout-ms must be an integer (ms; 0 disables), got `{s}`")
        })?;
        cfg.idle_timeout = (ms > 0).then(|| Duration::from_millis(ms));
    }
    if let Some(s) = flags.get("read-timeout-ms") {
        let ms: u64 = s.parse().map_err(|_| {
            anyhow::anyhow!("--read-timeout-ms must be an integer (ms; 0 disables), got `{s}`")
        })?;
        cfg.read_timeout = (ms > 0).then(|| Duration::from_millis(ms));
    }
    if let Some(s) = flags.get("write-stall-timeout-ms") {
        let ms: u64 = s.parse().map_err(|_| {
            anyhow::anyhow!(
                "--write-stall-timeout-ms must be an integer (ms; 0 disables), got `{s}`"
            )
        })?;
        cfg.write_stall_timeout = (ms > 0).then(|| Duration::from_millis(ms));
    }
    if let Some(s) = flags.get("reactor-workers") {
        cfg.workers = match s.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => anyhow::bail!("--reactor-workers must be an integer ≥ 1, got `{s}`"),
        };
    }
    Ok(cfg)
}

/// Tracing shape from `--trace-sample-rate` / `--trace-slow-ms` /
/// `--trace-dir` (the trace directory falls back to `$DOMINO_TRACE_DIR`).
/// The default config disables tracing; `"trace": true` requests still
/// get an inline summary.
fn parse_trace(flags: &HashMap<String, String>) -> domino::Result<TraceConfig> {
    let mut cfg = TraceConfig::default();
    if let Some(s) = flags.get("trace-sample-rate") {
        cfg.sample_rate = match s.parse::<f64>() {
            Ok(r) if r.is_finite() && (0.0..=1.0).contains(&r) => r,
            _ => anyhow::bail!("--trace-sample-rate must be a number in [0, 1], got `{s}`"),
        };
    }
    if let Some(s) = flags.get("trace-slow-ms") {
        let ms: u64 = s.parse().map_err(|_| {
            anyhow::anyhow!("--trace-slow-ms must be an integer (ms; 0 disables), got `{s}`")
        })?;
        cfg.slow = (ms > 0).then(|| Duration::from_millis(ms));
    }
    cfg.trace_dir = flags
        .get("trace-dir")
        .map(PathBuf::from)
        .or_else(|| std::env::var_os("DOMINO_TRACE_DIR").map(PathBuf::from));
    Ok(cfg)
}

fn start_scheduler(flags: &HashMap<String, String>) -> domino::Result<Scheduler> {
    let mock = flags.contains_key("mock");
    let tier_defaults = SchedulerConfig::default();
    let tier = |name: &str, default: usize| -> domino::Result<usize> {
        match flags.get(name) {
            None => Ok(default),
            Some(s) => match s.parse::<usize>() {
                Ok(n) => Ok(n),
                Err(_) => anyhow::bail!("--{name} must be a non-negative integer, got `{s}`"),
            },
        }
    };
    let cfg = SchedulerConfig {
        registry_capacity: tier("registry-hot", tier_defaults.registry_capacity)?,
        registry_warm_capacity: tier("registry-warm", tier_defaults.registry_warm_capacity)?,
        engines: flags.get("engines").and_then(|s| s.parse().ok()).unwrap_or(1),
        slots_per_engine: flags.get("slots").and_then(|s| s.parse().ok()).unwrap_or(4),
        queue_depth: flags.get("queue-depth").and_then(|s| s.parse().ok()).unwrap_or(64),
        default_deadline: flags
            .get("deadline-ms")
            .and_then(|s| s.parse().ok())
            .map(Duration::from_millis),
        artifact_dir: constraint_artifact_dir(flags),
        lazy_compile: flags.contains_key("lazy-compile")
            || std::env::var_os("DOMINO_LAZY_COMPILE").is_some_and(|v| v != "0"),
        tenants: parse_tenant_policy(flags)?,
        trace: parse_trace(flags)?,
        ..SchedulerConfig::default()
    };
    // One vocab Arc shared by every shard (registry keys hash the vocab
    // *content*, so equal copies would dedupe too — sharing just avoids
    // redundant fingerprinting). Models stay per-shard (PJRT handles are
    // thread-pinned; each shard init loads its own on its thread).
    if mock {
        let (vocab, model) = json_mock(512);
        Ok(Scheduler::start(
            move |_shard, registry| {
                Ok(EngineCtx::with_registry(
                    Box::new(MockFactory { model: model.clone() }),
                    vocab.clone(),
                    registry,
                ))
            },
            cfg,
        ))
    } else {
        let dir = artifacts_dir();
        let vocab = load_vocab(&dir)?;
        Ok(Scheduler::start(
            move |_shard, registry| {
                let model = PjrtModel::load(&dir)?;
                let factory = Box::new(PjrtFactory { model });
                Ok(EngineCtx::with_registry(factory, vocab.clone(), registry))
            },
            cfg,
        ))
    }
}

/// The constraint spec named by CLI flags: one of `--ebnf-file` /
/// `--ebnf` / `--json-schema-file` / `--json-schema` / `--regex` /
/// `--grammar` / `--stop` (first present wins).
fn parse_spec(flags: &HashMap<String, String>) -> domino::Result<Option<ConstraintSpec>> {
    Ok(if let Some(path) = flags.get("ebnf-file") {
        Some(ConstraintSpec::ebnf(std::fs::read_to_string(path)?))
    } else if let Some(src) = flags.get("ebnf") {
        Some(ConstraintSpec::ebnf(src.clone()))
    } else if let Some(path) = flags.get("json-schema-file") {
        Some(ConstraintSpec::json_schema(std::fs::read_to_string(path)?))
    } else if let Some(src) = flags.get("json-schema") {
        Some(ConstraintSpec::json_schema(src.clone()))
    } else if let Some(p) = flags.get("regex") {
        Some(ConstraintSpec::regex(p.clone()))
    } else if let Some(g) = flags.get("grammar") {
        Some(ConstraintSpec::builtin(g.clone()))
    } else {
        flags
            .get("stop")
            .map(|s| ConstraintSpec::stop(s.split(',').map(|x| x.to_string()).collect()))
    })
}

/// `--draft K`: the grammar-pruned draft-lane depth. Validated like the
/// wire field — `K = 0` would silently disable the feature the user
/// asked for, so it is rejected with the valid range.
fn parse_draft(flags: &HashMap<String, String>) -> domino::Result<Option<usize>> {
    match flags.get("draft") {
        None => Ok(None),
        Some(s) => match s.parse::<usize>() {
            Ok(k) if k >= 1 => Ok(Some(k)),
            _ => anyhow::bail!("--draft must be an integer ≥ 1 (got `{s}`); omit it to disable"),
        },
    }
}

/// Build the request constraint from CLI flags: the spec from
/// [`parse_spec`], the enforcement from `--method` / `--k` /
/// `--speculative` / `--draft`.
fn parse_constraint(flags: &HashMap<String, String>) -> domino::Result<Constraint> {
    let method = flags.get("method").map(|s| s.as_str()).unwrap_or("domino");
    let speculative = flags.get("speculative").and_then(|s| s.parse().ok());
    let draft = parse_draft(flags)?;
    if draft.is_some() {
        if speculative.is_some() {
            anyhow::bail!("--draft and --speculative are mutually exclusive");
        }
        if method != "domino" {
            anyhow::bail!("--draft requires --method domino (got `{method}`)");
        }
    }
    Ok(Constraint::from_parts(
        method,
        parse_spec(flags)?,
        flags.get("k").and_then(|k| k.parse().ok()),
        speculative,
        draft,
    ))
}

fn cmd_generate(flags: HashMap<String, String>) -> domino::Result<()> {
    let server = start_scheduler(&flags)?;
    let constraint = parse_constraint(&flags)?;
    let req = GenRequest {
        prompt: flags.get("prompt").cloned().unwrap_or_default(),
        constraint,
        max_tokens: flags.get("max-tokens").and_then(|m| m.parse().ok()).unwrap_or(128),
        temperature: flags.get("temperature").and_then(|t| t.parse().ok()),
        seed: flags.get("seed").and_then(|s| s.parse().ok()).unwrap_or(0),
        ..Default::default()
    };
    let resp = server.generate(req)?;
    if let Some(e) = resp.error {
        anyhow::bail!("{e}");
    }
    println!("{}", resp.text);
    eprintln!(
        "# {} tokens in {:.2}s ({:.1} tok/s) | interventions {} | model calls {} | \
         spec accepted {} | draft {}/{} accepted",
        resp.stats.tokens_out,
        resp.elapsed_s,
        resp.stats.tokens_out as f64 / resp.elapsed_s.max(1e-9),
        resp.stats.interventions,
        resp.stats.model_calls,
        resp.stats.spec_accepted,
        resp.stats.draft_accepted,
        resp.stats.draft_proposed,
    );
    if let Ok(m) = server.metrics() {
        eprintln!(
            "# registry: {} hit / {} miss ({} ms compiling) | artifacts {} hit / {} invalid | \
             mask cache {:.0}% hit",
            m.registry_hits,
            m.registry_misses,
            m.engine_compile_ms,
            m.artifact_hits,
            m.artifact_invalid,
            m.mask_cache_hit_rate() * 100.0,
        );
    }
    server.shutdown();
    Ok(())
}

/// `(spec, k)` pairs from a precompile manifest: a JSON array (or
/// `{"constraints": [...]}`) of objects with one of `grammar` / `ebnf` /
/// `ebnf_file` / `json_schema` (inline schema object or source string) /
/// `json_schema_file` / `regex`, plus an optional `k` (lookahead;
/// null/absent = ∞).
fn manifest_entries(v: &Json) -> domino::Result<Vec<(ConstraintSpec, Option<u32>)>> {
    let arr: &[Json] = if let Json::Arr(a) = v {
        a
    } else {
        v.get("constraints").and_then(|c| c.as_arr()).ok_or_else(|| {
            anyhow::anyhow!("manifest must be a JSON array or {{\"constraints\": [...]}}")
        })?
    };
    let mut out = Vec::new();
    for (i, e) in arr.iter().enumerate() {
        let spec = if let Some(src) = e.get("ebnf").and_then(|x| x.as_str()) {
            ConstraintSpec::ebnf(src)
        } else if let Some(path) = e.get("ebnf_file").and_then(|x| x.as_str()) {
            ConstraintSpec::ebnf(std::fs::read_to_string(path)?)
        } else if let Some(schema) = e.get("json_schema") {
            match schema {
                // Inline schema object, or its source as a string.
                Json::Obj(_) => ConstraintSpec::json_schema(schema.to_string()),
                Json::Str(s) => ConstraintSpec::json_schema(s.clone()),
                _ => anyhow::bail!(
                    "manifest entry {i}: `json_schema` must be a schema object or a string"
                ),
            }
        } else if let Some(path) = e.get("json_schema_file").and_then(|x| x.as_str()) {
            ConstraintSpec::json_schema(std::fs::read_to_string(path)?)
        } else if let Some(p) = e.get("regex").and_then(|x| x.as_str()) {
            ConstraintSpec::regex(p)
        } else if let Some(g) = e.get("grammar").and_then(|x| x.as_str()) {
            ConstraintSpec::builtin(g)
        } else {
            anyhow::bail!(
                "manifest entry {i} needs one of `grammar`, `ebnf`, `ebnf_file`, `json_schema`, `json_schema_file`, `regex`"
            );
        };
        let k = match e.get("k") {
            None | Some(Json::Null) => None,
            Some(x) => match x.as_f64() {
                Some(f) if f.is_finite() && f >= 0.0 => Some(f as u32),
                _ => anyhow::bail!("manifest entry {i}: `k` must be a non-negative number"),
            },
        };
        out.push((spec, k));
    }
    Ok(out)
}

/// `domino precompile`: batch-compile a manifest of constraints into the
/// artifact store, so servers pointed at the same `--artifact-dir` boot
/// warm. Already-valid artifacts are left alone (reported as cached).
fn cmd_precompile(flags: HashMap<String, String>) -> domino::Result<()> {
    let dir = constraint_artifact_dir(&flags).ok_or_else(|| {
        anyhow::anyhow!("precompile needs --artifact-dir DIR (or $DOMINO_ARTIFACT_DIR)")
    })?;
    // Compile against the vocabulary the server will use: the AOT
    // bundle's tokenizer, or the mock vocab with --mock (artifacts are
    // validated by vocab fingerprint, so this must match `serve`).
    let vocab = if flags.contains_key("mock") {
        json_mock(512).0
    } else {
        load_vocab(&artifacts_dir())?
    };
    let mut entries: Vec<(ConstraintSpec, Option<u32>)> = Vec::new();
    if let Some(path) = flags.get("manifest") {
        let src = std::fs::read_to_string(path)?;
        entries.extend(manifest_entries(&Json::parse(&src)?)?);
    }
    if let Some(spec) = parse_spec(&flags)? {
        entries.push((spec, flags.get("k").and_then(|k| k.parse().ok())));
    }
    if entries.is_empty() {
        anyhow::bail!(
            "nothing to precompile: pass --manifest FILE and/or --grammar/--ebnf/--json-schema/--regex"
        );
    }
    let store = ArtifactStore::new(&dir)?;
    let registry = EngineRegistry::with_store(entries.len().max(8), store);
    println!("precompiling {} constraint(s) into {}", entries.len(), dir.display());
    let mut failures = 0usize;
    for (spec, k) in entries {
        let label = spec.label();
        let kstr = k.map_or("inf".to_string(), |k| k.to_string());
        let t0 = Instant::now();
        let hits_before = registry.stats().artifact_hits;
        match registry.get_or_compile(&spec, &vocab, k) {
            Ok((engine, _)) => {
                let cached = registry.stats().artifact_hits > hits_before;
                println!(
                    "  {label} (k={kstr}): {} nodes, {:.2}s{}",
                    engine.trees.total_nodes(),
                    t0.elapsed().as_secs_f64(),
                    if cached { " [artifact already valid]" } else { "" },
                );
            }
            Err(e) => {
                failures += 1;
                eprintln!("  {label} (k={kstr}): FAILED: {e:#}");
            }
        }
    }
    let s = registry.stats();
    println!(
        "done: {} compiled ({} ms), {} already on disk, {} invalid replaced, {} failed",
        s.misses - s.artifact_hits,
        s.compile_ms,
        s.artifact_hits,
        s.artifact_invalid,
        failures
    );
    if failures > 0 {
        anyhow::bail!("{failures} constraint(s) failed to precompile");
    }
    Ok(())
}

fn cmd_grammar(name: &str) -> domino::Result<()> {
    let cfg = builtin::by_name(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown grammar `{name}` (known: {})",
            builtin::GRAMMAR_NAMES.join(", ")
        )
    })?;
    println!("grammar `{name}`:");
    println!("  nonterminals: {}", cfg.nonterminals.len());
    println!("  productions:  {}", cfg.productions.len());
    println!("  terminals:    {}", cfg.num_terminals());
    let t0 = Instant::now();
    let scanner = Scanner::new(&cfg)?;
    println!("  scanner:      {} positions ({:.1} ms)", scanner.num_pos(), t0.elapsed().as_secs_f64() * 1e3);
    // Tree precompute against the bundled (or synthetic) vocabulary.
    let vocab = match load_vocab(&artifacts_dir()) {
        Ok(v) => v,
        Err(_) => std::sync::Arc::new(domino::tokenizer::bpe::synthetic_json_vocab(512)),
    };
    let t0 = Instant::now();
    let engine = GrammarEngine::compile(cfg, vocab.clone())?;
    println!(
        "  trees:        {} nodes over {} positions, vocab {} ({:.2} s precompute)",
        engine.trees.total_nodes(),
        engine.scanner.num_pos(),
        vocab.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let (flags, positional) = parse_flags(&args[args.len().min(1)..]);
    let result = match cmd {
        "serve" => match parse_draft(&flags).and_then(|draft| {
            let gateway = parse_gateway(&flags)?;
            let sched = start_scheduler(&flags)?;
            Ok((draft, gateway, sched))
        }) {
            Ok((draft, mut gateway, sched)) => {
                gateway.defaults = tcp::ServeDefaults { draft };
                let sched = std::sync::Arc::new(sched);
                let metrics_addr = flags
                    .get("metrics-port")
                    .cloned()
                    .or_else(|| std::env::var("DOMINO_METRICS_PORT").ok())
                    .map(|p| format!("0.0.0.0:{p}"));
                let addr =
                    flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7761".into());
                // One reactor multiplexes both listeners over the same
                // worker pool — no per-connection (or per-scrape) threads.
                match Reactor::start(&sched, Some(&addr), metrics_addr.as_deref(), gateway) {
                    Ok(reactor) => {
                        if let Some(m) = reactor.metrics_addr() {
                            eprintln!("domino: metrics on http://{m}/metrics");
                        }
                        eprintln!(
                            "domino: serving on {addr} ({} engine shard(s))",
                            sched.engines()
                        );
                        reactor.join();
                        Ok(())
                    }
                    Err(e) => Err(e.context("binding gateway listeners")),
                }
            }
            Err(e) => Err(e),
        },
        "generate" => cmd_generate(flags),
        "precompile" => cmd_precompile(flags),
        "grammar" => match positional.first() {
            Some(name) => cmd_grammar(name),
            None => Err(anyhow::anyhow!("usage: domino grammar <name>")),
        },
        "grammars" => {
            for g in builtin::GRAMMAR_NAMES {
                println!("{g}");
            }
            Ok(())
        }
        // Regenerate the metrics reference from the in-code registry:
        //   cargo run --release -- metrics-doc > ../docs/METRICS.md
        "metrics-doc" => {
            print!("{}", domino::server::metrics::metrics_doc());
            Ok(())
        }
        // Render a captured trace (a --trace-dir file or one entry of an
        // {"op":"trace"} dump) as a human-readable per-tick timeline.
        "trace" => match positional.first() {
            Some(path) => (|| {
                let src = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
                let v = Json::parse(&src)?;
                println!("{}", render_timeline(&v)?.trim_end());
                Ok(())
            })(),
            None => Err(anyhow::anyhow!("usage: domino trace FILE.json")),
        },
        _ => {
            eprintln!(
                "usage: domino <serve|generate|precompile|grammar|grammars|metrics-doc|trace> [flags]\n\
                 \n\
                 serve     --addr HOST:PORT [--engines N] [--slots N] [--queue-depth N]\n\
                 \u{20}          [--deadline-ms N] [--artifact-dir DIR] [--lazy-compile]\n\
                 \u{20}          [--draft K] [--mock]\n\
                 \u{20}          [--metrics-port P] Prometheus /metrics on 0.0.0.0:P\n\
                 \u{20}          [--tenant-rate R] [--tenant-burst B] per-tenant admission quota\n\
                 \u{20}          [--tenant-weights \"a=4,b=1\"] weighted-fair queue drain\n\
                 \u{20}          [--max-connections N] [--idle-timeout-ms MS] [--read-timeout-ms MS]\n\
                 \u{20}          [--write-stall-timeout-ms MS] [--reactor-workers N]\n\
                 \u{20}          gateway shape (0 ms disables a timeout)\n\
                 \u{20}          [--registry-hot N] [--registry-warm N] engine-registry tier sizes\n\
                 \u{20}          [--trace-sample-rate R] [--trace-slow-ms MS] [--trace-dir DIR]\n\
                 \u{20}          request tracing (head sampling + aborted/slow tail capture)\n\
                 generate  --prompt STR [--grammar NAME | --ebnf SRC | --ebnf-file PATH |\n\
                 \u{20}           --json-schema SRC | --json-schema-file PATH |\n\
                 \u{20}           --regex PATTERN | --stop \"SEQ1,SEQ2\"]\n\
                 \u{20}          [--method domino|domino-full|online|unconstrained]\n\
                 \u{20}          [--k N] [--speculative S] [--draft K] [--max-tokens N]\n\
                 \u{20}          [--temperature T] [--seed N]\n\
                 \u{20}          [--artifact-dir DIR] [--mock]\n\
                 precompile --artifact-dir DIR [--manifest FILE]\n\
                 \u{20}          [--grammar NAME | --ebnf SRC | --ebnf-file PATH |\n\
                 \u{20}           --json-schema SRC | --json-schema-file PATH | --regex P] [--k N] [--mock]\n\
                 \u{20}          batch-compile constraints into the persistent artifact store\n\
                 \u{20}          (servers with the same --artifact-dir then boot warm)\n\
                 grammar   NAME    inspect a builtin grammar\n\
                 grammars          list builtin grammars\n\
                 metrics-doc       print the metrics reference (docs/METRICS.md) from\n\
                 \u{20}          the in-code registry\n\
                 trace     FILE    render a captured trace (--trace-dir file or one\n\
                 \u{20}          {\"op\":\"trace\"} dump entry) as a per-tick timeline\n\
                 \n\
                 --artifact-dir defaults to $DOMINO_ARTIFACT_DIR when unset."
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
