//! The DOMINO decoder (§3.4–3.5): scanner+parser hypotheses, lookahead-`k`
//! mask computation by parser-pruned tree traversal, opportunistic
//! single-token checks, EOS handling.
//!
//! ## Lookahead cost model (Fig. 3 (e))
//!
//! A token's *cost* counts the subterminals it spans, except that closing
//! the already-pending terminal is free (its first character forces the
//! close anyway):
//!
//! ```text
//! cost = (#completed terminals) − (1 if the walk starts mid-terminal and
//!                                  completes ≥ 1 terminal)
//!        + (1 if a pending subterminal remains)   // it always does
//! ```
//!
//! A token is admitted at lookahead `k` iff `cost ≤ k + 1`. Thus `k = 0`
//! is Fig. 1's "greedy" constraining (single-subterminal tokens only: in
//! mid-string JSON that's whitespace, `"` and `}` — no bridge tokens),
//! while `k = ∞` admits every parser-viable token: minimally invasive
//! (Def. 2.1).

use super::mask::TokenMask;
use super::tree::TreeSet;
use super::Checker;
use crate::grammar::Cfg;
use crate::parser::{Chart, Earley};
use crate::scanner::{Pos, Scanner};
use crate::tokenizer::{Vocab, EOS_ID};
use crate::TokenId;
use anyhow::bail;
use std::sync::Arc;

/// Lookahead depth `k` (§3.4). `Infinite` = minimally invasive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lookahead {
    K(u32),
    Infinite,
}

impl Lookahead {
    fn admits(self, cost: u32) -> bool {
        match self {
            Lookahead::K(k) => cost <= k + 1,
            Lookahead::Infinite => true,
        }
    }
}

/// One decoding hypothesis: a parser checkpoint + the pending scanner
/// positions (§3.4: "the active state of S will be a set of states").
#[derive(Clone)]
struct Hypothesis {
    chart: Chart,
    posset: Vec<Pos>,
}

/// Immutable per-grammar engine shared by all decoder instances (the
/// offline precomputation: scanner, trees, Earley tables).
pub struct Engine {
    pub grammar: Arc<Cfg>,
    pub scanner: Arc<Scanner>,
    pub trees: Arc<TreeSet>,
    pub earley: Arc<Earley>,
    pub vocab: Arc<Vocab>,
}

impl Engine {
    /// Precompute everything for a (grammar, vocabulary) pair.
    pub fn compile(grammar: Cfg, vocab: Arc<Vocab>) -> crate::Result<Arc<Engine>> {
        let grammar = Arc::new(grammar);
        let scanner = Arc::new(Scanner::new(&grammar)?);
        let trees = Arc::new(TreeSet::build(&scanner, &vocab));
        let earley = Arc::new(Earley::new(grammar.clone()));
        Ok(Arc::new(Engine { grammar, scanner, trees, earley, vocab }))
    }

    /// Compile **lazily**: terminal regexes stay NFAs (determinized per
    /// visited state, [`Scanner::new_lazy`]) and subterminal trees build
    /// on demand per reached position ([`TreeSet::lazy`]). Construction is
    /// near-instant regardless of grammar size; per-step cost amortizes to
    /// the eager engine's as states are discovered. Masks are identical to
    /// [`Engine::compile`]'s — only *when* the tables are built differs.
    pub fn compile_lazy(grammar: Cfg, vocab: Arc<Vocab>) -> crate::Result<Arc<Engine>> {
        let grammar = Arc::new(grammar);
        let scanner = Arc::new(Scanner::new_lazy(&grammar)?);
        let trees = Arc::new(TreeSet::lazy(vocab.clone()));
        let earley = Arc::new(Earley::new(grammar.clone()));
        Ok(Arc::new(Engine { grammar, scanner, trees, earley, vocab }))
    }

    /// Was this engine compiled lazily (see [`Engine::compile_lazy`])?
    pub fn is_lazy(&self) -> bool {
        self.scanner.is_lazy()
    }

    /// An equivalent fully-materialized engine: the lazy scanner is
    /// explored to fixpoint with its state numbering preserved (so every
    /// `Pos` this engine ever handed out stays valid), and complete trees
    /// are built over the dense scanner. This is what artifact
    /// serialization snapshots; eager engines are returned as-is
    /// (rebuilding nothing).
    pub fn materialize_full(&self) -> Arc<Engine> {
        let (scanner, trees) = if self.is_lazy() {
            let scanner = Arc::new(self.scanner.materialized());
            let trees = Arc::new(TreeSet::build(&scanner, &self.vocab));
            (scanner, trees)
        } else {
            (self.scanner.clone(), self.trees.clone())
        };
        Arc::new(Engine {
            grammar: self.grammar.clone(),
            scanner,
            trees,
            earley: self.earley.clone(),
            vocab: self.vocab.clone(),
        })
    }

    /// Reassemble an engine from already-precomputed parts (the artifact
    /// load path): no scanner determinization, no tree build — only the
    /// (cheap) Earley machine is derived fresh from the grammar.
    pub fn from_parts(
        grammar: Cfg,
        scanner: Scanner,
        trees: TreeSet,
        vocab: Arc<Vocab>,
    ) -> Arc<Engine> {
        let grammar = Arc::new(grammar);
        let earley = Arc::new(Earley::new(grammar.clone()));
        Arc::new(Engine {
            grammar,
            scanner: Arc::new(scanner),
            trees: Arc::new(trees),
            earley,
            vocab,
        })
    }
}

/// The inference-time DOMINO decoder. Cheap to create from a shared
/// [`Engine`]; cloneable for speculative rollback.
#[derive(Clone)]
pub struct DominoDecoder {
    engine: Arc<Engine>,
    k: Lookahead,
    hyps: Vec<Hypothesis>,
    /// Most recently committed token — part of the speculation state α
    /// (§3.6: "the most recently read subterminal"; the concrete token
    /// pins the tokenization phase, which matters for prediction).
    last_token: Option<TokenId>,
}

impl DominoDecoder {
    pub fn new(engine: Arc<Engine>, k: Lookahead) -> DominoDecoder {
        let start = Hypothesis { chart: engine.earley.start_chart(), posset: vec![Pos::Boundary] };
        DominoDecoder { engine, k, hyps: vec![start], last_token: None }
    }

    pub fn lookahead(&self) -> Lookahead {
        self.k
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Is the decoder still in a live state?
    pub fn alive(&self) -> bool {
        !self.hyps.is_empty()
    }

    /// Advance every hypothesis through `bytes`, feeding completed
    /// terminals to the parser and pruning dead branches.
    fn advance_hyps(&self, bytes: &[u8]) -> Vec<Hypothesis> {
        let eng = &self.engine;
        let mut out: Vec<Hypothesis> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for hyp in &self.hyps {
            for (seq, posset) in eng.scanner.traverse(&hyp.posset, bytes) {
                let Some(chart) = hyp.chart.feed_all(&eng.earley, &seq) else { continue };
                // Keep only pending positions whose terminal the parser
                // still allows next.
                let posset: Vec<Pos> = posset
                    .into_iter()
                    .filter(|p| match p {
                        Pos::In(t, _) => chart.allows(*t),
                        Pos::Boundary => true,
                    })
                    .collect();
                if posset.is_empty() {
                    continue;
                }
                if seen.insert((chart.frontier_fingerprint(), chart.pos(), posset.clone())) {
                    out.push(Hypothesis { chart, posset });
                }
            }
        }
        out
    }

    /// Lookahead-limited, parser-pruned traversal of the tree for `pos`
    /// (Fig. 3 (e)), accumulating allowed tokens into `mask`.
    fn traverse_tree(&self, hyp: &Hypothesis, pos: Pos, mask: &mut TokenMask) {
        let eng = &self.engine;
        let tree = eng.trees.tree(&eng.scanner, pos);
        let mid_terminal = matches!(pos, Pos::In(..));
        // DFS stack: (node, chart, completed-count).
        let mut stack: Vec<(u32, Chart, u32)> = vec![(0, hyp.chart.clone(), 0)];
        while let Some((node_id, chart, depth)) = stack.pop() {
            let node = &tree.nodes[node_id as usize];
            // Discount: closing the pending terminal is free.
            let discount = (mid_terminal && depth >= 1) as u32;
            // Entries at this node: cost = depth - discount + 1 (pending).
            let cost = depth - discount + 1;
            if self.k.admits(cost) {
                for (set_id, tokens) in &node.entries {
                    let info = eng.trees.posset(*set_id);
                    if info.terms.iter().any(|&t| chart.allows(t)) {
                        for &t in tokens {
                            mask.allow(t);
                        }
                    }
                }
            }
            // Descend: any deeper entry costs ≥ depth+1 - discount' + 1.
            let next_depth = depth + 1;
            let next_discount = (mid_terminal && next_depth >= 1) as u32;
            if !self.k.admits(next_depth - next_discount + 1) {
                continue;
            }
            for &(term, child) in &node.children {
                if let Some(next_chart) = chart.feed(&eng.earley, term) {
                    stack.push((child, next_chart, next_depth));
                }
            }
        }
    }

    /// Can generation stop here? EOS is legal iff some pending terminal
    /// can close now and complete a parse.
    fn eos_allowed(&self) -> bool {
        let eng = &self.engine;
        self.hyps.iter().any(|hyp| {
            hyp.posset.iter().any(|&p| {
                if !eng.scanner.accepting(p) {
                    return false;
                }
                let Pos::In(t, _) = p else { return false };
                hyp.chart.feed(&eng.earley, t).map_or(false, |c| c.accepts())
            })
        })
    }

    /// Advance through raw bytes (used by the template baseline's healing
    /// and by tests) — same semantics as [`Checker::advance`] but not
    /// token-aligned.
    pub fn advance_bytes(&mut self, bytes: &[u8]) -> crate::Result<()> {
        let out = self.advance_hyps(bytes);
        if out.is_empty() {
            bail!("bytes {:?} are not a legal continuation", String::from_utf8_lossy(bytes));
        }
        self.hyps = out;
        Ok(())
    }

    /// Byte-level legality check (no state change).
    pub fn check_bytes(&self, bytes: &[u8]) -> bool {
        let eng = &self.engine;
        for hyp in &self.hyps {
            for (seq, posset) in eng.scanner.traverse(&hyp.posset, bytes) {
                let Some(chart) = hyp.chart.feed_all(&eng.earley, &seq) else { continue };
                if posset.iter().any(|p| match p {
                    Pos::In(t, _) => chart.allows(*t),
                    Pos::Boundary => false,
                }) {
                    return true;
                }
            }
        }
        false
    }

    /// Fold the live hypotheses (parser frontiers + pending scanner
    /// positions) into a hasher — the mask-determining part of the state.
    fn hash_hyps(&self, h: &mut impl std::hash::Hasher) {
        use std::hash::Hash;
        for hyp in &self.hyps {
            hyp.chart.frontier_fingerprint().hash(h);
            for p in &hyp.posset {
                p.hash(h);
            }
        }
    }

    /// Check a single token without a full mask (opportunistic masking,
    /// §3.5: find the nodes linked to the proposed token, then check a
    /// parser-allowed path from the root — realized by direct scanner
    /// traversal of the token's bytes, which is equivalent and O(|token|)).
    ///
    /// Traverses per starting position so the mid-terminal discount is
    /// attributed exactly as in [`Self::traverse_tree`]: with a mixed
    /// posset ({Boundary, In(..)}), a path starting at the Boundary gets
    /// no discount even though a sibling position is mid-terminal —
    /// otherwise `check_token` would admit tokens at finite `k` that
    /// `compute_mask` rejects, breaking the trait contract (and making
    /// cached-mask answers disagree with direct checks).
    fn check_token_inner(&self, token: TokenId) -> bool {
        if token == EOS_ID {
            return self.eos_allowed();
        }
        let eng = &self.engine;
        let bytes = eng.vocab.token_bytes(token);
        if bytes.is_empty() {
            return false;
        }
        if self.k == Lookahead::Infinite {
            // k = ∞ admits every parser-viable token, so discount
            // attribution is irrelevant — keep the joint traversal, which
            // dedups converging segmentations across start positions.
            for hyp in &self.hyps {
                for (seq, posset) in eng.scanner.traverse(&hyp.posset, bytes) {
                    let Some(chart) = hyp.chart.feed_all(&eng.earley, &seq) else { continue };
                    if posset.iter().any(|p| match p {
                        Pos::In(t, _) => chart.allows(*t),
                        Pos::Boundary => false,
                    }) {
                        return true;
                    }
                }
            }
            return false;
        }
        for hyp in &self.hyps {
            for &start in &hyp.posset {
                let mid_terminal = matches!(start, Pos::In(..));
                for (seq, posset) in eng.scanner.traverse(&[start], bytes) {
                    let depth = seq.len() as u32;
                    let discount = (mid_terminal && depth >= 1) as u32;
                    if !self.k.admits(depth - discount + 1) {
                        continue;
                    }
                    let Some(chart) = hyp.chart.feed_all(&eng.earley, &seq) else { continue };
                    if posset.iter().any(|p| match p {
                        Pos::In(t, _) => chart.allows(*t),
                        Pos::Boundary => false,
                    }) {
                        return true;
                    }
                }
            }
        }
        false
    }
}

impl Checker for DominoDecoder {
    fn advance(&mut self, token: TokenId) -> crate::Result<()> {
        if token == EOS_ID {
            if !self.eos_allowed() {
                bail!("EOS not legal here");
            }
            return Ok(());
        }
        self.last_token = Some(token);
        let next = self.advance_hyps(&self.engine.vocab.token_bytes(token).to_vec());
        if next.is_empty() {
            bail!(
                "token {} ({:?}) is not a legal continuation",
                token,
                self.engine.vocab.token_str(token)
            );
        }
        self.hyps = next;
        Ok(())
    }

    fn compute_mask(&mut self) -> Arc<TokenMask> {
        let mut mask = TokenMask::none(self.engine.vocab.len());
        for i in 0..self.hyps.len() {
            let hyp = self.hyps[i].clone();
            for &pos in &hyp.posset {
                self.traverse_tree(&hyp, pos, &mut mask);
            }
        }
        if self.eos_allowed() {
            mask.allow(EOS_ID);
        }
        Arc::new(mask)
    }

    fn check_token(&mut self, token: TokenId) -> bool {
        self.check_token_inner(token)
    }

    fn reset(&mut self) {
        let start = Hypothesis {
            chart: self.engine.earley.start_chart(),
            posset: vec![Pos::Boundary],
        };
        self.hyps = vec![start];
        self.last_token = None;
    }

    fn check_bytes(&mut self, bytes: &[u8]) -> bool {
        DominoDecoder::check_bytes(self, bytes)
    }

    fn advance_bytes(&mut self, bytes: &[u8]) -> crate::Result<()> {
        DominoDecoder::advance_bytes(self, bytes)
    }

    fn state_key(&self) -> Option<u64> {
        // (α, β) of §3.6: α = the pending subterminal set, β = the parser
        // frontier — folded into one fingerprint, plus the last committed
        // token (it pins the tokenization phase, which matters for
        // speculation but not for mask legality).
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.last_token.hash(&mut h);
        self.hash_hyps(&mut h);
        Some(h.finish())
    }

    fn mask_key(&self) -> Option<u64> {
        // Masks depend only on the live hypotheses (and the lookahead k,
        // which the cache encodes separately), so states reached via
        // different tokenizations of the same text share cached masks.
        use std::hash::Hasher;
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.hash_hyps(&mut h);
        Some(h.finish())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grammar::builtin::{fig3_expr, json};
    use crate::tokenizer;

    fn fig3_engine() -> Arc<Engine> {
        // Explicit merges so the Fig. 3 (c)-style tokens are guaranteed:
        // "+1" (bridge), "12", "((".
        let byte = |c: u8| (c as usize + tokenizer::NUM_SPECIAL) as TokenId;
        let vocab = Arc::new(
            Vocab::from_merges(vec![
                (byte(b'+'), byte(b'1')),
                (byte(b'1'), byte(b'2')),
                (byte(b'('), byte(b'(')),
            ])
            .unwrap(),
        );
        Engine::compile(fig3_expr(), vocab).unwrap()
    }

    fn tok(v: &Vocab, s: &str) -> TokenId {
        (0..v.len() as TokenId)
            .find(|&id| v.token_bytes(id) == s.as_bytes())
            .unwrap_or_else(|| panic!("token {s:?} not in vocab"))
    }

    fn advance_str(d: &mut DominoDecoder, s: &str) {
        for &b in s.as_bytes() {
            let id = (b as usize + tokenizer::NUM_SPECIAL) as TokenId;
            d.advance(id).unwrap();
        }
    }

    #[test]
    fn mask_at_start() {
        let eng = fig3_engine();
        let mut d = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        let m = d.compute_mask();
        let v = &eng.vocab;
        assert!(m.allowed(tok(v, "(")));
        assert!(m.allowed(tok(v, "1")));
        assert!(!m.allowed(tok(v, ")")));
        assert!(!m.allowed(tok(v, "+")));
        assert!(!m.allowed(EOS_ID)); // empty string not in the language
    }

    #[test]
    fn mask_mid_int_matches_fig3e() {
        // After "(12": continuations, ")" and "+" legal; "(" and EOS not.
        let eng = fig3_engine();
        let mut d = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        advance_str(&mut d, "(12");
        let m = d.compute_mask();
        let v = &eng.vocab;
        assert!(m.allowed(tok(v, "0")), "int continuation");
        assert!(m.allowed(tok(v, ")")));
        assert!(m.allowed(tok(v, "+")));
        assert!(!m.allowed(tok(v, "(")), "( illegal after (12");
        assert!(!m.allowed(EOS_ID), "unbalanced paren");
    }

    #[test]
    fn lookahead_gates_bridge_tokens() {
        // From "(12": "+1" costs 2 (close int free, +, start int) → needs
        // k ≥ 1. ")" costs 1 → allowed at k = 0.
        let eng = fig3_engine();
        let v = &eng.vocab;
        let plus1 = tok(v, "+1");
        let rp = tok(v, ")");
        for (k, expect_plus1) in [(Lookahead::K(0), false), (Lookahead::K(1), true), (Lookahead::Infinite, true)] {
            let mut d = DominoDecoder::new(eng.clone(), k);
            advance_str(&mut d, "(12");
            let m = d.compute_mask();
            assert_eq!(m.allowed(plus1), expect_plus1, "k={k:?}");
            assert!(m.allowed(rp), "k={k:?}");
        }
    }

    #[test]
    fn eos_exactly_at_complete_parses() {
        let eng = fig3_engine();
        let mut d = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        advance_str(&mut d, "(12+3)");
        assert!(d.compute_mask().allowed(EOS_ID));
        assert!(d.check_token(EOS_ID));
        // But "12+3" (no parens) also accepts; "(12+3" does not — covered
        // above. After full parse, "+" continues legally (E + E).
        assert!(d.check_token(tok(&eng.vocab, "+")));
        d.advance(EOS_ID).unwrap();
    }

    #[test]
    fn check_token_agrees_with_mask() {
        let eng = fig3_engine();
        for k in [Lookahead::K(0), Lookahead::K(1), Lookahead::Infinite] {
            let mut d = DominoDecoder::new(eng.clone(), k);
            advance_str(&mut d, "(12");
            let m = d.compute_mask();
            for id in 0..eng.vocab.len() as TokenId {
                assert_eq!(
                    d.check_token(id),
                    m.allowed(id),
                    "token {} ({:?}) k={k:?}",
                    id,
                    eng.vocab.token_str(id)
                );
            }
        }
    }

    #[test]
    fn advance_rejects_illegal() {
        let eng = fig3_engine();
        let mut d = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        assert!(d.advance(tok(&eng.vocab, ")")).is_err());
        assert!(d.advance(EOS_ID).is_err());
    }

    #[test]
    fn json_decoding_session() {
        let vocab = Arc::new(tokenizer::bpe::synthetic_json_vocab(512));
        let eng = Engine::compile(json(), vocab.clone()).unwrap();
        let mut d = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        // Drive the decoder through a full JSON object token by token,
        // asserting every committed token was mask-legal.
        let text = "{\"name\": \"John\", \"age\": 35}";
        let ids = vocab.encode(text.as_bytes());
        for &id in &ids {
            let m = d.compute_mask();
            assert!(m.allowed(id), "mask rejects {:?}", vocab.token_str(id));
            d.advance(id).unwrap();
        }
        assert!(d.check_token(EOS_ID), "complete object → EOS legal");
    }

    #[test]
    fn json_bridge_tokens_need_lookahead() {
        // In a JSON object after a value, the bridge token `",` (quote +
        // comma) spans two terminals: it needs k ≥ 1... it closes the
        // pending STRING (free) then completes ','? No: from mid-string,
        // `",` closes STRING (free) and completes ',' pending → cost 1.
        // From the *boundary* after `{`, `":` costs 2.
        let vocab = Arc::new(tokenizer::bpe::synthetic_json_vocab(512));
        let eng = Engine::compile(json(), vocab.clone()).unwrap();
        let quote_colon = (0..vocab.len() as TokenId)
            .find(|&id| vocab.token_bytes(id) == b"\":")
            .expect("\": bridge token in synthetic vocab");
        let prefix = "{\"name";
        let mut d0 = DominoDecoder::new(eng.clone(), Lookahead::K(0));
        let mut dinf = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        for &b in prefix.as_bytes() {
            let id = (b as usize + tokenizer::NUM_SPECIAL) as TokenId;
            d0.advance(id).unwrap();
            dinf.advance(id).unwrap();
        }
        // From mid-STRING (after `{"name`): `":` closes STRING (free) and
        // leaves ':' pending → cost 1 → allowed at every k.
        assert!(dinf.check_token(quote_colon));
        assert!(d0.check_token(quote_colon));
        // After just `{`: `":` is legal only as the *start* of a string
        // whose content begins with ':' (the colon is string content) —
        // still one subterminal → legal. But a bare ',' is neither a legal
        // next terminal after '{' nor string content at the boundary:
        let mut d = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        d.advance((b'{' as usize + tokenizer::NUM_SPECIAL) as TokenId).unwrap();
        assert!(d.check_token(quote_colon), "\": = string starting with colon");
        let comma = (b',' as usize + tokenizer::NUM_SPECIAL) as TokenId;
        assert!(!d.check_token(comma), ", illegal right after {{");
    }

    #[test]
    fn mask_key_shared_across_tokenizations() {
        // Reaching the same text via different tokenizations ("(" "1" "2"
        // vs "(" "12") must share a mask_key (masks are identical) while
        // state_key differs (the last token matters for speculation).
        let eng = fig3_engine();
        let v = &eng.vocab;
        let mut by_bytes = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        advance_str(&mut by_bytes, "(12");
        let mut by_merge = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        by_merge.advance(tok(v, "(")).unwrap();
        by_merge.advance(tok(v, "12")).unwrap();
        assert_eq!(by_bytes.mask_key(), by_merge.mask_key());
        assert_ne!(by_bytes.state_key(), by_merge.state_key());
        assert_eq!(by_bytes.compute_mask(), by_merge.compute_mask());
    }

    #[test]
    fn lazy_engine_masks_match_eager() {
        // Masks are determined by grammar semantics, not by when automata
        // are determinized: a lazily-compiled engine must be bit-identical
        // to the eager one along a decoding walk, at finite and infinite k.
        let vocab = Arc::new(tokenizer::bpe::synthetic_json_vocab(512));
        let eager = Engine::compile(json(), vocab.clone()).unwrap();
        let lazy = Engine::compile_lazy(json(), vocab.clone()).unwrap();
        assert!(lazy.is_lazy() && !eager.is_lazy());
        let ids = vocab.encode(b"{\"name\": \"John\", \"age\": 35}");
        for k in [Lookahead::K(0), Lookahead::Infinite] {
            let mut de = DominoDecoder::new(eager.clone(), k);
            let mut dl = DominoDecoder::new(lazy.clone(), k);
            for &id in &ids {
                assert_eq!(de.compute_mask(), dl.compute_mask(), "k={k:?} before {:?}", vocab.token_str(id));
                de.advance(id).unwrap();
                dl.advance(id).unwrap();
            }
            assert_eq!(de.compute_mask(), dl.compute_mask(), "k={k:?} at end");
        }
        // Lazy construction only built what the walk touched.
        assert!(lazy.trees.num_trees() > 0);
        // Materialization preserves behavior (and the engine stops being
        // lazy).
        let mat = lazy.materialize_full();
        assert!(!mat.is_lazy());
        let mut dm = DominoDecoder::new(mat, Lookahead::Infinite);
        let mut dl = DominoDecoder::new(lazy.clone(), Lookahead::Infinite);
        for &id in &ids {
            dm.advance(id).unwrap();
            dl.advance(id).unwrap();
        }
        assert_eq!(dm.compute_mask(), dl.compute_mask());
    }

    #[test]
    fn mask_never_empty_while_alive() {
        // Property: as long as the decoder is alive, the mask admits at
        // least one token (no deadlock) — byte tokens guarantee progress.
        let eng = fig3_engine();
        let mut d = DominoDecoder::new(eng.clone(), Lookahead::K(0));
        advance_str(&mut d, "(12+");
        let m = d.compute_mask();
        assert!(m.count() > 0);
    }
}
