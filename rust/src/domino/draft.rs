//! The draft lane's cheap proposers — grammar-pruned multi-token
//! drafting over the batched tick.
//!
//! §3.6's count-based speculation only fires when one token dominates a
//! state (`P ≥ τ`). The draft lane generalizes it: a [`DraftModel`]
//! proposes up to K tokens per slot per tick from whatever cheap signal
//! it has (here: the [`SpeculativeModel`] priors' n-gram continuation
//! counts), and the grammar prunes the proposal **while it is built** —
//! every candidate token is filtered through `Checker::compute_mask` via
//! the shared [`MaskCache`] before it is added, so an infeasible branch
//! never occupies a row of the target model's batched forward pass.
//! Verification then rides the existing `scored` lanes with
//! longest-accepted-prefix adoption (`server::slot`), which keeps drafted
//! decoding token-identical to plain decoding: acceptance-or-correction,
//! never a changed distribution.
//!
//! The trait is the extension point for richer proposers (e.g. a second,
//! smaller `LmBackend` acting as draft model — such an implementation
//! keeps its own session in sync through [`DraftModel::commit`]).

use super::decoder::DominoDecoder;
use super::mask::TokenMask;
use super::spec::SpeculativeModel;
use crate::constraint::MaskCache;
use crate::tokenizer::EOS_ID;
use crate::TokenId;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// A gram must have been observed this often before it is drafted.
const MIN_GRAM_COUNT: u64 = 2;

/// A mask for `decoder`'s current state via the shared cache (compute and
/// fill on miss) — the drafted/speculative paths hold the concrete
/// decoder (no [`crate::constraint::CachedChecker`] wrapper), so their
/// mask computations go through the cache explicitly.
pub fn cached_mask(decoder: &mut DominoDecoder, masks: &MaskCache, variant: u64) -> Arc<TokenMask> {
    cached_mask_with_hit(decoder, masks, variant).0
}

/// [`cached_mask`], also reporting the cache outcome: `Some(true)` hit,
/// `Some(false)` computed-and-filled, `None` uncacheable (no mask key).
/// The tracing layer records this per decode decision; the plain path
/// ignores it.
pub fn cached_mask_with_hit(
    decoder: &mut DominoDecoder,
    masks: &MaskCache,
    variant: u64,
) -> (Arc<TokenMask>, Option<bool>) {
    match decoder.mask_key() {
        Some(state) => match masks.get(variant, state) {
            Some(m) => (m, Some(true)),
            None => {
                let m = decoder.compute_mask();
                masks.put(variant, state, m.clone());
                (m, Some(false))
            }
        },
        None => (decoder.compute_mask(), None),
    }
}

/// A cheap multi-token proposer for the drafted decode lane
/// (`DecodeMode::Drafted` in `server::slot`).
pub trait DraftModel: Send {
    /// Propose up to `k` tokens continuing `decoder`'s current state.
    /// Implementations are expected to filter every candidate through the
    /// shared mask cache (`masks`/`variant`) *while building* the
    /// proposal (prune-before-verify) so infeasible branches never reach
    /// the target model; the verifier tolerates illegal tokens regardless
    /// (they are rejected like any mispredicted token).
    fn propose(
        &mut self,
        decoder: &DominoDecoder,
        masks: &MaskCache,
        variant: u64,
        k: usize,
    ) -> Vec<TokenId>;

    /// Feedback after verification: the accepted prefix of the last
    /// proposal plus the correction token committed on mismatch (if any).
    /// Stateless proposers ignore it; a session-backed draft model uses
    /// it to keep its own context in sync with the target.
    fn commit(&mut self, _accepted: &[TokenId], _corrected: Option<TokenId>) {}
}

/// Proposal length from the slot's recent acceptance rate: a cold or
/// mispredicting prior degrades gracefully to K=1 (a one-token scored
/// lane costs the same forward row as a plain step), a hot one ramps to
/// `k_max`.
pub fn adaptive_k(accept_ewma: f64, k_max: usize) -> usize {
    let k_max = k_max.max(1);
    let extra = (accept_ewma.clamp(0.0, 1.0) * (k_max - 1) as f64).round() as usize;
    (1 + extra).min(k_max)
}

/// Core of prior-driven drafting, shared by the serving lane
/// ([`PriorDraft`], which filters through the shared [`MaskCache`]) and
/// the scalar reference path (`generate::generate_drafted`): chain
/// gram/argmax lookups from `spec` through a cloned decoder until `k`
/// tokens are drafted or the prior runs dry. `allowed` is the grammar
/// filter applied to every candidate *before* it joins the proposal;
/// with `prune` false the filter is skipped (the prune-after-verify
/// comparison ordering — infeasible draft tokens ride to verification
/// and waste scored rows there). No confidence threshold gates the
/// chain — the caller's adaptive proposal length throttles a cold or
/// noisy prior instead.
pub fn draft_from_prior(
    spec: &SpeculativeModel,
    decoder: &DominoDecoder,
    k: usize,
    prune: bool,
    mut allowed: impl FnMut(&mut DominoDecoder, TokenId) -> bool,
) -> Vec<TokenId> {
    let mut clone = decoder.clone();
    let mut alive = true;
    let mut queue: VecDeque<TokenId> = VecDeque::new();
    let mut out = Vec::new();
    while out.len() < k {
        if queue.is_empty() {
            if !alive {
                break;
            }
            let Some(key) = clone.state_key() else { break };
            let visits = spec.visits(key);
            // Whole-gram lookup first: a majority gram drafts several
            // tokens from one table hit.
            match spec.best_gram(key) {
                Some((g, c)) if c >= MIN_GRAM_COUNT && c * 2 >= visits => {
                    queue.extend(g.iter().copied())
                }
                _ => match spec.argmax(key) {
                    Some(t) => queue.push_back(t),
                    None => break,
                },
            }
        }
        let t = queue.pop_front().expect("refilled above");
        if t == EOS_ID {
            // A stop can't ride a scored lane (nothing follows it); let
            // the verifier's own choice conclude the stream.
            break;
        }
        if prune {
            // Prune-before-verify: the grammar filters the candidate
            // BEFORE it can occupy a forward-pass row.
            if !allowed(&mut clone, t) || clone.advance(t).is_err() {
                break;
            }
            out.push(t);
        } else {
            // Prune-after-verify ordering: the candidate goes into the
            // proposal unchecked; once the chain leaves the grammar,
            // later lookups stop (no live state key) but the queued gram
            // tail still wastes rows.
            out.push(t);
            if alive {
                alive = clone.advance(t).is_ok();
            }
        }
    }
    out
}

/// Prior-driven drafting from the shared [`SpeculativeModel`], with
/// every candidate filtered through the shared mask cache (the serving
/// draft lane's proposer).
pub struct PriorDraft {
    spec: Arc<Mutex<SpeculativeModel>>,
    /// When false, candidates skip the grammar filter at build time (the
    /// "prune-after-verify" ordering `fig5_speculation` compares
    /// against).
    prune: bool,
}

impl PriorDraft {
    pub fn new(spec: Arc<Mutex<SpeculativeModel>>) -> PriorDraft {
        PriorDraft { spec, prune: true }
    }

    /// The prune-after-verify comparison lane (benches only).
    pub fn without_pruning(spec: Arc<Mutex<SpeculativeModel>>) -> PriorDraft {
        PriorDraft { spec, prune: false }
    }
}

impl DraftModel for PriorDraft {
    fn propose(
        &mut self,
        decoder: &DominoDecoder,
        masks: &MaskCache,
        variant: u64,
        k: usize,
    ) -> Vec<TokenId> {
        let spec = self.spec.lock().expect("spec lock");
        draft_from_prior(&spec, decoder, k, self.prune, |clone, t| {
            cached_mask(clone, masks, variant).allowed(t)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domino::decoder::{Engine, Lookahead};
    use crate::grammar::builtin::fixed_template;
    use crate::tokenizer;

    fn template_setup() -> (Arc<Engine>, DominoDecoder, Arc<Mutex<SpeculativeModel>>, Vec<TokenId>)
    {
        let vocab = Arc::new(tokenizer::bpe::synthetic_json_vocab(512));
        let eng = Engine::compile(fixed_template(), vocab.clone()).unwrap();
        let ids = vocab.encode("{\"id\"".as_bytes());
        let mut m = SpeculativeModel::new(0.75);
        // Observe the template prefix twice so grams clear MIN_GRAM_COUNT.
        for _ in 0..2 {
            let mut d = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
            let mut hist: Vec<(u64, TokenId)> = Vec::new();
            for &id in &ids {
                m.observe_step(&mut hist, d.state_key(), id);
                d.advance(id).unwrap();
            }
        }
        let dec = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        (eng, dec, Arc::new(Mutex::new(m)), ids)
    }

    #[test]
    fn adaptive_k_ramps_with_acceptance() {
        assert_eq!(adaptive_k(0.0, 8), 1, "cold prior degrades to K=1");
        assert_eq!(adaptive_k(1.0, 8), 8, "fully accepted drafts ramp to K max");
        assert_eq!(adaptive_k(0.5, 8), 5);
        assert_eq!(adaptive_k(0.0, 1), 1);
        assert_eq!(adaptive_k(1.0, 0), 1, "degenerate K is clamped up");
        assert_eq!(adaptive_k(7.5, 4), 4, "rates clamp into [0, 1]");
    }

    #[test]
    fn prior_draft_replays_observed_prefix() {
        let (_eng, dec, spec, ids) = template_setup();
        let masks = MaskCache::new(256);
        let mut draft = PriorDraft::new(spec);
        let prop = draft.propose(&dec, &masks, 0, 8);
        assert!(!prop.is_empty(), "warm prior must draft");
        assert_eq!(&prop[..], &ids[..prop.len().min(ids.len())]);
        // Every drafted token was checked against the grammar, so a
        // fresh decoder replays the proposal without error.
        let mut d = dec.clone();
        for &t in &prop {
            assert!(d.check_token(t), "drafted token {t} is grammar-illegal");
            d.advance(t).unwrap();
        }
    }

    #[test]
    fn draft_respects_k_and_cold_prior_drafts_nothing() {
        let (_eng, dec, spec, _ids) = template_setup();
        let masks = MaskCache::new(256);
        let mut draft = PriorDraft::new(spec);
        for k in [0usize, 1, 2] {
            assert!(draft.propose(&dec, &masks, 0, k).len() <= k);
        }
        let cold = Arc::new(Mutex::new(SpeculativeModel::new(0.75)));
        let mut draft = PriorDraft::new(cold);
        assert!(draft.propose(&dec, &masks, 0, 8).is_empty());
    }

    #[test]
    fn unpruned_draft_may_propose_illegal_tokens() {
        // Poison the prior with a token that is grammar-illegal at the
        // start state: the pruned drafter must cut it, the unpruned one
        // ships it to verification.
        let (eng, dec, spec, _ids) = template_setup();
        let masks = MaskCache::new(256);
        let start_key = dec.state_key().unwrap();
        let mut illegal = None;
        for t in 1..eng.vocab.len() as TokenId {
            let mut probe = dec.clone();
            if !probe.check_token(t) {
                illegal = Some(t);
                break;
            }
        }
        let illegal = illegal.expect("template grammar rejects some token");
        {
            let mut m = spec.lock().unwrap();
            for _ in 0..100 {
                m.observe(start_key, illegal);
            }
        }
        let pruned = PriorDraft::new(spec.clone()).propose(&dec, &masks, 0, 8);
        assert!(pruned.is_empty(), "pruned draft must cut the illegal branch");
        let unpruned = PriorDraft::without_pruning(spec).propose(&dec, &masks, 0, 8);
        assert_eq!(unpruned.first(), Some(&illegal), "unpruned draft ships the bad token");
    }
}
