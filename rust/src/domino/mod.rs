//! DOMINO (§3.5) — minimally-invasive constrained decoding.
//!
//! * [`mask`] — vocabulary bitmasks (the `m` of Algorithm 1),
//! * [`tree`] — vocabulary-aligned subterminal trees (Algorithm 2),
//!   precomputed offline per scanner position,
//! * [`decoder`] — the inference-time decoder: advances scanner + parser
//!   hypotheses per generated token, computes lookahead-`k` masks by
//!   parser-pruned tree traversal (Fig. 3 (e)), checks single tokens for
//!   opportunistic masking,
//! * [`spec`] — the count-based speculative model `P(l | α, β)` of §3.6,
//! * [`draft`] — grammar-pruned multi-token draft proposers built on
//!   those priors (the serving draft lane).
//!
//! The [`Checker`] trait is Algorithm 1's `C`: baselines implement it too,
//! so the eval harness and server are decoder-agnostic.

pub mod decoder;
pub mod draft;
pub mod generate;
pub mod mask;
pub mod spec;
pub mod tree;

pub use decoder::{DominoDecoder, Engine, Lookahead};
pub use draft::{DraftModel, PriorDraft};
pub use generate::{
    generate, generate_drafted, generate_speculative, GenConfig, GenResult, MaskMode,
};
pub use mask::TokenMask;
pub use spec::SpeculativeModel;
pub use tree::TreeSet;

use crate::TokenId;
use std::sync::Arc;

/// Algorithm 1's checker interface.
///
/// `advance` is the incremental form of `C.update(o)`; `compute_mask` is
/// `C.mask()`. `check_token` supports opportunistic masking: it must agree
/// with `compute_mask` (`check_token(t) ⇔ compute_mask().allowed(t)`), but
/// may be much cheaper for a single token.
pub trait Checker: Send {
    /// Consume one committed output token.
    fn advance(&mut self, token: TokenId) -> crate::Result<()>;

    /// Mask of legal next tokens (EOS included, as token id 0).
    ///
    /// Returned behind an `Arc` so cache hits (the common case under the
    /// shared [`crate::constraint::MaskCache`]) hand out the stored mask
    /// without deep-copying a vocabulary-sized bitset per step.
    fn compute_mask(&mut self) -> Arc<TokenMask>;

    /// Is this single token a legal continuation?
    fn check_token(&mut self, token: TokenId) -> bool;

    /// Reset to the initial state (empty output).
    fn reset(&mut self);

    /// Has the output reached a state where generation may stop (EOS
    /// legal)?
    fn can_stop(&mut self) -> bool {
        self.check_token(crate::tokenizer::EOS_ID)
    }

    /// A fingerprint of the checker state `(α, β)` used by the speculative
    /// model (§3.6). `None` = speculation unsupported.
    fn state_key(&self) -> Option<u64> {
        None
    }

    /// A fingerprint of the state as it determines [`compute_mask`]
    /// (`Checker::compute_mask`) — i.e. excluding anything mask-irrelevant
    /// that `state_key` folds in for speculation (DOMINO's last committed
    /// token pins the tokenization phase, which matters for predicting the
    /// next token but not for which tokens are legal). Used as the
    /// mask-cache key: states reached via different tokenizations share
    /// cached masks. Defaults to [`state_key`](Checker::state_key).
    fn mask_key(&self) -> Option<u64> {
        self.state_key()
    }

    /// Byte-level legality check (token healing at the prompt boundary
    /// commits partial tokens, §3.5). Unconstrained checkers accept
    /// everything.
    fn check_bytes(&mut self, _bytes: &[u8]) -> bool {
        true
    }

    /// Byte-level advance (see [`Checker::check_bytes`]).
    fn advance_bytes(&mut self, _bytes: &[u8]) -> crate::Result<()> {
        Ok(())
    }
}

/// The trivial checker: everything allowed (unconstrained decoding).
pub struct Unconstrained {
    all: Arc<TokenMask>,
}

impl Unconstrained {
    pub fn new(vocab_size: usize) -> Self {
        Unconstrained { all: Arc::new(TokenMask::all(vocab_size)) }
    }
}

impl Checker for Unconstrained {
    fn advance(&mut self, _token: TokenId) -> crate::Result<()> {
        Ok(())
    }

    fn compute_mask(&mut self) -> Arc<TokenMask> {
        self.all.clone()
    }

    fn check_token(&mut self, _token: TokenId) -> bool {
        true
    }

    fn reset(&mut self) {}
}
