//! Token masks: bitsets over the vocabulary (EOS = bit 0).

use crate::TokenId;

/// The `m` of Algorithm 1 — one bit per vocabulary token.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenMask {
    words: Vec<u64>,
    size: usize,
}

impl TokenMask {
    pub fn none(size: usize) -> TokenMask {
        TokenMask { words: vec![0; size.div_ceil(64)], size }
    }

    pub fn all(size: usize) -> TokenMask {
        let mut m = TokenMask { words: vec![u64::MAX; size.div_ceil(64)], size };
        // Clear bits beyond `size`.
        let extra = m.words.len() * 64 - size;
        if extra > 0 {
            let last = m.words.len() - 1;
            m.words[last] >>= extra;
        }
        m
    }

    #[inline]
    pub fn allow(&mut self, t: TokenId) {
        let i = t as usize;
        debug_assert!(i < self.size);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn forbid(&mut self, t: TokenId) {
        let i = t as usize;
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn allowed(&self, t: TokenId) -> bool {
        let i = t as usize;
        i < self.size && self.words[i >> 6] & (1u64 << (i & 63)) != 0
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// The raw bit words (serialization; bit `i` of word `i/64` = token `i`).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from raw words (deserialization). Rejects a word count that
    /// doesn't match `size` and set bits past `size` — ghost bits would
    /// break the equality the mask cache keys on.
    pub fn from_words(size: usize, words: Vec<u64>) -> crate::Result<TokenMask> {
        if words.len() != size.div_ceil(64) {
            anyhow::bail!("mask has {} words, size {size} needs {}", words.len(), size.div_ceil(64));
        }
        let extra = words.len() * 64 - size;
        if extra > 0 {
            let last = words[words.len() - 1];
            if last >> (64 - extra) != 0 {
                anyhow::bail!("mask has bits set past its size {size}");
            }
        }
        Ok(TokenMask { words, size })
    }

    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn union(&mut self, other: &TokenMask) {
        debug_assert_eq!(self.size, other.size);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = TokenId> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            let mut out = Vec::with_capacity(w.count_ones() as usize);
            while w != 0 {
                let b = w.trailing_zeros();
                out.push((wi * 64 + b as usize) as TokenId);
                w &= w - 1;
            }
            out
        })
    }

    /// Apply to a logits row: disallowed entries become `-inf`
    /// (Algorithm 1 line 7, `m ⊙ v`).
    pub fn apply(&self, logits: &mut [f32]) {
        for (i, l) in logits.iter_mut().enumerate() {
            if !self.allowed(i as TokenId) {
                *l = f32::NEG_INFINITY;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_and_query() {
        let mut m = TokenMask::none(100);
        assert!(m.is_empty());
        m.allow(0);
        m.allow(63);
        m.allow(64);
        m.allow(99);
        assert_eq!(m.count(), 4);
        assert!(m.allowed(0) && m.allowed(63) && m.allowed(64) && m.allowed(99));
        assert!(!m.allowed(1) && !m.allowed(100));
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![0, 63, 64, 99]);
        m.forbid(63);
        assert!(!m.allowed(63));
    }

    #[test]
    fn all_respects_size() {
        let m = TokenMask::all(70);
        assert_eq!(m.count(), 70);
        assert!(m.allowed(69));
        assert!(!m.allowed(70));
    }

    #[test]
    fn all_handles_every_word_edge() {
        // Non-multiple-of-64 sizes must not leak bits past `size` (those
        // ghost bits would corrupt count()/is_empty() and cache equality).
        for size in [1usize, 63, 64, 65, 127, 128, 130, 512] {
            let m = TokenMask::all(size);
            assert_eq!(m.count(), size, "size {size}");
            assert!(m.allowed((size - 1) as TokenId), "top bit of size {size}");
            assert!(!m.allowed(size as TokenId), "first ghost bit of size {size}");
            assert_eq!(m.iter().count(), size, "iter agrees for size {size}");
            assert!(!m.is_empty());
        }
    }

    #[test]
    fn allow_allowed_roundtrip_at_word_boundaries() {
        let mut m = TokenMask::none(192);
        let probes: [TokenId; 7] = [0, 63, 64, 65, 127, 128, 191];
        for &t in &probes {
            assert!(!m.allowed(t));
            m.allow(t);
            assert!(m.allowed(t), "allow({t}) must round-trip");
        }
        assert_eq!(m.iter().collect::<Vec<_>>(), probes.to_vec());
        assert_eq!(m.count(), probes.len());
        m.forbid(63);
        m.forbid(128);
        assert!(!m.allowed(63) && !m.allowed(128));
        assert_eq!(m.count(), probes.len() - 2);
        // Out-of-range queries are false, never a panic.
        assert!(!m.allowed(192));
        assert!(!m.allowed(10_000));
    }

    #[test]
    fn equality_is_cache_key_safe() {
        // TokenMask is stored/compared by the mask cache: masks built by
        // different operation orders but with the same bits are equal.
        let mut a = TokenMask::none(130);
        let mut b = TokenMask::none(130);
        for t in [1u32, 64, 129] {
            a.allow(t);
        }
        for t in [129u32, 1, 64] {
            b.allow(t);
        }
        assert_eq!(a, b);
        b.forbid(64);
        assert_ne!(a, b);
        // all() equals an explicitly-filled mask of the same size.
        let mut c = TokenMask::none(70);
        for t in 0..70u32 {
            c.allow(t);
        }
        assert_eq!(c, TokenMask::all(70));
    }

    #[test]
    fn words_roundtrip_rejects_ghost_bits() {
        let mut m = TokenMask::none(70);
        m.allow(0);
        m.allow(69);
        let back = TokenMask::from_words(70, m.words().to_vec()).unwrap();
        assert_eq!(back, m);
        // Wrong word count.
        assert!(TokenMask::from_words(70, vec![0u64]).is_err());
        // A bit past `size` is corrupt, not silently carried.
        let mut words = m.words().to_vec();
        words[1] |= 1u64 << 63;
        assert!(TokenMask::from_words(70, words).is_err());
        // Exact multiples of 64 have no ghost range.
        assert!(TokenMask::from_words(128, vec![u64::MAX, u64::MAX]).is_ok());
    }

    #[test]
    fn apply_to_logits() {
        let mut m = TokenMask::none(4);
        m.allow(2);
        let mut logits = vec![1.0f32, 2.0, 3.0, 4.0];
        m.apply(&mut logits);
        assert_eq!(logits[2], 3.0);
        assert!(logits[0].is_infinite() && logits[1].is_infinite() && logits[3].is_infinite());
    }

    #[test]
    fn union() {
        let mut a = TokenMask::none(10);
        a.allow(1);
        let mut b = TokenMask::none(10);
        b.allow(8);
        a.union(&b);
        assert!(a.allowed(1) && a.allowed(8));
        assert_eq!(a.count(), 2);
    }
}
