//! Token masks: bitsets over the vocabulary (EOS = bit 0).
//!
//! The kernels here sit on the per-step decode hot path (`apply` runs once
//! per slot per tick over the whole vocabulary), so they are written
//! word-at-a-time: each `u64` of the bitset drives a 64-lane chunk of the
//! logits row with a branchless select that LLVM autovectorizes. No
//! `unsafe`, no nightly SIMD — `benches/mask_micro.rs` verifies the
//! speedup over a scalar per-bit reference.

use crate::TokenId;

/// The `m` of Algorithm 1 — one bit per vocabulary token.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenMask {
    words: Vec<u64>,
    size: usize,
}

impl TokenMask {
    pub fn none(size: usize) -> TokenMask {
        TokenMask { words: vec![0; size.div_ceil(64)], size }
    }

    pub fn all(size: usize) -> TokenMask {
        let mut m = TokenMask { words: vec![u64::MAX; size.div_ceil(64)], size };
        // Clear bits beyond `size`.
        let extra = m.words.len() * 64 - size;
        if extra > 0 {
            let last = m.words.len() - 1;
            m.words[last] >>= extra;
        }
        m
    }

    #[inline]
    pub fn allow(&mut self, t: TokenId) {
        let i = t as usize;
        debug_assert!(i < self.size);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn forbid(&mut self, t: TokenId) {
        let i = t as usize;
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn allowed(&self, t: TokenId) -> bool {
        let i = t as usize;
        i < self.size && self.words[i >> 6] & (1u64 << (i & 63)) != 0
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// The raw bit words (serialization; bit `i` of word `i/64` = token `i`).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from raw words (deserialization). Rejects a word count that
    /// doesn't match `size` and set bits past `size` — ghost bits would
    /// break the equality the mask cache keys on.
    pub fn from_words(size: usize, words: Vec<u64>) -> crate::Result<TokenMask> {
        if words.len() != size.div_ceil(64) {
            anyhow::bail!("mask has {} words, size {size} needs {}", words.len(), size.div_ceil(64));
        }
        let extra = words.len() * 64 - size;
        if extra > 0 {
            let last = words[words.len() - 1];
            if last >> (64 - extra) != 0 {
                anyhow::bail!("mask has bits set past its size {size}");
            }
        }
        Ok(TokenMask { words, size })
    }

    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn union(&mut self, other: &TokenMask) {
        debug_assert_eq!(self.size, other.size);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// `self &= other` — restrict to tokens both masks allow.
    pub fn intersect(&mut self, other: &TokenMask) {
        debug_assert_eq!(self.size, other.size);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// `self &= !other` — remove every token `other` allows.
    pub fn and_not(&mut self, other: &TokenMask) {
        debug_assert_eq!(self.size, other.size);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// Popcount of `self & other` without materializing the intersection.
    pub fn count_intersect(&self, other: &TokenMask) -> usize {
        debug_assert_eq!(self.size, other.size);
        self.words.iter().zip(&other.words).map(|(a, b)| (a & b).count_ones() as usize).sum()
    }

    /// Iterate set bits in ascending token order. Allocation-free: a word
    /// cursor peels one bit per `next` with `trailing_zeros`.
    pub fn iter(&self) -> MaskIter<'_> {
        MaskIter { words: &self.words, wi: 0, cur: self.words.first().copied().unwrap_or(0) }
    }

    /// Apply to a logits row: disallowed entries become `-inf`
    /// (Algorithm 1 line 7, `m ⊙ v`). Word-parallel: each bitset word is
    /// expanded into a branchless 64-lane select, with all-ones words
    /// skipped and all-zero words block-filled. Indices past `size` (a
    /// logits row longer than the vocabulary) are forbidden, matching the
    /// scalar `allowed()` semantics.
    pub fn apply(&self, logits: &mut [f32]) {
        let n = logits.len().min(self.size);
        let (head, tail) = logits.split_at_mut(n);
        let mut chunks = head.chunks_exact_mut(64);
        let mut wi = 0;
        for chunk in &mut chunks {
            let w = self.words[wi];
            wi += 1;
            if w == u64::MAX {
                continue;
            }
            if w == 0 {
                chunk.fill(f32::NEG_INFINITY);
                continue;
            }
            for (j, l) in chunk.iter_mut().enumerate() {
                // Branchless lane select — autovectorizes.
                *l = if (w >> j) & 1 != 0 { *l } else { f32::NEG_INFINITY };
            }
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let w = self.words[wi];
            for (j, l) in rem.iter_mut().enumerate() {
                *l = if (w >> j) & 1 != 0 { *l } else { f32::NEG_INFINITY };
            }
        }
        tail.fill(f32::NEG_INFINITY);
    }
}

/// Lazy word-cursor iterator over a mask's set bits (see
/// [`TokenMask::iter`]): `cur` holds the not-yet-yielded bits of word
/// `wi`; each step pops the lowest with `trailing_zeros` + `w & (w-1)`.
pub struct MaskIter<'a> {
    words: &'a [u64],
    wi: usize,
    cur: u64,
}

impl Iterator for MaskIter<'_> {
    type Item = TokenId;

    #[inline]
    fn next(&mut self) -> Option<TokenId> {
        while self.cur == 0 {
            self.wi += 1;
            if self.wi >= self.words.len() {
                return None;
            }
            self.cur = self.words[self.wi];
        }
        let b = self.cur.trailing_zeros();
        self.cur &= self.cur - 1;
        Some((self.wi * 64 + b as usize) as TokenId)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn set_and_query() {
        let mut m = TokenMask::none(100);
        assert!(m.is_empty());
        m.allow(0);
        m.allow(63);
        m.allow(64);
        m.allow(99);
        assert_eq!(m.count(), 4);
        assert!(m.allowed(0) && m.allowed(63) && m.allowed(64) && m.allowed(99));
        assert!(!m.allowed(1) && !m.allowed(100));
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![0, 63, 64, 99]);
        m.forbid(63);
        assert!(!m.allowed(63));
    }

    #[test]
    fn all_respects_size() {
        let m = TokenMask::all(70);
        assert_eq!(m.count(), 70);
        assert!(m.allowed(69));
        assert!(!m.allowed(70));
    }

    #[test]
    fn all_handles_every_word_edge() {
        // Non-multiple-of-64 sizes must not leak bits past `size` (those
        // ghost bits would corrupt count()/is_empty() and cache equality).
        for size in [1usize, 63, 64, 65, 127, 128, 130, 512] {
            let m = TokenMask::all(size);
            assert_eq!(m.count(), size, "size {size}");
            assert!(m.allowed((size - 1) as TokenId), "top bit of size {size}");
            assert!(!m.allowed(size as TokenId), "first ghost bit of size {size}");
            assert_eq!(m.iter().count(), size, "iter agrees for size {size}");
            assert!(!m.is_empty());
        }
    }

    #[test]
    fn allow_allowed_roundtrip_at_word_boundaries() {
        let mut m = TokenMask::none(192);
        let probes: [TokenId; 7] = [0, 63, 64, 65, 127, 128, 191];
        for &t in &probes {
            assert!(!m.allowed(t));
            m.allow(t);
            assert!(m.allowed(t), "allow({t}) must round-trip");
        }
        assert_eq!(m.iter().collect::<Vec<_>>(), probes.to_vec());
        assert_eq!(m.count(), probes.len());
        m.forbid(63);
        m.forbid(128);
        assert!(!m.allowed(63) && !m.allowed(128));
        assert_eq!(m.count(), probes.len() - 2);
        // Out-of-range queries are false, never a panic.
        assert!(!m.allowed(192));
        assert!(!m.allowed(10_000));
    }

    #[test]
    fn equality_is_cache_key_safe() {
        // TokenMask is stored/compared by the mask cache: masks built by
        // different operation orders but with the same bits are equal.
        let mut a = TokenMask::none(130);
        let mut b = TokenMask::none(130);
        for t in [1u32, 64, 129] {
            a.allow(t);
        }
        for t in [129u32, 1, 64] {
            b.allow(t);
        }
        assert_eq!(a, b);
        b.forbid(64);
        assert_ne!(a, b);
        // all() equals an explicitly-filled mask of the same size.
        let mut c = TokenMask::none(70);
        for t in 0..70u32 {
            c.allow(t);
        }
        assert_eq!(c, TokenMask::all(70));
    }

    #[test]
    fn words_roundtrip_rejects_ghost_bits() {
        let mut m = TokenMask::none(70);
        m.allow(0);
        m.allow(69);
        let back = TokenMask::from_words(70, m.words().to_vec()).unwrap();
        assert_eq!(back, m);
        // Wrong word count.
        assert!(TokenMask::from_words(70, vec![0u64]).is_err());
        // A bit past `size` is corrupt, not silently carried.
        let mut words = m.words().to_vec();
        words[1] |= 1u64 << 63;
        assert!(TokenMask::from_words(70, words).is_err());
        // Exact multiples of 64 have no ghost range.
        assert!(TokenMask::from_words(128, vec![u64::MAX, u64::MAX]).is_ok());
    }

    #[test]
    fn apply_to_logits() {
        let mut m = TokenMask::none(4);
        m.allow(2);
        let mut logits = vec![1.0f32, 2.0, 3.0, 4.0];
        m.apply(&mut logits);
        assert_eq!(logits[2], 3.0);
        assert!(logits[0].is_infinite() && logits[1].is_infinite() && logits[3].is_infinite());
    }

    #[test]
    fn union() {
        let mut a = TokenMask::none(10);
        a.allow(1);
        let mut b = TokenMask::none(10);
        b.allow(8);
        a.union(&b);
        assert!(a.allowed(1) && a.allowed(8));
        assert_eq!(a.count(), 2);
    }

    /// Random mask of `size` bits at roughly `density` fill.
    fn random_mask(rng: &mut Rng, size: usize, density: f64) -> TokenMask {
        let mut m = TokenMask::none(size);
        for t in 0..size {
            if rng.chance(density) {
                m.allow(t as TokenId);
            }
        }
        m
    }

    /// The pre-kernel scalar apply: one `allowed()` probe per logit.
    fn scalar_apply(mask: &TokenMask, logits: &mut [f32]) {
        for (i, l) in logits.iter_mut().enumerate() {
            if !mask.allowed(i as TokenId) {
                *l = f32::NEG_INFINITY;
            }
        }
    }

    #[test]
    fn wordwise_apply_matches_scalar_reference() {
        let mut rng = Rng::new(42);
        for size in [1usize, 63, 64, 65, 127, 128, 130, 512] {
            for density in [0.0, 0.3, 1.0] {
                let m = random_mask(&mut rng, size, density);
                // Logits same length, longer, and shorter than the mask.
                for len in [size, size + 7, size.saturating_sub(3)] {
                    let base: Vec<f32> = (0..len).map(|i| i as f32 * 0.5 - 3.0).collect();
                    let mut fast = base.clone();
                    let mut slow = base;
                    m.apply(&mut fast);
                    scalar_apply(&m, &mut slow);
                    assert_eq!(fast, slow, "size {size} density {density} len {len}");
                }
            }
        }
    }

    #[test]
    fn intersect_and_not_match_scalar_reference() {
        let mut rng = Rng::new(7);
        for size in [63usize, 64, 65, 127, 128] {
            let a = random_mask(&mut rng, size, 0.5);
            let b = random_mask(&mut rng, size, 0.5);

            let mut and = a.clone();
            and.intersect(&b);
            let mut andnot = a.clone();
            andnot.and_not(&b);
            for t in 0..size as TokenId {
                assert_eq!(and.allowed(t), a.allowed(t) && b.allowed(t), "intersect bit {t}");
                assert_eq!(andnot.allowed(t), a.allowed(t) && !b.allowed(t), "and_not bit {t}");
            }
            assert_eq!(a.count_intersect(&b), and.count(), "count_intersect size {size}");
            // Wordwise ops never create ghost bits — results stay valid
            // cache keys / serializable.
            assert!(TokenMask::from_words(size, and.words().to_vec()).is_ok());
            assert!(TokenMask::from_words(size, andnot.words().to_vec()).is_ok());
        }
    }

    #[test]
    fn iter_parity_with_eager_per_word_expansion() {
        // The old iter() expanded each word into a Vec inside flat_map;
        // the word-cursor iterator must yield the identical sequence.
        fn old_iter(m: &TokenMask) -> Vec<TokenId> {
            m.words()
                .iter()
                .enumerate()
                .flat_map(|(wi, &w)| {
                    let mut w = w;
                    let mut out = Vec::with_capacity(w.count_ones() as usize);
                    while w != 0 {
                        let b = w.trailing_zeros();
                        out.push((wi * 64 + b as usize) as TokenId);
                        w &= w - 1;
                    }
                    out
                })
                .collect()
        }
        let mut rng = Rng::new(11);
        for size in [1usize, 63, 64, 65, 127, 128, 513] {
            for density in [0.0, 0.05, 0.5, 1.0] {
                let m = random_mask(&mut rng, size, density);
                assert_eq!(m.iter().collect::<Vec<_>>(), old_iter(&m), "size {size}");
            }
        }
        // Empty-words edge: iterator over none() terminates immediately.
        assert_eq!(TokenMask::none(200).iter().count(), 0);
    }
}
