//! Count-based speculative decoding (§3.6).
//!
//! Conditioned on the joint scanner/parser state `(α, β)` (exposed by
//! [`super::Checker::state_key`]), a count model estimates
//!
//! ```text
//! P(l | α, β) = #{LLM chose l in state (α, β)} / #{reached state (α, β)}
//! ```
//!
//! Proposals: while the argmax token's estimated probability is ≥ the
//! confidence threshold, propose it and advance a *cloned* decoder — up to
//! `s` tokens per step. The LLM then validates the whole proposal with one
//! chunked forward pass; the accepted prefix is committed, the rest
//! discarded (no backtracking, as in Chen et al. 2023).
//!
//! Because counts are keyed on parser state, only grammar-legal tokens are
//! ever learned — structured formats (schema-driven JSON, XML) become
//! near-deterministic and speculation shines; free-form C does not (§4.3).

use super::decoder::DominoDecoder;
use super::Checker;
use crate::util::binio::{ByteReader, ByteWriter};
use crate::TokenId;
use std::collections::HashMap;

/// Minimum proposal length worth a chunked verification call.
pub const MIN_PROPOSAL: usize = 3;

/// Longest continuation n-gram recorded per state (the draft lane's
/// multi-token lookups; see [`crate::domino::draft`]).
pub const NGRAM_N: usize = 3;

/// Count table for `P(l | α, β)`.
#[derive(Default, Clone)]
pub struct SpeculativeModel {
    /// state key → (total visits, per-token counts).
    counts: HashMap<u64, StateCounts>,
    /// Confidence threshold τ: propose only while `P ≥ τ`.
    pub threshold: f64,
    /// Learning enabled? (The paper freezes priors after warmup.)
    pub frozen: bool,
}

#[derive(Default, Clone)]
struct StateCounts {
    total: u64,
    tokens: HashMap<TokenId, u64>,
    /// Multi-token continuations (length 2..=[`NGRAM_N`]) observed from
    /// this state — the draft lane proposes whole grams in one lookup
    /// instead of re-chaining per-token predictions.
    grams: HashMap<Box<[TokenId]>, u64>,
}

impl SpeculativeModel {
    pub fn new(threshold: f64) -> SpeculativeModel {
        SpeculativeModel { counts: HashMap::new(), threshold, frozen: false }
    }

    /// Record that the LLM chose `token` in state `key`.
    pub fn observe(&mut self, key: u64, token: TokenId) {
        if self.frozen {
            return;
        }
        let sc = self.counts.entry(key).or_default();
        sc.total += 1;
        *sc.tokens.entry(token).or_insert(0) += 1;
    }

    /// Record that the LLM produced the multi-token continuation `gram`
    /// (length 2..=[`NGRAM_N`]) from state `key`. Unigrams go through
    /// [`SpeculativeModel::observe`], which also counts the state visit.
    pub fn observe_gram(&mut self, key: u64, gram: &[TokenId]) {
        if self.frozen || gram.len() < 2 || gram.len() > NGRAM_N {
            return;
        }
        let sc = self.counts.entry(key).or_default();
        *sc.grams.entry(gram.into()).or_insert(0) += 1;
    }

    /// Best prediction for state `key`, if confident enough.
    pub fn predict(&self, key: u64) -> Option<TokenId> {
        let sc = self.counts.get(&key)?;
        if sc.total == 0 {
            return None;
        }
        let (&tok, &cnt) = sc.tokens.iter().max_by_key(|(_, &c)| c)?;
        ((cnt as f64 / sc.total as f64) >= self.threshold).then_some(tok)
    }

    /// The most frequent next token for state `key`, regardless of the
    /// confidence threshold (the draft lane's greedy fallback; ties break
    /// on the smaller token id for determinism).
    pub fn argmax(&self, key: u64) -> Option<TokenId> {
        let sc = self.counts.get(&key)?;
        sc.tokens.iter().max_by_key(|(&t, &c)| (c, std::cmp::Reverse(t))).map(|(&t, _)| t)
    }

    /// The most frequent multi-token continuation observed from state
    /// `key` and its count (ties break on the longer, then
    /// lexicographically smaller gram for determinism).
    pub fn best_gram(&self, key: u64) -> Option<(&[TokenId], u64)> {
        let sc = self.counts.get(&key)?;
        sc.grams
            .iter()
            .max_by(|(ga, ca), (gb, cb)| {
                ca.cmp(cb)
                    .then(ga.len().cmp(&gb.len()))
                    .then_with(|| gb.as_ref().cmp(ga.as_ref()))
            })
            .map(|(g, &c)| (g.as_ref(), c))
    }

    /// Times state `key` has been visited (observed) so far.
    pub fn visits(&self, key: u64) -> u64 {
        self.counts.get(&key).map_or(0, |sc| sc.total)
    }

    pub fn num_states(&self) -> usize {
        self.counts.len()
    }

    /// Record one committed step for the draft lane: the unigram count plus
    /// every n-gram window (length 2..=[`NGRAM_N`]) ending at `token`.
    /// `hist` is the caller's rolling `(state key, token)` window; a step
    /// with no state key breaks the chain (grams must not span it).
    pub fn observe_step(
        &mut self,
        hist: &mut Vec<(u64, TokenId)>,
        key: Option<u64>,
        token: TokenId,
    ) {
        let Some(key) = key else {
            hist.clear();
            return;
        };
        self.observe(key, token);
        hist.push((key, token));
        for n in 2..=NGRAM_N {
            if hist.len() >= n {
                let start = hist.len() - n;
                let gram: Vec<TokenId> = hist[start..].iter().map(|&(_, t)| t).collect();
                self.observe_gram(hist[start].0, &gram);
            }
        }
        if hist.len() > NGRAM_N {
            hist.remove(0);
        }
    }

    /// Propose up to `s` tokens from `decoder`'s current state by chaining
    /// confident predictions through a cloned decoder.
    ///
    /// Proposals shorter than [`MIN_PROPOSAL`] are suppressed: verifying a
    /// chunk costs about one model call, so a 1–2 token proposal cannot
    /// pay for itself.
    pub fn propose(&self, decoder: &DominoDecoder, s: usize) -> Vec<TokenId> {
        let mut clone = decoder.clone();
        let mut out = Vec::new();
        for _ in 0..s {
            let Some(key) = clone.state_key() else { break };
            let Some(tok) = self.predict(key) else { break };
            // Only propose grammar-legal tokens (they should be legal by
            // construction — counts are keyed on parser state — but a hash
            // collision must not poison the proposal).
            if clone.advance(tok).is_err() {
                break;
            }
            out.push(tok);
        }
        if out.len() < MIN_PROPOSAL {
            out.clear();
        }
        out
    }

    /// Serialize the count tables (threshold included, `frozen`
    /// deliberately not — a warm-started server keeps learning). The
    /// encoding is the artifact store's little-endian framing; see
    /// [`crate::constraint::artifact`] for the enclosing record.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u64(self.threshold.to_bits());
        // Sort for byte-stable output (HashMap order is per-process).
        let mut keys: Vec<&u64> = self.counts.keys().collect();
        keys.sort();
        w.u32(keys.len() as u32);
        for &key in keys {
            let sc = &self.counts[&key];
            w.u64(key);
            w.u64(sc.total);
            let mut toks: Vec<_> = sc.tokens.iter().collect();
            toks.sort();
            w.u32(toks.len() as u32);
            for (&t, &c) in toks {
                w.u32(t);
                w.u64(c);
            }
            let mut grams: Vec<_> = sc.grams.iter().collect();
            grams.sort();
            w.u32(grams.len() as u32);
            for (g, &c) in grams {
                w.u32(g.len() as u32);
                for &t in g.iter() {
                    w.u32(t);
                }
                w.u64(c);
            }
        }
        w.into_inner()
    }

    /// Inverse of [`SpeculativeModel::to_bytes`]; fails cleanly on
    /// truncated or malformed input (the caller falls back to a cold
    /// prior).
    pub fn from_bytes(bytes: &[u8]) -> crate::Result<SpeculativeModel> {
        let mut r = ByteReader::new(bytes);
        let threshold = f64::from_bits(r.u64()?);
        anyhow::ensure!(threshold.is_finite(), "non-finite prior threshold");
        let n_states = r.u32()? as usize;
        let mut counts = HashMap::with_capacity(n_states);
        for _ in 0..n_states {
            let key = r.u64()?;
            let total = r.u64()?;
            let n_toks = r.u32()? as usize;
            let mut tokens = HashMap::with_capacity(n_toks);
            for _ in 0..n_toks {
                let t = r.u32()?;
                let c = r.u64()?;
                tokens.insert(t, c);
            }
            let n_grams = r.u32()? as usize;
            let mut grams = HashMap::with_capacity(n_grams);
            for _ in 0..n_grams {
                let len = r.u32()? as usize;
                anyhow::ensure!(len >= 2 && len <= NGRAM_N, "gram length {len} out of range");
                let mut g = Vec::with_capacity(len);
                for _ in 0..len {
                    g.push(r.u32()?);
                }
                let c = r.u64()?;
                grams.insert(g.into_boxed_slice(), c);
            }
            counts.insert(key, StateCounts { total, tokens, grams });
        }
        r.expect_end()?;
        Ok(SpeculativeModel { counts, threshold, frozen: false })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domino::decoder::{Engine, Lookahead};
    use crate::grammar::builtin::fixed_template;
    use crate::tokenizer;
    use std::sync::Arc;

    #[test]
    fn counts_and_threshold() {
        let mut m = SpeculativeModel::new(0.6);
        for _ in 0..7 {
            m.observe(42, 5);
        }
        for _ in 0..3 {
            m.observe(42, 6);
        }
        assert_eq!(m.predict(42), Some(5)); // 0.7 ≥ 0.6
        m.threshold = 0.8;
        assert_eq!(m.predict(42), None);
        assert_eq!(m.predict(99), None); // unseen state
    }

    #[test]
    fn ngram_continuations_and_argmax() {
        let mut m = SpeculativeModel::new(0.75);
        m.observe(7, 1);
        m.observe(7, 1);
        m.observe(7, 2);
        // 2/3 < 0.75: below the speculation threshold, but the draft
        // lane's greedy argmax still has a best guess.
        assert_eq!(m.predict(7), None);
        assert_eq!(m.argmax(7), Some(1));
        assert_eq!(m.visits(7), 3);
        assert_eq!(m.best_gram(7), None);
        m.observe_gram(7, &[1, 4]);
        m.observe_gram(7, &[1, 4, 9]);
        m.observe_gram(7, &[1, 4, 9]);
        assert_eq!(m.best_gram(7), Some((&[1, 4, 9][..], 2)));
        // Out-of-range grams are ignored.
        m.observe_gram(7, &[1]);
        m.observe_gram(7, &[1, 2, 3, 4]);
        assert_eq!(m.best_gram(7), Some((&[1, 4, 9][..], 2)));
    }

    #[test]
    fn prior_bytes_round_trip() {
        let mut m = SpeculativeModel::new(0.6);
        for _ in 0..5 {
            m.observe(11, 3);
        }
        m.observe(11, 4);
        m.observe(22, 8);
        m.observe_gram(11, &[3, 3]);
        m.observe_gram(11, &[3, 3, 4]);
        let bytes = m.to_bytes();
        let got = SpeculativeModel::from_bytes(&bytes).unwrap();
        assert_eq!(got.threshold, 0.6);
        assert_eq!(got.num_states(), 2);
        assert_eq!(got.visits(11), 6);
        assert_eq!(got.argmax(11), Some(3));
        assert_eq!(got.predict(11), Some(3));
        let norm = |o: Option<(&[TokenId], u64)>| o.map(|(g, c)| (g.to_vec(), c));
        assert_eq!(norm(got.best_gram(11)), norm(m.best_gram(11)));
        // Serialization is byte-stable (sorted tables).
        assert_eq!(got.to_bytes(), bytes);
        // Truncation fails cleanly.
        assert!(SpeculativeModel::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(SpeculativeModel::from_bytes(&[]).is_err());
    }

    #[test]
    fn frozen_stops_learning() {
        let mut m = SpeculativeModel::new(0.5);
        m.observe(1, 2);
        m.frozen = true;
        m.observe(1, 3);
        m.observe(1, 3);
        assert_eq!(m.predict(1), Some(2));
    }

    #[test]
    fn proposes_deterministic_template_prefix() {
        // On the fixed-template grammar the opening tokens are forced;
        // after observing one generation, the model should re-propose the
        // same prefix.
        let vocab = Arc::new(tokenizer::bpe::synthetic_json_vocab(512));
        let eng = Engine::compile(fixed_template(), vocab.clone()).unwrap();
        let text = "{\"id\"";
        let ids = vocab.encode(text.as_bytes());

        let mut m = SpeculativeModel::new(0.5);
        let mut d = crate::domino::DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        for &id in &ids {
            let key = d.state_key().unwrap();
            m.observe(key, id);
            d.advance(id).unwrap();
        }
        // Fresh decoder: proposal should replay the observed prefix.
        let d2 = crate::domino::DominoDecoder::new(eng, Lookahead::Infinite);
        let prop = m.propose(&d2, 8);
        assert_eq!(&prop[..], &ids[..prop.len().min(ids.len())]);
        assert!(!prop.is_empty());
    }
}
