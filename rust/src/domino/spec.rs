//! Count-based speculative decoding (§3.6).
//!
//! Conditioned on the joint scanner/parser state `(α, β)` (exposed by
//! [`super::Checker::state_key`]), a count model estimates
//!
//! ```text
//! P(l | α, β) = #{LLM chose l in state (α, β)} / #{reached state (α, β)}
//! ```
//!
//! Proposals: while the argmax token's estimated probability is ≥ the
//! confidence threshold, propose it and advance a *cloned* decoder — up to
//! `s` tokens per step. The LLM then validates the whole proposal with one
//! chunked forward pass; the accepted prefix is committed, the rest
//! discarded (no backtracking, as in Chen et al. 2023).
//!
//! Because counts are keyed on parser state, only grammar-legal tokens are
//! ever learned — structured formats (schema-driven JSON, XML) become
//! near-deterministic and speculation shines; free-form C does not (§4.3).

use super::decoder::DominoDecoder;
use super::Checker;
use crate::TokenId;
use std::collections::HashMap;

/// Minimum proposal length worth a chunked verification call.
pub const MIN_PROPOSAL: usize = 3;

/// Count table for `P(l | α, β)`.
#[derive(Default, Clone)]
pub struct SpeculativeModel {
    /// state key → (total visits, per-token counts).
    counts: HashMap<u64, StateCounts>,
    /// Confidence threshold τ: propose only while `P ≥ τ`.
    pub threshold: f64,
    /// Learning enabled? (The paper freezes priors after warmup.)
    pub frozen: bool,
}

#[derive(Default, Clone)]
struct StateCounts {
    total: u64,
    tokens: HashMap<TokenId, u64>,
}

impl SpeculativeModel {
    pub fn new(threshold: f64) -> SpeculativeModel {
        SpeculativeModel { counts: HashMap::new(), threshold, frozen: false }
    }

    /// Record that the LLM chose `token` in state `key`.
    pub fn observe(&mut self, key: u64, token: TokenId) {
        if self.frozen {
            return;
        }
        let sc = self.counts.entry(key).or_default();
        sc.total += 1;
        *sc.tokens.entry(token).or_insert(0) += 1;
    }

    /// Best prediction for state `key`, if confident enough.
    pub fn predict(&self, key: u64) -> Option<TokenId> {
        let sc = self.counts.get(&key)?;
        if sc.total == 0 {
            return None;
        }
        let (&tok, &cnt) = sc.tokens.iter().max_by_key(|(_, &c)| c)?;
        ((cnt as f64 / sc.total as f64) >= self.threshold).then_some(tok)
    }

    pub fn num_states(&self) -> usize {
        self.counts.len()
    }

    /// Propose up to `s` tokens from `decoder`'s current state by chaining
    /// confident predictions through a cloned decoder.
    ///
    /// Proposals shorter than [`MIN_PROPOSAL`] are suppressed: verifying a
    /// chunk costs about one model call, so a 1–2 token proposal cannot
    /// pay for itself.
    pub fn propose(&self, decoder: &DominoDecoder, s: usize) -> Vec<TokenId> {
        let mut clone = decoder.clone();
        let mut out = Vec::new();
        for _ in 0..s {
            let Some(key) = clone.state_key() else { break };
            let Some(tok) = self.predict(key) else { break };
            // Only propose grammar-legal tokens (they should be legal by
            // construction — counts are keyed on parser state — but a hash
            // collision must not poison the proposal).
            if clone.advance(tok).is_err() {
                break;
            }
            out.push(tok);
        }
        if out.len() < MIN_PROPOSAL {
            out.clear();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domino::decoder::{Engine, Lookahead};
    use crate::grammar::builtin::fixed_template;
    use crate::tokenizer;
    use std::sync::Arc;

    #[test]
    fn counts_and_threshold() {
        let mut m = SpeculativeModel::new(0.6);
        for _ in 0..7 {
            m.observe(42, 5);
        }
        for _ in 0..3 {
            m.observe(42, 6);
        }
        assert_eq!(m.predict(42), Some(5)); // 0.7 ≥ 0.6
        m.threshold = 0.8;
        assert_eq!(m.predict(42), None);
        assert_eq!(m.predict(99), None); // unseen state
    }

    #[test]
    fn frozen_stops_learning() {
        let mut m = SpeculativeModel::new(0.5);
        m.observe(1, 2);
        m.frozen = true;
        m.observe(1, 3);
        m.observe(1, 3);
        assert_eq!(m.predict(1), Some(2));
    }

    #[test]
    fn proposes_deterministic_template_prefix() {
        // On the fixed-template grammar the opening tokens are forced;
        // after observing one generation, the model should re-propose the
        // same prefix.
        let vocab = Arc::new(tokenizer::bpe::synthetic_json_vocab(512));
        let eng = Engine::compile(fixed_template(), vocab.clone()).unwrap();
        let text = "{\"id\"";
        let ids = vocab.encode(text.as_bytes());

        let mut m = SpeculativeModel::new(0.5);
        let mut d = crate::domino::DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        for &id in &ids {
            let key = d.state_key().unwrap();
            m.observe(key, id);
            d.advance(id).unwrap();
        }
        // Fresh decoder: proposal should replay the observed prefix.
        let d2 = crate::domino::DominoDecoder::new(eng, Lookahead::Infinite);
        let prop = m.propose(&d2, 8);
        assert_eq!(&prop[..], &ids[..prop.len().min(ids.len())]);
        assert!(!prop.is_empty());
    }
}
