//! The constrained decoding loop (Algorithm 1) and its speculative variant
//! (§3.6), with prompt-boundary token healing (§3.5).
//!
//! Two cost modes:
//! * **FullMask** — compute `C.mask()` every step, apply, sample
//!   (Algorithm 1 verbatim).
//! * **Opportunistic** — sample from the raw logits first and only compute
//!   the mask when the checker rejects the proposal (§3.5 "opportunistic
//!   masking"; llama.cpp's default).
//!
//! Both use *lazy coupling* for sampling: the unconstrained proposal is
//! kept whenever it is legal, so a minimally-invasive checker reproduces
//! unconstrained output exactly (Def. 2.1) and `interventions` counts
//! every divergence.
//!
//! ## Prompt healing
//!
//! A prompt's own tokenization ends at an arbitrary token boundary the
//! model may never have seen ("all other boundaries are embedded
//! seamlessly into the grammar, [healing] is only relevant for the first
//! boundary with the prompt" — §3.5). [`Prompt::healed`] strips the
//! trailing tokens and re-emits their bytes as a *forced byte prefix*:
//! generation starts a few bytes early, constrained to reproduce the
//! stripped text, and naturally crosses the boundary with the model's own
//! preferred (possibly bridging) tokens.

use super::draft::{adaptive_k, draft_from_prior};
use super::spec::SpeculativeModel;
use super::{Checker, DominoDecoder, TokenMask};
use crate::runtime::sampler::{decode, log_prob, Sampling};
use crate::runtime::LmSession;
use crate::tokenizer::{Vocab, EOS_ID};
use crate::util::Rng;
use crate::TokenId;
use anyhow::bail;

/// Masking cost mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskMode {
    FullMask,
    Opportunistic,
}

/// Generation configuration.
#[derive(Clone, Copy, Debug)]
pub struct GenConfig {
    pub max_tokens: usize,
    pub sampling: Sampling,
    pub mode: MaskMode,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { max_tokens: 128, sampling: Sampling::Greedy, mode: MaskMode::Opportunistic }
    }
}

/// A (possibly healed) prompt.
#[derive(Clone, Debug, Default)]
pub struct Prompt {
    pub ids: Vec<TokenId>,
    /// Bytes generation must reproduce before free decoding starts.
    pub forced: Vec<u8>,
}

/// Heal back at least this many bytes (longer than most merged tokens, so
/// the context never ends mid-mega-token).
const HEAL_BYTES: usize = 6;

impl Prompt {
    pub fn plain(vocab: &Vocab, text: &str) -> Prompt {
        Prompt { ids: vocab.encode(text.as_bytes()), forced: Vec::new() }
    }

    pub fn from_ids(ids: Vec<TokenId>) -> Prompt {
        Prompt { ids, forced: Vec::new() }
    }

    /// Token healing: strip trailing tokens until ≥ [`HEAL_BYTES`] bytes
    /// are forced. At least one prompt token is kept (the LM session needs
    /// a non-empty context).
    pub fn healed(vocab: &Vocab, text: &str) -> Prompt {
        let mut ids = vocab.encode(text.as_bytes());
        let mut forced: Vec<u8> = Vec::new();
        while forced.len() < HEAL_BYTES && ids.len() > 1 {
            let last = ids.pop().expect("len > 1");
            let mut b = vocab.token_bytes(last).to_vec();
            b.extend_from_slice(&forced);
            forced = b;
        }
        Prompt { ids, forced }
    }
}

/// Outcome of one generation.
#[derive(Clone, Debug, Default)]
pub struct GenResult {
    /// Committed generation-phase tokens (healing tokens included — their
    /// leading bytes reproduce stripped prompt text).
    pub tokens: Vec<TokenId>,
    /// The *output* text bytes (prompt text excluded even when a healing
    /// token straddles the boundary).
    pub text_bytes: Vec<u8>,
    /// Sum of `log P(token)` under the *unmasked* model — perplexity =
    /// `exp(-logprob_sum / tokens.len())`.
    pub logprob_sum: f64,
    /// Steps where the mask rejected the model's proposal (invasiveness).
    pub interventions: usize,
    /// Model forward calls (chunked calls count once).
    pub model_calls: usize,
    /// Total full-mask computations performed.
    pub masks_computed: usize,
    /// Proposal statistics (zero unless [`generate_speculative`] or
    /// [`generate_drafted`]): tokens proposed ahead of the model and the
    /// accepted prefix total across all proposals.
    pub spec_proposed: usize,
    pub spec_accepted: usize,
    /// True iff generation ended with a legal EOS (not the length cap).
    pub stopped: bool,
}

impl GenResult {
    pub fn perplexity(&self) -> f64 {
        if self.tokens.is_empty() {
            return f64::NAN;
        }
        (-self.logprob_sum / self.tokens.len() as f64).exp()
    }

    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.text_bytes).into_owned()
    }
}

/// Shared state of one decoding run.
struct Loop<'a> {
    lm: &'a mut dyn LmSession,
    checker: &'a mut dyn Checker,
    vocab: &'a Vocab,
    cfg: &'a GenConfig,
    rng: &'a mut Rng,
    res: GenResult,
    logits: Vec<f32>,
}

impl<'a> Loop<'a> {
    /// Consume the healed prompt suffix: pick (sampled) tokens compatible
    /// with the forced bytes, route overhangs into the checker + output.
    fn heal(&mut self, forced: &[u8]) -> crate::Result<()> {
        let mut forced = forced.to_vec();
        while !forced.is_empty() {
            let mut mask = TokenMask::none(self.vocab.len());
            for id in 0..self.vocab.len() as TokenId {
                let b = self.vocab.token_bytes(id);
                if b.is_empty() {
                    continue;
                }
                let ok = if b.len() <= forced.len() {
                    forced.starts_with(b)
                } else {
                    b.starts_with(&forced) && self.checker.check_bytes(&b[forced.len()..])
                };
                if ok {
                    mask.allow(id);
                }
            }
            if mask.is_empty() {
                bail!("prompt healing deadlocked on {:?}", String::from_utf8_lossy(&forced));
            }
            let mut masked = self.logits.clone();
            mask.apply(&mut masked);
            let t = decode(&masked, self.cfg.sampling, self.rng);
            self.res.logprob_sum += log_prob(&self.logits, t);
            let b = self.vocab.token_bytes(t).to_vec();
            if b.len() <= forced.len() {
                forced.drain(..b.len());
            } else {
                let overhang = b[forced.len()..].to_vec();
                forced.clear();
                self.checker.advance_bytes(&overhang)?;
                self.res.text_bytes.extend_from_slice(&overhang);
            }
            self.res.tokens.push(t);
            self.logits = self.lm.append(&[t])?;
            self.res.model_calls += 1;
        }
        Ok(())
    }

    /// One constrained choice from the current logits (lazy coupling).
    /// Returns `None` on a dead end.
    fn choose(&mut self) -> Option<TokenId> {
        match self.cfg.mode {
            MaskMode::Opportunistic => {
                let proposal = decode(&self.logits, self.cfg.sampling, self.rng);
                if self.checker.check_token(proposal) {
                    Some(proposal)
                } else {
                    self.res.interventions += 1;
                    let mask = self.checker.compute_mask();
                    self.res.masks_computed += 1;
                    if mask.is_empty() {
                        return None;
                    }
                    let mut masked = self.logits.clone();
                    mask.apply(&mut masked);
                    Some(decode(&masked, self.cfg.sampling, self.rng))
                }
            }
            MaskMode::FullMask => {
                let mask = self.checker.compute_mask();
                self.res.masks_computed += 1;
                if mask.is_empty() {
                    return None;
                }
                let proposal = decode(&self.logits, self.cfg.sampling, self.rng);
                if mask.allowed(proposal) {
                    Some(proposal)
                } else {
                    self.res.interventions += 1;
                    let mut masked = self.logits.clone();
                    mask.apply(&mut masked);
                    Some(decode(&masked, self.cfg.sampling, self.rng))
                }
            }
        }
    }

    /// Commit `chosen`; returns true when generation is finished.
    fn commit(&mut self, chosen: TokenId) -> crate::Result<bool> {
        self.res.logprob_sum += log_prob(&self.logits, chosen);
        if chosen == EOS_ID {
            self.res.stopped = true;
            return Ok(true);
        }
        self.checker.advance(chosen)?;
        self.res.tokens.push(chosen);
        self.res.text_bytes.extend_from_slice(self.vocab.token_bytes(chosen));
        self.logits = self.lm.append(&[chosen])?;
        self.res.model_calls += 1;
        Ok(self.res.tokens.len() >= self.cfg.max_tokens)
    }
}

/// Run Algorithm 1 after `prompt` (healing phase included).
pub fn generate(
    lm: &mut dyn LmSession,
    checker: &mut dyn Checker,
    vocab: &Vocab,
    prompt: &Prompt,
    cfg: &GenConfig,
    rng: &mut Rng,
) -> crate::Result<GenResult> {
    let logits = lm.append(&prompt.ids)?;
    let mut l = Loop { lm, checker, vocab, cfg, rng, res: GenResult::default(), logits };
    l.res.model_calls += 1;
    l.heal(&prompt.forced)?;
    while l.res.tokens.len() < cfg.max_tokens {
        let Some(chosen) = l.choose() else { break };
        if l.commit(chosen)? {
            break;
        }
    }
    Ok(l.res)
}

/// §3.6: the speculative loop. Proposals come from the count model
/// conditioned on `(α, β)`; a single chunked forward pass verifies them.
#[allow(clippy::too_many_arguments)]
pub fn generate_speculative(
    lm: &mut dyn LmSession,
    decoder: &mut DominoDecoder,
    spec: &mut SpeculativeModel,
    vocab: &Vocab,
    prompt: &Prompt,
    s: usize,
    cfg: &GenConfig,
    rng: &mut Rng,
) -> crate::Result<GenResult> {
    let mut res = GenResult::default();
    let mut logits = lm.append(&prompt.ids)?;
    res.model_calls += 1;

    // Healing phase (plain, unspeculated).
    {
        let mut l = Loop { lm, checker: decoder, vocab, cfg, rng, res, logits };
        l.heal(&prompt.forced)?;
        res = l.res;
        logits = l.logits;
    }

    'outer: while res.tokens.len() < cfg.max_tokens {
        let proposal = spec.propose(decoder, s);
        if proposal.is_empty() {
            // One plain opportunistic step; teach the count model.
            let chosen = {
                let p = decode(&logits, cfg.sampling, rng);
                if decoder.check_token(p) {
                    p
                } else {
                    res.interventions += 1;
                    let mask = decoder.compute_mask();
                    res.masks_computed += 1;
                    if mask.is_empty() {
                        break;
                    }
                    let mut masked = logits.clone();
                    mask.apply(&mut masked);
                    decode(&masked, cfg.sampling, rng)
                }
            };
            res.logprob_sum += log_prob(&logits, chosen);
            if chosen == EOS_ID {
                res.stopped = true;
                break;
            }
            if let Some(key) = decoder.state_key() {
                spec.observe(key, chosen);
            }
            decoder.advance(chosen)?;
            res.tokens.push(chosen);
            res.text_bytes.extend_from_slice(vocab.token_bytes(chosen));
            logits = lm.append(&[chosen])?;
            res.model_calls += 1;
            continue;
        }

        // One chunked pass scores the whole proposal.
        res.spec_proposed += proposal.len();
        let rows = lm.append_scored(&proposal)?;
        res.model_calls += 1;
        let mut accepted = 0usize;
        let mut cur = logits;
        for (i, &p) in proposal.iter().enumerate() {
            let choice = {
                let c = decode(&cur, cfg.sampling, rng);
                if decoder.check_token(c) {
                    c
                } else {
                    res.interventions += 1;
                    res.masks_computed += 1;
                    let mask = decoder.compute_mask();
                    if mask.is_empty() {
                        break;
                    }
                    let mut masked = cur.clone();
                    mask.apply(&mut masked);
                    decode(&masked, cfg.sampling, rng)
                }
            };
            if choice == p {
                res.logprob_sum += log_prob(&cur, p);
                if let Some(key) = decoder.state_key() {
                    spec.observe(key, p);
                }
                decoder.advance(p)?;
                res.tokens.push(p);
                res.text_bytes.extend_from_slice(vocab.token_bytes(p));
                res.spec_accepted += 1;
                accepted += 1;
                cur = rows[i].clone();
                if res.tokens.len() >= cfg.max_tokens {
                    lm.rollback(proposal.len() - accepted)?;
                    break 'outer;
                }
            } else {
                // Reject the rest; commit the model's own choice instead.
                lm.rollback(proposal.len() - accepted)?;
                res.logprob_sum += log_prob(&cur, choice);
                if choice == EOS_ID {
                    res.stopped = true;
                    break 'outer;
                }
                if let Some(key) = decoder.state_key() {
                    spec.observe(key, choice);
                }
                decoder.advance(choice)?;
                res.tokens.push(choice);
                res.text_bytes.extend_from_slice(vocab.token_bytes(choice));
                logits = lm.append(&[choice])?;
                res.model_calls += 1;
                continue 'outer;
            }
        }
        logits = cur;
    }
    Ok(res)
}

/// The draft lane's scalar reference path: multi-token proposals chained
/// from the prior's n-gram continuation counts, grammar-pruned *while
/// built* (`prune` = prune-before-verify; false gives the
/// prune-after-verify comparison ordering), verified by one chunked pass
/// with longest-accepted-prefix adoption. Proposal length adapts online to
/// the run's acceptance rate ([`adaptive_k`]), so a cold prior degrades to
/// K=1. Token-identical to [`generate`] under the same seed: every
/// committed token is re-derived from the model's own logits
/// (acceptance-or-correction, never a changed distribution).
#[allow(clippy::too_many_arguments)]
pub fn generate_drafted(
    lm: &mut dyn LmSession,
    decoder: &mut DominoDecoder,
    spec: &mut SpeculativeModel,
    vocab: &Vocab,
    prompt: &Prompt,
    k_max: usize,
    prune: bool,
    cfg: &GenConfig,
    rng: &mut Rng,
) -> crate::Result<GenResult> {
    let mut res = GenResult::default();
    let mut logits = lm.append(&prompt.ids)?;
    res.model_calls += 1;

    // Healing phase (plain, undrafted).
    {
        let mut l = Loop { lm, checker: decoder, vocab, cfg, rng, res, logits };
        l.heal(&prompt.forced)?;
        res = l.res;
        logits = l.logits;
    }

    let mut hist: Vec<(u64, TokenId)> = Vec::new();
    let mut accept_ewma = 0.0f64;
    while res.tokens.len() < cfg.max_tokens {
        let k = adaptive_k(accept_ewma, k_max);
        let proposal = draft_from_prior(spec, decoder, k, prune, |clone, t| clone.check_token(t));
        if proposal.is_empty() {
            // One plain opportunistic step; teach the prior.
            let chosen = {
                let p = decode(&logits, cfg.sampling, rng);
                if decoder.check_token(p) {
                    p
                } else {
                    res.interventions += 1;
                    let mask = decoder.compute_mask();
                    res.masks_computed += 1;
                    if mask.is_empty() {
                        break;
                    }
                    let mut masked = logits.clone();
                    mask.apply(&mut masked);
                    decode(&masked, cfg.sampling, rng)
                }
            };
            res.logprob_sum += log_prob(&logits, chosen);
            if chosen == EOS_ID {
                res.stopped = true;
                break;
            }
            spec.observe_step(&mut hist, decoder.state_key(), chosen);
            decoder.advance(chosen)?;
            res.tokens.push(chosen);
            res.text_bytes.extend_from_slice(vocab.token_bytes(chosen));
            logits = lm.append(&[chosen])?;
            res.model_calls += 1;
            continue;
        }

        // One chunked pass scores the whole proposal; adopt the longest
        // accepted prefix, then commit the model's own choice on mismatch.
        res.spec_proposed += proposal.len();
        let rows = lm.append_scored(&proposal)?;
        res.model_calls += 1;
        let mut accepted = 0usize;
        let mut correction: Option<TokenId> = None;
        let mut dead_end = false;
        let mut cur = logits;
        for (i, &p) in proposal.iter().enumerate() {
            let choice = {
                let c = decode(&cur, cfg.sampling, rng);
                if decoder.check_token(c) {
                    c
                } else {
                    res.interventions += 1;
                    res.masks_computed += 1;
                    let mask = decoder.compute_mask();
                    if mask.is_empty() {
                        dead_end = true;
                        break;
                    }
                    let mut masked = cur.clone();
                    mask.apply(&mut masked);
                    decode(&masked, cfg.sampling, rng)
                }
            };
            if choice != p {
                correction = Some(choice);
                break;
            }
            res.logprob_sum += log_prob(&cur, p);
            spec.observe_step(&mut hist, decoder.state_key(), p);
            decoder.advance(p)?;
            res.tokens.push(p);
            res.text_bytes.extend_from_slice(vocab.token_bytes(p));
            res.spec_accepted += 1;
            accepted += 1;
            cur = rows[i].clone();
            if res.tokens.len() >= cfg.max_tokens {
                break;
            }
        }
        accept_ewma = (accept_ewma + accepted as f64 / proposal.len() as f64) / 2.0;
        if accepted < proposal.len() {
            lm.rollback(proposal.len() - accepted)?;
        }
        logits = cur;
        if dead_end || res.tokens.len() >= cfg.max_tokens {
            break;
        }
        let Some(choice) = correction else { continue };
        res.logprob_sum += log_prob(&logits, choice);
        if choice == EOS_ID {
            res.stopped = true;
            break;
        }
        spec.observe_step(&mut hist, decoder.state_key(), choice);
        decoder.advance(choice)?;
        res.tokens.push(choice);
        res.text_bytes.extend_from_slice(vocab.token_bytes(choice));
        logits = lm.append(&[choice])?;
        res.model_calls += 1;
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domino::decoder::{Engine, Lookahead};
    use crate::domino::Unconstrained;
    use crate::grammar::builtin::json;
    use crate::runtime::mock::{json_mock, MockLm};
    use crate::util::Rng;

    fn setup() -> (std::sync::Arc<Engine>, std::sync::Arc<crate::runtime::mock::MockModel>) {
        let (vocab, model) = json_mock(512);
        let eng = Engine::compile(json(), vocab.clone()).unwrap();
        (eng, model)
    }

    #[test]
    fn unconstrained_vs_domino_greedy_identical() {
        // The mock LM was trained on valid JSON, so greedy unconstrained
        // output is valid — a minimally invasive decoder must match it
        // token for token (Def. 2.1).
        let (eng, model) = setup();
        let cfg = GenConfig { max_tokens: 64, sampling: Sampling::Greedy, mode: MaskMode::Opportunistic };
        let prompt = Prompt::default();

        let mut lm1 = MockLm::new(model.clone());
        let mut unc = Unconstrained::new(eng.vocab.len());
        let r1 = generate(&mut lm1, &mut unc, &eng.vocab, &prompt, &cfg, &mut Rng::new(1)).unwrap();

        let mut lm2 = MockLm::new(model);
        let mut dec = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        let r2 = generate(&mut lm2, &mut dec, &eng.vocab, &prompt, &cfg, &mut Rng::new(1)).unwrap();

        assert_eq!(r1.text(), r2.text(), "minimally invasive must equal unconstrained");
        assert_eq!(r2.interventions, 0);
        assert!(crate::util::Json::parse(&r2.text()).is_ok(), "{}", r2.text());
    }

    #[test]
    fn healed_prompt_reproduces_stripped_text() {
        // Healing must regenerate exactly the stripped prompt bytes before
        // free generation, whatever tokenization it picks.
        let (eng, model) = setup();
        let cfg = GenConfig { max_tokens: 24, sampling: Sampling::Greedy, mode: MaskMode::Opportunistic };
        let text = "{\"name\": \"John Doe\", \"ag";
        let healed = Prompt::healed(&eng.vocab, text);
        assert!(!healed.forced.is_empty());
        let plain = Prompt::plain(&eng.vocab, text);

        let mut lm = MockLm::new(model.clone());
        let mut dec = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        // Pre-advance the decoder over the *visible* prompt: the decoder
        // state tracks output only, and here the whole text is "output".
        dec.advance_bytes(&eng.vocab.decode(&healed.ids)).unwrap();
        let r = generate(&mut lm, &mut dec, &eng.vocab, &healed, &cfg, &mut Rng::new(3)).unwrap();
        // Output bytes continue the prompt text seamlessly.
        let full = format!("{}{}", String::from_utf8_lossy(&eng.vocab.decode(&healed.ids)), {
            // forced bytes are prompt text, so text_bytes excludes them.
            let mut s = String::from_utf8_lossy(&healed.forced).into_owned();
            s.push_str(&r.text());
            s
        });
        assert!(full.starts_with(text), "healed generation must reproduce {text:?}: {full:?}");
        let _ = plain;
    }

    #[test]
    fn speculative_output_matches_plain() {
        // Schema-driven grammar: the skeleton is deterministic enough for
        // proposals to clear MIN_PROPOSAL.
        let (vocab, model) = json_mock(512);
        let eng = Engine::compile(crate::grammar::builtin::gsm8k_schema(), vocab).unwrap();
        let cfg = GenConfig { max_tokens: 64, sampling: Sampling::Greedy, mode: MaskMode::Opportunistic };
        let prompt = Prompt::default();

        let mut lm1 = MockLm::new(model.clone());
        let mut d1 = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        let plain = generate(&mut lm1, &mut d1, &eng.vocab, &prompt, &cfg, &mut Rng::new(5)).unwrap();

        let mut spec = SpeculativeModel::new(0.5);
        {
            let mut lm = MockLm::new(model.clone());
            let mut d = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
            generate_speculative(&mut lm, &mut d, &mut spec, &eng.vocab, &prompt, 8, &cfg, &mut Rng::new(5))
                .unwrap();
        }
        spec.frozen = true;
        let mut lm2 = MockLm::new(model);
        let mut d2 = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        let specd = generate_speculative(
            &mut lm2, &mut d2, &mut spec, &eng.vocab, &prompt, 8, &cfg, &mut Rng::new(5),
        )
        .unwrap();

        assert_eq!(plain.tokens, specd.tokens);
        assert!(specd.spec_accepted > 0);
        assert!(specd.model_calls < plain.model_calls);
    }

    #[test]
    fn drafted_output_matches_plain() {
        // Grammar-pruned drafting must be token-identical to plain
        // decoding (acceptance-or-correction), in both prune orderings,
        // and a warm prior must save model calls.
        let (vocab, model) = json_mock(512);
        let eng = Engine::compile(crate::grammar::builtin::gsm8k_schema(), vocab).unwrap();
        let cfg = GenConfig { max_tokens: 64, sampling: Sampling::Greedy, mode: MaskMode::Opportunistic };
        let prompt = Prompt::default();

        let mut lm1 = MockLm::new(model.clone());
        let mut d1 = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        let plain = generate(&mut lm1, &mut d1, &eng.vocab, &prompt, &cfg, &mut Rng::new(5)).unwrap();

        let mut spec = SpeculativeModel::new(0.5);
        {
            let mut lm = MockLm::new(model.clone());
            let mut d = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
            generate_drafted(
                &mut lm, &mut d, &mut spec, &eng.vocab, &prompt, 8, true, &cfg, &mut Rng::new(5),
            )
            .unwrap();
        }
        spec.frozen = true;
        for prune in [true, false] {
            let mut lm = MockLm::new(model.clone());
            let mut d = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
            let drafted = generate_drafted(
                &mut lm, &mut d, &mut spec, &eng.vocab, &prompt, 8, prune, &cfg, &mut Rng::new(5),
            )
            .unwrap();
            assert_eq!(plain.tokens, drafted.tokens, "prune={prune}");
            assert!(drafted.spec_accepted > 0, "prune={prune}");
            assert!(drafted.model_calls < plain.model_calls, "prune={prune}");
        }
    }

    #[test]
    fn k0_distorts_output() {
        let (eng, model) = setup();
        let cfg = GenConfig { max_tokens: 64, sampling: Sampling::Greedy, mode: MaskMode::FullMask };
        let prompt = Prompt::default();

        let mut lm1 = MockLm::new(model.clone());
        let mut dinf = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        let rinf = generate(&mut lm1, &mut dinf, &eng.vocab, &prompt, &cfg, &mut Rng::new(2)).unwrap();

        let mut lm2 = MockLm::new(model);
        let mut d0 = DominoDecoder::new(eng.clone(), Lookahead::K(0));
        let r0 = generate(&mut lm2, &mut d0, &eng.vocab, &prompt, &cfg, &mut Rng::new(2)).unwrap();

        assert!(r0.interventions > rinf.interventions);
        assert!(r0.perplexity() >= rinf.perplexity());
    }

    #[test]
    fn max_tokens_cap_reported() {
        let (eng, model) = setup();
        let mut lm = MockLm::new(model);
        let mut dec = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        let cfg = GenConfig { max_tokens: 3, sampling: Sampling::Greedy, mode: MaskMode::Opportunistic };
        let r = generate(&mut lm, &mut dec, &eng.vocab, &Prompt::default(), &cfg, &mut Rng::new(0)).unwrap();
        assert_eq!(r.tokens.len(), 3);
        assert!(!r.stopped);
    }
}
