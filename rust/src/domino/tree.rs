//! Vocabulary-aligned subterminal trees (§3.3, Algorithm 2).
//!
//! For every scanner position `q` we enumerate, for every vocabulary token
//! `l`, all subterminal sequences the scanner can read `l` as
//! (`q.traverse(l)`), and organize them into a prefix tree over the
//! *completed-terminal* sequences. Tokens land on the node for their
//! completed sequence, grouped by the final pending position set (the
//! trailing start/continuation subterminal).
//!
//! At inference time the decoder walks a tree with the parser, pruning
//! edges whose terminal the parser rejects — mask computation then touches
//! only the (small) tree instead of the whole vocabulary (§3.5).

use crate::grammar::TermId;
use crate::scanner::{Pos, Scanner};
use crate::tokenizer::Vocab;
use crate::TokenId;
use std::collections::HashMap;

/// Interned final-position sets, shared across all trees.
#[derive(Debug, Default)]
pub struct PosSets {
    sets: Vec<PosSetInfo>,
    ids: HashMap<Vec<Pos>, u32>,
}

/// A deduplicated pending-position set plus derived lookups.
#[derive(Debug)]
pub struct PosSetInfo {
    pub positions: Vec<Pos>,
    /// Distinct pending terminals (tags of `positions`).
    pub terms: Vec<TermId>,
    /// Pending terminals that are complete at their current state
    /// (acceptable close points) — used for the EOS check.
    pub accepting_terms: Vec<TermId>,
}

impl PosSets {
    fn intern(&mut self, scanner: &Scanner, mut set: Vec<Pos>) -> u32 {
        set.sort_unstable();
        set.dedup();
        if let Some(&id) = self.ids.get(&set) {
            return id;
        }
        let mut terms: Vec<TermId> = Vec::new();
        let mut accepting: Vec<TermId> = Vec::new();
        for &p in &set {
            if let Pos::In(t, _) = p {
                terms.push(t);
                if scanner.accepting(p) {
                    accepting.push(t);
                }
            }
        }
        terms.sort_unstable();
        terms.dedup();
        accepting.sort_unstable();
        accepting.dedup();
        let id = self.sets.len() as u32;
        self.ids.insert(set.clone(), id);
        self.sets.push(PosSetInfo { positions: set, terms, accepting_terms: accepting });
        id
    }

    pub fn get(&self, id: u32) -> &PosSetInfo {
        &self.sets[id as usize]
    }

    pub fn len(&self) -> usize {
        self.sets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// Rebuild an interned table from its serialized position sets, in id
    /// order (the artifact load path). Derived lookups (`terms`,
    /// `accepting_terms`) are recomputed against `scanner`; ids must come
    /// out exactly as stored, so duplicate sets are an error.
    pub fn from_positions(scanner: &Scanner, sets: Vec<Vec<Pos>>) -> crate::Result<PosSets> {
        let mut ps = PosSets::default();
        for (i, set) in sets.into_iter().enumerate() {
            let id = ps.intern(scanner, set);
            if id as usize != i {
                anyhow::bail!("posset table corrupt: set {i} re-interned as {id}");
            }
        }
        Ok(ps)
    }
}

/// One prefix-tree node (path = sequence of completed terminals).
#[derive(Debug, Default)]
pub struct TreeNode {
    /// Child edges, labeled by the completed terminal.
    pub children: Vec<(TermId, u32)>,
    /// Tokens whose traversal ends here, grouped by interned pending set.
    pub entries: Vec<(u32, Vec<TokenId>)>,
}

/// The subterminal tree `T_q` for one scanner position.
#[derive(Debug)]
pub struct Tree {
    pub nodes: Vec<TreeNode>,
}

impl Tree {
    pub fn root(&self) -> &TreeNode {
        &self.nodes[0]
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// All trees for a (grammar, vocabulary) pair + interned position sets —
/// the offline precomputation of §3.5.
pub struct TreeSet {
    /// Indexed by [`Scanner::pos_id`].
    pub trees: Vec<Tree>,
    pub possets: PosSets,
    pub vocab_size: usize,
}

impl TreeSet {
    /// Algorithm 2, for all scanner positions. Single-threaded; see
    /// [`TreeSet::build`] for the parallel entry point.
    pub fn build_serial(scanner: &Scanner, vocab: &Vocab) -> TreeSet {
        let positions = scanner.reachable_positions();
        let mut possets = PosSets::default();
        let mut trees: Vec<Tree> = Vec::with_capacity(positions.len());
        for pos in positions {
            trees.push(Self::build_tree(scanner, vocab, pos, &mut possets));
        }
        TreeSet { trees, possets, vocab_size: vocab.len() }
    }

    /// Parallel build: positions are independent, so trees build on worker
    /// threads; position-set interning is merged afterwards.
    pub fn build(scanner: &Scanner, vocab: &Vocab) -> TreeSet {
        let positions = scanner.reachable_positions();
        let workers = std::thread::available_parallelism().map_or(4, |n| n.get()).min(positions.len().max(1));
        if workers <= 1 || positions.len() < 8 {
            return Self::build_serial(scanner, vocab);
        }
        // Each worker builds (tree, local posset) pairs for a stripe.
        let chunks: Vec<Vec<Pos>> = positions
            .chunks(positions.len().div_ceil(workers))
            .map(|c| c.to_vec())
            .collect();
        let results: Vec<Vec<(Pos, Tree, PosSets)>> = std::thread::scope(|s| {
            let handles: Vec<_> = chunks
                .into_iter()
                .map(|chunk| {
                    s.spawn(move || {
                        chunk
                            .into_iter()
                            .map(|pos| {
                                let mut local = PosSets::default();
                                let t = Self::build_tree(scanner, vocab, pos, &mut local);
                                (pos, t, local)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("tree worker")).collect()
        });
        // Merge: re-intern local posset ids into the global table.
        let mut possets = PosSets::default();
        let mut by_pos: HashMap<Pos, Tree> = HashMap::new();
        for batch in results {
            for (pos, mut tree, local) in batch {
                let remap: Vec<u32> = local
                    .sets
                    .iter()
                    .map(|info| possets.intern(scanner, info.positions.clone()))
                    .collect();
                for node in &mut tree.nodes {
                    for (set_id, _) in &mut node.entries {
                        *set_id = remap[*set_id as usize];
                    }
                }
                by_pos.insert(pos, tree);
            }
        }
        let trees = scanner
            .reachable_positions()
            .into_iter()
            .map(|pos| by_pos.remove(&pos).expect("tree built for every position"))
            .collect();
        TreeSet { trees, possets, vocab_size: vocab.len() }
    }

    fn build_tree(scanner: &Scanner, vocab: &Vocab, pos: Pos, possets: &mut PosSets) -> Tree {
        let mut nodes: Vec<TreeNode> = vec![TreeNode::default()];
        // entries collected as (node, posset) -> tokens, then flattened.
        let mut entry_map: HashMap<(u32, u32), Vec<TokenId>> = HashMap::new();
        let start = [pos];
        for id in 0..vocab.len() as TokenId {
            let bytes = vocab.token_bytes(id);
            if bytes.is_empty() {
                continue; // specials
            }
            for (seq, posset) in scanner.traverse(&start, bytes) {
                // Walk/extend the trie along the completed sequence.
                let mut node = 0u32;
                for &t in &seq {
                    node = match nodes[node as usize].children.iter().find(|(tt, _)| *tt == t) {
                        Some(&(_, child)) => child,
                        None => {
                            let child = nodes.len() as u32;
                            nodes.push(TreeNode::default());
                            nodes[node as usize].children.push((t, child));
                            child
                        }
                    };
                }
                let set_id = possets.intern(scanner, posset);
                entry_map.entry((node, set_id)).or_default().push(id);
            }
        }
        for ((node, set_id), tokens) in entry_map {
            nodes[node as usize].entries.push((set_id, tokens));
        }
        Tree { nodes }
    }

    pub fn tree(&self, scanner: &Scanner, pos: Pos) -> &Tree {
        &self.trees[scanner.pos_id(pos) as usize]
    }

    /// Total node count across all trees (the §4.3 size statistic).
    pub fn total_nodes(&self) -> usize {
        self.trees.iter().map(|t| t.num_nodes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grammar::builtin::fig3_expr;
    use crate::tokenizer::Vocab;

    /// Fig. 3 (c)-style mini vocabulary on top of raw bytes: tokens
    /// "12", "+1", "1 (" etc. come from merges.
    fn mini_vocab() -> Vocab {
        let corpus = b"(12+1)(12+1)1 (1 (0+0)12+34+56".repeat(8);
        crate::tokenizer::train(&corpus, 300)
    }

    #[test]
    fn builds_trees_for_all_positions() {
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        let v = mini_vocab();
        let ts = TreeSet::build(&s, &v);
        assert_eq!(ts.trees.len(), s.num_pos());
        assert!(ts.total_nodes() >= s.num_pos()); // at least a root each
        assert!(ts.possets.len() > 0);
    }

    #[test]
    fn parallel_matches_serial() {
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        let v = mini_vocab();
        let a = TreeSet::build(&s, &v);
        let b = TreeSet::build_serial(&s, &v);
        assert_eq!(a.trees.len(), b.trees.len());
        for (ta, tb) in a.trees.iter().zip(&b.trees) {
            assert_eq!(ta.num_nodes(), tb.num_nodes());
            // Same token multiset at the root.
            let count = |t: &Tree| -> usize {
                t.nodes.iter().map(|n| n.entries.iter().map(|(_, ts)| ts.len()).sum::<usize>()).sum()
            };
            assert_eq!(count(ta), count(tb));
        }
    }

    #[test]
    fn boundary_tree_contains_single_byte_starts() {
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        let v = Vocab::byte_level();
        let ts = TreeSet::build(&s, &v);
        let root = ts.tree(&s, Pos::Boundary).root();
        // Tokens '(' ')' '+' '0'..'9' all end at the root (no completed
        // terminal) with a pending position.
        let mut root_tokens: Vec<TokenId> = root
            .entries
            .iter()
            .flat_map(|(_, toks)| toks.iter().copied())
            .collect();
        root_tokens.sort_unstable();
        let expect_byte = |c: u8| (c as usize + crate::tokenizer::NUM_SPECIAL) as TokenId;
        for c in [b'(', b')', b'+', b'0', b'5', b'9'] {
            assert!(root_tokens.contains(&expect_byte(c)), "{}", c as char);
        }
        // 'x' matches nothing.
        assert!(!root_tokens.contains(&expect_byte(b'x')));
        // Boundary tree has no children (single bytes never complete a
        // terminal AND start another).
        assert!(root.children.is_empty());
    }

    #[test]
    fn bridge_token_lands_at_depth_two() {
        // Token ")+" from inside int: completes int, completes +, nothing
        // pending... no — ')' closes int and starts ')'; '+' closes ')'
        // and starts '+': seq [int, ')'], pending {'+'}.
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        let corpus = b")+)+)+)+)+".repeat(4);
        let v = crate::tokenizer::train(&corpus, 270);
        let bridge = (0..v.len() as TokenId).find(|&id| v.token_bytes(id) == b")+").unwrap();
        let ts = TreeSet::build(&s, &v);
        // From a mid-int position:
        let mid = s.traverse(&[Pos::Boundary], b"12").into_iter().find(|(q, _)| q.is_empty()).unwrap().1;
        let int_pos = mid[0];
        let tree = ts.tree(&s, int_pos);
        // Walk: root --int--> n1 --')'--> n2; ")+" should be in n2's entries.
        let int_id = g.terminals.iter().position(|t| t.name == "int").unwrap() as TermId;
        let rp_id = g.terminals.iter().position(|t| t.name == "')'").unwrap() as TermId;
        let n1 = tree.root().children.iter().find(|(t, _)| *t == int_id).expect("int edge").1;
        let n2 = tree.nodes[n1 as usize].children.iter().find(|(t, _)| *t == rp_id).expect("rp edge").1;
        let found = tree.nodes[n2 as usize]
            .entries
            .iter()
            .any(|(_, toks)| toks.contains(&bridge));
        assert!(found, "bridge token should land at depth 2");
    }
}
