//! Vocabulary-aligned subterminal trees (§3.3, Algorithm 2).
//!
//! For every scanner position `q` we enumerate, for every vocabulary token
//! `l`, all subterminal sequences the scanner can read `l` as
//! (`q.traverse(l)`), and organize them into a prefix tree over the
//! *completed-terminal* sequences. Tokens land on the node for their
//! completed sequence, grouped by the final pending position set (the
//! trailing start/continuation subterminal).
//!
//! At inference time the decoder walks a tree with the parser, pruning
//! edges whose terminal the parser rejects — mask computation then touches
//! only the (small) tree instead of the whole vocabulary (§3.5).
//!
//! A [`TreeSet`] comes in two flavours:
//!
//! * **Complete** ([`TreeSet::build`]) — one tree per scanner position,
//!   precomputed offline; requires a dense scanner (trees are indexed by
//!   [`Scanner::pos_id`]).
//! * **Lazy** ([`TreeSet::lazy`]) — trees built on first request per
//!   position and memoized in a keyed table; pairs with
//!   [`Scanner::new_lazy`] so huge grammars pay precompute cost only for
//!   positions decoding actually reaches.
//!
//! Both hand out trees as `Arc<Tree>` so the decoder holds no borrows into
//! the set while walking.

use crate::grammar::TermId;
use crate::scanner::{Pos, Scanner};
use crate::tokenizer::Vocab;
use crate::TokenId;
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// Interned final-position sets, shared across all trees.
#[derive(Debug, Default)]
pub struct PosSets {
    sets: Vec<Arc<PosSetInfo>>,
    ids: HashMap<Vec<Pos>, u32>,
}

/// A deduplicated pending-position set plus derived lookups.
#[derive(Debug)]
pub struct PosSetInfo {
    pub positions: Vec<Pos>,
    /// Distinct pending terminals (tags of `positions`).
    pub terms: Vec<TermId>,
    /// Pending terminals that are complete at their current state
    /// (acceptable close points) — used for the EOS check.
    pub accepting_terms: Vec<TermId>,
}

impl PosSets {
    fn intern(&mut self, scanner: &Scanner, mut set: Vec<Pos>) -> u32 {
        set.sort_unstable();
        set.dedup();
        if let Some(&id) = self.ids.get(&set) {
            return id;
        }
        let mut terms: Vec<TermId> = Vec::new();
        let mut accepting: Vec<TermId> = Vec::new();
        for &p in &set {
            if let Pos::In(t, _) = p {
                terms.push(t);
                if scanner.accepting(p) {
                    accepting.push(t);
                }
            }
        }
        terms.sort_unstable();
        terms.dedup();
        accepting.sort_unstable();
        accepting.dedup();
        let id = self.sets.len() as u32;
        self.ids.insert(set.clone(), id);
        self.sets.push(Arc::new(PosSetInfo { positions: set, terms, accepting_terms: accepting }));
        id
    }

    pub fn get(&self, id: u32) -> &PosSetInfo {
        &self.sets[id as usize]
    }

    /// Shared handle to an interned set (what the decoder holds while
    /// traversing — no borrow into the table).
    pub fn get_arc(&self, id: u32) -> Arc<PosSetInfo> {
        self.sets[id as usize].clone()
    }

    pub fn len(&self) -> usize {
        self.sets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// Rebuild an interned table from its serialized position sets, in id
    /// order (the artifact load path). Derived lookups (`terms`,
    /// `accepting_terms`) are recomputed against `scanner`; ids must come
    /// out exactly as stored, so duplicate sets are an error.
    pub fn from_positions(scanner: &Scanner, sets: Vec<Vec<Pos>>) -> crate::Result<PosSets> {
        let mut ps = PosSets::default();
        for (i, set) in sets.into_iter().enumerate() {
            let id = ps.intern(scanner, set);
            if id as usize != i {
                anyhow::bail!("posset table corrupt: set {i} re-interned as {id}");
            }
        }
        Ok(ps)
    }
}

/// One prefix-tree node (path = sequence of completed terminals).
#[derive(Debug, Default)]
pub struct TreeNode {
    /// Child edges, labeled by the completed terminal.
    pub children: Vec<(TermId, u32)>,
    /// Tokens whose traversal ends here, grouped by interned pending set.
    pub entries: Vec<(u32, Vec<TokenId>)>,
}

/// The subterminal tree `T_q` for one scanner position.
#[derive(Debug)]
pub struct Tree {
    pub nodes: Vec<TreeNode>,
}

impl Tree {
    pub fn root(&self) -> &TreeNode {
        &self.nodes[0]
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// Memoized on-demand trees (the lazy backend).
struct LazyState {
    trees: HashMap<Pos, Arc<Tree>>,
    possets: PosSets,
}

enum Inner {
    Complete {
        /// Indexed by [`Scanner::pos_id`].
        trees: Vec<Arc<Tree>>,
        possets: PosSets,
    },
    Lazy {
        vocab: Arc<Vocab>,
        state: RwLock<LazyState>,
    },
}

/// All trees for a (grammar, vocabulary) pair + interned position sets —
/// the offline precomputation of §3.5 (complete mode), or its on-demand
/// equivalent (lazy mode).
pub struct TreeSet {
    inner: Inner,
    vocab_size: usize,
}

impl TreeSet {
    /// Algorithm 2, for all scanner positions. Single-threaded; see
    /// [`TreeSet::build`] for the parallel entry point.
    pub fn build_serial(scanner: &Scanner, vocab: &Vocab) -> TreeSet {
        let positions = scanner.reachable_positions();
        let mut possets = PosSets::default();
        let mut trees: Vec<Arc<Tree>> = Vec::with_capacity(positions.len());
        for pos in positions {
            trees.push(Arc::new(Self::build_tree(scanner, vocab, pos, &mut possets)));
        }
        TreeSet { inner: Inner::Complete { trees, possets }, vocab_size: vocab.len() }
    }

    /// Parallel build: positions are independent, so trees build on worker
    /// threads; position-set interning is merged afterwards.
    pub fn build(scanner: &Scanner, vocab: &Vocab) -> TreeSet {
        let positions = scanner.reachable_positions();
        let workers = std::thread::available_parallelism().map_or(4, |n| n.get()).min(positions.len().max(1));
        if workers <= 1 || positions.len() < 8 {
            return Self::build_serial(scanner, vocab);
        }
        // Each worker builds (tree, local posset) pairs for a stripe.
        let chunks: Vec<Vec<Pos>> = positions
            .chunks(positions.len().div_ceil(workers))
            .map(|c| c.to_vec())
            .collect();
        let results: Vec<Vec<(Pos, Tree, PosSets)>> = std::thread::scope(|s| {
            let handles: Vec<_> = chunks
                .into_iter()
                .map(|chunk| {
                    s.spawn(move || {
                        chunk
                            .into_iter()
                            .map(|pos| {
                                let mut local = PosSets::default();
                                let t = Self::build_tree(scanner, vocab, pos, &mut local);
                                (pos, t, local)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("tree worker")).collect()
        });
        // Merge: re-intern local posset ids into the global table.
        let mut possets = PosSets::default();
        let mut by_pos: HashMap<Pos, Arc<Tree>> = HashMap::new();
        for batch in results {
            for (pos, mut tree, local) in batch {
                remap_entries(scanner, &mut possets, &local, &mut tree);
                by_pos.insert(pos, Arc::new(tree));
            }
        }
        let trees = scanner
            .reachable_positions()
            .into_iter()
            .map(|pos| by_pos.remove(&pos).expect("tree built for every position"))
            .collect();
        TreeSet { inner: Inner::Complete { trees, possets }, vocab_size: vocab.len() }
    }

    /// An empty lazy set: trees are built (and memoized) on first request
    /// per position via [`TreeSet::tree`]. Works with both scanner
    /// backends — no dense [`Scanner::pos_id`] numbering is required.
    pub fn lazy(vocab: Arc<Vocab>) -> TreeSet {
        let vocab_size = vocab.len();
        TreeSet {
            inner: Inner::Lazy {
                vocab,
                state: RwLock::new(LazyState { trees: HashMap::new(), possets: PosSets::default() }),
            },
            vocab_size,
        }
    }

    /// Reassemble a complete set from deserialized parts (the artifact
    /// load path). `trees[i]` must correspond to `scanner.pos_of_id(i)`.
    pub fn from_parts(trees: Vec<Tree>, possets: PosSets, vocab_size: usize) -> TreeSet {
        TreeSet {
            inner: Inner::Complete { trees: trees.into_iter().map(Arc::new).collect(), possets },
            vocab_size,
        }
    }

    /// The complete tables, for serialization. Panics on a lazy set —
    /// artifact encoding materializes the engine first.
    pub fn complete_parts(&self) -> (&[Arc<Tree>], &PosSets) {
        match &self.inner {
            Inner::Complete { trees, possets } => (trees, possets),
            Inner::Lazy { .. } => {
                panic!("complete_parts on a lazy TreeSet; materialize the engine first")
            }
        }
    }

    pub fn is_lazy(&self) -> bool {
        matches!(self.inner, Inner::Lazy { .. })
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    fn build_tree(scanner: &Scanner, vocab: &Vocab, pos: Pos, possets: &mut PosSets) -> Tree {
        let mut nodes: Vec<TreeNode> = vec![TreeNode::default()];
        // entries collected as (node, posset) -> tokens, then flattened.
        let mut entry_map: HashMap<(u32, u32), Vec<TokenId>> = HashMap::new();
        let start = [pos];
        for id in 0..vocab.len() as TokenId {
            let bytes = vocab.token_bytes(id);
            if bytes.is_empty() {
                continue; // specials
            }
            for (seq, posset) in scanner.traverse(&start, bytes) {
                // Walk/extend the trie along the completed sequence.
                let mut node = 0u32;
                for &t in &seq {
                    node = match nodes[node as usize].children.iter().find(|(tt, _)| *tt == t) {
                        Some(&(_, child)) => child,
                        None => {
                            let child = nodes.len() as u32;
                            nodes.push(TreeNode::default());
                            nodes[node as usize].children.push((t, child));
                            child
                        }
                    };
                }
                let set_id = possets.intern(scanner, posset);
                entry_map.entry((node, set_id)).or_default().push(id);
            }
        }
        for ((node, set_id), tokens) in entry_map {
            nodes[node as usize].entries.push((set_id, tokens));
        }
        Tree { nodes }
    }

    /// The tree for `pos`. Complete sets index by [`Scanner::pos_id`];
    /// lazy sets build and memoize on first request (subsequent requests
    /// are a read-lock + `Arc` clone).
    pub fn tree(&self, scanner: &Scanner, pos: Pos) -> Arc<Tree> {
        match &self.inner {
            Inner::Complete { trees, .. } => trees[scanner.pos_id(pos) as usize].clone(),
            Inner::Lazy { vocab, state } => {
                if let Some(t) = state.read().unwrap().trees.get(&pos) {
                    return t.clone();
                }
                // Build outside the lock (traversal may be slow and may
                // itself take the lazy scanner's locks), interning into a
                // local table; splice into the shared table under the
                // write lock.
                let mut local = PosSets::default();
                let mut tree = Self::build_tree(scanner, vocab, pos, &mut local);
                let mut st = state.write().unwrap();
                if let Some(t) = st.trees.get(&pos) {
                    return t.clone(); // another slot won the race
                }
                remap_entries(scanner, &mut st.possets, &local, &mut tree);
                let tree = Arc::new(tree);
                st.trees.insert(pos, tree.clone());
                tree
            }
        }
    }

    /// Shared handle to interned position-set `id`.
    pub fn posset(&self, id: u32) -> Arc<PosSetInfo> {
        match &self.inner {
            Inner::Complete { possets, .. } => possets.get_arc(id),
            Inner::Lazy { state, .. } => state.read().unwrap().possets.get_arc(id),
        }
    }

    /// Trees existing right now: all positions (complete) or those built
    /// so far (lazy).
    pub fn num_trees(&self) -> usize {
        match &self.inner {
            Inner::Complete { trees, .. } => trees.len(),
            Inner::Lazy { state, .. } => state.read().unwrap().trees.len(),
        }
    }

    /// Interned position sets existing right now (see
    /// [`TreeSet::num_trees`]).
    pub fn num_possets(&self) -> usize {
        match &self.inner {
            Inner::Complete { possets, .. } => possets.len(),
            Inner::Lazy { state, .. } => state.read().unwrap().possets.len(),
        }
    }

    /// Total node count across existing trees (the §4.3 size statistic).
    pub fn total_nodes(&self) -> usize {
        match &self.inner {
            Inner::Complete { trees, .. } => trees.iter().map(|t| t.num_nodes()).sum(),
            Inner::Lazy { state, .. } => {
                state.read().unwrap().trees.values().map(|t| t.num_nodes()).sum()
            }
        }
    }
}

/// Re-intern `local`'s posset ids into `global`, rewriting `tree`'s
/// entries in place.
fn remap_entries(scanner: &Scanner, global: &mut PosSets, local: &PosSets, tree: &mut Tree) {
    let remap: Vec<u32> =
        local.sets.iter().map(|info| global.intern(scanner, info.positions.clone())).collect();
    for node in &mut tree.nodes {
        for (set_id, _) in &mut node.entries {
            *set_id = remap[*set_id as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grammar::builtin::fig3_expr;
    use crate::tokenizer::Vocab;

    /// Fig. 3 (c)-style mini vocabulary on top of raw bytes: tokens
    /// "12", "+1", "1 (" etc. come from merges.
    fn mini_vocab() -> Vocab {
        let corpus = b"(12+1)(12+1)1 (1 (0+0)12+34+56".repeat(8);
        crate::tokenizer::train(&corpus, 300)
    }

    #[test]
    fn builds_trees_for_all_positions() {
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        let v = mini_vocab();
        let ts = TreeSet::build(&s, &v);
        assert_eq!(ts.num_trees(), s.num_pos());
        assert!(ts.total_nodes() >= s.num_pos()); // at least a root each
        assert!(ts.num_possets() > 0);
    }

    #[test]
    fn parallel_matches_serial() {
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        let v = mini_vocab();
        let a = TreeSet::build(&s, &v);
        let b = TreeSet::build_serial(&s, &v);
        assert_eq!(a.num_trees(), b.num_trees());
        let (ta_all, _) = a.complete_parts();
        let (tb_all, _) = b.complete_parts();
        for (ta, tb) in ta_all.iter().zip(tb_all) {
            assert_eq!(ta.num_nodes(), tb.num_nodes());
            // Same token multiset at the root.
            let count = |t: &Tree| -> usize {
                t.nodes.iter().map(|n| n.entries.iter().map(|(_, ts)| ts.len()).sum::<usize>()).sum()
            };
            assert_eq!(count(ta), count(tb));
        }
    }

    #[test]
    fn boundary_tree_contains_single_byte_starts() {
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        let v = Vocab::byte_level();
        let ts = TreeSet::build(&s, &v);
        let tree = ts.tree(&s, Pos::Boundary);
        let root = tree.root();
        // Tokens '(' ')' '+' '0'..'9' all end at the root (no completed
        // terminal) with a pending position.
        let mut root_tokens: Vec<TokenId> = root
            .entries
            .iter()
            .flat_map(|(_, toks)| toks.iter().copied())
            .collect();
        root_tokens.sort_unstable();
        let expect_byte = |c: u8| (c as usize + crate::tokenizer::NUM_SPECIAL) as TokenId;
        for c in [b'(', b')', b'+', b'0', b'5', b'9'] {
            assert!(root_tokens.contains(&expect_byte(c)), "{}", c as char);
        }
        // 'x' matches nothing.
        assert!(!root_tokens.contains(&expect_byte(b'x')));
        // Boundary tree has no children (single bytes never complete a
        // terminal AND start another).
        assert!(root.children.is_empty());
    }

    #[test]
    fn bridge_token_lands_at_depth_two() {
        // Token ")+" from inside int: completes int, completes +, nothing
        // pending... no — ')' closes int and starts ')'; '+' closes ')'
        // and starts '+': seq [int, ')'], pending {'+'}.
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        let corpus = b")+)+)+)+)+".repeat(4);
        let v = crate::tokenizer::train(&corpus, 270);
        let bridge = (0..v.len() as TokenId).find(|&id| v.token_bytes(id) == b")+").unwrap();
        let ts = TreeSet::build(&s, &v);
        // From a mid-int position:
        let mid = s.traverse(&[Pos::Boundary], b"12").into_iter().find(|(q, _)| q.is_empty()).unwrap().1;
        let int_pos = mid[0];
        let tree = ts.tree(&s, int_pos);
        // Walk: root --int--> n1 --')'--> n2; ")+" should be in n2's entries.
        let int_id = g.terminals.iter().position(|t| t.name == "int").unwrap() as TermId;
        let rp_id = g.terminals.iter().position(|t| t.name == "')'").unwrap() as TermId;
        let n1 = tree.root().children.iter().find(|(t, _)| *t == int_id).expect("int edge").1;
        let n2 = tree.nodes[n1 as usize].children.iter().find(|(t, _)| *t == rp_id).expect("rp edge").1;
        let found = tree.nodes[n2 as usize]
            .entries
            .iter()
            .any(|(_, toks)| toks.contains(&bridge));
        assert!(found, "bridge token should land at depth 2");
    }

    #[test]
    fn lazy_treeset_builds_on_demand_and_matches_complete() {
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        let v = Arc::new(mini_vocab());
        let complete = TreeSet::build(&s, &v);
        let lazy = TreeSet::lazy(v.clone());
        assert!(lazy.is_lazy());
        assert_eq!(lazy.num_trees(), 0);
        // Request a couple of positions; each must match the precomputed
        // tree structurally (node count + token multiset).
        let mid = s.traverse(&[Pos::Boundary], b"12").into_iter().find(|(q, _)| q.is_empty()).unwrap().1;
        for pos in [Pos::Boundary, mid[0]] {
            let a = complete.tree(&s, pos);
            let b = lazy.tree(&s, pos);
            assert_eq!(a.num_nodes(), b.num_nodes(), "{pos:?}");
            let count = |t: &Tree| -> usize {
                t.nodes.iter().map(|n| n.entries.iter().map(|(_, ts)| ts.len()).sum::<usize>()).sum()
            };
            assert_eq!(count(&a), count(&b), "{pos:?}");
        }
        // Only the requested trees exist; a repeat request is memoized.
        assert_eq!(lazy.num_trees(), 2);
        let again = lazy.tree(&s, Pos::Boundary);
        assert_eq!(lazy.num_trees(), 2);
        assert!(Arc::ptr_eq(&again, &lazy.tree(&s, Pos::Boundary)));
        // Posset lookups resolve for every entry in a lazy tree.
        for node in &again.nodes {
            for (set_id, _) in &node.entries {
                assert!(!lazy.posset(*set_id).positions.is_empty());
            }
        }
    }
}
