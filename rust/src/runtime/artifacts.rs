//! AOT-bundle metadata shared by the real PJRT backend and its stub.
//!
//! Everything here is `xla`-free: locating the artifacts directory,
//! parsing `model_config.json`, loading the bundled tokenizer. The
//! heavyweight parts (device buffers, executables) live in
//! [`super::pjrt`], which is gated behind the `xla` cargo feature.

use crate::util::Json;
use anyhow::Context;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Parsed `model_config.json`.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub param_order: Vec<String>,
    /// (batch, chunk, hlo file name).
    pub variants: Vec<(usize, usize, String)>,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn load(dir: &Path) -> crate::Result<ModelConfig> {
        let text = std::fs::read_to_string(dir.join("model_config.json"))
            .with_context(|| format!("reading model_config.json in {}", dir.display()))?;
        let v = Json::parse(&text)?;
        let model = v.get("model").context("model key")?;
        let get = |k: &str| -> crate::Result<usize> {
            Ok(model.get(k).and_then(|x| x.as_f64()).with_context(|| format!("model.{k}"))?
                as usize)
        };
        let param_order = v
            .get("param_order")
            .and_then(|x| x.as_arr())
            .context("param_order")?
            .iter()
            .map(|s| s.as_str().unwrap_or_default().to_string())
            .collect();
        let variants = v
            .get("variants")
            .and_then(|x| x.as_arr())
            .context("variants")?
            .iter()
            .map(|e| {
                let b = e.get("batch").and_then(|x| x.as_f64()).unwrap_or(0.0) as usize;
                let c = e.get("chunk").and_then(|x| x.as_f64()).unwrap_or(0.0) as usize;
                let f = e.get("file").and_then(|x| x.as_str()).unwrap_or_default().to_string();
                (b, c, f)
            })
            .collect();
        Ok(ModelConfig {
            vocab_size: get("vocab_size")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            max_seq: get("max_seq")?,
            param_order,
            variants,
        })
    }
}

/// Locate the artifacts directory: `$DOMINO_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("DOMINO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Load the tokenizer that ships with the bundle.
pub fn load_vocab(dir: &Path) -> crate::Result<Arc<crate::tokenizer::Vocab>> {
    Ok(Arc::new(crate::tokenizer::Vocab::load(&dir.join("tokenizer.json"))?))
}
