//! Model runtime — the L3↔L2 boundary.
//!
//! [`LmSession`] is the contract every decoder, baseline, server slot and
//! bench speaks: an append-only token context with per-step logits, chunk
//! scoring (for speculative verification) and KV rollback.
//!
//! Implementations:
//! * [`pjrt::PjrtLm`] — the real thing: loads the AOT-compiled JAX model
//!   (`artifacts/*.hlo.txt` + `weights.npz`) and executes it on the PJRT
//!   CPU client via the `xla` crate. Python never runs here. Gated behind
//!   the off-by-default `xla` cargo feature (the vendored `xla` crate is
//!   not available offline); without it, `pjrt_stub.rs` keeps the same
//!   API and fails at load time with a clear message.
//! * [`mock::MockLm`] — a deterministic n-gram LM over a synthetic corpus;
//!   used by unit/integration tests and baselines benches so the grammar
//!   machinery can be exercised without artifacts. The default backend.
//! * [`artifacts`] — `xla`-free bundle metadata (model config, tokenizer),
//!   shared by both pjrt variants.

pub mod artifacts;
pub mod mock;
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
pub mod sampler;

use crate::TokenId;

/// An autoregressive LM session: an append-only token context.
///
/// The session owns its KV cache; `append` costs one model step per token
/// (or one chunked step, implementation-defined), `rollback` undoes
/// context without recomputation (functional KV caches make this free).
///
/// Deliberately NOT `Send`: the `xla` crate's PJRT handles are `Rc`-based,
/// so all model interaction lives on one engine thread (the server's
/// engine-loop architecture — see `server/`).
pub trait LmSession {
    fn vocab_size(&self) -> usize;

    /// Number of tokens currently in the context.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append tokens; return the logits row following the *last* token.
    fn append(&mut self, tokens: &[TokenId]) -> crate::Result<Vec<f32>>;

    /// Append tokens; return the logits row following *each* token
    /// (`result[i]` = distribution over token `i+1`). Used to verify
    /// speculative proposals with a single forward pass (§3.6).
    fn append_scored(&mut self, tokens: &[TokenId]) -> crate::Result<Vec<Vec<f32>>>;

    /// Remove the last `n` tokens from the context.
    fn rollback(&mut self, n: usize) -> crate::Result<()>;
}

/// Factory for per-request sessions (the engine thread spawns one per
/// slot).
pub trait LmFactory {
    fn vocab_size(&self) -> usize;
    fn new_session(&self) -> crate::Result<Box<dyn LmSession>>;
}
