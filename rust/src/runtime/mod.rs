//! Model runtime — the L3↔L2 boundary.
//!
//! [`LmSession`] is the contract every decoder, baseline, server slot and
//! bench speaks: an append-only token context with per-step logits, chunk
//! scoring (for speculative verification) and KV rollback. [`LmBackend`]
//! sits above the sessions: it spawns them and runs the **batched
//! cross-slot forward pass** ([`LmBackend::forward_batch`]) the engine
//! issues once per tick, so a shard with N live slots pays one model
//! call per tick instead of N.
//!
//! Implementations:
//! * [`pjrt::PjrtLm`] — the real thing: loads the AOT-compiled JAX model
//!   (`artifacts/*.hlo.txt` + `weights.npz`) and executes it on the PJRT
//!   CPU client via the `xla` crate. Python never runs here. Gated behind
//!   the off-by-default `xla` cargo feature (the vendored `xla` crate is
//!   not available offline); without it, `pjrt_stub.rs` keeps the same
//!   API and fails at load time with a clear message.
//! * [`mock::MockLm`] — a deterministic n-gram LM over a synthetic corpus;
//!   used by unit/integration tests and baselines benches so the grammar
//!   machinery can be exercised without artifacts. The default backend.
//! * [`artifacts`] — `xla`-free bundle metadata (model config, tokenizer),
//!   shared by both pjrt variants.

pub mod artifacts;
pub mod mock;
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
pub mod sampler;

use crate::TokenId;

/// An autoregressive LM session: an append-only token context.
///
/// The session owns its KV cache; `append` costs one model step per token
/// (or one chunked step, implementation-defined), `rollback` undoes
/// context without recomputation (functional KV caches make this free).
///
/// Deliberately NOT `Send`: the `xla` crate's PJRT handles are `Rc`-based,
/// so all model interaction lives on one engine thread (the server's
/// engine-loop architecture — see `server/`).
pub trait LmSession {
    fn vocab_size(&self) -> usize;

    /// Number of tokens currently in the context.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append tokens; return the logits row following the *last* token.
    fn append(&mut self, tokens: &[TokenId]) -> crate::Result<Vec<f32>>;

    /// Append tokens; return the logits row following *each* token
    /// (`result[i]` = distribution over token `i+1`). Used to verify
    /// speculative proposals with a single forward pass (§3.6).
    fn append_scored(&mut self, tokens: &[TokenId]) -> crate::Result<Vec<Vec<f32>>>;

    /// Remove the last `n` tokens from the context.
    fn rollback(&mut self, n: usize) -> crate::Result<()>;

    /// Concrete-type access for batched backends:
    /// [`LmBackend::forward_batch`] downcasts the sessions it recognizes
    /// to vectorize across them in one model call. A session the backend
    /// does not own (wrappers, test fakes) returns `None` here and takes
    /// the sequential per-lane fallback instead — correct, just unbatched.
    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }
}

/// One slot's pending token extension within a batched forward pass —
/// one lane of the batch the engine gathers per tick.
pub struct BatchLane<'a> {
    /// The slot's session; the forward pass appends `tokens` to it.
    pub session: &'a mut dyn LmSession,
    /// The tokens this lane appends this tick (a committed token for
    /// plain decoding, a proposal chunk under speculation).
    pub tokens: Vec<TokenId>,
    /// `true` — return a logits row after *every* token (the batched
    /// analogue of [`LmSession::append_scored`], used to verify
    /// speculative proposals); `false` — only the row after the last.
    pub scored: bool,
}

/// The logit rows one batch lane produced: a single row for a plain
/// lane, one row per proposed token for a scored lane.
pub type LaneRows = Vec<Vec<f32>>;

/// The model backend: spawns per-request sessions and runs the batched
/// cross-slot forward pass. (Formerly `LmFactory`; the alias remains for
/// older call sites.)
pub trait LmBackend {
    fn vocab_size(&self) -> usize;

    /// Spawn one session (the engine creates one per request slot).
    fn new_session(&self) -> crate::Result<Box<dyn LmSession>>;

    /// Advance every lane's session by its pending tokens and return the
    /// per-lane logit rows. The engine calls this ONCE per decode tick —
    /// plain lanes (`scored: false`, one token, one row) and speculative
    /// lanes (`scored: true`, a proposal chunk, one row per token)
    /// coexist in the same batch, so throughput scales with batch width
    /// instead of slot count.
    ///
    /// Failures are per-lane: one session's error must not poison its
    /// siblings (the engine fails only that slot and keeps stepping the
    /// rest).
    ///
    /// The default implementation is the sequential per-lane fallback;
    /// backends with a real vectorized path override it (the mock shares
    /// the per-batch base-row work across lanes — see
    /// [`mock::MockFactory`]).
    fn forward_batch(&self, lanes: &mut [BatchLane<'_>]) -> Vec<crate::Result<LaneRows>> {
        lanes
            .iter_mut()
            .map(|l| {
                if l.scored {
                    l.session.append_scored(&l.tokens)
                } else {
                    l.session.append(&l.tokens).map(|row| vec![row])
                }
            })
            .collect()
    }
}

/// Pre-batching name of [`LmBackend`], kept for older call sites.
pub use self::LmBackend as LmFactory;
