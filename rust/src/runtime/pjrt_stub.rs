//! Stub PJRT backend, compiled when the `xla` cargo feature is OFF
//! (the default — the vendored `xla` crate is not available offline).
//!
//! Presents the same API surface as the real `runtime::pjrt` so callers
//! (CLI, eval harness, examples, integration tests) compile unchanged;
//! loading the bundle reports a clear error and everything falls back to
//! [`super::mock`]. Bundle *metadata* (config, tokenizer) still loads —
//! that part is `xla`-free and lives in [`super::artifacts`].

use super::{LmBackend, LmSession};
use crate::TokenId;
use anyhow::bail;
use std::path::Path;
use std::sync::Arc;

pub use super::artifacts::{artifacts_dir, load_vocab, ModelConfig};

const NO_XLA: &str = "this build has no PJRT backend (compiled without the `xla` cargo \
                      feature); use the mock backend, or rebuild with `--features xla` \
                      after adding the vendored `xla` crate to Cargo.toml";

/// Stub of the loaded model. Never constructible: [`PjrtModel::load`]
/// always fails in a no-`xla` build.
pub struct PjrtModel {
    pub config: ModelConfig,
}

impl PjrtModel {
    pub fn load(_dir: &Path) -> crate::Result<Arc<PjrtModel>> {
        bail!(NO_XLA)
    }

    pub fn load_default() -> crate::Result<Arc<PjrtModel>> {
        bail!(NO_XLA)
    }

    pub fn chunk_sizes(&self, _b: usize) -> Vec<usize> {
        Vec::new()
    }

    pub fn batch_widths(&self) -> Vec<usize> {
        Vec::new()
    }

    pub fn new_cache(&self, _b: usize) -> crate::Result<CacheBufs> {
        bail!(NO_XLA)
    }

    pub fn run(
        &self,
        _b: usize,
        _c: usize,
        _cache: &CacheBufs,
        _kv_len: &[i32],
        _tokens: &[i32],
        _mask: Option<&[f32]>,
    ) -> crate::Result<(Vec<f32>, CacheBufs)> {
        bail!(NO_XLA)
    }
}

/// Stub KV-cache handle.
pub struct CacheBufs {}

/// Stub session; never constructible.
pub struct PjrtLm {
    _model: Arc<PjrtModel>,
}

impl PjrtLm {
    pub fn new(_model: Arc<PjrtModel>) -> crate::Result<PjrtLm> {
        bail!(NO_XLA)
    }
}

impl LmSession for PjrtLm {
    fn vocab_size(&self) -> usize {
        0
    }

    fn len(&self) -> usize {
        0
    }

    fn append(&mut self, _tokens: &[TokenId]) -> crate::Result<Vec<f32>> {
        bail!(NO_XLA)
    }

    fn append_scored(&mut self, _tokens: &[TokenId]) -> crate::Result<Vec<Vec<f32>>> {
        bail!(NO_XLA)
    }

    fn rollback(&mut self, _n: usize) -> crate::Result<()> {
        bail!(NO_XLA)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

/// Stub factory mirroring `pjrt::PjrtFactory`. Keeps the same
/// [`LmBackend`] surface (including the inherited sequential
/// `forward_batch` fallback) so batched-engine callers compile
/// identically with and without the `xla` feature.
pub struct PjrtFactory {
    pub model: Arc<PjrtModel>,
}

impl LmBackend for PjrtFactory {
    fn vocab_size(&self) -> usize {
        self.model.config.vocab_size
    }

    fn new_session(&self) -> crate::Result<Box<dyn LmSession>> {
        bail!(NO_XLA)
    }
}
