//! Sampling: Algorithm 1's `decode(v')` — argmax or temperature sampling
//! over (masked) logits, plus log-softmax utilities used for perplexity.

use crate::util::Rng;
use crate::TokenId;

/// Decoding strategy.
#[derive(Clone, Copy, Debug)]
pub enum Sampling {
    Greedy,
    /// Temperature sampling (1.0 = the model's distribution).
    Temperature(f32),
}

/// Pick the next token from a (possibly masked) logits row.
pub fn decode(logits: &[f32], sampling: Sampling, rng: &mut Rng) -> TokenId {
    match sampling {
        Sampling::Greedy => argmax(logits),
        Sampling::Temperature(t) => {
            let probs = softmax_with_temp(logits, t);
            rng.weighted(&probs) as TokenId
        }
    }
}

pub fn argmax(logits: &[f32]) -> TokenId {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as TokenId
}

fn softmax_with_temp(logits: &[f32], t: f32) -> Vec<f64> {
    let t = t.max(1e-4) as f64;
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    logits
        .iter()
        .map(|&v| {
            if v.is_finite() {
                ((v as f64 - max) / t).exp()
            } else {
                0.0
            }
        })
        .collect()
}

/// `log P(token)` under the (unmasked) logits row — perplexity accounting.
pub fn log_prob(logits: &[f32], token: TokenId) -> f64 {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits
        .iter()
        .map(|&v| if v.is_finite() { (v as f64 - max).exp() } else { 0.0 })
        .sum::<f64>()
        .ln()
        + max;
    logits[token as usize] as f64 - lse
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -5.0]), 1);
    }

    #[test]
    fn greedy_deterministic() {
        let mut rng = Rng::new(0);
        assert_eq!(decode(&[1.0, 9.0, 2.0], Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn temperature_respects_mask() {
        // -inf entries must never be sampled.
        let mut rng = Rng::new(0);
        let logits = [f32::NEG_INFINITY, 1.0, f32::NEG_INFINITY, 0.5];
        for _ in 0..200 {
            let t = decode(&logits, Sampling::Temperature(1.0), &mut rng);
            assert!(t == 1 || t == 3, "sampled masked token {t}");
        }
    }

    #[test]
    fn log_prob_normalizes() {
        let logits = [1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_prob(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
