//! PJRT runtime: load the AOT bundle, execute it from the request path.
//!
//! * Weights (`weights.npz`) are uploaded to device buffers **once** per
//!   process; every call passes them by reference (`execute_b`).
//! * The KV cache is functional: each call consumes the previous cache
//!   buffers and yields new ones. Rollback (speculative rejection, §3.6)
//!   is free — keep the pre-call `kv_len` and let later writes overwrite.
//! * Executable variants `model_b{B}_c{C}.hlo.txt` cover decode (C=1),
//!   speculation verify (C=8) and prefill (C=16); [`PjrtLm::append`]
//!   greedily chunks arbitrary token runs over the available Cs.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md for why not serialized protos).

use super::{LmBackend, LmSession};
use crate::TokenId;
use anyhow::{bail, Context};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

pub use super::artifacts::{artifacts_dir, load_vocab, ModelConfig};

/// The loaded model: client + compiled variants + device-resident weights.
///
/// PJRT executions are serialized behind a mutex — serving concurrency
/// comes from batching *inside* a call (the B=4 variants), not from
/// concurrent executions.
pub struct PjrtModel {
    client: PjRtClient,
    pub config: ModelConfig,
    exes: HashMap<(usize, usize), PjRtLoadedExecutable>,
    params: Vec<PjRtBuffer>,
    /// Host copies of the weights: `buffer_from_host_literal` transfers
    /// ASYNCHRONOUSLY on the TFRT CPU client, so the literals must stay
    /// alive as long as the device buffers (use-after-free segfault
    /// otherwise).
    _param_literals: Vec<Literal>,
    lock: Mutex<()>,
}

impl PjrtModel {
    /// Load + compile everything in `dir`.
    pub fn load(dir: &Path) -> crate::Result<Arc<PjrtModel>> {
        let config = ModelConfig::load(dir)?;
        let client = PjRtClient::cpu()?;
        // Weights: host → device once, in manifest order. (Via `Literal`:
        // the vendored crate's `PjRtBuffer::read_npz` mis-types f32 arrays
        // as F16.)
        let names: Vec<&str> = config.param_order.iter().map(|s| s.as_str()).collect();
        let literals = Literal::read_npz_by_name(dir.join("weights.npz"), &(), &names)?;
        let params = literals
            .iter()
            .map(|l| Ok(client.buffer_from_host_literal(None, l)?))
            .collect::<crate::Result<Vec<_>>>()?;
        // One-time: force the uploads so dropping an unused model can
        // never race the async copies.
        for p in &params {
            p.to_literal_sync()?;
        }
        let mut exes = HashMap::new();
        for (b, c, file) in &config.variants {
            let proto = xla::HloModuleProto::from_text_file(dir.join(file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            exes.insert((*b, *c), exe);
        }
        if exes.is_empty() {
            bail!("no executable variants in {}", dir.display());
        }
        Ok(Arc::new(PjrtModel {
            client,
            config,
            exes,
            params,
            _param_literals: literals,
            lock: Mutex::new(()),
        }))
    }

    /// Load from [`artifacts_dir`], or explain how to build it.
    pub fn load_default() -> crate::Result<Arc<PjrtModel>> {
        let dir = artifacts_dir();
        Self::load(&dir).with_context(|| {
            format!(
                "loading AOT bundle from {} (run `make artifacts`, or set DOMINO_ARTIFACTS)",
                dir.display()
            )
        })
    }

    /// Chunk sizes available at batch width `b`, descending.
    pub fn chunk_sizes(&self, b: usize) -> Vec<usize> {
        let mut cs: Vec<usize> =
            self.exes.keys().filter(|(eb, _)| *eb == b).map(|(_, c)| *c).collect();
        cs.sort_unstable_by(|a, b| b.cmp(a));
        cs
    }

    pub fn batch_widths(&self) -> Vec<usize> {
        let mut bs: Vec<usize> = self.exes.keys().map(|(b, _)| *b).collect();
        bs.sort_unstable();
        bs.dedup();
        bs
    }

    /// Fresh zeroed KV cache buffers for batch width `b`.
    pub fn new_cache(&self, b: usize) -> crate::Result<CacheBufs> {
        let cfg = &self.config;
        let dims: Vec<usize> =
            vec![cfg.n_layers, b, cfg.n_heads, cfg.max_seq, cfg.head_dim()];
        let k_lit = Literal::create_from_shape(xla::PrimitiveType::F32, &dims);
        let v_lit = Literal::create_from_shape(xla::PrimitiveType::F32, &dims);
        let k = self.client.buffer_from_host_literal(None, &k_lit)?;
        let v = self.client.buffer_from_host_literal(None, &v_lit)?;
        Ok(CacheBufs { k, v, _host: Some((k_lit, v_lit)), used: std::cell::Cell::new(false) })
    }

    /// Execute one (b, c) variant. Returns host logprobs `[B*C*V]` and
    /// the successor cache buffers.
    pub fn run(
        &self,
        b: usize,
        c: usize,
        cache: &CacheBufs,
        kv_len: &[i32],
        tokens: &[i32],
        mask: Option<&[f32]>,
    ) -> crate::Result<(Vec<f32>, CacheBufs)> {
        let cfg = &self.config;
        assert_eq!(kv_len.len(), b);
        assert_eq!(tokens.len(), b * c);
        let exe = self
            .exes
            .get(&(b, c))
            .with_context(|| format!("no executable variant for (batch={b}, chunk={c})"))?;
        let _guard = self.lock.lock().expect("pjrt lock");
        let kv_len_buf = self.client.buffer_from_host_buffer(kv_len, &[b], None)?;
        let tokens_buf = self.client.buffer_from_host_buffer(tokens, &[b, c], None)?;
        let ones;
        let mask_host: &[f32] = match mask {
            Some(m) => m,
            None => {
                ones = vec![1f32; b * cfg.vocab_size];
                &ones
            }
        };
        let mask_buf =
            self.client.buffer_from_host_buffer(mask_host, &[b, cfg.vocab_size], None)?;

        let mut args: Vec<&PjRtBuffer> = self.params.iter().collect();
        args.push(&cache.k);
        args.push(&cache.v);
        args.push(&kv_len_buf);
        args.push(&tokens_buf);
        args.push(&mask_buf);

        let mut outs = exe.execute_b(&args)?;
        let mut replica = outs.swap_remove(0);
        if replica.len() == 3 {
            // Untupled outputs: logprobs, k', v' — caches stay on device.
            let v_new = replica.pop().unwrap();
            let k_new = replica.pop().unwrap();
            let logprobs_buf = replica.pop().unwrap();
            cache.used.set(true); // execution completed → uploads consumed
            let logprobs = logprobs_buf.to_literal_sync()?.to_vec::<f32>()?;
            Ok((logprobs, CacheBufs { k: k_new, v: v_new, _host: None, used: std::cell::Cell::new(false) }))
        } else {
            // Single tuple output: split on host, re-upload the caches.
            // The host literals are kept alive inside `CacheBufs`: the
            // TFRT CPU client copies from them ASYNCHRONOUSLY and reads
            // them at the next execute (use-after-free segfault if
            // dropped here).
            let tuple = replica.pop().context("no outputs")?.to_literal_sync()?;
            cache.used.set(true); // execution completed → uploads consumed
            let parts = tuple.to_tuple()?;
            let [lp, k_new, v_new]: [Literal; 3] =
                parts.try_into().map_err(|_| anyhow::anyhow!("expected 3 outputs"))?;
            let logprobs = lp.to_vec::<f32>()?;
            let k_buf = self.client.buffer_from_host_literal(None, &k_new)?;
            let v_buf = self.client.buffer_from_host_literal(None, &v_new)?;
            Ok((
                logprobs,
                CacheBufs {
                    k: k_buf,
                    v: v_buf,
                    _host: Some((k_new, v_new)),
                    used: std::cell::Cell::new(false),
                },
            ))
        }
    }
}

/// KV cache device buffers + (when needed) the host literals backing a
/// pending async upload.
///
/// Lifecycle contract: the TFRT CPU client enqueues host→device copies
/// asynchronously. A `CacheBufs` whose buffers were consumed by a
/// *completed* execution is safe to drop (the execution forced the
/// copies). One that was never executed must block on the pending copies
/// before freeing the backing literals — `Drop` does that via a forced
/// readback when `used` was never set.
pub struct CacheBufs {
    k: PjRtBuffer,
    v: PjRtBuffer,
    _host: Option<(Literal, Literal)>,
    used: std::cell::Cell<bool>,
}

impl Drop for CacheBufs {
    fn drop(&mut self) {
        if self._host.is_some() && !self.used.get() {
            // Force the pending async uploads to finish while the host
            // literals are still alive.
            let _ = self.k.to_literal_sync();
            let _ = self.v.to_literal_sync();
        }
    }
}

/// A single-lane (B=1) session over the shared model.
pub struct PjrtLm {
    model: Arc<PjrtModel>,
    cache: CacheBufs,
    len: usize,
    chunk_sizes: Vec<usize>,
}

impl PjrtLm {
    pub fn new(model: Arc<PjrtModel>) -> crate::Result<PjrtLm> {
        let cache = model.new_cache(1)?;
        let chunk_sizes = model.chunk_sizes(1);
        anyhow::ensure!(!chunk_sizes.is_empty(), "no B=1 executables in bundle");
        Ok(PjrtLm { model, cache, len: 0, chunk_sizes })
    }

    /// Run one exact-C chunk (padded if needed); returns the logprob rows
    /// for the real tokens.
    fn run_chunk(&mut self, tokens: &[i32], c: usize) -> crate::Result<Vec<Vec<f32>>> {
        let v_sz = self.model.config.vocab_size;
        let mut padded = tokens.to_vec();
        padded.resize(c, crate::tokenizer::PAD_ID as i32);
        let (lp, cache) =
            self.model.run(1, c, &self.cache, &[self.len as i32], &padded, None)?;
        self.cache = cache;
        self.len += tokens.len();
        Ok(lp.chunks(v_sz).take(tokens.len()).map(|r| r.to_vec()).collect())
    }

    fn check_capacity(&self, n: usize) -> crate::Result<()> {
        // Headroom: padded chunk tails may write past the logical end.
        let max_c = self.chunk_sizes.first().copied().unwrap_or(1);
        anyhow::ensure!(
            self.len + n + max_c < self.model.config.max_seq,
            "context overflow: {} + {} exceeds max_seq {}",
            self.len,
            n,
            self.model.config.max_seq
        );
        Ok(())
    }

    /// Plan `(take, exe_chunk)` pieces for `n` tokens.
    ///
    /// One padded chunk beats several small calls on this backend (the
    /// per-call overhead dominates: C=1 ≈ 1.8 ms, C=8 ≈ 2.5 ms, C=16 ≈
    /// 3.2 ms — §Perf), so: full max-size chunks while they fit, then ONE
    /// call in the smallest executable that holds the remainder.
    fn plan(&self, mut n: usize) -> Vec<(usize, usize)> {
        let max_c = self.chunk_sizes.first().copied().unwrap_or(1);
        let mut out = Vec::new();
        while n > 0 {
            if n >= max_c {
                out.push((max_c, max_c));
                n -= max_c;
            } else {
                // Smallest executable chunk that holds the remainder.
                let exe_c =
                    self.chunk_sizes.iter().rev().copied().find(|&c| c >= n).unwrap_or(n);
                out.push((n, exe_c));
                n = 0;
            }
        }
        out
    }
}

impl LmSession for PjrtLm {
    fn vocab_size(&self) -> usize {
        self.model.config.vocab_size
    }

    fn len(&self) -> usize {
        self.len
    }

    fn append(&mut self, tokens: &[TokenId]) -> crate::Result<Vec<f32>> {
        if tokens.is_empty() {
            bail!("append of zero tokens has no fresh logits row");
        }
        self.check_capacity(tokens.len())?;
        let ids: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let mut i = 0;
        let mut last_row: Option<Vec<f32>> = None;
        for (take, exe_c) in self.plan(ids.len()) {
            let rows = self.run_chunk(&ids[i..i + take], exe_c)?;
            last_row = rows.into_iter().last();
            i += take;
        }
        last_row.context("no logits row produced")
    }

    fn append_scored(&mut self, tokens: &[TokenId]) -> crate::Result<Vec<Vec<f32>>> {
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        self.check_capacity(tokens.len())?;
        let ids: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let mut rows = Vec::with_capacity(ids.len());
        let mut i = 0;
        for (take, exe_c) in self.plan(ids.len()) {
            rows.extend(self.run_chunk(&ids[i..i + take], exe_c)?);
            i += take;
        }
        Ok(rows)
    }

    fn rollback(&mut self, n: usize) -> crate::Result<()> {
        anyhow::ensure!(n <= self.len, "rollback past start");
        // Functional cache: entries past `len` are invisible to the
        // attention mask and overwritten by later appends.
        self.len -= n;
        Ok(())
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

/// Factory for serving: one session per request slot.
///
/// `forward_batch` currently inherits the sequential per-lane fallback:
/// the bundle's B=4 executable variants could serve a true cross-slot
/// batch, but each `PjrtLm` owns a B=1 KV cache, so real batching here
/// needs slot-pinned lanes inside one shared B-wide cache (the per-slot
/// `kv_len` row already supports ragged lengths). The engine-side
/// gather/finish pipeline and this trait boundary are exactly the shape
/// that upgrade drops into.
pub struct PjrtFactory {
    pub model: Arc<PjrtModel>,
}

impl LmBackend for PjrtFactory {
    fn vocab_size(&self) -> usize {
        self.model.config.vocab_size
    }

    fn new_session(&self) -> crate::Result<Box<dyn LmSession>> {
        Ok(Box::new(PjrtLm::new(self.model.clone())?))
    }
}

