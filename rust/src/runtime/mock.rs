//! Deterministic n-gram mock LM.
//!
//! Tests and artifact-less bench fallbacks need an LM whose "natural"
//! distribution (a) follows the structured formats the grammars describe,
//! (b) is reproducible. A trigram model with interpolated backoff over a
//! synthetic corpus does both — and, crucially for the invasiveness
//! experiments, it has *tokenization preferences* (it assigns high
//! probability to corpus-typical token sequences), so misaligned
//! constraining measurably degrades it just like a real LLM.

use super::{BatchLane, LaneRows, LmBackend, LmSession};
use crate::tokenizer::Vocab;
use crate::TokenId;
use std::collections::HashMap;
use std::sync::Arc;

/// Shared trigram tables, stored pre-normalized for the batched forward:
/// the smoothed-unigram *base row* (identical for every context) is
/// precomputed once at train time, in both probability and log space, and
/// the bigram/trigram terms are kept as sparse, already-weighted
/// contribution lists.
pub struct MockModel {
    vocab_size: usize,
    /// Smoothed-unigram term of the interpolation, per token.
    base_probs: Vec<f32>,
    /// `ln(max(base_probs, 1e-9))` — the logits row of a context with no
    /// n-gram matches; batched rows start as a copy of this.
    base_logits: Vec<f32>,
    /// Per-predecessor sparse contributions, pre-weighted `0.25·c/Σc`.
    bigram: HashMap<TokenId, Vec<(TokenId, f32)>>,
    /// Per-bigram sparse contributions, pre-weighted `0.70·c/Σc`.
    trigram: HashMap<(TokenId, TokenId), Vec<(TokenId, f32)>>,
}

impl MockModel {
    /// Train on raw text: encode with `vocab`, count n-grams. Documents
    /// are separated by EOS so the model learns to stop.
    pub fn train(vocab: &Vocab, documents: &[&str]) -> Arc<MockModel> {
        let mut unigram = vec![0f32; vocab.len()];
        let mut bigram: HashMap<TokenId, HashMap<TokenId, f32>> = HashMap::new();
        let mut trigram: HashMap<(TokenId, TokenId), HashMap<TokenId, f32>> = HashMap::new();
        for doc in documents {
            let mut ids = vec![crate::tokenizer::BOS_ID];
            ids.extend(vocab.encode(doc.as_bytes()));
            ids.push(crate::tokenizer::EOS_ID);
            for w in ids.windows(2) {
                unigram[w[1] as usize] += 1.0;
                *bigram.entry(w[0]).or_default().entry(w[1]).or_insert(0.0) += 1.0;
            }
            for w in ids.windows(3) {
                *trigram.entry((w[0], w[1])).or_default().entry(w[2]).or_insert(0.0) += 1.0;
            }
        }
        let n = vocab.len() as f32;
        let uni_total: f32 = unigram.iter().sum::<f32>().max(1.0);
        let base_probs: Vec<f32> =
            unigram.iter().map(|&c| 0.05 * (c + 0.1) / (uni_total + 0.1 * n)).collect();
        let base_logits: Vec<f32> = base_probs.iter().map(|&p| p.max(1e-9).ln()).collect();
        let normalize = |m: HashMap<TokenId, f32>, weight: f32| -> Vec<(TokenId, f32)> {
            let total: f32 = m.values().sum();
            let mut v: Vec<(TokenId, f32)> =
                m.into_iter().map(|(t, c)| (t, weight * c / total)).collect();
            v.sort_unstable_by_key(|&(t, _)| t);
            v
        };
        Arc::new(MockModel {
            vocab_size: vocab.len(),
            base_probs,
            base_logits,
            bigram: bigram.into_iter().map(|(k, m)| (k, normalize(m, 0.25))).collect(),
            trigram: trigram.into_iter().map(|(k, m)| (k, normalize(m, 0.70))).collect(),
        })
    }

    /// Feed `context`'s sparse interpolation contributions to `add`,
    /// bigram before trigram. Both logits paths apply contributions in
    /// this exact order, so an index touched by both maps accumulates
    /// bitwise-identically on either path.
    fn sparse_contributions(&self, context: &[TokenId], mut add: impl FnMut(TokenId, f32)) {
        let last = context.last().copied().unwrap_or(crate::tokenizer::BOS_ID);
        if let Some(v) = self.bigram.get(&last) {
            for &(t, p) in v {
                add(t, p);
            }
        }
        if !context.is_empty() {
            let prev = if context.len() >= 2 {
                context[context.len() - 2]
            } else {
                crate::tokenizer::BOS_ID
            };
            if let Some(v) = self.trigram.get(&(prev, last)) {
                for &(t, p) in v {
                    add(t, p);
                }
            }
        }
    }

    /// Logits for the next token after `context` (interpolated trigram →
    /// bigram → unigram → uniform smoothing).
    ///
    /// This is the *scalar* path: a full-row recompute (O(V) `ln`) per
    /// call — deliberately the per-call cost a real backend pays for one
    /// forward pass, so benches comparing per-slot stepping against the
    /// batched path measure a realistic cost structure.
    pub fn next_logits(&self, context: &[TokenId]) -> Vec<f32> {
        let mut probs = self.base_probs.clone();
        self.sparse_contributions(context, |t, p| probs[t as usize] += p);
        probs.iter().map(|&p| p.max(1e-9).ln()).collect()
    }

    /// One row of the batched forward: copy the precomputed base-logits
    /// row, then recompute only the sparse indices this context touches —
    /// O(V) memcpy + O(K) `ln` instead of the scalar path's O(V) `ln`,
    /// with the base-row work shared across every lane of the batch.
    ///
    /// Bitwise-identical to [`MockModel::next_logits`] (same
    /// contributions, same accumulation order, same float expressions);
    /// the batched-vs-per-slot parity tests and the engine's
    /// token-identical guarantee rely on that.
    fn next_logits_row(&self, context: &[TokenId], touched: &mut Vec<(TokenId, f32)>) -> Vec<f32> {
        let mut row = self.base_logits.clone();
        touched.clear();
        let base_probs = &self.base_probs;
        self.sparse_contributions(context, |t, p| {
            if let Some(entry) = touched.iter_mut().find(|e| e.0 == t) {
                entry.1 += p;
            } else {
                touched.push((t, base_probs[t as usize] + p));
            }
        });
        for &(t, acc) in touched.iter() {
            row[t as usize] = acc.max(1e-9).ln();
        }
        row
    }
}

/// A session over the shared model: context vector + logits on demand.
pub struct MockLm {
    model: Arc<MockModel>,
    context: Vec<TokenId>,
}

impl MockLm {
    pub fn new(model: Arc<MockModel>) -> MockLm {
        MockLm { model, context: Vec::new() }
    }
}

impl LmSession for MockLm {
    fn vocab_size(&self) -> usize {
        self.model.vocab_size
    }

    fn len(&self) -> usize {
        self.context.len()
    }

    fn append(&mut self, tokens: &[TokenId]) -> crate::Result<Vec<f32>> {
        self.context.extend_from_slice(tokens);
        Ok(self.model.next_logits(&self.context))
    }

    fn append_scored(&mut self, tokens: &[TokenId]) -> crate::Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(tokens.len());
        for &t in tokens {
            self.context.push(t);
            out.push(self.model.next_logits(&self.context));
        }
        Ok(out)
    }

    fn rollback(&mut self, n: usize) -> crate::Result<()> {
        anyhow::ensure!(n <= self.context.len(), "rollback past start");
        self.context.truncate(self.context.len() - n);
        Ok(())
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

/// Factory over a shared mock model. Its [`LmBackend::forward_batch`] is
/// the true vectorized path — the per-batch base-row precomputation is
/// shared across lanes — not a per-lane `append` loop, so benches
/// comparing batched vs per-slot stepping measure real batching.
pub struct MockFactory {
    pub model: Arc<MockModel>,
}

impl LmBackend for MockFactory {
    fn vocab_size(&self) -> usize {
        self.model.vocab_size
    }

    fn new_session(&self) -> crate::Result<Box<dyn LmSession>> {
        Ok(Box::new(MockLm::new(self.model.clone())))
    }

    /// Vectorized cross-lane forward: every lane backed by this factory's
    /// own [`MockModel`] gets the shared-base-row fast path
    /// ([`MockModel::next_logits_row`]); a lane this backend doesn't
    /// recognize (wrapper sessions, other models) falls back to its own
    /// `append`, so mixed batches stay correct lane-by-lane.
    fn forward_batch(&self, lanes: &mut [BatchLane<'_>]) -> Vec<crate::Result<LaneRows>> {
        // Scratch for the sparse indices of each row, reused across the
        // whole batch (zero steady-state allocation besides the rows).
        let mut touched: Vec<(TokenId, f32)> = Vec::new();
        lanes
            .iter_mut()
            .map(|lane| {
                let downcast = lane.session.as_any_mut().and_then(|a| a.downcast_mut::<MockLm>());
                let vectorized = match downcast {
                    Some(m) if Arc::ptr_eq(&m.model, &self.model) => {
                        Some(if lane.scored {
                            let mut rows = Vec::with_capacity(lane.tokens.len());
                            for &t in &lane.tokens {
                                m.context.push(t);
                                rows.push(self.model.next_logits_row(&m.context, &mut touched));
                            }
                            rows
                        } else {
                            m.context.extend_from_slice(&lane.tokens);
                            vec![self.model.next_logits_row(&m.context, &mut touched)]
                        })
                    }
                    _ => None,
                };
                match vectorized {
                    Some(rows) => Ok(rows),
                    // Foreign session: sequential fallback for this lane.
                    None if lane.scored => lane.session.append_scored(&lane.tokens),
                    None => lane.session.append(&lane.tokens).map(|row| vec![row]),
                }
            })
            .collect()
    }
}

/// A ready-made mock setup over JSON-ish documents — the shared fixture
/// for tests and artifact-less benches.
pub fn json_mock(vocab_size: usize) -> (Arc<Vocab>, Arc<MockModel>) {
    let vocab = Arc::new(crate::tokenizer::bpe::synthetic_json_vocab(vocab_size));
    let docs: Vec<String> = (0..60)
        .map(|i| {
            let names = ["John Doe", "Jane Roe", "Alice Li", "Bob Iger", "Eve Fox"];
            let jobs = ["engineer", "doctor", "teacher", "artist", "pilot"];
            format!(
                "{{\"name\": \"{}\", \"age\": {}, \"occupation\": \"{}\"}}",
                names[i % 5],
                20 + (i % 50),
                jobs[(i / 5) % 5]
            )
        })
        .collect();
    let doc_refs: Vec<&str> = docs.iter().map(|s| s.as_str()).collect();
    let model = MockModel::train(&vocab, &doc_refs);
    (vocab, model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sampler::argmax;

    #[test]
    fn deterministic_and_rollback() {
        let (vocab, model) = json_mock(512);
        let mut s = MockLm::new(model.clone());
        let ids = vocab.encode(b"{\"name\": ");
        let a = s.append(&ids).unwrap();
        s.rollback(ids.len()).unwrap();
        let b = s.append(&ids).unwrap();
        assert_eq!(a, b);
        assert_eq!(s.len(), ids.len());
    }

    #[test]
    fn learns_corpus_structure() {
        let (vocab, model) = json_mock(512);
        let mut s = MockLm::new(model);
        // Feeding a corpus-typical prefix (in the corpus' own
        // tokenization), the model must continue it as in the corpus.
        let doc = b"{\"name\": \"John Doe\", \"age\": 35, \"occupation\": \"doctor\"}";
        let ids = vocab.encode(doc);
        assert!(ids.len() >= 4, "corpus docs must be multi-token");
        let logits = s.append(&ids[..2]).unwrap();
        let top = argmax(&logits);
        assert_eq!(top, ids[2], "expected {:?}, got {:?}", vocab.token_str(ids[2]), vocab.token_str(top));
    }

    #[test]
    fn append_scored_matches_append() {
        let (vocab, model) = json_mock(512);
        let ids = vocab.encode(b"{\"age\": 4");
        let mut a = MockLm::new(model.clone());
        let rows = a.append_scored(&ids).unwrap();
        let mut b = MockLm::new(model);
        let last = b.append(&ids).unwrap();
        assert_eq!(rows.last().unwrap(), &last);
        assert_eq!(rows.len(), ids.len());
    }

    #[test]
    fn forward_batch_bitwise_matches_append() {
        let (vocab, model) = json_mock(512);
        let f = MockFactory { model: model.clone() };
        let exts: Vec<Vec<TokenId>> = vec![
            vocab.encode(b"{\"name\": "),
            vocab.encode(b"{\"age\": 4"),
            vocab.encode(b"{"),
        ];
        // Reference: the scalar per-session path (lane 1 scored).
        let mut want = Vec::new();
        for (i, ext) in exts.iter().enumerate() {
            let mut s = MockLm::new(model.clone());
            if i == 1 {
                want.push(s.append_scored(ext).unwrap());
            } else {
                want.push(vec![s.append(ext).unwrap()]);
            }
        }
        // One batched forward over all three lanes (mixed plain+scored).
        let mut sessions: Vec<Box<dyn LmSession>> =
            (0..exts.len()).map(|_| f.new_session().unwrap()).collect();
        let mut lanes: Vec<BatchLane> = sessions
            .iter_mut()
            .zip(&exts)
            .enumerate()
            .map(|(i, (s, ext))| BatchLane {
                session: s.as_mut(),
                tokens: ext.clone(),
                scored: i == 1,
            })
            .collect();
        let got = f.forward_batch(&mut lanes);
        drop(lanes);
        for (g, w) in got.into_iter().zip(want) {
            // Bitwise float equality: the vectorized fast path must agree
            // exactly with the scalar path or batched decoding diverges.
            assert_eq!(g.unwrap(), w);
        }
        for (s, ext) in sessions.iter().zip(&exts) {
            assert_eq!(s.len(), ext.len(), "lane session must have advanced");
        }
    }

    #[test]
    fn forward_batch_foreign_model_falls_back() {
        let (vocab, m1) = json_mock(512);
        let (_v2, m2) = json_mock(512);
        let f = MockFactory { model: m1 };
        // A session over a different model instance: not vectorizable by
        // this backend, must take the per-lane fallback and still answer
        // from its own model.
        let mut foreign = MockLm::new(m2.clone());
        let ids = vocab.encode(b"{\"age\": 1");
        let want = MockLm::new(m2).append(&ids).unwrap();
        let mut lanes =
            vec![BatchLane { session: &mut foreign, tokens: ids.clone(), scored: false }];
        let got = f.forward_batch(&mut lanes);
        drop(lanes);
        assert_eq!(got[0].as_ref().unwrap()[0], want);
        assert_eq!(foreign.len(), ids.len());
    }

    #[test]
    fn eos_learned_at_document_end() {
        let (vocab, model) = json_mock(512);
        let mut s = MockLm::new(model);
        let logits = s
            .append(&vocab.encode(b"{\"name\": \"John Doe\", \"age\": 35, \"occupation\": \"doctor\"}"))
            .unwrap();
        assert_eq!(argmax(&logits), crate::tokenizer::EOS_ID);
    }
}
