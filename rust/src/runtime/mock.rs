//! Deterministic n-gram mock LM.
//!
//! Tests and artifact-less bench fallbacks need an LM whose "natural"
//! distribution (a) follows the structured formats the grammars describe,
//! (b) is reproducible. A trigram model with interpolated backoff over a
//! synthetic corpus does both — and, crucially for the invasiveness
//! experiments, it has *tokenization preferences* (it assigns high
//! probability to corpus-typical token sequences), so misaligned
//! constraining measurably degrades it just like a real LLM.

use super::{LmFactory, LmSession};
use crate::tokenizer::Vocab;
use crate::TokenId;
use std::collections::HashMap;
use std::sync::Arc;

/// Shared trigram tables.
pub struct MockModel {
    vocab_size: usize,
    unigram: Vec<f32>,
    bigram: HashMap<TokenId, HashMap<TokenId, f32>>,
    trigram: HashMap<(TokenId, TokenId), HashMap<TokenId, f32>>,
}

impl MockModel {
    /// Train on raw text: encode with `vocab`, count n-grams. Documents
    /// are separated by EOS so the model learns to stop.
    pub fn train(vocab: &Vocab, documents: &[&str]) -> Arc<MockModel> {
        let mut unigram = vec![0f32; vocab.len()];
        let mut bigram: HashMap<TokenId, HashMap<TokenId, f32>> = HashMap::new();
        let mut trigram: HashMap<(TokenId, TokenId), HashMap<TokenId, f32>> = HashMap::new();
        for doc in documents {
            let mut ids = vec![crate::tokenizer::BOS_ID];
            ids.extend(vocab.encode(doc.as_bytes()));
            ids.push(crate::tokenizer::EOS_ID);
            for w in ids.windows(2) {
                unigram[w[1] as usize] += 1.0;
                *bigram.entry(w[0]).or_default().entry(w[1]).or_insert(0.0) += 1.0;
            }
            for w in ids.windows(3) {
                *trigram.entry((w[0], w[1])).or_default().entry(w[2]).or_insert(0.0) += 1.0;
            }
        }
        Arc::new(MockModel { vocab_size: vocab.len(), unigram, bigram, trigram })
    }

    /// Logits for the next token after `context` (interpolated trigram →
    /// bigram → unigram → uniform smoothing).
    pub fn next_logits(&self, context: &[TokenId]) -> Vec<f32> {
        let n = self.vocab_size as f32;
        let uni_total: f32 = self.unigram.iter().sum::<f32>().max(1.0);
        let mut probs: Vec<f32> = self
            .unigram
            .iter()
            .map(|&c| 0.05 * (c + 0.1) / (uni_total + 0.1 * n))
            .collect();
        let last = context.last().copied().unwrap_or(crate::tokenizer::BOS_ID);
        if let Some(m) = self.bigram.get(&last) {
            let total: f32 = m.values().sum();
            for (&t, &c) in m {
                probs[t as usize] += 0.25 * c / total;
            }
        }
        if context.len() >= 1 {
            let prev = if context.len() >= 2 {
                context[context.len() - 2]
            } else {
                crate::tokenizer::BOS_ID
            };
            if let Some(m) = self.trigram.get(&(prev, last)) {
                let total: f32 = m.values().sum();
                for (&t, &c) in m {
                    probs[t as usize] += 0.70 * c / total;
                }
            }
        }
        probs.iter().map(|&p| p.max(1e-9).ln()).collect()
    }
}

/// A session over the shared model: context vector + logits on demand.
pub struct MockLm {
    model: Arc<MockModel>,
    context: Vec<TokenId>,
}

impl MockLm {
    pub fn new(model: Arc<MockModel>) -> MockLm {
        MockLm { model, context: Vec::new() }
    }
}

impl LmSession for MockLm {
    fn vocab_size(&self) -> usize {
        self.model.vocab_size
    }

    fn len(&self) -> usize {
        self.context.len()
    }

    fn append(&mut self, tokens: &[TokenId]) -> crate::Result<Vec<f32>> {
        self.context.extend_from_slice(tokens);
        Ok(self.model.next_logits(&self.context))
    }

    fn append_scored(&mut self, tokens: &[TokenId]) -> crate::Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(tokens.len());
        for &t in tokens {
            self.context.push(t);
            out.push(self.model.next_logits(&self.context));
        }
        Ok(out)
    }

    fn rollback(&mut self, n: usize) -> crate::Result<()> {
        anyhow::ensure!(n <= self.context.len(), "rollback past start");
        self.context.truncate(self.context.len() - n);
        Ok(())
    }
}

/// Factory over a shared mock model.
pub struct MockFactory {
    pub model: Arc<MockModel>,
}

impl LmFactory for MockFactory {
    fn vocab_size(&self) -> usize {
        self.model.vocab_size
    }

    fn new_session(&self) -> crate::Result<Box<dyn LmSession>> {
        Ok(Box::new(MockLm::new(self.model.clone())))
    }
}

/// A ready-made mock setup over JSON-ish documents — the shared fixture
/// for tests and artifact-less benches.
pub fn json_mock(vocab_size: usize) -> (Arc<Vocab>, Arc<MockModel>) {
    let vocab = Arc::new(crate::tokenizer::bpe::synthetic_json_vocab(vocab_size));
    let docs: Vec<String> = (0..60)
        .map(|i| {
            let names = ["John Doe", "Jane Roe", "Alice Li", "Bob Iger", "Eve Fox"];
            let jobs = ["engineer", "doctor", "teacher", "artist", "pilot"];
            format!(
                "{{\"name\": \"{}\", \"age\": {}, \"occupation\": \"{}\"}}",
                names[i % 5],
                20 + (i % 50),
                jobs[(i / 5) % 5]
            )
        })
        .collect();
    let doc_refs: Vec<&str> = docs.iter().map(|s| s.as_str()).collect();
    let model = MockModel::train(&vocab, &doc_refs);
    (vocab, model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sampler::argmax;

    #[test]
    fn deterministic_and_rollback() {
        let (vocab, model) = json_mock(512);
        let mut s = MockLm::new(model.clone());
        let ids = vocab.encode(b"{\"name\": ");
        let a = s.append(&ids).unwrap();
        s.rollback(ids.len()).unwrap();
        let b = s.append(&ids).unwrap();
        assert_eq!(a, b);
        assert_eq!(s.len(), ids.len());
    }

    #[test]
    fn learns_corpus_structure() {
        let (vocab, model) = json_mock(512);
        let mut s = MockLm::new(model);
        // Feeding a corpus-typical prefix (in the corpus' own
        // tokenization), the model must continue it as in the corpus.
        let doc = b"{\"name\": \"John Doe\", \"age\": 35, \"occupation\": \"doctor\"}";
        let ids = vocab.encode(doc);
        assert!(ids.len() >= 4, "corpus docs must be multi-token");
        let logits = s.append(&ids[..2]).unwrap();
        let top = argmax(&logits);
        assert_eq!(top, ids[2], "expected {:?}, got {:?}", vocab.token_str(ids[2]), vocab.token_str(top));
    }

    #[test]
    fn append_scored_matches_append() {
        let (vocab, model) = json_mock(512);
        let ids = vocab.encode(b"{\"age\": 4");
        let mut a = MockLm::new(model.clone());
        let rows = a.append_scored(&ids).unwrap();
        let mut b = MockLm::new(model);
        let last = b.append(&ids).unwrap();
        assert_eq!(rows.last().unwrap(), &last);
        assert_eq!(rows.len(), ids.len());
    }

    #[test]
    fn eos_learned_at_document_end() {
        let (vocab, model) = json_mock(512);
        let mut s = MockLm::new(model);
        let logits = s
            .append(&vocab.encode(b"{\"name\": \"John Doe\", \"age\": 35, \"occupation\": \"doctor\"}"))
            .unwrap();
        assert_eq!(argmax(&logits), crate::tokenizer::EOS_ID);
    }
}
