//! Serving metrics: counters + latency/throughput summaries.

use std::time::Duration;

/// A streaming summary (count/mean/min/max/p50-ish via reservoir).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    samples: Vec<f64>,
}

impl Summary {
    pub fn record(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
        // Simple capped reservoir for percentiles.
        if self.samples.len() < 4096 {
            self.samples.push(v);
        } else {
            let i = (self.count % 4096) as usize;
            self.samples[i] = v;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * p).round() as usize;
        s[idx]
    }
}

/// Aggregate serving metrics (owned by the engine thread).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests_completed: u64,
    pub requests_failed: u64,
    pub tokens_generated: u64,
    pub model_calls: u64,
    pub interventions: u64,
    pub masks_computed: u64,
    pub spec_proposed: u64,
    pub spec_accepted: u64,
    /// Engine-registry lookups served from cache.
    pub registry_hits: u64,
    /// Engine-registry lookups that compiled a grammar.
    pub registry_misses: u64,
    /// Engines dropped by LRU eviction.
    pub registry_evictions: u64,
    /// Lookups that waited on a concurrent build instead of compiling.
    pub registry_coalesced: u64,
    /// Total wall time spent compiling grammar engines, milliseconds.
    pub engine_compile_ms: u64,
    /// State-keyed mask-cache hits (mask reused, no tree traversal).
    pub mask_cache_hits: u64,
    /// Mask-cache misses (mask computed and cached).
    pub mask_cache_misses: u64,
    /// Masks dropped by LRU eviction.
    pub mask_cache_evictions: u64,
    /// Time to first token, seconds.
    pub ttft: Summary,
    /// Per-request tokens/second.
    pub req_tps: Summary,
    /// Mask computation time, microseconds.
    pub mask_us: Summary,
    /// Engine wall time spent in model calls, seconds.
    pub model_time: Duration,
}

impl Metrics {
    pub fn report(&self) -> String {
        format!(
            "requests: {} ok / {} failed | tokens: {} | model calls: {} | \
             interventions: {} | masks: {} | spec: {}/{} accepted | \
             ttft p50 {:.1} ms | req tps mean {:.1} | \
             registry: {} hit / {} miss / {} evict / {} coalesced ({} ms compiling) | \
             mask cache: {} hit / {} miss ({:.0}% hit rate)",
            self.requests_completed,
            self.requests_failed,
            self.tokens_generated,
            self.model_calls,
            self.interventions,
            self.masks_computed,
            self.spec_accepted,
            self.spec_proposed,
            self.ttft.percentile(0.5) * 1e3,
            self.req_tps.mean(),
            self.registry_hits,
            self.registry_misses,
            self.registry_evictions,
            self.registry_coalesced,
            self.engine_compile_ms,
            self.mask_cache_hits,
            self.mask_cache_misses,
            self.mask_cache_hit_rate() * 100.0,
        )
    }

    /// Mask-cache hit rate in [0, 1] (0 when no lookups yet).
    pub fn mask_cache_hit_rate(&self) -> f64 {
        let total = self.mask_cache_hits + self.mask_cache_misses;
        if total == 0 {
            0.0
        } else {
            self.mask_cache_hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let mut s = Summary::default();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.record(v);
        }
        assert_eq!(s.count, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.percentile(0.5), 3.0);
        assert_eq!(s.percentile(1.0), 5.0);
    }

    #[test]
    fn report_formats() {
        let mut m = Metrics::default();
        assert!(m.report().contains("requests"));
        assert!(m.report().contains("registry"));
        assert_eq!(m.mask_cache_hit_rate(), 0.0, "no lookups yet");
        m.mask_cache_hits = 3;
        m.mask_cache_misses = 1;
        assert!((m.mask_cache_hit_rate() - 0.75).abs() < 1e-12);
        assert!(m.report().contains("75% hit rate"));
    }
}
