//! Serving metrics: counters + latency/throughput summaries, plus the
//! metric **registry** — the single source of truth behind both the
//! Prometheus `/metrics` exposition (`render_prometheus`) and the
//! generated operator reference (`metrics_doc`, surfaced as the
//! `domino metrics-doc` subcommand and checked into `docs/METRICS.md`).
//! Names and help strings live once, in [`METRIC_DEFS`], so the wire
//! format and the docs cannot drift.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Duration;

/// Shared log-spaced histogram bounds ({1, 2.5, 5} per decade). One
/// global ladder keeps `Summary` allocation-free of per-metric config
/// and spans every unit we record: seconds (1 µs – 5 ks), microseconds
/// (sub-µs – 5 ms), batch widths (1 – 64) and ratios (0 – 1) all land
/// inside it. Values above the last bound fall into the implicit
/// `+Inf` bucket.
pub const HIST_BOUNDS: [f64; 30] = [
    1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
    5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0,
    2500.0, 5000.0,
];

/// A streaming summary (count/mean/min/max/p50-ish via reservoir) plus
/// fixed-bound histogram buckets for Prometheus exposition.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    samples: Vec<f64>,
    /// Per-bucket (non-cumulative) counts aligned with [`HIST_BOUNDS`];
    /// empty until the first record. `count - buckets.sum()` is the
    /// implicit `+Inf` bucket.
    buckets: Vec<u64>,
}

impl Summary {
    pub fn record(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
        if self.buckets.is_empty() {
            self.buckets = vec![0; HIST_BOUNDS.len()];
        }
        if let Some(i) = HIST_BOUNDS.iter().position(|&b| v <= b) {
            self.buckets[i] += 1;
        }
        // Simple capped reservoir for percentiles.
        if self.samples.len() < 4096 {
            self.samples.push(v);
        } else {
            let i = (self.count % 4096) as usize;
            self.samples[i] = v;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * p).round() as usize;
        s[idx]
    }

    /// Count in bucket `i` of [`HIST_BOUNDS`] (non-cumulative).
    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets.get(i).copied().unwrap_or(0)
    }

    /// Fold `other` into this summary (cross-shard aggregation). Exact for
    /// count/sum/min/max and buckets; the percentile reservoir keeps as
    /// many of the other side's samples as fit under the cap.
    pub fn merge(&mut self, other: &Summary) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.count += other.count;
        self.sum += other.sum;
        if self.buckets.is_empty() {
            self.buckets = vec![0; HIST_BOUNDS.len()];
        }
        for (i, b) in other.buckets.iter().enumerate() {
            self.buckets[i] += b;
        }
        for &v in &other.samples {
            if self.samples.len() >= 4096 {
                break;
            }
            self.samples.push(v);
        }
    }
}

/// Hard cap on per-tenant / per-grammar label cardinality. A gateway
/// must bound what an unauthenticated client can allocate: once a label
/// map holds this many distinct keys, further keys collapse into the
/// `_other` overflow series instead of growing the map.
pub const MAX_LABEL_CARDINALITY: usize = 64;

/// Overflow series name for label maps at [`MAX_LABEL_CARDINALITY`].
pub const OTHER_LABEL: &str = "_other";

/// Fetch-or-insert `key` in a label map, collapsing to [`OTHER_LABEL`]
/// once the map is at [`MAX_LABEL_CARDINALITY`].
pub fn labeled<'a, T: Default>(map: &'a mut BTreeMap<String, T>, key: &str) -> &'a mut T {
    if !map.contains_key(key) && map.len() >= MAX_LABEL_CARDINALITY {
        return map.entry(OTHER_LABEL.to_string()).or_default();
    }
    map.entry(key.to_string()).or_default()
}

/// Per-tenant slice of the serving metrics (keyed by the wire `tenant`
/// field; requests that omit it land under `"default"`).
#[derive(Clone, Debug, Default)]
pub struct TenantMetrics {
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    pub deadline_exceeded: u64,
    pub shed: u64,
    pub tokens_generated: u64,
    /// Admission-queue wait (submit → slot admission), seconds.
    pub queue_wait: Summary,
}

impl TenantMetrics {
    pub fn merge(&mut self, other: &TenantMetrics) {
        self.completed += other.completed;
        self.failed += other.failed;
        self.cancelled += other.cancelled;
        self.deadline_exceeded += other.deadline_exceeded;
        self.shed += other.shed;
        self.tokens_generated += other.tokens_generated;
        self.queue_wait.merge(&other.queue_wait);
    }
}

/// Per-grammar-fingerprint slice of the serving metrics (keyed by the
/// constraint's content fingerprint, hex; unconstrained requests are
/// not tracked here).
#[derive(Clone, Debug, Default)]
pub struct GrammarMetrics {
    pub requests: u64,
    pub tokens_generated: u64,
    pub masks_computed: u64,
    pub interventions: u64,
    /// Mean per-mask computation time for each request, microseconds.
    pub mask_us: Summary,
}

impl GrammarMetrics {
    pub fn merge(&mut self, other: &GrammarMetrics) {
        self.requests += other.requests;
        self.tokens_generated += other.tokens_generated;
        self.masks_computed += other.masks_computed;
        self.interventions += other.interventions;
        self.mask_us.merge(&other.mask_us);
    }
}

/// Aggregate serving metrics (owned by the engine thread).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests_completed: u64,
    pub requests_failed: u64,
    /// Requests aborted mid-decode because the client cancelled or
    /// disconnected (the slot stops burning engine ticks immediately).
    pub requests_cancelled: u64,
    /// Requests aborted because their deadline passed (queued or
    /// mid-decode).
    pub requests_deadline_exceeded: u64,
    /// Requests shed at admission because every eligible shard's queue
    /// was at capacity (the structured `"error":"overloaded"` reply).
    pub requests_shed: u64,
    pub tokens_generated: u64,
    pub model_calls: u64,
    /// Batched cross-slot forward passes executed (one per engine tick
    /// that had at least one lane).
    pub forward_batches: u64,
    /// Total logit rows produced by batched forward passes (a
    /// speculative lane contributes one row per proposed token).
    pub forward_rows: u64,
    /// Lanes per batched forward pass — the batch-width histogram. A
    /// mean near `slots_per_engine` means ticks run at full width; near
    /// 1 means the shard is effectively stepping per-slot.
    pub batch_size: Summary,
    pub interventions: u64,
    pub masks_computed: u64,
    pub spec_proposed: u64,
    pub spec_accepted: u64,
    /// Tokens proposed by draft lanes (grammar-pruned multi-token
    /// drafting; distinct from single-token opportunistic speculation).
    pub draft_proposed: u64,
    /// Draft-lane tokens accepted by batched verification.
    pub draft_accepted: u64,
    /// Engine-registry lookups served from cache.
    pub registry_hits: u64,
    /// Engine-registry lookups that compiled a grammar.
    pub registry_misses: u64,
    /// Engines dropped by LRU eviction.
    pub registry_evictions: u64,
    /// Lookups that waited on a concurrent build instead of compiling.
    pub registry_coalesced: u64,
    /// Total wall time spent compiling grammar engines, milliseconds.
    pub engine_compile_ms: u64,
    /// Engines deserialized from the persistent artifact store (warm
    /// starts + on-demand loads) instead of compiled.
    pub artifact_hits: u64,
    /// Artifact-store lookups that found nothing (the compile wrote one
    /// back).
    pub artifact_misses: u64,
    /// Artifacts rejected as unusable (truncated / checksum / version /
    /// vocab mismatch); each fell back to a clean rebuild.
    pub artifact_invalid: u64,
    /// Engines registered by the boot-time warm-start scan.
    pub warm_start_loaded: u64,
    /// Wall time of the boot-time warm-start scan, milliseconds.
    pub warm_start_ms: u64,
    /// State-keyed mask-cache hits (mask reused, no tree traversal).
    pub mask_cache_hits: u64,
    /// Mask-cache misses (mask computed and cached).
    pub mask_cache_misses: u64,
    /// Masks dropped by LRU eviction.
    pub mask_cache_evictions: u64,
    /// Gateway connections currently open (JSONL + metrics listeners).
    pub connections_open: u64,
    /// Connections accepted by the gateway since boot.
    pub connections_accepted: u64,
    /// Connections refused at accept because `--max-connections` was
    /// reached (the client saw `"overloaded"`/`"connection_limit"`).
    pub connections_rejected: u64,
    /// Connections closed by the gateway idle timeout.
    pub connections_idle_timeout: u64,
    /// Connections closed by the gateway read (partial-request) timeout.
    pub connections_read_timeout: u64,
    /// Connections cut by the gateway write-stall timeout (the peer
    /// stopped reading a non-empty reply buffer).
    pub connections_write_stall: u64,
    /// Engines resident in the registry's hot tier (full mask caches).
    pub registry_hot_entries: u64,
    /// Engines resident in the warm tier (compiled, mask caches dropped).
    pub registry_warm_entries: u64,
    /// Artifacts indexed on disk but not resident (cold tier).
    pub registry_cold_entries: u64,
    /// Gateway connection lifetime, seconds (recorded at close).
    pub conn_lifetime: Summary,
    /// Time to first token, seconds.
    pub ttft: Summary,
    /// Admission-queue wait (submit → slot admission), seconds.
    pub queue_wait: Summary,
    /// Per-request tokens/second.
    pub req_tps: Summary,
    /// Mask computation time, microseconds (per-request mean across the
    /// masks that request computed).
    pub mask_us: Summary,
    /// Engine-tick wall time (one `step_all` over the live slots),
    /// seconds.
    pub tick_time: Summary,
    /// Tick decide-phase wall time (per-slot mask/sample/commit, no
    /// model calls), seconds. With the other three phase summaries this
    /// gives operators phase attribution without tracing on.
    pub tick_decide: Summary,
    /// Tick gather-phase wall time (collecting pending extensions into
    /// batch lanes), seconds.
    pub tick_gather: Summary,
    /// Tick forward-phase wall time (the single batched model call),
    /// seconds.
    pub tick_forward: Summary,
    /// Tick finish-phase wall time (verify / commit / stream), seconds.
    pub tick_finish: Summary,
    /// Traces captured by head sampling (`--trace-sample-rate`).
    pub traces_sampled: u64,
    /// Traces captured because the request set `"trace": true`.
    pub traces_requested: u64,
    /// Traces captured tail-based because the request aborted.
    pub traces_aborted: u64,
    /// Traces captured tail-based because the request exceeded
    /// `--trace-slow-ms`.
    pub traces_slow: u64,
    /// Per-request draft acceptance ratio (accepted / proposed) for
    /// requests that ran the draft lane.
    pub draft_acceptance: Summary,
    /// Engine wall time spent in model calls, seconds.
    pub model_time: Duration,
    /// Structured abort/shed accounting keyed `"kind/reason"` — e.g.
    /// `cancelled/client_disconnect`, `deadline/queued`,
    /// `shed/tenant_quota`. The same reason strings travel on the wire
    /// in the response `reason` field.
    pub abort_reasons: BTreeMap<String, u64>,
    /// Per-tenant metrics (cardinality-capped; see [`labeled`]).
    pub tenants: BTreeMap<String, TenantMetrics>,
    /// Per-grammar-fingerprint metrics (cardinality-capped).
    pub grammars: BTreeMap<String, GrammarMetrics>,
}

impl Metrics {
    /// Record a structured abort/shed reason (`kind` and `reason` both
    /// appear as labels on `domino_requests_aborted_total`).
    pub fn record_abort(&mut self, kind: &str, reason: &str) {
        *labeled(&mut self.abort_reasons, &format!("{kind}/{reason}")) += 1;
    }

    /// Per-tenant slice for `tenant`, creating it on first use.
    pub fn tenant(&mut self, tenant: &str) -> &mut TenantMetrics {
        labeled(&mut self.tenants, tenant)
    }

    /// Per-grammar slice for fingerprint `fp`, creating it on first use.
    pub fn grammar(&mut self, fp: &str) -> &mut GrammarMetrics {
        labeled(&mut self.grammars, fp)
    }

    /// Fold another shard's snapshot into this one (cross-shard
    /// aggregation for `Scheduler::metrics` and the TCP `stats` op).
    ///
    /// Engine-loop counters and summaries are per-shard and sum; the
    /// registry/mask-cache counters are pulled from the **shared**
    /// registry by every shard's snapshot, so summing would multiply
    /// them by the shard count — they aggregate by `max` instead (the
    /// counters are monotonic, so the max is the freshest snapshot).
    pub fn merge(&mut self, other: &Metrics) {
        self.requests_completed += other.requests_completed;
        self.requests_failed += other.requests_failed;
        self.requests_cancelled += other.requests_cancelled;
        self.requests_deadline_exceeded += other.requests_deadline_exceeded;
        self.requests_shed += other.requests_shed;
        self.tokens_generated += other.tokens_generated;
        self.model_calls += other.model_calls;
        self.forward_batches += other.forward_batches;
        self.forward_rows += other.forward_rows;
        self.batch_size.merge(&other.batch_size);
        self.interventions += other.interventions;
        self.masks_computed += other.masks_computed;
        self.spec_proposed += other.spec_proposed;
        self.spec_accepted += other.spec_accepted;
        self.draft_proposed += other.draft_proposed;
        self.draft_accepted += other.draft_accepted;
        self.registry_hits = self.registry_hits.max(other.registry_hits);
        self.registry_misses = self.registry_misses.max(other.registry_misses);
        self.registry_evictions = self.registry_evictions.max(other.registry_evictions);
        self.registry_coalesced = self.registry_coalesced.max(other.registry_coalesced);
        self.engine_compile_ms = self.engine_compile_ms.max(other.engine_compile_ms);
        self.artifact_hits = self.artifact_hits.max(other.artifact_hits);
        self.artifact_misses = self.artifact_misses.max(other.artifact_misses);
        self.artifact_invalid = self.artifact_invalid.max(other.artifact_invalid);
        self.warm_start_loaded = self.warm_start_loaded.max(other.warm_start_loaded);
        self.warm_start_ms = self.warm_start_ms.max(other.warm_start_ms);
        self.mask_cache_hits = self.mask_cache_hits.max(other.mask_cache_hits);
        self.mask_cache_misses = self.mask_cache_misses.max(other.mask_cache_misses);
        self.mask_cache_evictions = self.mask_cache_evictions.max(other.mask_cache_evictions);
        // Connection counters and registry tier gauges have a single
        // source (the gateway reactor / the shared registry), so they
        // aggregate by max like the other shared-source fields.
        self.connections_open = self.connections_open.max(other.connections_open);
        self.connections_accepted = self.connections_accepted.max(other.connections_accepted);
        self.connections_rejected = self.connections_rejected.max(other.connections_rejected);
        self.connections_idle_timeout =
            self.connections_idle_timeout.max(other.connections_idle_timeout);
        self.connections_read_timeout =
            self.connections_read_timeout.max(other.connections_read_timeout);
        self.connections_write_stall =
            self.connections_write_stall.max(other.connections_write_stall);
        self.registry_hot_entries = self.registry_hot_entries.max(other.registry_hot_entries);
        self.registry_warm_entries = self.registry_warm_entries.max(other.registry_warm_entries);
        self.registry_cold_entries = self.registry_cold_entries.max(other.registry_cold_entries);
        self.conn_lifetime.merge(&other.conn_lifetime);
        self.ttft.merge(&other.ttft);
        self.queue_wait.merge(&other.queue_wait);
        self.req_tps.merge(&other.req_tps);
        self.mask_us.merge(&other.mask_us);
        self.tick_time.merge(&other.tick_time);
        self.tick_decide.merge(&other.tick_decide);
        self.tick_gather.merge(&other.tick_gather);
        self.tick_forward.merge(&other.tick_forward);
        self.tick_finish.merge(&other.tick_finish);
        // Trace-capture counters have a single source (the scheduler's
        // shared tracer fills them at aggregation), so max-merge like the
        // other shared-source counters.
        self.traces_sampled = self.traces_sampled.max(other.traces_sampled);
        self.traces_requested = self.traces_requested.max(other.traces_requested);
        self.traces_aborted = self.traces_aborted.max(other.traces_aborted);
        self.traces_slow = self.traces_slow.max(other.traces_slow);
        self.draft_acceptance.merge(&other.draft_acceptance);
        self.model_time += other.model_time;
        for (k, v) in &other.abort_reasons {
            *labeled(&mut self.abort_reasons, k) += v;
        }
        for (k, v) in &other.tenants {
            labeled(&mut self.tenants, k).merge(v);
        }
        for (k, v) in &other.grammars {
            labeled(&mut self.grammars, k).merge(v);
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests: {} ok / {} failed / {} cancelled / {} deadline / {} shed | \
             tokens: {} | model calls: {} | \
             forward: {} batches / {} rows (mean width {:.1}) | \
             interventions: {} | masks: {} | spec: {}/{} accepted | \
             draft: {}/{} accepted ({:.0}%) | \
             ttft p50 {:.1} ms | req tps mean {:.1} | \
             registry: {} hit / {} miss / {} evict / {} coalesced ({} ms compiling) | \
             artifacts: {} hit / {} miss / {} invalid (warm start {} in {} ms) | \
             mask cache: {} hit / {} miss ({:.0}% hit rate)",
            self.requests_completed,
            self.requests_failed,
            self.requests_cancelled,
            self.requests_deadline_exceeded,
            self.requests_shed,
            self.tokens_generated,
            self.model_calls,
            self.forward_batches,
            self.forward_rows,
            self.batch_size.mean(),
            self.interventions,
            self.masks_computed,
            self.spec_accepted,
            self.spec_proposed,
            self.draft_accepted,
            self.draft_proposed,
            self.draft_accept_rate() * 100.0,
            self.ttft.percentile(0.5) * 1e3,
            self.req_tps.mean(),
            self.registry_hits,
            self.registry_misses,
            self.registry_evictions,
            self.registry_coalesced,
            self.engine_compile_ms,
            self.artifact_hits,
            self.artifact_misses,
            self.artifact_invalid,
            self.warm_start_loaded,
            self.warm_start_ms,
            self.mask_cache_hits,
            self.mask_cache_misses,
            self.mask_cache_hit_rate() * 100.0,
        )
    }

    /// Draft-lane acceptance rate in [0, 1] (0 when nothing proposed).
    pub fn draft_accept_rate(&self) -> f64 {
        if self.draft_proposed == 0 {
            0.0
        } else {
            self.draft_accepted as f64 / self.draft_proposed as f64
        }
    }

    /// Mask-cache hit rate in [0, 1] (0 when no lookups yet).
    pub fn mask_cache_hit_rate(&self) -> f64 {
        let total = self.mask_cache_hits + self.mask_cache_misses;
        if total == 0 {
            0.0
        } else {
            self.mask_cache_hits as f64 / total as f64
        }
    }
}

// ---------------------------------------------------------------------------
// Metric registry: the single source of truth for exposition + docs.
// ---------------------------------------------------------------------------

/// Prometheus metric kind, as written in `# TYPE` lines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// One row of the metric registry. `render_prometheus` writes the
/// `# HELP`/`# TYPE` header and samples for every def; `metrics_doc`
/// renders the same rows as the markdown reference in `docs/METRICS.md`.
#[derive(Clone, Copy, Debug)]
pub struct MetricDef {
    /// Full exposition name (`domino_` prefix; counters end `_total`).
    pub name: &'static str,
    pub kind: MetricKind,
    /// Label names attached to every sample of this metric.
    pub labels: &'static [&'static str],
    /// One-line operator-facing description (the `# HELP` text).
    pub help: &'static str,
}

/// Every metric the gateway exports, in exposition order. Adding a
/// field to [`Metrics`] without a row here (or vice versa) fails the
/// `registry_renders_every_def` test.
pub const METRIC_DEFS: &[MetricDef] = &[
    MetricDef {
        name: "domino_requests_total",
        kind: MetricKind::Counter,
        labels: &["outcome"],
        help: "Requests by final outcome: completed, failed, cancelled, deadline_exceeded, or shed.",
    },
    MetricDef {
        name: "domino_requests_aborted_total",
        kind: MetricKind::Counter,
        labels: &["kind", "reason"],
        help: "Structured abort accounting: kind is cancelled/deadline/shed, reason is the wire-visible cause (client_cancel, client_disconnect, queued, decoding, queue_full, tenant_quota).",
    },
    MetricDef {
        name: "domino_tokens_generated_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Tokens emitted across all completed and in-flight requests.",
    },
    MetricDef {
        name: "domino_model_calls_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "LM forward calls (a batched tick counts once per lane row consumed).",
    },
    MetricDef {
        name: "domino_forward_batches_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Batched cross-slot forward passes (one per engine tick with at least one lane).",
    },
    MetricDef {
        name: "domino_forward_rows_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Logit rows produced by batched forward passes (a draft lane contributes one row per proposed token).",
    },
    MetricDef {
        name: "domino_batch_width",
        kind: MetricKind::Histogram,
        labels: &[],
        help: "Lanes per batched forward pass; a mean near --slots means ticks run at full width.",
    },
    MetricDef {
        name: "domino_tick_seconds",
        kind: MetricKind::Histogram,
        labels: &[],
        help: "Engine-tick wall time (one step_all over a shard's live slots).",
    },
    MetricDef {
        name: "domino_tick_phase_seconds",
        kind: MetricKind::Histogram,
        labels: &["phase"],
        help: "Engine-tick phase attribution: decide (mask/sample/commit), gather (lane collection), forward (the batched model call), finish (verify/commit/stream).",
    },
    MetricDef {
        name: "domino_traces_captured_total",
        kind: MetricKind::Counter,
        labels: &["cause"],
        help: "Request traces captured, by cause: sampled (head sampling), requested (\"trace\": true on the wire), aborted / slow (tail-based capture).",
    },
    MetricDef {
        name: "domino_interventions_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Decode steps where the grammar mask changed the sampled token (DOMINO interventions).",
    },
    MetricDef {
        name: "domino_masks_computed_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Token masks computed (mask-cache misses do the work; hits reuse it).",
    },
    MetricDef {
        name: "domino_mask_compute_us",
        kind: MetricKind::Histogram,
        labels: &[],
        help: "Per-request mean mask-computation time, microseconds.",
    },
    MetricDef {
        name: "domino_spec_proposed_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Tokens proposed by single-token opportunistic speculation.",
    },
    MetricDef {
        name: "domino_spec_accepted_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Opportunistically speculated tokens accepted by verification.",
    },
    MetricDef {
        name: "domino_draft_proposed_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Tokens proposed by grammar-pruned multi-token draft lanes.",
    },
    MetricDef {
        name: "domino_draft_accepted_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Draft-lane tokens accepted by batched verification.",
    },
    MetricDef {
        name: "domino_draft_acceptance_ratio",
        kind: MetricKind::Histogram,
        labels: &[],
        help: "Per-request draft acceptance ratio (accepted / proposed) for requests that drafted.",
    },
    MetricDef {
        name: "domino_queue_wait_seconds",
        kind: MetricKind::Histogram,
        labels: &[],
        help: "Admission-queue wait from submit to slot admission.",
    },
    MetricDef {
        name: "domino_ttft_seconds",
        kind: MetricKind::Histogram,
        labels: &[],
        help: "Time to first token (submit to first emitted token).",
    },
    MetricDef {
        name: "domino_request_tokens_per_second",
        kind: MetricKind::Histogram,
        labels: &[],
        help: "Per-request decode throughput, tokens per second.",
    },
    MetricDef {
        name: "domino_model_time_seconds_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Engine wall time spent inside LM forward calls.",
    },
    MetricDef {
        name: "domino_registry_lookups_total",
        kind: MetricKind::Counter,
        labels: &["result"],
        help: "Engine-registry lookups: hit (cached), miss (compiled), coalesced (waited on a concurrent build).",
    },
    MetricDef {
        name: "domino_registry_evictions_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Compiled engines dropped by registry LRU eviction.",
    },
    MetricDef {
        name: "domino_engine_compile_seconds_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Wall time spent compiling grammar engines.",
    },
    MetricDef {
        name: "domino_artifact_lookups_total",
        kind: MetricKind::Counter,
        labels: &["result"],
        help: "Persistent-artifact lookups: hit (deserialized), miss (compiled and written back), invalid (corrupt/stale, rebuilt).",
    },
    MetricDef {
        name: "domino_warm_start_loaded",
        kind: MetricKind::Gauge,
        labels: &[],
        help: "Engines registered by the boot-time warm-start scan.",
    },
    MetricDef {
        name: "domino_warm_start_seconds",
        kind: MetricKind::Gauge,
        labels: &[],
        help: "Wall time of the boot-time warm-start scan.",
    },
    MetricDef {
        name: "domino_mask_cache_lookups_total",
        kind: MetricKind::Counter,
        labels: &["result"],
        help: "State-keyed mask-cache lookups: hit (Arc reuse) or miss (mask computed and cached).",
    },
    MetricDef {
        name: "domino_mask_cache_evictions_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Masks dropped by mask-cache LRU eviction.",
    },
    MetricDef {
        name: "domino_registry_tier_entries",
        kind: MetricKind::Gauge,
        labels: &["tier"],
        help: "Registry residency by tier: hot (engine + mask cache), warm (engine only), cold (artifact indexed on disk, loaded on demand).",
    },
    MetricDef {
        name: "domino_engine_shards",
        kind: MetricKind::Gauge,
        labels: &[],
        help: "Engine shards (threads) the scheduler is running.",
    },
    MetricDef {
        name: "domino_connections_open",
        kind: MetricKind::Gauge,
        labels: &[],
        help: "Gateway connections currently open across the JSONL and metrics listeners.",
    },
    MetricDef {
        name: "domino_connections_accepted_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Connections accepted by the gateway since boot.",
    },
    MetricDef {
        name: "domino_connections_rejected_total",
        kind: MetricKind::Counter,
        labels: &[],
        help: "Connections refused at accept because --max-connections was reached (the client sees overloaded/connection_limit).",
    },
    MetricDef {
        name: "domino_connection_timeouts_total",
        kind: MetricKind::Counter,
        labels: &["kind"],
        help: "Connections closed by a gateway timeout: kind is idle (no request activity), read (a partial request stalled), or write_stall (the peer stopped reading its reply).",
    },
    MetricDef {
        name: "domino_connection_lifetime_seconds",
        kind: MetricKind::Histogram,
        labels: &[],
        help: "Gateway connection lifetime from accept to close.",
    },
    MetricDef {
        name: "domino_tenant_requests_total",
        kind: MetricKind::Counter,
        labels: &["tenant", "outcome"],
        help: "Per-tenant requests by final outcome (tenant label capped at 64 values; overflow collapses into \"_other\").",
    },
    MetricDef {
        name: "domino_tenant_tokens_generated_total",
        kind: MetricKind::Counter,
        labels: &["tenant"],
        help: "Per-tenant tokens emitted.",
    },
    MetricDef {
        name: "domino_tenant_queue_wait_seconds",
        kind: MetricKind::Histogram,
        labels: &["tenant"],
        help: "Per-tenant admission-queue wait — the fairness signal a flooding tenant moves for itself but (with weighted-fair drain) not for others.",
    },
    MetricDef {
        name: "domino_grammar_requests_total",
        kind: MetricKind::Counter,
        labels: &["grammar"],
        help: "Requests per constraint fingerprint (hex; label capped at 64 values).",
    },
    MetricDef {
        name: "domino_grammar_tokens_generated_total",
        kind: MetricKind::Counter,
        labels: &["grammar"],
        help: "Tokens emitted per constraint fingerprint.",
    },
    MetricDef {
        name: "domino_grammar_masks_computed_total",
        kind: MetricKind::Counter,
        labels: &["grammar"],
        help: "Token masks computed per constraint fingerprint.",
    },
    MetricDef {
        name: "domino_grammar_interventions_total",
        kind: MetricKind::Counter,
        labels: &["grammar"],
        help: "Grammar interventions per constraint fingerprint.",
    },
    MetricDef {
        name: "domino_grammar_mask_compute_us",
        kind: MetricKind::Histogram,
        labels: &["grammar"],
        help: "Per-request mean mask-computation time per constraint fingerprint, microseconds.",
    },
];

/// Escape a label value per the Prometheus text exposition format
/// (backslash, double quote, newline).
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn write_counter(out: &mut String, name: &str, labels: &str, v: f64) {
    if labels.is_empty() {
        let _ = writeln!(out, "{name} {v}");
    } else {
        let _ = writeln!(out, "{name}{{{labels}}} {v}");
    }
}

/// Write a full histogram family (`_bucket`/`_sum`/`_count`) from a
/// [`Summary`]. `labels` is either empty or `key="value"` pairs
/// **without** a trailing comma.
fn write_hist(out: &mut String, name: &str, labels: &str, s: &Summary) {
    let sep = if labels.is_empty() { "" } else { "," };
    let mut cum = 0u64;
    for (i, b) in HIST_BOUNDS.iter().enumerate() {
        cum += s.bucket(i);
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{b}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}", s.count);
    let sum = if s.count == 0 { 0.0 } else { s.sum };
    if labels.is_empty() {
        let _ = writeln!(out, "{name}_sum {sum}");
        let _ = writeln!(out, "{name}_count {}", s.count);
    } else {
        let _ = writeln!(out, "{name}_sum{{{labels}}} {sum}");
        let _ = writeln!(out, "{name}_count{{{labels}}} {}", s.count);
    }
}

/// Append every sample line for `def` from the snapshot. Returns false
/// only for names the registry does not know (caught by tests).
fn write_samples(out: &mut String, def: &MetricDef, m: &Metrics, shards: usize) -> bool {
    let name = def.name;
    match name {
        "domino_requests_total" => {
            for (outcome, v) in [
                ("completed", m.requests_completed),
                ("failed", m.requests_failed),
                ("cancelled", m.requests_cancelled),
                ("deadline_exceeded", m.requests_deadline_exceeded),
                ("shed", m.requests_shed),
            ] {
                write_counter(out, name, &format!("outcome=\"{outcome}\""), v as f64);
            }
        }
        "domino_requests_aborted_total" => {
            for (key, v) in &m.abort_reasons {
                let (kind, reason) = key.split_once('/').unwrap_or((key.as_str(), "unknown"));
                let labels = format!(
                    "kind=\"{}\",reason=\"{}\"",
                    escape_label(kind),
                    escape_label(reason)
                );
                write_counter(out, name, &labels, *v as f64);
            }
        }
        "domino_tokens_generated_total" => write_counter(out, name, "", m.tokens_generated as f64),
        "domino_model_calls_total" => write_counter(out, name, "", m.model_calls as f64),
        "domino_forward_batches_total" => write_counter(out, name, "", m.forward_batches as f64),
        "domino_forward_rows_total" => write_counter(out, name, "", m.forward_rows as f64),
        "domino_batch_width" => write_hist(out, name, "", &m.batch_size),
        "domino_tick_seconds" => write_hist(out, name, "", &m.tick_time),
        "domino_tick_phase_seconds" => {
            for (phase, s) in [
                ("decide", &m.tick_decide),
                ("gather", &m.tick_gather),
                ("forward", &m.tick_forward),
                ("finish", &m.tick_finish),
            ] {
                write_hist(out, name, &format!("phase=\"{phase}\""), s);
            }
        }
        "domino_traces_captured_total" => {
            for (cause, v) in [
                ("sampled", m.traces_sampled),
                ("requested", m.traces_requested),
                ("aborted", m.traces_aborted),
                ("slow", m.traces_slow),
            ] {
                write_counter(out, name, &format!("cause=\"{cause}\""), v as f64);
            }
        }
        "domino_interventions_total" => write_counter(out, name, "", m.interventions as f64),
        "domino_masks_computed_total" => write_counter(out, name, "", m.masks_computed as f64),
        "domino_mask_compute_us" => write_hist(out, name, "", &m.mask_us),
        "domino_spec_proposed_total" => write_counter(out, name, "", m.spec_proposed as f64),
        "domino_spec_accepted_total" => write_counter(out, name, "", m.spec_accepted as f64),
        "domino_draft_proposed_total" => write_counter(out, name, "", m.draft_proposed as f64),
        "domino_draft_accepted_total" => write_counter(out, name, "", m.draft_accepted as f64),
        "domino_draft_acceptance_ratio" => write_hist(out, name, "", &m.draft_acceptance),
        "domino_queue_wait_seconds" => write_hist(out, name, "", &m.queue_wait),
        "domino_ttft_seconds" => write_hist(out, name, "", &m.ttft),
        "domino_request_tokens_per_second" => write_hist(out, name, "", &m.req_tps),
        "domino_model_time_seconds_total" => {
            write_counter(out, name, "", m.model_time.as_secs_f64())
        }
        "domino_registry_lookups_total" => {
            for (result, v) in [
                ("hit", m.registry_hits),
                ("miss", m.registry_misses),
                ("coalesced", m.registry_coalesced),
            ] {
                write_counter(out, name, &format!("result=\"{result}\""), v as f64);
            }
        }
        "domino_registry_evictions_total" => {
            write_counter(out, name, "", m.registry_evictions as f64)
        }
        "domino_engine_compile_seconds_total" => {
            write_counter(out, name, "", m.engine_compile_ms as f64 / 1e3)
        }
        "domino_artifact_lookups_total" => {
            for (result, v) in [
                ("hit", m.artifact_hits),
                ("miss", m.artifact_misses),
                ("invalid", m.artifact_invalid),
            ] {
                write_counter(out, name, &format!("result=\"{result}\""), v as f64);
            }
        }
        "domino_warm_start_loaded" => write_counter(out, name, "", m.warm_start_loaded as f64),
        "domino_warm_start_seconds" => {
            write_counter(out, name, "", m.warm_start_ms as f64 / 1e3)
        }
        "domino_mask_cache_lookups_total" => {
            for (result, v) in [("hit", m.mask_cache_hits), ("miss", m.mask_cache_misses)] {
                write_counter(out, name, &format!("result=\"{result}\""), v as f64);
            }
        }
        "domino_mask_cache_evictions_total" => {
            write_counter(out, name, "", m.mask_cache_evictions as f64)
        }
        "domino_registry_tier_entries" => {
            for (tier, v) in [
                ("hot", m.registry_hot_entries),
                ("warm", m.registry_warm_entries),
                ("cold", m.registry_cold_entries),
            ] {
                write_counter(out, name, &format!("tier=\"{tier}\""), v as f64);
            }
        }
        "domino_engine_shards" => write_counter(out, name, "", shards as f64),
        "domino_connections_open" => write_counter(out, name, "", m.connections_open as f64),
        "domino_connections_accepted_total" => {
            write_counter(out, name, "", m.connections_accepted as f64)
        }
        "domino_connections_rejected_total" => {
            write_counter(out, name, "", m.connections_rejected as f64)
        }
        "domino_connection_timeouts_total" => {
            for (kind, v) in [
                ("idle", m.connections_idle_timeout),
                ("read", m.connections_read_timeout),
                ("write_stall", m.connections_write_stall),
            ] {
                write_counter(out, name, &format!("kind=\"{kind}\""), v as f64);
            }
        }
        "domino_connection_lifetime_seconds" => write_hist(out, name, "", &m.conn_lifetime),
        "domino_tenant_requests_total" => {
            for (tenant, t) in &m.tenants {
                for (outcome, v) in [
                    ("completed", t.completed),
                    ("failed", t.failed),
                    ("cancelled", t.cancelled),
                    ("deadline_exceeded", t.deadline_exceeded),
                    ("shed", t.shed),
                ] {
                    let labels = format!(
                        "tenant=\"{}\",outcome=\"{outcome}\"",
                        escape_label(tenant)
                    );
                    write_counter(out, name, &labels, v as f64);
                }
            }
        }
        "domino_tenant_tokens_generated_total" => {
            for (tenant, t) in &m.tenants {
                let labels = format!("tenant=\"{}\"", escape_label(tenant));
                write_counter(out, name, &labels, t.tokens_generated as f64);
            }
        }
        "domino_tenant_queue_wait_seconds" => {
            for (tenant, t) in &m.tenants {
                let labels = format!("tenant=\"{}\"", escape_label(tenant));
                write_hist(out, name, &labels, &t.queue_wait);
            }
        }
        "domino_grammar_requests_total" => {
            for (fp, g) in &m.grammars {
                let labels = format!("grammar=\"{}\"", escape_label(fp));
                write_counter(out, name, &labels, g.requests as f64);
            }
        }
        "domino_grammar_tokens_generated_total" => {
            for (fp, g) in &m.grammars {
                let labels = format!("grammar=\"{}\"", escape_label(fp));
                write_counter(out, name, &labels, g.tokens_generated as f64);
            }
        }
        "domino_grammar_masks_computed_total" => {
            for (fp, g) in &m.grammars {
                let labels = format!("grammar=\"{}\"", escape_label(fp));
                write_counter(out, name, &labels, g.masks_computed as f64);
            }
        }
        "domino_grammar_interventions_total" => {
            for (fp, g) in &m.grammars {
                let labels = format!("grammar=\"{}\"", escape_label(fp));
                write_counter(out, name, &labels, g.interventions as f64);
            }
        }
        "domino_grammar_mask_compute_us" => {
            for (fp, g) in &m.grammars {
                let labels = format!("grammar=\"{}\"", escape_label(fp));
                write_hist(out, name, &labels, &g.mask_us);
            }
        }
        _ => return false,
    }
    true
}

/// Render a [`Metrics`] snapshot as Prometheus text exposition format
/// 0.0.4 — the body served by the `/metrics` HTTP endpoint. `shards`
/// is the live engine-shard count (a gauge the snapshot itself does
/// not carry).
pub fn render_prometheus(m: &Metrics, shards: usize) -> String {
    let mut out = String::with_capacity(16 * 1024);
    for def in METRIC_DEFS {
        let _ = writeln!(out, "# HELP {} {}", def.name, def.help);
        let _ = writeln!(out, "# TYPE {} {}", def.name, def.kind.as_str());
        let known = write_samples(&mut out, def, m, shards);
        debug_assert!(known, "metric def {} has no sample writer", def.name);
    }
    out
}

/// Render the metric registry as the markdown reference checked in at
/// `docs/METRICS.md` (the `domino metrics-doc` subcommand; CI diffs
/// the committed file against this output).
pub fn metrics_doc() -> String {
    let mut out = String::with_capacity(8 * 1024);
    out.push_str(
        "# DOMINO metrics reference\n\n\
         Generated by `domino metrics-doc` from the metric registry in\n\
         `rust/src/server/metrics.rs` (`METRIC_DEFS`) — the same table that\n\
         drives the `/metrics` HELP lines, so this file cannot drift from\n\
         the exposition. Regenerate with:\n\n\
         ```sh\n\
         cargo run --release -- metrics-doc > ../docs/METRICS.md\n\
         ```\n\n\
         Histograms export `_bucket`/`_sum`/`_count` series on a shared\n\
         log-spaced bucket ladder ({1, 2.5, 5} per decade, 1e-6 to 5e3).\n\
         The `tenant` and `grammar` labels are cardinality-capped at 64\n\
         distinct values; overflow collapses into `_other`.\n\n\
         | metric | type | labels | description |\n\
         |--------|------|--------|-------------|\n",
    );
    for def in METRIC_DEFS {
        let labels = if def.labels.is_empty() {
            "—".to_string()
        } else {
            def.labels
                .iter()
                .map(|l| format!("`{l}`"))
                .collect::<Vec<_>>()
                .join(", ")
        };
        let _ = writeln!(
            out,
            "| `{}` | {} | {} | {} |",
            def.name,
            def.kind.as_str(),
            labels,
            def.help.replace('|', "\\|")
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let mut s = Summary::default();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.record(v);
        }
        assert_eq!(s.count, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.percentile(0.5), 3.0);
        assert_eq!(s.percentile(1.0), 5.0);
    }

    #[test]
    fn summary_buckets_align_with_bounds() {
        let mut s = Summary::default();
        s.record(0.5e-6); // -> le=1e-6 (first bucket)
        s.record(3.0); // -> le=5
        s.record(9999.0); // -> +Inf (beyond last bound)
        assert_eq!(s.bucket(0), 1);
        let idx5 = HIST_BOUNDS.iter().position(|&b| b == 5.0).unwrap();
        assert_eq!(s.bucket(idx5), 1);
        let in_bounds: u64 = (0..HIST_BOUNDS.len()).map(|i| s.bucket(i)).sum();
        assert_eq!(s.count - in_bounds, 1, "one sample in the +Inf bucket");
    }

    #[test]
    fn summary_merge_adds_buckets() {
        let mut a = Summary::default();
        a.record(2.0);
        let mut b = Summary::default();
        b.record(2.0);
        a.merge(&b);
        let idx = HIST_BOUNDS.iter().position(|&bound| 2.0 <= bound).unwrap();
        assert_eq!(a.bucket(idx), 2);
    }

    #[test]
    fn merge_sums_loop_counters_and_maxes_shared_caches() {
        let mut a = Metrics {
            requests_completed: 2,
            requests_shed: 1,
            tokens_generated: 10,
            draft_proposed: 8,
            draft_accepted: 6,
            registry_misses: 3, // shared-registry counter: same registry...
            ..Default::default()
        };
        a.ttft.record(0.5);
        let mut b = Metrics {
            requests_completed: 4,
            tokens_generated: 20,
            draft_proposed: 4,
            draft_accepted: 3,
            registry_misses: 3, // ...seen from another shard's snapshot
            ..Default::default()
        };
        b.ttft.record(1.5);
        a.merge(&b);
        assert_eq!(a.requests_completed, 6);
        assert_eq!(a.requests_shed, 1);
        assert_eq!(a.tokens_generated, 30);
        assert_eq!(a.draft_proposed, 12, "draft counters are per-shard loop work: they sum");
        assert_eq!(a.draft_accepted, 9);
        assert_eq!(a.registry_misses, 3, "shared registry must not double-count");
        assert_eq!(a.ttft.count, 2);
        assert_eq!(a.ttft.min, 0.5);
        assert_eq!(a.ttft.max, 1.5);
    }

    #[test]
    fn merge_sums_forward_counters_per_shard() {
        // Forward passes are engine-loop work (each shard runs its own
        // ticks), so they sum across shards — unlike the shared-registry
        // counters.
        let mut a = Metrics { forward_batches: 10, forward_rows: 40, ..Default::default() };
        a.batch_size.record(4.0);
        let mut b = Metrics { forward_batches: 5, forward_rows: 10, ..Default::default() };
        b.batch_size.record(2.0);
        a.merge(&b);
        assert_eq!(a.forward_batches, 15);
        assert_eq!(a.forward_rows, 50);
        assert_eq!(a.batch_size.count, 2);
        assert_eq!((a.batch_size.min, a.batch_size.max), (2.0, 4.0));
        assert!(a.report().contains("forward: 15 batches / 50 rows"));
    }

    #[test]
    fn summary_merge_empty_sides() {
        let mut a = Summary::default();
        let mut b = Summary::default();
        b.record(2.0);
        a.merge(&b);
        assert_eq!((a.count, a.min, a.max), (1, 2.0, 2.0));
        let empty = Summary::default();
        a.merge(&empty);
        assert_eq!(a.count, 1);
    }

    #[test]
    fn report_formats() {
        let mut m = Metrics::default();
        assert!(m.report().contains("requests"));
        assert!(m.report().contains("registry"));
        assert!(m.report().contains("artifacts"));
        assert_eq!(m.mask_cache_hit_rate(), 0.0, "no lookups yet");
        m.mask_cache_hits = 3;
        m.mask_cache_misses = 1;
        assert!((m.mask_cache_hit_rate() - 0.75).abs() < 1e-12);
        assert!(m.report().contains("75% hit rate"));
        assert_eq!(m.draft_accept_rate(), 0.0, "no drafting yet");
        m.draft_proposed = 10;
        m.draft_accepted = 8;
        assert!((m.draft_accept_rate() - 0.8).abs() < 1e-12);
        assert!(m.report().contains("draft: 8/10 accepted (80%)"));
    }

    #[test]
    fn merge_folds_tenants_grammars_and_reasons() {
        let mut a = Metrics::default();
        a.tenant("alpha").completed = 2;
        a.tenant("alpha").queue_wait.record(0.1);
        a.grammar("fp1").requests = 3;
        a.record_abort("shed", "queue_full");
        let mut b = Metrics::default();
        b.tenant("alpha").completed = 1;
        b.tenant("beta").shed = 4;
        b.grammar("fp1").requests = 1;
        b.record_abort("shed", "queue_full");
        b.record_abort("deadline", "queued");
        a.merge(&b);
        assert_eq!(a.tenants["alpha"].completed, 3);
        assert_eq!(a.tenants["alpha"].queue_wait.count, 1);
        assert_eq!(a.tenants["beta"].shed, 4);
        assert_eq!(a.grammars["fp1"].requests, 4);
        assert_eq!(a.abort_reasons["shed/queue_full"], 2);
        assert_eq!(a.abort_reasons["deadline/queued"], 1);
    }

    #[test]
    fn label_cardinality_is_capped() {
        let mut m = Metrics::default();
        for i in 0..(MAX_LABEL_CARDINALITY + 10) {
            m.tenant(&format!("t{i}")).completed += 1;
        }
        assert!(m.tenants.len() <= MAX_LABEL_CARDINALITY + 1);
        assert_eq!(m.tenants[OTHER_LABEL].completed, 10, "overflow collapses into _other");
        // Existing keys keep resolving to themselves at the cap.
        m.tenant("t0").completed += 1;
        assert_eq!(m.tenants["t0"].completed, 2);
    }

    #[test]
    fn registry_renders_every_def() {
        let mut m = Metrics::default();
        m.requests_completed = 3;
        m.tenant("acme").completed = 2;
        m.tenant("acme").tokens_generated = 40;
        m.tenant("acme").queue_wait.record(0.002);
        m.grammar("deadbeef").requests = 1;
        m.grammar("deadbeef").mask_us.record(12.0);
        m.record_abort("cancelled", "client_disconnect");
        m.queue_wait.record(0.001);
        m.tick_time.record(0.0005);
        m.draft_acceptance.record(0.75);
        let text = render_prometheus(&m, 4);
        for def in METRIC_DEFS {
            assert!(
                text.contains(&format!("# HELP {} ", def.name)),
                "missing HELP for {}",
                def.name
            );
            assert!(
                text.contains(&format!("# TYPE {} {}", def.name, def.kind.as_str())),
                "missing TYPE for {}",
                def.name
            );
            // Every metric must emit at least one sample line (counters
            // always do; histograms emit buckets even when empty).
            let sample = text.lines().any(|l| {
                !l.starts_with('#')
                    && (l.starts_with(&format!("{} ", def.name))
                        || l.starts_with(&format!("{}{{", def.name))
                        || l.starts_with(&format!("{}_bucket", def.name)))
            });
            assert!(sample, "no sample line for {}", def.name);
        }
        assert!(text.contains("domino_requests_total{outcome=\"completed\"} 3"));
        assert!(text
            .contains("domino_tenant_requests_total{tenant=\"acme\",outcome=\"completed\"} 2"));
        assert!(text.contains("domino_tenant_tokens_generated_total{tenant=\"acme\"} 40"));
        assert!(text.contains("domino_tenant_queue_wait_seconds_count{tenant=\"acme\"} 1"));
        assert!(text.contains("domino_grammar_requests_total{grammar=\"deadbeef\"} 1"));
        assert!(text
            .contains("domino_requests_aborted_total{kind=\"cancelled\",reason=\"client_disconnect\"} 1"));
        assert!(text.contains("domino_engine_shards 4"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_count() {
        let mut m = Metrics::default();
        m.queue_wait.record(0.002);
        m.queue_wait.record(0.004);
        m.queue_wait.record(99999.0); // +Inf territory
        let text = render_prometheus(&m, 1);
        assert!(text.contains("domino_queue_wait_seconds_bucket{le=\"0.005\"} 2"));
        assert!(text.contains("domino_queue_wait_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("domino_queue_wait_seconds_count 3"));
    }

    #[test]
    fn label_values_are_escaped() {
        let mut m = Metrics::default();
        m.tenant("a\"b\\c\nd").completed = 1;
        let text = render_prometheus(&m, 1);
        assert!(text.contains("tenant=\"a\\\"b\\\\c\\nd\""));
    }

    #[test]
    fn metrics_doc_covers_every_def() {
        let doc = metrics_doc();
        for def in METRIC_DEFS {
            assert!(doc.contains(&format!("`{}`", def.name)), "doc missing {}", def.name);
        }
        assert!(doc.contains("| metric | type | labels | description |"));
        assert!(doc.contains("metrics-doc"));
    }

    #[test]
    fn metric_names_are_well_formed() {
        for def in METRIC_DEFS {
            assert!(def.name.starts_with("domino_"), "{} lacks the domino_ prefix", def.name);
            assert!(
                def.name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
                "{} has invalid chars",
                def.name
            );
            if def.kind == MetricKind::Counter {
                assert!(def.name.ends_with("_total"), "counter {} should end _total", def.name);
            }
            assert!(!def.help.is_empty());
        }
        let mut names: Vec<_> = METRIC_DEFS.iter().map(|d| d.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), METRIC_DEFS.len(), "duplicate metric names");
    }
}
