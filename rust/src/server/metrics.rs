//! Serving metrics: counters + latency/throughput summaries.

use std::time::Duration;

/// A streaming summary (count/mean/min/max/p50-ish via reservoir).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    samples: Vec<f64>,
}

impl Summary {
    pub fn record(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
        // Simple capped reservoir for percentiles.
        if self.samples.len() < 4096 {
            self.samples.push(v);
        } else {
            let i = (self.count % 4096) as usize;
            self.samples[i] = v;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * p).round() as usize;
        s[idx]
    }

    /// Fold `other` into this summary (cross-shard aggregation). Exact for
    /// count/sum/min/max; the percentile reservoir keeps as many of the
    /// other side's samples as fit under the cap.
    pub fn merge(&mut self, other: &Summary) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.count += other.count;
        self.sum += other.sum;
        for &v in &other.samples {
            if self.samples.len() >= 4096 {
                break;
            }
            self.samples.push(v);
        }
    }
}

/// Aggregate serving metrics (owned by the engine thread).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests_completed: u64,
    pub requests_failed: u64,
    /// Requests aborted mid-decode because the client cancelled or
    /// disconnected (the slot stops burning engine ticks immediately).
    pub requests_cancelled: u64,
    /// Requests aborted because their deadline passed (queued or
    /// mid-decode).
    pub requests_deadline_exceeded: u64,
    /// Requests shed at admission because every eligible shard's queue
    /// was at capacity (the structured `"error":"overloaded"` reply).
    pub requests_shed: u64,
    pub tokens_generated: u64,
    pub model_calls: u64,
    /// Batched cross-slot forward passes executed (one per engine tick
    /// that had at least one lane).
    pub forward_batches: u64,
    /// Total logit rows produced by batched forward passes (a
    /// speculative lane contributes one row per proposed token).
    pub forward_rows: u64,
    /// Lanes per batched forward pass — the batch-width histogram. A
    /// mean near `slots_per_engine` means ticks run at full width; near
    /// 1 means the shard is effectively stepping per-slot.
    pub batch_size: Summary,
    pub interventions: u64,
    pub masks_computed: u64,
    pub spec_proposed: u64,
    pub spec_accepted: u64,
    /// Tokens proposed by draft lanes (grammar-pruned multi-token
    /// drafting; distinct from single-token opportunistic speculation).
    pub draft_proposed: u64,
    /// Draft-lane tokens accepted by batched verification.
    pub draft_accepted: u64,
    /// Engine-registry lookups served from cache.
    pub registry_hits: u64,
    /// Engine-registry lookups that compiled a grammar.
    pub registry_misses: u64,
    /// Engines dropped by LRU eviction.
    pub registry_evictions: u64,
    /// Lookups that waited on a concurrent build instead of compiling.
    pub registry_coalesced: u64,
    /// Total wall time spent compiling grammar engines, milliseconds.
    pub engine_compile_ms: u64,
    /// Engines deserialized from the persistent artifact store (warm
    /// starts + on-demand loads) instead of compiled.
    pub artifact_hits: u64,
    /// Artifact-store lookups that found nothing (the compile wrote one
    /// back).
    pub artifact_misses: u64,
    /// Artifacts rejected as unusable (truncated / checksum / version /
    /// vocab mismatch); each fell back to a clean rebuild.
    pub artifact_invalid: u64,
    /// Engines registered by the boot-time warm-start scan.
    pub warm_start_loaded: u64,
    /// Wall time of the boot-time warm-start scan, milliseconds.
    pub warm_start_ms: u64,
    /// State-keyed mask-cache hits (mask reused, no tree traversal).
    pub mask_cache_hits: u64,
    /// Mask-cache misses (mask computed and cached).
    pub mask_cache_misses: u64,
    /// Masks dropped by LRU eviction.
    pub mask_cache_evictions: u64,
    /// Time to first token, seconds.
    pub ttft: Summary,
    /// Admission-queue wait (submit → slot admission), seconds.
    pub queue_wait: Summary,
    /// Per-request tokens/second.
    pub req_tps: Summary,
    /// Mask computation time, microseconds.
    pub mask_us: Summary,
    /// Engine wall time spent in model calls, seconds.
    pub model_time: Duration,
}

impl Metrics {
    /// Fold another shard's snapshot into this one (cross-shard
    /// aggregation for `Scheduler::metrics` and the TCP `stats` op).
    ///
    /// Engine-loop counters and summaries are per-shard and sum; the
    /// registry/mask-cache counters are pulled from the **shared**
    /// registry by every shard's snapshot, so summing would multiply
    /// them by the shard count — they aggregate by `max` instead (the
    /// counters are monotonic, so the max is the freshest snapshot).
    pub fn merge(&mut self, other: &Metrics) {
        self.requests_completed += other.requests_completed;
        self.requests_failed += other.requests_failed;
        self.requests_cancelled += other.requests_cancelled;
        self.requests_deadline_exceeded += other.requests_deadline_exceeded;
        self.requests_shed += other.requests_shed;
        self.tokens_generated += other.tokens_generated;
        self.model_calls += other.model_calls;
        self.forward_batches += other.forward_batches;
        self.forward_rows += other.forward_rows;
        self.batch_size.merge(&other.batch_size);
        self.interventions += other.interventions;
        self.masks_computed += other.masks_computed;
        self.spec_proposed += other.spec_proposed;
        self.spec_accepted += other.spec_accepted;
        self.draft_proposed += other.draft_proposed;
        self.draft_accepted += other.draft_accepted;
        self.registry_hits = self.registry_hits.max(other.registry_hits);
        self.registry_misses = self.registry_misses.max(other.registry_misses);
        self.registry_evictions = self.registry_evictions.max(other.registry_evictions);
        self.registry_coalesced = self.registry_coalesced.max(other.registry_coalesced);
        self.engine_compile_ms = self.engine_compile_ms.max(other.engine_compile_ms);
        self.artifact_hits = self.artifact_hits.max(other.artifact_hits);
        self.artifact_misses = self.artifact_misses.max(other.artifact_misses);
        self.artifact_invalid = self.artifact_invalid.max(other.artifact_invalid);
        self.warm_start_loaded = self.warm_start_loaded.max(other.warm_start_loaded);
        self.warm_start_ms = self.warm_start_ms.max(other.warm_start_ms);
        self.mask_cache_hits = self.mask_cache_hits.max(other.mask_cache_hits);
        self.mask_cache_misses = self.mask_cache_misses.max(other.mask_cache_misses);
        self.mask_cache_evictions = self.mask_cache_evictions.max(other.mask_cache_evictions);
        self.ttft.merge(&other.ttft);
        self.queue_wait.merge(&other.queue_wait);
        self.req_tps.merge(&other.req_tps);
        self.mask_us.merge(&other.mask_us);
        self.model_time += other.model_time;
    }

    pub fn report(&self) -> String {
        format!(
            "requests: {} ok / {} failed / {} cancelled / {} deadline / {} shed | \
             tokens: {} | model calls: {} | \
             forward: {} batches / {} rows (mean width {:.1}) | \
             interventions: {} | masks: {} | spec: {}/{} accepted | \
             draft: {}/{} accepted ({:.0}%) | \
             ttft p50 {:.1} ms | req tps mean {:.1} | \
             registry: {} hit / {} miss / {} evict / {} coalesced ({} ms compiling) | \
             artifacts: {} hit / {} miss / {} invalid (warm start {} in {} ms) | \
             mask cache: {} hit / {} miss ({:.0}% hit rate)",
            self.requests_completed,
            self.requests_failed,
            self.requests_cancelled,
            self.requests_deadline_exceeded,
            self.requests_shed,
            self.tokens_generated,
            self.model_calls,
            self.forward_batches,
            self.forward_rows,
            self.batch_size.mean(),
            self.interventions,
            self.masks_computed,
            self.spec_accepted,
            self.spec_proposed,
            self.draft_accepted,
            self.draft_proposed,
            self.draft_accept_rate() * 100.0,
            self.ttft.percentile(0.5) * 1e3,
            self.req_tps.mean(),
            self.registry_hits,
            self.registry_misses,
            self.registry_evictions,
            self.registry_coalesced,
            self.engine_compile_ms,
            self.artifact_hits,
            self.artifact_misses,
            self.artifact_invalid,
            self.warm_start_loaded,
            self.warm_start_ms,
            self.mask_cache_hits,
            self.mask_cache_misses,
            self.mask_cache_hit_rate() * 100.0,
        )
    }

    /// Draft-lane acceptance rate in [0, 1] (0 when nothing proposed).
    pub fn draft_accept_rate(&self) -> f64 {
        if self.draft_proposed == 0 {
            0.0
        } else {
            self.draft_accepted as f64 / self.draft_proposed as f64
        }
    }

    /// Mask-cache hit rate in [0, 1] (0 when no lookups yet).
    pub fn mask_cache_hit_rate(&self) -> f64 {
        let total = self.mask_cache_hits + self.mask_cache_misses;
        if total == 0 {
            0.0
        } else {
            self.mask_cache_hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let mut s = Summary::default();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.record(v);
        }
        assert_eq!(s.count, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.percentile(0.5), 3.0);
        assert_eq!(s.percentile(1.0), 5.0);
    }

    #[test]
    fn merge_sums_loop_counters_and_maxes_shared_caches() {
        let mut a = Metrics {
            requests_completed: 2,
            requests_shed: 1,
            tokens_generated: 10,
            draft_proposed: 8,
            draft_accepted: 6,
            registry_misses: 3, // shared-registry counter: same registry...
            ..Default::default()
        };
        a.ttft.record(0.5);
        let mut b = Metrics {
            requests_completed: 4,
            tokens_generated: 20,
            draft_proposed: 4,
            draft_accepted: 3,
            registry_misses: 3, // ...seen from another shard's snapshot
            ..Default::default()
        };
        b.ttft.record(1.5);
        a.merge(&b);
        assert_eq!(a.requests_completed, 6);
        assert_eq!(a.requests_shed, 1);
        assert_eq!(a.tokens_generated, 30);
        assert_eq!(a.draft_proposed, 12, "draft counters are per-shard loop work: they sum");
        assert_eq!(a.draft_accepted, 9);
        assert_eq!(a.registry_misses, 3, "shared registry must not double-count");
        assert_eq!(a.ttft.count, 2);
        assert_eq!(a.ttft.min, 0.5);
        assert_eq!(a.ttft.max, 1.5);
    }

    #[test]
    fn merge_sums_forward_counters_per_shard() {
        // Forward passes are engine-loop work (each shard runs its own
        // ticks), so they sum across shards — unlike the shared-registry
        // counters.
        let mut a = Metrics { forward_batches: 10, forward_rows: 40, ..Default::default() };
        a.batch_size.record(4.0);
        let mut b = Metrics { forward_batches: 5, forward_rows: 10, ..Default::default() };
        b.batch_size.record(2.0);
        a.merge(&b);
        assert_eq!(a.forward_batches, 15);
        assert_eq!(a.forward_rows, 50);
        assert_eq!(a.batch_size.count, 2);
        assert_eq!((a.batch_size.min, a.batch_size.max), (2.0, 4.0));
        assert!(a.report().contains("forward: 15 batches / 50 rows"));
    }

    #[test]
    fn summary_merge_empty_sides() {
        let mut a = Summary::default();
        let mut b = Summary::default();
        b.record(2.0);
        a.merge(&b);
        assert_eq!((a.count, a.min, a.max), (1, 2.0, 2.0));
        let empty = Summary::default();
        a.merge(&empty);
        assert_eq!(a.count, 1);
    }

    #[test]
    fn report_formats() {
        let mut m = Metrics::default();
        assert!(m.report().contains("requests"));
        assert!(m.report().contains("registry"));
        assert!(m.report().contains("artifacts"));
        assert_eq!(m.mask_cache_hit_rate(), 0.0, "no lookups yet");
        m.mask_cache_hits = 3;
        m.mask_cache_misses = 1;
        assert!((m.mask_cache_hit_rate() - 0.75).abs() < 1e-12);
        assert!(m.report().contains("75% hit rate"));
        assert_eq!(m.draft_accept_rate(), 0.0, "no drafting yet");
        m.draft_proposed = 10;
        m.draft_accepted = 8;
        assert!((m.draft_accept_rate() - 0.8).abs() < 1e-12);
        assert!(m.report().contains("draft: 8/10 accepted (80%)"));
    }
}
