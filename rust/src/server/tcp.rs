//! JSONL-over-TCP front end.
//!
//! Protocol: one JSON object per line, one JSON object (or event stream)
//! per reply.
//!
//! Generation request:
//! ```json
//! {"prompt": "...", "grammar": "json", "method": "domino",
//!  "k": null, "speculative": 8, "max_tokens": 128,
//!  "temperature": 1.0, "seed": 7, "stream": false, "deadline_ms": 2000,
//!  "tenant": "team-a"}
//! ```
//! `method`: "unconstrained" | "domino" | "domino-full" | "online".
//!
//! `tenant` names the accounting/fairness bucket the request is billed
//! to (1..=64 bytes, no control characters; omitted → `"default"`). It
//! selects the token-bucket quota and weighted-fair queue lane at the
//! scheduler, and labels the request in the `/metrics` exporter.
//!
//! `"draft": K` (method "domino" only) enables the grammar-pruned draft
//! lane: up to `K ≥ 1` tokens are proposed per engine tick from the
//! grammar's learned prior and verified in one batched forward pass.
//! Mutually exclusive with `speculative` (the draft lane subsumes
//! single-token speculation).
//!
//! The constraint itself is exactly ONE of:
//! * `"ebnf": "root ::= ..."` — an inline grammar in the crate's EBNF
//!   notation, compiled on first sight and cached by content hash;
//! * `"json_schema": {...}` (or the same document as a string) — a JSON
//!   Schema compiled to a grammar (see `grammar::jsonschema` for the
//!   supported subset; unsupported keywords are a path-annotated error,
//!   never a silently weakened constraint);
//! * `"regex": "[0-9]+"` — output is exactly one match of the pattern;
//! * `"grammar": "json"` — a builtin evaluation grammar by name
//!   (unknown names are rejected here, listing the known grammars);
//! * `"stop": ["\n\n"]` — free generation until a stop sequence appears.
//!
//! Supplying more than one of these fields is a structured `bad request`
//! error — the server refuses to guess which constraint was meant.
//!
//! Validation: `k` / `speculative` / `draft` / `max_tokens` / `seed` /
//! `temperature` / `deadline_ms` must be non-negative finite numbers
//! (anything else is a `bad request` error, not a silent cast),
//! `speculative` and `draft` must additionally be ≥ 1 when present
//! (`0` would silently disable the feature the client asked for), and
//! `max_tokens` is clamped to the server-side cap [`MAX_TOKENS_CAP`].
//!
//! Non-streaming response (also the terminator of a streaming response):
//! ```json
//! {"text": "...", "tokens": 42, "interventions": 0, "model_calls": 40,
//!  "masks": 3, "elapsed_s": 0.8, "error": null, "reason": null}
//! ```
//! `error` is `null` on success; notable values: `"overloaded"` (the
//! scheduler shed the request at admission — bounded-queue backpressure),
//! `"cancelled"` (client disconnected mid-decode), `"deadline exceeded"`.
//! `reason` refines `error` with the structured cause when one is known:
//! `"queue_full"` / `"tenant_quota"` for sheds, `"queued"` / `"decoding"`
//! for deadline hits, `"client_cancel"` / `"client_disconnect"` for
//! cancellations; `null` otherwise.
//!
//! Streaming: with `"stream": true`, each decode step emits one event
//! line before the final stats object:
//! ```json
//! {"token": "...", "index": 1}
//! ```
//! Concatenating every `token` field yields the final `text`. If the
//! client disconnects mid-stream the request is aborted at the next
//! engine tick instead of decoding to `max_tokens`.
//!
//! Stats request — returns the aggregated cross-shard metrics snapshot:
//! ```json
//! {"op": "stats"}
//! ```
//!
//! Tracing: `"trace": true` on a generation request always captures that
//! request's trace and attaches an inline `trace` summary object to the
//! final response (span durations, decision counts, capture cause).
//! `{"op": "trace"}` dumps the ring of recently captured traces —
//! head-sampled at `--trace-sample-rate` plus tail-captured aborted /
//! over-`--trace-slow-ms` requests — as `{"traces": [...]}`, oldest
//! first. See `server::trace` and `rust/OPERATIONS.md`.

use super::engine::{Constraint, ConstraintSpec, GenRequest, GenResponse};
use super::metrics::Metrics;
use super::scheduler::Scheduler;
use super::slot::StreamEvent;
use crate::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Server-side ceiling on `max_tokens`: wire requests are clamped here so
/// a single request cannot pin a slot arbitrarily long.
pub const MAX_TOKENS_CAP: usize = 4096;

/// One parsed request line.
pub enum Request {
    Generate(GenRequest),
    /// `{"op": "stats"}` — aggregated cross-shard metrics.
    Stats,
    /// `{"op": "trace"}` — dump the ring of recently captured traces.
    Trace,
}

/// Server-side request defaults from CLI flags, applied to requests that
/// leave the knob unset (never overriding an explicit wire value).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeDefaults {
    /// Default draft depth (`--draft K` on `domino serve`): applied to
    /// domino-mode requests that set neither `draft` nor `speculative`.
    pub draft: Option<usize>,
}

impl ServeDefaults {
    /// Fold these defaults into a parsed request. A request that chose
    /// any speculation mode itself — or a non-domino method — is left
    /// alone: defaults fill gaps, they don't override.
    pub fn apply(&self, req: &mut GenRequest) {
        use super::engine::Enforcement;
        if let Some(k) = self.draft {
            if let Enforcement::Domino {
                speculative: None,
                draft: draft @ None,
                full_mask: false,
                ..
            } = &mut req.constraint.enforcement
            {
                *draft = Some(k);
            }
        }
    }
}

/// Parse one request line (generation or `stats` op).
pub fn parse_line(line: &str) -> crate::Result<Request> {
    let v = Json::parse(line)?;
    if let Some(op) = v.get("op").and_then(|o| o.as_str()) {
        return match op {
            "stats" => Ok(Request::Stats),
            "trace" => Ok(Request::Trace),
            "generate" => Ok(Request::Generate(parse_request_value(&v)?)),
            other => anyhow::bail!("unknown op `{other}`"),
        };
    }
    Ok(Request::Generate(parse_request_value(&v)?))
}

/// Parse one generation-request line.
pub fn parse_request(line: &str) -> crate::Result<GenRequest> {
    parse_request_value(&Json::parse(line)?)
}

/// Fetch `name` as a non-negative finite number, rejecting (rather than
/// silently casting) negative, non-finite and non-numeric values.
fn non_negative(v: &Json, name: &str) -> crate::Result<Option<f64>> {
    match v.get(name) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => match x.as_f64() {
            Some(f) if f.is_finite() && f >= 0.0 => Ok(Some(f)),
            Some(f) => anyhow::bail!("`{name}` must be non-negative and finite, got {f}"),
            None => anyhow::bail!("`{name}` must be a number"),
        },
    }
}

/// The request fields that each name a constraint. Exactly one may be
/// present — "first match wins" would silently ignore the others.
const CONSTRAINT_FIELDS: &[&str] = &["ebnf", "json_schema", "regex", "grammar", "stop"];

/// Fetch `name` as a string, rejecting non-string values (a number under
/// `"regex"` is a client bug, not a missing constraint).
fn require_str<'a>(v: &'a Json, name: &str) -> crate::Result<&'a str> {
    v.get(name)
        .and_then(|x| x.as_str())
        .ok_or_else(|| anyhow::anyhow!("`{name}` must be a string"))
}

/// The request's constraint spec, from the single present constraint
/// field; conflicting fields are a structured error.
fn parse_spec(v: &Json) -> crate::Result<Option<ConstraintSpec>> {
    let present: Vec<&str> = CONSTRAINT_FIELDS
        .iter()
        .copied()
        .filter(|name| !matches!(v.get(name), None | Some(Json::Null)))
        .collect();
    if present.len() > 1 {
        anyhow::bail!(
            "conflicting constraint fields `{}` (pass exactly one of `{}`)",
            present.join("`, `"),
            CONSTRAINT_FIELDS.join("`, `")
        );
    }
    Ok(match present.first().copied() {
        None => None,
        Some("ebnf") => Some(ConstraintSpec::ebnf(require_str(v, "ebnf")?)),
        Some("regex") => Some(ConstraintSpec::regex(require_str(v, "regex")?)),
        Some("json_schema") => match v.get("json_schema") {
            // Inline object (the ergonomic form) or the document as a
            // string — both normalize to the same canonical source.
            Some(obj @ Json::Obj(_)) => Some(ConstraintSpec::json_schema(obj.to_string())),
            Some(Json::Str(s)) => Some(ConstraintSpec::json_schema(s.clone())),
            _ => anyhow::bail!("`json_schema` must be a schema object or its source as a string"),
        },
        Some("grammar") => {
            let name = require_str(v, "grammar")?.trim().to_ascii_lowercase();
            if !crate::grammar::builtin::GRAMMAR_NAMES.contains(&name.as_str()) {
                anyhow::bail!(
                    "unknown builtin grammar `{name}` (known: {})",
                    crate::grammar::builtin::GRAMMAR_NAMES.join(", ")
                );
            }
            Some(ConstraintSpec::builtin(name))
        }
        Some("stop") => {
            // `stop` accepts the scalar form common to serving APIs as
            // well as an array; anything else is an error rather than a
            // silent no-constraint.
            let seqs = match v.get("stop") {
                Some(Json::Str(s)) => vec![s.clone()],
                Some(Json::Arr(a)) => {
                    let mut seqs = Vec::with_capacity(a.len());
                    for x in a {
                        match x.as_str() {
                            Some(s) => seqs.push(s.to_string()),
                            None => anyhow::bail!("stop entries must be strings"),
                        }
                    }
                    seqs
                }
                _ => anyhow::bail!("stop must be a string or an array of strings"),
            };
            Some(ConstraintSpec::stop(seqs))
        }
        Some(other) => unreachable!("unhandled constraint field `{other}`"),
    })
}

/// Fetch `name` as a count that is ≥ 1 when present: `0` would silently
/// disable the feature the client explicitly asked for, so it is rejected
/// with the valid range (negatives and non-numbers are rejected by
/// [`non_negative`] with the same shape of error).
fn positive_count(v: &Json, name: &str) -> crate::Result<Option<usize>> {
    match non_negative(v, name)? {
        Some(f) if f < 1.0 => {
            anyhow::bail!("`{name}` must be ≥ 1 when present (omit it or pass null to disable)")
        }
        Some(f) => Ok(Some(f as usize)),
        None => Ok(None),
    }
}

/// Server-side ceiling on `tenant` length, bytes. Tenant names become
/// metric label values and fairness-lane keys, so they are kept short
/// and printable rather than trusted wholesale.
pub const MAX_TENANT_LEN: usize = 64;

/// Fetch the optional `tenant` field: a short printable identifier.
/// Control characters are rejected (they would corrupt the line-oriented
/// wire protocol and the Prometheus exposition alike).
fn parse_tenant(v: &Json) -> crate::Result<Option<String>> {
    match v.get("tenant") {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Str(s)) => {
            if s.is_empty() || s.len() > MAX_TENANT_LEN {
                anyhow::bail!("`tenant` must be 1..={MAX_TENANT_LEN} bytes, got {}", s.len());
            }
            if s.chars().any(|c| c.is_control()) {
                anyhow::bail!("`tenant` must not contain control characters");
            }
            Ok(Some(s.clone()))
        }
        Some(_) => anyhow::bail!("`tenant` must be a string"),
    }
}

fn parse_request_value(v: &Json) -> crate::Result<GenRequest> {
    let prompt = v.get("prompt").and_then(|p| p.as_str()).unwrap_or("").to_string();
    let method = v.get("method").and_then(|m| m.as_str()).unwrap_or("domino");
    let k = non_negative(v, "k")?.map(|k| k as u32);
    let speculative = positive_count(v, "speculative")?;
    let draft = positive_count(v, "draft")?;
    if draft.is_some() {
        if speculative.is_some() {
            anyhow::bail!(
                "`draft` and `speculative` are mutually exclusive \
                 (the draft lane subsumes single-token speculation)"
            );
        }
        if method != "domino" {
            anyhow::bail!(
                "`draft` requires `method: \"domino\"` (got `{method}`): the draft lane \
                 needs the opportunistic checker to prune proposals as they are built"
            );
        }
    }
    let max_tokens = non_negative(v, "max_tokens")?.map(|m| m as usize).unwrap_or(128);
    let constraint = Constraint::from_parts(method, parse_spec(v)?, k, speculative, draft);
    Ok(GenRequest {
        prompt,
        constraint,
        max_tokens: max_tokens.min(MAX_TOKENS_CAP),
        temperature: non_negative(v, "temperature")?.map(|t| t as f32),
        seed: non_negative(v, "seed")?.unwrap_or(0.0) as u64,
        deadline: non_negative(v, "deadline_ms")?.map(|ms| Duration::from_millis(ms as u64)),
        stream: v.get("stream").and_then(|s| s.as_bool()).unwrap_or(false),
        tenant: parse_tenant(v)?,
        trace: v.get("trace").and_then(|s| s.as_bool()).unwrap_or(false),
    })
}

/// Format one response line.
pub fn format_response(resp: &GenResponse) -> String {
    let mut obj = vec![
        ("text", Json::str(resp.text.clone())),
        ("tokens", Json::Num(resp.stats.tokens_out as f64)),
        ("interventions", Json::Num(resp.stats.interventions as f64)),
        ("model_calls", Json::Num(resp.stats.model_calls as f64)),
        ("masks", Json::Num(resp.stats.masks_computed as f64)),
        ("spec_accepted", Json::Num(resp.stats.spec_accepted as f64)),
        ("draft_proposed", Json::Num(resp.stats.draft_proposed as f64)),
        ("draft_accepted", Json::Num(resp.stats.draft_accepted as f64)),
        ("stopped", Json::Bool(resp.stats.stopped)),
        ("elapsed_s", Json::Num(resp.elapsed_s)),
    ];
    match &resp.error {
        Some(e) => obj.push(("error", Json::str(e.clone()))),
        None => obj.push(("error", Json::Null)),
    }
    match &resp.reason {
        Some(r) => obj.push(("reason", Json::str(r.clone()))),
        None => obj.push(("reason", Json::Null)),
    }
    // Inline trace summary, only when the request set `"trace": true`.
    if let Some(t) = &resp.trace {
        obj.push(("trace", t.clone()));
    }
    Json::obj(obj).to_string()
}

/// Format the `{"op":"trace"}` reply: the ring of recently captured
/// traces (full span trees + decision records), oldest first.
pub fn format_trace_dump(tracer: &super::trace::Tracer) -> String {
    let traces: Vec<Json> = tracer.recent().iter().map(|t| t.to_json()).collect();
    Json::obj(vec![("traces", Json::Arr(traces))]).to_string()
}

/// Format one streaming token event line.
pub fn format_event(ev: &StreamEvent) -> String {
    Json::obj(vec![
        ("token", Json::str(ev.text.clone())),
        ("index", Json::Num(ev.index as f64)),
    ])
    .to_string()
}

fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Format the `{"op":"stats"}` reply: the aggregated cross-shard metrics
/// snapshot.
pub fn format_stats(m: &Metrics, engines: usize) -> String {
    let tenants = Json::Obj(
        m.tenants
            .iter()
            .map(|(t, tm)| {
                let obj = Json::obj(vec![
                    ("completed", Json::Num(tm.completed as f64)),
                    ("failed", Json::Num(tm.failed as f64)),
                    ("cancelled", Json::Num(tm.cancelled as f64)),
                    ("deadline_exceeded", Json::Num(tm.deadline_exceeded as f64)),
                    ("shed", Json::Num(tm.shed as f64)),
                    ("tokens_generated", Json::Num(tm.tokens_generated as f64)),
                    ("queue_wait_p50_s", num_or_null(tm.queue_wait.percentile(0.5))),
                    ("queue_wait_p99_s", num_or_null(tm.queue_wait.percentile(0.99))),
                ]);
                (t.clone(), obj)
            })
            .collect(),
    );
    let aborts = Json::Obj(
        m.abort_reasons
            .iter()
            .map(|(k, n)| (k.clone(), Json::Num(*n as f64)))
            .collect(),
    );
    Json::obj(vec![
        ("engines", Json::Num(engines as f64)),
        ("requests_completed", Json::Num(m.requests_completed as f64)),
        ("requests_failed", Json::Num(m.requests_failed as f64)),
        ("requests_cancelled", Json::Num(m.requests_cancelled as f64)),
        ("requests_deadline_exceeded", Json::Num(m.requests_deadline_exceeded as f64)),
        ("requests_shed", Json::Num(m.requests_shed as f64)),
        ("tokens_generated", Json::Num(m.tokens_generated as f64)),
        ("model_calls", Json::Num(m.model_calls as f64)),
        ("forward_batches", Json::Num(m.forward_batches as f64)),
        ("forward_rows", Json::Num(m.forward_rows as f64)),
        ("batch_size_mean", num_or_null(m.batch_size.mean())),
        ("batch_size_p50", num_or_null(m.batch_size.percentile(0.5))),
        ("interventions", Json::Num(m.interventions as f64)),
        ("masks_computed", Json::Num(m.masks_computed as f64)),
        ("spec_proposed", Json::Num(m.spec_proposed as f64)),
        ("spec_accepted", Json::Num(m.spec_accepted as f64)),
        ("draft_proposed", Json::Num(m.draft_proposed as f64)),
        ("draft_accepted", Json::Num(m.draft_accepted as f64)),
        ("draft_accept_rate", Json::Num(m.draft_accept_rate())),
        ("registry_hits", Json::Num(m.registry_hits as f64)),
        ("registry_misses", Json::Num(m.registry_misses as f64)),
        ("registry_evictions", Json::Num(m.registry_evictions as f64)),
        ("registry_coalesced", Json::Num(m.registry_coalesced as f64)),
        ("registry_hot_entries", Json::Num(m.registry_hot_entries as f64)),
        ("registry_warm_entries", Json::Num(m.registry_warm_entries as f64)),
        ("registry_cold_entries", Json::Num(m.registry_cold_entries as f64)),
        ("engine_compile_ms", Json::Num(m.engine_compile_ms as f64)),
        ("artifact_hits", Json::Num(m.artifact_hits as f64)),
        ("artifact_misses", Json::Num(m.artifact_misses as f64)),
        ("artifact_invalid", Json::Num(m.artifact_invalid as f64)),
        ("warm_start_loaded", Json::Num(m.warm_start_loaded as f64)),
        ("warm_start_ms", Json::Num(m.warm_start_ms as f64)),
        ("mask_cache_hits", Json::Num(m.mask_cache_hits as f64)),
        ("mask_cache_misses", Json::Num(m.mask_cache_misses as f64)),
        ("mask_cache_hit_rate", Json::Num(m.mask_cache_hit_rate())),
        ("ttft_p50_s", num_or_null(m.ttft.percentile(0.5))),
        ("queue_wait_p50_s", num_or_null(m.queue_wait.percentile(0.5))),
        ("req_tps_mean", num_or_null(m.req_tps.mean())),
        ("model_time_s", Json::Num(m.model_time.as_secs_f64())),
        // Per-phase tick-time attribution (always on; tracing not
        // required): where an engine tick actually goes.
        (
            "tick_phases",
            Json::obj(vec![
                ("decide_ms_mean", num_or_null(m.tick_decide.mean() * 1e3)),
                ("gather_ms_mean", num_or_null(m.tick_gather.mean() * 1e3)),
                ("forward_ms_mean", num_or_null(m.tick_forward.mean() * 1e3)),
                ("finish_ms_mean", num_or_null(m.tick_finish.mean() * 1e3)),
                ("decide_ms_p99", num_or_null(m.tick_decide.percentile(0.99) * 1e3)),
                ("gather_ms_p99", num_or_null(m.tick_gather.percentile(0.99) * 1e3)),
                ("forward_ms_p99", num_or_null(m.tick_forward.percentile(0.99) * 1e3)),
                ("finish_ms_p99", num_or_null(m.tick_finish.percentile(0.99) * 1e3)),
            ]),
        ),
        (
            "traces_captured",
            Json::obj(vec![
                ("sampled", Json::Num(m.traces_sampled as f64)),
                ("requested", Json::Num(m.traces_requested as f64)),
                ("aborted", Json::Num(m.traces_aborted as f64)),
                ("slow", Json::Num(m.traces_slow as f64)),
            ]),
        ),
        ("connections_open", Json::Num(m.connections_open as f64)),
        ("connections_accepted", Json::Num(m.connections_accepted as f64)),
        ("connections_rejected", Json::Num(m.connections_rejected as f64)),
        ("connections_idle_timeout", Json::Num(m.connections_idle_timeout as f64)),
        ("connections_read_timeout", Json::Num(m.connections_read_timeout as f64)),
        ("connections_write_stall", Json::Num(m.connections_write_stall as f64)),
        ("conn_lifetime_p50_s", num_or_null(m.conn_lifetime.percentile(0.5))),
        ("conn_lifetime_p99_s", num_or_null(m.conn_lifetime.percentile(0.99))),
        ("tenants", tenants),
        ("abort_reasons", aborts),
    ])
    .to_string()
}

/// Has the peer's connection *errored* (reset / broken pipe)? Used to
/// cancel in-flight work whose client is gone.
///
/// Deliberately tolerant of read-side EOF: a client may half-close after
/// sending its request (`echo req | nc host port`) and still be waiting
/// for the reply, so `Ok(0)` is NOT treated as a disconnect. A fully
/// closed peer is detected once writes start failing — immediately for
/// streaming responses; for non-streaming ones the request is otherwise
/// bounded by `max_tokens` (capped) and any deadline.
fn client_disconnected(stream: &TcpStream) -> bool {
    let mut buf = [0u8; 1];
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let r = stream.peek(&mut buf);
    let _ = stream.set_nonblocking(false);
    match r {
        Ok(_) => false, // pending bytes, or tolerated half-close EOF
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true, // reset / broken pipe
    }
}

pub(crate) fn error_line(prefix: &str, e: impl std::fmt::Display) -> String {
    Json::obj(vec![("error", Json::str(format!("{prefix}{e}")))]).to_string()
}

/// Serve one generation request, blocking until the final response while
/// watching the connection so a disconnected client cancels the work.
fn handle_generate(req: GenRequest, sched: &Scheduler, out: &mut TcpStream) -> std::io::Result<()> {
    if req.stream {
        let (stx, srx) = mpsc::channel::<StreamEvent>();
        let handle = sched.submit_streaming(req, stx);
        let mut write_failed = false;
        // Drain events until the engine drops the sink (slot retired);
        // buffered events are delivered before the disconnect.
        loop {
            match srx.recv_timeout(Duration::from_millis(25)) {
                Ok(ev) => {
                    if !write_failed && writeln!(out, "{}", format_event(&ev)).is_err() {
                        write_failed = true;
                        handle.cancel();
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if !write_failed && client_disconnected(out) {
                        write_failed = true;
                        handle.cancel();
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let line = match handle.recv() {
            Ok(resp) => format_response(&resp),
            Err(e) => error_line("", format!("{e:#}")),
        };
        if write_failed {
            return Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "client gone"));
        }
        writeln!(out, "{line}")
    } else {
        let handle = sched.submit(req);
        let resp = loop {
            match handle.recv_timeout(Duration::from_millis(50)) {
                Ok(resp) => break resp,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if client_disconnected(out) {
                        // Abort the in-flight work; the engine's final
                        // (cancelled) response still arrives below.
                        handle.cancel();
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return writeln!(out, "{}", error_line("", "engine gone"));
                }
            }
        };
        writeln!(out, "{}", format_response(&resp))
    }
}

fn handle_conn(stream: TcpStream, sched: Arc<Scheduler>, defaults: ServeDefaults) {
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut out = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let result = match parse_line(&line) {
            Ok(Request::Stats) => {
                let reply = match sched.metrics() {
                    Ok(m) => format_stats(&m, sched.engines()),
                    Err(e) => error_line("stats failed: ", format!("{e:#}")),
                };
                writeln!(out, "{reply}")
            }
            Ok(Request::Trace) => writeln!(out, "{}", format_trace_dump(sched.tracer())),
            Ok(Request::Generate(mut req)) => {
                defaults.apply(&mut req);
                handle_generate(req, &sched, &mut out)
            }
            Err(e) => writeln!(out, "{}", error_line("bad request: ", format!("{e:#}"))),
        };
        if result.is_err() {
            break;
        }
    }
}

/// Route one metrics-listener request line to `(status, content-type,
/// body)`. `render` is only invoked for `/metrics`, so a health probe
/// never pays for a cross-shard metrics merge.
pub(crate) fn metrics_route(
    request_line: &str,
    render: impl FnOnce() -> crate::Result<String>,
) -> (u16, &'static str, String) {
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method != "GET" {
        return (405, "text/plain; charset=utf-8", "method not allowed\n".into());
    }
    match path {
        "/metrics" => match render() {
            Ok(body) => (200, "text/plain; version=0.0.4; charset=utf-8", body),
            Err(e) => (500, "text/plain; charset=utf-8", format!("metrics failed: {e:#}\n")),
        },
        "/healthz" => (200, "text/plain; charset=utf-8", "ok\n".into()),
        _ => (404, "text/plain; charset=utf-8", "not found (try /metrics)\n".into()),
    }
}

/// Bind `addr` and serve the Prometheus scrape endpoint (`GET /metrics`,
/// plus `GET /healthz`) on the gateway reactor; returns the bound
/// address (use port 0 for an OS-assigned port — handy for tests).
///
/// Hand-rolled HTTP/1.1: one request per connection, `Connection: close`.
/// Prometheus opens a fresh connection per scrape by default, so the
/// short-lived connection model costs nothing at scrape rates.
///
/// Historically this path spawned one unnamed, unbounded thread per
/// scrape connection — a hostile or merely slow client could pin threads
/// indefinitely (the classic slow-loris shape). The reactor multiplexes
/// scrape connections on its fixed worker pool instead, and a stalled
/// request head is cut after the read timeout with a structured 408.
///
/// The reactor holds the scheduler only weakly, so it never keeps a
/// shut-down scheduler alive; once the last strong reference drops the
/// gateway threads exit on their own.
pub fn spawn_metrics_http(sched: Arc<Scheduler>, addr: &str) -> crate::Result<SocketAddr> {
    use super::reactor::{Reactor, ReactorConfig};
    let reactor = Reactor::start(&sched, None, Some(addr), ReactorConfig::default())?;
    let local = reactor.metrics_addr().expect("metrics listener bound");
    // Detach: the handle is intentionally leaked (no drain on exit); the
    // gateway threads exit once the scheduler is dropped.
    std::mem::forget(reactor);
    Ok(local)
}

/// Bind `addr` and serve JSONL on the gateway reactor; returns the bound
/// address (use port 0 for an OS-assigned port — handy for tests).
///
/// Connections are multiplexed over the reactor's fixed worker pool with
/// default [`ReactorConfig`](super::reactor::ReactorConfig) limits; the
/// scheduler is held weakly, so dropping the caller's last `Arc` shuts
/// the gateway down (and flushes artifacts/priors) exactly as if no
/// server were running.
pub fn spawn_serve(sched: Arc<Scheduler>, addr: &str) -> crate::Result<SocketAddr> {
    use super::reactor::{Reactor, ReactorConfig};
    let reactor = Reactor::start(&sched, Some(addr), None, ReactorConfig::default())?;
    let local = reactor.jsonl_addr().expect("jsonl listener bound");
    std::mem::forget(reactor);
    Ok(local)
}

/// The pre-reactor front end: one accept loop, one OS thread per
/// connection, blocking I/O. Retained as the differential reference for
/// the gateway — `tests/integration_gateway.rs` proves the reactor
/// produces byte-identical streams — and as a fallback while the reactor
/// soaks. Not used by the CLI.
pub fn spawn_serve_threaded(sched: Arc<Scheduler>, addr: &str) -> crate::Result<SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    std::thread::Builder::new()
        .name("domino-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                let sched = sched.clone();
                std::thread::spawn(move || handle_conn(stream, sched, ServeDefaults::default()));
            }
        })
        .expect("spawn accept thread");
    Ok(local)
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7761") over the gateway
/// reactor. Takes the scheduler behind an `Arc` so a metrics listener
/// ([`spawn_metrics_http`]) can share it.
pub fn serve(sched: Arc<Scheduler>, addr: &str, defaults: ServeDefaults) -> crate::Result<()> {
    use super::reactor::{Reactor, ReactorConfig};
    let cfg = ReactorConfig { defaults, ..ReactorConfig::default() };
    let reactor = Reactor::start(&sched, Some(addr), None, cfg)?;
    eprintln!("domino: serving on {addr} ({} engine shard(s))", sched.engines());
    reactor.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_variants() {
        let r = parse_request(r#"{"prompt": "hi", "grammar": "json", "speculative": 8}"#).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(
            r.constraint,
            Constraint::domino(ConstraintSpec::builtin("json")).with_speculation(8)
        );
        let r = parse_request(r#"{"prompt": "x", "method": "unconstrained"}"#).unwrap();
        assert_eq!(r.constraint, Constraint::none());
        let r = parse_request(r#"{"prompt": "x", "grammar": "c", "method": "online"}"#).unwrap();
        assert_eq!(r.constraint, Constraint::online(ConstraintSpec::builtin("c")));
        let r = parse_request(r#"{"prompt": "x", "grammar": "json", "method": "domino-full", "k": 1}"#)
            .unwrap();
        assert_eq!(
            r.constraint,
            Constraint::domino(ConstraintSpec::builtin("json"))
                .with_lookahead(Some(1))
                .with_full_mask()
        );
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn parses_inline_constraints() {
        let r = parse_request(r#"{"prompt": "x", "ebnf": "root ::= \"a\""}"#).unwrap();
        assert_eq!(r.constraint.spec, ConstraintSpec::ebnf("root ::= \"a\""));
        let r = parse_request(r#"{"prompt": "x", "regex": "[0-9]+"}"#).unwrap();
        assert_eq!(r.constraint.spec, ConstraintSpec::regex("[0-9]+"));
        let r = parse_request(r#"{"prompt": "x", "stop": ["\n\n", "```"]}"#).unwrap();
        assert_eq!(
            r.constraint.spec,
            ConstraintSpec::stop(vec!["\n\n".into(), "```".into()])
        );
        // The scalar form common to serving APIs works too.
        let r = parse_request(r#"{"prompt": "x", "stop": "\n\n"}"#).unwrap();
        assert_eq!(r.constraint.spec, ConstraintSpec::stop(vec!["\n\n".into()]));
        // Malformed stop values are errors, not silent no-constraints.
        assert!(parse_request(r#"{"prompt": "x", "stop": 42}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "stop": [42]}"#).is_err());
        // Non-string constraint sources are client bugs, not no-ops.
        assert!(parse_request(r#"{"prompt": "x", "ebnf": 7}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "regex": false}"#).is_err());
        // Explicit nulls read as "field absent", matching the knobs.
        let r = parse_request(r#"{"prompt": "x", "ebnf": null, "grammar": "json"}"#).unwrap();
        assert_eq!(r.constraint.spec, ConstraintSpec::builtin("json"));
    }

    #[test]
    fn parses_json_schema_constraints() {
        // Inline schema object.
        let r = parse_request(
            r#"{"prompt": "x", "json_schema": {"type": "object", "required": ["a"], "properties": {"a": {"type": "integer"}}}}"#,
        )
        .unwrap();
        let ConstraintSpec::JsonSchema { source } = &r.constraint.spec else {
            panic!("{:?}", r.constraint.spec);
        };
        assert!(source.contains("\"required\""), "{source}");
        // The same schema as a string parses to an equal (normalized) spec.
        let r2 = parse_request(
            r#"{"prompt": "x", "json_schema": "{\"required\": [\"a\"], \"type\": \"object\", \"properties\": {\"a\": {\"type\": \"integer\"}}}"}"#,
        )
        .unwrap();
        assert_eq!(
            r.constraint.spec.fingerprint(),
            r2.constraint.spec.fingerprint(),
            "object and string forms must dedupe to one engine"
        );
        // Non-schema values are rejected.
        assert!(parse_request(r#"{"prompt": "x", "json_schema": 7}"#).is_err());
    }

    #[test]
    fn rejects_conflicting_constraint_fields() {
        for line in [
            r#"{"prompt": "x", "ebnf": "root ::= \"a\"", "grammar": "json"}"#,
            r#"{"prompt": "x", "json_schema": {}, "regex": "[0-9]+"}"#,
            r#"{"prompt": "x", "grammar": "json", "stop": ["\n"]}"#,
        ] {
            let err = parse_request(line).unwrap_err().to_string();
            assert!(err.contains("conflicting constraint fields"), "{line}: {err}");
            assert!(err.contains("exactly one"), "{line}: {err}");
        }
        // The error names the offending fields.
        let err = parse_request(r#"{"prompt": "x", "ebnf": "r ::= \"a\"", "stop": "x"}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("`ebnf`") && err.contains("`stop`"), "{err}");
    }

    #[test]
    fn unknown_grammar_name_error_lists_builtins() {
        let err =
            parse_request(r#"{"prompt": "x", "grammar": "jsonx"}"#).unwrap_err().to_string();
        assert!(err.contains("unknown builtin grammar `jsonx`"), "{err}");
        for name in crate::grammar::builtin::GRAMMAR_NAMES {
            assert!(err.contains(name), "missing `{name}` in: {err}");
        }
        // Known names still normalize (trim + lowercase).
        let r = parse_request(r#"{"prompt": "x", "grammar": " JSON "}"#).unwrap();
        assert_eq!(r.constraint.spec, ConstraintSpec::builtin("json"));
    }

    #[test]
    fn parses_draft_requests() {
        let r = parse_request(r#"{"prompt": "hi", "grammar": "json", "draft": 6}"#).unwrap();
        assert_eq!(
            r.constraint,
            Constraint::domino(ConstraintSpec::builtin("json")).with_draft(6)
        );
        // Explicit null means "absent", like every other knob.
        let r = parse_request(r#"{"prompt": "hi", "grammar": "json", "draft": null}"#).unwrap();
        assert_eq!(r.constraint, Constraint::domino(ConstraintSpec::builtin("json")));
    }

    #[test]
    fn serve_defaults_fill_draft_without_overriding() {
        let defaults = ServeDefaults { draft: Some(4) };
        let json = || ConstraintSpec::builtin("json");
        let mut r = parse_request(r#"{"prompt": "x", "grammar": "json"}"#).unwrap();
        defaults.apply(&mut r);
        assert_eq!(r.constraint, Constraint::domino(json()).with_draft(4));
        // Explicit wire values win over the server default.
        let mut r = parse_request(r#"{"prompt": "x", "grammar": "json", "draft": 2}"#).unwrap();
        defaults.apply(&mut r);
        assert_eq!(r.constraint, Constraint::domino(json()).with_draft(2));
        let mut r =
            parse_request(r#"{"prompt": "x", "grammar": "json", "speculative": 8}"#).unwrap();
        defaults.apply(&mut r);
        assert_eq!(r.constraint, Constraint::domino(json()).with_speculation(8));
        // Non-domino methods are untouched.
        let mut r =
            parse_request(r#"{"prompt": "x", "grammar": "json", "method": "online"}"#).unwrap();
        defaults.apply(&mut r);
        assert_eq!(r.constraint, Constraint::online(json()));
    }

    #[test]
    fn rejects_zero_speculation_and_draft_with_valid_range() {
        for (line, knob) in [
            (r#"{"prompt": "x", "grammar": "json", "speculative": 0}"#, "speculative"),
            (r#"{"prompt": "x", "grammar": "json", "draft": 0}"#, "draft"),
        ] {
            let err = parse_request(line).unwrap_err().to_string();
            assert!(err.contains(&format!("`{knob}` must be ≥ 1")), "{line}: {err}");
            assert!(err.contains("null to disable"), "{line}: {err}");
        }
    }

    #[test]
    fn rejects_draft_with_incompatible_modes() {
        for method in ["online", "domino-full", "unconstrained"] {
            let line =
                format!(r#"{{"prompt": "", "grammar": "json", "method": "{method}", "draft": 4}}"#);
            let err = parse_request(&line).unwrap_err().to_string();
            assert!(err.contains("requires `method: \"domino\"`"), "{method}: {err}");
            assert!(err.contains(method), "error must name the offending method: {err}");
        }
        let err = parse_request(r#"{"prompt": "x", "draft": 4, "speculative": 8}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn rejects_negative_and_non_numeric_knobs() {
        assert!(parse_request(r#"{"prompt": "x", "k": -1}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "speculative": -8}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "draft": -4}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "max_tokens": -5}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "seed": -7}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "deadline_ms": -100}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "temperature": -2}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "max_tokens": "many"}"#).is_err());
        // Explicit nulls mean "absent", as before.
        let r = parse_request(r#"{"prompt": "x", "grammar": "json", "k": null}"#).unwrap();
        assert_eq!(r.constraint, Constraint::domino(ConstraintSpec::builtin("json")));
    }

    #[test]
    fn clamps_max_tokens_to_cap() {
        let r = parse_request(r#"{"prompt": "x", "max_tokens": 1000000}"#).unwrap();
        assert_eq!(r.max_tokens, MAX_TOKENS_CAP);
        let r = parse_request(r#"{"prompt": "x", "max_tokens": 16}"#).unwrap();
        assert_eq!(r.max_tokens, 16);
    }

    #[test]
    fn parses_stream_and_deadline() {
        let r = parse_request(r#"{"prompt": "x", "stream": true, "deadline_ms": 1500}"#).unwrap();
        assert!(r.stream);
        assert_eq!(r.deadline, Some(Duration::from_millis(1500)));
        let r = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert!(!r.stream);
        assert_eq!(r.deadline, None);
    }

    #[test]
    fn parses_stats_op() {
        assert!(matches!(parse_line(r#"{"op": "stats"}"#).unwrap(), Request::Stats));
        assert!(matches!(
            parse_line(r#"{"prompt": "x"}"#).unwrap(),
            Request::Generate(_)
        ));
        assert!(parse_line(r#"{"op": "nope"}"#).is_err());
    }

    #[test]
    fn parses_trace_flag_and_op() {
        let r = parse_request(r#"{"prompt": "x", "trace": true}"#).unwrap();
        assert!(r.trace);
        let r = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert!(!r.trace, "trace defaults off");
        assert!(matches!(parse_line(r#"{"op": "trace"}"#).unwrap(), Request::Trace));
    }

    #[test]
    fn formats_inline_trace_summary() {
        let mut resp = GenResponse::overloaded("queue_full");
        resp.trace = Some(Json::obj(vec![("id", Json::Num(7.0))]));
        let v = Json::parse(&format_response(&resp)).unwrap();
        assert_eq!(v.get("trace").unwrap().get("id").unwrap().as_f64().unwrap(), 7.0);
    }

    #[test]
    fn trace_dump_formats_empty_ring() {
        let tracer = super::super::trace::Tracer::disabled();
        let v = Json::parse(&format_trace_dump(&tracer)).unwrap();
        assert_eq!(v.get("traces").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn stats_include_tick_phases_and_trace_counts() {
        let mut m = Metrics::default();
        m.tick_forward.record(0.002);
        m.traces_aborted = 3;
        let v = Json::parse(&format_stats(&m, 1)).unwrap();
        let phases = v.get("tick_phases").unwrap();
        assert!(
            (phases.get("forward_ms_mean").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9
        );
        // Unrecorded phases serialize as null, not NaN.
        assert_eq!(phases.get("decide_ms_mean"), Some(&Json::Null));
        let traces = v.get("traces_captured").unwrap();
        assert_eq!(traces.get("aborted").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(traces.get("sampled").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn formats_response() {
        let resp = GenResponse {
            text: "{\"a\": 1}".into(),
            stats: Default::default(),
            error: None,
            reason: None,
            elapsed_s: 0.25,
            trace: None,
        };
        let line = format_response(&resp);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("text").unwrap().as_str().unwrap(), "{\"a\": 1}");
        assert_eq!(v.get("error"), Some(&Json::Null));
        assert_eq!(v.get("reason"), Some(&Json::Null));
        // No trace requested → no trace key at all (not a null).
        assert_eq!(v.get("trace"), None);
        // Structured failures carry the machine-readable cause.
        let resp = GenResponse::overloaded("tenant_quota");
        let v = Json::parse(&format_response(&resp)).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "overloaded");
        assert_eq!(v.get("reason").unwrap().as_str().unwrap(), "tenant_quota");
    }

    #[test]
    fn parses_and_validates_tenant() {
        let r = parse_request(r#"{"prompt": "x", "tenant": "team-a"}"#).unwrap();
        assert_eq!(r.tenant.as_deref(), Some("team-a"));
        assert_eq!(r.tenant_label(), "team-a");
        // Absent and explicit-null both mean the default bucket.
        let r = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(r.tenant, None);
        assert_eq!(r.tenant_label(), super::super::engine::DEFAULT_TENANT);
        let r = parse_request(r#"{"prompt": "x", "tenant": null}"#).unwrap();
        assert_eq!(r.tenant, None);
        // Malformed tenants are structured errors, not silent defaults.
        assert!(parse_request(r#"{"prompt": "x", "tenant": ""}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "tenant": 7}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "tenant": "a\tb"}"#).is_err());
        let long = format!(r#"{{"prompt": "x", "tenant": "{}"}}"#, "t".repeat(65));
        assert!(parse_request(&long).is_err());
        let max = format!(r#"{{"prompt": "x", "tenant": "{}"}}"#, "t".repeat(64));
        assert!(parse_request(&max).is_ok());
    }

    #[test]
    fn stats_include_tenants_and_abort_reasons() {
        let mut m = Metrics::default();
        m.tenant("team-a").completed = 3;
        m.tenant("team-a").queue_wait.record(0.5);
        m.record_abort("shed", "tenant_quota");
        let v = Json::parse(&format_stats(&m, 1)).unwrap();
        let t = v.get("tenants").unwrap().get("team-a").unwrap();
        assert_eq!(t.get("completed").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(t.get("queue_wait_p50_s").unwrap().as_f64().unwrap(), 0.5);
        let a = v.get("abort_reasons").unwrap().get("shed/tenant_quota").unwrap();
        assert_eq!(a.as_f64().unwrap(), 1.0);
    }

    #[test]
    fn metrics_route_serves_exposition_health_and_errors() {
        let render = || Ok("# HELP domino_tokens_generated_total t\n".to_string());
        let (status, ctype, body) = metrics_route("GET /metrics HTTP/1.1", render);
        assert_eq!(status, 200);
        assert!(ctype.contains("version=0.0.4"), "{ctype}");
        assert!(body.starts_with("# HELP"), "{body}");

        let (status, _, body) = metrics_route("GET /healthz HTTP/1.1", render);
        assert_eq!((status, body.as_str()), (200, "ok\n"));

        let (status, _, _) = metrics_route("GET /nope HTTP/1.1", render);
        assert_eq!(status, 404);
        let (status, _, _) = metrics_route("POST /metrics HTTP/1.1", render);
        assert_eq!(status, 405);
        // A health probe must not trigger a metrics render.
        let (status, _, _) =
            metrics_route("GET /healthz HTTP/1.1", || -> crate::Result<String> {
                panic!("rendered for /healthz")
            });
        assert_eq!(status, 200);
        // Render failures surface as a 500, not a hung scrape.
        let (status, _, body) =
            metrics_route("GET /metrics HTTP/1.1", || anyhow::bail!("shard poisoned"));
        assert_eq!(status, 500);
        assert!(body.contains("shard poisoned"), "{body}");
    }

    #[test]
    fn formats_event_and_stats() {
        let line = format_event(&StreamEvent { text: "ab".into(), index: 3 });
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("token").unwrap().as_str().unwrap(), "ab");
        assert_eq!(v.get("index").unwrap().as_f64().unwrap(), 3.0);

        let mut m = Metrics {
            artifact_hits: 2,
            warm_start_ms: 12,
            forward_batches: 3,
            forward_rows: 9,
            draft_proposed: 4,
            draft_accepted: 2,
            ..Default::default()
        };
        m.batch_size.record(3.0);
        let line = format_stats(&m, 4);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("engines").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(v.get("forward_batches").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(v.get("forward_rows").unwrap().as_f64().unwrap(), 9.0);
        assert_eq!(v.get("batch_size_mean").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(v.get("requests_shed").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(v.get("artifact_hits").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(v.get("artifact_invalid").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(v.get("warm_start_ms").unwrap().as_f64().unwrap(), 12.0);
        assert_eq!(v.get("draft_proposed").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(v.get("draft_accepted").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(v.get("draft_accept_rate").unwrap().as_f64().unwrap(), 0.5);
        // Empty summaries serialize as null, not NaN (which isn't JSON).
        assert_eq!(v.get("ttft_p50_s"), Some(&Json::Null));
    }
}
