//! JSONL-over-TCP front end.
//!
//! Protocol: one JSON object per line.
//!
//! Request:
//! ```json
//! {"prompt": "...", "grammar": "json", "method": "domino",
//!  "k": null, "speculative": 8, "max_tokens": 128,
//!  "temperature": 1.0, "seed": 7}
//! ```
//! `method`: "unconstrained" | "domino" | "domino-full" | "online".
//!
//! The constraint itself is any ONE of (first match wins):
//! * `"ebnf": "root ::= ..."` — an inline grammar in the crate's EBNF
//!   notation, compiled on first sight and cached by content hash;
//! * `"regex": "[0-9]+"` — output is exactly one match of the pattern;
//! * `"grammar": "json"` — a builtin evaluation grammar by name;
//! * `"stop": ["\n\n"]` — free generation until a stop sequence appears.
//!
//! Response:
//! ```json
//! {"text": "...", "tokens": 42, "interventions": 0, "model_calls": 40,
//!  "masks": 3, "elapsed_s": 0.8, "error": null}
//! ```

use super::engine::{Constraint, ConstraintSpec, GenRequest, GenResponse, Server};
use crate::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// Parse one request line.
pub fn parse_request(line: &str) -> crate::Result<GenRequest> {
    let v = Json::parse(line)?;
    let prompt = v.get("prompt").and_then(|p| p.as_str()).unwrap_or("").to_string();
    let method = v.get("method").and_then(|m| m.as_str()).unwrap_or("domino");
    let k = v.get("k").and_then(|k| k.as_f64()).map(|k| k as u32);
    let speculative = v.get("speculative").and_then(|s| s.as_f64()).map(|s| s as usize);
    // `stop` accepts the scalar form common to serving APIs as well as an
    // array; anything else is an error rather than a silent no-constraint.
    let stop: Option<Vec<String>> = match v.get("stop") {
        None => None,
        Some(Json::Str(s)) => Some(vec![s.clone()]),
        Some(Json::Arr(a)) => {
            let mut seqs = Vec::with_capacity(a.len());
            for x in a {
                match x.as_str() {
                    Some(s) => seqs.push(s.to_string()),
                    None => anyhow::bail!("stop entries must be strings"),
                }
            }
            Some(seqs)
        }
        Some(_) => anyhow::bail!("stop must be a string or an array of strings"),
    };
    let spec = if let Some(src) = v.get("ebnf").and_then(|g| g.as_str()) {
        Some(ConstraintSpec::ebnf(src))
    } else if let Some(p) = v.get("regex").and_then(|g| g.as_str()) {
        Some(ConstraintSpec::regex(p))
    } else if let Some(g) = v.get("grammar").and_then(|g| g.as_str()) {
        Some(ConstraintSpec::builtin(g))
    } else {
        stop.map(ConstraintSpec::stop)
    };
    let constraint = Constraint::from_parts(method, spec, k, speculative);
    Ok(GenRequest {
        prompt,
        constraint,
        max_tokens: v.get("max_tokens").and_then(|m| m.as_f64()).unwrap_or(128.0) as usize,
        temperature: v.get("temperature").and_then(|t| t.as_f64()).map(|t| t as f32),
        seed: v.get("seed").and_then(|s| s.as_f64()).unwrap_or(0.0) as u64,
    })
}

/// Format one response line.
pub fn format_response(resp: &GenResponse) -> String {
    let mut obj = vec![
        ("text", Json::str(resp.text.clone())),
        ("tokens", Json::Num(resp.stats.tokens_out as f64)),
        ("interventions", Json::Num(resp.stats.interventions as f64)),
        ("model_calls", Json::Num(resp.stats.model_calls as f64)),
        ("masks", Json::Num(resp.stats.masks_computed as f64)),
        ("spec_accepted", Json::Num(resp.stats.spec_accepted as f64)),
        ("stopped", Json::Bool(resp.stats.stopped)),
        ("elapsed_s", Json::Num(resp.elapsed_s)),
    ];
    match &resp.error {
        Some(e) => obj.push(("error", Json::str(e.clone()))),
        None => obj.push(("error", Json::Null)),
    }
    Json::obj(obj).to_string()
}

fn handle_conn(stream: TcpStream, server: Arc<Server>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut out = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse_request(&line) {
            Ok(req) => match server.generate(req) {
                Ok(resp) => format_response(&resp),
                Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string(),
            },
            Err(e) => Json::obj(vec![("error", Json::str(format!("bad request: {e:#}")))])
                .to_string(),
        };
        if writeln!(out, "{reply}").is_err() {
            break;
        }
    }
    let _ = peer;
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7761").
pub fn serve(server: Server, addr: &str) -> crate::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("domino: serving on {addr}");
    let server = Arc::new(server);
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let server = server.clone();
        std::thread::spawn(move || handle_conn(stream, server));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_variants() {
        let r = parse_request(r#"{"prompt": "hi", "grammar": "json", "speculative": 8}"#).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(
            r.constraint,
            Constraint::domino(ConstraintSpec::builtin("json")).with_speculation(8)
        );
        let r = parse_request(r#"{"prompt": "x", "method": "unconstrained"}"#).unwrap();
        assert_eq!(r.constraint, Constraint::none());
        let r = parse_request(r#"{"prompt": "x", "grammar": "c", "method": "online"}"#).unwrap();
        assert_eq!(r.constraint, Constraint::online(ConstraintSpec::builtin("c")));
        let r = parse_request(r#"{"prompt": "x", "grammar": "json", "method": "domino-full", "k": 1}"#)
            .unwrap();
        assert_eq!(
            r.constraint,
            Constraint::domino(ConstraintSpec::builtin("json"))
                .with_lookahead(Some(1))
                .with_full_mask()
        );
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn parses_inline_constraints() {
        let r = parse_request(r#"{"prompt": "x", "ebnf": "root ::= \"a\""}"#).unwrap();
        assert_eq!(r.constraint.spec, ConstraintSpec::ebnf("root ::= \"a\""));
        let r = parse_request(r#"{"prompt": "x", "regex": "[0-9]+"}"#).unwrap();
        assert_eq!(r.constraint.spec, ConstraintSpec::regex("[0-9]+"));
        let r = parse_request(r#"{"prompt": "x", "stop": ["\n\n", "```"]}"#).unwrap();
        assert_eq!(
            r.constraint.spec,
            ConstraintSpec::stop(vec!["\n\n".into(), "```".into()])
        );
        // The scalar form common to serving APIs works too.
        let r = parse_request(r#"{"prompt": "x", "stop": "\n\n"}"#).unwrap();
        assert_eq!(r.constraint.spec, ConstraintSpec::stop(vec!["\n\n".into()]));
        // Malformed stop values are errors, not silent no-constraints.
        assert!(parse_request(r#"{"prompt": "x", "stop": 42}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "stop": [42]}"#).is_err());
        // Inline EBNF takes precedence over a builtin name on one line.
        let r = parse_request(r#"{"prompt": "x", "ebnf": "root ::= \"a\"", "grammar": "json"}"#)
            .unwrap();
        assert!(matches!(r.constraint.spec, ConstraintSpec::Ebnf { .. }));
    }

    #[test]
    fn formats_response() {
        let resp = GenResponse {
            text: "{\"a\": 1}".into(),
            stats: Default::default(),
            error: None,
            elapsed_s: 0.25,
        };
        let line = format_response(&resp);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("text").unwrap().as_str().unwrap(), "{\"a\": 1}");
        assert_eq!(v.get("error"), Some(&Json::Null));
    }
}
