//! JSONL-over-TCP front end.
//!
//! Protocol: one JSON object per line.
//!
//! Request:
//! ```json
//! {"prompt": "...", "grammar": "json", "method": "domino",
//!  "k": null, "speculative": 8, "max_tokens": 128,
//!  "temperature": 1.0, "seed": 7}
//! ```
//! `method`: "unconstrained" | "domino" | "domino-full" | "online".
//!
//! Response:
//! ```json
//! {"text": "...", "tokens": 42, "interventions": 0, "model_calls": 40,
//!  "elapsed_s": 0.8, "error": null}
//! ```

use super::engine::{Constraint, GenRequest, GenResponse, Server};
use crate::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// Parse one request line.
pub fn parse_request(line: &str) -> crate::Result<GenRequest> {
    let v = Json::parse(line)?;
    let prompt = v.get("prompt").and_then(|p| p.as_str()).unwrap_or("").to_string();
    let grammar = v.get("grammar").and_then(|g| g.as_str()).map(|s| s.to_string());
    let method = v.get("method").and_then(|m| m.as_str()).unwrap_or("domino");
    let k = v.get("k").and_then(|k| k.as_f64()).map(|k| k as u32);
    let speculative = v.get("speculative").and_then(|s| s.as_f64()).map(|s| s as usize);
    let constraint = match (method, grammar) {
        ("unconstrained", _) | (_, None) => Constraint::None,
        ("online", Some(g)) => Constraint::Online { grammar: g },
        ("domino-full", Some(g)) => {
            Constraint::Domino { grammar: g, k, speculative: None, full_mask: true }
        }
        (_, Some(g)) => Constraint::Domino { grammar: g, k, speculative, full_mask: false },
    };
    Ok(GenRequest {
        prompt,
        constraint,
        max_tokens: v.get("max_tokens").and_then(|m| m.as_f64()).unwrap_or(128.0) as usize,
        temperature: v.get("temperature").and_then(|t| t.as_f64()).map(|t| t as f32),
        seed: v.get("seed").and_then(|s| s.as_f64()).unwrap_or(0.0) as u64,
    })
}

/// Format one response line.
pub fn format_response(resp: &GenResponse) -> String {
    let mut obj = vec![
        ("text", Json::str(resp.text.clone())),
        ("tokens", Json::Num(resp.stats.tokens_out as f64)),
        ("interventions", Json::Num(resp.stats.interventions as f64)),
        ("model_calls", Json::Num(resp.stats.model_calls as f64)),
        ("spec_accepted", Json::Num(resp.stats.spec_accepted as f64)),
        ("stopped", Json::Bool(resp.stats.stopped)),
        ("elapsed_s", Json::Num(resp.elapsed_s)),
    ];
    match &resp.error {
        Some(e) => obj.push(("error", Json::str(e.clone()))),
        None => obj.push(("error", Json::Null)),
    }
    Json::obj(obj).to_string()
}

fn handle_conn(stream: TcpStream, server: Arc<Server>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut out = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse_request(&line) {
            Ok(req) => match server.generate(req) {
                Ok(resp) => format_response(&resp),
                Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string(),
            },
            Err(e) => Json::obj(vec![("error", Json::str(format!("bad request: {e:#}")))])
                .to_string(),
        };
        if writeln!(out, "{reply}").is_err() {
            break;
        }
    }
    let _ = peer;
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7761").
pub fn serve(server: Server, addr: &str) -> crate::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("domino: serving on {addr}");
    let server = Arc::new(server);
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let server = server.clone();
        std::thread::spawn(move || handle_conn(stream, server));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::engine::Constraint;

    #[test]
    fn parses_request_variants() {
        let r = parse_request(r#"{"prompt": "hi", "grammar": "json", "speculative": 8}"#).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(
            r.constraint,
            Constraint::Domino {
                grammar: "json".into(),
                k: None,
                speculative: Some(8),
                full_mask: false
            }
        );
        let r = parse_request(r#"{"prompt": "x", "method": "unconstrained"}"#).unwrap();
        assert_eq!(r.constraint, Constraint::None);
        let r = parse_request(r#"{"prompt": "x", "grammar": "c", "method": "online"}"#).unwrap();
        assert_eq!(r.constraint, Constraint::Online { grammar: "c".into() });
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn formats_response() {
        let resp = GenResponse {
            text: "{\"a\": 1}".into(),
            stats: Default::default(),
            error: None,
            elapsed_s: 0.25,
        };
        let line = format_response(&resp);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("text").unwrap().as_str().unwrap(), "{\"a\": 1}");
        assert_eq!(v.get("error"), Some(&Json::Null));
    }
}
