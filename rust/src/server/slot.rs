//! One in-flight request slot: decode state, per-step token streaming,
//! and an abort path for cancellation/deadlines.
//!
//! A slot's decode iteration is split at the model-call boundary so the
//! engine can batch the forward pass across slots (one
//! [`LmBackend::forward_batch`] per tick instead of one `append` per
//! slot):
//!
//! * [`Slot::begin_step`] — *decide*: mask/sample/commit against the
//!   current logits (plain modes) or form a speculative proposal; leaves
//!   the needed model call as a pending extension.
//! * [`Slot::take_lane`] — *gather*: expose that extension as one lane
//!   of the tick's batch.
//! * [`Slot::finish_step`] — *finish*: consume the logit rows the
//!   batched forward produced (assign the successor row, or verify the
//!   proposal and commit its accepted prefix).
//!
//! [`step_batched`] drives one whole tick over a set of slots;
//! [`Slot::step`] recombines the halves into the self-contained per-slot
//! path (tests, benches, the batched path's parity reference).

use crate::constraint::MaskCache;
use crate::domino::draft::{adaptive_k, cached_mask_with_hit, DraftModel};
use crate::domino::generate::Prompt;
use crate::domino::{Checker, DominoDecoder, SpeculativeModel, TokenMask};
use crate::runtime::sampler::{decode, log_prob, Sampling};
use crate::runtime::{BatchLane, LmBackend, LmSession};
use crate::server::trace::SlotTrace;
use crate::tokenizer::{Vocab, EOS_ID};
use crate::util::Rng;
use crate::TokenId;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One streamed chunk of output text: the bytes a committed token (or the
/// prompt-healing overhang) contributed to the output. Tokens are byte
/// sequences, so a token may end mid-way through a multi-byte UTF-8
/// character; the stream buffers such an incomplete tail and emits it
/// with the next token's bytes, keeping the concatenation of all `text`
/// fields equal to the final response text.
#[derive(Clone, Debug)]
pub struct StreamEvent {
    /// Output text contributed by this step.
    pub text: String,
    /// 1-based index of this event within the request's stream.
    pub index: usize,
}

/// Per-slot streaming state, kept separate from [`Slot`]'s decode state so
/// the speculative path (which holds `&mut self.mode`) can still emit.
#[derive(Default)]
struct Stream {
    sink: Option<mpsc::Sender<StreamEvent>>,
    events: usize,
    gone: bool,
    /// Bytes held back because they end in an incomplete UTF-8 sequence
    /// (a token boundary split a multi-byte character).
    pending: Vec<u8>,
}

impl Stream {
    fn emit_bytes(&mut self, bytes: &[u8]) {
        if self.sink.is_none() {
            return;
        }
        self.pending.extend_from_slice(bytes);
        // Emit the longest prefix that ends on a character boundary; an
        // incomplete trailing sequence waits for the next token's bytes.
        let emit_to = match std::str::from_utf8(&self.pending) {
            Ok(_) => self.pending.len(),
            // Incomplete final sequence: hold the tail back.
            Err(e) if e.error_len().is_none() => e.valid_up_to(),
            // Genuinely invalid bytes: flush everything lossily (matches
            // the final text's lossy decode).
            Err(_) => self.pending.len(),
        };
        if emit_to == 0 {
            return;
        }
        let chunk: Vec<u8> = self.pending.drain(..emit_to).collect();
        self.send(String::from_utf8_lossy(&chunk).into_owned());
    }

    fn emit_token(&mut self, vocab: &Vocab, t: TokenId) {
        if self.sink.is_some() {
            self.emit_bytes(vocab.token_bytes(t));
        }
    }

    /// Flush any held-back incomplete tail (stream is ending).
    fn flush(&mut self) {
        if self.sink.is_some() && !self.pending.is_empty() {
            let tail = std::mem::take(&mut self.pending);
            self.send(String::from_utf8_lossy(&tail).into_owned());
        }
    }

    fn send(&mut self, text: String) {
        if let Some(sink) = &self.sink {
            self.events += 1;
            let ev = StreamEvent { text, index: self.events };
            if sink.send(ev).is_err() {
                // The stream consumer hung up: flag it so the engine loop
                // aborts this slot instead of decoding to the end.
                self.gone = true;
                self.sink = None;
            }
        }
    }
}

/// How this request is constrained/decoded.
///
/// Grammar-backed checkers arrive here wrapped in
/// [`crate::constraint::CachedChecker`], so mask computations hit the
/// engine's shared state-keyed cache before traversing trees (or, for
/// the online baseline, scanning the vocabulary).
pub enum DecodeMode {
    /// No constraint.
    Unconstrained,
    /// Any checker, opportunistic masking (check proposal, mask on
    /// rejection).
    Opportunistic(Box<dyn Checker>),
    /// Any checker, full mask every step (Algorithm 1 verbatim).
    FullMask(Box<dyn Checker>),
    /// DOMINO with count-based speculation (§3.6). The model is shared
    /// across requests of the same grammar (that is what makes the priors
    /// useful), and so is the engine's mask cache — speculation needs the
    /// concrete decoder (no [`crate::constraint::CachedChecker`] wrapper),
    /// so its mask computations go through the cache explicitly.
    Speculative {
        decoder: DominoDecoder,
        spec: Arc<std::sync::Mutex<SpeculativeModel>>,
        s: usize,
        masks: Arc<MaskCache>,
        variant: u64,
    },
    /// The draft lane: multi-token proposals from a cheap
    /// [`DraftModel`] proposer (the shared prior's n-gram continuation
    /// counts), grammar-pruned *while built* — each candidate filtered
    /// through the shared mask cache before it can occupy a forward-pass
    /// row — then verified on the batched `scored` lanes with
    /// longest-accepted-prefix adoption. Proposal length adapts online to
    /// the slot's recent acceptance rate ([`adaptive_k`]), so a cold
    /// grammar degrades gracefully to K=1.
    Drafted {
        decoder: DominoDecoder,
        spec: Arc<std::sync::Mutex<SpeculativeModel>>,
        draft: Box<dyn DraftModel>,
        /// Request's draft-depth cap (`"draft": K` on the wire).
        k_max: usize,
        masks: Arc<MaskCache>,
        variant: u64,
        /// EWMA of per-proposal acceptance rates (drives [`adaptive_k`]).
        accept_ewma: f64,
        /// Rolling `(state key, token)` window for n-gram observation.
        hist: Vec<(u64, TokenId)>,
    },
}

impl DecodeMode {
    fn checker(&mut self) -> Option<&mut dyn Checker> {
        match self {
            DecodeMode::Unconstrained => None,
            DecodeMode::Opportunistic(c) | DecodeMode::FullMask(c) => Some(c.as_mut()),
            DecodeMode::Speculative { decoder, .. } => Some(decoder),
            DecodeMode::Drafted { decoder, .. } => Some(decoder),
        }
    }
}

/// Per-slot progress/statistics (mirrors `GenResult`).
#[derive(Clone, Debug, Default)]
pub struct SlotStats {
    pub tokens_out: usize,
    pub logprob_sum: f64,
    pub interventions: usize,
    pub model_calls: usize,
    pub masks_computed: usize,
    /// Wall time spent computing token masks, nanoseconds (the engine
    /// exports the per-request mean as `domino_mask_compute_us`).
    pub mask_ns: u64,
    pub spec_proposed: usize,
    pub spec_accepted: usize,
    pub draft_proposed: usize,
    pub draft_accepted: usize,
    pub stopped: bool,
}

/// A model call this slot is waiting on (the decide half ran; the
/// forward half hasn't).
enum Pending {
    /// Committed token(s) whose successor logits row hasn't arrived yet.
    Row(Vec<TokenId>),
    /// A speculative or drafted proposal awaiting per-token scored rows.
    /// Nothing is committed until [`Slot::finish_step`] verifies the
    /// prefix.
    Proposal(Vec<TokenId>),
}

/// A running request.
pub struct Slot {
    pub id: u64,
    pub session: Box<dyn LmSession>,
    pub mode: DecodeMode,
    pub vocab: Arc<Vocab>,
    pub sampling: Sampling,
    pub max_tokens: usize,
    pub rng: Rng,
    pub out: Vec<TokenId>,
    pub stats: SlotStats,
    logits: Vec<f32>,
    /// The forward pass this slot needs before it can decide again.
    pending: Option<Pending>,
    pub done: bool,
    /// Aborted by cancellation or deadline (set via [`Slot::abort`]); the
    /// output is the partial text produced so far.
    pub aborted: bool,
    /// Per-step streaming state (token sink + consumer liveness).
    stream: Stream,
    /// Output bytes produced by the healing phase (token overhang).
    text_prefix: Vec<u8>,
    /// Per-token decision recorder, attached by the engine when the
    /// owning request is traced (`server::trace`); `None` costs one
    /// branch per decision site.
    pub trace: Option<Box<SlotTrace>>,
}

impl Slot {
    /// Create the slot, run the prefill and the prompt-healing phase
    /// (§3.5: the prompt boundary is the one place healing matters).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: u64,
        mut session: Box<dyn LmSession>,
        mode: DecodeMode,
        vocab: Arc<Vocab>,
        prompt: &Prompt,
        sampling: Sampling,
        max_tokens: usize,
        seed: u64,
    ) -> crate::Result<Slot> {
        let logits = session.append(&prompt.ids)?;
        let mut stats = SlotStats::default();
        stats.model_calls += 1;
        let mut slot = Slot {
            id,
            session,
            mode,
            vocab,
            sampling,
            max_tokens,
            rng: Rng::new(seed),
            out: Vec::new(),
            stats,
            logits,
            pending: None,
            done: false,
            aborted: false,
            stream: Stream::default(),
            text_prefix: Vec::new(),
            trace: None,
        };
        slot.heal(&prompt.forced)?;
        Ok(slot)
    }

    /// Attach a per-step token sink (streaming). Output text already
    /// produced (the healing overhang) is flushed as the first event so
    /// the stream's concatenation equals the final `text()`.
    pub fn attach_sink(&mut self, sink: mpsc::Sender<StreamEvent>) {
        self.stream.sink = Some(sink);
        if !self.text_prefix.is_empty() {
            let prefix = self.text_prefix.clone();
            self.stream.emit_bytes(&prefix);
        }
    }

    /// Abort this slot mid-decode (cancellation or deadline). The slot
    /// stops consuming engine ticks; the partial output stays readable.
    pub fn abort(&mut self) {
        self.done = true;
        self.aborted = true;
    }

    /// Did the streaming consumer disappear (a sink send failed)? The
    /// driving loop treats this as a client disconnect and aborts.
    pub fn client_gone(&self) -> bool {
        self.stream.gone
    }

    /// Flush any buffered incomplete-UTF-8 tail to the stream. Called by
    /// the engine when the slot retires (complete or aborted), before
    /// the final response is sent.
    pub fn finish_stream(&mut self) {
        self.stream.flush();
    }

    /// Consume the healed prompt suffix (cf. `generate::Loop::heal`).
    fn heal(&mut self, forced: &[u8]) -> crate::Result<()> {
        let mut forced = forced.to_vec();
        while !forced.is_empty() {
            let mut mask = TokenMask::none(self.vocab.len());
            for id in 0..self.vocab.len() as crate::TokenId {
                let b = self.vocab.token_bytes(id);
                if b.is_empty() {
                    continue;
                }
                let ok = if b.len() <= forced.len() {
                    forced.starts_with(b)
                } else if b.starts_with(&forced) {
                    match self.mode.checker() {
                        Some(c) => c.check_bytes(&b[forced.len()..]),
                        None => true,
                    }
                } else {
                    false
                };
                if ok {
                    mask.allow(id);
                }
            }
            anyhow::ensure!(!mask.is_empty(), "prompt healing deadlocked");
            let mut masked = self.logits.clone();
            mask.apply(&mut masked);
            let t = decode(&masked, self.sampling, &mut self.rng);
            let b = self.vocab.token_bytes(t).to_vec();
            if b.len() <= forced.len() {
                forced.drain(..b.len());
            } else {
                let overhang = b[forced.len()..].to_vec();
                forced.clear();
                if let Some(c) = self.mode.checker() {
                    c.advance_bytes(&overhang)?;
                }
                self.out_text_prefix(&overhang);
            }
            self.logits = self.session.append(&[t])?;
            self.stats.model_calls += 1;
        }
        Ok(())
    }

    /// Bytes produced during healing that belong to the OUTPUT (the
    /// overhang past the prompt text). Kept separately: `out` holds whole
    /// tokens only.
    fn out_text_prefix(&mut self, bytes: &[u8]) {
        self.text_prefix.extend_from_slice(bytes);
    }

    /// Pick a (possibly masked) next token from `logits` with lazy
    /// coupling; records interventions.
    fn choose(
        logits: &[f32],
        checker: Option<&mut dyn Checker>,
        sampling: Sampling,
        rng: &mut Rng,
        stats: &mut SlotStats,
        full_mask: bool,
        mut trace: Option<&mut SlotTrace>,
    ) -> Option<TokenId> {
        let Some(checker) = checker else {
            return Some(decode(logits, sampling, rng));
        };
        if full_mask {
            let t_mask = Instant::now();
            let mask = checker.compute_mask();
            stats.masks_computed += 1;
            stats.mask_ns += t_mask.elapsed().as_nanos() as u64;
            if let Some(tr) = trace.as_deref_mut() {
                // The grammar-backed checkers here are CachedChecker
                // wrappers whose cache outcome is internal — only the
                // cardinality is observable.
                tr.note_mask(mask.count() as u32, None);
            }
            if mask.is_empty() {
                return None;
            }
            let proposal = decode(logits, sampling, rng);
            if mask.allowed(proposal) {
                return Some(proposal);
            }
            stats.interventions += 1;
            if let Some(tr) = trace.as_deref_mut() {
                tr.note_intervention();
            }
            let mut masked = logits.to_vec();
            mask.apply(&mut masked);
            Some(decode(&masked, sampling, rng))
        } else {
            let proposal = decode(logits, sampling, rng);
            if checker.check_token(proposal) {
                return Some(proposal);
            }
            stats.interventions += 1;
            let t_mask = Instant::now();
            let mask = checker.compute_mask();
            stats.masks_computed += 1;
            stats.mask_ns += t_mask.elapsed().as_nanos() as u64;
            if let Some(tr) = trace.as_deref_mut() {
                tr.note_intervention();
                tr.note_mask(mask.count() as u32, None);
            }
            if mask.is_empty() {
                return None;
            }
            let mut masked = logits.to_vec();
            mask.apply(&mut masked);
            Some(decode(&masked, sampling, rng))
        }
    }

    /// Commit one chosen token (checker advance + output + stream). The
    /// model-call half — fetching the successor logits — is left as the
    /// pending extension for the tick's batched forward pass.
    fn commit_choice(&mut self, chosen: TokenId) -> crate::Result<()> {
        self.stats.logprob_sum += log_prob(&self.logits, chosen);
        if chosen == EOS_ID {
            self.stats.stopped = true;
            self.done = true;
            return Ok(());
        }
        if let Some(c) = self.mode.checker() {
            c.advance(chosen)?;
        }
        self.out.push(chosen);
        self.stats.tokens_out += 1;
        if self.trace.is_some() {
            let state = self.mode.checker().and_then(|c| c.mask_key());
            if let Some(tr) = self.trace.as_deref_mut() {
                tr.commit(self.out.len() - 1, chosen, "sampled", state);
            }
        }
        self.stream.emit_token(&self.vocab, chosen);
        if self.out.len() >= self.max_tokens {
            self.done = true;
            return Ok(());
        }
        self.pending = Some(Pending::Row(vec![chosen]));
        Ok(())
    }

    /// The decide half of a decode iteration: choose and commit the next
    /// token against the current logits (plain modes) or form a
    /// speculative proposal — no model calls. The forward pass the slot
    /// now needs is left pending for [`Slot::take_lane`] /
    /// [`Slot::finish_step`]. No-op when the slot is done or already
    /// awaiting a forward pass (e.g. a correction row deferred from the
    /// previous tick's speculative verify).
    pub fn begin_step(&mut self) -> crate::Result<()> {
        if self.done || self.pending.is_some() {
            return Ok(());
        }
        // Speculative fast path: propose a chunk for one scored verify.
        if let DecodeMode::Speculative { decoder, spec, s, masks, variant } = &mut self.mode {
            let proposal = {
                let spec_guard = spec.lock().expect("spec lock");
                spec_guard.propose(decoder, *s)
            };
            if !proposal.is_empty() {
                self.stats.spec_proposed += proposal.len();
                if let Some(tr) = self.trace.as_deref_mut() {
                    tr.event(format!("spec proposed={}", proposal.len()));
                }
                self.pending = Some(Pending::Proposal(proposal));
                return Ok(());
            }
            // No confident proposal: plain step, and teach the count
            // model what the LLM chose.
            let chosen = {
                let proposal = decode(&self.logits, self.sampling, &mut self.rng);
                if decoder.check_token(proposal) {
                    proposal
                } else {
                    self.stats.interventions += 1;
                    let t_mask = Instant::now();
                    let (mask, hit) = cached_mask_with_hit(decoder, masks, *variant);
                    self.stats.masks_computed += 1;
                    self.stats.mask_ns += t_mask.elapsed().as_nanos() as u64;
                    if let Some(tr) = self.trace.as_deref_mut() {
                        tr.note_intervention();
                        tr.note_mask(mask.count() as u32, hit);
                    }
                    if mask.is_empty() {
                        self.done = true;
                        return Ok(());
                    }
                    let mut masked = self.logits.clone();
                    mask.apply(&mut masked);
                    decode(&masked, self.sampling, &mut self.rng)
                }
            };
            {
                let mut spec_guard = spec.lock().expect("spec lock");
                if let Some(key) = decoder.state_key() {
                    spec_guard.observe(key, chosen);
                }
            }
            return self.commit_choice(chosen);
        }

        // Draft lane: grammar-pruned multi-token proposal for one scored
        // verify, K adapted from the slot's recent acceptance rate.
        if let DecodeMode::Drafted {
            decoder,
            spec,
            draft,
            k_max,
            masks,
            variant,
            accept_ewma,
            hist,
        } = &mut self.mode
        {
            let k = adaptive_k(*accept_ewma, *k_max);
            let proposal = draft.propose(decoder, masks, *variant, k);
            if !proposal.is_empty() {
                self.stats.draft_proposed += proposal.len();
                self.pending = Some(Pending::Proposal(proposal));
                return Ok(());
            }
            // Cold prior: one plain opportunistic step (same forward cost
            // as an undrafted slot), and teach the prior what the LLM
            // chose — unigram plus every n-gram window.
            let chosen = {
                let proposal = decode(&self.logits, self.sampling, &mut self.rng);
                if decoder.check_token(proposal) {
                    proposal
                } else {
                    self.stats.interventions += 1;
                    let t_mask = Instant::now();
                    let (mask, hit) = cached_mask_with_hit(decoder, masks, *variant);
                    self.stats.masks_computed += 1;
                    self.stats.mask_ns += t_mask.elapsed().as_nanos() as u64;
                    if let Some(tr) = self.trace.as_deref_mut() {
                        tr.note_intervention();
                        tr.note_mask(mask.count() as u32, hit);
                    }
                    if mask.is_empty() {
                        self.done = true;
                        return Ok(());
                    }
                    let mut masked = self.logits.clone();
                    mask.apply(&mut masked);
                    decode(&masked, self.sampling, &mut self.rng)
                }
            };
            {
                let mut spec_guard = spec.lock().expect("spec lock");
                spec_guard.observe_step(hist, decoder.state_key(), chosen);
            }
            return self.commit_choice(chosen);
        }

        // Plain modes.
        let full_mask = matches!(self.mode, DecodeMode::FullMask(_));
        let chosen = Self::choose(
            &self.logits.clone(),
            self.mode.checker(),
            self.sampling,
            &mut self.rng,
            &mut self.stats,
            full_mask,
            self.trace.as_deref_mut(),
        );
        match chosen {
            Some(t) => self.commit_choice(t),
            None => {
                self.done = true; // dead end
                Ok(())
            }
        }
    }

    /// The gather half: expose the pending extension as one lane of the
    /// tick's batch, borrowing this slot's session. `None` when the slot
    /// needs no forward pass this tick (done, dead end, or EOS).
    pub fn take_lane(&mut self) -> Option<BatchLane<'_>> {
        if self.done {
            return None;
        }
        let (tokens, scored) = match &self.pending {
            None => return None,
            Some(Pending::Row(t)) => (t.clone(), false),
            Some(Pending::Proposal(t)) => (t.clone(), true),
        };
        Some(BatchLane { session: self.session.as_mut(), tokens, scored })
    }

    /// The finish half: consume the logit rows the batched forward pass
    /// produced for this slot's pending extension.
    pub fn finish_step(&mut self, rows: Vec<Vec<f32>>) -> crate::Result<()> {
        self.stats.model_calls += 1;
        match self.pending.take() {
            None => anyhow::bail!("finish_step without a pending forward"),
            Some(Pending::Row(_)) => {
                self.logits = rows
                    .into_iter()
                    .next_back()
                    .ok_or_else(|| anyhow::anyhow!("batched forward returned no logits row"))?;
                Ok(())
            }
            Some(Pending::Proposal(proposal)) => {
                if matches!(self.mode, DecodeMode::Drafted { .. }) {
                    self.finish_drafted(proposal, rows)
                } else {
                    self.finish_speculative(proposal, rows)
                }
            }
        }
    }

    /// Verify a speculative proposal against its scored rows (§3.6):
    /// commit the accepted prefix; on the first disagreement roll the
    /// session back and commit the corrected token, deferring its
    /// successor row to the next tick's batch (one forward pass per slot
    /// per tick).
    fn finish_speculative(
        &mut self,
        proposal: Vec<TokenId>,
        rows: Vec<Vec<f32>>,
    ) -> crate::Result<()> {
        anyhow::ensure!(rows.len() == proposal.len(), "scored rows/proposal length mismatch");
        let DecodeMode::Speculative { decoder, spec, masks, variant, .. } = &mut self.mode else {
            anyhow::bail!("scored rows arrived for a non-speculative slot");
        };
        let mut accepted = 0;
        for (i, &p) in proposal.iter().enumerate() {
            let choice = decode(&self.logits, self.sampling, &mut self.rng);
            let choice = if decoder.check_token(choice) {
                choice
            } else {
                self.stats.interventions += 1;
                let t_mask = Instant::now();
                let (mask, hit) = cached_mask_with_hit(decoder, masks, *variant);
                self.stats.masks_computed += 1;
                self.stats.mask_ns += t_mask.elapsed().as_nanos() as u64;
                if let Some(tr) = self.trace.as_deref_mut() {
                    tr.note_intervention();
                    tr.note_mask(mask.count() as u32, hit);
                }
                if mask.is_empty() {
                    // Dead end mid-verify: drop the unaccepted proposal
                    // suffix from the context and let the next decide
                    // phase conclude the dead end.
                    self.session.rollback(proposal.len() - accepted)?;
                    break;
                }
                let mut masked = self.logits.clone();
                mask.apply(&mut masked);
                decode(&masked, self.sampling, &mut self.rng)
            };
            if choice == p {
                self.stats.logprob_sum += log_prob(&self.logits, p);
                {
                    let mut spec_guard = spec.lock().expect("spec lock");
                    if let Some(key) = decoder.state_key() {
                        spec_guard.observe(key, p);
                    }
                }
                decoder.advance(p)?;
                self.out.push(p);
                self.stats.tokens_out += 1;
                if let Some(tr) = self.trace.as_deref_mut() {
                    tr.commit(self.out.len() - 1, p, "speculative", decoder.mask_key());
                }
                self.stream.emit_token(&self.vocab, p);
                self.stats.spec_accepted += 1;
                accepted += 1;
                self.logits = rows[i].clone();
                if self.out.len() >= self.max_tokens {
                    self.session.rollback(proposal.len() - accepted)?;
                    self.done = true;
                    return Ok(());
                }
            } else {
                self.session.rollback(proposal.len() - accepted)?;
                self.stats.logprob_sum += log_prob(&self.logits, choice);
                if choice == EOS_ID {
                    self.stats.stopped = true;
                    self.done = true;
                    return Ok(());
                }
                {
                    let mut spec_guard = spec.lock().expect("spec lock");
                    if let Some(key) = decoder.state_key() {
                        spec_guard.observe(key, choice);
                    }
                }
                decoder.advance(choice)?;
                self.out.push(choice);
                self.stats.tokens_out += 1;
                if let Some(tr) = self.trace.as_deref_mut() {
                    tr.commit(self.out.len() - 1, choice, "corrected", decoder.mask_key());
                }
                self.stream.emit_token(&self.vocab, choice);
                if self.out.len() >= self.max_tokens {
                    self.done = true;
                    return Ok(());
                }
                self.pending = Some(Pending::Row(vec![choice]));
                return Ok(());
            }
        }
        Ok(())
    }

    /// Verify a drafted proposal against its scored rows: commit the
    /// longest accepted prefix; on the first disagreement roll the
    /// session back and commit the corrected token, deferring its
    /// successor row to the next tick's batch (the same deferred
    /// correction as the speculative lane). The slot's acceptance EWMA —
    /// which sets the next proposal's length — and the proposer's
    /// feedback hook are updated exactly once per proposal.
    fn finish_drafted(&mut self, proposal: Vec<TokenId>, rows: Vec<Vec<f32>>) -> crate::Result<()> {
        anyhow::ensure!(rows.len() == proposal.len(), "scored rows/proposal length mismatch");
        let DecodeMode::Drafted { decoder, spec, draft, masks, variant, accept_ewma, hist, .. } =
            &mut self.mode
        else {
            anyhow::bail!("drafted rows arrived for a non-drafted slot");
        };
        let mut accepted = 0usize;
        let mut correction: Option<TokenId> = None;
        let mut capped = false;
        for (i, &p) in proposal.iter().enumerate() {
            let choice = decode(&self.logits, self.sampling, &mut self.rng);
            let choice = if decoder.check_token(choice) {
                choice
            } else {
                self.stats.interventions += 1;
                let t_mask = Instant::now();
                let (mask, hit) = cached_mask_with_hit(decoder, masks, *variant);
                self.stats.masks_computed += 1;
                self.stats.mask_ns += t_mask.elapsed().as_nanos() as u64;
                if let Some(tr) = self.trace.as_deref_mut() {
                    tr.note_intervention();
                    tr.note_mask(mask.count() as u32, hit);
                }
                if mask.is_empty() {
                    // Dead end mid-verify: drop the unaccepted suffix and
                    // let the next decide phase conclude the dead end.
                    break;
                }
                let mut masked = self.logits.clone();
                mask.apply(&mut masked);
                decode(&masked, self.sampling, &mut self.rng)
            };
            if choice != p {
                correction = Some(choice);
                break;
            }
            self.stats.logprob_sum += log_prob(&self.logits, p);
            {
                let mut spec_guard = spec.lock().expect("spec lock");
                spec_guard.observe_step(hist, decoder.state_key(), p);
            }
            decoder.advance(p)?;
            self.out.push(p);
            self.stats.tokens_out += 1;
            if let Some(tr) = self.trace.as_deref_mut() {
                tr.commit(self.out.len() - 1, p, "drafted", decoder.mask_key());
            }
            self.stream.emit_token(&self.vocab, p);
            self.stats.draft_accepted += 1;
            accepted += 1;
            self.logits = rows[i].clone();
            if self.out.len() >= self.max_tokens {
                capped = true;
                break;
            }
        }
        // Once per proposal: the acceptance EWMA drives the next tick's
        // adaptive K; the feedback hook lets a session-backed draft model
        // resync with the target.
        *accept_ewma = (*accept_ewma + accepted as f64 / proposal.len() as f64) / 2.0;
        draft.commit(&proposal[..accepted], correction);
        if let Some(tr) = self.trace.as_deref_mut() {
            tr.event(format!("draft proposed={} accepted={}", proposal.len(), accepted));
        }
        if accepted < proposal.len() {
            self.session.rollback(proposal.len() - accepted)?;
        }
        if capped {
            self.done = true;
            return Ok(());
        }
        let Some(choice) = correction else { return Ok(()) };
        self.stats.logprob_sum += log_prob(&self.logits, choice);
        if choice == EOS_ID {
            self.stats.stopped = true;
            self.done = true;
            return Ok(());
        }
        {
            let mut spec_guard = spec.lock().expect("spec lock");
            spec_guard.observe_step(hist, decoder.state_key(), choice);
        }
        decoder.advance(choice)?;
        self.out.push(choice);
        self.stats.tokens_out += 1;
        if let Some(tr) = self.trace.as_deref_mut() {
            tr.commit(self.out.len() - 1, choice, "corrected", decoder.mask_key());
        }
        self.stream.emit_token(&self.vocab, choice);
        if self.out.len() >= self.max_tokens {
            self.done = true;
            return Ok(());
        }
        self.pending = Some(Pending::Row(vec![choice]));
        Ok(())
    }

    /// One decode iteration, self-contained (the per-slot path): decide,
    /// run this slot's own forward pass, finish. Under speculation this
    /// may commit several tokens (one chunked verify); otherwise exactly
    /// one. The engine instead batches the forward half across slots —
    /// [`step_batched`] — with token-identical behavior.
    pub fn step(&mut self) -> crate::Result<()> {
        if self.done {
            return Ok(());
        }
        self.begin_step()?;
        while !self.done {
            let rows = match &self.pending {
                None => break,
                Some(Pending::Row(t)) => {
                    let t = t.clone();
                    vec![self.session.append(&t)?]
                }
                Some(Pending::Proposal(t)) => {
                    let t = t.clone();
                    self.session.append_scored(&t)?
                }
            };
            self.finish_step(rows)?;
        }
        Ok(())
    }

    /// The decoded output text (healing overhang + committed tokens).
    pub fn text(&self) -> String {
        let mut bytes = self.text_prefix.clone();
        bytes.extend_from_slice(&self.vocab.decode(&self.out));
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Mask utility for tests: current full mask if constrained.
    pub fn current_mask(&mut self) -> Option<Arc<TokenMask>> {
        self.mode.checker().map(|c| c.compute_mask())
    }
}

/// Outcome of one batched tick over a set of slots.
pub struct BatchTick {
    /// Per-slot results, index-aligned with the input slice. An `Err` is
    /// that slot's failure only — sibling slots in the same batch are
    /// unaffected and keep decoding.
    pub results: Vec<crate::Result<()>>,
    /// Slots that participated in the forward pass (the batch width).
    pub lanes: usize,
    /// Total logit rows the forward pass produced (a speculative lane
    /// contributes one per proposed token).
    pub rows: usize,
    /// Wall time of the decide phase (per-slot mask/sample/commit
    /// against current logits; no model calls).
    pub decide: Duration,
    /// Wall time of the gather phase (collecting pending extensions
    /// into batch lanes).
    pub gather: Duration,
    /// Wall time of the single batched forward pass (zero when no slot
    /// needed one this tick).
    pub forward: Duration,
    /// Wall time of the finish phase (routing rows back: verify /
    /// commit / stream).
    pub finish: Duration,
}

impl BatchTick {
    /// Did every slot step cleanly?
    pub fn all_ok(&self) -> bool {
        self.results.iter().all(|r| r.is_ok())
    }
}

/// Step a set of slots one decode tick with ONE batched forward pass:
/// decide per slot (mask/sample/commit against its current logits),
/// gather every pending extension into a single
/// [`LmBackend::forward_batch`] call, then finish each slot against its
/// returned rows. Plain slots, speculative slots mid-proposal and slots
/// with deferred correction rows coexist in the same batch; failures are
/// isolated per slot.
pub fn step_batched(backend: &dyn LmBackend, slots: &mut [&mut Slot]) -> BatchTick {
    let mut results: Vec<crate::Result<()>> = slots.iter().map(|_| Ok(())).collect();
    // Decide: no model calls.
    let t_decide = Instant::now();
    for (i, s) in slots.iter_mut().enumerate() {
        if s.done {
            continue;
        }
        if let Err(e) = s.begin_step() {
            s.done = true;
            results[i] = Err(e);
        }
    }
    let decide = t_decide.elapsed();
    // Gather → one batched forward. The lanes borrow the slots' sessions;
    // the returned rows are owned, so the borrow ends before finish.
    let mut lane_idx: Vec<usize> = Vec::new();
    let t_gather = Instant::now();
    let gather;
    let mut forward = Duration::ZERO;
    let lane_rows = {
        let mut lanes: Vec<BatchLane<'_>> = Vec::new();
        for (i, s) in slots.iter_mut().enumerate() {
            if results[i].is_err() {
                continue;
            }
            if let Some(lane) = s.take_lane() {
                lane_idx.push(i);
                lanes.push(lane);
            }
        }
        gather = t_gather.elapsed();
        if lanes.is_empty() {
            Vec::new()
        } else {
            let t_forward = Instant::now();
            let rows = backend.forward_batch(&mut lanes);
            forward = t_forward.elapsed();
            rows
        }
    };
    let lanes = lane_idx.len();
    let answered = lane_rows.len();
    let rows = lane_rows.iter().map(|r| r.as_ref().map_or(0, Vec::len)).sum();
    // Finish: route each lane's rows back to its slot. A backend that
    // breaks the one-result-per-lane contract fails the unanswered slots
    // outright — their sessions may already have advanced, so leaving
    // them silently pending would re-append the same tokens next tick.
    let t_finish = Instant::now();
    let mut lane_results = lane_rows.into_iter();
    for i in lane_idx {
        let r = match lane_results.next() {
            Some(Ok(rows)) => slots[i].finish_step(rows),
            Some(Err(e)) => Err(e),
            None => Err(anyhow::anyhow!("forward_batch answered {answered} of {lanes} lanes")),
        };
        if let Err(e) = r {
            slots[i].done = true;
            results[i] = Err(e);
        }
    }
    let finish = t_finish.elapsed();
    BatchTick { results, lanes, rows, decide, gather, forward, finish }
}
