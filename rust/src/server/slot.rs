//! One in-flight request slot: decode state, per-step token streaming,
//! and an abort path for cancellation/deadlines.

use crate::constraint::MaskCache;
use crate::domino::generate::Prompt;
use crate::domino::{Checker, DominoDecoder, SpeculativeModel, TokenMask};
use crate::runtime::sampler::{decode, log_prob, Sampling};
use crate::runtime::LmSession;
use crate::tokenizer::{Vocab, EOS_ID};
use crate::util::Rng;
use crate::TokenId;
use std::sync::mpsc;
use std::sync::Arc;

/// One streamed chunk of output text: the bytes a committed token (or the
/// prompt-healing overhang) contributed to the output. Tokens are byte
/// sequences, so a token may end mid-way through a multi-byte UTF-8
/// character; the stream buffers such an incomplete tail and emits it
/// with the next token's bytes, keeping the concatenation of all `text`
/// fields equal to the final response text.
#[derive(Clone, Debug)]
pub struct StreamEvent {
    /// Output text contributed by this step.
    pub text: String,
    /// 1-based index of this event within the request's stream.
    pub index: usize,
}

/// Per-slot streaming state, kept separate from [`Slot`]'s decode state so
/// the speculative path (which holds `&mut self.mode`) can still emit.
#[derive(Default)]
struct Stream {
    sink: Option<mpsc::Sender<StreamEvent>>,
    events: usize,
    gone: bool,
    /// Bytes held back because they end in an incomplete UTF-8 sequence
    /// (a token boundary split a multi-byte character).
    pending: Vec<u8>,
}

impl Stream {
    fn emit_bytes(&mut self, bytes: &[u8]) {
        if self.sink.is_none() {
            return;
        }
        self.pending.extend_from_slice(bytes);
        // Emit the longest prefix that ends on a character boundary; an
        // incomplete trailing sequence waits for the next token's bytes.
        let emit_to = match std::str::from_utf8(&self.pending) {
            Ok(_) => self.pending.len(),
            // Incomplete final sequence: hold the tail back.
            Err(e) if e.error_len().is_none() => e.valid_up_to(),
            // Genuinely invalid bytes: flush everything lossily (matches
            // the final text's lossy decode).
            Err(_) => self.pending.len(),
        };
        if emit_to == 0 {
            return;
        }
        let chunk: Vec<u8> = self.pending.drain(..emit_to).collect();
        self.send(String::from_utf8_lossy(&chunk).into_owned());
    }

    fn emit_token(&mut self, vocab: &Vocab, t: TokenId) {
        if self.sink.is_some() {
            self.emit_bytes(vocab.token_bytes(t));
        }
    }

    /// Flush any held-back incomplete tail (stream is ending).
    fn flush(&mut self) {
        if self.sink.is_some() && !self.pending.is_empty() {
            let tail = std::mem::take(&mut self.pending);
            self.send(String::from_utf8_lossy(&tail).into_owned());
        }
    }

    fn send(&mut self, text: String) {
        if let Some(sink) = &self.sink {
            self.events += 1;
            let ev = StreamEvent { text, index: self.events };
            if sink.send(ev).is_err() {
                // The stream consumer hung up: flag it so the engine loop
                // aborts this slot instead of decoding to the end.
                self.gone = true;
                self.sink = None;
            }
        }
    }
}

/// How this request is constrained/decoded.
///
/// Grammar-backed checkers arrive here wrapped in
/// [`crate::constraint::CachedChecker`], so mask computations hit the
/// engine's shared state-keyed cache before traversing trees (or, for
/// the online baseline, scanning the vocabulary).
pub enum DecodeMode {
    /// No constraint.
    Unconstrained,
    /// Any checker, opportunistic masking (check proposal, mask on
    /// rejection).
    Opportunistic(Box<dyn Checker>),
    /// Any checker, full mask every step (Algorithm 1 verbatim).
    FullMask(Box<dyn Checker>),
    /// DOMINO with count-based speculation (§3.6). The model is shared
    /// across requests of the same grammar (that is what makes the priors
    /// useful), and so is the engine's mask cache — speculation needs the
    /// concrete decoder (no [`crate::constraint::CachedChecker`] wrapper),
    /// so its mask computations go through the cache explicitly.
    Speculative {
        decoder: DominoDecoder,
        spec: Arc<std::sync::Mutex<SpeculativeModel>>,
        s: usize,
        masks: Arc<MaskCache>,
        variant: u64,
    },
}

/// A mask for `decoder`'s current state via the shared cache (compute and
/// fill on miss) — the speculative path's equivalent of
/// [`crate::constraint::CachedChecker::compute_mask`].
fn cached_mask(decoder: &mut DominoDecoder, masks: &MaskCache, variant: u64) -> TokenMask {
    match decoder.mask_key() {
        Some(state) => match masks.get(variant, state) {
            Some(m) => m,
            None => {
                let m = decoder.compute_mask();
                masks.put(variant, state, m.clone());
                m
            }
        },
        None => decoder.compute_mask(),
    }
}

impl DecodeMode {
    fn checker(&mut self) -> Option<&mut dyn Checker> {
        match self {
            DecodeMode::Unconstrained => None,
            DecodeMode::Opportunistic(c) | DecodeMode::FullMask(c) => Some(c.as_mut()),
            DecodeMode::Speculative { decoder, .. } => Some(decoder),
        }
    }
}

/// Per-slot progress/statistics (mirrors `GenResult`).
#[derive(Clone, Debug, Default)]
pub struct SlotStats {
    pub tokens_out: usize,
    pub logprob_sum: f64,
    pub interventions: usize,
    pub model_calls: usize,
    pub masks_computed: usize,
    pub spec_proposed: usize,
    pub spec_accepted: usize,
    pub stopped: bool,
}

/// A running request.
pub struct Slot {
    pub id: u64,
    pub session: Box<dyn LmSession>,
    pub mode: DecodeMode,
    pub vocab: Arc<Vocab>,
    pub sampling: Sampling,
    pub max_tokens: usize,
    pub rng: Rng,
    pub out: Vec<TokenId>,
    pub stats: SlotStats,
    logits: Vec<f32>,
    pub done: bool,
    /// Aborted by cancellation or deadline (set via [`Slot::abort`]); the
    /// output is the partial text produced so far.
    pub aborted: bool,
    /// Per-step streaming state (token sink + consumer liveness).
    stream: Stream,
    /// Output bytes produced by the healing phase (token overhang).
    text_prefix: Vec<u8>,
}

impl Slot {
    /// Create the slot, run the prefill and the prompt-healing phase
    /// (§3.5: the prompt boundary is the one place healing matters).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: u64,
        mut session: Box<dyn LmSession>,
        mode: DecodeMode,
        vocab: Arc<Vocab>,
        prompt: &Prompt,
        sampling: Sampling,
        max_tokens: usize,
        seed: u64,
    ) -> crate::Result<Slot> {
        let logits = session.append(&prompt.ids)?;
        let mut stats = SlotStats::default();
        stats.model_calls += 1;
        let mut slot = Slot {
            id,
            session,
            mode,
            vocab,
            sampling,
            max_tokens,
            rng: Rng::new(seed),
            out: Vec::new(),
            stats,
            logits,
            done: false,
            aborted: false,
            stream: Stream::default(),
            text_prefix: Vec::new(),
        };
        slot.heal(&prompt.forced)?;
        Ok(slot)
    }

    /// Attach a per-step token sink (streaming). Output text already
    /// produced (the healing overhang) is flushed as the first event so
    /// the stream's concatenation equals the final `text()`.
    pub fn attach_sink(&mut self, sink: mpsc::Sender<StreamEvent>) {
        self.stream.sink = Some(sink);
        if !self.text_prefix.is_empty() {
            let prefix = self.text_prefix.clone();
            self.stream.emit_bytes(&prefix);
        }
    }

    /// Abort this slot mid-decode (cancellation or deadline). The slot
    /// stops consuming engine ticks; the partial output stays readable.
    pub fn abort(&mut self) {
        self.done = true;
        self.aborted = true;
    }

    /// Did the streaming consumer disappear (a sink send failed)? The
    /// driving loop treats this as a client disconnect and aborts.
    pub fn client_gone(&self) -> bool {
        self.stream.gone
    }

    /// Flush any buffered incomplete-UTF-8 tail to the stream. Called by
    /// the engine when the slot retires (complete or aborted), before
    /// the final response is sent.
    pub fn finish_stream(&mut self) {
        self.stream.flush();
    }

    /// Consume the healed prompt suffix (cf. `generate::Loop::heal`).
    fn heal(&mut self, forced: &[u8]) -> crate::Result<()> {
        let mut forced = forced.to_vec();
        while !forced.is_empty() {
            let mut mask = TokenMask::none(self.vocab.len());
            for id in 0..self.vocab.len() as crate::TokenId {
                let b = self.vocab.token_bytes(id);
                if b.is_empty() {
                    continue;
                }
                let ok = if b.len() <= forced.len() {
                    forced.starts_with(b)
                } else if b.starts_with(&forced) {
                    match self.mode.checker() {
                        Some(c) => c.check_bytes(&b[forced.len()..]),
                        None => true,
                    }
                } else {
                    false
                };
                if ok {
                    mask.allow(id);
                }
            }
            anyhow::ensure!(!mask.is_empty(), "prompt healing deadlocked");
            let mut masked = self.logits.clone();
            mask.apply(&mut masked);
            let t = decode(&masked, self.sampling, &mut self.rng);
            let b = self.vocab.token_bytes(t).to_vec();
            if b.len() <= forced.len() {
                forced.drain(..b.len());
            } else {
                let overhang = b[forced.len()..].to_vec();
                forced.clear();
                if let Some(c) = self.mode.checker() {
                    c.advance_bytes(&overhang)?;
                }
                self.out_text_prefix(&overhang);
            }
            self.logits = self.session.append(&[t])?;
            self.stats.model_calls += 1;
        }
        Ok(())
    }

    /// Bytes produced during healing that belong to the OUTPUT (the
    /// overhang past the prompt text). Kept separately: `out` holds whole
    /// tokens only.
    fn out_text_prefix(&mut self, bytes: &[u8]) {
        self.text_prefix.extend_from_slice(bytes);
    }

    /// Pick a (possibly masked) next token from `logits` with lazy
    /// coupling; records interventions.
    fn choose(
        logits: &[f32],
        checker: Option<&mut dyn Checker>,
        sampling: Sampling,
        rng: &mut Rng,
        stats: &mut SlotStats,
        full_mask: bool,
    ) -> Option<TokenId> {
        let Some(checker) = checker else {
            return Some(decode(logits, sampling, rng));
        };
        if full_mask {
            let mask = checker.compute_mask();
            stats.masks_computed += 1;
            if mask.is_empty() {
                return None;
            }
            let proposal = decode(logits, sampling, rng);
            if mask.allowed(proposal) {
                return Some(proposal);
            }
            stats.interventions += 1;
            let mut masked = logits.to_vec();
            mask.apply(&mut masked);
            Some(decode(&masked, sampling, rng))
        } else {
            let proposal = decode(logits, sampling, rng);
            if checker.check_token(proposal) {
                return Some(proposal);
            }
            stats.interventions += 1;
            let mask = checker.compute_mask();
            stats.masks_computed += 1;
            if mask.is_empty() {
                return None;
            }
            let mut masked = logits.to_vec();
            mask.apply(&mut masked);
            Some(decode(&masked, sampling, rng))
        }
    }

    /// Commit one chosen token (advance checker + LM).
    fn commit(&mut self, chosen: TokenId) -> crate::Result<bool> {
        self.stats.logprob_sum += log_prob(&self.logits, chosen);
        if chosen == EOS_ID {
            self.stats.stopped = true;
            self.done = true;
            return Ok(true);
        }
        if let Some(c) = self.mode.checker() {
            c.advance(chosen)?;
        }
        self.out.push(chosen);
        self.stats.tokens_out += 1;
        self.stream.emit_token(&self.vocab, chosen);
        self.logits = self.session.append(&[chosen])?;
        self.stats.model_calls += 1;
        if self.out.len() >= self.max_tokens {
            self.done = true;
        }
        Ok(self.done)
    }

    /// One decode iteration. Under speculation this may commit several
    /// tokens (one chunked verify); otherwise exactly one.
    pub fn step(&mut self) -> crate::Result<()> {
        if self.done {
            return Ok(());
        }
        // Speculative fast path.
        if let DecodeMode::Speculative { decoder, spec, s, masks, variant } = &mut self.mode {
            let proposal = {
                let spec_guard = spec.lock().expect("spec lock");
                spec_guard.propose(decoder, *s)
            };
            if !proposal.is_empty() {
                self.stats.spec_proposed += proposal.len();
                let rows = self.session.append_scored(&proposal)?;
                self.stats.model_calls += 1;
                let mut accepted = 0;
                for (i, &p) in proposal.iter().enumerate() {
                    let choice = decode(&self.logits, self.sampling, &mut self.rng);
                    let choice = if decoder.check_token(choice) {
                        choice
                    } else {
                        self.stats.interventions += 1;
                        let mask = cached_mask(decoder, masks, *variant);
                        self.stats.masks_computed += 1;
                        if mask.is_empty() {
                            break;
                        }
                        let mut masked = self.logits.clone();
                        mask.apply(&mut masked);
                        decode(&masked, self.sampling, &mut self.rng)
                    };
                    if choice == p {
                        self.stats.logprob_sum += log_prob(&self.logits, p);
                        {
                            let mut spec_guard = spec.lock().expect("spec lock");
                            if let Some(key) = decoder.state_key() {
                                spec_guard.observe(key, p);
                            }
                        }
                        decoder.advance(p)?;
                        self.out.push(p);
                        self.stats.tokens_out += 1;
                        self.stream.emit_token(&self.vocab, p);
                        self.stats.spec_accepted += 1;
                        accepted += 1;
                        self.logits = rows[i].clone();
                        if self.out.len() >= self.max_tokens {
                            self.session.rollback(proposal.len() - accepted)?;
                            self.done = true;
                            return Ok(());
                        }
                    } else {
                        self.session.rollback(proposal.len() - accepted)?;
                        self.stats.logprob_sum += log_prob(&self.logits, choice);
                        if choice == EOS_ID {
                            self.stats.stopped = true;
                            self.done = true;
                            return Ok(());
                        }
                        {
                            let mut spec_guard = spec.lock().expect("spec lock");
                            if let Some(key) = decoder.state_key() {
                                spec_guard.observe(key, choice);
                            }
                        }
                        decoder.advance(choice)?;
                        self.out.push(choice);
                        self.stats.tokens_out += 1;
                        self.stream.emit_token(&self.vocab, choice);
                        self.logits = self.session.append(&[choice])?;
                        self.stats.model_calls += 1;
                        if self.out.len() >= self.max_tokens {
                            self.done = true;
                        }
                        return Ok(());
                    }
                }
                return Ok(());
            }
            // No confident proposal: fall through to a plain step, and
            // teach the count model what the LLM chose.
            let chosen = {
                let proposal = decode(&self.logits, self.sampling, &mut self.rng);
                if decoder.check_token(proposal) {
                    proposal
                } else {
                    self.stats.interventions += 1;
                    let mask = cached_mask(decoder, masks, *variant);
                    self.stats.masks_computed += 1;
                    if mask.is_empty() {
                        self.done = true;
                        return Ok(());
                    }
                    let mut masked = self.logits.clone();
                    mask.apply(&mut masked);
                    decode(&masked, self.sampling, &mut self.rng)
                }
            };
            {
                let mut spec_guard = spec.lock().expect("spec lock");
                if let Some(key) = decoder.state_key() {
                    spec_guard.observe(key, chosen);
                }
            }
            self.commit(chosen)?;
            return Ok(());
        }

        // Plain modes.
        let full_mask = matches!(self.mode, DecodeMode::FullMask(_));
        let chosen = Self::choose(
            &self.logits.clone(),
            self.mode.checker(),
            self.sampling,
            &mut self.rng,
            &mut self.stats,
            full_mask,
        );
        match chosen {
            Some(t) => {
                self.commit(t)?;
            }
            None => {
                self.done = true; // dead end
            }
        }
        Ok(())
    }

    /// The decoded output text (healing overhang + committed tokens).
    pub fn text(&self) -> String {
        let mut bytes = self.text_prefix.clone();
        bytes.extend_from_slice(&self.vocab.decode(&self.out));
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Mask utility for tests: current full mask if constrained.
    pub fn current_mask(&mut self) -> Option<TokenMask> {
        self.mode.checker().map(|c| c.compute_mask())
    }
}
