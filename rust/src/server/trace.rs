//! Request-scoped decode tracing: span trees + per-token decision
//! records, cheap enough to leave on in production.
//!
//! Aggregate histograms (`server::metrics`) answer "how is the fleet
//! doing"; this module answers "where did *this* request spend its
//! time, and why did the constraint engine mask / heal / reject a draft
//! at token 17". Every traced request records a span tree —
//! `request` → `queue` (submit → shard admit) → `decode` → per-tick
//! `tick` spans with `decide` / `gather` / `forward` / `finish` phase
//! children — plus one [`Decision`] per emitted token carrying mask
//! cardinality, mask-cache hit/miss, scanner/parser state key, token
//! origin (sampled / speculative / drafted / corrected) and whether the
//! grammar intervened. Healing and draft proposed-vs-accepted lengths
//! ride as timestamped events.
//!
//! Capture policy is head sampling (`--trace-sample-rate`, a
//! deterministic 1-in-N on request ids so overhead is predictable)
//! plus tail-based always-capture for requests that abort or exceed
//! `--trace-slow-ms` — the two classes an operator actually debugs.
//! A request with `"trace": true` on the wire is always captured and
//! additionally gets an inline summary in its response. Captured
//! traces land in a bounded ring (the `{"op":"trace"}` admin dump) and,
//! with `--trace-dir`, as one Chrome trace-event JSON file per request
//! (loadable in Perfetto / `chrome://tracing`; `domino trace FILE`
//! renders the same file as a per-tick text timeline).
//!
//! The subsystem is paid for: `benches/trace_overhead.rs` gates that a
//! disabled tracer costs ~nothing on the tick path and 1% sampling
//! stays within a few percent of untraced throughput.

use crate::server::metrics::Metrics;
use crate::util::Json;
use crate::TokenId;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Tracing policy — part of `SchedulerConfig`. Default = fully off:
/// `Tracer::begin` returns `None` for every request that does not ask
/// for a trace on the wire, and the tick path stays untouched.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Head-sampling rate in [0, 1]: 0 = off, 1 = every request.
    /// Implemented as a deterministic 1-in-N on the request sequence
    /// number (N = `round(1/rate)`), so overhead is predictable and
    /// tests are reproducible.
    pub sample_rate: f64,
    /// Tail-based capture: any traced request slower than this is
    /// captured even when head sampling passed it by.
    pub slow: Option<Duration>,
    /// Write each captured trace as Chrome trace-event JSON
    /// (`trace-{id}.json`) into this directory.
    pub trace_dir: Option<PathBuf>,
    /// Captured traces retained for the `{"op":"trace"}` dump (oldest
    /// evicted first).
    pub ring_capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig { sample_rate: 0.0, slow: None, trace_dir: None, ring_capacity: 64 }
    }
}

impl TraceConfig {
    /// Whether any request should be recorded without asking on the
    /// wire. When false the tracer's only cost is one branch per
    /// request.
    pub fn enabled(&self) -> bool {
        self.sample_rate > 0.0 || self.slow.is_some() || self.trace_dir.is_some()
    }
}

/// Why a finished trace was kept. Precedence (highest first) when
/// several apply: aborted, slow, requested, sampled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaptureCause {
    /// The request ended with a structured abort (cancel / deadline /
    /// error) — always captured so post-mortems have data.
    Aborted,
    /// Wall time exceeded `--trace-slow-ms`.
    Slow,
    /// The wire request set `"trace": true`.
    Requested,
    /// Head sampling picked it.
    Sampled,
}

impl CaptureCause {
    pub fn as_str(&self) -> &'static str {
        match self {
            CaptureCause::Aborted => "aborted",
            CaptureCause::Slow => "slow",
            CaptureCause::Requested => "requested",
            CaptureCause::Sampled => "sampled",
        }
    }
}

/// One closed interval on the request's timeline, microseconds since
/// the request was submitted. Nesting is by time containment — the
/// span names form a fixed hierarchy (`request` ⊃ `queue`/`decode`,
/// `decode` ⊃ `tick`, `tick` ⊃ `decide`/`gather`/`forward`/`finish`),
/// so no parent pointers are needed.
#[derive(Clone, Debug)]
pub struct Span {
    pub name: &'static str,
    pub start_us: u64,
    pub end_us: u64,
}

impl Span {
    pub fn dur_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }
}

/// One per-token decode decision record, attached to the `decode` span.
#[derive(Clone, Debug)]
pub struct Decision {
    /// Output-token index (0-based).
    pub index: usize,
    pub token: TokenId,
    /// Microseconds since submit when the token was committed.
    pub at_us: u64,
    /// Whether a grammar mask was computed for this step (the
    /// opportunistic fast path commits without one).
    pub masked: bool,
    /// Mask cardinality (allowed-token count) when a mask was computed.
    pub mask_card: Option<u32>,
    /// Shared mask-cache outcome, when the lookup went through the
    /// cache (speculative/drafted paths; `None` for paths that hold a
    /// `CachedChecker` whose cache is internal).
    pub cache_hit: Option<bool>,
    /// The grammar rejected the LM's preferred token and the sample was
    /// redrawn from the mask (a DOMINO intervention).
    pub intervention: bool,
    /// How the token was produced: `sampled`, `speculative`, `drafted`,
    /// or `corrected` (the verifier's replacement for a rejected
    /// speculation).
    pub origin: &'static str,
    /// Scanner/parser state key at commit time (`None` once the
    /// grammar's state space is no longer hashable, e.g. unconstrained
    /// tails).
    pub state: Option<u64>,
}

/// Per-request trace under construction. Owned by the `Work` /
/// `Active` bookkeeping on the shard thread; never shared.
#[derive(Debug)]
pub struct RequestTrace {
    pub id: u64,
    pub tenant: String,
    /// `"trace": true` on the wire — always capture + inline summary.
    pub requested: bool,
    /// Head sampling picked this request.
    pub sampled: bool,
    pub started: Instant,
    pub spans: Vec<Span>,
    pub decisions: Vec<Decision>,
    /// Timestamped point events (healing, draft outcomes, …).
    pub events: Vec<(u64, String)>,
    /// Structured abort reason, when the request did not complete.
    pub abort: Option<String>,
    pub ticks: u64,
    decode_start: Option<u64>,
}

impl RequestTrace {
    fn now_us(&self) -> u64 {
        self.started.elapsed().as_micros() as u64
    }

    /// The request left the fair queue and was admitted to a slot:
    /// close the `queue` span, open `decode`.
    pub fn admitted(&mut self) {
        let now = self.now_us();
        self.spans.push(Span { name: "queue", start_us: 0, end_us: now });
        self.decode_start = Some(now);
    }

    /// Record one engine tick this request took part in: the tick span
    /// plus its four sequential phase children. `t0` is the tick's
    /// start instant (shared across the batch).
    pub fn record_tick(
        &mut self,
        t0: Instant,
        decide: Duration,
        gather: Duration,
        forward: Duration,
        finish: Duration,
    ) {
        let base = t0.checked_duration_since(self.started).unwrap_or_default().as_micros() as u64;
        let mut cur = base;
        let mut child = |name, d: Duration, cur: &mut u64| {
            let start = *cur;
            *cur += d.as_micros() as u64;
            Span { name, start_us: start, end_us: *cur }
        };
        let d = child("decide", decide, &mut cur);
        let g = child("gather", gather, &mut cur);
        let f = child("forward", forward, &mut cur);
        let fin = child("finish", finish, &mut cur);
        self.spans.push(Span { name: "tick", start_us: base, end_us: cur });
        self.spans.extend([d, g, f, fin]);
        self.ticks += 1;
    }

    /// Record a timestamped point event (healing, abort context, …).
    pub fn event(&mut self, label: impl Into<String>) {
        let at = self.now_us();
        self.events.push((at, label.into()));
    }

    /// Fold a slot's per-token records into this trace (at finalize;
    /// the slot trace lives on the decode side, the request trace on
    /// the bookkeeping side).
    pub fn merge_slot(&mut self, slot: SlotTrace) {
        self.decisions.extend(slot.decisions);
        self.events.extend(slot.events);
    }
}

/// Per-slot decision recorder, attached to the `Slot` so the decode
/// hot path never touches the request-side trace. Scratch fields
/// accumulate within one decode step and are consumed by
/// [`SlotTrace::commit`].
#[derive(Debug)]
pub struct SlotTrace {
    started: Instant,
    decisions: Vec<Decision>,
    events: Vec<(u64, String)>,
    mask_card: Option<u32>,
    cache_hit: Option<bool>,
    intervention: bool,
}

impl SlotTrace {
    /// `started` is the owning request's submit instant, so decision
    /// timestamps share the span timeline.
    pub fn new(started: Instant) -> SlotTrace {
        SlotTrace {
            started,
            decisions: Vec::new(),
            events: Vec::new(),
            mask_card: None,
            cache_hit: None,
            intervention: false,
        }
    }

    /// A grammar mask was computed (or fetched) for the current step.
    pub fn note_mask(&mut self, card: u32, cache_hit: Option<bool>) {
        self.mask_card = Some(card);
        self.cache_hit = cache_hit;
    }

    /// The grammar rejected the LM's preferred token this step.
    pub fn note_intervention(&mut self) {
        self.intervention = true;
    }

    /// A token was committed: flush the step scratch into a decision
    /// record.
    pub fn commit(&mut self, index: usize, token: TokenId, origin: &'static str, state: Option<u64>) {
        let at_us = self.started.elapsed().as_micros() as u64;
        self.decisions.push(Decision {
            index,
            token,
            at_us,
            masked: self.mask_card.is_some(),
            mask_card: self.mask_card.take(),
            cache_hit: self.cache_hit.take(),
            intervention: std::mem::take(&mut self.intervention),
            origin,
            state,
        });
    }

    /// Record a timestamped point event (draft outcome, healing, …).
    pub fn event(&mut self, label: impl Into<String>) {
        let at = self.started.elapsed().as_micros() as u64;
        self.events.push((at, label.into()));
    }
}

/// A finalized, captured trace (immutable; shared by the ring and any
/// in-flight dump).
#[derive(Debug)]
pub struct FinishedTrace {
    pub id: u64,
    pub tenant: String,
    pub cause: CaptureCause,
    pub total_us: u64,
    pub ticks: u64,
    pub spans: Vec<Span>,
    pub decisions: Vec<Decision>,
    pub events: Vec<(u64, String)>,
    pub abort: Option<String>,
}

fn opt_json<T, F: FnOnce(T) -> Json>(v: Option<T>, f: F) -> Json {
    match v {
        Some(v) => f(v),
        None => Json::Null,
    }
}

impl FinishedTrace {
    /// Compact inline summary for the `"trace": true` response field:
    /// top-level spans + decision aggregates, no per-token records.
    pub fn summary(&self) -> Json {
        let interventions = self.decisions.iter().filter(|d| d.intervention).count();
        let masked = self.decisions.iter().filter(|d| d.masked).count();
        let cache_hits = self.decisions.iter().filter(|d| d.cache_hit == Some(true)).count();
        let cache_misses = self.decisions.iter().filter(|d| d.cache_hit == Some(false)).count();
        let top: Vec<Json> = self
            .spans
            .iter()
            .filter(|s| matches!(s.name, "request" | "queue" | "decode"))
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(s.name)),
                    ("start_us", Json::Num(s.start_us as f64)),
                    ("dur_us", Json::Num(s.dur_us() as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("cause", Json::str(self.cause.as_str())),
            ("total_us", Json::Num(self.total_us as f64)),
            ("ticks", Json::Num(self.ticks as f64)),
            ("decisions", Json::Num(self.decisions.len() as f64)),
            ("masked", Json::Num(masked as f64)),
            ("interventions", Json::Num(interventions as f64)),
            ("mask_cache_hits", Json::Num(cache_hits as f64)),
            ("mask_cache_misses", Json::Num(cache_misses as f64)),
            ("abort", opt_json(self.abort.as_deref(), Json::str)),
            ("spans", Json::Arr(top)),
        ])
    }

    /// Full trace as JSON — the `{"op":"trace"}` dump format.
    pub fn to_json(&self) -> Json {
        let spans: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(s.name)),
                    ("start_us", Json::Num(s.start_us as f64)),
                    ("end_us", Json::Num(s.end_us as f64)),
                ])
            })
            .collect();
        let decisions: Vec<Json> = self
            .decisions
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("index", Json::Num(d.index as f64)),
                    ("token", Json::Num(d.token as f64)),
                    ("at_us", Json::Num(d.at_us as f64)),
                    ("masked", Json::Bool(d.masked)),
                    ("mask_card", opt_json(d.mask_card, |c| Json::Num(c as f64))),
                    ("cache_hit", opt_json(d.cache_hit, Json::Bool)),
                    ("intervention", Json::Bool(d.intervention)),
                    ("origin", Json::str(d.origin)),
                    ("state", opt_json(d.state, |s| Json::Num(s as f64))),
                ])
            })
            .collect();
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|(at, label)| {
                Json::obj(vec![
                    ("at_us", Json::Num(*at as f64)),
                    ("label", Json::str(label.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("tenant", Json::str(self.tenant.clone())),
            ("cause", Json::str(self.cause.as_str())),
            ("total_us", Json::Num(self.total_us as f64)),
            ("ticks", Json::Num(self.ticks as f64)),
            ("abort", opt_json(self.abort.as_deref(), Json::str)),
            ("spans", Json::Arr(spans)),
            ("decisions", Json::Arr(decisions)),
            ("events", Json::Arr(events)),
        ])
    }

    /// Chrome trace-event JSON (the "JSON Array Format" wrapped in
    /// `{"traceEvents": [...]}`) — loadable in Perfetto and
    /// `chrome://tracing`. Spans become complete (`ph:"X"`) events,
    /// decisions and point events become thread-scoped instants
    /// (`ph:"i"`).
    pub fn perfetto(&self) -> String {
        let mut events: Vec<Json> = Vec::with_capacity(self.spans.len() + self.decisions.len());
        for s in &self.spans {
            events.push(Json::obj(vec![
                ("name", Json::str(s.name)),
                ("cat", Json::str("request")),
                ("ph", Json::str("X")),
                ("ts", Json::Num(s.start_us as f64)),
                ("dur", Json::Num(s.dur_us() as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(self.id as f64)),
            ]));
        }
        for d in &self.decisions {
            events.push(Json::obj(vec![
                ("name", Json::str(format!("token[{}]", d.index))),
                ("cat", Json::str("decision")),
                ("ph", Json::str("i")),
                ("s", Json::str("t")),
                ("ts", Json::Num(d.at_us as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(self.id as f64)),
                (
                    "args",
                    Json::obj(vec![
                        ("token", Json::Num(d.token as f64)),
                        ("origin", Json::str(d.origin)),
                        ("masked", Json::Bool(d.masked)),
                        ("mask_card", opt_json(d.mask_card, |c| Json::Num(c as f64))),
                        ("cache_hit", opt_json(d.cache_hit, Json::Bool)),
                        ("intervention", Json::Bool(d.intervention)),
                        ("state", opt_json(d.state, |s| Json::Num(s as f64))),
                    ]),
                ),
            ]));
        }
        for (at, label) in &self.events {
            events.push(Json::obj(vec![
                ("name", Json::str(label.clone())),
                ("cat", Json::str("event")),
                ("ph", Json::str("i")),
                ("s", Json::str("t")),
                ("ts", Json::Num(*at as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(self.id as f64)),
            ]));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            (
                "otherData",
                Json::obj(vec![
                    ("cause", Json::str(self.cause.as_str())),
                    ("tenant", Json::str(self.tenant.clone())),
                    ("abort", opt_json(self.abort.as_deref(), Json::str)),
                ]),
            ),
        ])
        .to_string()
    }
}

/// Render a Chrome trace-event JSON value (as written by
/// [`FinishedTrace::perfetto`] or any tool emitting the format) as a
/// human-readable per-tick timeline — the `domino trace FILE`
/// subcommand.
pub fn render_timeline(v: &Json) -> crate::Result<String> {
    let events = match v.get("traceEvents").and_then(|e| e.as_arr()) {
        Some(a) => a,
        None => v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("not trace-event JSON: no traceEvents array"))?,
    };
    struct Ev<'a> {
        name: &'a str,
        ts: f64,
        dur: f64,
        complete: bool,
        args: Option<&'a Json>,
    }
    let mut evs: Vec<Ev> = Vec::new();
    for e in events {
        let name = e.get("name").and_then(|n| n.as_str()).unwrap_or("?");
        let ts = e.get("ts").and_then(|t| t.as_f64()).unwrap_or(0.0);
        let ph = e.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        let dur = e.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0);
        evs.push(Ev { name, ts, dur, complete: ph == "X", args: e.get("args") });
    }
    if evs.is_empty() {
        anyhow::bail!("trace-event JSON contains no events");
    }
    // Sort by start time; at equal start the longer (outer) span first
    // so the containment stack nests correctly.
    evs.sort_by(|a, b| {
        a.ts.partial_cmp(&b.ts)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.dur.partial_cmp(&a.dur).unwrap_or(std::cmp::Ordering::Equal))
    });
    let mut out = String::new();
    if let Some(other) = v.get("otherData") {
        let cause = other.get("cause").and_then(|c| c.as_str()).unwrap_or("?");
        out.push_str(&format!("captured: {cause}"));
        if let Some(abort) = other.get("abort").and_then(|a| a.as_str()) {
            out.push_str(&format!(" (abort: {abort})"));
        }
        out.push('\n');
    }
    let mut stack: Vec<f64> = Vec::new(); // open span end times
    let mut tick = 0u64;
    for e in &evs {
        while let Some(&end) = stack.last() {
            if e.ts >= end - 1e-9 {
                stack.pop();
            } else {
                break;
            }
        }
        let indent = "  ".repeat(stack.len());
        if e.complete {
            let label = if e.name == "tick" {
                tick += 1;
                format!("tick #{}", tick - 1)
            } else {
                e.name.to_string()
            };
            out.push_str(&format!(
                "{indent}{label:<12} {:>10.3} ms  +{:.3} ms\n",
                e.ts / 1e3,
                e.dur / 1e3
            ));
            stack.push(e.ts + e.dur);
        } else {
            let detail = e
                .args
                .map(|a| {
                    let origin = a.get("origin").and_then(|o| o.as_str()).unwrap_or("");
                    let mut d = String::new();
                    if !origin.is_empty() {
                        d.push_str(&format!(" {origin}"));
                    }
                    if let Some(c) = a.get("mask_card").and_then(|c| c.as_f64()) {
                        d.push_str(&format!(" mask={c}"));
                    }
                    if let Some(h) = a.get("cache_hit").and_then(|h| h.as_bool()) {
                        d.push_str(if h { " cache=hit" } else { " cache=miss" });
                    }
                    if a.get("intervention").and_then(|i| i.as_bool()) == Some(true) {
                        d.push_str(" INTERVENED");
                    }
                    d
                })
                .unwrap_or_default();
            out.push_str(&format!("{indent}· {:<10} {:>10.3} ms {detail}\n", e.name, e.ts / 1e3));
        }
    }
    Ok(out)
}

/// The capture sink shared by every shard: sampling decision, the
/// bounded recent-trace ring, capture counters for the metrics layer,
/// and the optional Perfetto file writer.
#[derive(Debug)]
pub struct Tracer {
    cfg: TraceConfig,
    seq: AtomicU64,
    captured_sampled: AtomicU64,
    captured_requested: AtomicU64,
    captured_aborted: AtomicU64,
    captured_slow: AtomicU64,
    ring: Mutex<VecDeque<Arc<FinishedTrace>>>,
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Arc<Tracer> {
        Arc::new(Tracer {
            cfg,
            seq: AtomicU64::new(0),
            captured_sampled: AtomicU64::new(0),
            captured_requested: AtomicU64::new(0),
            captured_aborted: AtomicU64::new(0),
            captured_slow: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
        })
    }

    /// A tracer with the default (fully off) config — requests are
    /// traced only when they ask on the wire.
    pub fn disabled() -> Arc<Tracer> {
        Tracer::new(TraceConfig::default())
    }

    pub fn config(&self) -> &TraceConfig {
        &self.cfg
    }

    /// Head-sampling decision for request `id` (1-based sequence
    /// numbers): deterministic 1-in-N with N = `round(1/rate)`.
    fn head_sampled(&self, id: u64) -> bool {
        let rate = self.cfg.sample_rate;
        if rate <= 0.0 {
            return false;
        }
        if rate >= 1.0 {
            return true;
        }
        let period = (1.0 / rate).round().max(1.0) as u64;
        (id - 1) % period == 0
    }

    /// Start a trace for a new request, or `None` when tracing is off
    /// and the request did not ask. When the tracer is enabled every
    /// request records (tail-based capture needs the data to exist);
    /// the keep/drop decision happens in [`Tracer::finish`].
    pub fn begin(&self, requested: bool, tenant: &str) -> Option<Box<RequestTrace>> {
        if !self.cfg.enabled() && !requested {
            return None;
        }
        let id = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        Some(Box::new(RequestTrace {
            id,
            tenant: tenant.to_string(),
            requested,
            sampled: self.head_sampled(id),
            started: Instant::now(),
            spans: Vec::new(),
            decisions: Vec::new(),
            events: Vec::new(),
            abort: None,
            ticks: 0,
            decode_start: None,
        }))
    }

    /// Finalize a trace: decide capture, close the `request`/`decode`
    /// spans, push to the ring, write the Perfetto file, and return the
    /// inline summary when the request asked for one. Runs on the shard
    /// thread *before* the final response is sent, so a `"trace": true`
    /// client never races its own dump.
    pub fn finish(&self, mut trace: Box<RequestTrace>) -> Option<Json> {
        let total_us = trace.now_us();
        let elapsed = trace.started.elapsed();
        let cause = if trace.abort.is_some() {
            Some(CaptureCause::Aborted)
        } else if self.cfg.slow.is_some_and(|s| elapsed > s) {
            Some(CaptureCause::Slow)
        } else if trace.requested {
            Some(CaptureCause::Requested)
        } else if trace.sampled {
            Some(CaptureCause::Sampled)
        } else {
            None
        };
        let cause = cause?;
        let requested = trace.requested;
        if let Some(start) = trace.decode_start {
            trace.spans.push(Span { name: "decode", start_us: start, end_us: total_us });
        }
        trace.spans.push(Span { name: "request", start_us: 0, end_us: total_us });
        // Stable render order: outer spans before their children.
        trace
            .spans
            .sort_by(|a, b| a.start_us.cmp(&b.start_us).then(b.end_us.cmp(&a.end_us)));
        trace.decisions.sort_by_key(|d| d.index);
        trace.events.sort_by_key(|(at, _)| *at);
        let counter = match cause {
            CaptureCause::Aborted => &self.captured_aborted,
            CaptureCause::Slow => &self.captured_slow,
            CaptureCause::Requested => &self.captured_requested,
            CaptureCause::Sampled => &self.captured_sampled,
        };
        counter.fetch_add(1, Ordering::Relaxed);
        let finished = Arc::new(FinishedTrace {
            id: trace.id,
            tenant: trace.tenant,
            cause,
            total_us,
            ticks: trace.ticks,
            spans: trace.spans,
            decisions: trace.decisions,
            events: trace.events,
            abort: trace.abort,
        });
        if let Some(dir) = &self.cfg.trace_dir {
            let path = dir.join(format!("trace-{:08}.json", finished.id));
            // Best-effort: a full disk must not fail the request.
            let _ = std::fs::create_dir_all(dir);
            let _ = std::fs::write(&path, finished.perfetto());
        }
        {
            let mut ring = self.ring.lock().expect("trace ring lock");
            while ring.len() >= self.cfg.ring_capacity.max(1) {
                ring.pop_front();
            }
            ring.push_back(finished.clone());
        }
        // A requested trace owes the client its inline summary even
        // when a higher-precedence cause (abort / slow) won the label.
        if requested {
            return Some(finished.summary());
        }
        None
    }

    /// Recent captured traces, oldest first.
    pub fn recent(&self) -> Vec<Arc<FinishedTrace>> {
        self.ring.lock().expect("trace ring lock").iter().cloned().collect()
    }

    /// Fold the capture counters into a metrics snapshot (called once
    /// per aggregation by `Scheduler::metrics`; the tracer is the
    /// single source, so the fields use max-merge like other
    /// shared-source counters).
    pub fn fill(&self, m: &mut Metrics) {
        m.traces_sampled = self.captured_sampled.load(Ordering::Relaxed);
        m.traces_requested = self.captured_requested.load(Ordering::Relaxed);
        m.traces_aborted = self.captured_aborted.load(Ordering::Relaxed);
        m.traces_slow = self.captured_slow.load(Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_all() -> TraceConfig {
        TraceConfig { sample_rate: 1.0, ..TraceConfig::default() }
    }

    #[test]
    fn disabled_tracer_returns_none_unless_requested() {
        let t = Tracer::disabled();
        assert!(t.begin(false, "default").is_none());
        let tr = t.begin(true, "default").expect("wire-requested trace");
        assert!(tr.requested);
        let summary = t.finish(tr).expect("requested trace returns a summary");
        assert_eq!(summary.get("cause").and_then(|c| c.as_str()), Some("requested"));
        assert_eq!(t.recent().len(), 1);
    }

    #[test]
    fn head_sampling_is_deterministic_one_in_n() {
        let t = Tracer::new(TraceConfig { sample_rate: 0.25, ..TraceConfig::default() });
        let sampled: Vec<bool> = (1..=8).map(|id| t.head_sampled(id)).collect();
        assert_eq!(sampled, [true, false, false, false, true, false, false, false]);
        let t = Tracer::new(cfg_all());
        assert!((1..=5).all(|id| t.head_sampled(id)));
    }

    #[test]
    fn sampled_trace_lands_in_ring_without_summary() {
        let t = Tracer::new(cfg_all());
        let tr = t.begin(false, "acme").unwrap();
        assert!(t.finish(tr).is_none(), "non-requested capture returns no inline summary");
        let recent = t.recent();
        assert_eq!(recent.len(), 1);
        assert_eq!(recent[0].cause, CaptureCause::Sampled);
        assert_eq!(recent[0].tenant, "acme");
        let mut m = Metrics::default();
        t.fill(&mut m);
        assert_eq!(m.traces_sampled, 1);
    }

    #[test]
    fn abort_beats_sampling_and_requested() {
        let t = Tracer::new(cfg_all());
        let mut tr = t.begin(true, "default").unwrap();
        tr.abort = Some("client_cancel".into());
        let summary = t.finish(tr).expect("requested trace keeps its summary on abort");
        assert_eq!(summary.get("cause").and_then(|c| c.as_str()), Some("aborted"));
        assert_eq!(summary.get("abort").and_then(|a| a.as_str()), Some("client_cancel"));
        assert_eq!(t.recent()[0].cause, CaptureCause::Aborted);
    }

    #[test]
    fn ring_evicts_oldest_first() {
        let t = Tracer::new(TraceConfig { sample_rate: 1.0, ring_capacity: 3, ..TraceConfig::default() });
        for _ in 0..5 {
            let tr = t.begin(false, "default").unwrap();
            t.finish(tr);
        }
        let ids: Vec<u64> = t.recent().iter().map(|f| f.id).collect();
        assert_eq!(ids, [3, 4, 5], "capacity 3 keeps the newest three, oldest first");
    }

    #[test]
    fn span_tree_is_well_formed() {
        let t = Tracer::new(cfg_all());
        let mut tr = t.begin(false, "default").unwrap();
        tr.admitted();
        let t0 = Instant::now();
        tr.record_tick(
            t0,
            Duration::from_micros(10),
            Duration::from_micros(5),
            Duration::from_micros(100),
            Duration::from_micros(20),
        );
        t.finish(tr);
        let f = &t.recent()[0];
        assert_eq!(f.ticks, 1);
        let span = |name: &str| f.spans.iter().find(|s| s.name == name).unwrap().clone();
        let (req, decode, tick) = (span("request"), span("decode"), span("tick"));
        assert!(req.start_us <= decode.start_us && decode.end_us <= req.end_us);
        assert!(decode.start_us <= tick.start_us && tick.end_us <= decode.end_us);
        let mut cursor = tick.start_us;
        for phase in ["decide", "gather", "forward", "finish"] {
            let s = span(phase);
            assert_eq!(s.start_us, cursor, "{phase} starts where the previous phase ended");
            cursor = s.end_us;
        }
        assert_eq!(cursor, tick.end_us, "phases tile the tick exactly");
    }

    #[test]
    fn slot_trace_scratch_flushes_per_decision() {
        let mut st = SlotTrace::new(Instant::now());
        st.note_mask(42, Some(true));
        st.note_intervention();
        st.commit(0, 7, "sampled", Some(99));
        st.commit(1, 8, "speculative", None);
        assert_eq!(st.decisions.len(), 2);
        let d0 = &st.decisions[0];
        assert!(d0.masked && d0.intervention);
        assert_eq!((d0.mask_card, d0.cache_hit, d0.state), (Some(42), Some(true), Some(99)));
        let d1 = &st.decisions[1];
        assert!(!d1.masked && !d1.intervention, "scratch must not leak across commits");
        assert_eq!(d1.origin, "speculative");
    }

    #[test]
    fn perfetto_roundtrips_and_renders() {
        let t = Tracer::new(cfg_all());
        let mut tr = t.begin(false, "default").unwrap();
        tr.admitted();
        let mut st = SlotTrace::new(tr.started);
        st.note_mask(12, Some(false));
        st.commit(0, 3, "sampled", Some(1));
        tr.record_tick(
            Instant::now(),
            Duration::from_micros(10),
            Duration::from_micros(5),
            Duration::from_micros(50),
            Duration::from_micros(8),
        );
        tr.merge_slot(st);
        tr.event("healed 2 prompt tokens");
        t.finish(tr);
        let f = &t.recent()[0];
        let parsed = Json::parse(&f.perfetto()).expect("perfetto output is valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert!(events.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X")));
        assert!(events.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("i")));
        for name in ["request", "decode", "tick", "decide", "gather", "forward", "finish"] {
            assert!(
                events.iter().any(|e| e.get("name").and_then(|n| n.as_str()) == Some(name)),
                "perfetto output missing span {name}"
            );
        }
        let timeline = render_timeline(&parsed).expect("timeline renders");
        assert!(timeline.contains("tick #0"));
        assert!(timeline.contains("forward"));
        assert!(timeline.contains("token[0]"));
        assert!(timeline.contains("healed 2 prompt tokens"));
    }

    #[test]
    fn timeline_rejects_non_trace_json() {
        assert!(render_timeline(&Json::parse("{\"a\": 1}").unwrap()).is_err());
        assert!(render_timeline(&Json::parse("[]").unwrap()).is_err());
    }
}
