//! The sharded scheduler: N engine threads behind one admission front.
//!
//! DOMINO's serving pitch is constrained generation at serving speed —
//! but one engine thread caps throughput at one core no matter how cheap
//! masking gets. The scheduler owns **N engine shards** (each an
//! [`EngineCore`] on its own thread, as PJRT handles are thread-pinned)
//! that share one [`EngineRegistry`], so the expensive per-grammar
//! precomputation (§3.5) still happens exactly once per distinct grammar
//! process-wide:
//!
//! ```text
//!  clients ──▶ Scheduler::submit ──route──▶ shard 0: [queue]→[S0 S1 …]
//!              │ affinity: fingerprint % N  shard 1: [queue]→[S0 S1 …]
//!              │ spill:    least-loaded     …        (shared registry,
//!              │ full:     shed (overload)            shared mask cache)
//!              ▼
//!        RequestHandle { response rx, cancel }
//! ```
//!
//! * **Grammar-affinity routing** — a request's constraint fingerprint
//!   hashes to a preferred shard, so per-shard speculation priors and the
//!   per-engine mask caches stay warm for that grammar. When the
//!   preferred shard's queue is full (or the request has no grammar), it
//!   spills to the least-loaded shard (queued + active) instead.
//! * **Bounded admission + backpressure** — each shard's queue holds at
//!   most [`SchedulerConfig::queue_depth`] requests **per tenant**. When
//!   every eligible shard is full for the request's tenant it is
//!   **shed** immediately with the structured `"overloaded"` reply
//!   (`reason: "queue_full"`) rather than queueing forever.
//! * **Per-tenant fairness** — the wire `tenant` field buys two
//!   isolations: token-bucket admission quotas
//!   ([`TenantPolicy::rate`]/[`TenantPolicy::burst`]; over-quota
//!   requests shed with `reason: "tenant_quota"`), and weighted-fair
//!   queue drain (deficit round-robin over per-tenant lanes,
//!   [`TenantPolicy::weights`]) so a flooding tenant lengthens only its
//!   own queue. `benches/fairness.rs` gates the cold-tenant p99.
//! * **Deadlines + cancellation** — every submission carries a cancel
//!   flag ([`RequestHandle::cancel`] / [`CancelToken`]) and an optional
//!   deadline. Both are honored while queued *and* mid-decode: the shard
//!   loop aborts the slot at the next tick instead of burning engine
//!   ticks to `max_tokens`. A streaming request whose sink consumer
//!   disappeared (client disconnect) aborts the same way.
//! * **Streaming** — [`Scheduler::submit_streaming`] attaches a per-step
//!   token sink; one [`StreamEvent`](super::slot::StreamEvent) arrives
//!   per committed token, then the final [`GenResponse`].
//! * **Cross-shard metrics** — [`Scheduler::metrics`] merges every
//!   shard's snapshot ([`Metrics::merge`]) and folds in scheduler-level
//!   shed counts; `shard_metrics` exposes the per-shard view.

use super::engine::{EngineCore, EngineCtx, GenRequest, GenResponse, Work};
use super::metrics::{labeled, Metrics};
use super::slot::StreamEvent;
use super::trace::{TraceConfig, Tracer};
use crate::constraint::{ArtifactStore, EngineRegistry};
use anyhow::Context;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Hard cap on distinct per-tenant admission buckets (and shed-account
/// keys). Tenants beyond the cap share one overflow bucket — an
/// unauthenticated client must not be able to allocate unbounded state
/// by inventing tenant names.
const MAX_TENANT_BUCKETS: usize = 4096;

/// Per-tenant admission and fairness policy.
#[derive(Clone, Debug, Default)]
pub struct TenantPolicy {
    /// Token-bucket admission rate, requests/second per tenant. `None`
    /// disables quota admission (every request passes). `Some(0.0)` is
    /// burst-only: the initial burst passes, then everything sheds.
    pub rate: Option<f64>,
    /// Bucket capacity (max burst above the steady rate). `None`
    /// defaults to `max(rate, 1)`; always clamped to at least 1 so a
    /// quota can never shed every request of an idle tenant.
    pub burst: Option<f64>,
    /// Weighted-fair drain weights (deficit round-robin quantum) per
    /// tenant name. Unlisted tenants get weight 1; weights are clamped
    /// to at least 1.
    pub weights: HashMap<String, u32>,
}

/// Classic token bucket with lazy refill. Time is an explicit argument
/// so refill edge cases are unit-testable without sleeping.
#[derive(Clone, Debug)]
struct TokenBucket {
    tokens: f64,
    last: Instant,
    rate: f64,
    burst: f64,
}

impl TokenBucket {
    fn new(rate: f64, burst: f64, now: Instant) -> TokenBucket {
        let burst = burst.max(1.0);
        TokenBucket { tokens: burst, last: now, rate: rate.max(0.0), burst }
    }

    /// Refill for the elapsed time, then try to take one token.
    fn try_take(&mut self, now: Instant) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Deficit-round-robin queue: one FIFO lane per tenant, drained in
/// round-robin order with a per-tenant quantum of `weight` items per
/// turn (unit request cost). A tenant flooding its own lane lengthens
/// only that lane; other tenants keep draining at their weighted share.
/// Single-tenant traffic degenerates to the old FIFO exactly.
struct FairQueue<T> {
    lanes: HashMap<String, VecDeque<T>>,
    /// Tenants awaiting a turn (may hold stale names whose lanes have
    /// drained; `pop` skips those).
    ring: VecDeque<String>,
    /// The tenant currently spending its quantum: (name, credit left).
    current: Option<(String, u32)>,
    weights: Arc<HashMap<String, u32>>,
    len: usize,
}

impl<T> FairQueue<T> {
    fn new(weights: Arc<HashMap<String, u32>>) -> FairQueue<T> {
        FairQueue { lanes: HashMap::new(), ring: VecDeque::new(), current: None, weights, len: 0 }
    }

    fn weight(&self, tenant: &str) -> u32 {
        self.weights.get(tenant).copied().unwrap_or(1).max(1)
    }

    fn len(&self) -> usize {
        self.len
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn push(&mut self, tenant: String, item: T) {
        let lane = self.lanes.entry(tenant.clone()).or_default();
        if lane.is_empty()
            && !self.ring.contains(&tenant)
            && self.current.as_ref().map_or(true, |(c, _)| c != &tenant)
        {
            self.ring.push_back(tenant);
        }
        lane.push_back(item);
        self.len += 1;
    }

    /// Next item under DRR order, with the tenant it belongs to.
    fn pop(&mut self) -> Option<(String, T)> {
        if self.len == 0 {
            self.current = None;
            return None;
        }
        loop {
            if let Some((t, credit)) = self.current.take() {
                let has_work = self.lanes.get(&t).is_some_and(|l| !l.is_empty());
                if has_work && credit > 0 {
                    let lane = self.lanes.get_mut(&t).expect("lane checked non-empty");
                    let item = lane.pop_front().expect("lane checked non-empty");
                    self.len -= 1;
                    if lane.is_empty() {
                        self.lanes.remove(&t);
                    } else if credit > 1 {
                        self.current = Some((t.clone(), credit - 1));
                    } else {
                        // Quantum spent with work left: back of the ring.
                        self.ring.push_back(t.clone());
                    }
                    return Some((t, item));
                }
                if has_work {
                    // Credit spent: requeue for a fresh quantum.
                    self.ring.push_back(t);
                }
                // Drained lanes just drop out; push() re-rings them.
            }
            let t = self.ring.pop_front()?;
            if self.lanes.get(&t).is_some_and(|l| !l.is_empty()) {
                let w = self.weight(&t);
                self.current = Some((t, w));
            }
            // Stale ring entry (lane drained or purged): skip.
        }
    }

    /// Remove and return every queued item `dead` matches (queue-purge
    /// of cancelled / deadline-expired work).
    fn purge<F: FnMut(&T) -> bool>(&mut self, mut dead: F) -> Vec<(String, T)> {
        let mut out = Vec::new();
        for (t, lane) in self.lanes.iter_mut() {
            let mut keep = VecDeque::with_capacity(lane.len());
            while let Some(item) = lane.pop_front() {
                if dead(&item) {
                    out.push((t.clone(), item));
                } else {
                    keep.push_back(item);
                }
            }
            *lane = keep;
        }
        self.len -= out.len();
        self.lanes.retain(|_, l| !l.is_empty());
        out
    }
}

/// Scheduler shape knobs.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Engine shards (threads). Each runs its own model state.
    pub engines: usize,
    /// Concurrent decode slots per shard (continuous batching width).
    pub slots_per_engine: usize,
    /// Max requests waiting (unadmitted) per shard before shedding.
    pub queue_depth: usize,
    /// Deadline applied to requests that don't carry their own.
    pub default_deadline: Option<Duration>,
    /// Capacity of the shared compiled-engine registry's hot tier
    /// (engine + mask cache resident).
    pub registry_capacity: usize,
    /// Warm-tier capacity: engines demoted from the hot tier are kept
    /// (mask caches dropped) up to this many, so a re-request recomputes
    /// masks instead of recompiling. 0 disables the tier. CLI
    /// `--registry-warm`.
    pub registry_warm_capacity: usize,
    /// Directory of persistent precompute artifacts (CLI `--artifact-dir`
    /// / `$DOMINO_ARTIFACT_DIR`). When set, the shared registry loads
    /// compiled engines from disk at boot (warm start), writes fresh
    /// compiles back, and re-saves hot masks at shutdown. `None` = purely
    /// in-memory registry, the pre-artifact behavior.
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Compile cache-missed grammars lazily (on-demand scanner DFAs and
    /// subterminal trees): first-token latency for huge schema-emitted
    /// grammars drops from full-precompute to cost-proportional-to-states
    /// -visited. Artifact persistence still writes dense engines (they
    /// are materialized at save time). CLI `--lazy-compile` /
    /// `$DOMINO_LAZY_COMPILE`.
    pub lazy_compile: bool,
    /// Per-tenant admission quota + weighted-fair drain policy (CLI
    /// `--tenant-rate` / `--tenant-burst` / `--tenant-weights`). The
    /// default policy admits everything and weights every tenant 1.
    pub tenants: TenantPolicy,
    /// Request tracing (CLI `--trace-sample-rate` / `--trace-slow-ms` /
    /// `--trace-dir`). The default config disables tracing entirely;
    /// `"trace": true` requests still get an inline summary.
    pub trace: TraceConfig,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            engines: 1,
            slots_per_engine: 4,
            queue_depth: 64,
            default_deadline: None,
            registry_capacity: super::engine::DEFAULT_REGISTRY_CAPACITY,
            registry_warm_capacity: super::engine::DEFAULT_REGISTRY_CAPACITY * 4,
            artifact_dir: None,
            lazy_compile: false,
            tenants: TenantPolicy::default(),
            trace: TraceConfig::default(),
        }
    }
}

enum Job {
    Work(Work),
    Stats(mpsc::Sender<Metrics>),
    Shutdown,
}

struct Shard {
    tx: mpsc::Sender<Job>,
    /// Requests submitted to this shard but not yet admitted to a slot.
    queued: Arc<AtomicUsize>,
    /// Slots currently decoding on this shard.
    active: Arc<AtomicUsize>,
    /// Per-tenant share of `queued` — the queue bound is per tenant per
    /// shard, so one tenant filling its allotment can't shed another's
    /// traffic (entries are removed at zero to stay bounded).
    tenant_queued: Arc<Mutex<HashMap<String, usize>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Shard {
    fn tenant_queue_len(&self, tenant: &str) -> usize {
        self.tenant_queued.lock().expect("tenant gauge lock").get(tenant).copied().unwrap_or(0)
    }

    fn load(&self) -> usize {
        self.queued.load(Ordering::Relaxed) + self.active.load(Ordering::Relaxed)
    }
}

/// Decrement (and clean up) a shard's per-tenant queued gauge.
fn dec_tenant_gauge(map: &Mutex<HashMap<String, usize>>, tenant: &str) {
    let mut m = map.lock().expect("tenant gauge lock");
    if let Some(c) = m.get_mut(tenant) {
        *c -= 1;
        if *c == 0 {
            m.remove(tenant);
        }
    }
}

/// Client-side handle for one submitted request: the response receiver
/// plus the cancellation flag the shard loops poll.
pub struct RequestHandle {
    rx: mpsc::Receiver<GenResponse>,
    cancel: Arc<AtomicBool>,
}

impl RequestHandle {
    /// Block for the final response.
    pub fn recv(&self) -> crate::Result<GenResponse> {
        self.rx.recv().context("engine gone")
    }

    pub fn recv_timeout(&self, d: Duration) -> Result<GenResponse, mpsc::RecvTimeoutError> {
        self.rx.recv_timeout(d)
    }

    pub fn try_recv(&self) -> Result<GenResponse, mpsc::TryRecvError> {
        self.rx.try_recv()
    }

    /// Abort the request (queued or mid-decode). The engine still sends
    /// a final response (error `"cancelled"`, partial text/stats).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// A detachable cancel flag (e.g. for a disconnect watcher thread).
    pub fn cancel_token(&self) -> CancelToken {
        CancelToken(self.cancel.clone())
    }

    /// Drop the cancellation side and keep only the response receiver
    /// (the pre-scheduler `Server::submit` shape).
    pub fn into_receiver(self) -> mpsc::Receiver<GenResponse> {
        self.rx
    }
}

/// Clonable cancellation flag for one request.
#[derive(Clone)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Handle to a running shard fleet.
pub struct Scheduler {
    shards: Vec<Shard>,
    cfg: SchedulerConfig,
    registry: Arc<EngineRegistry>,
    shed: AtomicU64,
    /// Per-tenant admission buckets (lazily created; capped at
    /// [`MAX_TENANT_BUCKETS`], overflow shares one bucket).
    buckets: Mutex<HashMap<String, TokenBucket>>,
    /// Scheduler-level shed accounting keyed (tenant, reason) — folded
    /// into [`Scheduler::metrics`] as per-tenant shed counts and
    /// `shed/<reason>` abort entries.
    shed_by: Mutex<BTreeMap<(String, String), u64>>,
    /// Shared request tracer: every shard captures into its ring, the
    /// admission front begins traces and finalizes shed ones.
    tracer: Arc<Tracer>,
}

impl Scheduler {
    /// Start `cfg.engines` shard threads. `init` runs once per shard ON
    /// that shard's thread (model state is thread-pinned) and receives
    /// the shared registry — build the context with
    /// [`EngineCtx::with_registry`] so grammar compiles dedupe across
    /// shards. Registry keys hash the vocabulary *content*, so shards
    /// may share one `Arc<Vocab>` or load equal copies — both dedupe.
    pub fn start<F>(init: F, cfg: SchedulerConfig) -> Scheduler
    where
        F: Fn(usize, Arc<EngineRegistry>) -> crate::Result<EngineCtx> + Send + Sync + 'static,
    {
        let mut cfg = cfg;
        cfg.engines = cfg.engines.max(1);
        cfg.slots_per_engine = cfg.slots_per_engine.max(1);
        cfg.queue_depth = cfg.queue_depth.max(1);
        let capacity = cfg.registry_capacity.max(1);
        let warm = cfg.registry_warm_capacity;
        let registry = match &cfg.artifact_dir {
            None => EngineRegistry::with_tiers(capacity, warm, None),
            Some(dir) => match ArtifactStore::new(dir) {
                Ok(store) => EngineRegistry::with_tiers(capacity, warm, Some(store)),
                Err(e) => {
                    // An unusable store costs warm starts, not serving.
                    eprintln!("domino: artifact store disabled: {e:#}");
                    EngineRegistry::with_tiers(capacity, warm, None)
                }
            },
        };
        registry.set_lazy_build(cfg.lazy_compile);
        let init = Arc::new(init);
        let weights = Arc::new(cfg.tenants.weights.clone());
        let tracer = Tracer::new(cfg.trace.clone());
        let mut shards = Vec::with_capacity(cfg.engines);
        for i in 0..cfg.engines {
            let (tx, rx) = mpsc::channel::<Job>();
            let queued = Arc::new(AtomicUsize::new(0));
            let active = Arc::new(AtomicUsize::new(0));
            let tenant_queued = Arc::new(Mutex::new(HashMap::new()));
            let init = init.clone();
            let registry = registry.clone();
            let weights = weights.clone();
            let slots = cfg.slots_per_engine;
            let shard_tracer = tracer.clone();
            let (q, a, tq) = (queued.clone(), active.clone(), tenant_queued.clone());
            let handle = std::thread::Builder::new()
                .name(format!("domino-shard-{i}"))
                .spawn(move || {
                    let ctx = match init(i, registry) {
                        Ok(ctx) => ctx,
                        Err(e) => {
                            eprintln!("shard {i} init failed: {e:#}");
                            // Drain jobs with failures.
                            for job in rx.iter() {
                                if let Job::Work(w) = job {
                                    q.fetch_sub(1, Ordering::Relaxed);
                                    dec_tenant_gauge(&tq, w.req.tenant_label());
                                    let msg = format!("engine init failed: {e:#}");
                                    let _ = w.resp.send(GenResponse::failure(msg));
                                }
                            }
                            return;
                        }
                    };
                    let core = EngineCore::with_tracer(ctx, slots, shard_tracer);
                    shard_loop(core, rx, q, a, tq, weights, i == 0);
                })
                .expect("spawn shard thread");
            shards.push(Shard { tx, queued, active, tenant_queued, handle: Some(handle) });
        }
        Scheduler {
            shards,
            cfg,
            registry,
            shed: AtomicU64::new(0),
            buckets: Mutex::new(HashMap::new()),
            shed_by: Mutex::new(BTreeMap::new()),
            tracer,
        }
    }

    /// Number of engine shards.
    pub fn engines(&self) -> usize {
        self.shards.len()
    }

    /// The shared compiled-engine registry (passed to every shard init).
    pub fn registry(&self) -> Arc<EngineRegistry> {
        self.registry.clone()
    }

    /// The shared request tracer (ring of recently captured traces; the
    /// TCP front end serves `{"op":"trace"}` from it).
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// Pick the shard for `req`: preferred = constraint fingerprint mod
    /// N (keeps that grammar's speculation priors and mask-cache states
    /// hot on one shard); spill to the least-loaded shard when the
    /// preferred queue is full or the request has no grammar; `None`
    /// when every eligible queue is full (shed).
    fn route(&self, req: &GenRequest) -> Option<usize> {
        let n = self.shards.len();
        let spec = &req.constraint.spec;
        // The queue bound is per tenant per shard: a tenant with its
        // allotment queued sheds, without consuming other tenants' room
        // (single-tenant traffic sees exactly the old shared bound).
        let tenant = req.tenant_label();
        let has_room = |i: usize| self.shards[i].tenant_queue_len(tenant) < self.cfg.queue_depth;
        if spec.is_grammar_backed() {
            let preferred = (spec.fingerprint() % n as u64) as usize;
            if has_room(preferred) {
                return Some(preferred);
            }
        }
        // Spill: least-loaded among the shards that still have queue
        // room (shed only when every queue is full).
        (0..n).filter(|&i| has_room(i)).min_by_key(|&i| self.shards[i].load())
    }

    /// Token-bucket quota admission for `tenant`. `true` when no rate
    /// is configured or the tenant's bucket has a token.
    fn admit_quota(&self, tenant: &str) -> bool {
        let Some(rate) = self.cfg.tenants.rate else { return true };
        let burst = self.cfg.tenants.burst.unwrap_or_else(|| rate.max(1.0));
        let now = Instant::now();
        let mut buckets = self.buckets.lock().expect("bucket lock");
        let key = if buckets.contains_key(tenant) || buckets.len() < MAX_TENANT_BUCKETS {
            tenant
        } else {
            "_overflow"
        };
        buckets
            .entry(key.to_string())
            .or_insert_with(|| TokenBucket::new(rate, burst, now))
            .try_take(now)
    }

    /// Count a scheduler-level shed for the metrics fold.
    fn note_shed(&self, tenant: &str, reason: &str) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        let mut map = self.shed_by.lock().expect("shed lock");
        let key = if map.len() >= MAX_TENANT_BUCKETS
            && !map.contains_key(&(tenant.to_string(), reason.to_string()))
        {
            ("_other".to_string(), reason.to_string())
        } else {
            (tenant.to_string(), reason.to_string())
        };
        *map.entry(key).or_insert(0) += 1;
    }

    /// Submit a request. Always returns a handle: overload and routing
    /// failures arrive as structured error responses on the handle's
    /// channel (`"overloaded"`), mirroring the wire protocol.
    pub fn submit(&self, req: GenRequest) -> RequestHandle {
        self.submit_with(req, None)
    }

    /// Submit a streaming request: one event per decode step lands on
    /// `sink`, then the final response on the returned handle. If the
    /// sink's receiver is dropped mid-decode the request is aborted
    /// (client-disconnect cancellation).
    pub fn submit_streaming(
        &self,
        req: GenRequest,
        sink: mpsc::Sender<StreamEvent>,
    ) -> RequestHandle {
        self.submit_with(req, Some(sink))
    }

    fn submit_with(
        &self,
        mut req: GenRequest,
        sink: Option<mpsc::Sender<StreamEvent>>,
    ) -> RequestHandle {
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let handle = RequestHandle { rx, cancel: cancel.clone() };
        if req.deadline.is_none() {
            req.deadline = self.cfg.default_deadline;
        }
        let tenant = req.tenant_label().to_string();
        // Begin the trace at submission so queue wait (and even shed
        // decisions) land on the timeline. `shed` finalizes it with the
        // structured reason; admitted work carries it to the shard.
        let trace = self.tracer.begin(req.trace, &tenant);
        let shed = |mut trace: Option<Box<super::trace::RequestTrace>>, reason: &str| {
            let summary = trace.take().and_then(|mut t| {
                t.abort = Some(reason.to_string());
                self.tracer.finish(t)
            });
            let mut resp = GenResponse::overloaded(reason);
            resp.trace = summary;
            resp
        };
        if !self.admit_quota(&tenant) {
            self.note_shed(&tenant, "tenant_quota");
            let _ = tx.send(shed(trace, "tenant_quota"));
            return handle;
        }
        match self.route(&req) {
            None => {
                self.note_shed(&tenant, "queue_full");
                let _ = tx.send(shed(trace, "queue_full"));
            }
            Some(i) => {
                let deadline = req.deadline.map(|d| Instant::now() + d);
                let work = Work {
                    req,
                    resp: tx.clone(),
                    sink,
                    cancel,
                    enqueued: Instant::now(),
                    deadline,
                    trace,
                };
                {
                    let mut tq =
                        self.shards[i].tenant_queued.lock().expect("tenant gauge lock");
                    *tq.entry(tenant.clone()).or_insert(0) += 1;
                }
                self.shards[i].queued.fetch_add(1, Ordering::Relaxed);
                if self.shards[i].tx.send(Job::Work(work)).is_err() {
                    self.shards[i].queued.fetch_sub(1, Ordering::Relaxed);
                    dec_tenant_gauge(&self.shards[i].tenant_queued, &tenant);
                    let _ = tx.send(GenResponse::failure("engine gone"));
                }
            }
        }
        handle
    }

    /// Generate synchronously.
    pub fn generate(&self, req: GenRequest) -> crate::Result<GenResponse> {
        self.submit(req).recv()
    }

    /// Per-shard metrics snapshots (loop counters + shared-cache view).
    pub fn shard_metrics(&self) -> crate::Result<Vec<Metrics>> {
        let mut out = Vec::with_capacity(self.shards.len());
        for s in &self.shards {
            let (tx, rx) = mpsc::channel();
            s.tx.send(Job::Stats(tx)).ok().context("shard gone")?;
            out.push(rx.recv().context("shard gone")?);
        }
        Ok(out)
    }

    /// Aggregated cross-shard metrics: shard snapshots merged (loop
    /// counters sum; shared registry/mask counters max — see
    /// [`Metrics::merge`]) plus scheduler-level shed counts.
    pub fn metrics(&self) -> crate::Result<Metrics> {
        let mut agg = Metrics::default();
        for m in self.shard_metrics()? {
            agg.merge(&m);
        }
        agg.requests_shed += self.shed.load(Ordering::Relaxed);
        for ((tenant, reason), count) in self.shed_by.lock().expect("shed lock").iter() {
            labeled(&mut agg.tenants, tenant).shed += count;
            *labeled(&mut agg.abort_reasons, &format!("shed/{reason}")) += count;
        }
        // Capture counters live on the shared tracer, not any shard.
        self.tracer.fill(&mut agg);
        Ok(agg)
    }

    /// Requests shed at admission so far.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(Job::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// One shard's loop: drain the channel, purge dead queued work, admit
/// into free slots in deficit-round-robin order over per-tenant lanes
/// (weighted by [`TenantPolicy::weights`]; a single tenant degenerates
/// to plain FIFO), step every slot one decode tick, retire finished
/// slots. The tick is batched at the
/// model-call boundary — `step_all` gathers every live slot's pending
/// extension into ONE `LmBackend::forward_batch` call (plain,
/// speculative and deferred-correction slots in the same batch), so a
/// shard's per-tick model cost is one batched call, not one `append` per
/// slot. Blocks on the channel only when fully idle. On exit, the
/// primary shard flushes its context's registry — the one that actually
/// served engines, whether shared or built by the init closure — so
/// warmed masks persist across restarts. Every shard flushes its own
/// speculative priors: grammar-affinity routing means each shard learned
/// from the grammars it served, so the draft-lane priors live per shard.
fn shard_loop(
    core: EngineCore,
    rx: mpsc::Receiver<Job>,
    queued_gauge: Arc<AtomicUsize>,
    active_gauge: Arc<AtomicUsize>,
    tenant_gauge: Arc<Mutex<HashMap<String, usize>>>,
    weights: Arc<HashMap<String, u32>>,
    primary: bool,
) {
    let core = shard_loop_inner(core, rx, queued_gauge, active_gauge, tenant_gauge, weights);
    core.ctx.flush_priors();
    if primary {
        core.ctx.registry.flush_artifacts();
    }
}

fn shard_loop_inner(
    mut core: EngineCore,
    rx: mpsc::Receiver<Job>,
    queued_gauge: Arc<AtomicUsize>,
    active_gauge: Arc<AtomicUsize>,
    tenant_gauge: Arc<Mutex<HashMap<String, usize>>>,
    weights: Arc<HashMap<String, u32>>,
) -> EngineCore {
    let mut queue: FairQueue<Work> = FairQueue::new(weights);
    loop {
        // Drain the channel (block only when idle).
        if core.active_len() == 0 && queue.is_empty() {
            match rx.recv() {
                Ok(Job::Work(w)) => {
                    let tenant = w.req.tenant_label().to_string();
                    queue.push(tenant, w);
                }
                Ok(Job::Stats(tx)) => {
                    let _ = tx.send(core.snapshot());
                    continue;
                }
                Ok(Job::Shutdown) | Err(_) => return core,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Job::Work(w)) => {
                    let tenant = w.req.tenant_label().to_string();
                    queue.push(tenant, w);
                }
                Ok(Job::Stats(tx)) => {
                    let _ = tx.send(core.snapshot());
                }
                Ok(Job::Shutdown) => return core,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => return core,
            }
        }

        // Purge queued work that died waiting (cancelled / deadline
        // passed) so it neither occupies queue depth nor gets admitted.
        for (tenant, w) in queue.purge(|w| w.dead_reason().is_some()) {
            queued_gauge.fetch_sub(1, Ordering::Relaxed);
            dec_tenant_gauge(&tenant_gauge, &tenant);
            let abort = w.dead_reason().expect("purged as dead");
            core.reject(w, abort);
        }

        // Admit in weighted-fair (DRR) order over the tenant lanes.
        while core.has_capacity() {
            let Some((tenant, work)) = queue.pop() else { break };
            queued_gauge.fetch_sub(1, Ordering::Relaxed);
            dec_tenant_gauge(&tenant_gauge, &tenant);
            core.admit(work);
        }
        active_gauge.store(core.active_len(), Ordering::Relaxed);

        // Step every active slot once; retire the finished.
        core.step_all();
        core.reap();
        active_gauge.store(core.active_len(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn at(base: Instant, ms: u64) -> Instant {
        base + Duration::from_millis(ms)
    }

    #[test]
    fn token_bucket_refills_at_rate() {
        let t0 = Instant::now();
        // 2 req/s, burst 2: both burst tokens, then dry until refill.
        let mut b = TokenBucket::new(2.0, 2.0, t0);
        assert!(b.try_take(t0));
        assert!(b.try_take(t0));
        assert!(!b.try_take(t0), "burst spent");
        assert!(!b.try_take(at(t0, 100)), "0.2 tokens refilled, below 1");
        assert!(b.try_take(at(t0, 600)), "1.2 tokens refilled");
        assert!(!b.try_take(at(t0, 600)));
    }

    #[test]
    fn token_bucket_zero_rate_is_burst_only() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(0.0, 3.0, t0);
        for _ in 0..3 {
            assert!(b.try_take(t0));
        }
        // No refill, ever — even a year later.
        assert!(!b.try_take(t0 + Duration::from_secs(365 * 24 * 3600)));
    }

    #[test]
    fn token_bucket_burst_clamps_to_one() {
        let t0 = Instant::now();
        // Degenerate burst configs still admit one request.
        let mut b = TokenBucket::new(1.0, 0.0, t0);
        assert!(b.try_take(t0));
        assert!(!b.try_take(t0));
        // Refill never exceeds the clamped burst.
        assert!(b.try_take(at(t0, 10_000)));
        assert!(!b.try_take(at(t0, 10_000)), "burst clamp holds after long idle");
    }

    fn weights(pairs: &[(&str, u32)]) -> Arc<HashMap<String, u32>> {
        Arc::new(pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect())
    }

    #[test]
    fn fair_queue_single_tenant_is_fifo() {
        let mut q: FairQueue<u32> = FairQueue::new(weights(&[]));
        for i in 0..5 {
            q.push("a".into(), i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
    }

    #[test]
    fn fair_queue_drains_by_weight() {
        // a weighted 3, b weighted 1: DRR serves 3 a's per b.
        let mut q: FairQueue<u32> = FairQueue::new(weights(&[("a", 3), ("b", 1)]));
        for i in 0..6 {
            q.push("a".into(), i);
            q.push("b".into(), 100 + i);
        }
        let order: Vec<String> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(
            order,
            ["a", "a", "a", "b", "a", "a", "a", "b", "b", "b", "b", "b"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn fair_queue_interleaves_equal_weights() {
        let mut q: FairQueue<u32> = FairQueue::new(weights(&[]));
        for i in 0..4 {
            q.push("a".into(), i);
            q.push("b".into(), 100 + i);
        }
        let order: Vec<String> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec!["a", "b", "a", "b", "a", "b", "a", "b"]);
    }

    #[test]
    fn fair_queue_no_starvation_under_flood() {
        // Starvation regression: a hot tenant at 100× the cold tenant's
        // arrival rate must not delay the cold tenant's single request
        // past one DRR round.
        let mut q: FairQueue<u32> = FairQueue::new(weights(&[]));
        for i in 0..100 {
            q.push("hot".into(), i);
        }
        q.push("cold".into(), 999);
        let pos = std::iter::from_fn(|| q.pop())
            .position(|(t, _)| t == "cold")
            .expect("cold item drains");
        assert!(pos <= 1, "cold tenant served within one round, got position {pos}");
    }

    #[test]
    fn fair_queue_purge_removes_dead_lanes() {
        let mut q: FairQueue<u32> = FairQueue::new(weights(&[]));
        for i in 0..3 {
            q.push("a".into(), i);
        }
        q.push("b".into(), 100);
        let dead = q.purge(|&v| v < 100);
        assert_eq!(dead.len(), 3);
        assert_eq!(q.len(), 1);
        let (t, v) = q.pop().expect("b survives");
        assert_eq!((t.as_str(), v), ("b", 100));
        assert!(q.pop().is_none(), "purged lanes don't resurrect");
    }

    #[test]
    fn fair_queue_reactivates_drained_tenant() {
        let mut q: FairQueue<u32> = FairQueue::new(weights(&[]));
        q.push("a".into(), 1);
        assert_eq!(q.pop().map(|(_, v)| v), Some(1));
        assert!(q.pop().is_none());
        q.push("a".into(), 2);
        assert_eq!(q.pop().map(|(_, v)| v), Some(2), "tenant re-rings after draining");
    }
}
