//! The sharded scheduler: N engine threads behind one admission front.
//!
//! DOMINO's serving pitch is constrained generation at serving speed —
//! but one engine thread caps throughput at one core no matter how cheap
//! masking gets. The scheduler owns **N engine shards** (each an
//! [`EngineCore`] on its own thread, as PJRT handles are thread-pinned)
//! that share one [`EngineRegistry`], so the expensive per-grammar
//! precomputation (§3.5) still happens exactly once per distinct grammar
//! process-wide:
//!
//! ```text
//!  clients ──▶ Scheduler::submit ──route──▶ shard 0: [queue]→[S0 S1 …]
//!              │ affinity: fingerprint % N  shard 1: [queue]→[S0 S1 …]
//!              │ spill:    least-loaded     …        (shared registry,
//!              │ full:     shed (overload)            shared mask cache)
//!              ▼
//!        RequestHandle { response rx, cancel }
//! ```
//!
//! * **Grammar-affinity routing** — a request's constraint fingerprint
//!   hashes to a preferred shard, so per-shard speculation priors and the
//!   per-engine mask caches stay warm for that grammar. When the
//!   preferred shard's queue is full (or the request has no grammar), it
//!   spills to the least-loaded shard (queued + active) instead.
//! * **Bounded admission + backpressure** — each shard's queue holds at
//!   most [`SchedulerConfig::queue_depth`] requests. When every eligible
//!   shard is full the request is **shed** immediately with the
//!   structured `"overloaded"` reply rather than queueing forever.
//! * **Deadlines + cancellation** — every submission carries a cancel
//!   flag ([`RequestHandle::cancel`] / [`CancelToken`]) and an optional
//!   deadline. Both are honored while queued *and* mid-decode: the shard
//!   loop aborts the slot at the next tick instead of burning engine
//!   ticks to `max_tokens`. A streaming request whose sink consumer
//!   disappeared (client disconnect) aborts the same way.
//! * **Streaming** — [`Scheduler::submit_streaming`] attaches a per-step
//!   token sink; one [`StreamEvent`](super::slot::StreamEvent) arrives
//!   per committed token, then the final [`GenResponse`].
//! * **Cross-shard metrics** — [`Scheduler::metrics`] merges every
//!   shard's snapshot ([`Metrics::merge`]) and folds in scheduler-level
//!   shed counts; `shard_metrics` exposes the per-shard view.

use super::engine::{EngineCore, EngineCtx, GenRequest, GenResponse, Work};
use super::metrics::Metrics;
use super::slot::StreamEvent;
use crate::constraint::{ArtifactStore, EngineRegistry};
use anyhow::Context;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Scheduler shape knobs.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Engine shards (threads). Each runs its own model state.
    pub engines: usize,
    /// Concurrent decode slots per shard (continuous batching width).
    pub slots_per_engine: usize,
    /// Max requests waiting (unadmitted) per shard before shedding.
    pub queue_depth: usize,
    /// Deadline applied to requests that don't carry their own.
    pub default_deadline: Option<Duration>,
    /// Capacity of the shared compiled-engine registry.
    pub registry_capacity: usize,
    /// Directory of persistent precompute artifacts (CLI `--artifact-dir`
    /// / `$DOMINO_ARTIFACT_DIR`). When set, the shared registry loads
    /// compiled engines from disk at boot (warm start), writes fresh
    /// compiles back, and re-saves hot masks at shutdown. `None` = purely
    /// in-memory registry, the pre-artifact behavior.
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Compile cache-missed grammars lazily (on-demand scanner DFAs and
    /// subterminal trees): first-token latency for huge schema-emitted
    /// grammars drops from full-precompute to cost-proportional-to-states
    /// -visited. Artifact persistence still writes dense engines (they
    /// are materialized at save time). CLI `--lazy-compile` /
    /// `$DOMINO_LAZY_COMPILE`.
    pub lazy_compile: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            engines: 1,
            slots_per_engine: 4,
            queue_depth: 64,
            default_deadline: None,
            registry_capacity: super::engine::DEFAULT_REGISTRY_CAPACITY,
            artifact_dir: None,
            lazy_compile: false,
        }
    }
}

enum Job {
    Work(Work),
    Stats(mpsc::Sender<Metrics>),
    Shutdown,
}

struct Shard {
    tx: mpsc::Sender<Job>,
    /// Requests submitted to this shard but not yet admitted to a slot.
    queued: Arc<AtomicUsize>,
    /// Slots currently decoding on this shard.
    active: Arc<AtomicUsize>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Shard {
    fn queue_len(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    fn load(&self) -> usize {
        self.queued.load(Ordering::Relaxed) + self.active.load(Ordering::Relaxed)
    }
}

/// Client-side handle for one submitted request: the response receiver
/// plus the cancellation flag the shard loops poll.
pub struct RequestHandle {
    rx: mpsc::Receiver<GenResponse>,
    cancel: Arc<AtomicBool>,
}

impl RequestHandle {
    /// Block for the final response.
    pub fn recv(&self) -> crate::Result<GenResponse> {
        self.rx.recv().context("engine gone")
    }

    pub fn recv_timeout(&self, d: Duration) -> Result<GenResponse, mpsc::RecvTimeoutError> {
        self.rx.recv_timeout(d)
    }

    pub fn try_recv(&self) -> Result<GenResponse, mpsc::TryRecvError> {
        self.rx.try_recv()
    }

    /// Abort the request (queued or mid-decode). The engine still sends
    /// a final response (error `"cancelled"`, partial text/stats).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// A detachable cancel flag (e.g. for a disconnect watcher thread).
    pub fn cancel_token(&self) -> CancelToken {
        CancelToken(self.cancel.clone())
    }

    /// Drop the cancellation side and keep only the response receiver
    /// (the pre-scheduler `Server::submit` shape).
    pub fn into_receiver(self) -> mpsc::Receiver<GenResponse> {
        self.rx
    }
}

/// Clonable cancellation flag for one request.
#[derive(Clone)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Handle to a running shard fleet.
pub struct Scheduler {
    shards: Vec<Shard>,
    cfg: SchedulerConfig,
    registry: Arc<EngineRegistry>,
    shed: AtomicU64,
}

impl Scheduler {
    /// Start `cfg.engines` shard threads. `init` runs once per shard ON
    /// that shard's thread (model state is thread-pinned) and receives
    /// the shared registry — build the context with
    /// [`EngineCtx::with_registry`] so grammar compiles dedupe across
    /// shards. Registry keys hash the vocabulary *content*, so shards
    /// may share one `Arc<Vocab>` or load equal copies — both dedupe.
    pub fn start<F>(init: F, cfg: SchedulerConfig) -> Scheduler
    where
        F: Fn(usize, Arc<EngineRegistry>) -> crate::Result<EngineCtx> + Send + Sync + 'static,
    {
        let mut cfg = cfg;
        cfg.engines = cfg.engines.max(1);
        cfg.slots_per_engine = cfg.slots_per_engine.max(1);
        cfg.queue_depth = cfg.queue_depth.max(1);
        let capacity = cfg.registry_capacity.max(1);
        let registry = match &cfg.artifact_dir {
            None => EngineRegistry::new(capacity),
            Some(dir) => match ArtifactStore::new(dir) {
                Ok(store) => EngineRegistry::with_store(capacity, store),
                Err(e) => {
                    // An unusable store costs warm starts, not serving.
                    eprintln!("domino: artifact store disabled: {e:#}");
                    EngineRegistry::new(capacity)
                }
            },
        };
        registry.set_lazy_build(cfg.lazy_compile);
        let init = Arc::new(init);
        let mut shards = Vec::with_capacity(cfg.engines);
        for i in 0..cfg.engines {
            let (tx, rx) = mpsc::channel::<Job>();
            let queued = Arc::new(AtomicUsize::new(0));
            let active = Arc::new(AtomicUsize::new(0));
            let init = init.clone();
            let registry = registry.clone();
            let slots = cfg.slots_per_engine;
            let (q, a) = (queued.clone(), active.clone());
            let handle = std::thread::Builder::new()
                .name(format!("domino-shard-{i}"))
                .spawn(move || {
                    let ctx = match init(i, registry) {
                        Ok(ctx) => ctx,
                        Err(e) => {
                            eprintln!("shard {i} init failed: {e:#}");
                            // Drain jobs with failures.
                            for job in rx.iter() {
                                if let Job::Work(w) = job {
                                    q.fetch_sub(1, Ordering::Relaxed);
                                    let msg = format!("engine init failed: {e:#}");
                                    let _ = w.resp.send(GenResponse::failure(msg));
                                }
                            }
                            return;
                        }
                    };
                    shard_loop(EngineCore::new(ctx, slots), rx, q, a, i == 0);
                })
                .expect("spawn shard thread");
            shards.push(Shard { tx, queued, active, handle: Some(handle) });
        }
        Scheduler { shards, cfg, registry, shed: AtomicU64::new(0) }
    }

    /// Number of engine shards.
    pub fn engines(&self) -> usize {
        self.shards.len()
    }

    /// The shared compiled-engine registry (passed to every shard init).
    pub fn registry(&self) -> Arc<EngineRegistry> {
        self.registry.clone()
    }

    /// Pick the shard for `req`: preferred = constraint fingerprint mod
    /// N (keeps that grammar's speculation priors and mask-cache states
    /// hot on one shard); spill to the least-loaded shard when the
    /// preferred queue is full or the request has no grammar; `None`
    /// when every eligible queue is full (shed).
    fn route(&self, req: &GenRequest) -> Option<usize> {
        let n = self.shards.len();
        let spec = &req.constraint.spec;
        if spec.is_grammar_backed() {
            let preferred = (spec.fingerprint() % n as u64) as usize;
            if self.shards[preferred].queue_len() < self.cfg.queue_depth {
                return Some(preferred);
            }
        }
        // Spill: least-loaded among the shards that still have queue
        // room (shed only when every queue is full).
        (0..n)
            .filter(|&i| self.shards[i].queue_len() < self.cfg.queue_depth)
            .min_by_key(|&i| self.shards[i].load())
    }

    /// Submit a request. Always returns a handle: overload and routing
    /// failures arrive as structured error responses on the handle's
    /// channel (`"overloaded"`), mirroring the wire protocol.
    pub fn submit(&self, req: GenRequest) -> RequestHandle {
        self.submit_with(req, None)
    }

    /// Submit a streaming request: one event per decode step lands on
    /// `sink`, then the final response on the returned handle. If the
    /// sink's receiver is dropped mid-decode the request is aborted
    /// (client-disconnect cancellation).
    pub fn submit_streaming(
        &self,
        req: GenRequest,
        sink: mpsc::Sender<StreamEvent>,
    ) -> RequestHandle {
        self.submit_with(req, Some(sink))
    }

    fn submit_with(
        &self,
        mut req: GenRequest,
        sink: Option<mpsc::Sender<StreamEvent>>,
    ) -> RequestHandle {
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let handle = RequestHandle { rx, cancel: cancel.clone() };
        if req.deadline.is_none() {
            req.deadline = self.cfg.default_deadline;
        }
        match self.route(&req) {
            None => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(GenResponse::overloaded());
            }
            Some(i) => {
                let deadline = req.deadline.map(|d| Instant::now() + d);
                let work = Work {
                    req,
                    resp: tx.clone(),
                    sink,
                    cancel,
                    enqueued: Instant::now(),
                    deadline,
                };
                self.shards[i].queued.fetch_add(1, Ordering::Relaxed);
                if self.shards[i].tx.send(Job::Work(work)).is_err() {
                    self.shards[i].queued.fetch_sub(1, Ordering::Relaxed);
                    let _ = tx.send(GenResponse::failure("engine gone"));
                }
            }
        }
        handle
    }

    /// Generate synchronously.
    pub fn generate(&self, req: GenRequest) -> crate::Result<GenResponse> {
        self.submit(req).recv()
    }

    /// Per-shard metrics snapshots (loop counters + shared-cache view).
    pub fn shard_metrics(&self) -> crate::Result<Vec<Metrics>> {
        let mut out = Vec::with_capacity(self.shards.len());
        for s in &self.shards {
            let (tx, rx) = mpsc::channel();
            s.tx.send(Job::Stats(tx)).ok().context("shard gone")?;
            out.push(rx.recv().context("shard gone")?);
        }
        Ok(out)
    }

    /// Aggregated cross-shard metrics: shard snapshots merged (loop
    /// counters sum; shared registry/mask counters max — see
    /// [`Metrics::merge`]) plus scheduler-level shed counts.
    pub fn metrics(&self) -> crate::Result<Metrics> {
        let mut agg = Metrics::default();
        for m in self.shard_metrics()? {
            agg.merge(&m);
        }
        agg.requests_shed += self.shed.load(Ordering::Relaxed);
        Ok(agg)
    }

    /// Requests shed at admission so far.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(Job::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// One shard's loop: drain the channel, purge dead queued work, admit
/// into free slots (FIFO, O(1) `VecDeque` pops), step every slot one
/// decode tick, retire finished slots. The tick is batched at the
/// model-call boundary — `step_all` gathers every live slot's pending
/// extension into ONE `LmBackend::forward_batch` call (plain,
/// speculative and deferred-correction slots in the same batch), so a
/// shard's per-tick model cost is one batched call, not one `append` per
/// slot. Blocks on the channel only when fully idle. On exit, the
/// primary shard flushes its context's registry — the one that actually
/// served engines, whether shared or built by the init closure — so
/// warmed masks persist across restarts. Every shard flushes its own
/// speculative priors: grammar-affinity routing means each shard learned
/// from the grammars it served, so the draft-lane priors live per shard.
fn shard_loop(
    core: EngineCore,
    rx: mpsc::Receiver<Job>,
    queued_gauge: Arc<AtomicUsize>,
    active_gauge: Arc<AtomicUsize>,
    primary: bool,
) {
    let core = shard_loop_inner(core, rx, queued_gauge, active_gauge);
    core.ctx.flush_priors();
    if primary {
        core.ctx.registry.flush_artifacts();
    }
}

fn shard_loop_inner(
    mut core: EngineCore,
    rx: mpsc::Receiver<Job>,
    queued_gauge: Arc<AtomicUsize>,
    active_gauge: Arc<AtomicUsize>,
) -> EngineCore {
    let mut queue: VecDeque<Work> = VecDeque::new();
    loop {
        // Drain the channel (block only when idle).
        if core.active_len() == 0 && queue.is_empty() {
            match rx.recv() {
                Ok(Job::Work(w)) => queue.push_back(w),
                Ok(Job::Stats(tx)) => {
                    let _ = tx.send(core.snapshot());
                    continue;
                }
                Ok(Job::Shutdown) | Err(_) => return core,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Job::Work(w)) => queue.push_back(w),
                Ok(Job::Stats(tx)) => {
                    let _ = tx.send(core.snapshot());
                }
                Ok(Job::Shutdown) => return core,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => return core,
            }
        }

        // Purge queued work that died waiting (cancelled / deadline
        // passed) so it neither occupies queue depth nor gets admitted.
        for _ in 0..queue.len() {
            let w = queue.pop_front().expect("len-bounded pop");
            match w.dead_reason() {
                Some(abort) => {
                    queued_gauge.fetch_sub(1, Ordering::Relaxed);
                    core.reject(w, abort);
                }
                None => queue.push_back(w),
            }
        }

        // Admit.
        while core.has_capacity() {
            let Some(work) = queue.pop_front() else { break };
            queued_gauge.fetch_sub(1, Ordering::Relaxed);
            core.admit(work);
        }
        active_gauge.store(core.active_len(), Ordering::Relaxed);

        // Step every active slot once; retire the finished.
        core.step_all();
        core.reap();
        active_gauge.store(core.active_len(), Ordering::Relaxed);
    }
}
