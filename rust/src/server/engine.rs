//! The engine thread: request admission, slot stepping, completion.
//!
//! All model/PJRT state is created ON the engine thread (the `xla` handles
//! are not `Send`); clients talk to it over an mpsc channel. The loop is
//! a continuous batcher: every tick admits queued requests into free
//! slots and steps every active slot by one decode iteration, so long
//! requests don't block short ones (iteration-level scheduling, as in
//! Orca/vLLM).

use super::metrics::Metrics;
use super::slot::{DecodeMode, Slot, SlotStats};
use crate::domino::decoder::{Engine as GrammarEngine, Lookahead};
use crate::domino::{DominoDecoder, SpeculativeModel};
use crate::grammar::builtin;
use crate::runtime::sampler::Sampling;
use crate::runtime::LmFactory;
use crate::tokenizer::Vocab;
use anyhow::Context;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Constraint selection for a request.
#[derive(Clone, Debug, PartialEq)]
pub enum Constraint {
    None,
    /// Grammar by builtin name, DOMINO decoder.
    Domino { grammar: String, k: Option<u32>, speculative: Option<usize>, full_mask: bool },
    /// Grammar by builtin name, online full-vocab baseline.
    Online { grammar: String },
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: String,
    pub constraint: Constraint,
    pub max_tokens: usize,
    pub temperature: Option<f32>,
    pub seed: u64,
}

impl Default for GenRequest {
    fn default() -> Self {
        GenRequest {
            prompt: String::new(),
            constraint: Constraint::None,
            max_tokens: 128,
            temperature: None,
            seed: 0,
        }
    }
}

/// The response.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub text: String,
    pub stats: SlotStats,
    pub error: Option<String>,
    /// Wall time spent generating, seconds.
    pub elapsed_s: f64,
}

/// Everything the engine thread owns; built by the init closure on the
/// engine thread itself.
pub struct EngineCtx {
    pub factory: Box<dyn LmFactory>,
    pub vocab: Arc<Vocab>,
    /// Precompiled grammar engines (name → engine), lazily extended.
    pub grammars: HashMap<String, Arc<GrammarEngine>>,
    /// Shared speculation priors per grammar (§4.2: priors formed over
    /// warmup requests, then reused).
    pub specs: HashMap<String, Arc<Mutex<SpeculativeModel>>>,
}

impl EngineCtx {
    pub fn new(factory: Box<dyn LmFactory>, vocab: Arc<Vocab>) -> EngineCtx {
        EngineCtx { factory, vocab, grammars: HashMap::new(), specs: HashMap::new() }
    }

    fn grammar_engine(&mut self, name: &str) -> crate::Result<Arc<GrammarEngine>> {
        if let Some(e) = self.grammars.get(name) {
            return Ok(e.clone());
        }
        let cfg = builtin::by_name(name).with_context(|| format!("unknown grammar `{name}`"))?;
        let engine = GrammarEngine::compile(cfg, self.vocab.clone())?;
        self.grammars.insert(name.to_string(), engine.clone());
        Ok(engine)
    }

    fn spec_model(&mut self, name: &str) -> Arc<Mutex<SpeculativeModel>> {
        self.specs
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Mutex::new(SpeculativeModel::new(0.75))))
            .clone()
    }

    fn build_mode(&mut self, c: &Constraint) -> crate::Result<DecodeMode> {
        Ok(match c {
            Constraint::None => DecodeMode::Unconstrained,
            Constraint::Domino { grammar, k, speculative, full_mask } => {
                let engine = self.grammar_engine(grammar)?;
                let lookahead = match k {
                    Some(k) => Lookahead::K(*k),
                    None => Lookahead::Infinite,
                };
                let decoder = DominoDecoder::new(engine, lookahead);
                match speculative {
                    Some(s) => DecodeMode::Speculative {
                        decoder,
                        spec: self.spec_model(grammar),
                        s: *s,
                    },
                    None if *full_mask => DecodeMode::FullMask(Box::new(decoder)),
                    None => DecodeMode::Opportunistic(Box::new(decoder)),
                }
            }
            Constraint::Online { grammar } => {
                let engine = self.grammar_engine(grammar)?;
                DecodeMode::Opportunistic(Box::new(crate::baselines::OnlineChecker::new(engine)))
            }
        })
    }
}

enum Job {
    Generate(GenRequest, mpsc::Sender<GenResponse>),
    Stats(mpsc::Sender<Metrics>),
    Shutdown,
}

/// Handle to a running engine thread.
pub struct Server {
    tx: mpsc::Sender<Job>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the engine; `init` runs on the engine thread and builds all
    /// model state.
    pub fn start<F>(init: F, max_slots: usize) -> Server
    where
        F: FnOnce() -> crate::Result<EngineCtx> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        let handle = std::thread::Builder::new()
            .name("domino-engine".into())
            .spawn(move || {
                let ctx = match init() {
                    Ok(ctx) => ctx,
                    Err(e) => {
                        eprintln!("engine init failed: {e:#}");
                        // Drain jobs with failures.
                        for job in rx.iter() {
                            if let Job::Generate(_, resp) = job {
                                let _ = resp.send(GenResponse {
                                    text: String::new(),
                                    stats: SlotStats::default(),
                                    error: Some(format!("engine init failed: {e:#}")),
                                    elapsed_s: 0.0,
                                });
                            }
                        }
                        return;
                    }
                };
                engine_loop(ctx, rx, max_slots);
            })
            .expect("spawn engine thread");
        Server { tx, handle: Some(handle) }
    }

    /// Enqueue a request; returns a receiver for the response.
    pub fn submit(&self, req: GenRequest) -> mpsc::Receiver<GenResponse> {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Job::Generate(req, tx));
        rx
    }

    /// Generate synchronously.
    pub fn generate(&self, req: GenRequest) -> crate::Result<GenResponse> {
        let rx = self.submit(req);
        Ok(rx.recv()?)
    }

    pub fn metrics(&self) -> crate::Result<Metrics> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Job::Stats(tx)).ok().context("engine gone")?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Active {
    slot: Slot,
    resp: mpsc::Sender<GenResponse>,
    started: Instant,
    first_token_at: Option<Instant>,
}

fn engine_loop(mut ctx: EngineCtx, rx: mpsc::Receiver<Job>, max_slots: usize) {
    let mut queue: Vec<(GenRequest, mpsc::Sender<GenResponse>)> = Vec::new();
    let mut active: Vec<Active> = Vec::new();
    let mut metrics = Metrics::default();
    let mut next_id = 0u64;

    loop {
        // Drain the channel (block only when idle).
        if active.is_empty() && queue.is_empty() {
            match rx.recv() {
                Ok(job) => match job {
                    Job::Generate(r, tx) => queue.push((r, tx)),
                    Job::Stats(tx) => {
                        let _ = tx.send(metrics.clone());
                        continue;
                    }
                    Job::Shutdown => return,
                },
                Err(_) => return,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Job::Generate(r, tx)) => queue.push((r, tx)),
                Ok(Job::Stats(tx)) => {
                    let _ = tx.send(metrics.clone());
                }
                Ok(Job::Shutdown) => return,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => return,
            }
        }

        // Admit.
        while active.len() < max_slots && !queue.is_empty() {
            let (req, resp) = queue.remove(0);
            next_id += 1;
            let admit = (|| -> crate::Result<Slot> {
                let mode = ctx.build_mode(&req.constraint)?;
                let session = ctx.factory.new_session()?;
                let prompt = crate::domino::generate::Prompt::healed(&ctx.vocab, &req.prompt);
                let sampling = match req.temperature {
                    Some(t) => Sampling::Temperature(t),
                    None => Sampling::Greedy,
                };
                Slot::new(
                    next_id,
                    session,
                    mode,
                    ctx.vocab.clone(),
                    &prompt,
                    sampling,
                    req.max_tokens,
                    req.seed,
                )
            })();
            match admit {
                Ok(slot) => active.push(Active {
                    slot,
                    resp,
                    started: Instant::now(),
                    first_token_at: None,
                }),
                Err(e) => {
                    metrics.requests_failed += 1;
                    let _ = resp.send(GenResponse {
                        text: String::new(),
                        stats: SlotStats::default(),
                        error: Some(format!("{e:#}")),
                        elapsed_s: 0.0,
                    });
                }
            }
        }

        // Step every active slot once (iteration-level scheduling).
        for a in active.iter_mut() {
            let before_tokens = a.slot.stats.tokens_out;
            let before_calls = a.slot.stats.model_calls;
            let t0 = Instant::now();
            if let Err(e) = a.slot.step() {
                metrics.requests_failed += 1;
                a.slot.done = true;
                let _ = a.resp.send(GenResponse {
                    text: a.slot.text(),
                    stats: a.slot.stats.clone(),
                    error: Some(format!("{e:#}")),
                    elapsed_s: a.started.elapsed().as_secs_f64(),
                });
                a.slot.stats.stopped = false;
                continue;
            }
            metrics.model_time += t0.elapsed();
            metrics.tokens_generated += (a.slot.stats.tokens_out - before_tokens) as u64;
            metrics.model_calls += (a.slot.stats.model_calls - before_calls) as u64;
            if a.first_token_at.is_none() && a.slot.stats.tokens_out > 0 {
                a.first_token_at = Some(Instant::now());
                metrics.ttft.record(a.started.elapsed().as_secs_f64());
            }
        }

        // Complete.
        let mut i = 0;
        while i < active.len() {
            if active[i].slot.done {
                let a = active.swap_remove(i);
                let elapsed = a.started.elapsed().as_secs_f64();
                metrics.requests_completed += 1;
                metrics.interventions += a.slot.stats.interventions as u64;
                metrics.masks_computed += a.slot.stats.masks_computed as u64;
                metrics.spec_proposed += a.slot.stats.spec_proposed as u64;
                metrics.spec_accepted += a.slot.stats.spec_accepted as u64;
                if elapsed > 0.0 {
                    metrics.req_tps.record(a.slot.stats.tokens_out as f64 / elapsed);
                }
                let _ = a.resp.send(GenResponse {
                    text: a.slot.text(),
                    stats: a.slot.stats.clone(),
                    error: None,
                    elapsed_s: elapsed,
                });
            } else {
                i += 1;
            }
        }
    }
}
