//! The engine thread: request admission, slot stepping, completion.
//!
//! All model/PJRT state is created ON the engine thread (the `xla` handles
//! are not `Send`); clients talk to it over an mpsc channel. The loop is
//! a continuous batcher: every tick admits queued requests into free
//! slots and steps every active slot by one decode iteration, so long
//! requests don't block short ones (iteration-level scheduling, as in
//! Orca/vLLM).
//!
//! Constraints arrive as first-class [`Constraint`] values (spec + how to
//! enforce it — see [`crate::constraint`]). Admission resolves them
//! through the shared [`EngineRegistry`], so the expensive per-grammar
//! precomputation (§3.5) happens exactly once per distinct grammar, and
//! checkers share each engine's state-keyed mask cache across slots.

use super::metrics::Metrics;
use super::slot::{DecodeMode, Slot, SlotStats};
use crate::constraint::{CachedChecker, EngineRegistry, MaskCache, StopChecker};
use crate::domino::decoder::Lookahead;
use crate::domino::{DominoDecoder, SpeculativeModel};
use crate::runtime::sampler::Sampling;
use crate::runtime::LmFactory;
use crate::tokenizer::Vocab;
use anyhow::Context;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

pub use crate::constraint::{Constraint, ConstraintSpec, Enforcement};

/// Compiled engines kept hot by default (per engine thread).
const DEFAULT_REGISTRY_CAPACITY: usize = 32;

/// Speculation-prior models kept per constraint fingerprint. Bounded for
/// the same reason the registry is: inline constraints make the key space
/// adversarial, and priors are a performance aid, not correctness.
const SPEC_MODEL_CAPACITY: usize = 256;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: String,
    pub constraint: Constraint,
    pub max_tokens: usize,
    pub temperature: Option<f32>,
    pub seed: u64,
}

impl Default for GenRequest {
    fn default() -> Self {
        GenRequest {
            prompt: String::new(),
            constraint: Constraint::none(),
            max_tokens: 128,
            temperature: None,
            seed: 0,
        }
    }
}

/// The response.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub text: String,
    pub stats: SlotStats,
    pub error: Option<String>,
    /// Wall time spent generating, seconds.
    pub elapsed_s: f64,
}

/// Everything the engine thread owns; built by the init closure on the
/// engine thread itself.
pub struct EngineCtx {
    pub factory: Box<dyn LmFactory>,
    pub vocab: Arc<Vocab>,
    /// Compiled-engine cache shared across requests (and, if the caller
    /// passes one in, across engine threads / benches too).
    pub registry: Arc<EngineRegistry>,
    /// Shared speculation priors per constraint fingerprint (§4.2: priors
    /// formed over warmup requests, then reused).
    specs: HashMap<u64, Arc<Mutex<SpeculativeModel>>>,
}

impl EngineCtx {
    pub fn new(factory: Box<dyn LmFactory>, vocab: Arc<Vocab>) -> EngineCtx {
        Self::with_registry(factory, vocab, EngineRegistry::new(DEFAULT_REGISTRY_CAPACITY))
    }

    pub fn with_registry(
        factory: Box<dyn LmFactory>,
        vocab: Arc<Vocab>,
        registry: Arc<EngineRegistry>,
    ) -> EngineCtx {
        EngineCtx { factory, vocab, registry, specs: HashMap::new() }
    }

    fn spec_model(&mut self, fingerprint: u64) -> Arc<Mutex<SpeculativeModel>> {
        if !self.specs.contains_key(&fingerprint) && self.specs.len() >= SPEC_MODEL_CAPACITY {
            // Drop an arbitrary prior: losing one only costs warmup
            // quality for that grammar, and it keeps a stream of distinct
            // inline constraints from growing this map without bound.
            let victim = self.specs.keys().next().copied();
            if let Some(victim) = victim {
                self.specs.remove(&victim);
            }
        }
        self.specs
            .entry(fingerprint)
            .or_insert_with(|| Arc::new(Mutex::new(SpeculativeModel::new(0.75))))
            .clone()
    }

    /// Resolve a request's constraint into a decode mode. Grammar-backed
    /// specs go through the registry (compile once, reuse forever) and
    /// their checkers share the engine's mask cache, so a warm-registry
    /// request constructs no engine and often not even a mask.
    fn build_mode(&mut self, c: &Constraint) -> crate::Result<DecodeMode> {
        match &c.spec {
            ConstraintSpec::Unconstrained => Ok(DecodeMode::Unconstrained),
            ConstraintSpec::Stop { sequences } => Ok(DecodeMode::Opportunistic(Box::new(
                StopChecker::new(self.vocab.clone(), sequences),
            ))),
            spec => {
                let (engine, masks) = self.registry.get_or_compile(spec, &self.vocab)?;
                match &c.enforcement {
                    Enforcement::Online => {
                        let checker = crate::baselines::OnlineChecker::new(engine);
                        let cached = CachedChecker::new(
                            Box::new(checker),
                            masks,
                            MaskCache::variant(Lookahead::Infinite),
                        );
                        Ok(DecodeMode::Opportunistic(Box::new(cached)))
                    }
                    Enforcement::Domino { k, speculative, full_mask } => {
                        let lookahead = match k {
                            Some(k) => Lookahead::K(*k),
                            None => Lookahead::Infinite,
                        };
                        let decoder = DominoDecoder::new(engine, lookahead);
                        if let Some(s) = speculative {
                            Ok(DecodeMode::Speculative {
                                decoder,
                                spec: self.spec_model(spec.fingerprint()),
                                s: *s,
                                masks,
                                variant: MaskCache::variant(lookahead),
                            })
                        } else {
                            let cached = CachedChecker::new(
                                Box::new(decoder),
                                masks,
                                MaskCache::variant(lookahead),
                            );
                            Ok(if *full_mask {
                                DecodeMode::FullMask(Box::new(cached))
                            } else {
                                DecodeMode::Opportunistic(Box::new(cached))
                            })
                        }
                    }
                }
            }
        }
    }
}

enum Job {
    Generate(GenRequest, mpsc::Sender<GenResponse>),
    Stats(mpsc::Sender<Metrics>),
    Shutdown,
}

/// Handle to a running engine thread.
pub struct Server {
    tx: mpsc::Sender<Job>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the engine; `init` runs on the engine thread and builds all
    /// model state.
    pub fn start<F>(init: F, max_slots: usize) -> Server
    where
        F: FnOnce() -> crate::Result<EngineCtx> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        let handle = std::thread::Builder::new()
            .name("domino-engine".into())
            .spawn(move || {
                let ctx = match init() {
                    Ok(ctx) => ctx,
                    Err(e) => {
                        eprintln!("engine init failed: {e:#}");
                        // Drain jobs with failures.
                        for job in rx.iter() {
                            if let Job::Generate(_, resp) = job {
                                let _ = resp.send(GenResponse {
                                    text: String::new(),
                                    stats: SlotStats::default(),
                                    error: Some(format!("engine init failed: {e:#}")),
                                    elapsed_s: 0.0,
                                });
                            }
                        }
                        return;
                    }
                };
                engine_loop(ctx, rx, max_slots);
            })
            .expect("spawn engine thread");
        Server { tx, handle: Some(handle) }
    }

    /// Enqueue a request; returns a receiver for the response.
    pub fn submit(&self, req: GenRequest) -> mpsc::Receiver<GenResponse> {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Job::Generate(req, tx));
        rx
    }

    /// Generate synchronously.
    pub fn generate(&self, req: GenRequest) -> crate::Result<GenResponse> {
        let rx = self.submit(req);
        Ok(rx.recv()?)
    }

    pub fn metrics(&self) -> crate::Result<Metrics> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Job::Stats(tx)).ok().context("engine gone")?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Active {
    slot: Slot,
    resp: mpsc::Sender<GenResponse>,
    started: Instant,
    first_token_at: Option<Instant>,
}

/// Metrics snapshot: the engine-loop counters plus the registry's and
/// mask caches' (pulled at read time — they live in concurrent caches,
/// not the loop).
fn metrics_snapshot(metrics: &Metrics, ctx: &EngineCtx) -> Metrics {
    let mut m = metrics.clone();
    let r = ctx.registry.stats();
    m.registry_hits = r.hits;
    m.registry_misses = r.misses;
    m.registry_evictions = r.evictions;
    m.registry_coalesced = r.coalesced;
    m.engine_compile_ms = r.compile_ms;
    let mc = ctx.registry.mask_stats();
    m.mask_cache_hits = mc.hits;
    m.mask_cache_misses = mc.misses;
    m.mask_cache_evictions = mc.evictions;
    m
}

fn engine_loop(mut ctx: EngineCtx, rx: mpsc::Receiver<Job>, max_slots: usize) {
    let mut queue: Vec<(GenRequest, mpsc::Sender<GenResponse>)> = Vec::new();
    let mut active: Vec<Active> = Vec::new();
    let mut metrics = Metrics::default();
    let mut next_id = 0u64;

    loop {
        // Drain the channel (block only when idle).
        if active.is_empty() && queue.is_empty() {
            match rx.recv() {
                Ok(job) => match job {
                    Job::Generate(r, tx) => queue.push((r, tx)),
                    Job::Stats(tx) => {
                        let _ = tx.send(metrics_snapshot(&metrics, &ctx));
                        continue;
                    }
                    Job::Shutdown => return,
                },
                Err(_) => return,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Job::Generate(r, tx)) => queue.push((r, tx)),
                Ok(Job::Stats(tx)) => {
                    let _ = tx.send(metrics_snapshot(&metrics, &ctx));
                }
                Ok(Job::Shutdown) => return,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => return,
            }
        }

        // Admit.
        while active.len() < max_slots && !queue.is_empty() {
            let (req, resp) = queue.remove(0);
            next_id += 1;
            let admit = (|| -> crate::Result<Slot> {
                let mode = ctx.build_mode(&req.constraint)?;
                let session = ctx.factory.new_session()?;
                let prompt = crate::domino::generate::Prompt::healed(&ctx.vocab, &req.prompt);
                let sampling = match req.temperature {
                    Some(t) => Sampling::Temperature(t),
                    None => Sampling::Greedy,
                };
                Slot::new(
                    next_id,
                    session,
                    mode,
                    ctx.vocab.clone(),
                    &prompt,
                    sampling,
                    req.max_tokens,
                    req.seed,
                )
            })();
            match admit {
                Ok(slot) => active.push(Active {
                    slot,
                    resp,
                    started: Instant::now(),
                    first_token_at: None,
                }),
                Err(e) => {
                    metrics.requests_failed += 1;
                    let _ = resp.send(GenResponse {
                        text: String::new(),
                        stats: SlotStats::default(),
                        error: Some(format!("{e:#}")),
                        elapsed_s: 0.0,
                    });
                }
            }
        }

        // Step every active slot once (iteration-level scheduling).
        for a in active.iter_mut() {
            let before_tokens = a.slot.stats.tokens_out;
            let before_calls = a.slot.stats.model_calls;
            let t0 = Instant::now();
            if let Err(e) = a.slot.step() {
                metrics.requests_failed += 1;
                a.slot.done = true;
                let _ = a.resp.send(GenResponse {
                    text: a.slot.text(),
                    stats: a.slot.stats.clone(),
                    error: Some(format!("{e:#}")),
                    elapsed_s: a.started.elapsed().as_secs_f64(),
                });
                a.slot.stats.stopped = false;
                continue;
            }
            metrics.model_time += t0.elapsed();
            metrics.tokens_generated += (a.slot.stats.tokens_out - before_tokens) as u64;
            metrics.model_calls += (a.slot.stats.model_calls - before_calls) as u64;
            if a.first_token_at.is_none() && a.slot.stats.tokens_out > 0 {
                a.first_token_at = Some(Instant::now());
                metrics.ttft.record(a.started.elapsed().as_secs_f64());
            }
        }

        // Complete.
        let mut i = 0;
        while i < active.len() {
            if active[i].slot.done {
                let a = active.swap_remove(i);
                let elapsed = a.started.elapsed().as_secs_f64();
                metrics.requests_completed += 1;
                metrics.interventions += a.slot.stats.interventions as u64;
                metrics.masks_computed += a.slot.stats.masks_computed as u64;
                metrics.spec_proposed += a.slot.stats.spec_proposed as u64;
                metrics.spec_accepted += a.slot.stats.spec_accepted as u64;
                if elapsed > 0.0 {
                    metrics.req_tps.record(a.slot.stats.tokens_out as f64 / elapsed);
                }
                let _ = a.resp.send(GenResponse {
                    text: a.slot.text(),
                    stats: a.slot.stats.clone(),
                    error: None,
                    elapsed_s: elapsed,
                });
            } else {
                i += 1;
            }
        }
    }
}
