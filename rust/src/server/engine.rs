//! The engine core: request admission, slot stepping, completion.
//!
//! All model/PJRT state is created ON an engine thread (the `xla` handles
//! are not `Send`); clients talk to it over an mpsc channel. Each engine
//! is a continuous batcher: every tick admits queued requests into free
//! slots and steps every active slot by one decode iteration, so long
//! requests don't block short ones (iteration-level scheduling, as in
//! Orca/vLLM). The tick itself is batched at the model-call boundary:
//! `step_all` runs gather → ONE [`LmBackend::forward_batch`] → per-slot
//! mask/sample/commit, so a shard with N live slots pays one model call
//! per tick instead of N sequential `append`s.
//!
//! This module owns the *reusable pieces* of that loop — [`EngineCore`]
//! with `admit` / `step_all` / `reap` — which the sharded
//! [`Scheduler`](super::scheduler::Scheduler) drives once per shard. The
//! single-engine [`Server`] is a thin compatibility wrapper over a
//! one-shard scheduler.
//!
//! Constraints arrive as first-class [`Constraint`] values (spec + how to
//! enforce it — see [`crate::constraint`]). Admission resolves them
//! through the shared [`EngineRegistry`], so the expensive per-grammar
//! precomputation (§3.5) happens exactly once per distinct grammar
//! across every shard, and checkers share the state-keyed mask cache.

use super::metrics::Metrics;
use super::scheduler::{RequestHandle, Scheduler, SchedulerConfig};
use super::slot::{step_batched, DecodeMode, Slot, SlotStats, StreamEvent};
use super::trace::{RequestTrace, SlotTrace, Tracer};
use crate::constraint::{CachedChecker, EngineRegistry, MaskCache, StopChecker};
use crate::domino::decoder::Lookahead;
use crate::domino::{DominoDecoder, PriorDraft, SpeculativeModel};
use crate::runtime::sampler::Sampling;
use crate::runtime::LmBackend;
use crate::tokenizer::Vocab;
use crate::util::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub use crate::constraint::{Constraint, ConstraintSpec, Enforcement};

/// Compiled engines kept hot by default (shared across engine shards).
pub(super) const DEFAULT_REGISTRY_CAPACITY: usize = 32;

/// Speculation-prior models kept per constraint fingerprint. Bounded for
/// the same reason the registry is: inline constraints make the key space
/// adversarial, and priors are a performance aid, not correctness.
const SPEC_MODEL_CAPACITY: usize = 256;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: String,
    pub constraint: Constraint,
    pub max_tokens: usize,
    pub temperature: Option<f32>,
    pub seed: u64,
    /// Abort the request (queued or mid-decode) once this much wall time
    /// has passed since submission. `None` = no deadline.
    pub deadline: Option<Duration>,
    /// Wire-level streaming flag (`"stream": true`): the TCP front end
    /// attaches a per-step token sink when set. In-process callers use
    /// [`Scheduler::submit_streaming`] directly.
    pub stream: bool,
    /// Accounting/fairness identity (the wire `tenant` field). Drives
    /// per-tenant quota admission, weighted-fair queue drain, and the
    /// `tenant` label on exported metrics. `None` lands under
    /// [`DEFAULT_TENANT`].
    pub tenant: Option<String>,
    /// Wire-level tracing flag (`"trace": true`): always capture this
    /// request's trace and attach an inline summary to the response,
    /// regardless of the head-sampling rate.
    pub trace: bool,
}

/// Tenant label for requests that omit the wire `tenant` field.
pub const DEFAULT_TENANT: &str = "default";

impl GenRequest {
    /// The tenant label this request is accounted under.
    pub fn tenant_label(&self) -> &str {
        self.tenant.as_deref().unwrap_or(DEFAULT_TENANT)
    }
}

impl Default for GenRequest {
    fn default() -> Self {
        GenRequest {
            prompt: String::new(),
            constraint: Constraint::none(),
            max_tokens: 128,
            temperature: None,
            seed: 0,
            deadline: None,
            stream: false,
            tenant: None,
            trace: false,
        }
    }
}

/// The response.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub text: String,
    pub stats: SlotStats,
    pub error: Option<String>,
    /// Machine-readable cause accompanying `error` for aborts and sheds
    /// (`client_cancel`, `client_disconnect`, `queued`, `decoding`,
    /// `queue_full`, `tenant_quota`). `None` for successes and plain
    /// failures. Travels on the wire as the response `reason` field and
    /// feeds `domino_requests_aborted_total{kind,reason}`.
    pub reason: Option<String>,
    /// Wall time spent generating, seconds.
    pub elapsed_s: f64,
    /// Inline trace summary, present only when the request set
    /// `"trace": true` (span durations, per-token decision counts,
    /// capture cause — see [`super::trace::FinishedTrace::summary`]).
    pub trace: Option<Json>,
}

impl GenResponse {
    pub(super) fn failure(error: impl Into<String>) -> GenResponse {
        GenResponse {
            text: String::new(),
            stats: SlotStats::default(),
            error: Some(error.into()),
            reason: None,
            elapsed_s: 0.0,
            trace: None,
        }
    }

    pub(super) fn failure_with_reason(
        error: impl Into<String>,
        reason: impl Into<String>,
    ) -> GenResponse {
        GenResponse { reason: Some(reason.into()), ..GenResponse::failure(error) }
    }

    /// The structured reply for load-shed requests. `reason` says which
    /// limit shed it: `queue_full` (every eligible shard's queue at
    /// capacity) or `tenant_quota` (token-bucket admission).
    pub(super) fn overloaded(reason: &str) -> GenResponse {
        GenResponse::failure_with_reason("overloaded", reason)
    }
}

/// Everything one engine shard owns; built by the init closure on the
/// shard thread itself.
pub struct EngineCtx {
    /// The model backend: spawns per-slot sessions and runs the batched
    /// cross-slot forward pass `step_all` issues once per tick.
    pub backend: Box<dyn LmBackend>,
    pub vocab: Arc<Vocab>,
    /// Compiled-engine cache shared across requests and engine shards
    /// (the scheduler hands every shard the same registry).
    pub registry: Arc<EngineRegistry>,
    /// Shared speculation priors per *build* fingerprint (grammar ×
    /// vocab × lookahead — priors learned at one depth don't leak into
    /// another; §4.2: priors formed over warmup requests, then reused).
    /// Per-shard: affinity
    /// routing keeps same-grammar requests on one shard so these stay
    /// warm without cross-shard locking. Bounded with LRU eviction
    /// ([`EngineCtx::spec_model`]); misses warm-start from the registry's
    /// artifact store when one is attached.
    specs: HashMap<u64, SpecEntry>,
    /// Monotonic access counter backing the prior map's LRU eviction.
    spec_tick: u64,
}

/// One cached speculation prior plus its last-access tick (LRU victim
/// selection, like the mask cache shards).
struct SpecEntry {
    model: Arc<Mutex<SpeculativeModel>>,
    tick: u64,
}

impl EngineCtx {
    pub fn new(backend: Box<dyn LmBackend>, vocab: Arc<Vocab>) -> EngineCtx {
        Self::with_registry(backend, vocab, EngineRegistry::new(DEFAULT_REGISTRY_CAPACITY))
    }

    pub fn with_registry(
        backend: Box<dyn LmBackend>,
        vocab: Arc<Vocab>,
        registry: Arc<EngineRegistry>,
    ) -> EngineCtx {
        // Warm-start from the registry's artifact store (idempotent: the
        // first shard to get here scans, the rest no-op), so a restarted
        // process serves its first constrained request with zero compile
        // latency. No-op for registries without a store.
        let loaded = registry.warm_start(&vocab);
        if loaded > 0 {
            let s = registry.stats();
            eprintln!(
                "domino: warm-started {loaded} engine(s) from artifacts in {} ms",
                s.warm_start_ms
            );
        }
        EngineCtx { backend, vocab, registry, specs: HashMap::new(), spec_tick: 0 }
    }

    fn spec_model(&mut self, fingerprint: u64) -> Arc<Mutex<SpeculativeModel>> {
        self.spec_tick += 1;
        let tick = self.spec_tick;
        if !self.specs.contains_key(&fingerprint) {
            if self.specs.len() >= SPEC_MODEL_CAPACITY {
                // Evict the least recently used prior (LRU tick, like the
                // mask cache shards): losing one only costs warmup quality
                // for that grammar, and recency keeps a hot grammar's
                // prior alive under a stream of distinct inline
                // constraints.
                let victim = self.specs.iter().min_by_key(|(_, e)| e.tick).map(|(&k, _)| k);
                if let Some(victim) = victim {
                    self.specs.remove(&victim);
                }
            }
            // A restarted server warm-starts the prior from the artifact
            // store (flushed on shard exit), so it drafts/speculates well
            // from the first request. Corrupt or absent records fall back
            // to a cold prior.
            let model = self
                .registry
                .store()
                .and_then(|s| s.load_prior(fingerprint))
                .unwrap_or_else(|| SpeculativeModel::new(0.75));
            self.specs.insert(fingerprint, SpecEntry { model: Arc::new(Mutex::new(model)), tick });
        }
        let entry = self.specs.get_mut(&fingerprint).expect("present or just inserted");
        entry.tick = tick;
        entry.model.clone()
    }

    /// Persist every learned speculation prior to the registry's artifact
    /// store (no-op without a store, or for priors that never observed a
    /// step). Called by the shard loop on clean shutdown so a restarted
    /// server drafts from warm priors.
    pub fn flush_priors(&self) {
        let Some(store) = self.registry.store() else { return };
        for (&key, e) in &self.specs {
            let model = e.model.lock().expect("spec lock");
            if model.num_states() > 0 {
                let _ = store.save_prior(key, &model);
            }
        }
    }

    /// Resolve a request's constraint into a decode mode. Grammar-backed
    /// specs go through the registry (compile once, reuse forever) and
    /// their checkers share the engine's mask cache, so a warm-registry
    /// request constructs no engine and often not even a mask. Public so
    /// benches and tests can build [`Slot`]s exactly the way admission
    /// does.
    pub fn decode_mode(&mut self, c: &Constraint) -> crate::Result<DecodeMode> {
        match &c.spec {
            ConstraintSpec::Unconstrained => Ok(DecodeMode::Unconstrained),
            ConstraintSpec::Stop { sequences } => Ok(DecodeMode::Opportunistic(Box::new(
                StopChecker::new(self.vocab.clone(), sequences),
            ))),
            spec => {
                // The build parameter `k` (lookahead depth; Online = ∞)
                // is part of the registry/artifact key, so the same
                // grammar at different depths can never share (or
                // persist) colliding builds or speculation priors.
                let build_k = match &c.enforcement {
                    Enforcement::Online => None,
                    Enforcement::Domino { k, .. } => *k,
                };
                let (engine, masks) = self.registry.get_or_compile(spec, &self.vocab, build_k)?;
                match &c.enforcement {
                    Enforcement::Online => {
                        let checker = crate::baselines::OnlineChecker::new(engine);
                        let cached = CachedChecker::new(
                            Box::new(checker),
                            masks,
                            MaskCache::variant(Lookahead::Infinite),
                        );
                        Ok(DecodeMode::Opportunistic(Box::new(cached)))
                    }
                    Enforcement::Domino { k, speculative, draft, full_mask } => {
                        let lookahead = match k {
                            Some(k) => Lookahead::K(*k),
                            None => Lookahead::Infinite,
                        };
                        let decoder = DominoDecoder::new(engine, lookahead);
                        if let Some(d) = draft {
                            let prior_key =
                                spec.build_fingerprint(self.vocab.fingerprint(), build_k);
                            let prior = self.spec_model(prior_key);
                            Ok(DecodeMode::Drafted {
                                decoder,
                                spec: prior.clone(),
                                draft: Box::new(PriorDraft::new(prior)),
                                k_max: (*d).max(1),
                                masks,
                                variant: MaskCache::variant(lookahead),
                                accept_ewma: 0.0,
                                hist: Vec::new(),
                            })
                        } else if let Some(s) = speculative {
                            let prior_key =
                                spec.build_fingerprint(self.vocab.fingerprint(), build_k);
                            Ok(DecodeMode::Speculative {
                                decoder,
                                spec: self.spec_model(prior_key),
                                s: *s,
                                masks,
                                variant: MaskCache::variant(lookahead),
                            })
                        } else {
                            let cached = CachedChecker::new(
                                Box::new(decoder),
                                masks,
                                MaskCache::variant(lookahead),
                            );
                            Ok(if *full_mask {
                                DecodeMode::FullMask(Box::new(cached))
                            } else {
                                DecodeMode::Opportunistic(Box::new(cached))
                            })
                        }
                    }
                }
            }
        }
    }
}

/// One unit of admitted work: the request plus every channel the engine
/// needs to answer, stream, and abort it.
pub struct Work {
    pub req: GenRequest,
    pub resp: mpsc::Sender<GenResponse>,
    /// Per-step token sink (streaming requests).
    pub sink: Option<mpsc::Sender<StreamEvent>>,
    /// Set by the client (or the front end, on disconnect) to abort the
    /// request whether it is still queued or already decoding.
    pub cancel: Arc<AtomicBool>,
    /// Submission time (queue-wait metric + deadline base).
    pub enqueued: Instant,
    /// Absolute deadline resolved at submission.
    pub deadline: Option<Instant>,
    /// Request trace under construction (None when the tracer skipped
    /// this request). Begun at submission so queue wait is on the
    /// timeline; finalized wherever the request is answered.
    pub trace: Option<Box<RequestTrace>>,
}

impl Work {
    /// Is this work item dead before admission (cancelled or past its
    /// deadline)? Returns the abort reason when so.
    pub(super) fn dead_reason(&self) -> Option<Abort> {
        if self.cancel.load(Ordering::Relaxed) {
            return Some(Abort::Cancelled { disconnect: false });
        }
        match self.deadline {
            Some(d) if Instant::now() >= d => Some(Abort::DeadlineExceeded { queued: true }),
            _ => None,
        }
    }
}

/// Why a request was aborted without running to completion. The wire
/// `error` string stays coarse (`cancelled` / `deadline exceeded`, as it
/// always was); the structured `reason()` distinguishes the cause for
/// the wire `reason` field and the abort-reason metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(super) enum Abort {
    Cancelled {
        /// True when the abort came from the front end observing the
        /// client socket close rather than an explicit cancel.
        disconnect: bool,
    },
    DeadlineExceeded {
        /// True when the deadline expired while the request was still
        /// queued (never admitted to a slot).
        queued: bool,
    },
}

impl Abort {
    fn message(self) -> &'static str {
        match self {
            Abort::Cancelled { .. } => "cancelled",
            Abort::DeadlineExceeded { .. } => "deadline exceeded",
        }
    }

    /// Abort kind label (`domino_requests_aborted_total{kind=...}`).
    pub(super) fn kind(self) -> &'static str {
        match self {
            Abort::Cancelled { .. } => "cancelled",
            Abort::DeadlineExceeded { .. } => "deadline",
        }
    }

    /// Structured cause (`reason` on the wire and in the exporter).
    pub(super) fn reason(self) -> &'static str {
        match self {
            Abort::Cancelled { disconnect: false } => "client_cancel",
            Abort::Cancelled { disconnect: true } => "client_disconnect",
            Abort::DeadlineExceeded { queued: true } => "queued",
            Abort::DeadlineExceeded { queued: false } => "decoding",
        }
    }
}

struct Active {
    slot: Slot,
    resp: mpsc::Sender<GenResponse>,
    cancel: Arc<AtomicBool>,
    deadline: Option<Instant>,
    started: Instant,
    first_token_at: Option<Instant>,
    /// A response was already sent (step error or abort); `reap` must
    /// not send a second one.
    responded: bool,
    /// Tenant label this request is accounted under.
    tenant: String,
    /// Constraint fingerprint (hex) for per-grammar metrics; `None` for
    /// unconstrained requests.
    grammar: Option<String>,
    /// Request-side trace (span tree); the per-token decision records
    /// accumulate on `slot.trace` and are merged at finalize.
    trace: Option<Box<RequestTrace>>,
}

/// Finalize a request's trace wherever the request is answered: fold the
/// slot-side decision records in, stamp the structured abort reason, and
/// hand the trace to the shared tracer (which decides capture). Returns
/// the inline summary when the request asked for one.
fn finish_trace(
    tracer: &Tracer,
    trace: Option<Box<RequestTrace>>,
    slot: Option<Box<SlotTrace>>,
    abort: Option<&str>,
) -> Option<Json> {
    let mut trace = trace?;
    if let Some(slot) = slot {
        trace.merge_slot(*slot);
    }
    if let Some(reason) = abort {
        trace.abort = Some(reason.to_string());
    }
    tracer.finish(trace)
}

/// One engine shard's state: the model context, the active slots, and the
/// loop-local metrics. The scheduler's shard loop drives it as
/// `admit* → step_all → reap` per tick.
pub struct EngineCore {
    pub ctx: EngineCtx,
    active: Vec<Active>,
    pub metrics: Metrics,
    next_id: u64,
    max_slots: usize,
    /// Shared request tracer (all shards hand finished traces to one
    /// ring). A disabled tracer for cores built with [`EngineCore::new`].
    tracer: Arc<Tracer>,
}

impl EngineCore {
    pub fn new(ctx: EngineCtx, max_slots: usize) -> EngineCore {
        Self::with_tracer(ctx, max_slots, Tracer::disabled())
    }

    /// An engine core wired to a shared [`Tracer`] (the scheduler's
    /// shard loops use this so every shard captures into one ring).
    pub fn with_tracer(ctx: EngineCtx, max_slots: usize, tracer: Arc<Tracer>) -> EngineCore {
        EngineCore {
            ctx,
            active: Vec::new(),
            metrics: Metrics::default(),
            next_id: 0,
            max_slots: max_slots.max(1),
            tracer,
        }
    }

    /// Number of slots currently decoding.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Can another request be admitted this tick?
    pub fn has_capacity(&self) -> bool {
        self.active.len() < self.max_slots
    }

    /// Answer `work` without admitting it (pre-admission cancellation,
    /// deadline expiry in the queue).
    pub(super) fn reject(&mut self, work: Work, abort: Abort) {
        let tenant = work.req.tenant_label();
        match abort {
            Abort::Cancelled { .. } => {
                self.metrics.requests_cancelled += 1;
                self.metrics.tenant(tenant).cancelled += 1;
            }
            Abort::DeadlineExceeded { .. } => {
                self.metrics.requests_deadline_exceeded += 1;
                self.metrics.tenant(tenant).deadline_exceeded += 1;
            }
        }
        self.metrics.record_abort(abort.kind(), abort.reason());
        let trace = finish_trace(&self.tracer, work.trace, None, Some(abort.reason()));
        let mut resp = GenResponse::failure_with_reason(abort.message(), abort.reason());
        resp.trace = trace;
        let _ = work.resp.send(resp);
    }

    /// Admit one request into a free slot: resolve the constraint through
    /// the shared registry, build the LM session, run prefill + healing.
    /// Failures answer the request instead of killing the engine.
    pub fn admit(&mut self, work: Work) {
        debug_assert!(self.has_capacity(), "admit called without capacity");
        if let Some(abort) = work.dead_reason() {
            self.reject(work, abort);
            return;
        }
        let Work { req, resp, sink, cancel, enqueued, deadline, mut trace } = work;
        let tenant = req.tenant_label().to_string();
        let grammar = match &req.constraint.spec {
            ConstraintSpec::Unconstrained => None,
            spec => Some(format!("{:016x}", spec.fingerprint())),
        };
        let wait = enqueued.elapsed().as_secs_f64();
        self.metrics.queue_wait.record(wait);
        self.metrics.tenant(&tenant).queue_wait.record(wait);
        if let Some(fp) = &grammar {
            self.metrics.grammar(fp).requests += 1;
        }
        self.next_id += 1;
        let next_id = self.next_id;
        let ctx = &mut self.ctx;
        let admit = (|| -> crate::Result<(Slot, usize)> {
            let mode = ctx.decode_mode(&req.constraint)?;
            let session = ctx.backend.new_session()?;
            let prompt = crate::domino::generate::Prompt::healed(&ctx.vocab, &req.prompt);
            let healed = prompt.forced.len();
            let sampling = match req.temperature {
                Some(t) => Sampling::Temperature(t),
                None => Sampling::Greedy,
            };
            let slot = Slot::new(
                next_id,
                session,
                mode,
                ctx.vocab.clone(),
                &prompt,
                sampling,
                req.max_tokens,
                req.seed,
            )?;
            Ok((slot, healed))
        })();
        match admit {
            Ok((mut slot, healed)) => {
                if let Some(sink) = sink {
                    slot.attach_sink(sink);
                }
                if let Some(tr) = trace.as_deref_mut() {
                    tr.admitted();
                    if healed > 0 {
                        tr.event(format!("healed {healed} prompt bytes"));
                    }
                    // The slot-side recorder shares the request's submit
                    // instant so decision timestamps land on the span
                    // timeline.
                    slot.trace = Some(Box::new(SlotTrace::new(tr.started)));
                }
                self.active.push(Active {
                    slot,
                    resp,
                    cancel,
                    deadline,
                    started: Instant::now(),
                    first_token_at: None,
                    responded: false,
                    tenant,
                    grammar,
                    trace,
                });
            }
            Err(e) => {
                self.metrics.requests_failed += 1;
                self.metrics.tenant(&tenant).failed += 1;
                let mut r = GenResponse::failure(format!("{e:#}"));
                r.trace = finish_trace(&self.tracer, trace, None, None);
                let _ = resp.send(r);
            }
        }
    }

    /// Step every active slot one decode tick with ONE batched forward
    /// pass (gather → batched forward → per-slot mask/sample/commit; see
    /// [`step_batched`]), checking cancellation and deadlines first so an
    /// abandoned request stops burning engine ticks mid-decode instead of
    /// running to `max_tokens`. Plain, speculative and deferred-row slots
    /// share the tick's batch; a slot whose lane fails is answered and
    /// retired without poisoning its siblings.
    pub fn step_all(&mut self) {
        // Phase 0: abort checks; collect the slots that step this tick.
        let mut live: Vec<usize> = Vec::new();
        for (i, a) in self.active.iter_mut().enumerate() {
            if a.slot.done {
                continue;
            }
            let abort = if a.cancel.load(Ordering::Relaxed) {
                Some(Abort::Cancelled { disconnect: false })
            } else if a.slot.client_gone() {
                Some(Abort::Cancelled { disconnect: true })
            } else if a.deadline.map_or(false, |d| Instant::now() >= d) {
                Some(Abort::DeadlineExceeded { queued: false })
            } else {
                None
            };
            if let Some(abort) = abort {
                a.slot.abort();
                a.slot.finish_stream();
                match abort {
                    Abort::Cancelled { .. } => {
                        self.metrics.requests_cancelled += 1;
                        self.metrics.tenant(&a.tenant).cancelled += 1;
                    }
                    Abort::DeadlineExceeded { .. } => {
                        self.metrics.requests_deadline_exceeded += 1;
                        self.metrics.tenant(&a.tenant).deadline_exceeded += 1;
                    }
                }
                self.metrics.record_abort(abort.kind(), abort.reason());
                a.responded = true;
                // Flush the (tail-sampled) trace BEFORE the partial
                // response: an aborted streaming request must land in
                // the ring even though reap() only sweeps the slot.
                let trace = finish_trace(
                    &self.tracer,
                    a.trace.take(),
                    a.slot.trace.take(),
                    Some(abort.reason()),
                );
                let _ = a.resp.send(GenResponse {
                    text: a.slot.text(),
                    stats: a.slot.stats.clone(),
                    error: Some(abort.message().into()),
                    reason: Some(abort.reason().into()),
                    elapsed_s: a.started.elapsed().as_secs_f64(),
                    trace,
                });
                continue;
            }
            live.push(i);
        }
        if live.is_empty() {
            return;
        }
        let before: Vec<(usize, usize)> = live
            .iter()
            .map(|&i| (self.active[i].slot.stats.tokens_out, self.active[i].slot.stats.model_calls))
            .collect();
        // Phases 1–3: decide / gather+forward / finish, over the live
        // slots (`live` is sorted, so one walk pairs them up).
        let t0 = Instant::now();
        let tick = {
            let mut want = live.iter().copied().peekable();
            let mut view: Vec<&mut Slot> = Vec::with_capacity(live.len());
            for (i, a) in self.active.iter_mut().enumerate() {
                if want.peek() == Some(&i) {
                    want.next();
                    view.push(&mut a.slot);
                }
            }
            step_batched(self.ctx.backend.as_ref(), &mut view)
        };
        self.metrics.model_time += t0.elapsed();
        if tick.lanes > 0 {
            self.metrics.forward_batches += 1;
            self.metrics.forward_rows += tick.rows as u64;
            self.metrics.batch_size.record(tick.lanes as f64);
            self.metrics.tick_time.record(t0.elapsed().as_secs_f64());
            // Per-phase attribution (decide / gather / forward / finish)
            // is always on — it feeds `{"op":"stats"}` and the
            // `domino_tick_phase_seconds` histogram without tracing.
            self.metrics.tick_decide.record(tick.decide.as_secs_f64());
            self.metrics.tick_gather.record(tick.gather.as_secs_f64());
            self.metrics.tick_forward.record(tick.forward.as_secs_f64());
            self.metrics.tick_finish.record(tick.finish.as_secs_f64());
        }
        // Per-slot bookkeeping: answer failures, count fresh tokens.
        for ((&i, result), &(before_tokens, before_calls)) in
            live.iter().zip(&tick.results).zip(&before)
        {
            let a = &mut self.active[i];
            if let Some(tr) = a.trace.as_deref_mut() {
                tr.record_tick(t0, tick.decide, tick.gather, tick.forward, tick.finish);
            }
            if let Err(e) = result {
                self.metrics.requests_failed += 1;
                self.metrics.tenant(&a.tenant).failed += 1;
                a.slot.done = true;
                a.slot.finish_stream();
                a.responded = true;
                let trace = finish_trace(&self.tracer, a.trace.take(), a.slot.trace.take(), None);
                let _ = a.resp.send(GenResponse {
                    text: a.slot.text(),
                    stats: a.slot.stats.clone(),
                    error: Some(format!("{e:#}")),
                    reason: None,
                    elapsed_s: a.started.elapsed().as_secs_f64(),
                    trace,
                });
                continue;
            }
            let fresh = (a.slot.stats.tokens_out - before_tokens) as u64;
            self.metrics.tokens_generated += fresh;
            self.metrics.tenant(&a.tenant).tokens_generated += fresh;
            if let Some(fp) = &a.grammar {
                self.metrics.grammar(fp).tokens_generated += fresh;
            }
            self.metrics.model_calls += (a.slot.stats.model_calls - before_calls) as u64;
            if a.first_token_at.is_none() && a.slot.stats.tokens_out > 0 {
                a.first_token_at = Some(Instant::now());
                self.metrics.ttft.record(a.started.elapsed().as_secs_f64());
            }
        }
    }

    /// Retire finished slots, answering the ones that still owe a
    /// response.
    pub fn reap(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].slot.done {
                let mut a = self.active.swap_remove(i);
                if a.responded {
                    continue;
                }
                a.slot.finish_stream();
                let elapsed = a.started.elapsed().as_secs_f64();
                self.metrics.requests_completed += 1;
                self.metrics.tenant(&a.tenant).completed += 1;
                self.metrics.interventions += a.slot.stats.interventions as u64;
                self.metrics.masks_computed += a.slot.stats.masks_computed as u64;
                self.metrics.spec_proposed += a.slot.stats.spec_proposed as u64;
                self.metrics.spec_accepted += a.slot.stats.spec_accepted as u64;
                self.metrics.draft_proposed += a.slot.stats.draft_proposed as u64;
                self.metrics.draft_accepted += a.slot.stats.draft_accepted as u64;
                if a.slot.stats.masks_computed > 0 {
                    // Per-request mean mask cost, µs (ns totals are too
                    // coarse to histogram directly across request sizes).
                    let mean_us = a.slot.stats.mask_ns as f64
                        / a.slot.stats.masks_computed as f64
                        / 1e3;
                    self.metrics.mask_us.record(mean_us);
                    if let Some(fp) = &a.grammar {
                        self.metrics.grammar(fp).mask_us.record(mean_us);
                    }
                }
                if let Some(fp) = &a.grammar {
                    self.metrics.grammar(fp).masks_computed += a.slot.stats.masks_computed as u64;
                    self.metrics.grammar(fp).interventions += a.slot.stats.interventions as u64;
                }
                if a.slot.stats.draft_proposed > 0 {
                    self.metrics.draft_acceptance.record(
                        a.slot.stats.draft_accepted as f64 / a.slot.stats.draft_proposed as f64,
                    );
                }
                if elapsed > 0.0 {
                    self.metrics.req_tps.record(a.slot.stats.tokens_out as f64 / elapsed);
                }
                let trace = finish_trace(&self.tracer, a.trace.take(), a.slot.trace.take(), None);
                let _ = a.resp.send(GenResponse {
                    text: a.slot.text(),
                    stats: a.slot.stats.clone(),
                    error: None,
                    reason: None,
                    elapsed_s: elapsed,
                    trace,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Metrics snapshot: the loop counters plus the shared registry's and
    /// mask caches' (pulled at read time — they live in concurrent
    /// caches, not the loop). Aggregating snapshots from shards that
    /// share one registry must therefore use [`Metrics::merge`], which
    /// maxes rather than sums the registry fields.
    pub fn snapshot(&self) -> Metrics {
        let mut m = self.metrics.clone();
        let r = self.ctx.registry.stats();
        m.registry_hits = r.hits;
        m.registry_misses = r.misses;
        m.registry_evictions = r.evictions;
        m.registry_coalesced = r.coalesced;
        m.engine_compile_ms = r.compile_ms;
        m.artifact_hits = r.artifact_hits;
        m.artifact_misses = r.artifact_misses;
        m.artifact_invalid = r.artifact_invalid;
        m.warm_start_loaded = r.warm_loaded;
        m.warm_start_ms = r.warm_start_ms;
        m.registry_hot_entries = r.hot_entries as u64;
        m.registry_warm_entries = r.warm_entries as u64;
        m.registry_cold_entries = r.cold_entries as u64;
        let mc = self.ctx.registry.mask_stats();
        m.mask_cache_hits = mc.hits;
        m.mask_cache_misses = mc.misses;
        m.mask_cache_evictions = mc.evictions;
        m
    }
}

/// Handle to a single-engine scheduler — the pre-sharding API, kept for
/// callers that want exactly one engine thread with an effectively
/// unbounded queue (CLI one-shots, tests). New code should use
/// [`Scheduler`] directly.
pub struct Server {
    sched: Scheduler,
}

impl Server {
    /// Start the engine; `init` runs on the engine thread and builds all
    /// model state.
    pub fn start<F>(init: F, max_slots: usize) -> Server
    where
        F: FnOnce() -> crate::Result<EngineCtx> + Send + 'static,
    {
        let init = Mutex::new(Some(init));
        let sched = Scheduler::start(
            move |_shard, _registry| {
                let init = init.lock().expect("server init lock").take();
                (init.expect("single-shard init runs once"))()
            },
            SchedulerConfig {
                engines: 1,
                slots_per_engine: max_slots,
                queue_depth: usize::MAX,
                ..SchedulerConfig::default()
            },
        );
        Server { sched }
    }

    /// Enqueue a request; returns a receiver for the response.
    pub fn submit(&self, req: GenRequest) -> mpsc::Receiver<GenResponse> {
        self.sched.submit(req).into_receiver()
    }

    /// Enqueue a request, keeping the cancellation handle.
    pub fn submit_handle(&self, req: GenRequest) -> RequestHandle {
        self.sched.submit(req)
    }

    /// Generate synchronously.
    pub fn generate(&self, req: GenRequest) -> crate::Result<GenResponse> {
        self.sched.generate(req)
    }

    pub fn metrics(&self) -> crate::Result<Metrics> {
        self.sched.metrics()
    }

    pub fn shutdown(self) {
        self.sched.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::{json_mock, MockFactory};

    #[test]
    fn hot_spec_prior_survives_cold_sweep() {
        // LRU eviction: a prior that keeps being touched must survive a
        // sweep of > capacity distinct cold fingerprints; the cold ones
        // churn among themselves.
        let (vocab, model) = json_mock(64);
        let mut ctx = EngineCtx::new(Box::new(MockFactory { model }), vocab);
        const HOT: u64 = u64::MAX;
        let hot = ctx.spec_model(HOT);
        for cold in 1..=(super::SPEC_MODEL_CAPACITY as u64 + 8) {
            let _ = ctx.spec_model(cold);
            // Touch the hot prior between cold insertions (recency).
            let again = ctx.spec_model(HOT);
            assert!(Arc::ptr_eq(&hot, &again), "hot prior evicted at cold={cold}");
        }
        assert!(ctx.specs.len() <= super::SPEC_MODEL_CAPACITY);
    }

    #[test]
    fn spec_prior_is_shared_per_fingerprint_and_bounded() {
        let (vocab, model) = json_mock(64);
        let mut ctx = EngineCtx::new(Box::new(MockFactory { model }), vocab);
        let a = ctx.spec_model(7);
        let b = ctx.spec_model(7);
        assert!(Arc::ptr_eq(&a, &b), "same fingerprint shares one prior");
        for f in 100..100 + (SPEC_MODEL_CAPACITY as u64 * 2) {
            let _ = ctx.spec_model(f);
        }
        assert!(ctx.specs.len() <= SPEC_MODEL_CAPACITY, "prior map stays bounded");
    }
}
