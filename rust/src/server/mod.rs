//! Serving layer: sharded scheduler + dynamic batchers over the DOMINO
//! engine.
//!
//! Architecture (vLLM-router-like, adapted to thread-pinned PJRT state —
//! the `xla` crate's handles are `Rc`-based, so each shard's model state
//! lives on its own *engine thread*):
//!
//! ```text
//!  clients ──TCP/JSONL──▶ gateway reactor ──▶ Scheduler
//!          (thousands)    (accept + fixed      │ affinity route / spill /
//!                          worker pool)        │ shed ("overloaded")
//!                        ┌────────────────────┼──────────────┐
//!                        ▼                    ▼              ▼
//!                  shard 0 thread       shard 1 thread    … shard N-1
//!                  queue→[S0 S1 …]      queue→[S0 S1 …]
//!                        │  each tick: purge dead, admit, step, reap
//!                        └───────── shared EngineRegistry ───┘
//!                                   (hot/warm/cold tiers,
//!                                    one compile per grammar)
//! ```
//!
//! * [`scheduler`] — the sharded front: grammar-affinity routing with
//!   least-loaded spill, bounded per-shard queues with overload shedding,
//!   per-request deadlines + cancellation, streaming submission, and
//!   cross-shard metrics aggregation.
//! * [`engine`] — one shard's core: admission, the batched decode tick
//!   (gather every live slot's pending extension → ONE cross-slot
//!   `forward_batch` → per-slot mask/sample/commit; opportunistic /
//!   full-mask / speculative §3.6 slots share the batch), completion —
//!   the reusable `admit`/`step_all`/`reap` pieces the scheduler drives.
//!   Also the single-engine [`Server`](engine::Server) compatibility
//!   wrapper.
//! * [`slot`] — one in-flight request: LM session + checker + sampling
//!   state. A decode iteration is split at the model-call boundary
//!   (`begin_step` / `take_lane` / `finish_step`) so the engine can
//!   batch the forward pass across slots; `step()` recombines the halves
//!   into the self-contained per-slot path. Supports mid-decode abort
//!   and a per-step token sink for streaming.
//! * [`metrics`] — counters + latency/throughput summaries, mergeable
//!   across shards.
//! * [`tcp`] — the JSONL wire protocol: parsing, validation, response /
//!   event / stats formatting, and the `spawn_serve` / `serve` /
//!   `spawn_metrics_http` entry points (now backed by the reactor; the
//!   legacy thread-per-connection loop survives as
//!   [`tcp::spawn_serve_threaded`] for differential testing).
//! * [`reactor`] — the async connection gateway: nonblocking sockets
//!   multiplexed over a fixed worker pool (std::net polling; the
//!   vendored crate set has no tokio/mio), `--max-connections`
//!   admission, idle/read timeouts with structured abort reasons, and
//!   graceful drain on shutdown.
//! * [`trace`] — request-scoped tracing: span trees (queue → decode →
//!   per-tick decide/gather/forward/finish) with per-token decode
//!   decision records, head-sampled + tail-captured (aborted/slow) into
//!   a shared ring, exportable as Chrome trace-event JSON (Perfetto)
//!   and a human-readable timeline (`domino trace`).

pub mod engine;
pub mod metrics;
pub mod reactor;
pub mod scheduler;
pub mod slot;
pub mod tcp;
pub mod trace;

pub use engine::{
    Constraint, ConstraintSpec, EngineCore, EngineCtx, Enforcement, GenRequest, GenResponse, Server,
};
pub use metrics::Metrics;
pub use reactor::{GatewayStats, Reactor, ReactorConfig};
pub use scheduler::{CancelToken, RequestHandle, Scheduler, SchedulerConfig};
pub use slot::{step_batched, BatchTick, DecodeMode, Slot, StreamEvent};
pub use trace::{TraceConfig, Tracer};
