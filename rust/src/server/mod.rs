//! Serving layer: request router + dynamic batcher over the DOMINO engine.
//!
//! Architecture (vLLM-router-like, adapted to thread-pinned PJRT state —
//! the `xla` crate's handles are `Rc`-based, so **all** model state lives
//! on one *engine thread*):
//!
//! ```text
//!  clients ──TCP/JSONL──▶ router threads ──mpsc──▶ engine thread
//!                                                   │  slots: [S0 S1 …]
//!                                                   │  each loop tick:
//!                                                   │   admit new jobs
//!                                                   │   step every slot
//!                                                   ▼
//!                                           response channels
//! ```
//!
//! * [`engine`] — the engine loop: admission, per-slot decode stepping
//!   (opportunistic / full-mask / speculative §3.6), completion.
//! * [`slot`] — one in-flight request: LM session + checker + sampling
//!   state; `step()` advances by one decode iteration (which commits
//!   multiple tokens under speculation).
//! * [`metrics`] — counters + latency/throughput summaries.
//! * [`tcp`] — a JSONL-over-TCP front end (std::net, thread per
//!   connection; the vendored crate set has no tokio).

pub mod engine;
pub mod metrics;
pub mod slot;
pub mod tcp;

pub use engine::{Constraint, ConstraintSpec, Enforcement, EngineCtx, GenRequest, GenResponse, Server};
pub use metrics::Metrics;
pub use slot::DecodeMode;
