//! The async connection gateway: thousands of idle streaming clients on a
//! fixed handful of threads.
//!
//! The original front end ([`tcp::spawn_serve`](super::tcp::spawn_serve))
//! burned one OS thread per accepted connection — fine at tens of
//! clients, fatal at the "millions of users" scale the roadmap targets,
//! where most connections are *idle* (streaming consumers between tokens,
//! keepalive clients between requests) and a parked thread per idle
//! socket is pure waste. This module replaces it with a dependency-light
//! reactor:
//!
//! * **one accept thread** owns the listeners (JSONL and/or the metrics
//!   HTTP endpoint), applies the `--max-connections` admission cap, and
//!   deals accepted sockets round-robin to the workers;
//! * **a small worker pool** (default [`ReactorConfig::workers`]) owns
//!   every connection as a nonblocking state machine: readiness-driven
//!   reads assemble JSONL frames across arbitrary packet boundaries,
//!   decode work is handed to the existing [`Scheduler`] *unchanged*
//!   (same `submit`/`submit_streaming` calls the threaded path used),
//!   and streaming tokens drain through buffered, nonblocking writes;
//! * **timeouts with structured reasons**: connections over the admission
//!   cap are refused with `"overloaded"`/`"connection_limit"`, silent
//!   keepalive connections are closed after `idle_timeout` with
//!   `"timeout"`/`"idle_timeout"`, a stalled partial request (the
//!   slow-loris shape) is closed after `read_timeout` with
//!   `"timeout"`/`"read_timeout"`, and a peer that stops reading its own
//!   non-empty reply buffer is cut after `write_stall_timeout`
//!   (`"timeout"`/`"write_stall"`, metrics-only — nothing is deliverable
//!   to it) — all four documented in PROTOCOL.md and covered by the
//!   docs-drift test. Read buffers are hard-capped ([`MAX_READ_BUF`],
//!   [`MAX_HTTP_HEAD_BYTES`]): a client that pipelines bytes faster than
//!   the gateway parses them gets TCP backpressure, not server memory;
//! * **graceful drain**: [`Reactor::stop`] stops accepting, lets in-flight
//!   requests finish and flush for up to `drain_grace`, then cancels the
//!   stragglers. The workers hold the scheduler only **weakly**, so the
//!   gateway never keeps a shut-down scheduler alive — artifact and prior
//!   flushes run exactly as they would without a gateway in front.
//!
//! There is deliberately no epoll/kqueue binding here (the crate's only
//! dependency is `anyhow`): readiness is discovered by polling nonblocking
//! sockets with a per-connection adaptive backoff (fresh activity polls at
//! 1 ms; a quiet connection decays to [`MAX_READ_BACKOFF`]), which keeps
//! the syscall load of thousands of idle connections to a few hundred
//! reads per second per worker — measured in `benches/gateway_scale.rs`,
//! which gates ≥4k concurrent idle streaming connections on a bounded
//! thread count.

use super::metrics::{Metrics, Summary};
use super::scheduler::{RequestHandle, Scheduler};
use super::slot::StreamEvent;
use super::tcp::{
    error_line, format_event, format_response, format_stats, metrics_route, parse_line, Request,
    ServeDefaults,
};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, Weak};
use std::time::{Duration, Instant};

/// Ceiling on one buffered request line; a client that exceeds it gets a
/// structured `bad request` and the connection is closed (there is no way
/// to resynchronize mid-line).
const MAX_LINE_BYTES: usize = 1 << 20;

/// Ceiling on *total* buffered unparsed request bytes per connection.
/// The gateway parses one request at a time, so a client that pipelines
/// complete lines behind a long-running request could otherwise grow
/// `read_buf` without limit; at this cap the gateway simply stops
/// reading the socket (ordinary TCP backpressure — the client's writes
/// stall) until parsing frees space. Must exceed [`MAX_LINE_BYTES`] so
/// the oversized-line error path stays reachable.
const MAX_READ_BUF: usize = 4 * MAX_LINE_BYTES;

/// Ceiling on a metrics-listener HTTP request head. A head that grows
/// past this without its terminating blank line is answered with 431
/// and the connection is closed — newline-terminated header lines must
/// not accumulate unboundedly (`find_head_end` never consumes them).
const MAX_HTTP_HEAD_BYTES: usize = 16 << 10;

/// Ceiling on buffered unsent reply bytes per connection. A consumer that
/// falls this far behind its own stream is treated as gone.
const MAX_WRITE_BUF: usize = 8 << 20;

/// Fastest per-connection read poll (fresh activity).
const MIN_READ_BACKOFF: Duration = Duration::from_millis(1);

/// Slowest per-connection read poll (long-idle connection). Bounds the
/// idle-detection latency while keeping 4k idle sockets cheap.
const MAX_READ_BACKOFF: Duration = Duration::from_millis(50);

/// Accept-loop and worker-pass sleep when nothing made progress.
const PASS_SLEEP: Duration = Duration::from_millis(1);

/// Gateway shape knobs (CLI: `--max-connections`, `--idle-timeout-ms`,
/// `--read-timeout-ms`, `--write-stall-timeout-ms`, `--reactor-workers`).
#[derive(Clone, Debug)]
pub struct ReactorConfig {
    /// Admission cap across all listeners. Connections over the cap are
    /// refused with `"overloaded"`/`"connection_limit"` (HTTP 503 on the
    /// metrics listener) rather than queued invisibly in the backlog.
    pub max_connections: usize,
    /// Close connections with no in-flight request and no traffic for
    /// this long (`None` = never): `"timeout"`/`"idle_timeout"`.
    pub idle_timeout: Option<Duration>,
    /// Close connections holding an *incomplete* request (a partial JSONL
    /// line, or an unterminated HTTP request head — the slow-loris shape)
    /// for this long (`None` = never): `"timeout"`/`"read_timeout"`.
    pub read_timeout: Option<Duration>,
    /// Close connections whose buffered reply bytes move nowhere for this
    /// long (`None` = never): the peer requested work and then stopped
    /// reading. Nothing is deliverable to such a peer, so there is no
    /// goodbye line — the close shows up only in metrics, as
    /// `"timeout"`/`"write_stall"`. Without this a non-reading client
    /// escapes both other timeouts (it is neither idle nor mid-request)
    /// and parks in a `--max-connections` slot forever.
    pub write_stall_timeout: Option<Duration>,
    /// Worker threads multiplexing the connections. Each added worker
    /// buys parallel request parsing/formatting, not decode throughput —
    /// decoding is the scheduler's department.
    pub workers: usize,
    /// How long [`Reactor::stop`] lets in-flight requests finish and
    /// flush before cancelling them.
    pub drain_grace: Duration,
    /// Server-side request defaults (`--draft`), applied exactly as the
    /// threaded path applies them.
    pub defaults: ServeDefaults,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        ReactorConfig {
            max_connections: 4096,
            idle_timeout: Some(Duration::from_secs(300)),
            read_timeout: Some(Duration::from_secs(30)),
            write_stall_timeout: Some(Duration::from_secs(60)),
            workers: 2,
            drain_grace: Duration::from_secs(5),
            defaults: ServeDefaults::default(),
        }
    }
}

/// Shared gateway counters, snapshotted into [`Metrics`] at render time by
/// [`GatewayStats::fill`] (the gateway is a single source, so these never
/// ride through the shard merge).
#[derive(Default)]
pub struct GatewayStats {
    open: AtomicU64,
    accepted: AtomicU64,
    rejected: AtomicU64,
    idle_timeouts: AtomicU64,
    read_timeouts: AtomicU64,
    write_stalls: AtomicU64,
    lifetime: Mutex<Summary>,
}

impl GatewayStats {
    /// Fold the gateway's connection counters into a metrics snapshot
    /// (typically the scheduler aggregate, just before rendering).
    pub fn fill(&self, m: &mut Metrics) {
        m.connections_open = self.open.load(Ordering::Relaxed);
        m.connections_accepted = self.accepted.load(Ordering::Relaxed);
        m.connections_rejected = self.rejected.load(Ordering::Relaxed);
        m.connections_idle_timeout = self.idle_timeouts.load(Ordering::Relaxed);
        m.connections_read_timeout = self.read_timeouts.load(Ordering::Relaxed);
        m.connections_write_stall = self.write_stalls.load(Ordering::Relaxed);
        m.conn_lifetime.merge(&self.lifetime.lock().expect("gateway lifetime lock"));
        let rejected = m.connections_rejected;
        if rejected > 0 {
            *m.abort_reasons.entry("overloaded/connection_limit".into()).or_insert(0) += rejected;
        }
        for (reason, n) in [
            ("idle_timeout", m.connections_idle_timeout),
            ("read_timeout", m.connections_read_timeout),
            ("write_stall", m.connections_write_stall),
        ] {
            if n > 0 {
                *m.abort_reasons.entry(format!("timeout/{reason}")).or_insert(0) += n;
            }
        }
    }

    pub fn open(&self) -> u64 {
        self.open.load(Ordering::Relaxed)
    }

    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Connections cut because their peer stopped reading a non-empty
    /// reply buffer for `write_stall_timeout`.
    pub fn write_stalls(&self) -> u64 {
        self.write_stalls.load(Ordering::Relaxed)
    }

    fn record_close(&self, opened: Instant) {
        self.open.fetch_sub(1, Ordering::Relaxed);
        let mut lt = self.lifetime.lock().expect("gateway lifetime lock");
        lt.record(opened.elapsed().as_secs_f64());
    }
}

/// Which protocol a connection speaks.
#[derive(Clone, Copy, PartialEq)]
enum Kind {
    /// JSONL request/response + token streaming.
    Jsonl,
    /// One hand-rolled HTTP/1.1 exchange (`GET /metrics`, `/healthz`),
    /// `Connection: close` — folded into the reactor so a slow or hostile
    /// metrics client can no longer spawn (or exhaust) threads.
    Metrics,
}

/// An in-flight generation request: the scheduler handle plus, for
/// streaming requests, the event receiver. The sink side lives in the
/// engine slot; it is dropped when the slot retires, which is how the
/// pump learns the stream is complete (same ordering contract as the
/// threaded path: events first, final stats line last).
struct InFlight {
    handle: RequestHandle,
    events: Option<mpsc::Receiver<StreamEvent>>,
}

/// One multiplexed connection as a nonblocking state machine.
struct Conn {
    stream: TcpStream,
    kind: Kind,
    /// Unparsed request bytes (may hold a partial line between readiness
    /// events — frames are reassembled across arbitrary packet splits).
    read_buf: Vec<u8>,
    /// Reply bytes not yet accepted by the socket.
    write_buf: VecDeque<u8>,
    inflight: Option<InFlight>,
    opened: Instant,
    /// Last moment the connection did anything (bytes in, reply queued).
    last_activity: Instant,
    /// Set while `read_buf` holds an incomplete request; the read-timeout
    /// clock. For metrics connections this starts at accept: the whole
    /// request head is "incomplete" until its terminating blank line.
    /// For JSONL it tracks only a genuinely partial *tail* frame while no
    /// request is in flight — a complete pipelined line waiting behind an
    /// in-flight request is patience, not a slow loris.
    partial_since: Option<Instant>,
    /// Set while `write_buf` is non-empty and the socket accepts no bytes;
    /// the write-stall clock (a peer that stopped reading its own reply).
    write_stalled_since: Option<Instant>,
    /// Next read poll and current backoff (adaptive: reset by activity,
    /// doubled while quiet).
    next_read: Instant,
    read_backoff: Duration,
    /// Peer half-closed its write side (EOF). Tolerated: in-flight work
    /// finishes and the reply flushes before the connection closes.
    read_closed: bool,
    /// Flush `write_buf`, then close.
    closing: bool,
    /// Connection is unusable (reset, write failure, oversized buffers):
    /// cancel in-flight work and close without flushing.
    broken: bool,
}

impl Conn {
    fn new(stream: TcpStream, kind: Kind) -> Conn {
        let now = Instant::now();
        Conn {
            stream,
            kind,
            read_buf: Vec::new(),
            write_buf: VecDeque::new(),
            inflight: None,
            opened: now,
            last_activity: now,
            partial_since: if kind == Kind::Metrics { Some(now) } else { None },
            write_stalled_since: None,
            next_read: now,
            read_backoff: MIN_READ_BACKOFF,
            read_closed: false,
            closing: false,
            broken: false,
        }
    }

    fn queue_line(&mut self, line: &str) {
        self.write_buf.extend(line.as_bytes());
        self.write_buf.push_back(b'\n');
        self.last_activity = Instant::now();
    }

    fn queue_raw(&mut self, bytes: &[u8]) {
        self.write_buf.extend(bytes);
        self.last_activity = Instant::now();
    }
}

/// Handle to a running gateway. Dropping it signals shutdown but does not
/// drain — call [`Reactor::stop`] for the graceful path, or
/// [`Reactor::join`] to serve until the scheduler goes away.
pub struct Reactor {
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    jsonl_addr: Option<SocketAddr>,
    metrics_addr: Option<SocketAddr>,
    stats: Arc<GatewayStats>,
}

impl Reactor {
    /// Bind the requested listeners and start the gateway threads: one
    /// acceptor plus `cfg.workers` connection workers — the thread count
    /// is fixed at startup and *independent of the connection count*.
    /// Either address may be omitted; port 0 binds an OS-assigned port
    /// (handy for tests — read it back with [`Reactor::jsonl_addr`] /
    /// [`Reactor::metrics_addr`]).
    ///
    /// The gateway holds the scheduler only weakly: once the caller drops
    /// its last `Arc<Scheduler>`, the shards shut down (flushing
    /// artifacts/priors) and the gateway threads exit on their own.
    pub fn start(
        sched: &Arc<Scheduler>,
        jsonl: Option<&str>,
        metrics: Option<&str>,
        cfg: ReactorConfig,
    ) -> crate::Result<Reactor> {
        let jsonl_listener = match jsonl {
            Some(addr) => Some(TcpListener::bind(addr)?),
            None => None,
        };
        let metrics_listener = match metrics {
            Some(addr) => Some(TcpListener::bind(addr)?),
            None => None,
        };
        let jsonl_addr = jsonl_listener.as_ref().map(|l| l.local_addr()).transpose()?;
        let metrics_addr = metrics_listener.as_ref().map(|l| l.local_addr()).transpose()?;
        for l in jsonl_listener.iter().chain(metrics_listener.iter()) {
            l.set_nonblocking(true)?;
        }

        let cfg = ReactorConfig { workers: cfg.workers.max(1), ..cfg };
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(GatewayStats::default());
        let weak = Arc::downgrade(sched);

        let mut senders = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let (tx, rx) = mpsc::channel::<Conn>();
            senders.push(tx);
            let w = Worker {
                conns: Vec::new(),
                incoming: rx,
                sched: weak.clone(),
                stats: stats.clone(),
                cfg: cfg.clone(),
                shutdown: shutdown.clone(),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("domino-gateway-{i}"))
                    .spawn(move || w.run())
                    .expect("spawn gateway worker"),
            );
        }

        let accept = {
            let shutdown = shutdown.clone();
            let stats = stats.clone();
            let sched = weak;
            let max_connections = cfg.max_connections;
            std::thread::Builder::new()
                .name("domino-gateway-accept".into())
                .spawn(move || {
                    accept_loop(
                        jsonl_listener,
                        metrics_listener,
                        senders,
                        shutdown,
                        stats,
                        sched,
                        max_connections,
                    )
                })
                .expect("spawn gateway accept thread")
        };

        Ok(Reactor {
            shutdown,
            accept: Some(accept),
            workers,
            jsonl_addr,
            metrics_addr,
            stats,
        })
    }

    /// The bound JSONL address, when a JSONL listener was requested.
    pub fn jsonl_addr(&self) -> Option<SocketAddr> {
        self.jsonl_addr
    }

    /// The bound metrics-HTTP address, when one was requested.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    /// The gateway's shared connection counters.
    pub fn stats(&self) -> Arc<GatewayStats> {
        self.stats.clone()
    }

    /// Graceful drain: stop accepting, let workers finish in-flight
    /// requests and flush replies (bounded by `drain_grace`), then join
    /// every gateway thread.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    /// Block until the gateway exits on its own (scheduler dropped or
    /// shutdown signalled) — the `domino serve` foreground path.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        // Signal only: a dropped handle must not block the caller on a
        // drain. Threads also exit once the scheduler is gone.
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    jsonl: Option<TcpListener>,
    metrics: Option<TcpListener>,
    senders: Vec<mpsc::Sender<Conn>>,
    shutdown: Arc<AtomicBool>,
    stats: Arc<GatewayStats>,
    sched: Weak<Scheduler>,
    max_connections: usize,
) {
    let mut next_worker = 0usize;
    loop {
        if shutdown.load(Ordering::Relaxed) || sched.strong_count() == 0 {
            // Dropping the listeners here closes the accept sockets while
            // workers drain what's already connected.
            return;
        }
        let mut progressed = false;
        for (listener, kind) in jsonl
            .iter()
            .map(|l| (l, Kind::Jsonl))
            .chain(metrics.iter().map(|l| (l, Kind::Metrics)))
        {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        progressed = true;
                        if stats.open.load(Ordering::Relaxed) >= max_connections as u64 {
                            stats.rejected.fetch_add(1, Ordering::Relaxed);
                            refuse(stream, kind);
                            continue;
                        }
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        stats.open.fetch_add(1, Ordering::Relaxed);
                        stats.accepted.fetch_add(1, Ordering::Relaxed);
                        let conn = Conn::new(stream, kind);
                        // Round-robin deal; a worker can only be gone if
                        // we are shutting down, so a failed send just
                        // closes the connection.
                        if senders[next_worker % senders.len()].send(conn).is_err() {
                            stats.record_close(Instant::now());
                        }
                        next_worker = next_worker.wrapping_add(1);
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(_) => break,
                }
            }
        }
        if !progressed {
            std::thread::sleep(PASS_SLEEP);
        }
    }
}

/// Refuse an over-cap connection with the structured
/// `"overloaded"`/`"connection_limit"` abort (503 on the metrics
/// listener). Best-effort: the socket is fresh, so the handful of bytes
/// lands in the kernel buffer without blocking the accept loop.
fn refuse(mut stream: TcpStream, kind: Kind) {
    let _ = stream.set_nonblocking(true);
    match kind {
        Kind::Jsonl => {
            let body = crate::util::Json::obj(vec![
                ("error", crate::util::Json::str("overloaded")),
                ("reason", crate::util::Json::str("connection_limit")),
            ])
            .to_string();
            let _ = stream.write_all(format!("{body}\n").as_bytes());
        }
        Kind::Metrics => {
            let body = "overloaded: connection_limit\n";
            let _ = stream.write_all(
                format!(
                    "HTTP/1.1 503 Service Unavailable\r\nContent-Type: text/plain; \
                     charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
                    body.len()
                )
                .as_bytes(),
            );
        }
    }
}

/// One gateway worker: owns a slice of the connections and pumps each as
/// a state machine every pass.
struct Worker {
    conns: Vec<Conn>,
    incoming: mpsc::Receiver<Conn>,
    sched: Weak<Scheduler>,
    stats: Arc<GatewayStats>,
    cfg: ReactorConfig,
    shutdown: Arc<AtomicBool>,
}

impl Worker {
    fn run(mut self) {
        let mut drain_deadline: Option<Instant> = None;
        loop {
            while let Ok(conn) = self.incoming.try_recv() {
                self.conns.push(conn);
            }
            let draining = self.shutdown.load(Ordering::Relaxed);
            if draining && drain_deadline.is_none() {
                drain_deadline = Some(Instant::now() + self.cfg.drain_grace);
            }
            let Some(sched) = self.sched.upgrade() else {
                // Scheduler gone: nothing can make progress; close
                // everything and exit.
                for c in self.conns.drain(..) {
                    self.stats.record_close(c.opened);
                }
                return;
            };
            let past_grace = drain_deadline.is_some_and(|d| Instant::now() >= d);
            let mut progressed = false;
            let mut i = 0;
            while i < self.conns.len() {
                let done = {
                    let c = &mut self.conns[i];
                    progressed |= pump(c, &sched, &self.stats, &self.cfg, draining);
                    if draining
                        && c.inflight.is_none()
                        && c.write_buf.is_empty()
                        && !has_complete_line(&c.read_buf)
                    {
                        // Drain: nothing in flight, nothing buffered to
                        // start — this connection is done; idle keepalive
                        // clients must not hold the drain open.
                        c.closing = true;
                    }
                    if past_grace && (c.inflight.is_some() || !c.write_buf.is_empty()) {
                        // Out of drain grace: cancel and cut.
                        if let Some(inf) = &c.inflight {
                            inf.handle.cancel();
                        }
                        c.broken = true;
                    }
                    conn_finished(c)
                };
                if done {
                    let c = self.conns.swap_remove(i);
                    self.stats.record_close(c.opened);
                } else {
                    i += 1;
                }
            }
            drop(sched);
            if draining && self.conns.is_empty() {
                return;
            }
            if !progressed {
                std::thread::sleep(PASS_SLEEP);
            }
        }
    }
}

/// Is this connection ready to be dropped?
fn conn_finished(c: &Conn) -> bool {
    if c.broken {
        return true;
    }
    if (c.closing || c.read_closed) && c.inflight.is_none() && c.write_buf.is_empty() {
        // `closing`: server decided to end it (timeout, metrics exchange
        // complete, fatal parse error) and the reply has flushed.
        // `read_closed`: the peer half-closed; with nothing in flight and
        // nothing left to flush there is nothing more to say.
        return c.closing || (c.read_closed && c.read_buf.iter().all(|b| b.is_ascii_whitespace()));
    }
    false
}

/// Advance one connection's state machine a step: read newly-ready bytes,
/// start at most one request, pump streaming events, flush buffered
/// writes, fire timeouts. Returns whether anything happened (drives the
/// worker's sleep decision).
fn pump(
    c: &mut Conn,
    sched: &Scheduler,
    stats: &GatewayStats,
    cfg: &ReactorConfig,
    draining: bool,
) -> bool {
    let mut progressed = false;
    let now = Instant::now();

    // --- read readiness (adaptively backed off while quiet) ---
    // A full read buffer stops the reads entirely (TCP backpressure on
    // the pipelining client) until parsing frees space; memory per
    // connection stays bounded no matter what the peer sends.
    if !c.read_closed && !c.closing && now >= c.next_read && c.read_buf.len() < read_cap(c.kind) {
        match read_ready(c) {
            ReadOutcome::Progress => {
                progressed = true;
                c.read_backoff = MIN_READ_BACKOFF;
                c.last_activity = now;
            }
            ReadOutcome::Idle => {
                c.read_backoff = (c.read_backoff * 2).min(MAX_READ_BACKOFF);
            }
            ReadOutcome::Eof => {
                progressed = true;
                c.read_closed = true;
            }
            ReadOutcome::Broken => {
                if let Some(inf) = &c.inflight {
                    inf.handle.cancel();
                }
                c.broken = true;
                return true;
            }
        }
        c.next_read = now + c.read_backoff;
    }

    // --- parse + dispatch (one request at a time per connection) ---
    match c.kind {
        Kind::Jsonl => {
            while c.inflight.is_none() && !c.closing {
                if draining && !has_complete_line(&c.read_buf) {
                    break; // drain: finish what's buffered, start nothing new
                }
                match next_line(&mut c.read_buf) {
                    NextLine::Line(line) => {
                        progressed = true;
                        dispatch_jsonl(c, &line, sched, stats, cfg);
                    }
                    NextLine::TooLong => {
                        progressed = true;
                        c.queue_line(&error_line("bad request: ", "request line too long"));
                        c.closing = true;
                    }
                    NextLine::Invalid => {
                        progressed = true;
                        c.queue_line(&error_line("bad request: ", "request line is not valid UTF-8"));
                        c.closing = true;
                    }
                    NextLine::Partial => break,
                }
            }
            // Partial-frame bookkeeping for the read timeout: the clock
            // runs only on a genuinely partial frame — non-whitespace
            // bytes *after the last newline* — and only while no request
            // is in flight. A complete pipelined line parked behind an
            // in-flight request must never start the clock: it would be
            // stale by the time the request finishes and would cut the
            // connection with the valid follow-up still buffered.
            let tail_start = c.read_buf.iter().rposition(|&b| b == b'\n').map_or(0, |p| p + 1);
            let tail_partial =
                c.read_buf[tail_start..].iter().any(|b| !b.is_ascii_whitespace());
            if tail_partial && c.inflight.is_none() {
                c.partial_since.get_or_insert(now);
            } else {
                c.partial_since = None;
            }
        }
        Kind::Metrics => {
            if !c.closing {
                if let Some(head_end) = find_head_end(&c.read_buf) {
                    progressed = true;
                    let head = String::from_utf8_lossy(&c.read_buf[..head_end]).into_owned();
                    c.read_buf.clear();
                    let request_line = head.lines().next().unwrap_or("").to_string();
                    let (status, ctype, body) = metrics_route(&request_line, || {
                        let mut m = sched.metrics()?;
                        stats.fill(&mut m);
                        Ok(super::metrics::render_prometheus(&m, sched.engines()))
                    });
                    queue_http(c, status, ctype, &body);
                    c.closing = true; // Connection: close, as before
                } else if c.read_buf.len() > MAX_HTTP_HEAD_BYTES {
                    // Endless newline-terminated header lines with no
                    // terminating blank line must not buffer forever.
                    progressed = true;
                    queue_http(
                        c,
                        431,
                        "text/plain; charset=utf-8",
                        "bad request: header section too large\n",
                    );
                    c.closing = true;
                }
            }
        }
    }

    // --- streaming pump + final response ---
    if let Some(inf) = &mut c.inflight {
        let mut events_done = false;
        if let Some(events) = &inf.events {
            loop {
                match events.try_recv() {
                    Ok(ev) => {
                        progressed = true;
                        let line = format_event(&ev);
                        c.write_buf.extend(line.as_bytes());
                        c.write_buf.push_back(b'\n');
                        c.last_activity = Instant::now();
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        // Slot retired: every buffered event is in, the
                        // final stats line comes next.
                        events_done = true;
                        break;
                    }
                }
            }
        }
        if events_done {
            inf.events = None;
        }
        if inf.events.is_none() {
            match inf.handle.try_recv() {
                Ok(resp) => {
                    progressed = true;
                    c.inflight = None;
                    c.queue_line(&format_response(&resp));
                }
                Err(mpsc::TryRecvError::Empty) => {}
                Err(mpsc::TryRecvError::Disconnected) => {
                    progressed = true;
                    c.inflight = None;
                    c.queue_line(&error_line("", "engine gone"));
                }
            }
        }
    }

    // --- write flush ---
    if !c.write_buf.is_empty() {
        match flush_writes(c) {
            Ok(true) => {
                progressed = true;
                c.write_stalled_since = None;
            }
            Ok(false) => {
                c.write_stalled_since.get_or_insert(now);
            }
            Err(_) => {
                if let Some(inf) = &c.inflight {
                    inf.handle.cancel();
                }
                c.broken = true;
                return true;
            }
        }
    }
    if c.write_buf.is_empty() {
        c.write_stalled_since = None;
    }
    if c.write_buf.len() > MAX_WRITE_BUF {
        if let Some(inf) = &c.inflight {
            inf.handle.cancel();
        }
        c.broken = true;
        return true;
    }
    // A peer that requested work and then stopped reading is neither
    // idle (write_buf is non-empty) nor mid-request (no partial frame),
    // so without this check it would escape every timeout and park in a
    // `--max-connections` slot forever. Nothing is deliverable to it, so
    // there is no goodbye line — the cut is visible in metrics as
    // `"timeout"`/`"write_stall"`.
    if let Some(limit) = cfg.write_stall_timeout {
        if c.write_stalled_since.is_some_and(|t| t.elapsed() >= limit) {
            stats.write_stalls.fetch_add(1, Ordering::Relaxed);
            if let Some(inf) = &c.inflight {
                inf.handle.cancel();
            }
            c.broken = true;
            return true;
        }
    }

    // --- timeouts (structured reasons; see PROTOCOL.md "Connection
    // lifecycle") ---
    if c.inflight.is_none() && !c.closing && !c.broken {
        if let Some(limit) = cfg.read_timeout {
            if c.partial_since.is_some_and(|t| t.elapsed() >= limit) {
                stats.read_timeouts.fetch_add(1, Ordering::Relaxed);
                match c.kind {
                    Kind::Jsonl => c.queue_line(&timeout_line("read_timeout")),
                    Kind::Metrics => queue_http(
                        c,
                        408,
                        "text/plain; charset=utf-8",
                        "timeout: read_timeout\n",
                    ),
                }
                c.closing = true;
                progressed = true;
            }
        }
        if let Some(limit) = cfg.idle_timeout {
            if !c.closing
                && c.partial_since.is_none()
                && c.write_buf.is_empty()
                && c.last_activity.elapsed() >= limit
            {
                stats.idle_timeouts.fetch_add(1, Ordering::Relaxed);
                if c.kind == Kind::Jsonl {
                    c.queue_line(&timeout_line("idle_timeout"));
                }
                c.closing = true;
                progressed = true;
            }
        }
    }

    progressed
}

/// The structured timeout abort line: `{"error":"timeout","reason":...}`.
fn timeout_line(reason: &str) -> String {
    crate::util::Json::obj(vec![
        ("error", crate::util::Json::str("timeout")),
        ("reason", crate::util::Json::str(reason)),
    ])
    .to_string()
}

enum ReadOutcome {
    Progress,
    Idle,
    Eof,
    Broken,
}

/// Hard ceiling on `read_buf` for a connection of this kind; reads stop
/// at the cap (backpressure) and resume once parsing frees space.
fn read_cap(kind: Kind) -> usize {
    match kind {
        Kind::Jsonl => MAX_READ_BUF,
        // One past the head cap, so the parser can observe the overflow
        // and answer 431.
        Kind::Metrics => MAX_HTTP_HEAD_BYTES + 1,
    }
}

/// Drain whatever the socket has ready into `read_buf` (nonblocking),
/// never growing it past [`read_cap`].
fn read_ready(c: &mut Conn) -> ReadOutcome {
    let mut outcome = ReadOutcome::Idle;
    let mut chunk = [0u8; 4096];
    loop {
        match c.stream.read(&mut chunk) {
            Ok(0) => return ReadOutcome::Eof,
            Ok(n) => {
                c.read_buf.extend_from_slice(&chunk[..n]);
                outcome = ReadOutcome::Progress;
                if c.read_buf.len() >= read_cap(c.kind) {
                    // Full: let the parser drain (or reject) what we
                    // have before pulling more off the socket.
                    return outcome;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => return outcome,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return ReadOutcome::Broken,
        }
    }
}

enum NextLine {
    Line(String),
    Partial,
    TooLong,
    /// The line is not valid UTF-8. The threaded reference path
    /// (`BufReader::lines`) errors out and drops such connections; the
    /// gateway matches that strictness but says why first (a structured
    /// bad-request line, then close) — documented in PROTOCOL.md.
    Invalid,
}

fn has_complete_line(buf: &[u8]) -> bool {
    buf.contains(&b'\n')
}

/// Pop the next non-empty line off the buffer, tolerating partial frames
/// (bytes after the last newline stay buffered for the next readiness
/// event).
fn next_line(buf: &mut Vec<u8>) -> NextLine {
    loop {
        match buf.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                let rest = buf.split_off(pos + 1);
                let mut line = std::mem::replace(buf, rest);
                line.pop(); // the newline
                let Ok(line) = String::from_utf8(line) else {
                    return NextLine::Invalid;
                };
                if line.trim().is_empty() {
                    continue; // blank keepalive lines are ignored
                }
                return NextLine::Line(line);
            }
            None if buf.len() > MAX_LINE_BYTES => return NextLine::TooLong,
            None => return NextLine::Partial,
        }
    }
}

/// The end of an HTTP request head (`\r\n\r\n` or `\n\n`), if complete.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4).or_else(|| {
        buf.windows(2).position(|w| w == b"\n\n").map(|p| p + 2)
    })
}

/// Parse one JSONL request line and either answer it inline (stats, parse
/// errors) or hand it to the scheduler (generation — the decode work
/// itself never runs on a gateway thread).
fn dispatch_jsonl(
    c: &mut Conn,
    line: &str,
    sched: &Scheduler,
    stats: &GatewayStats,
    cfg: &ReactorConfig,
) {
    match parse_line(line) {
        Ok(Request::Stats) => {
            let reply = match sched.metrics() {
                Ok(mut m) => {
                    stats.fill(&mut m);
                    format_stats(&m, sched.engines())
                }
                Err(e) => error_line("stats failed: ", format!("{e:#}")),
            };
            c.queue_line(&reply);
        }
        Ok(Request::Trace) => {
            c.queue_line(&super::tcp::format_trace_dump(sched.tracer()));
        }
        Ok(Request::Generate(mut req)) => {
            cfg.defaults.apply(&mut req);
            let inflight = if req.stream {
                let (stx, srx) = mpsc::channel::<StreamEvent>();
                let handle = sched.submit_streaming(req, stx);
                InFlight { handle, events: Some(srx) }
            } else {
                InFlight { handle: sched.submit(req), events: None }
            };
            c.inflight = Some(inflight);
        }
        Err(e) => c.queue_line(&error_line("bad request: ", format!("{e:#}"))),
    }
}

/// Queue a complete HTTP/1.1 response (status line + headers + body).
fn queue_http(c: &mut Conn, status: u16, ctype: &str, body: &str) {
    let text = match status {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        431 => "Request Header Fields Too Large",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    };
    c.queue_raw(
        format!(
            "HTTP/1.1 {status} {text}\r\nContent-Type: {ctype}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    );
}

/// Push buffered reply bytes into the socket without blocking. Returns
/// whether any bytes moved; `Err` means the peer is gone.
fn flush_writes(c: &mut Conn) -> std::io::Result<bool> {
    let mut moved = false;
    while !c.write_buf.is_empty() {
        let (front, _) = c.write_buf.as_slices();
        match c.stream.write(front) {
            Ok(0) => return Err(std::io::ErrorKind::WriteZero.into()),
            Ok(n) => {
                c.write_buf.drain(..n);
                moved = true;
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(moved)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_line_reassembles_partial_frames() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"{\"op\": ");
        assert!(matches!(next_line(&mut buf), NextLine::Partial));
        buf.extend_from_slice(b"\"stats\"}\n{\"pro");
        let NextLine::Line(line) = next_line(&mut buf) else { panic!("expected a line") };
        assert_eq!(line, "{\"op\": \"stats\"}");
        assert!(matches!(next_line(&mut buf), NextLine::Partial));
        assert_eq!(buf, b"{\"pro");
        // Blank keepalive lines between requests are skipped, not errors.
        let mut buf = b"\n \n{\"op\": \"stats\"}\n".to_vec();
        let NextLine::Line(line) = next_line(&mut buf) else { panic!("expected a line") };
        assert_eq!(line, "{\"op\": \"stats\"}");
    }

    #[test]
    fn next_line_rejects_oversized_frames() {
        let mut buf = vec![b'x'; MAX_LINE_BYTES + 1];
        assert!(matches!(next_line(&mut buf), NextLine::TooLong));
    }

    #[test]
    fn next_line_rejects_invalid_utf8() {
        // Lone continuation byte: never valid UTF-8. The threaded path
        // drops such connections; the gateway answers a structured bad
        // request first — either way the bytes are not silently mangled
        // the way `from_utf8_lossy` would.
        let mut buf = b"{\"prompt\": \"\x80\"}\n".to_vec();
        assert!(matches!(next_line(&mut buf), NextLine::Invalid));
    }

    #[test]
    fn read_caps_bound_every_connection_kind() {
        // The pipelined-backlog cap must leave the oversized-line error
        // reachable, and the metrics cap must let the parser observe one
        // byte past the head limit (the 431 trigger).
        assert!(read_cap(Kind::Jsonl) > MAX_LINE_BYTES);
        assert_eq!(read_cap(Kind::Metrics), MAX_HTTP_HEAD_BYTES + 1);
    }

    #[test]
    fn head_end_detection_handles_both_line_endings() {
        assert_eq!(find_head_end(b"GET /metrics HTTP/1.1\r\n\r\n"), Some(25));
        assert_eq!(find_head_end(b"GET /metrics HTTP/1.1\n\n"), Some(23));
        assert_eq!(find_head_end(b"GET /metrics HTTP/1.1\r\nHost: x\r\n"), None);
    }

    #[test]
    fn timeout_lines_carry_structured_reasons() {
        let v = crate::util::Json::parse(&timeout_line("idle_timeout")).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "timeout");
        assert_eq!(v.get("reason").unwrap().as_str().unwrap(), "idle_timeout");
        let v = crate::util::Json::parse(&timeout_line("read_timeout")).unwrap();
        assert_eq!(v.get("reason").unwrap().as_str().unwrap(), "read_timeout");
    }

    #[test]
    fn gateway_stats_fill_snapshots_counters_and_abort_reasons() {
        let g = GatewayStats::default();
        g.open.store(3, Ordering::Relaxed);
        g.accepted.store(7, Ordering::Relaxed);
        g.rejected.store(2, Ordering::Relaxed);
        g.idle_timeouts.store(1, Ordering::Relaxed);
        g.write_stalls.store(4, Ordering::Relaxed);
        g.lifetime.lock().unwrap().record(0.25);
        let mut m = Metrics::default();
        g.fill(&mut m);
        assert_eq!(m.connections_open, 3);
        assert_eq!(m.connections_accepted, 7);
        assert_eq!(m.connections_rejected, 2);
        assert_eq!(m.connections_idle_timeout, 1);
        assert_eq!(m.connections_read_timeout, 0);
        assert_eq!(m.connections_write_stall, 4);
        assert_eq!(m.conn_lifetime.count, 1);
        assert_eq!(m.abort_reasons.get("overloaded/connection_limit"), Some(&2));
        assert_eq!(m.abort_reasons.get("timeout/idle_timeout"), Some(&1));
        assert_eq!(m.abort_reasons.get("timeout/read_timeout"), None);
        assert_eq!(m.abort_reasons.get("timeout/write_stall"), Some(&4));
    }
}
