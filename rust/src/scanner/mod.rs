//! Character scanner (§3.2) — the union automaton over terminal regexes.
//!
//! Every legal program is a sequence of terminals (Lemma 3.1): the scanner
//! recognizes `R = (r_1 | … | r_n)+` while **tracking which terminal
//! sub-automaton each active state belongs to**, so completed terminals can
//! be fed to the parser and partial (sub)terminals classified (§3.3).
//!
//! Each terminal's regex is determinized and minimized individually
//! ([`crate::regex::Dfa`]); the union is simulated as a set of
//! [`Pos`]itions. Segmentation is *nondeterministic*: at an accepting
//! state the scanner may close the terminal and start a new one on the
//! same byte, or keep extending — both paths are kept and the parser
//! prunes (maximal munch is never assumed; this is what makes bridge
//! tokens like `",` representable).

use crate::grammar::{Cfg, TermId};
use crate::regex::dfa::{Dfa, DEAD};
use std::collections::HashMap;
use std::sync::Arc;

/// A scanner position: at a terminal boundary, or inside terminal `t` at
/// DFA state `s`.
///
/// `In(t, s)` with `dfas[t].accepting[s]` means the terminal *may* close
/// here (a Full subterminal, possibly extendable — the two accepting
/// states of Fig. 4); closing is deferred until the next byte forces it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Pos {
    /// At a terminal boundary (only before the first byte of generation).
    Boundary,
    /// Inside terminal `.0`'s DFA at state `.1`.
    In(TermId, u32),
}

/// Dense id for a [`Pos`] (`0` = Boundary, then per-terminal DFA states).
pub type PosId = u32;

/// The compiled scanner: per-terminal DFAs + dense `Pos` numbering.
#[derive(Clone)]
pub struct Scanner {
    pub dfas: Vec<Dfa>,
    /// `pos_offset[t] + s + 1` = PosId of `In(t, s)`.
    pos_offset: Vec<u32>,
    num_pos: u32,
}

impl Scanner {
    pub fn new(cfg: &Cfg) -> crate::Result<Scanner> {
        Ok(Self::from_dfas(cfg.terminal_dfas()?))
    }

    /// Assemble a scanner from per-terminal DFAs determinized elsewhere
    /// (the artifact load path: deserialized DFAs skip the regex → NFA →
    /// DFA → minimize pipeline). `dfas[t]` must be terminal `t`'s
    /// automaton in the owning grammar's terminal order.
    pub fn from_dfas(dfas: Vec<Dfa>) -> Scanner {
        let mut pos_offset = Vec::with_capacity(dfas.len());
        let mut next = 0u32;
        for d in &dfas {
            pos_offset.push(next);
            next += d.num_states() as u32;
        }
        Scanner { dfas, pos_offset, num_pos: next + 1 }
    }

    /// Total number of distinct positions (Boundary + all DFA states).
    pub fn num_pos(&self) -> usize {
        self.num_pos as usize
    }

    pub fn pos_id(&self, pos: Pos) -> PosId {
        match pos {
            Pos::Boundary => 0,
            Pos::In(t, s) => 1 + self.pos_offset[t as usize] + s,
        }
    }

    pub fn pos_of_id(&self, id: PosId) -> Pos {
        if id == 0 {
            return Pos::Boundary;
        }
        let id = id - 1;
        // pos_offset is sorted; find the terminal owning this id.
        let t = match self.pos_offset.binary_search(&id) {
            Ok(mut i) => {
                // Later terminals may share the offset only if a DFA had
                // zero states (impossible — every DFA has ≥ 1 state), but
                // be safe and take the last offset equal to `id`.
                while i + 1 < self.pos_offset.len() && self.pos_offset[i + 1] == id {
                    i += 1;
                }
                i
            }
            Err(i) => i - 1,
        };
        Pos::In(t as TermId, id - self.pos_offset[t])
    }

    /// Can the terminal close at this position?
    pub fn accepting(&self, pos: Pos) -> bool {
        match pos {
            Pos::Boundary => false,
            Pos::In(t, s) => self.dfas[t as usize].accepting[s as usize],
        }
    }

    /// All positions reachable by starting a fresh terminal with byte `b`.
    fn starts(&self, b: u8) -> impl Iterator<Item = Pos> + '_ {
        self.dfas.iter().enumerate().filter_map(move |(t, d)| {
            let s = d.next(d.start, b);
            (s != DEAD).then_some(Pos::In(t as TermId, s))
        })
    }

    /// Advance one position by one byte. Produces `(emitted terminal, new
    /// position)` pairs: `None` = continued within the current terminal,
    /// `Some(t)` = closed terminal `t` and started a new one on `b`.
    pub fn step_pos(&self, pos: Pos, b: u8, out: &mut Vec<(Option<TermId>, Pos)>) {
        match pos {
            Pos::Boundary => {
                for p in self.starts(b) {
                    out.push((None, p));
                }
            }
            Pos::In(t, s) => {
                let d = &self.dfas[t as usize];
                let s2 = d.next(s, b);
                if s2 != DEAD {
                    out.push((None, Pos::In(t, s2)));
                }
                if d.accepting[s as usize] {
                    for p in self.starts(b) {
                        out.push((Some(t), p));
                    }
                }
            }
        }
    }

    /// Run a byte string through the scanner from a set of start positions,
    /// tracking every segmentation. Returns each distinct
    /// `(completed terminal sequence, final position set)` hypothesis.
    ///
    /// This is the `q.traverse(l)` of Algorithm 2.
    pub fn traverse(&self, start: &[Pos], bytes: &[u8]) -> Vec<(Vec<TermId>, Vec<Pos>)> {
        // Map: completed-terminal-sequence -> set of positions.
        let mut hyps: HashMap<Vec<TermId>, Vec<Pos>> = HashMap::new();
        let mut sorted_start: Vec<Pos> = start.to_vec();
        sorted_start.sort_unstable();
        sorted_start.dedup();
        hyps.insert(Vec::new(), sorted_start);
        let mut scratch: Vec<(Option<TermId>, Pos)> = Vec::new();
        for &b in bytes {
            let mut next: HashMap<Vec<TermId>, Vec<Pos>> = HashMap::new();
            for (seq, posset) in hyps {
                for &pos in &posset {
                    scratch.clear();
                    self.step_pos(pos, b, &mut scratch);
                    for &(emitted, p2) in &scratch {
                        let key = match emitted {
                            None => seq.clone(),
                            Some(t) => {
                                let mut k = seq.clone();
                                k.push(t);
                                k
                            }
                        };
                        next.entry(key).or_default().push(p2);
                    }
                }
            }
            for posset in next.values_mut() {
                posset.sort_unstable();
                posset.dedup();
            }
            hyps = next;
            if hyps.is_empty() {
                break;
            }
        }
        hyps.into_iter().collect()
    }

    /// Positions for which subterminal trees are precomputed: Boundary plus
    /// every state of every terminal DFA (all are reachable — subset
    /// construction only creates reachable states).
    pub fn reachable_positions(&self) -> Vec<Pos> {
        let mut out = vec![Pos::Boundary];
        for (t, d) in self.dfas.iter().enumerate() {
            for s in 0..d.num_states() as u32 {
                out.push(Pos::In(t as TermId, s));
            }
        }
        out
    }
}

/// Shared handle used across trees / decoders.
pub type ScannerRef = Arc<Scanner>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grammar::builtin::fig3_expr;

    fn fig3_scanner() -> (crate::grammar::Cfg, Scanner) {
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        (g, s)
    }

    fn term(g: &crate::grammar::Cfg, name: &str) -> TermId {
        g.terminals.iter().position(|t| t.name == name).unwrap() as TermId
    }

    #[test]
    fn pos_id_roundtrip() {
        let (_, s) = fig3_scanner();
        for pos in s.reachable_positions() {
            assert_eq!(s.pos_of_id(s.pos_id(pos)), pos);
        }
        assert_eq!(s.num_pos(), s.reachable_positions().len());
    }

    #[test]
    fn traverse_single_terminal() {
        let (g, s) = fig3_scanner();
        let int = term(&g, "int");
        let res = s.traverse(&[Pos::Boundary], b"12");
        // Unsplit segmentation: inside int("12"), nothing completed.
        let empty_seq: Vec<_> = res.iter().filter(|(seq, _)| seq.is_empty()).collect();
        assert_eq!(empty_seq.len(), 1);
        let (_, posset) = empty_seq[0];
        assert!(posset.iter().all(|p| matches!(p, Pos::In(t, _) if *t == int)));
        // Split segmentation int("1") | int("2") is also tracked.
        assert!(res.iter().any(|(seq, _)| seq == &vec![int]));
    }

    #[test]
    fn traverse_bridge_token() {
        // ")+(" spans three terminals — the bridge-token case.
        let (g, s) = fig3_scanner();
        let (rp, plus, lp) = (term(&g, "')'"), term(&g, "'+'"), term(&g, "'('"));
        let res = s.traverse(&[Pos::Boundary], b")+(");
        assert_eq!(res.len(), 1);
        let (seq, posset) = &res[0];
        assert_eq!(seq, &vec![rp, plus]);
        assert_eq!(posset.len(), 1);
        assert!(matches!(posset[0], Pos::In(t, _) if t == lp));
        assert!(s.accepting(posset[0]));
    }

    #[test]
    fn traverse_from_mid_terminal() {
        // From inside int("12"), token "+3" closes int and ends inside a
        // fresh int.
        let (g, s) = fig3_scanner();
        let int = term(&g, "int");
        let plus = term(&g, "'+'");
        let mid = {
            let res = s.traverse(&[Pos::Boundary], b"12");
            res.into_iter().find(|(seq, _)| seq.is_empty()).unwrap().1
        };
        let res = s.traverse(&mid, b"+3");
        assert_eq!(res.len(), 1);
        let (seq, posset) = &res[0];
        assert_eq!(seq, &vec![int, plus]);
        assert!(posset.iter().all(|p| matches!(p, Pos::In(t, _) if *t == int)));
    }

    #[test]
    fn traverse_illegal_bytes() {
        let (_, s) = fig3_scanner();
        assert!(s.traverse(&[Pos::Boundary], b"x").is_empty());
        // "012" is not one int (no leading zeros) but IS int("0") int("12")
        // and int("0") int("1") int("2") — splits with ≥ 1 completion.
        let res = s.traverse(&[Pos::Boundary], b"012");
        assert!(!res.is_empty());
        assert!(res.iter().all(|(seq, _)| !seq.is_empty()));
    }

    #[test]
    fn c_identifier_keyword_ambiguity() {
        let g = crate::grammar::builtin::c_lang();
        let s = Scanner::new(&g).unwrap();
        let res = s.traverse(&[Pos::Boundary], b"int");
        // The zero-completions hypothesis must be live in BOTH the
        // identifier and the "int" keyword sub-automata (§3.3's edge case).
        let (_, posset) = res.iter().find(|(seq, _)| seq.is_empty()).unwrap();
        let terms: Vec<TermId> = posset
            .iter()
            .filter_map(|p| match p {
                Pos::In(t, _) => Some(*t),
                _ => None,
            })
            .collect();
        let ident = g.terminals.iter().position(|t| t.name == "identifier").unwrap() as TermId;
        assert!(terms.contains(&ident));
        assert!(terms.len() >= 2, "keyword + identifier both live: {terms:?}");
    }
}
