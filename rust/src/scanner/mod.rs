//! Character scanner (§3.2) — the union automaton over terminal regexes.
//!
//! Every legal program is a sequence of terminals (Lemma 3.1): the scanner
//! recognizes `R = (r_1 | … | r_n)+` while **tracking which terminal
//! sub-automaton each active state belongs to**, so completed terminals can
//! be fed to the parser and partial (sub)terminals classified (§3.3).
//!
//! Each terminal's regex is determinized and minimized individually
//! ([`crate::regex::Dfa`]); the union is simulated as a set of
//! [`Pos`]itions. Segmentation is *nondeterministic*: at an accepting
//! state the scanner may close the terminal and start a new one on the
//! same byte, or keep extending — both paths are kept and the parser
//! prunes (maximal munch is never assumed; this is what makes bridge
//! tokens like `",` representable).
//!
//! Two automata backends share the position-level API:
//!
//! * **Dense** ([`Scanner::new`] / [`Scanner::from_dfas`]) — every
//!   terminal eagerly determinized and minimized. Supports the dense
//!   [`PosId`] numbering that subterminal-tree precomputation and artifact
//!   serialization rely on.
//! * **Lazy** ([`Scanner::new_lazy`]) — terminals kept as Thompson NFAs
//!   and determinized per *visited* state ([`crate::regex::LazyDfa`]), so
//!   huge schema-emitted grammars pay compile cost proportional to the
//!   states decoding actually touches. Lazy scanners have no global
//!   [`PosId`] numbering (the state count is open-ended); callers needing
//!   one materialize first ([`Scanner::materialized`]).

use crate::grammar::{Cfg, TermId};
use crate::regex::dfa::{Dfa, LazyDfa, DEAD};
use std::collections::HashMap;
use std::sync::Arc;

/// A scanner position: at a terminal boundary, or inside terminal `t` at
/// DFA state `s`.
///
/// `In(t, s)` with state `s` accepting in terminal `t` means the terminal
/// *may* close here (a Full subterminal, possibly extendable — the two
/// accepting states of Fig. 4); closing is deferred until the next byte
/// forces it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Pos {
    /// At a terminal boundary (only before the first byte of generation).
    Boundary,
    /// Inside terminal `.0`'s DFA at state `.1`.
    In(TermId, u32),
}

/// Dense id for a [`Pos`] (`0` = Boundary, then per-terminal DFA states).
/// Dense scanners only.
pub type PosId = u32;

/// The automata backing a scanner.
#[derive(Clone)]
enum Automata {
    Dense(Vec<Dfa>),
    Lazy(Vec<LazyDfa>),
}

/// The compiled scanner: per-terminal automata + (dense mode) a dense
/// `Pos` numbering.
#[derive(Clone)]
pub struct Scanner {
    auto: Automata,
    /// Dense mode: `pos_offset[t] + s + 1` = PosId of `In(t, s)`. Empty in
    /// lazy mode.
    pos_offset: Vec<u32>,
    /// Dense mode only; 0 in lazy mode (meaningless — use
    /// [`Scanner::discovered_states`]).
    num_pos: u32,
}

impl Scanner {
    pub fn new(cfg: &Cfg) -> crate::Result<Scanner> {
        Ok(Self::from_dfas(cfg.terminal_dfas()?))
    }

    /// Assemble a dense scanner from per-terminal DFAs determinized
    /// elsewhere (the artifact load path: deserialized DFAs skip the regex
    /// → NFA → DFA → minimize pipeline). `dfas[t]` must be terminal `t`'s
    /// automaton in the owning grammar's terminal order.
    pub fn from_dfas(dfas: Vec<Dfa>) -> Scanner {
        let mut pos_offset = Vec::with_capacity(dfas.len());
        let mut next = 0u32;
        for d in &dfas {
            pos_offset.push(next);
            next += d.num_states() as u32;
        }
        Scanner { auto: Automata::Dense(dfas), pos_offset, num_pos: next + 1 }
    }

    /// Build a **lazy** scanner: terminal regexes are compiled to NFAs only
    /// (cheap), and subset construction happens on demand as positions are
    /// visited. See the module docs for the trade-off.
    pub fn new_lazy(cfg: &Cfg) -> crate::Result<Scanner> {
        let lazies = cfg.terminal_nfas()?.into_iter().map(LazyDfa::new).collect();
        Ok(Scanner { auto: Automata::Lazy(lazies), pos_offset: Vec::new(), num_pos: 0 })
    }

    pub fn is_lazy(&self) -> bool {
        matches!(self.auto, Automata::Lazy(_))
    }

    pub fn num_terminals(&self) -> usize {
        match &self.auto {
            Automata::Dense(d) => d.len(),
            Automata::Lazy(l) => l.len(),
        }
    }

    /// The dense per-terminal DFAs, or `None` for a lazy scanner. Artifact
    /// serialization materializes first and then relies on this.
    pub fn dense_dfas(&self) -> Option<&[Dfa]> {
        match &self.auto {
            Automata::Dense(d) => Some(d),
            Automata::Lazy(_) => None,
        }
    }

    /// States currently known for terminal `t`: the full (minimized)
    /// automaton in dense mode, states discovered so far in lazy mode.
    pub fn num_states_of(&self, t: usize) -> usize {
        match &self.auto {
            Automata::Dense(d) => d[t].num_states(),
            Automata::Lazy(l) => l[t].num_states(),
        }
    }

    /// Total states across terminals currently known (see
    /// [`Scanner::num_states_of`]).
    pub fn discovered_states(&self) -> usize {
        (0..self.num_terminals()).map(|t| self.num_states_of(t)).sum()
    }

    /// An equivalent dense scanner. Lazy automata are explored to fixpoint
    /// with their discovery-order numbering **preserved** (no
    /// minimization), so every `Pos` observed through `self` denotes the
    /// same state in the result — the contract artifact serialization
    /// depends on. Dense scanners just clone.
    pub fn materialized(&self) -> Scanner {
        match &self.auto {
            Automata::Dense(_) => self.clone(),
            Automata::Lazy(l) => Self::from_dfas(l.iter().map(|d| d.materialize()).collect()),
        }
    }

    #[inline]
    fn next_state(&self, t: usize, s: u32, b: u8) -> u32 {
        match &self.auto {
            Automata::Dense(d) => d[t].next(s, b),
            Automata::Lazy(l) => l[t].next(s, b),
        }
    }

    #[inline]
    fn start_state(&self, t: usize) -> u32 {
        match &self.auto {
            Automata::Dense(d) => d[t].start,
            Automata::Lazy(l) => l[t].start(),
        }
    }

    #[inline]
    fn state_accepting(&self, t: usize, s: u32) -> bool {
        match &self.auto {
            Automata::Dense(d) => d[t].accepting[s as usize],
            Automata::Lazy(l) => l[t].accepting(s),
        }
    }

    /// Total number of distinct positions (Boundary + all DFA states).
    /// Dense scanners only.
    pub fn num_pos(&self) -> usize {
        assert!(!self.is_lazy(), "num_pos is undefined for a lazy scanner; materialize first");
        self.num_pos as usize
    }

    /// Dense scanners only (lazy state counts are open-ended).
    pub fn pos_id(&self, pos: Pos) -> PosId {
        assert!(!self.is_lazy(), "pos_id is undefined for a lazy scanner; materialize first");
        match pos {
            Pos::Boundary => 0,
            Pos::In(t, s) => 1 + self.pos_offset[t as usize] + s,
        }
    }

    pub fn pos_of_id(&self, id: PosId) -> Pos {
        assert!(!self.is_lazy(), "pos_of_id is undefined for a lazy scanner; materialize first");
        if id == 0 {
            return Pos::Boundary;
        }
        let id = id - 1;
        // pos_offset is sorted; find the terminal owning this id.
        let t = match self.pos_offset.binary_search(&id) {
            Ok(mut i) => {
                // Later terminals may share the offset only if a DFA had
                // zero states (impossible — every DFA has ≥ 1 state), but
                // be safe and take the last offset equal to `id`.
                while i + 1 < self.pos_offset.len() && self.pos_offset[i + 1] == id {
                    i += 1;
                }
                i
            }
            Err(i) => i - 1,
        };
        Pos::In(t as TermId, id - self.pos_offset[t])
    }

    /// Can the terminal close at this position?
    pub fn accepting(&self, pos: Pos) -> bool {
        match pos {
            Pos::Boundary => false,
            Pos::In(t, s) => self.state_accepting(t as usize, s),
        }
    }

    /// All positions reachable by starting a fresh terminal with byte `b`.
    fn starts(&self, b: u8) -> impl Iterator<Item = Pos> + '_ {
        (0..self.num_terminals()).filter_map(move |t| {
            let s = self.next_state(t, self.start_state(t), b);
            (s != DEAD).then_some(Pos::In(t as TermId, s))
        })
    }

    /// Advance one position by one byte. Produces `(emitted terminal, new
    /// position)` pairs: `None` = continued within the current terminal,
    /// `Some(t)` = closed terminal `t` and started a new one on `b`.
    pub fn step_pos(&self, pos: Pos, b: u8, out: &mut Vec<(Option<TermId>, Pos)>) {
        match pos {
            Pos::Boundary => {
                for p in self.starts(b) {
                    out.push((None, p));
                }
            }
            Pos::In(t, s) => {
                let s2 = self.next_state(t as usize, s, b);
                if s2 != DEAD {
                    out.push((None, Pos::In(t, s2)));
                }
                if self.state_accepting(t as usize, s) {
                    for p in self.starts(b) {
                        out.push((Some(t), p));
                    }
                }
            }
        }
    }

    /// Run a byte string through the scanner from a set of start positions,
    /// tracking every segmentation. Returns each distinct
    /// `(completed terminal sequence, final position set)` hypothesis.
    ///
    /// This is the `q.traverse(l)` of Algorithm 2.
    pub fn traverse(&self, start: &[Pos], bytes: &[u8]) -> Vec<(Vec<TermId>, Vec<Pos>)> {
        // Map: completed-terminal-sequence -> set of positions.
        let mut hyps: HashMap<Vec<TermId>, Vec<Pos>> = HashMap::new();
        let mut sorted_start: Vec<Pos> = start.to_vec();
        sorted_start.sort_unstable();
        sorted_start.dedup();
        hyps.insert(Vec::new(), sorted_start);
        let mut scratch: Vec<(Option<TermId>, Pos)> = Vec::new();
        for &b in bytes {
            let mut next: HashMap<Vec<TermId>, Vec<Pos>> = HashMap::new();
            for (seq, posset) in hyps {
                for &pos in &posset {
                    scratch.clear();
                    self.step_pos(pos, b, &mut scratch);
                    for &(emitted, p2) in &scratch {
                        let key = match emitted {
                            None => seq.clone(),
                            Some(t) => {
                                let mut k = seq.clone();
                                k.push(t);
                                k
                            }
                        };
                        next.entry(key).or_default().push(p2);
                    }
                }
            }
            for posset in next.values_mut() {
                posset.sort_unstable();
                posset.dedup();
            }
            hyps = next;
            if hyps.is_empty() {
                break;
            }
        }
        hyps.into_iter().collect()
    }

    /// Positions for which subterminal trees are precomputed: Boundary plus
    /// every state of every terminal DFA (all are reachable — subset
    /// construction only creates reachable states). Dense scanners only.
    pub fn reachable_positions(&self) -> Vec<Pos> {
        assert!(
            !self.is_lazy(),
            "reachable_positions is undefined for a lazy scanner; materialize first"
        );
        let mut out = vec![Pos::Boundary];
        for t in 0..self.num_terminals() {
            for s in 0..self.num_states_of(t) as u32 {
                out.push(Pos::In(t as TermId, s));
            }
        }
        out
    }
}

/// Shared handle used across trees / decoders.
pub type ScannerRef = Arc<Scanner>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grammar::builtin::fig3_expr;

    fn fig3_scanner() -> (crate::grammar::Cfg, Scanner) {
        let g = fig3_expr();
        let s = Scanner::new(&g).unwrap();
        (g, s)
    }

    fn term(g: &crate::grammar::Cfg, name: &str) -> TermId {
        g.terminals.iter().position(|t| t.name == name).unwrap() as TermId
    }

    #[test]
    fn pos_id_roundtrip() {
        let (_, s) = fig3_scanner();
        for pos in s.reachable_positions() {
            assert_eq!(s.pos_of_id(s.pos_id(pos)), pos);
        }
        assert_eq!(s.num_pos(), s.reachable_positions().len());
    }

    #[test]
    fn traverse_single_terminal() {
        let (g, s) = fig3_scanner();
        let int = term(&g, "int");
        let res = s.traverse(&[Pos::Boundary], b"12");
        // Unsplit segmentation: inside int("12"), nothing completed.
        let empty_seq: Vec<_> = res.iter().filter(|(seq, _)| seq.is_empty()).collect();
        assert_eq!(empty_seq.len(), 1);
        let (_, posset) = empty_seq[0];
        assert!(posset.iter().all(|p| matches!(p, Pos::In(t, _) if *t == int)));
        // Split segmentation int("1") | int("2") is also tracked.
        assert!(res.iter().any(|(seq, _)| seq == &vec![int]));
    }

    #[test]
    fn traverse_bridge_token() {
        // ")+(" spans three terminals — the bridge-token case.
        let (g, s) = fig3_scanner();
        let (rp, plus, lp) = (term(&g, "')'"), term(&g, "'+'"), term(&g, "'('"));
        let res = s.traverse(&[Pos::Boundary], b")+(");
        assert_eq!(res.len(), 1);
        let (seq, posset) = &res[0];
        assert_eq!(seq, &vec![rp, plus]);
        assert_eq!(posset.len(), 1);
        assert!(matches!(posset[0], Pos::In(t, _) if t == lp));
        assert!(s.accepting(posset[0]));
    }

    #[test]
    fn traverse_from_mid_terminal() {
        // From inside int("12"), token "+3" closes int and ends inside a
        // fresh int.
        let (g, s) = fig3_scanner();
        let int = term(&g, "int");
        let plus = term(&g, "'+'");
        let mid = {
            let res = s.traverse(&[Pos::Boundary], b"12");
            res.into_iter().find(|(seq, _)| seq.is_empty()).unwrap().1
        };
        let res = s.traverse(&mid, b"+3");
        assert_eq!(res.len(), 1);
        let (seq, posset) = &res[0];
        assert_eq!(seq, &vec![int, plus]);
        assert!(posset.iter().all(|p| matches!(p, Pos::In(t, _) if *t == int)));
    }

    #[test]
    fn traverse_illegal_bytes() {
        let (_, s) = fig3_scanner();
        assert!(s.traverse(&[Pos::Boundary], b"x").is_empty());
        // "012" is not one int (no leading zeros) but IS int("0") int("12")
        // and int("0") int("1") int("2") — splits with ≥ 1 completion.
        let res = s.traverse(&[Pos::Boundary], b"012");
        assert!(!res.is_empty());
        assert!(res.iter().all(|(seq, _)| !seq.is_empty()));
    }

    #[test]
    fn c_identifier_keyword_ambiguity() {
        let g = crate::grammar::builtin::c_lang();
        let s = Scanner::new(&g).unwrap();
        let res = s.traverse(&[Pos::Boundary], b"int");
        // The zero-completions hypothesis must be live in BOTH the
        // identifier and the "int" keyword sub-automata (§3.3's edge case).
        let (_, posset) = res.iter().find(|(seq, _)| seq.is_empty()).unwrap();
        let terms: Vec<TermId> = posset
            .iter()
            .filter_map(|p| match p {
                Pos::In(t, _) => Some(*t),
                _ => None,
            })
            .collect();
        let ident = g.terminals.iter().position(|t| t.name == "identifier").unwrap() as TermId;
        assert!(terms.contains(&ident));
        assert!(terms.len() >= 2, "keyword + identifier both live: {terms:?}");
    }

    /// Same segmentations from lazy and dense scanners: terminal sequences
    /// must match exactly, position-set *sizes* may differ (lazy automata
    /// are unminimized) but accepting status per hypothesis must agree.
    fn assert_traverse_equiv(dense: &Scanner, lazy: &Scanner, bytes: &[u8]) {
        let a = dense.traverse(&[Pos::Boundary], bytes);
        let b = lazy.traverse(&[Pos::Boundary], bytes);
        let mut seqs_a: Vec<Vec<TermId>> = a.iter().map(|(s, _)| s.clone()).collect();
        let mut seqs_b: Vec<Vec<TermId>> = b.iter().map(|(s, _)| s.clone()).collect();
        seqs_a.sort();
        seqs_b.sort();
        assert_eq!(seqs_a, seqs_b, "segmentations differ on {bytes:?}");
        for (seq, posset) in &a {
            let (_, lazy_posset) = b.iter().find(|(s, _)| s == seq).unwrap();
            let acc_a = posset.iter().any(|&p| dense.accepting(p));
            let acc_b = lazy_posset.iter().any(|&p| lazy.accepting(p));
            assert_eq!(acc_a, acc_b, "accepting status differs for {seq:?} on {bytes:?}");
        }
    }

    #[test]
    fn lazy_scanner_matches_dense_traversals() {
        let g = fig3_expr();
        let dense = Scanner::new(&g).unwrap();
        let lazy = Scanner::new_lazy(&g).unwrap();
        assert!(lazy.is_lazy() && !dense.is_lazy());
        for bytes in [&b"12"[..], b")+(", b"012", b"(1+20)", b"x"] {
            assert_traverse_equiv(&dense, &lazy, bytes);
        }
        // Lazy exploration is bounded by what was visited.
        assert!(lazy.discovered_states() <= lazy.materialized().discovered_states());
    }

    #[test]
    fn lazy_scanner_materializes_to_dense_with_stable_numbering() {
        let g = crate::grammar::builtin::c_lang();
        let lazy = Scanner::new_lazy(&g).unwrap();
        // Drive some exploration, remembering observed positions.
        let res = lazy.traverse(&[Pos::Boundary], b"int x");
        let observed: Vec<Pos> =
            res.iter().flat_map(|(_, ps)| ps.iter().copied()).collect();
        assert!(!observed.is_empty());
        let visited = lazy.discovered_states();
        let dense = lazy.materialized();
        assert!(!dense.is_lazy());
        assert!(dense.num_pos() > 0);
        // Numbering preserved: every observed position is valid in the
        // materialized scanner with the same accepting flag.
        for &p in &observed {
            assert_eq!(dense.accepting(p), lazy.accepting(p), "{p:?}");
            assert_eq!(dense.pos_of_id(dense.pos_id(p)), p);
        }
        assert!(
            visited < dense.discovered_states(),
            "lazy visited {visited} of {} states",
            dense.discovered_states()
        );
    }
}
