//! Online parser-guided constraining (llama.cpp grammars, PICARD, GCD,
//! SYNCHROMESH).
//!
//! Same scanner/parser state tracking as DOMINO, but **no precomputed
//! subterminal trees**: `compute_mask` checks every vocabulary token by
//! running its bytes through the scanner and parser ("in the worst case,
//! they have to check the entire model vocabulary at each step" — §2).
//!
//! Masks are semantically identical to `DominoDecoder` at `k = ∞` (both
//! are minimally invasive); only the cost differs. That equivalence is a
//! property test (`rust/tests/prop_invariants.rs`).

use crate::domino::decoder::{DominoDecoder, Engine, Lookahead};
use crate::domino::{Checker, TokenMask};
use crate::TokenId;
use std::sync::Arc;

/// The online checker: DOMINO's state machinery, a full-vocab scan per
/// mask.
pub struct OnlineChecker {
    inner: DominoDecoder,
    vocab_size: usize,
}

impl OnlineChecker {
    pub fn new(engine: Arc<Engine>) -> OnlineChecker {
        let vocab_size = engine.vocab.len();
        // k = ∞: online parsers check full tokens, so they admit every
        // parser-viable token (minimally invasive).
        OnlineChecker { inner: DominoDecoder::new(engine, Lookahead::Infinite), vocab_size }
    }
}

impl Checker for OnlineChecker {
    fn advance(&mut self, token: TokenId) -> crate::Result<()> {
        self.inner.advance(token)
    }

    fn compute_mask(&mut self) -> Arc<TokenMask> {
        // The defining cost: one scanner+parser traversal per vocab token.
        let mut mask = TokenMask::none(self.vocab_size);
        for id in 0..self.vocab_size as TokenId {
            if self.inner.check_token(id) {
                mask.allow(id);
            }
        }
        Arc::new(mask)
    }

    fn check_token(&mut self, token: TokenId) -> bool {
        // Opportunistic mode (llama.cpp always runs with it — Table 3
        // footnote): single-token check is cheap even online.
        self.inner.check_token(token)
    }

    fn reset(&mut self) {
        self.inner.reset()
    }

    fn state_key(&self) -> Option<u64> {
        self.inner.state_key()
    }

    fn mask_key(&self) -> Option<u64> {
        self.inner.mask_key()
    }

    fn check_bytes(&mut self, bytes: &[u8]) -> bool {
        self.inner.check_bytes(bytes)
    }

    fn advance_bytes(&mut self, bytes: &[u8]) -> crate::Result<()> {
        self.inner.advance_bytes(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grammar::builtin::json;
    use crate::tokenizer;

    #[test]
    fn online_mask_equals_domino_infinite() {
        let vocab = Arc::new(tokenizer::bpe::synthetic_json_vocab(512));
        let eng = Engine::compile(json(), vocab.clone()).unwrap();
        let mut online = OnlineChecker::new(eng.clone());
        let mut domino = DominoDecoder::new(eng.clone(), Lookahead::Infinite);
        let ids = vocab.encode(b"{\"name\": \"Jo");
        for &id in &ids {
            assert_eq!(online.compute_mask(), domino.compute_mask(), "at token {id}");
            online.advance(id).unwrap();
            domino.advance(id).unwrap();
        }
        assert_eq!(online.compute_mask(), domino.compute_mask());
    }
}
