//! GUIDANCE-style template programs (§2 "Template-Based Approaches",
//! App. A).
//!
//! A program is a sequence of segments: fixed **literals** (injected via
//! external tokenization — no model calls, but also the source of
//! template-induced misalignment, Fig. 2), **gen** holes constrained by a
//! regex (`stop='c'` desugars to `[^c\n]+`), and **select** choices
//! (desugared to a regex alternation).
//!
//! *Token healing* (Lundberg & Ribeiro): before each hole, the last token
//! of the preceding literal is stripped and its bytes are enforced as a
//! byte-prefix on generation, so bridge tokens spanning the
//! literal→generation boundary become available.
//!
//! The App. A **WS-flexible** variant ([`TemplateProgram::ws_flexible`])
//! replaces every literal whitespace run by a `gen(/[ \t\n]+/?)` hole, so
//! the model chooses its own formatting — higher accuracy, more model
//! calls (Table 2 "GUIDANCE WS").

use crate::domino::decoder::{DominoDecoder, Engine, Lookahead};
use crate::domino::Checker;
use crate::grammar::parse_ebnf;
use crate::runtime::sampler::{decode, log_prob, Sampling};
use crate::runtime::LmSession;
use crate::tokenizer::{Vocab, EOS_ID};
use crate::util::Rng;
use crate::TokenId;
use anyhow::{bail, Context};
use std::sync::Arc;

/// One template segment.
#[derive(Clone, Debug)]
pub enum Segment {
    /// Fixed text, injected with the external tokenizer.
    Literal(String),
    /// A generated hole constrained by `regex` (field name for capture).
    Gen { name: String, regex: String, max_tokens: usize },
    /// One of the given literal options.
    Select { name: String, options: Vec<String> },
}

/// A GUIDANCE-like program.
#[derive(Clone, Debug, Default)]
pub struct TemplateProgram {
    pub segments: Vec<Segment>,
}

impl TemplateProgram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn lit(mut self, s: &str) -> Self {
        self.segments.push(Segment::Literal(s.to_string()));
        self
    }

    pub fn gen(mut self, name: &str, regex: &str) -> Self {
        self.segments.push(Segment::Gen {
            name: name.to_string(),
            regex: regex.to_string(),
            max_tokens: 48,
        });
        self
    }

    /// `gen(stop='c')` — free text until the (single-char) stop.
    pub fn gen_stop(mut self, name: &str, stop: char) -> Self {
        let esc = escape_regex(&stop.to_string());
        self.segments.push(Segment::Gen {
            name: name.to_string(),
            regex: format!("[^{esc}\\n]+"),
            max_tokens: 48,
        });
        self
    }

    pub fn select(mut self, name: &str, options: &[&str]) -> Self {
        self.segments.push(Segment::Select {
            name: name.to_string(),
            options: options.iter().map(|s| s.to_string()).collect(),
        });
        self
    }

    /// §3.5: "DOMINO can also be extended to other forms of constraining,
    /// e.g. to execute GUIDANCE programs" — compile this template into a
    /// CFG and run it through the DOMINO decoder instead of the template
    /// executor. Literals become literal terminals, holes become regex
    /// terminals; DOMINO then executes the program *minimally invasively*
    /// (bridge tokens across every literal/hole boundary, no external
    /// tokenization at all — strictly better than token healing).
    pub fn to_grammar(&self) -> crate::Result<crate::grammar::Cfg> {
        use crate::grammar::{CfgBuilder, Symbol};
        anyhow::ensure!(!self.segments.is_empty(), "empty template");
        let mut b = CfgBuilder::new();
        let root = b.nonterminal("root");
        let mut rhs = Vec::new();
        for (i, seg) in self.segments.iter().enumerate() {
            match seg {
                Segment::Literal(text) => rhs.push(Symbol::T(b.literal(text))),
                Segment::Gen { name, regex, .. } => {
                    rhs.push(Symbol::T(b.regex_term(&format!("{name}#{i}"), regex)))
                }
                Segment::Select { name, options } => {
                    let alts: Vec<String> =
                        options.iter().map(|o| format!("({})", escape_regex(o))).collect();
                    rhs.push(Symbol::T(b.regex_term(&format!("{name}#{i}"), &alts.join("|"))));
                }
            }
        }
        b.production(root, rhs);
        b.build(root)
    }

    /// App. A: replace literal whitespace runs with generated-whitespace
    /// holes.
    pub fn ws_flexible(&self) -> TemplateProgram {
        let mut out = TemplateProgram::new();
        let mut ws_id = 0usize;
        for seg in &self.segments {
            match seg {
                Segment::Literal(s) => {
                    let mut chunk = String::new();
                    for c in s.chars() {
                        if c == ' ' || c == '\t' || c == '\n' {
                            if !chunk.is_empty() {
                                out.segments.push(Segment::Literal(std::mem::take(&mut chunk)));
                            }
                            // Merge consecutive ws into one hole.
                            if !matches!(out.segments.last(), Some(Segment::Gen { name, .. }) if name.starts_with("%ws"))
                            {
                                ws_id += 1;
                                out.segments.push(Segment::Gen {
                                    name: format!("%ws{ws_id}"),
                                    regex: "[ \\t\\n]+".to_string(),
                                    max_tokens: 8,
                                });
                            }
                        } else {
                            chunk.push(c);
                        }
                    }
                    if !chunk.is_empty() {
                        out.segments.push(Segment::Literal(chunk));
                    }
                }
                other => out.segments.push(other.clone()),
            }
        }
        out
    }
}

/// Escape a literal for embedding in our regex dialect.
pub fn escape_regex(s: &str) -> String {
    let mut out = String::new();
    for c in s.chars() {
        if "\\.*+?()[]{}|/\"'-^$".contains(c) {
            out.push('\\');
        }
        if c == '\n' {
            out.push_str("\\n");
        } else if c == '\t' {
            out.push_str("\\t");
        } else {
            out.push(c);
        }
    }
    out
}

/// Compiled program: a regex-constraint engine per hole.
pub struct TemplateRuntime {
    pub program: TemplateProgram,
    vocab: Arc<Vocab>,
    /// Engine per segment index (None for literals).
    engines: Vec<Option<Arc<Engine>>>,
    /// Token healing on?
    pub healing: bool,
}

/// Outcome of a template run.
#[derive(Clone, Debug, Default)]
pub struct TemplateResult {
    pub tokens: Vec<TokenId>,
    pub text: String,
    pub logprob_sum: f64,
    pub forced_tokens: usize,
    pub gen_tokens: usize,
    pub model_calls: usize,
    pub fields: Vec<(String, String)>,
}

impl TemplateResult {
    pub fn perplexity(&self) -> f64 {
        if self.tokens.is_empty() {
            return f64::NAN;
        }
        (-self.logprob_sum / self.tokens.len() as f64).exp()
    }
}

/// Split `(healed literal, healed-away suffix)` at the second-to-last
/// token boundary of `text` under `vocab`.
pub fn healed_prefix(vocab: &Vocab, text: &str) -> (Vec<TokenId>, Vec<u8>) {
    let mut ids = vocab.encode(text.as_bytes());
    if let Some(last) = ids.pop() {
        (ids, vocab.token_bytes(last).to_vec())
    } else {
        (ids, Vec::new())
    }
}

impl TemplateRuntime {
    pub fn compile(
        program: TemplateProgram,
        vocab: Arc<Vocab>,
        healing: bool,
    ) -> crate::Result<TemplateRuntime> {
        let mut engines = Vec::with_capacity(program.segments.len());
        for seg in &program.segments {
            let engine = match seg {
                Segment::Literal(_) => None,
                Segment::Gen { regex, name, .. } => {
                    let src = format!("root ::= /{}/", regex.replace('/', "\\/"));
                    let g = parse_ebnf(&src)
                        .with_context(|| format!("gen `{name}` regex /{regex}/"))?;
                    Some(Engine::compile(g, vocab.clone())?)
                }
                Segment::Select { options, name } => {
                    if options.is_empty() {
                        bail!("select `{name}` has no options");
                    }
                    let alts: Vec<String> =
                        options.iter().map(|o| format!("({})", escape_regex(o))).collect();
                    let src = format!("root ::= /{}/", alts.join("|").replace('/', "\\/"));
                    let g = parse_ebnf(&src)
                        .with_context(|| format!("select `{name}`"))?;
                    Some(Engine::compile(g, vocab.clone())?)
                }
            };
            engines.push(engine);
        }
        Ok(TemplateRuntime { program, vocab, engines, healing })
    }

    /// Execute the program after `prompt` token ids (no prompt-boundary
    /// healing — see [`TemplateRuntime::run_with_prompt`]).
    pub fn run(
        &self,
        lm: &mut dyn LmSession,
        prompt: &[TokenId],
        sampling: Sampling,
        rng: &mut Rng,
    ) -> crate::Result<TemplateResult> {
        let mut last_logits = lm.append(prompt)?;
        let mut res = TemplateResult::default();
        res.model_calls += 1;
        self.run_segments(lm, 0, sampling, rng, &mut last_logits, &mut res)?;
        Ok(res)
    }

    /// Execute the program after a *text* prompt, healing the
    /// prompt→template boundary by tokenizing the prompt jointly with the
    /// first literal (GUIDANCE-style: the template text is part of the
    /// same string as the prompt).
    pub fn run_with_prompt(
        &self,
        lm: &mut dyn LmSession,
        prompt_text: &str,
        sampling: Sampling,
        rng: &mut Rng,
    ) -> crate::Result<TemplateResult> {
        let mut res = TemplateResult::default();
        let (first_lit, rest_start) = match self.program.segments.first() {
            Some(Segment::Literal(text)) => (text.as_str(), 1usize),
            _ => ("", 0usize),
        };
        // Joint tokenization of prompt + first literal.
        let joint = format!("{prompt_text}{first_lit}");
        let ids = self.vocab.encode(joint.as_bytes());
        let pbytes = prompt_text.len();
        // Split at the first token extending past the prompt bytes.
        let mut off = 0usize;
        let mut split = ids.len();
        for (j, &id) in ids.iter().enumerate() {
            let l = self.vocab.token_bytes(id).len();
            if off + l > pbytes {
                split = j;
                break;
            }
            off += l;
        }
        let mut last_logits = lm.append(&ids[..split.max(1)])?;
        res.model_calls += 1;
        // Forced template tokens (incl. the one straddling the boundary).
        let forced = &ids[split.max(1)..];
        if !forced.is_empty() {
            let rows = lm.append_scored(forced)?;
            res.model_calls += 1;
            let mut boff = {
                // bytes of context consumed so far
                ids[..split.max(1)].iter().map(|&t| self.vocab.token_bytes(t).len()).sum::<usize>()
            };
            for (j, &id) in forced.iter().enumerate() {
                let row = if j == 0 { &last_logits } else { &rows[j - 1] };
                res.logprob_sum += log_prob(row, id);
                res.tokens.push(id);
                // Only the part beyond the prompt belongs to the output.
                let b = self.vocab.token_bytes(id);
                let out_from = pbytes.saturating_sub(boff).min(b.len());
                res.text.push_str(&String::from_utf8_lossy(&b[out_from..]));
                boff += b.len();
            }
            res.forced_tokens += forced.len();
            last_logits = rows.last().cloned().unwrap_or(last_logits);
        }
        self.run_segments(lm, rest_start, sampling, rng, &mut last_logits, &mut res)?;
        Ok(res)
    }

    /// Run segments from `start` onward.
    fn run_segments(
        &self,
        lm: &mut dyn LmSession,
        start: usize,
        sampling: Sampling,
        rng: &mut Rng,
        last_logits: &mut Vec<f32>,
        res: &mut TemplateResult,
    ) -> crate::Result<()> {
        let mut i = start;
        while i < self.program.segments.len() {
            match &self.program.segments[i] {
                Segment::Literal(text) => {
                    // Heal: hold back the literal's last token if a hole
                    // follows.
                    let next_is_hole = matches!(
                        self.program.segments.get(i + 1),
                        Some(Segment::Gen { .. }) | Some(Segment::Select { .. })
                    );
                    let (ids, healed) = if self.healing && next_is_hole {
                        healed_prefix(&self.vocab, text)
                    } else {
                        (self.vocab.encode(text.as_bytes()), Vec::new())
                    };
                    if !ids.is_empty() {
                        // Score + inject in one chunked call — this is the
                        // template speedup: len(ids) tokens, 1 model call.
                        let rows = lm.append_scored(&ids)?;
                        res.model_calls += 1;
                        for (j, &id) in ids.iter().enumerate() {
                            let row = if j == 0 { &*last_logits } else { &rows[j - 1] };
                            res.logprob_sum += log_prob(row, id);
                            res.tokens.push(id);
                        }
                        res.forced_tokens += ids.len();
                        if let Some(r) = rows.last() {
                            *last_logits = r.clone();
                        }
                        res.text.push_str(
                            &String::from_utf8_lossy(&self.vocab.decode(&ids)),
                        );
                    }
                    // Run the following hole with the healed prefix.
                    if next_is_hole {
                        i += 1;
                        self.run_hole(lm, i, &healed, sampling, rng, last_logits, res)?;
                    }
                }
                Segment::Gen { .. } | Segment::Select { .. } => {
                    self.run_hole(lm, i, &[], sampling, rng, last_logits, res)?;
                }
            }
            i += 1;
        }
        Ok(())
    }

    /// Generate one hole, with `forced` byte-prefix (token healing).
    #[allow(clippy::too_many_arguments)]
    fn run_hole(
        &self,
        lm: &mut dyn LmSession,
        idx: usize,
        forced: &[u8],
        sampling: Sampling,
        rng: &mut Rng,
        last_logits: &mut Vec<f32>,
        res: &mut TemplateResult,
    ) -> crate::Result<()> {
        let engine = self.engines[idx].as_ref().expect("hole has an engine");
        let (name, max_tokens) = match &self.program.segments[idx] {
            Segment::Gen { name, max_tokens, .. } => (name.clone(), *max_tokens),
            Segment::Select { name, .. } => (name.clone(), 32),
            Segment::Literal(_) => unreachable!(),
        };
        let mut decoder = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let mut pending: Vec<u8> = forced.to_vec();
        let mut field_text = Vec::new();
        let mut generated = 0usize;

        // Legality under prefix forcing: the token must agree with the
        // remaining forced bytes; any overhang must be accepted by the
        // hole's regex decoder.
        let check = |dec: &DominoDecoder, pending: &[u8], bytes: &[u8]| -> bool {
            if bytes.is_empty() {
                return false;
            }
            if bytes.len() <= pending.len() {
                pending.starts_with(bytes)
            } else {
                bytes.starts_with(pending) && dec.check_bytes(&bytes[pending.len()..])
            }
        };

        while generated < max_tokens {
            // Propose from raw logits (lazy coupling).
            let proposal = decode(last_logits, sampling, rng);
            let pbytes = self.vocab.token_bytes(proposal).to_vec();
            let ok = proposal != EOS_ID && check(&decoder, &pending, &pbytes);
            // Can the hole end here? Only when the forced prefix is fully
            // consumed and the regex accepts.
            let may_stop = pending.is_empty() && decoder.check_token(EOS_ID);
            let chosen = if ok {
                proposal
            } else if may_stop {
                break; // hole ends; the proposal belongs to the next literal
            } else {
                // Masked re-pick.
                let mut mask = crate::domino::TokenMask::none(self.vocab.len());
                for id in 0..self.vocab.len() as TokenId {
                    if check(&decoder, &pending, self.vocab.token_bytes(id)) {
                        mask.allow(id);
                    }
                }
                if mask.is_empty() {
                    bail!("template hole `{name}` deadlocked");
                }
                let mut masked = last_logits.clone();
                mask.apply(&mut masked);
                decode(&masked, sampling, rng)
            };
            res.logprob_sum += log_prob(last_logits, chosen);
            let bytes = self.vocab.token_bytes(chosen).to_vec();
            if bytes.len() <= pending.len() {
                pending.drain(..bytes.len());
            } else {
                let overhang = bytes[pending.len()..].to_vec();
                pending.clear();
                decoder.advance_bytes(&overhang)?;
                field_text.extend_from_slice(&overhang);
            }
            res.tokens.push(chosen);
            res.gen_tokens += 1;
            generated += 1;
            res.text.push_str(&String::from_utf8_lossy(&bytes));
            *last_logits = lm.append(&[chosen])?;
            res.model_calls += 1;
        }
        if !pending.is_empty() {
            bail!("template hole `{name}`: forced prefix not consumed");
        }
        res.fields.push((name, String::from_utf8_lossy(&field_text).into_owned()));
        Ok(())
    }
}

/// The paper's GSM8K template (App. D structure, fixed two-step variant —
/// templates cannot express variable-length lists, which is precisely
/// their accuracy limitation).
pub fn gsm8k_program(steps: usize) -> TemplateProgram {
    let mut p = TemplateProgram::new().lit("{\n  \"thoughts\": [\n");
    for i in 0..steps {
        p = p
            .lit("    {\"step\": \"")
            .gen_stop(&format!("step{i}"), '"')
            .lit("\", \"calculation\": \"")
            .gen_stop(&format!("calc{i}"), '"')
            .lit("\", \"result\": ")
            .gen(&format!("result{i}"), "-?[0-9]+");
        p = p.lit(if i + 1 < steps { "},\n" } else { "}\n" });
    }
    p.lit("  ],\n  \"answer\": ").gen("answer", "-?[0-9]+").lit("\n}")
}

/// CoNLL NER template (fixed number of entity slots).
pub fn conll_program(entities: usize) -> TemplateProgram {
    let mut p = TemplateProgram::new().lit("{\"entities\": [");
    for i in 0..entities {
        if i > 0 {
            p = p.lit(", ");
        }
        p = p
            .lit("{\"entity\": \"")
            .gen_stop(&format!("entity{i}"), '"')
            .lit("\", \"type\": \"")
            .select(&format!("type{i}"), &["PER", "LOC", "ORG", "MISC"])
            .lit("\"}");
    }
    p.lit("]}")
}

/// Listing 1: the RPG character profile template.
pub fn rpg_program() -> TemplateProgram {
    TemplateProgram::new()
        .lit("{\n  \"id\": ")
        .gen("id", "[1-9][0-9]*")
        .lit(",\n  \"description\": \"A nimble fighter\",\n  \"name\": \"")
        .gen_stop("name", '"')
        .lit("\",\n  \"age\": ")
        .gen("age", "[1-9][0-9]*")
        .lit(",\n  \"armor\": \"")
        .select("armor", &["leather", "chainmail", "plate"])
        .lit("\",\n  \"weapon\": \"")
        .select("weapon", &["sword", "axe", "bow"])
        .lit("\",\n  \"class\": \"")
        .gen_stop("class", '"')
        .lit("\",\n  \"mantra\": \"")
        .gen_stop("mantra", '"')
        .lit("\",\n  \"strength\": ")
        .gen("strength", "[1-9][0-9]*")
        .lit(",\n  \"items\": [\"")
        .gen_stop("item1", '"')
        .lit("\", \"")
        .gen_stop("item2", '"')
        .lit("\"]\n}")
}

/// Simple person-JSON template used by the Fig. 2 misalignment example.
pub fn person_program() -> TemplateProgram {
    TemplateProgram::new()
        .lit("{\"name\": \"")
        .gen_stop("name", '"')
        .lit("\", \"age\": ")
        .gen("age", "[1-9][0-9]*")
        .lit(", \"occupation\": \"")
        .gen_stop("occupation", '"')
        .lit("\"}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::{json_mock, MockLm};

    #[test]
    fn ws_flexible_transform() {
        let p = TemplateProgram::new().lit("{\n  \"a\": ").gen("a", "[0-9]+");
        let ws = p.ws_flexible();
        // Literals split around whitespace; ws holes inserted.
        let holes = ws
            .segments
            .iter()
            .filter(|s| matches!(s, Segment::Gen { name, .. } if name.starts_with("%ws")))
            .count();
        assert!(holes >= 1, "{:?}", ws.segments);
        let has_brace = ws
            .segments
            .iter()
            .any(|s| matches!(s, Segment::Literal(l) if l == "{"));
        assert!(has_brace);
    }

    #[test]
    fn escape_regex_roundtrip() {
        let s = "a+b (c) [d]";
        let pat = escape_regex(s);
        assert!(crate::regex::matches(&pat, s).unwrap());
        assert!(!crate::regex::matches(&pat, "aab (c) [d]").unwrap());
    }

    #[test]
    fn runs_person_template_on_mock() {
        let (vocab, model) = json_mock(512);
        let rt = TemplateRuntime::compile(person_program(), vocab.clone(), false).unwrap();
        let mut lm = MockLm::new(model);
        let mut rng = crate::util::Rng::new(7);
        let res = rt.run(&mut lm, &[], Sampling::Greedy, &mut rng).unwrap();
        // Output is well-formed JSON with the three fields.
        let v = crate::util::Json::parse(&res.text).unwrap_or_else(|e| panic!("{e}: {}", res.text));
        assert!(v.get("name").is_some() && v.get("age").is_some());
        assert!(res.forced_tokens > 0 && res.gen_tokens > 0);
        // Far fewer model calls than tokens (the template speedup).
        assert!(res.model_calls < res.tokens.len());
    }

    #[test]
    fn healing_enables_bridge_tokens() {
        let (vocab, model) = json_mock(512);
        // With healing, the literal's trailing `"` is healed away and the
        // hole may start with a `"J`-style bridge token.
        let rt = TemplateRuntime::compile(person_program(), vocab.clone(), true).unwrap();
        let mut lm = MockLm::new(model);
        let mut rng = crate::util::Rng::new(7);
        let res = rt.run(&mut lm, &[], Sampling::Greedy, &mut rng).unwrap();
        let v = crate::util::Json::parse(&res.text).unwrap_or_else(|e| panic!("{e}: {}", res.text));
        assert!(v.get("name").is_some());
    }

    #[test]
    fn select_only_yields_an_option() {
        let (vocab, model) = json_mock(512);
        let p = TemplateProgram::new().lit("{\"armor\": \"").select("armor", &["leather", "plate"]).lit("\"}");
        let rt = TemplateRuntime::compile(p, vocab, false).unwrap();
        let mut lm = MockLm::new(model);
        let mut rng = crate::util::Rng::new(1);
        let res = rt.run(&mut lm, &[], Sampling::Greedy, &mut rng).unwrap();
        let field = &res.fields.iter().find(|(n, _)| n == "armor").unwrap().1;
        assert!(field == "leather" || field == "plate", "{field}");
    }

    #[test]
    fn gsm8k_program_compiles() {
        let (vocab, _) = json_mock(512);
        TemplateRuntime::compile(gsm8k_program(2), vocab.clone(), true).unwrap();
        TemplateRuntime::compile(gsm8k_program(2).ws_flexible(), vocab, true).unwrap();
    }
}
