//! The constrained-decoding baselines the paper evaluates against (§2, §4).
//!
//! * [`online`] — llama.cpp/GCD/PICARD-style **online parser-guided**
//!   masking: scanner + parser in lock-step with the LLM, but no
//!   precomputation — every mask is a full-vocabulary scan. Minimally
//!   invasive, high per-step cost (Table 1 row "llama.cpp"/"GCD").
//! * [`template`] — GUIDANCE-style **template programs**: fixed structure
//!   injected via external tokenization (the source of template-induced
//!   misalignment, Fig. 2), generated holes under regex constraints,
//!   optional token healing, and the whitespace-flexible `WS` variant of
//!   App. A.
//! * **Naive/greedy** constraining (Fig. 1) is `DominoDecoder` with
//!   `Lookahead::K(0)`: only single-subterminal tokens, no bridge tokens —
//!   exercised directly by the Table 4 ablation.

pub mod online;
pub mod template;

pub use online::OnlineChecker;
pub use template::{healed_prefix, Segment, TemplateProgram, TemplateResult, TemplateRuntime};
