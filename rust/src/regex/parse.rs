//! Recursive-descent regex parser.
//!
//! Dialect (matches the terminal regexes in the paper's App. C grammars):
//!
//! ```text
//! alt    ::= concat ('|' concat)*
//! concat ::= repeat*
//! repeat ::= atom ('*' | '+' | '?' | '{' m (',' n?)? '}')*
//! atom   ::= '(' alt ')' | '[' class ']' | '.' | escape | char
//! class  ::= '^'? (char '-' char | char | escape)+
//! escape ::= '\' (n t r f b 0 \ . * + ? ( ) [ ] { } | / " ' - ^ $ | x HH | u HHHH)
//! ```
//!
//! Anchors are implicit: the automata built from these regexes always
//! perform *full* matches, so `^`/`$` are not part of the dialect.

use super::ast::{ByteSet, Regex};
use anyhow::{bail, Context};

struct Parser<'a> {
    /// Pattern as characters (unicode-aware; chars compile to UTF-8 bytes).
    chars: Vec<char>,
    pos: usize,
    pattern: &'a str,
}

/// Parse a regex pattern into its AST.
pub fn parse(pattern: &str) -> crate::Result<Regex> {
    let mut p = Parser { chars: pattern.chars().collect(), pos: 0, pattern };
    let re = p.alt()?;
    if p.pos != p.chars.len() {
        bail!("regex `{}`: trailing input at char {}", pattern, p.pos);
    }
    Ok(re)
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn eat(&mut self, c: char) -> bool {
        if self.peek() == Some(c) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn alt(&mut self) -> crate::Result<Regex> {
        let mut branches = vec![self.concat()?];
        while self.eat('|') {
            branches.push(self.concat()?);
        }
        Ok(if branches.len() == 1 { branches.pop().unwrap() } else { Regex::Alt(branches) })
    }

    fn concat(&mut self) -> crate::Result<Regex> {
        let mut parts = Vec::new();
        while let Some(c) = self.peek() {
            if c == '|' || c == ')' {
                break;
            }
            parts.push(self.repeat()?);
        }
        Ok(match parts.len() {
            0 => Regex::Empty,
            1 => parts.pop().unwrap(),
            _ => Regex::Concat(parts),
        })
    }

    fn repeat(&mut self) -> crate::Result<Regex> {
        let mut atom = self.atom()?;
        loop {
            match self.peek() {
                Some('*') => {
                    self.pos += 1;
                    atom = Regex::Star(Box::new(atom));
                }
                Some('+') => {
                    self.pos += 1;
                    atom = Regex::Plus(Box::new(atom));
                }
                Some('?') => {
                    self.pos += 1;
                    atom = Regex::Opt(Box::new(atom));
                }
                Some('{') => {
                    self.pos += 1;
                    let min = self.number()?;
                    let max = if self.eat(',') {
                        if self.peek() == Some('}') {
                            None
                        } else {
                            Some(self.number()?)
                        }
                    } else {
                        Some(min)
                    };
                    if !self.eat('}') {
                        bail!("regex `{}`: expected `}}` at char {}", self.pattern, self.pos);
                    }
                    if let Some(max) = max {
                        if max < min {
                            bail!("regex `{}`: repeat max < min", self.pattern);
                        }
                    }
                    atom = Regex::Repeat(Box::new(atom), min, max);
                }
                _ => break,
            }
        }
        Ok(atom)
    }

    fn number(&mut self) -> crate::Result<u32> {
        let start = self.pos;
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.pos == start {
            bail!("regex `{}`: expected number at char {}", self.pattern, start);
        }
        self.chars[start..self.pos]
            .iter()
            .collect::<String>()
            .parse()
            .with_context(|| format!("regex `{}`: bad repeat count", self.pattern))
    }

    fn atom(&mut self) -> crate::Result<Regex> {
        match self.bump() {
            Some('(') => {
                let inner = self.alt()?;
                if !self.eat(')') {
                    bail!("regex `{}`: unclosed group at char {}", self.pattern, self.pos);
                }
                Ok(inner)
            }
            Some('[') => self.class(),
            Some('.') => Ok(Regex::Class(ByteSet::dot())),
            Some('\\') => {
                let set = self.escape_set()?;
                Ok(Regex::Class(set))
            }
            Some(c) if c == '*' || c == '+' || c == '?' || c == ')' || c == ']' || c == '}' || c == '{' => {
                bail!("regex `{}`: unexpected `{}` at char {}", self.pattern, c, self.pos - 1)
            }
            Some(c) => Ok(char_regex(c)),
            None => bail!("regex `{}`: unexpected end of pattern", self.pattern),
        }
    }

    /// An escape sequence, as a byte set (single byte).
    fn escape_set(&mut self) -> crate::Result<ByteSet> {
        let c = self
            .bump()
            .with_context(|| format!("regex `{}`: dangling escape", self.pattern))?;
        let b = match c {
            'n' => b'\n',
            't' => b'\t',
            'r' => b'\r',
            'f' => 0x0c,
            'b' => 0x08,
            '0' => 0x00,
            'x' => {
                let hi = self.hex_digit()?;
                let lo = self.hex_digit()?;
                (hi << 4) | lo
            }
            'u' => {
                // \uHHHH — compile to the UTF-8 bytes of the code point; only
                // single-byte code points yield a class, otherwise error (the
                // paper's grammars only use \u inside literal escape handling
                // for JSON, which our class-based form covers).
                let mut v: u32 = 0;
                for _ in 0..4 {
                    v = (v << 4) | self.hex_digit()? as u32;
                }
                if v > 0x7f {
                    bail!("regex `{}`: \\u escape above ASCII unsupported in class position", self.pattern);
                }
                v as u8
            }
            // Identity escapes for metacharacters.
            '\\' | '.' | '*' | '+' | '?' | '(' | ')' | '[' | ']' | '{' | '}' | '|' | '/'
            | '"' | '\'' | '-' | '^' | '$' | ' ' => {
                let mut buf = [0u8; 4];
                let s = c.encode_utf8(&mut buf);
                s.as_bytes()[0]
            }
            other => bail!("regex `{}`: unknown escape `\\{}`", self.pattern, other),
        };
        Ok(ByteSet::single(b))
    }

    fn hex_digit(&mut self) -> crate::Result<u8> {
        let c = self
            .bump()
            .with_context(|| format!("regex `{}`: truncated hex escape", self.pattern))?;
        c.to_digit(16)
            .map(|d| d as u8)
            .with_context(|| format!("regex `{}`: bad hex digit `{}`", self.pattern, c))
    }

    fn class(&mut self) -> crate::Result<Regex> {
        let negated = self.eat('^');
        let mut set = ByteSet::empty();
        let mut first = true;
        loop {
            let c = match self.peek() {
                None => bail!("regex `{}`: unclosed class", self.pattern),
                Some(']') if !first => {
                    self.pos += 1;
                    break;
                }
                Some(c) => c,
            };
            first = false;
            self.pos += 1;
            let lo: u8 = if c == '\\' {
                self.pos -= 1;
                self.pos += 1; // re-consume the backslash
                let s = self.escape_set()?;
                let b = s.iter().next().unwrap();
                b
            } else {
                char_byte(c, self.pattern)?
            };
            // Range?
            if self.peek() == Some('-') && self.chars.get(self.pos + 1) != Some(&']') && self.chars.get(self.pos + 1).is_some() {
                self.pos += 1; // '-'
                let hc = self.bump().unwrap();
                let hi: u8 = if hc == '\\' {
                    let s = self.escape_set()?;
                    let b = s.iter().next().unwrap();
                    b
                } else {
                    char_byte(hc, self.pattern)?
                };
                if hi < lo {
                    bail!("regex `{}`: inverted class range", self.pattern);
                }
                set.union(&ByteSet::range(lo, hi));
            } else {
                set.insert(lo);
            }
        }
        if negated {
            set.negate();
        }
        if set.is_empty() {
            bail!("regex `{}`: empty character class", self.pattern);
        }
        Ok(Regex::Class(set))
    }
}

fn char_byte(c: char, pattern: &str) -> crate::Result<u8> {
    let mut buf = [0u8; 4];
    let s = c.encode_utf8(&mut buf);
    if s.len() != 1 {
        bail!("regex `{}`: multi-byte char `{}` not allowed inside a class", pattern, c);
    }
    Ok(s.as_bytes()[0])
}

/// A bare character: single-byte chars become classes, multi-byte UTF-8
/// characters become byte-sequence literals.
fn char_regex(c: char) -> Regex {
    let mut buf = [0u8; 4];
    let s = c.encode_utf8(&mut buf);
    if s.len() == 1 {
        Regex::Class(ByteSet::single(s.as_bytes()[0]))
    } else {
        Regex::Literal(s.as_bytes().to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_alternation_precedence() {
        // a|bc == a | (bc)
        let re = parse("a|bc").unwrap();
        match re {
            Regex::Alt(branches) => {
                assert_eq!(branches.len(), 2);
                assert!(matches!(branches[1], Regex::Concat(_)));
            }
            other => panic!("expected Alt, got {other:?}"),
        }
    }

    #[test]
    fn parses_bounded_repeat() {
        let re = parse("a{2,4}").unwrap();
        assert!(matches!(re, Regex::Repeat(_, 2, Some(4))));
        let re = parse("a{3}").unwrap();
        assert!(matches!(re, Regex::Repeat(_, 3, Some(3))));
        let re = parse("a{1,}").unwrap();
        assert!(matches!(re, Regex::Repeat(_, 1, None)));
    }

    #[test]
    fn parses_negated_class() {
        let re = parse("[^<]").unwrap();
        match re {
            Regex::Class(s) => {
                assert!(!s.contains(b'<'));
                assert!(s.contains(b'a'));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn class_with_leading_bracket() {
        // []] — a literal ']' as the first class member.
        let re = parse("[]]").unwrap();
        match re {
            Regex::Class(s) => {
                assert!(s.contains(b']'));
                assert_eq!(s.len(), 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn escapes() {
        for (pat, byte) in [(r"\n", b'\n'), (r"\t", b'\t'), (r"\\", b'\\'), (r"\x41", b'A')] {
            match parse(pat).unwrap() {
                Regex::Class(s) => assert!(s.contains(byte), "pattern {pat}"),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("(").is_err());
        assert!(parse("a{4,2}").is_err());
        assert!(parse("*a").is_err());
        assert!(parse("[z-a]").is_err());
        assert!(parse("a\\").is_err());
    }

    #[test]
    fn dash_at_class_end_is_literal() {
        match parse("[a-]").unwrap() {
            Regex::Class(s) => {
                assert!(s.contains(b'a'));
                assert!(s.contains(b'-'));
                assert_eq!(s.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }
}
