//! Subset-construction DFA + Moore minimization.
//!
//! Individual terminal regexes are determinized (and minimized) before the
//! scanner unions them: the union must stay an NFA so every active state
//! remains attributable to its terminal (§3.2), but *within* a terminal a
//! DFA keeps the simulated state sets small — this is the main lever on
//! subterminal-tree precomputation time (§4.3 reports 1–5 s per grammar).

use super::nfa::{Nfa, StateId};
use std::collections::HashMap;

/// Sentinel for "no transition".
pub const DEAD: u32 = u32::MAX;

/// A dense DFA over bytes.
#[derive(Clone, Debug)]
pub struct Dfa {
    /// `trans[state * 256 + byte]` — next state or [`DEAD`].
    pub trans: Vec<u32>,
    pub accepting: Vec<bool>,
    pub start: u32,
}

impl Dfa {
    pub fn num_states(&self) -> usize {
        self.accepting.len()
    }

    #[inline]
    pub fn next(&self, state: u32, byte: u8) -> u32 {
        self.trans[state as usize * 256 + byte as usize]
    }

    pub fn accepts(&self, input: &[u8]) -> bool {
        let mut s = self.start;
        for &b in input {
            s = self.next(s, b);
            if s == DEAD {
                return false;
            }
        }
        self.accepting[s as usize]
    }

    /// Subset construction from a Thompson NFA.
    pub fn from_nfa(nfa: &Nfa) -> Dfa {
        let start_set = nfa.start_set();
        let mut ids: HashMap<Vec<StateId>, u32> = HashMap::new();
        let mut sets: Vec<Vec<StateId>> = Vec::new();
        let mut trans: Vec<u32> = Vec::new();
        let mut accepting: Vec<bool> = Vec::new();

        let intern = |set: Vec<StateId>,
                          sets: &mut Vec<Vec<StateId>>,
                          trans: &mut Vec<u32>,
                          accepting: &mut Vec<bool>,
                          ids: &mut HashMap<Vec<StateId>, u32>|
         -> u32 {
            if let Some(&id) = ids.get(&set) {
                return id;
            }
            let id = sets.len() as u32;
            accepting.push(set.contains(&nfa.accept));
            sets.push(set.clone());
            trans.extend(std::iter::repeat(DEAD).take(256));
            ids.insert(set, id);
            id
        };

        let start = intern(start_set, &mut sets, &mut trans, &mut accepting, &mut ids);
        let mut work = vec![start];
        while let Some(id) = work.pop() {
            let set = sets[id as usize].clone();
            let live = nfa.live_bytes(&set);
            for b in live.iter() {
                let next = nfa.step(&set, b);
                if next.is_empty() {
                    continue;
                }
                let existed = ids.contains_key(&next);
                let nid = intern(next, &mut sets, &mut trans, &mut accepting, &mut ids);
                if !existed {
                    work.push(nid);
                }
                trans[id as usize * 256 + b as usize] = nid;
            }
        }
        Dfa { trans, accepting, start }.minimize()
    }

    /// Moore partition-refinement minimization.
    pub fn minimize(&self) -> Dfa {
        let n = self.num_states();
        // Initial partition: accepting vs non-accepting.
        let mut class: Vec<u32> = self.accepting.iter().map(|&a| a as u32).collect();
        let mut num_classes = 2;
        loop {
            // Signature of each state: (class, class of each byte target).
            let mut sig_ids: HashMap<(u32, Vec<u32>), u32> = HashMap::new();
            let mut new_class = vec![0u32; n];
            for s in 0..n {
                let targets: Vec<u32> = (0..256)
                    .map(|b| {
                        let t = self.trans[s * 256 + b];
                        if t == DEAD {
                            DEAD
                        } else {
                            class[t as usize]
                        }
                    })
                    .collect();
                let key = (class[s], targets);
                let next_id = sig_ids.len() as u32;
                let id = *sig_ids.entry(key).or_insert(next_id);
                new_class[s] = id;
            }
            let new_num = sig_ids.len();
            if new_num == num_classes {
                class = new_class;
                break;
            }
            num_classes = new_num;
            class = new_class;
        }
        // Build minimized DFA.
        let m = num_classes;
        let mut trans = vec![DEAD; m * 256];
        let mut accepting = vec![false; m];
        for s in 0..n {
            let c = class[s] as usize;
            accepting[c] = accepting[c] || self.accepting[s];
            for b in 0..256 {
                let t = self.trans[s * 256 + b];
                if t != DEAD {
                    trans[c * 256 + b] = class[t as usize];
                }
            }
        }
        Dfa { trans, accepting, start: class[self.start as usize] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::parse;

    fn dfa(pat: &str) -> Dfa {
        Dfa::from_nfa(&Nfa::from_regex(&parse(pat).unwrap()))
    }

    #[test]
    fn dfa_matches_nfa_semantics() {
        let cases = [
            ("(0+)|([1-9][0-9]*)", vec![("0", true), ("007", false), ("000", true), ("123", true), ("", false)]),
            ("a*b|c", vec![("b", true), ("aab", true), ("c", true), ("ac", false)]),
        ];
        for (pat, tests) in cases {
            let d = dfa(pat);
            for (s, expect) in tests {
                assert_eq!(d.accepts(s.as_bytes()), expect, "{pat} on {s:?}");
            }
        }
    }

    #[test]
    fn minimization_shrinks() {
        // (a|b)* over separate branches has redundant NFA states; the
        // minimal DFA has exactly 1 state.
        let d = dfa("(a|b)*");
        assert_eq!(d.num_states(), 1);
        assert!(d.accepting[d.start as usize]);
    }

    #[test]
    fn json_string_dfa() {
        let d = dfa(r#""([^"\\]|\\(["\\/bfnrt]|u[0-9a-fA-F]{4}))*""#);
        assert!(d.accepts(br#""ok""#));
        assert!(d.accepts("\"ÿ\"".as_bytes()));
        assert!(!d.accepts(br#""\u00f""#));
        // Sanity: stays small after minimization.
        assert!(d.num_states() < 16, "{} states", d.num_states());
    }
}
