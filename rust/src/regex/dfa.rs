//! Subset-construction DFA + Moore minimization.
//!
//! Individual terminal regexes are determinized (and minimized) before the
//! scanner unions them: the union must stay an NFA so every active state
//! remains attributable to its terminal (§3.2), but *within* a terminal a
//! DFA keeps the simulated state sets small — this is the main lever on
//! subterminal-tree precomputation time (§4.3 reports 1–5 s per grammar).

use super::nfa::{Nfa, StateId};
use std::collections::HashMap;
use std::sync::RwLock;

/// Sentinel for "no transition".
pub const DEAD: u32 = u32::MAX;

/// Sentinel inside a [`LazyDfa`] transition table: this `(state, byte)`
/// pair has not been determinized yet. Distinct from [`DEAD`] ("explored,
/// no transition").
const UNEXPLORED: u32 = u32::MAX - 1;

/// A dense DFA over bytes.
#[derive(Clone, Debug)]
pub struct Dfa {
    /// `trans[state * 256 + byte]` — next state or [`DEAD`].
    pub trans: Vec<u32>,
    pub accepting: Vec<bool>,
    pub start: u32,
}

impl Dfa {
    pub fn num_states(&self) -> usize {
        self.accepting.len()
    }

    #[inline]
    pub fn next(&self, state: u32, byte: u8) -> u32 {
        self.trans[state as usize * 256 + byte as usize]
    }

    pub fn accepts(&self, input: &[u8]) -> bool {
        let mut s = self.start;
        for &b in input {
            s = self.next(s, b);
            if s == DEAD {
                return false;
            }
        }
        self.accepting[s as usize]
    }

    /// Subset construction from a Thompson NFA.
    pub fn from_nfa(nfa: &Nfa) -> Dfa {
        let start_set = nfa.start_set();
        let mut ids: HashMap<Vec<StateId>, u32> = HashMap::new();
        let mut sets: Vec<Vec<StateId>> = Vec::new();
        let mut trans: Vec<u32> = Vec::new();
        let mut accepting: Vec<bool> = Vec::new();

        let intern = |set: Vec<StateId>,
                          sets: &mut Vec<Vec<StateId>>,
                          trans: &mut Vec<u32>,
                          accepting: &mut Vec<bool>,
                          ids: &mut HashMap<Vec<StateId>, u32>|
         -> u32 {
            if let Some(&id) = ids.get(&set) {
                return id;
            }
            let id = sets.len() as u32;
            accepting.push(set.contains(&nfa.accept));
            sets.push(set.clone());
            trans.extend(std::iter::repeat(DEAD).take(256));
            ids.insert(set, id);
            id
        };

        let start = intern(start_set, &mut sets, &mut trans, &mut accepting, &mut ids);
        let mut work = vec![start];
        while let Some(id) = work.pop() {
            let set = sets[id as usize].clone();
            let live = nfa.live_bytes(&set);
            for b in live.iter() {
                let next = nfa.step(&set, b);
                if next.is_empty() {
                    continue;
                }
                let existed = ids.contains_key(&next);
                let nid = intern(next, &mut sets, &mut trans, &mut accepting, &mut ids);
                if !existed {
                    work.push(nid);
                }
                trans[id as usize * 256 + b as usize] = nid;
            }
        }
        Dfa { trans, accepting, start }.minimize()
    }

    /// Moore partition-refinement minimization.
    pub fn minimize(&self) -> Dfa {
        let n = self.num_states();
        // Initial partition: accepting vs non-accepting.
        let mut class: Vec<u32> = self.accepting.iter().map(|&a| a as u32).collect();
        let mut num_classes = 2;
        loop {
            // Signature of each state: (class, class of each byte target).
            let mut sig_ids: HashMap<(u32, Vec<u32>), u32> = HashMap::new();
            let mut new_class = vec![0u32; n];
            for s in 0..n {
                let targets: Vec<u32> = (0..256)
                    .map(|b| {
                        let t = self.trans[s * 256 + b];
                        if t == DEAD {
                            DEAD
                        } else {
                            class[t as usize]
                        }
                    })
                    .collect();
                let key = (class[s], targets);
                let next_id = sig_ids.len() as u32;
                let id = *sig_ids.entry(key).or_insert(next_id);
                new_class[s] = id;
            }
            let new_num = sig_ids.len();
            if new_num == num_classes {
                class = new_class;
                break;
            }
            num_classes = new_num;
            class = new_class;
        }
        // Build minimized DFA.
        let m = num_classes;
        let mut trans = vec![DEAD; m * 256];
        let mut accepting = vec![false; m];
        for s in 0..n {
            let c = class[s] as usize;
            accepting[c] = accepting[c] || self.accepting[s];
            for b in 0..256 {
                let t = self.trans[s * 256 + b];
                if t != DEAD {
                    trans[c * 256 + b] = class[t as usize];
                }
            }
        }
        Dfa { trans, accepting, start: class[self.start as usize] }
    }
}

/// Mutable core of a [`LazyDfa`]: the subset-construction tables, grown
/// incrementally as `(state, byte)` pairs are first visited.
#[derive(Clone)]
struct LazyStates {
    /// ε-closed NFA state set backing each DFA state.
    sets: Vec<Vec<StateId>>,
    ids: HashMap<Vec<StateId>, u32>,
    /// `trans[state * 256 + byte]` — next state, [`DEAD`], or
    /// [`UNEXPLORED`].
    trans: Vec<u32>,
    accepting: Vec<bool>,
}

impl LazyStates {
    fn intern(&mut self, set: Vec<StateId>, accept: StateId) -> u32 {
        if let Some(&id) = self.ids.get(&set) {
            return id;
        }
        let id = self.sets.len() as u32;
        self.accepting.push(set.contains(&accept));
        self.trans.extend(std::iter::repeat(UNEXPLORED).take(256));
        self.sets.push(set.clone());
        self.ids.insert(set, id);
        id
    }
}

/// A lazily-determinized DFA: subset construction is performed on demand,
/// one `(state, byte)` transition at a time, so compile cost is
/// proportional to the states actually *visited* during decoding rather
/// than the full automaton. Huge schema-emitted grammars whose terminals
/// would take seconds to determinize eagerly start serving immediately.
///
/// State numbering is discovery order (start = 0) and is **stable**: once
/// a state has been handed out its id never changes, and
/// [`materialize`](LazyDfa::materialize) preserves the numbering (no
/// minimization pass), so scanner positions recorded against a lazy DFA —
/// including persisted mask seeds — stay valid for the dense artifact.
///
/// Interior mutability via an [`RwLock`]: lookups of already-explored
/// transitions take the read lock only, so concurrent decode slots sharing
/// an engine proceed without serializing on the hot path.
pub struct LazyDfa {
    nfa: Nfa,
    inner: RwLock<LazyStates>,
}

impl Clone for LazyDfa {
    fn clone(&self) -> LazyDfa {
        let snapshot = self.inner.read().unwrap().clone();
        LazyDfa { nfa: self.nfa.clone(), inner: RwLock::new(snapshot) }
    }
}

impl LazyDfa {
    pub fn new(nfa: Nfa) -> LazyDfa {
        let mut inner = LazyStates {
            sets: Vec::new(),
            ids: HashMap::new(),
            trans: Vec::new(),
            accepting: Vec::new(),
        };
        let accept = nfa.accept;
        inner.intern(nfa.start_set(), accept);
        LazyDfa { nfa, inner: RwLock::new(inner) }
    }

    /// The start state is always id 0 (first interned).
    #[inline]
    pub fn start(&self) -> u32 {
        0
    }

    /// Next state for `(state, byte)`, determinizing the transition on
    /// first visit. Returns [`DEAD`] when no transition exists.
    pub fn next(&self, state: u32, byte: u8) -> u32 {
        if state == DEAD {
            return DEAD;
        }
        let idx = state as usize * 256 + byte as usize;
        {
            let inner = self.inner.read().unwrap();
            let t = inner.trans[idx];
            if t != UNEXPLORED {
                return t;
            }
        }
        let mut inner = self.inner.write().unwrap();
        // Double-check: another thread may have explored it meanwhile.
        let t = inner.trans[idx];
        if t != UNEXPLORED {
            return t;
        }
        let set = inner.sets[state as usize].clone();
        let next = self.nfa.step(&set, byte);
        let t = if next.is_empty() { DEAD } else { inner.intern(next, self.nfa.accept) };
        inner.trans[idx] = t;
        t
    }

    pub fn accepting(&self, state: u32) -> bool {
        self.inner.read().unwrap().accepting[state as usize]
    }

    /// Number of DFA states discovered so far (not the full automaton's).
    pub fn num_states(&self) -> usize {
        self.inner.read().unwrap().accepting.len()
    }

    /// Full-match test (drives lazy exploration along the way).
    pub fn accepts(&self, input: &[u8]) -> bool {
        let mut s = self.start();
        for &b in input {
            s = self.next(s, b);
            if s == DEAD {
                return false;
            }
        }
        self.accepting(s)
    }

    /// Explore to fixpoint and emit a dense [`Dfa`].
    ///
    /// Discovery-order numbering is preserved (append-only exploration, no
    /// minimization), so any state id observed through this `LazyDfa` —
    /// e.g. a scanner position baked into a cached mask — denotes the same
    /// state in the returned automaton. Used to serialize artifacts from
    /// lazily-compiled engines.
    pub fn materialize(&self) -> Dfa {
        let mut inner = self.inner.write().unwrap();
        let mut i = 0;
        while i < inner.sets.len() {
            let set = inner.sets[i].clone();
            let live = self.nfa.live_bytes(&set);
            for b in live.iter() {
                let idx = i * 256 + b as usize;
                if inner.trans[idx] != UNEXPLORED {
                    continue;
                }
                let next = self.nfa.step(&set, b);
                let t = if next.is_empty() { DEAD } else { inner.intern(next, self.nfa.accept) };
                inner.trans[idx] = t;
            }
            i += 1;
        }
        let trans =
            inner.trans.iter().map(|&t| if t == UNEXPLORED { DEAD } else { t }).collect();
        Dfa { trans, accepting: inner.accepting.clone(), start: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::parse;

    fn dfa(pat: &str) -> Dfa {
        Dfa::from_nfa(&Nfa::from_regex(&parse(pat).unwrap()))
    }

    #[test]
    fn dfa_matches_nfa_semantics() {
        let cases = [
            ("(0+)|([1-9][0-9]*)", vec![("0", true), ("007", false), ("000", true), ("123", true), ("", false)]),
            ("a*b|c", vec![("b", true), ("aab", true), ("c", true), ("ac", false)]),
        ];
        for (pat, tests) in cases {
            let d = dfa(pat);
            for (s, expect) in tests {
                assert_eq!(d.accepts(s.as_bytes()), expect, "{pat} on {s:?}");
            }
        }
    }

    #[test]
    fn minimization_shrinks() {
        // (a|b)* over separate branches has redundant NFA states; the
        // minimal DFA has exactly 1 state.
        let d = dfa("(a|b)*");
        assert_eq!(d.num_states(), 1);
        assert!(d.accepting[d.start as usize]);
    }

    #[test]
    fn lazy_dfa_matches_eager_language() {
        let cases = [
            ("(0+)|([1-9][0-9]*)", vec!["0", "007", "000", "123", ""]),
            ("a*b|c", vec!["b", "aab", "c", "ac", "abc"]),
            (r#""([^"\\]|\\(["\\/bfnrt]|u[0-9a-fA-F]{4}))*""#, vec!["\"ok\"", "\"\\u00f\"", "\"\""]),
        ];
        for (pat, inputs) in cases {
            let nfa = Nfa::from_regex(&parse(pat).unwrap());
            let eager = Dfa::from_nfa(&nfa);
            let lazy = LazyDfa::new(nfa.clone());
            for s in &inputs {
                assert_eq!(lazy.accepts(s.as_bytes()), eager.accepts(s.as_bytes()), "{pat} on {s:?}");
            }
            // Materialized automaton recognizes the same language.
            let dense = lazy.materialize();
            for s in &inputs {
                assert_eq!(dense.accepts(s.as_bytes()), eager.accepts(s.as_bytes()), "{pat} dense on {s:?}");
            }
        }
    }

    #[test]
    fn lazy_dfa_explores_proportional_to_visits() {
        // A wide alternation: eager construction discovers every branch,
        // lazy discovers only the prefix actually walked.
        let pat = "(alpha|bravo|charlie|delta|echo|foxtrot|golf|hotel)";
        let nfa = Nfa::from_regex(&parse(pat).unwrap());
        let lazy = LazyDfa::new(nfa.clone());
        let after_new = lazy.num_states();
        assert_eq!(after_new, 1, "only the start set is interned up front");
        let mut s = lazy.start();
        for &b in b"alp" {
            s = lazy.next(s, b);
            assert_ne!(s, DEAD);
        }
        let visited = lazy.num_states();
        let full = lazy.materialize().num_states();
        assert!(visited < full, "walked {visited} of {full} states");
    }

    #[test]
    fn materialize_preserves_discovered_numbering() {
        let nfa = Nfa::from_regex(&parse("(ab|ac)d*").unwrap());
        let lazy = LazyDfa::new(nfa);
        // Explore a few transitions lazily, recording what we saw.
        let mut seen: Vec<(u32, u8, u32)> = Vec::new();
        let mut s = lazy.start();
        for &b in b"abdd" {
            let t = lazy.next(s, b);
            seen.push((s, b, t));
            s = t;
        }
        let dense = lazy.materialize();
        assert_eq!(dense.start, 0);
        for (from, b, to) in seen {
            assert_eq!(dense.next(from, b), to, "numbering drifted at ({from}, {b})");
        }
        // Accepting flags carry over per id.
        for id in 0..dense.num_states() as u32 {
            assert_eq!(dense.accepting[id as usize], lazy.accepting(id));
        }
    }

    #[test]
    fn json_string_dfa() {
        let d = dfa(r#""([^"\\]|\\(["\\/bfnrt]|u[0-9a-fA-F]{4}))*""#);
        assert!(d.accepts(br#""ok""#));
        assert!(d.accepts("\"ÿ\"".as_bytes()));
        assert!(!d.accepts(br#""\u00f""#));
        // Sanity: stays small after minimization.
        assert!(d.num_states() < 16, "{} states", d.num_states());
    }
}
