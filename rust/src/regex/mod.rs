//! Regular-expression substrate.
//!
//! DOMINO's scanner (§3.2 of the paper) is built from the NFAs of the
//! grammar's terminal regexes. This module provides the full pipeline:
//!
//! * [`ast`] — the regex syntax tree,
//! * [`parse`] — a parser for the regex dialect used by the paper's
//!   grammars (App. C): literals, escapes, classes (`[a-z]`, `[^<]`),
//!   `.`/`?`/`*`/`+`, bounded repeats `{m,n}`, groups and alternation,
//! * [`nfa`] — Thompson construction with ε-closures (McNaughton &
//!   Yamada 1960; Thompson 1968),
//! * [`dfa`] — subset construction, used to determinise *individual*
//!   terminal automata before they are unioned into the scanner (the union
//!   itself stays an NFA so each sub-automaton remains attributable to its
//!   terminal).
//!
//! All automata operate on **bytes**, matching the byte-level BPE
//! vocabulary: a UTF-8 character in a pattern is compiled to its byte
//! sequence.

pub mod ast;
pub mod dfa;
pub mod nfa;
pub mod parse;

pub use ast::Regex;
pub use dfa::{Dfa, LazyDfa};
pub use nfa::{Nfa, StateId};
pub use parse::parse;

/// Compile a regex pattern string straight to an NFA.
pub fn compile(pattern: &str) -> crate::Result<Nfa> {
    Ok(nfa::Nfa::from_regex(&parse(pattern)?))
}

/// Convenience: does `pattern` match `input` exactly (full match)?
pub fn matches(pattern: &str, input: &str) -> crate::Result<bool> {
    let nfa = compile(pattern)?;
    Ok(nfa.accepts(input.as_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_match() {
        assert!(matches("abc", "abc").unwrap());
        assert!(!matches("abc", "ab").unwrap());
        assert!(!matches("abc", "abcd").unwrap());
    }

    #[test]
    fn int_terminal_from_paper() {
        // Fig. 4: positive integers without leading zeros, or zeros.
        let p = "(0+)|([1-9][0-9]*)";
        assert!(matches(p, "0").unwrap());
        assert!(matches(p, "000").unwrap());
        assert!(matches(p, "12").unwrap());
        assert!(matches(p, "120").unwrap());
        assert!(!matches(p, "012").unwrap());
        assert!(!matches(p, "").unwrap());
        assert!(!matches(p, "a").unwrap());
    }

    #[test]
    fn json_string_terminal() {
        let p = r#""([^"\\]|\\(["\\/bfnrt]|u[0-9a-fA-F]{4}))*""#;
        assert!(matches(p, r#""hello""#).unwrap());
        assert!(matches(p, r#""""#).unwrap());
        assert!(matches(p, r#""a\nb""#).unwrap());
        assert!(matches(p, r#""ÿ""#).unwrap());
        assert!(!matches(p, r#""unterminated"#).unwrap());
        assert!(!matches(p, r#""bad\escape""#).unwrap());
    }

    #[test]
    fn unicode_literals_compile_to_bytes() {
        assert!(matches("é+", "ééé").unwrap());
        assert!(!matches("é", "e").unwrap());
    }
}
