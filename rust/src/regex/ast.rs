//! Regex abstract syntax tree.

use std::fmt;

/// A set of bytes, represented as a 256-bit bitmap.
///
/// Character classes (`[a-z0-9_]`, `[^<]`, `.`) compile to `ByteSet`s.
/// Multi-byte UTF-8 characters never appear inside classes in the paper's
/// grammars; negated classes are interpreted over all bytes except `\n`
/// handling follows the grammar author's intent (`.` excludes `\n`).
#[derive(Clone, PartialEq, Eq)]
pub struct ByteSet {
    bits: [u64; 4],
}

impl ByteSet {
    pub const fn empty() -> Self {
        ByteSet { bits: [0; 4] }
    }

    pub fn full() -> Self {
        ByteSet { bits: [u64::MAX; 4] }
    }

    pub fn single(b: u8) -> Self {
        let mut s = Self::empty();
        s.insert(b);
        s
    }

    pub fn range(lo: u8, hi: u8) -> Self {
        let mut s = Self::empty();
        for b in lo..=hi {
            s.insert(b);
        }
        s
    }

    /// `.` — any byte except `\n`.
    pub fn dot() -> Self {
        let mut s = Self::full();
        s.remove(b'\n');
        s
    }

    pub fn insert(&mut self, b: u8) {
        self.bits[(b >> 6) as usize] |= 1u64 << (b & 63);
    }

    pub fn remove(&mut self, b: u8) {
        self.bits[(b >> 6) as usize] &= !(1u64 << (b & 63));
    }

    pub fn contains(&self, b: u8) -> bool {
        self.bits[(b >> 6) as usize] & (1u64 << (b & 63)) != 0
    }

    pub fn union(&mut self, other: &ByteSet) {
        for i in 0..4 {
            self.bits[i] |= other.bits[i];
        }
    }

    pub fn negate(&mut self) {
        for i in 0..4 {
            self.bits[i] = !self.bits[i];
        }
    }

    pub fn is_empty(&self) -> bool {
        self.bits.iter().all(|&b| b == 0)
    }

    pub fn len(&self) -> usize {
        self.bits.iter().map(|b| b.count_ones() as usize).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = u8> + '_ {
        (0u16..256).map(|b| b as u8).filter(move |&b| self.contains(b))
    }
}

impl fmt::Debug for ByteSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        let mut i = 0u16;
        while i < 256 {
            let b = i as u8;
            if self.contains(b) {
                let start = b;
                let mut end = b;
                while (end as u16) < 255 && self.contains(end + 1) {
                    end += 1;
                }
                if start == end {
                    write!(f, "{}", escape_byte(start))?;
                } else {
                    write!(f, "{}-{}", escape_byte(start), escape_byte(end))?;
                }
                i = end as u16 + 1;
            } else {
                i += 1;
            }
        }
        write!(f, "]")
    }
}

fn escape_byte(b: u8) -> String {
    if b.is_ascii_graphic() {
        (b as char).to_string()
    } else {
        format!("\\x{b:02x}")
    }
}

/// Regex syntax tree over bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Regex {
    /// Matches the empty string.
    Empty,
    /// A single byte from the set.
    Class(ByteSet),
    /// A fixed byte sequence (a literal; multi-byte UTF-8 chars land here).
    Literal(Vec<u8>),
    /// Concatenation.
    Concat(Vec<Regex>),
    /// Alternation.
    Alt(Vec<Regex>),
    /// Kleene star.
    Star(Box<Regex>),
    /// One or more.
    Plus(Box<Regex>),
    /// Zero or one.
    Opt(Box<Regex>),
    /// Bounded repetition `{min, max}`; `max == None` means unbounded.
    Repeat(Box<Regex>, u32, Option<u32>),
}

impl Regex {
    /// Does this regex match the empty string?
    pub fn nullable(&self) -> bool {
        match self {
            Regex::Empty => true,
            Regex::Class(_) => false,
            Regex::Literal(bytes) => bytes.is_empty(),
            Regex::Concat(parts) => parts.iter().all(|p| p.nullable()),
            Regex::Alt(parts) => parts.iter().any(|p| p.nullable()),
            Regex::Star(_) | Regex::Opt(_) => true,
            Regex::Plus(inner) => inner.nullable(),
            Regex::Repeat(inner, min, _) => *min == 0 || inner.nullable(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byteset_ops() {
        let mut s = ByteSet::range(b'a', b'z');
        assert!(s.contains(b'm'));
        assert!(!s.contains(b'A'));
        assert_eq!(s.len(), 26);
        s.negate();
        assert!(!s.contains(b'm'));
        assert!(s.contains(b'A'));
        assert_eq!(s.len(), 256 - 26);
    }

    #[test]
    fn dot_excludes_newline() {
        let d = ByteSet::dot();
        assert!(!d.contains(b'\n'));
        assert!(d.contains(b'x'));
        assert_eq!(d.len(), 255);
    }

    #[test]
    fn nullable() {
        assert!(Regex::Empty.nullable());
        assert!(Regex::Star(Box::new(Regex::Class(ByteSet::single(b'a')))).nullable());
        assert!(!Regex::Plus(Box::new(Regex::Class(ByteSet::single(b'a')))).nullable());
        assert!(Regex::Repeat(Box::new(Regex::Class(ByteSet::single(b'a'))), 0, Some(3)).nullable());
    }
}
