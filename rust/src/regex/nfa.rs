//! Thompson NFA construction and simulation.

use super::ast::{ByteSet, Regex};
use std::collections::BTreeSet;

pub type StateId = u32;

/// One NFA state: byte-labelled transitions plus ε-transitions.
#[derive(Clone, Debug, Default)]
pub struct State {
    /// `(byte set, target)` — taking any byte in the set moves to `target`.
    pub byte_edges: Vec<(ByteSet, StateId)>,
    /// ε-transitions.
    pub eps: Vec<StateId>,
}

/// A Thompson NFA with one start state and one accepting state.
///
/// The single-accept invariant (guaranteed by the construction) is what the
/// scanner's union construction (§3.2) relies on to attach per-terminal
/// ε-exits.
#[derive(Clone, Debug)]
pub struct Nfa {
    pub states: Vec<State>,
    pub start: StateId,
    pub accept: StateId,
}

impl Nfa {
    /// Thompson construction.
    pub fn from_regex(re: &Regex) -> Nfa {
        let mut nfa = Nfa { states: Vec::new(), start: 0, accept: 0 };
        let start = nfa.new_state();
        let accept = nfa.new_state();
        nfa.start = start;
        nfa.accept = accept;
        nfa.build(re, start, accept);
        nfa
    }

    fn new_state(&mut self) -> StateId {
        self.states.push(State::default());
        (self.states.len() - 1) as StateId
    }

    fn add_eps(&mut self, from: StateId, to: StateId) {
        self.states[from as usize].eps.push(to);
    }

    fn add_bytes(&mut self, from: StateId, set: ByteSet, to: StateId) {
        self.states[from as usize].byte_edges.push((set, to));
    }

    /// Wire `re` between `from` and `to`.
    fn build(&mut self, re: &Regex, from: StateId, to: StateId) {
        match re {
            Regex::Empty => self.add_eps(from, to),
            Regex::Class(set) => self.add_bytes(from, set.clone(), to),
            Regex::Literal(bytes) => {
                let mut cur = from;
                for (i, &b) in bytes.iter().enumerate() {
                    let next = if i + 1 == bytes.len() { to } else { self.new_state() };
                    self.add_bytes(cur, ByteSet::single(b), next);
                    cur = next;
                }
                if bytes.is_empty() {
                    self.add_eps(from, to);
                }
            }
            Regex::Concat(parts) => {
                let mut cur = from;
                for (i, part) in parts.iter().enumerate() {
                    let next = if i + 1 == parts.len() { to } else { self.new_state() };
                    self.build(part, cur, next);
                    cur = next;
                }
                if parts.is_empty() {
                    self.add_eps(from, to);
                }
            }
            Regex::Alt(branches) => {
                for branch in branches {
                    let s = self.new_state();
                    let e = self.new_state();
                    self.add_eps(from, s);
                    self.build(branch, s, e);
                    self.add_eps(e, to);
                }
            }
            Regex::Star(inner) => {
                let s = self.new_state();
                self.add_eps(from, s);
                self.add_eps(s, to);
                let e = self.new_state();
                self.build(inner, s, e);
                self.add_eps(e, s);
            }
            Regex::Plus(inner) => {
                let s = self.new_state();
                let e = self.new_state();
                self.add_eps(from, s);
                self.build(inner, s, e);
                self.add_eps(e, s);
                self.add_eps(e, to);
            }
            Regex::Opt(inner) => {
                self.add_eps(from, to);
                self.build(inner, from, to);
            }
            Regex::Repeat(inner, min, max) => {
                // Unroll: min mandatory copies, then (max-min) optional ones
                // (or a star if unbounded).
                let mut cur = from;
                for _ in 0..*min {
                    let next = self.new_state();
                    self.build(inner, cur, next);
                    cur = next;
                }
                match max {
                    None => self.build(&Regex::Star(inner.clone()), cur, to),
                    Some(max) => {
                        for i in *min..*max {
                            let next = if i + 1 == *max { to } else { self.new_state() };
                            self.add_eps(cur, to);
                            self.build(inner, cur, next);
                            cur = next;
                        }
                        if max == min {
                            self.add_eps(cur, to);
                        }
                    }
                }
            }
        }
    }

    /// ε-closure of a state set (in place, returned sorted + deduped).
    pub fn eps_closure(&self, states: &mut Vec<StateId>) {
        let mut seen: BTreeSet<StateId> = states.iter().copied().collect();
        let mut stack: Vec<StateId> = states.clone();
        while let Some(s) = stack.pop() {
            for &t in &self.states[s as usize].eps {
                if seen.insert(t) {
                    stack.push(t);
                }
            }
        }
        states.clear();
        states.extend(seen);
    }

    /// Advance a (closed) state set by one byte; result is ε-closed.
    pub fn step(&self, states: &[StateId], byte: u8) -> Vec<StateId> {
        let mut next: Vec<StateId> = Vec::new();
        for &s in states {
            for (set, t) in &self.states[s as usize].byte_edges {
                if set.contains(byte) {
                    next.push(*t);
                }
            }
        }
        self.eps_closure(&mut next);
        next
    }

    /// Initial (ε-closed) state set.
    pub fn start_set(&self) -> Vec<StateId> {
        let mut v = vec![self.start];
        self.eps_closure(&mut v);
        v
    }

    /// Full-match test.
    pub fn accepts(&self, input: &[u8]) -> bool {
        let mut cur = self.start_set();
        for &b in input {
            cur = self.step(&cur, b);
            if cur.is_empty() {
                return false;
            }
        }
        cur.contains(&self.accept)
    }

    /// All bytes with at least one outgoing edge from this state set.
    pub fn live_bytes(&self, states: &[StateId]) -> ByteSet {
        let mut out = ByteSet::empty();
        for &s in states {
            for (set, _) in &self.states[s as usize].byte_edges {
                out.union(set);
            }
        }
        out
    }

    pub fn num_states(&self) -> usize {
        self.states.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::parse;

    fn accepts(pat: &str, s: &str) -> bool {
        Nfa::from_regex(&parse(pat).unwrap()).accepts(s.as_bytes())
    }

    #[test]
    fn star_and_plus() {
        assert!(accepts("a*", ""));
        assert!(accepts("a*", "aaaa"));
        assert!(!accepts("a+", ""));
        assert!(accepts("a+b", "aab"));
    }

    #[test]
    fn bounded_repeat() {
        assert!(!accepts("a{2,3}", "a"));
        assert!(accepts("a{2,3}", "aa"));
        assert!(accepts("a{2,3}", "aaa"));
        assert!(!accepts("a{2,3}", "aaaa"));
        assert!(accepts("a{0,2}b", "b"));
        assert!(accepts("(ab){2}", "abab"));
        assert!(!accepts("(ab){2}", "ab"));
    }

    #[test]
    fn unbounded_repeat() {
        assert!(accepts("a{2,}", "aaaaa"));
        assert!(!accepts("a{2,}", "a"));
    }

    #[test]
    fn ws_recursion_from_paper() {
        // ws ::= ([ \t\n] ws)? expressed as a regex: [ \t\n]*
        assert!(accepts("[ \t\n]*", " \t\n "));
        assert!(accepts("[ \t\n]*", ""));
        assert!(!accepts("[ \t\n]*", "x"));
    }

    #[test]
    fn c_number_terminal() {
        let p = r"(-?(0|[1-9][0-9]*))(\.[0-9]+)?([eE][-+]?[0-9]+)?";
        assert!(accepts(p, "0"));
        assert!(accepts(p, "-42"));
        assert!(accepts(p, "3.14"));
        assert!(accepts(p, "1e10"));
        assert!(accepts(p, "-2.5E-3"));
        assert!(!accepts(p, "01"));
        assert!(!accepts(p, "."));
    }

    #[test]
    fn live_bytes() {
        let nfa = Nfa::from_regex(&parse("[ab]c").unwrap());
        let start = nfa.start_set();
        let live = nfa.live_bytes(&start);
        assert!(live.contains(b'a') && live.contains(b'b') && !live.contains(b'c'));
    }
}
