//! Incremental Earley parser over terminal sequences (§3.4).
//!
//! The parser runs in lock-step with the scanner: the scanner emits
//! completed terminals, the parser tracks "rules that can match the output
//! so far" and answers, for subterminal-tree traversal,
//!
//! * [`Chart::allows`] — may terminal `t` come next? (viable-prefix query)
//! * [`Chart::feed`] — consume terminal `t`, returning the new chart,
//! * [`Chart::accepts`] — is the consumed sequence a complete parse?
//!
//! Earley (not LR) because the paper's grammars are arbitrary CFGs
//! (including the ambiguous C grammar) and because viable-prefix queries
//! and *checkpointing* are natural: a chart is a persistent
//! `Vec<Arc<ItemSet>>`, so cloning a checkpoint for tree traversal or
//! speculative decoding is O(sets), not O(items).
//!
//! ε-productions are handled with the Aycock–Horspool fix: predicting a
//! nullable nonterminal also advances the predicting item.

pub mod earley;

pub use earley::{Chart, Earley};
