//! Earley recognizer with persistent, checkpointable charts.

use crate::grammar::{Cfg, Symbol, TermId};
use std::sync::Arc;

/// One Earley item: `prod` with the dot before `rhs[dot]`, started at
/// terminal position `origin`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct Item {
    prod: u32,
    dot: u32,
    origin: u32,
}

/// A closed item set at one terminal position.
#[derive(Debug)]
pub struct ItemSet {
    items: Vec<Item>,
    /// Bitset over terminals: which may come next from this set.
    expected: Vec<u64>,
    /// Completed start production spanning from position 0?
    complete: bool,
}

impl ItemSet {
    fn expects(&self, t: TermId) -> bool {
        let i = t as usize;
        self.expected[i >> 6] & (1u64 << (i & 63)) != 0
    }
}

/// The Earley machine for one grammar.
#[derive(Clone)]
pub struct Earley {
    g: Arc<Cfg>,
    term_words: usize,
}

/// A parser state after consuming some terminal sequence. Cloning is cheap
/// (persistent sets): this is the checkpoint used by tree traversal and
/// speculative rollback.
#[derive(Clone)]
pub struct Chart {
    sets: Vec<Arc<ItemSet>>,
}

impl Earley {
    pub fn new(g: Arc<Cfg>) -> Earley {
        let term_words = g.num_terminals().div_ceil(64);
        Earley { g, term_words }
    }

    pub fn grammar(&self) -> &Arc<Cfg> {
        &self.g
    }

    /// Initial chart (position 0): predictions from the start symbol.
    pub fn start_chart(&self) -> Chart {
        let mut items = Vec::new();
        for &pi in &self.g.prods_by_lhs[self.g.start as usize] {
            items.push(Item { prod: pi as u32, dot: 0, origin: 0 });
        }
        let set = self.close(items, &[], 0);
        Chart { sets: vec![Arc::new(set)] }
    }

    /// Predict/complete closure of `seed` at position `pos`, given all
    /// earlier sets.
    fn close(&self, seed: Vec<Item>, earlier: &[Arc<ItemSet>], pos: u32) -> ItemSet {
        let g = &self.g;
        let mut items: Vec<Item> = Vec::with_capacity(seed.len() * 2);
        let mut seen = std::collections::HashSet::with_capacity(seed.len() * 2);
        let mut stack: Vec<Item> = Vec::with_capacity(seed.len());
        for it in seed {
            if seen.insert(it) {
                items.push(it);
                stack.push(it);
            }
        }
        // Nonterminals already predicted at this position.
        let mut predicted = vec![false; g.nonterminals.len()];
        let mut complete = false;

        while let Some(it) = stack.pop() {
            let prod = &g.productions[it.prod as usize];
            match prod.rhs.get(it.dot as usize) {
                Some(Symbol::Nt(n)) => {
                    // Predict.
                    let n = *n as usize;
                    if !predicted[n] {
                        predicted[n] = true;
                        for &pi in &g.prods_by_lhs[n] {
                            let new = Item { prod: pi as u32, dot: 0, origin: pos };
                            if seen.insert(new) {
                                items.push(new);
                                stack.push(new);
                            }
                        }
                    }
                    // Aycock–Horspool: a nullable nonterminal may be
                    // skipped immediately.
                    if g.nullable[n] {
                        let adv = Item { prod: it.prod, dot: it.dot + 1, origin: it.origin };
                        if seen.insert(adv) {
                            items.push(adv);
                            stack.push(adv);
                        }
                    }
                }
                Some(Symbol::T(_)) => {} // awaits a scan
                None => {
                    // Complete: advance items in the origin set expecting
                    // this lhs.
                    let lhs = prod.lhs;
                    if prod.lhs == g.start && it.origin == 0 {
                        complete = true;
                    }
                    let origin_items: Vec<Item> = if it.origin == pos {
                        // Items in the set under construction.
                        items.clone()
                    } else {
                        earlier[it.origin as usize].items.clone()
                    };
                    for oit in origin_items {
                        let oprod = &g.productions[oit.prod as usize];
                        if oprod.rhs.get(oit.dot as usize) == Some(&Symbol::Nt(lhs)) {
                            let adv = Item { prod: oit.prod, dot: oit.dot + 1, origin: oit.origin };
                            if seen.insert(adv) {
                                items.push(adv);
                                stack.push(adv);
                            }
                        }
                    }
                }
            }
        }

        // Expected-terminal bitset.
        let mut expected = vec![0u64; self.term_words];
        for it in &items {
            if let Some(Symbol::T(t)) = g.productions[it.prod as usize].rhs.get(it.dot as usize) {
                let i = *t as usize;
                expected[i >> 6] |= 1u64 << (i & 63);
            }
        }
        ItemSet { items, expected, complete }
    }
}

impl Chart {
    /// Number of terminals consumed.
    pub fn pos(&self) -> usize {
        self.sets.len() - 1
    }

    fn frontier(&self) -> &ItemSet {
        self.sets.last().expect("chart has at least the start set")
    }

    /// May terminal `t` come next?
    pub fn allows(&self, t: TermId) -> bool {
        self.frontier().expects(t)
    }

    /// Bitset word-view of the allowed next terminals.
    pub fn expected_bits(&self) -> &[u64] {
        &self.frontier().expected
    }

    /// Is the sequence consumed so far a complete parse of the grammar?
    pub fn accepts(&self) -> bool {
        self.frontier().complete
    }

    /// Is the frontier non-empty (the consumed sequence a viable prefix)?
    pub fn viable(&self) -> bool {
        !self.frontier().items.is_empty()
    }

    /// Consume terminal `t`: returns the extended chart, or `None` if `t`
    /// is not a legal continuation.
    pub fn feed(&self, e: &Earley, t: TermId) -> Option<Chart> {
        if !self.allows(t) {
            return None;
        }
        let pos = self.sets.len() as u32;
        let g = &e.g;
        let mut seed = Vec::new();
        for it in &self.frontier().items {
            if g.productions[it.prod as usize].rhs.get(it.dot as usize) == Some(&Symbol::T(t)) {
                seed.push(Item { prod: it.prod, dot: it.dot + 1, origin: it.origin });
            }
        }
        let set = e.close(seed, &self.sets, pos);
        if set.items.is_empty() {
            return None;
        }
        let mut sets = self.sets.clone();
        sets.push(Arc::new(set));
        Some(Chart { sets })
    }

    /// Feed a whole terminal sequence.
    pub fn feed_all(&self, e: &Earley, ts: &[TermId]) -> Option<Chart> {
        let mut c = self.clone();
        for &t in ts {
            c = c.feed(e, t)?;
        }
        Some(c)
    }

    /// A compact fingerprint of the frontier (used to dedup decoder
    /// hypotheses and as the speculation state β — §3.6's "substate of the
    /// currently used parser").
    ///
    /// Item origins are hashed *relative* to the current position, so the
    /// same local parse situation at different output offsets fingerprints
    /// identically — that is what lets speculation priors learned on one
    /// request fire on the next (§3.6). Dedup inside one request
    /// additionally keys on `pos()`, so relativity is safe there too.
    pub fn frontier_fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let pos = self.pos() as u32;
        let mut h = std::collections::hash_map::DefaultHasher::new();
        for it in &self.frontier().items {
            (it.prod, it.dot, pos - it.origin).hash(&mut h);
        }
        h.finish()
    }
}

/// Convenience: recognize a full terminal sequence.
pub fn recognize(e: &Earley, ts: &[TermId]) -> bool {
    e.start_chart().feed_all(e, ts).map_or(false, |c| c.accepts())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grammar::builtin::fig3_expr;
    use crate::grammar::{CfgBuilder, Symbol};

    fn fig3() -> (Arc<Cfg>, Earley) {
        let g = Arc::new(fig3_expr());
        let e = Earley::new(g.clone());
        (g, e)
    }

    fn tid(g: &Cfg, name: &str) -> TermId {
        g.terminals.iter().position(|t| t.name == name).unwrap() as TermId
    }

    #[test]
    fn recognizes_fig3_sentences() {
        let (g, e) = fig3();
        let (int, lp, rp, plus) = (tid(&g, "int"), tid(&g, "'('"), tid(&g, "')'"), tid(&g, "'+'"));
        assert!(recognize(&e, &[int]));
        assert!(recognize(&e, &[lp, int, rp]));
        assert!(recognize(&e, &[int, plus, int]));
        assert!(recognize(&e, &[lp, int, plus, int, rp, plus, int]));
        assert!(!recognize(&e, &[lp, int])); // viable prefix but incomplete
        assert!(!recognize(&e, &[int, int]));
        assert!(!recognize(&e, &[plus]));
        assert!(!recognize(&e, &[]));
    }

    #[test]
    fn viable_prefix_queries() {
        let (g, e) = fig3();
        let (int, lp, rp, plus) = (tid(&g, "int"), tid(&g, "'('"), tid(&g, "')'"), tid(&g, "'+'"));
        let c = e.start_chart();
        assert!(c.allows(int) && c.allows(lp));
        assert!(!c.allows(rp) && !c.allows(plus));
        let c = c.feed(&e, lp).unwrap().feed(&e, int).unwrap();
        // After "( int": ) and + possible, int not.
        assert!(c.allows(rp) && c.allows(plus));
        assert!(!c.allows(int));
        assert!(!c.accepts());
        let c = c.feed(&e, rp).unwrap();
        assert!(c.accepts());
        // "( int )" is complete AND extensible: + still allowed.
        assert!(c.allows(plus));
    }

    #[test]
    fn feed_rejects_illegal() {
        let (g, e) = fig3();
        let rp = tid(&g, "')'");
        assert!(e.start_chart().feed(&e, rp).is_none());
    }

    #[test]
    fn nullable_rules() {
        // S ::= A "x" ; A ::= "a" | ε — Aycock-Horspool case.
        let mut b = CfgBuilder::new();
        let s = b.nonterminal("S");
        let a_nt = b.nonterminal("A");
        let x = b.literal("x");
        let a = b.literal("a");
        b.production(s, vec![Symbol::Nt(a_nt), Symbol::T(x)]);
        b.production(a_nt, vec![Symbol::T(a)]);
        b.production(a_nt, vec![]);
        let g = Arc::new(b.build(s).unwrap());
        let e = Earley::new(g.clone());
        assert!(recognize(&e, &[x]));
        assert!(recognize(&e, &[a, x]));
        assert!(!recognize(&e, &[a, a, x]));
        // From the start, both "a" and "x" must be expected.
        let c = e.start_chart();
        assert!(c.allows(a) && c.allows(x));
    }

    #[test]
    fn deeply_nullable_chain() {
        // S ::= A B "x"; A ::= ε; B ::= A A — everything nullable.
        let mut b = CfgBuilder::new();
        let s = b.nonterminal("S");
        let a_nt = b.nonterminal("A");
        let b_nt = b.nonterminal("B");
        let x = b.literal("x");
        b.production(s, vec![Symbol::Nt(a_nt), Symbol::Nt(b_nt), Symbol::T(x)]);
        b.production(a_nt, vec![]);
        b.production(b_nt, vec![Symbol::Nt(a_nt), Symbol::Nt(a_nt)]);
        let g = Arc::new(b.build(s).unwrap());
        let e = Earley::new(g.clone());
        assert!(recognize(&e, &[x]));
    }

    #[test]
    fn ambiguous_grammar_ok() {
        // E ::= E + E is ambiguous for "int + int + int" — recognizer must
        // still accept (and not blow up).
        let (g, e) = fig3();
        let (int, plus) = (tid(&g, "int"), tid(&g, "'+'"));
        let seq: Vec<TermId> = (0..21).map(|i| if i % 2 == 0 { int } else { plus }).collect();
        assert!(recognize(&e, &seq));
    }

    #[test]
    fn json_grammar_parses() {
        let g = Arc::new(crate::grammar::builtin::json());
        let e = Earley::new(g.clone());
        // Tokenize `{"a": 1}` by hand: { STRING : NUMBER }
        let lb = tid(&g, "'{'");
        let rb = tid(&g, "'}'");
        let colon = tid(&g, "':'");
        let string = tid(&g, "STRING");
        let number = tid(&g, "NUMBER");
        assert!(recognize(&e, &[lb, string, colon, number, rb]));
        assert!(recognize(&e, &[lb, rb]));
        assert!(!recognize(&e, &[lb, string, colon, rb]));
        // With interleaved whitespace terminals.
        let ws = tid(&g, "WS");
        assert!(recognize(&e, &[lb, ws, string, colon, ws, number, ws, rb, ws]));
        // Two consecutive WS is NOT derivable (ws ::= WS?).
        assert!(!recognize(&e, &[lb, ws, ws, rb]));
    }

    #[test]
    fn checkpoint_clone_is_independent() {
        let (g, e) = fig3();
        let (int, plus) = (tid(&g, "int"), tid(&g, "'+'"));
        let c0 = e.start_chart();
        let c1 = c0.feed(&e, int).unwrap();
        let c2 = c1.feed(&e, plus).unwrap();
        // c1 still accepts; c2 doesn't.
        assert!(c1.accepts());
        assert!(!c2.accepts());
        assert_eq!(c1.pos(), 1);
        assert_eq!(c2.pos(), 2);
    }

    #[test]
    fn fingerprint_distinguishes_states() {
        let (g, e) = fig3();
        let (int, lp) = (tid(&g, "int"), tid(&g, "'('"));
        let a = e.start_chart().feed(&e, int).unwrap();
        let b = e.start_chart().feed(&e, lp).unwrap();
        assert_ne!(a.frontier_fingerprint(), b.frontier_fingerprint());
    }
}
